"""Degraded-wafer throughput benchmark: accepted throughput and latency
vs. the fraction of failed fabric links.

Wafer-scale integration makes dead links/routers the norm (known-good-die
yield, post-bond defects), so the interesting number is not peak throughput
but how gracefully the switch-less fabric degrades.  The grid is the
registered `bench_faults` scenario (repro.exp): one independently sampled
link-failure `FaultSpec` population per failure rate, one lane per
(failure rate, seed) with per-lane fault-aware routing tables, the WHOLE
grid lowered to ONE compiled batched scan (`BatchedSweep.run_lanes` stacks
the per-lane fault tables and vmaps the shared step over them) —
`compiles == 1` in the output is the proof.

Writes `BENCH_faults.json` (repo root) with the per-rate seed-averaged
curve; `monotone_within_tol` checks that accepted throughput never
*increases* materially as more links fail.

    python -m benchmarks.bench_faults           (repo root, pip install -e .)
    PYTHONPATH=src python -m benchmarks.bench_faults       (no install)
"""
from __future__ import annotations

import json
import os

DEFAULT_FRACS = (0.0, 0.04, 0.08, 0.12, 0.16)
DEFAULT_SEEDS = (0, 1)
# a shade above the pristine saturation point, so accepted throughput
# tracks the surviving capacity instead of the offered load
DEFAULT_OFFERED = 0.55
MONOTONE_TOL = 0.03   # allowed non-monotone wiggle (flits/cycle/chip)


def bench(fracs=DEFAULT_FRACS, seeds=DEFAULT_SEEDS,
          offered=DEFAULT_OFFERED, warmup=300, measure=1500) -> dict:
    from repro.exp import registry as SC
    from repro.exp.provenance import provenance
    from repro.exp.runner import run_experiment

    spec = SC.bench_faults_spec(fracs=fracs, seeds=seeds, offered=offered,
                                warmup=warmup, measure=measure)
    res = run_experiment(spec)
    [grid] = res.grids
    fracs, seeds = list(fracs), list(seeds)

    rows = res.rows()
    thr = [r["throughput"] for r in rows]
    lat = [r["latency"] for r in rows]
    monotone = all(thr[i + 1] <= thr[i] + MONOTONE_TOL
                   for i in range(len(thr) - 1))
    return dict(
        net="switchless a=2 b=2 m=2 n=4 g=5 (updown, minimal)",
        scenario=spec.name,
        channels=grid.topology.build().num_channels,
        offered_per_chip=offered,
        requested_fracs=fracs,
        achieved_fracs=grid.fault_fracs,
        seeds=seeds,
        lanes=len(fracs) * len(seeds),
        cycles_per_lane=warmup + measure,
        throughput_per_chip=thr,
        avg_latency=lat,
        per_seed_throughput=[[grid.result(i, 0, j).throughput_per_chip
                              for j in range(len(seeds))]
                             for i in range(len(fracs))],
        delivered_pkts=[[grid.result(i, 0, j).delivered_pkts
                         for j in range(len(seeds))]
                        for i in range(len(fracs))],
        compiles=grid.compile_count,
        wall_s=res.wall_s,
        monotone_within_tol=monotone,
        monotone_tol=MONOTONE_TOL,
        provenance=provenance(spec),
    )


def write(out: dict, path: str | None = None) -> str:
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_faults.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return os.path.abspath(path)


def main() -> None:
    out = bench()
    path = write(out)
    print(json.dumps(out, indent=2))
    print(f"\nwrote {path}")
    if out["compiles"] != 1:
        raise SystemExit(f"expected exactly 1 compile, got {out['compiles']}")
    if not out["monotone_within_tol"]:
        raise SystemExit("degraded-throughput curve is not monotone")


if __name__ == "__main__":
    main()
