"""Degraded-wafer throughput benchmark: accepted throughput and latency
vs. the fraction of failed fabric links.

Wafer-scale integration makes dead links/routers the norm (known-good-die
yield, post-bond defects), so the interesting number is not peak throughput
but how gracefully the switch-less fabric degrades.  This benchmark samples
one random link-failure `FaultSet` per (failure-rate, seed) lane, rebuilds
fault-aware routing per lane, and runs the WHOLE failure-rate x seed grid
as ONE compiled batched scan (`BatchedSweep.run_faults` stacks the per-lane
fault tables and vmaps the shared step over them) — `compiles == 1` in the
output is the proof.

Writes `BENCH_faults.json` (repo root) with the per-rate seed-averaged
curve; `monotone_within_tol` checks that accepted throughput never
*increases* materially as more links fail.

    PYTHONPATH=src python benchmarks/bench_faults.py
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

DEFAULT_FRACS = (0.0, 0.04, 0.08, 0.12, 0.16)
DEFAULT_SEEDS = (0, 1)
# a shade above the pristine saturation point, so accepted throughput
# tracks the surviving capacity instead of the offered load
DEFAULT_OFFERED = 0.55
MONOTONE_TOL = 0.03   # allowed non-monotone wiggle (flits/cycle/chip)


def bench(fracs=DEFAULT_FRACS, seeds=DEFAULT_SEEDS,
          offered=DEFAULT_OFFERED, warmup=300, measure=1500) -> dict:
    from repro.core import topology as T
    from repro.core import traffic as TR
    from repro.core.simulator import SimConfig, Simulator

    net = T.build_switchless(
        T.SwitchlessParams(a=2, b=2, m=2, n=4, noc=2, g=5), "bench-faults")
    cfg = SimConfig(warmup=warmup, measure=measure, vc_mode="updown",
                    route_mode="min", vcs_per_class=2)
    fracs, seeds = list(fracs), list(seeds)
    # one independently sampled fault set per (failure rate, seed) lane
    fault_grid = [
        [T.sample_link_faults(net, f, np.random.default_rng(1000 * i + s))
         for s in seeds]
        for i, f in enumerate(fracs)]
    sim = Simulator(net, cfg, TR.uniform(net))
    grid = sim.sweep_faults(offered, fault_grid, seeds=seeds)

    rows = grid.mean_over_seeds()
    thr = [r.throughput_per_chip for r in rows]
    lat = [r.avg_latency for r in rows]
    monotone = all(thr[i + 1] <= thr[i] + MONOTONE_TOL
                   for i in range(len(thr) - 1))
    return dict(
        net="switchless a=2 b=2 m=2 n=4 g=5 (updown, minimal)",
        channels=net.num_channels,
        offered_per_chip=offered,
        requested_fracs=fracs,
        achieved_fracs=grid.fault_fracs,
        seeds=seeds,
        lanes=len(fracs) * len(seeds),
        cycles_per_lane=warmup + measure,
        throughput_per_chip=thr,
        avg_latency=lat,
        per_seed_throughput=[[grid.result(i, j).throughput_per_chip
                              for j in range(len(seeds))]
                             for i in range(len(fracs))],
        delivered_pkts=[[grid.result(i, j).delivered_pkts
                         for j in range(len(seeds))]
                        for i in range(len(fracs))],
        compiles=grid.compile_count,
        wall_s=grid.wall_s,
        monotone_within_tol=monotone,
        monotone_tol=MONOTONE_TOL,
    )


def write(out: dict, path: str | None = None) -> str:
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_faults.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return os.path.abspath(path)


def main() -> None:
    out = bench()
    path = write(out)
    print(json.dumps(out, indent=2))
    print(f"\nwrote {path}")
    if out["compiles"] != 1:
        raise SystemExit(f"expected exactly 1 compile, got {out['compiles']}")
    if not out["monotone_within_tol"]:
        raise SystemExit("degraded-throughput curve is not monotone")


if __name__ == "__main__":
    main()
