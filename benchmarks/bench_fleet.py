"""Wafer-fleet Monte Carlo benchmark: yield distributions over sampled
defect maps and fault/repair schedules.

The fleet is the registered `smoke_fleet` spec (repro.exp.fleet): the
small up*/down*-routable wafer running three reliability levels — a
pristine reference, clustered wear-out that grows over two onsets and
then repairs one increment (a shrinking epoch, statically proven
restart-safe by `repro.analysis.check --spec`), and mid-run router death
with the age-based reaper draining the stranded population.  Every
sampled wafer is one sweep-seed lane, so the WHOLE fleet — 8 defect maps
fast, 128 with `--full` — runs through `BatchedSweep.run_lanes`' single
compiled dispatch per fault level grid; the per-record `compile_count`
certifies that all samples shared executables.

Writes `BENCH_fleet.json` (repo root): per (cell, level) records with
p10/p50/p90 throughput and latency over the sampled wafers, the yield
fraction against the pristine median, exact stranded max/mean, and the
reaper's drop totals.

`--serve-inbox DIR` additionally re-emits the fleet as a multi-tenant
`repro.exp.serve` inbox (one submission per wafer, one tenant each) —
the serve-scheduler stress form of the same fleet:

    python -m benchmarks.bench_fleet              (repo root, pip install -e .)
    python -m benchmarks.bench_fleet --full       (128-wafer distribution)
    python -m benchmarks.bench_fleet --serve-inbox /tmp/fleet_inbox
    PYTHONPATH=src python -m benchmarks.bench_fleet          (no install)
"""
from __future__ import annotations

import argparse
import json
import os


def bench(fast: bool = True) -> dict:
    from repro.exp.fleet import run_fleet, smoke_fleet
    from repro.exp.provenance import provenance

    fleet = smoke_fleet(fast=fast)
    res = run_fleet(fleet)
    exp = res.experiment
    spec = fleet.to_experiment()
    reaper_on = fleet.routing.reaper.park_age > 0
    # the acceptance posture: every level's samples shared one
    # executable (<= 1 compile per grid; 0 on cache reuse), and with
    # the reaper on, no run ends with an unbounded stranded population
    # unless the reaper was off
    compiles = [g.compile_count for g in exp.grids]
    return dict(
        fleet=fleet.name,
        net=fleet.topology.label,
        channels=fleet.topology.build().num_channels,
        samples=fleet.samples,
        offered_per_chip=fleet.offered,
        pattern=fleet.traffic.label,
        cycles_per_lane=fleet.warmup + fleet.measure,
        reap_age=fleet.routing.reaper.park_age,
        yield_threshold=fleet.yield_threshold,
        levels=[f.label for f in fleet.levels],
        onset_cycles=[list(f.onsets) for f in fleet.levels],
        repair_cycles=[list(f.repairs) for f in fleet.levels],
        records=res.records,
        compiles=compiles,
        reaper_on=reaper_on,
        wall_s=exp.wall_s,
        provenance=provenance(spec),
    )


def write(out: dict, path: str | None = None) -> str:
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_fleet.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return os.path.abspath(path)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="128-wafer distribution (fast runs 8)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--serve-inbox", default=None, metavar="DIR",
                    help="also emit the fleet as a multi-tenant serve "
                         "inbox (one submission file per wafer)")
    args = ap.parse_args(argv)
    out = bench(fast=not args.full)
    path = write(out, args.out)
    print(json.dumps(out, indent=2))
    print(f"\nwrote {path}")
    if args.serve_inbox:
        from repro.exp.fleet import fleet_inbox, smoke_fleet
        paths = fleet_inbox(smoke_fleet(fast=not args.full),
                            args.serve_inbox)
        print(f"wrote {len(paths)} serve submissions to "
              f"{os.path.abspath(args.serve_inbox)}")
    if any(c > 1 for c in out["compiles"]):
        raise SystemExit(f"expected <= 1 compile per grid (all samples "
                         f"share executables), got {out['compiles']}")
    if out["reaper_on"]:
        bad = [r["level"] for r in out["records"]
               if r["stranded_max"] > 0 and r["reaped_total"] == 0]
        if bad:
            raise SystemExit(f"reaper enabled but levels {bad} ended "
                             f"with a stranded population and zero "
                             f"reaps")


if __name__ == "__main__":
    main()
