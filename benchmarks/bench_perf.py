"""Standing perf-trajectory benchmark: the numbers every perf PR must move.

Runs a FIXED scenario set through the declarative runner —

  bench_sweep   the headline engine grid (24 lanes x 600 cycles, one
                C-group): cycles/s is compared against the frozen
                `BENCH_sweep.json` baseline AND bit-checked lane-for-lane
                against engine-sequential `Simulator.run`
  smoke         seconds-scale sanity point (tiny grid, dispatch-bound)
  fig11         the paper's radix-16 global network (reduced W-groups),
                on the OCCUPANCY-COMPACTED cycle step (`step_impl=
                "compact"`, the perf path — bit-identical to the jnp
                oracle, pinned by tests/test_compact_step.py and
                re-checked against the fused step at this scale on
                every run: `max_throughput_deviation` in the record
                must be 0.0)
  smoke_fused   the fused smoke grid dispatched with
                `REPRO_CHANNEL_SHARDS=2` — the 2-D (lanes x shards)
                placement point of the trajectory
  yield_curve   the radix-32-class warm-fault grid (2 routing cells, so
                it also exercises the multi-device cell round-robin)

and writes `BENCH_perf.json` (repo root): per-scenario cycles/s and
lanes/s, the compile/run wall split, device count, compile counts, the
device placement each scenario's grids actually ran on (`placements`,
`pad_fraction` — see docs/performance.md), and `speedup_vs_previous`
against the previous BENCH_perf.json — the trajectory every future perf
PR appends to.  Timings use the SECOND `run_experiment` call (zero
compiles, steady state); compile time is reported separately from the
first call.  A `kernels` section times the `repro.kernels.netsim`
`cycle_core` Pallas kernel standalone: interpret-mode ms/call on every
backend, plus a compiled (non-interpret) attempt that records
`supported: false` with the error on backends (CPU) whose Pallas
lowering only interprets.

The bench_sweep and fig11 points double as the PERF-REGRESSION GUARD:
when a previous BENCH_perf.json of the same mode exists and either
scenario's `speedup_vs_previous` drops below 0.85, the benchmark exits
nonzero (after writing the file) unless `--allow-regression` is given —
CI fails on accidental engine slowdowns instead of silently recording
them.  Every scenario record also carries the compact-step telemetry
(`occupancy_peak` / `compact_capacity` / `superstep` / `escalations`),
so the trajectory documents how much of each ladder rung the workload
actually used.

Unless already set in the environment, this benchmark defaults the two
engine perf knobs to their tuned values — `REPRO_HOST_DEVICES=4` (shard
lanes over 4 forced host devices) and `REPRO_CPU_RUNTIME=legacy` (the
pre-thunk XLA:CPU runtime, ~4x faster on this small-op scan; see
docs/performance.md) — and records both in the output, so the committed
file documents exactly how it was produced.  Export either knob to
override (e.g. `REPRO_HOST_DEVICES=1` for a single-device trajectory
point).  `--fast` trims the heavy scenarios' cycle budgets for CI smoke
runs; cycles/s stays comparable per (scenario, mode) pair, and
`speedup_vs_previous` only compares matching modes.

    python -m benchmarks.bench_perf
    python -m benchmarks.bench_perf --fast          (CI perf-smoke)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_perf.json")


def _scenarios(fast: bool):
    """(name, spec, env) triples; --fast trims the heavy grids' cycle
    budgets.  `env` is extra environment set around that scenario's runs
    (the channel-sharding knob is read per dispatch)."""
    import dataclasses

    from repro.exp import registry as SC
    out = [("bench_sweep", SC.bench_sweep_spec(), {}),
           ("smoke", SC.smoke_spec(), {})]
    fig11 = SC.get_scenario("fig11")
    yc = SC.get_scenario("yield_curve")
    if fast:
        fig11 = fig11.with_axes(warmup=50, measure=150)
        # keep the warm onset inside the trimmed run (scale with budget)
        trim_onset = 30 + 120 // 4
        faults = tuple(
            f if f.is_none else dataclasses.replace(f, onsets=(trim_onset,))
            for f in yc.axes.faults)
        yc = yc.with_axes(warmup=30, measure=120, faults=faults)
    # fig11 runs on the occupancy-compacted step — the perf path this
    # trajectory tracks (bit-identical to the oracle:
    # tests/test_compact_step.py pins compact == jnp, and
    # `_fig11_parity` below re-checks it against the fused step at this
    # scale on every benchmark run).  It runs at K=1: the sequential-
    # lane dispatch already keeps the scan body large, and unrolling
    # (REPRO_SUPERSTEP=4, parity-pinned by the same test file) measures
    # ~12% SLOWER here — the superstep's amortization only pays on
    # dispatch-bound grids, not this execution-bound one.
    fig11 = dataclasses.replace(
        fig11, routings=tuple(dataclasses.replace(r, step_impl="compact")
                              for r in fig11.routings))
    out += [("fig11", fig11, {}),
            ("smoke_fused", SC.get_scenario("smoke_fused"),
             {"REPRO_CHANNEL_SHARDS": "2"}),
            ("yield_curve", yc, {})]
    return out


def _cycles_total(spec) -> int:
    return spec.num_lanes * (spec.axes.warmup + spec.axes.measure)


def _bench_scenario(name, spec, env=None):
    from repro.exp.runner import run_experiment

    saved = {}
    for k, v in (env or {}).items():
        saved[k] = os.environ.get(k)
        os.environ[k] = v
    try:
        first = run_experiment(spec)                # compile + run
        t0 = time.perf_counter()
        steady = run_experiment(spec)               # 0 compiles
        wall = time.perf_counter() - t0
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
    cyc = _cycles_total(spec)
    return steady, dict(
        lanes=spec.num_lanes,
        grids=spec.num_grids,
        cycles_per_lane=spec.axes.warmup + spec.axes.measure,
        cycles_total=cyc,
        wall_s=wall,
        compile_s=first.compile_s,
        first_call_compiles=sum(first.compile_counts),
        max_compiles_per_grid=first.max_compiles_per_grid,
        steady_compiles=sum(steady.compile_counts),
        cycles_per_s=cyc / wall,
        lanes_per_s=spec.num_lanes / wall,
        step_impl=sorted({r.step_impl for r in spec.routings}),
        grant_impl=sorted({r.grant_impl for r in spec.routings}),
        # arbitration form each grid compiled ("combined" | "two_pass");
        # a fused scenario reporting "two_pass" hit the packed-key int32
        # overflow fallback (see docs/performance.md / repro.analysis)
        grant_form=sorted({g.grant_form for g in steady.grids}),
        placements=sorted({g.placement for g in steady.grids}),
        pad_fraction=max((g.pad_fraction for g in steady.grids),
                         default=0.0),
        # compact-step telemetry (zeros / 1 on non-compact scenarios):
        # the whole-run live-row high-water mark vs the ladder rung each
        # grid compiled at, the superstep unroll, and how many grids had
        # to re-dispatch at a larger rung (should stay 0 — an escalation
        # means the starting rung is undersized for this workload)
        occupancy_peak=max((g.occupancy_peak for g in steady.grids),
                           default=0),
        compact_capacity=sorted({g.compact_capacity for g in steady.grids}),
        superstep=sorted({g.superstep for g in steady.grids}),
        escalations=sum(g.escalations for g in steady.grids),
        escalation_compiles=sum(g.escalation_compiles
                                for g in steady.grids),
    )


def _bench_sweep_parity(spec, rec, res) -> None:
    """Headline extras for bench_sweep: bit-parity vs engine-sequential
    runs and speedup vs the frozen BENCH_sweep.json cycles/s.  `res` is
    the steady `ExperimentResult` the timing pass already produced."""
    from repro.core.simulator import Simulator
    from repro.exp.runner import cells

    grid = res.grids[0]
    [cell] = list(cells(spec))
    rates, seeds = list(spec.axes.rates), list(spec.axes.seeds)
    sim = Simulator(cell.net, cell.cfg, cell.pattern)
    seq = {(r, s): sim.run(r, seed=s) for r in rates for s in seeds}
    dev = max(
        abs(seq[r, s].throughput_per_chip
            - grid.result(0, i, j).throughput_per_chip)
        / max(seq[r, s].throughput_per_chip, 1e-9)
        for i, r in enumerate(rates) for j, s in enumerate(seeds))
    rec["max_throughput_deviation"] = dev
    base_path = os.path.join(os.path.dirname(DEFAULT_OUT),
                             "BENCH_sweep.json")
    try:
        with open(base_path) as f:
            base = json.load(f)["batched_cycles_per_s"]
        rec["bench_sweep_baseline_cycles_per_s"] = base
        rec["speedup_vs_bench_sweep_baseline"] = rec["cycles_per_s"] / base
    except (OSError, KeyError, json.JSONDecodeError):
        pass


def _fig11_parity(spec, rec, res) -> None:
    """Compact-vs-fused bit-parity at fig11 scale: re-run the scenario
    on the fused step and record the max relative throughput deviation.
    The fused step is itself pinned bit-identical to the jnp oracle
    (tests/test_fused_step.py), so 0.0 here chains the compacted fig11
    counters to the oracle without paying for a paper-scale jnp run."""
    import dataclasses

    from repro.exp.runner import run_experiment

    ref_spec = dataclasses.replace(
        spec, routings=tuple(dataclasses.replace(r, step_impl="fused")
                             for r in spec.routings))
    ref = run_experiment(ref_spec)
    rates, seeds = spec.axes.rates, spec.axes.seeds
    n_faults = max(len(spec.axes.faults), 1)
    dev = max(
        abs(gr.result(f, i, j).throughput_per_chip
            - gn.result(f, i, j).throughput_per_chip)
        / max(gr.result(f, i, j).throughput_per_chip, 1e-9)
        for gr, gn in zip(ref.grids, res.grids)
        for f in range(n_faults)
        for i in range(len(rates)) for j in range(len(seeds)))
    rec["max_throughput_deviation"] = dev


def _bench_kernels(fast: bool) -> dict:
    """Standalone timing of the netsim `cycle_core` Pallas kernel on
    synthetic fused-step-shaped inputs: interpret-mode ms/call, plus a
    compiled (non-interpret) attempt.  On CPU the Pallas lowering only
    interprets, so the compiled record documents `supported: false` with
    the error; on TPU it carries the real compiled timing."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.netsim import cycle_core

    N, E = (1024, 128) if fast else (4096, 512)
    rng = np.random.default_rng(0)
    out = jnp.asarray(rng.integers(-1, E, N), jnp.int32)
    itime = jnp.asarray(rng.integers(0, 1000, N), jnp.int32)
    ok = jnp.asarray(rng.random(N) < 0.7) & (out >= 0)
    ch_ok = jnp.asarray(rng.random(E) < 0.9)
    r2 = 1 << (N - 1).bit_length()
    rec = dict(n_rows=N, n_channels=E, backend=jax.default_backend())

    def timed(interpret):
        f = jax.jit(lambda o, t, k, c: cycle_core(
            o, t, k, c, r2=r2, interpret=interpret))
        jax.block_until_ready(f(out, itime, ok, ch_ok))   # compile
        iters = 2 if fast else 5
        t0 = time.perf_counter()
        for _ in range(iters):
            res = f(out, itime, ok, ch_ok)
        jax.block_until_ready(res)
        return (time.perf_counter() - t0) / iters * 1000

    rec["interpret_ms_per_call"] = timed(True)
    try:
        rec["compiled"] = dict(supported=True, ms_per_call=timed(False))
    except Exception as e:
        rec["compiled"] = dict(
            supported=False,
            error=f"{type(e).__name__}: {str(e)[:200]}")
    return {"netsim_cycle_core": rec}


def _legacy_runtime_supported() -> bool:
    """Probe whether this jaxlib still accepts the legacy-CPU-runtime
    flag.  Must run in a SUBPROCESS: XLA parses XLA_FLAGS at backend
    init and dies on unknown flags, so probing in-process would take the
    benchmark down with it on builds that dropped the flag."""
    import subprocess
    import sys
    probe = ("import os; "
             "os.environ['XLA_FLAGS'] = '--xla_cpu_use_thunk_runtime=false'; "
             "import jax; jax.devices()")
    try:
        return subprocess.run([sys.executable, "-c", probe],
                              capture_output=True,
                              timeout=300).returncode == 0
    except Exception:
        return False


def _previous(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def bench(fast: bool = False, out_path: str = DEFAULT_OUT) -> dict:
    import jax
    from repro.exp.provenance import provenance

    prev = _previous(out_path)
    prev_mode_match = prev.get("mode") == ("fast" if fast else "full")
    scenarios = {}
    for name, spec, env in _scenarios(fast):
        print(f"[bench_perf] {name}: {spec.num_lanes} lanes x "
              f"{spec.axes.warmup + spec.axes.measure} cycles ...",
              flush=True)
        steady, rec = _bench_scenario(name, spec, env)
        if name == "bench_sweep":
            _bench_sweep_parity(spec, rec, steady)
        if name == "fig11":
            _fig11_parity(spec, rec, steady)
        if prev_mode_match:
            p = prev.get("scenarios", {}).get(name)
            if p and p.get("cycles_per_s"):
                rec["prev_cycles_per_s"] = p["cycles_per_s"]
                rec["speedup_vs_previous"] = (rec["cycles_per_s"]
                                              / p["cycles_per_s"])
        scenarios[name] = rec
        print(f"[bench_perf]   {rec['cycles_per_s']:.0f} cycles/s, "
              f"{rec['wall_s']:.2f}s run + {rec['compile_s']:.2f}s "
              f"compile ({rec['first_call_compiles']} compiles, "
              f"placement {','.join(rec['placements'])})",
              flush=True)
    print("[bench_perf] kernels: netsim cycle_core ...", flush=True)
    kernels = _bench_kernels(fast)
    return dict(
        mode="fast" if fast else "full",
        device_count=len(jax.devices()),
        repro_host_devices=os.environ.get("REPRO_HOST_DEVICES"),
        repro_cpu_runtime=os.environ.get("REPRO_CPU_RUNTIME"),
        scenarios=scenarios,
        kernels=kernels,
        provenance=provenance(),
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.bench_perf", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--fast", action="store_true",
                    help="trimmed cycle budgets (CI perf-smoke)")
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--allow-regression", action="store_true",
                    help="record a bench_sweep slowdown (< 0.85x vs the "
                         "previous BENCH_perf.json) instead of exiting "
                         "nonzero")
    args = ap.parse_args(argv)
    # tuned defaults, recorded in the output; env overrides.  Must happen
    # before the first repro/jax import (the knobs set XLA_FLAGS).  The
    # legacy runtime is only defaulted on when the installed jaxlib still
    # accepts the flag, so an unpinned-jax CI job degrades to the default
    # runtime instead of dying at backend init.
    os.environ.setdefault("REPRO_HOST_DEVICES", "4")
    if "REPRO_CPU_RUNTIME" not in os.environ and _legacy_runtime_supported():
        os.environ["REPRO_CPU_RUNTIME"] = "legacy"
    import repro  # noqa: F401  (applies the knobs before jax init)
    path = os.path.abspath(args.out or DEFAULT_OUT)
    out = bench(fast=args.fast, out_path=path)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps(out, indent=2))
    print(f"\nwrote {path}")
    # perf-regression guard: neither the headline grid nor the fig11
    # hot path may silently slow down.  The file above is written either
    # way (the regression is recorded); only the exit status flags it.
    for guard in ("bench_sweep", "fig11"):
        spd = out["scenarios"].get(guard, {}).get("speedup_vs_previous")
        if spd is not None and spd < 0.85 and not args.allow_regression:
            print(f"[bench_perf] REGRESSION: {guard} at {spd:.3f}x of "
                  f"the previous trajectory point (< 0.85x). Pass "
                  f"--allow-regression to record it anyway.",
                  file=sys.stderr, flush=True)
            return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
