"""Load-latency sweep benchmark: seed baseline vs. the batch-parallel engine.

Times the same (rate x seed) sweep three ways on a small switch-less config:

  seed        the frozen PR-0 monolithic simulator (`seed_reference.py`),
              one jitted `lax.scan` per lane — what the paper-figure grid
              cost before this engine existed
  sequential  the modular engine, still one scan per lane (`Simulator.run`)
  batched     all lanes vmapped into ONE jitted scan (`BatchedSweep`)

and writes `BENCH_sweep.json` (repo root).  The headline `speedup` is
batched vs. the seed baseline — the wall-clock the refactor actually bought
(packed packet records, request-grid slicing, dense credit/busy/stats
updates, plus whole-sweep batching); `speedup_vs_engine_sequential` isolates
the batching itself.  `max_throughput_deviation` checks that the batched
lanes reproduce per-rate sequential runs (they are bit-identical by
construction).

    PYTHONPATH=src python benchmarks/bench_sweep.py
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

DEFAULT_RATES = (0.2, 0.4, 0.6, 0.8, 1.0, 1.2, 1.4, 1.6)
DEFAULT_SEEDS = (0, 1, 2)


def bench(rates=DEFAULT_RATES, seeds=DEFAULT_SEEDS,
          warmup=100, measure=500) -> dict:
    from repro.core import topology as T
    from repro.core import traffic as TR
    from repro.core.simulator import SimConfig, Simulator
    from benchmarks.seed_reference import SeedSimulator

    net = T.build_switchless(
        T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=1), "bench-sweep")
    cfg = SimConfig(warmup=warmup, measure=measure, vcs_per_class=2)
    pattern = TR.uniform(net)
    rates, seeds = list(rates), list(seeds)
    lanes = len(rates) * len(seeds)
    cycles_total = (warmup + measure) * lanes

    # --- batched: whole sweep in one jitted scan ----------------------
    sim = Simulator(net, cfg, pattern)
    grid = sim.sweep_grid(rates, seeds)           # compile + run
    compile_wall = grid.wall_s
    grid = sim.sweep_grid(rates, seeds)           # steady-state timing
    t_batched = grid.wall_s

    # --- engine sequential: one scan per lane -------------------------
    sim.run(rates[0], seed=seeds[0])              # compile
    t0 = time.perf_counter()
    seq = {(r, s): sim.run(r, seed=s) for r in rates for s in seeds}
    t_seq = time.perf_counter() - t0

    # --- seed baseline: the pre-engine monolithic simulator -----------
    seed_sim = SeedSimulator(net, cfg, pattern)
    seed_sim.run(rates[0])                        # compile
    t0 = time.perf_counter()
    for r in rates:
        for _ in seeds:
            seed_sim.run(r)
    t_seed = time.perf_counter() - t0

    max_dev = max(
        abs(seq[r, s].throughput_per_chip
            - grid.result(i, j).throughput_per_chip)
        / max(seq[r, s].throughput_per_chip, 1e-9)
        for i, r in enumerate(rates) for j, s in enumerate(seeds))

    return dict(
        net="switchless a=1 b=1 m=2 n=6 (one C-group)",
        channels=net.num_channels,
        rates=rates, seeds=seeds, lanes=lanes,
        cycles_per_lane=warmup + measure,
        seed_sequential_wall_s=t_seed,
        engine_sequential_wall_s=t_seq,
        batched_wall_s=t_batched,
        batched_first_call_s=compile_wall,
        speedup=t_seed / t_batched,                 # headline: vs PR-0 seed
        speedup_vs_engine_sequential=t_seq / t_batched,
        batched_cycles_per_s=cycles_total / t_batched,
        seed_cycles_per_s=cycles_total / t_seed,
        batched_compiles=grid.compile_count,        # 0: cache-hit on 2nd call
        max_throughput_deviation=max_dev,
    )


def write(out: dict, path: str | None = None) -> str:
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_sweep.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return os.path.abspath(path)


def main() -> None:
    out = bench()
    path = write(out)
    print(json.dumps(out, indent=2))
    print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
