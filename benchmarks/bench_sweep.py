"""Load-latency sweep benchmark: seed baseline vs. the batch-parallel engine.

Times the same (rate x seed) sweep three ways on a small switch-less config
(the registered `bench_sweep` scenario — every configuration here comes
from its `ExperimentSpec`, see repro.exp):

  seed        the frozen PR-0 monolithic simulator (`seed_reference.py`),
              one jitted `lax.scan` per lane — what the paper-figure grid
              cost before this engine existed
  sequential  the modular engine, still one scan per lane (`Simulator.run`)
  batched     all lanes lowered through `run_experiment` into ONE jitted
              scan (`BatchedSweep.run_lanes`)

and writes `BENCH_sweep.json` (repo root).  The headline `speedup` is
batched vs. the seed baseline — the wall-clock the refactor actually bought
(packed packet records, request-grid slicing, dense credit/busy/stats
updates, plus whole-sweep batching); `speedup_vs_engine_sequential` isolates
the batching itself.  `max_throughput_deviation` checks that the batched
lanes reproduce per-rate sequential runs (they are bit-identical by
construction).

    python -m benchmarks.bench_sweep            (repo root, pip install -e .)
    PYTHONPATH=src python -m benchmarks.bench_sweep        (no install)
"""
from __future__ import annotations

import json
import os
import time

DEFAULT_RATES = (0.2, 0.4, 0.6, 0.8, 1.0, 1.2, 1.4, 1.6)
DEFAULT_SEEDS = (0, 1, 2)


def bench(rates=DEFAULT_RATES, seeds=DEFAULT_SEEDS,
          warmup=100, measure=500) -> dict:
    from repro.core.simulator import Simulator
    from repro.exp import registry as SC
    from repro.exp.provenance import provenance
    from repro.exp.runner import cells, run_experiment
    from benchmarks.seed_reference import SeedSimulator

    spec = SC.bench_sweep_spec(rates=rates, seeds=seeds,
                               warmup=warmup, measure=measure)
    [cell] = list(cells(spec))   # one (topology, routing, traffic) grid
    rates, seeds = list(spec.axes.rates), list(spec.axes.seeds)
    lanes = len(rates) * len(seeds)
    cycles_total = (warmup + measure) * lanes

    # --- batched: the declarative lowering, whole sweep in one scan ---
    res = run_experiment(spec)                    # compile + run
    compile_s = res.compile_s                     # exact split (AOT cache)
    first_wall = res.wall_s
    first_compiles = res.max_compiles_per_grid
    res = run_experiment(spec)                    # steady-state timing
    t_batched = res.wall_s
    grid = res.grids[0]

    # --- engine sequential: one scan per lane -------------------------
    sim = Simulator(cell.net, cell.cfg, cell.pattern)
    sim.run(rates[0], seed=seeds[0])              # compile
    t0 = time.perf_counter()
    seq = {(r, s): sim.run(r, seed=s) for r in rates for s in seeds}
    t_seq = time.perf_counter() - t0

    # --- seed baseline: the pre-engine monolithic simulator -----------
    seed_sim = SeedSimulator(cell.net, cell.cfg, cell.pattern)
    seed_sim.run(rates[0])                        # compile
    t0 = time.perf_counter()
    for r in rates:
        for _ in seeds:
            seed_sim.run(r)
    t_seed = time.perf_counter() - t0

    max_dev = max(
        abs(seq[r, s].throughput_per_chip
            - grid.result(0, i, j).throughput_per_chip)
        / max(seq[r, s].throughput_per_chip, 1e-9)
        for i, r in enumerate(rates) for j, s in enumerate(seeds))

    return dict(
        net="switchless a=1 b=1 m=2 n=6 (one C-group)",
        scenario=spec.name,
        channels=cell.net.num_channels,
        rates=rates, seeds=seeds, lanes=lanes,
        cycles_per_lane=warmup + measure,
        seed_sequential_wall_s=t_seed,
        engine_sequential_wall_s=t_seq,
        batched_wall_s=t_batched,
        batched_first_call_s=first_wall + compile_s,
        batched_compile_s=compile_s,
        speedup=t_seed / t_batched,                 # headline: vs PR-0 seed
        speedup_vs_engine_sequential=t_seq / t_batched,
        batched_cycles_per_s=cycles_total / t_batched,
        seed_cycles_per_s=cycles_total / t_seed,
        first_call_compiles=first_compiles,         # 1: one compile per grid
        batched_compiles=grid.compile_count,        # 0: cache-hit on 2nd call
        max_throughput_deviation=max_dev,
        provenance=provenance(spec),
    )


def write(out: dict, path: str | None = None) -> str:
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_sweep.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return os.path.abspath(path)


def main() -> None:
    out = bench()
    path = write(out)
    print(json.dumps(out, indent=2))
    print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
