"""Yield-vs-throughput benchmark: delivered throughput vs. the fraction of
global links lost MID-RUN, minimal vs. fault-aware adaptive routing.

Wafer-scale yield analyses price a design by how gracefully it degrades as
links die, and warm faults are the realistic form: the link dies while
traffic is in flight, buffered packets must drain over the survivors.  The
grid is the registered `yield_curve` scenario (repro.exp): the paper's
radix-32-class switch-less network (2B on-wafer bandwidth), adversarial
worst-case traffic, and a `FaultSpec` schedule per fault fraction that
kills the links a quarter of the way into the measurement window.  Two
routings run as separate grids of ONE spec — minimal, and UGAL with the
fault-aware adaptive misroute stage (alive-masked candidates, sensors on
surviving links, degradation bias) — each grid one compiled batched scan.

Writes `BENCH_yield.json` (repo root).  The headline check is
`adaptive_ge_minimal`: adaptive routing must deliver at least minimal
routing's throughput at EVERY nonzero fault fraction (it re-routes around
the dead parallel links; minimal can only re-pick among survivors of the
same W-group pair).

    python -m benchmarks.bench_yield            (repo root, pip install -e .)
    python -m benchmarks.bench_yield --full     (paper-scale g=9 grid)
    PYTHONPATH=src python -m benchmarks.bench_yield        (no install)
"""
from __future__ import annotations

import argparse
import json
import os


def bench(fast: bool = True) -> dict:
    from repro.exp import registry as SC
    from repro.exp.provenance import provenance
    from repro.exp.runner import run_experiment

    spec = SC.yield_curve_spec(fast=fast)
    res = run_experiment(spec)
    # cells iterate routing-major inside one topology: grids[0] = minimal,
    # grids[1] = adaptive (the spec's routing order)
    by_mode = {g.routing.route_mode: g for g in res.grids}
    gmin, gada = by_mode["min"], by_mode["ugal"]
    fault_labels = gmin.fault_labels
    fracs = gmin.fault_fracs
    curves = {}
    for tag, grid in (("minimal", gmin), ("adaptive", gada)):
        curves[tag] = dict(
            throughput=[row[0].throughput_per_chip
                        for row in (grid.sweep_result(fi).mean_over_seeds()
                                    for fi in range(len(fault_labels)))],
            latency=[grid.sweep_result(fi).mean_over_seeds()[0].avg_latency
                     for fi in range(len(fault_labels))],
            delivered_pkts=[[grid.result(fi, 0, si).delivered_pkts
                             for si in range(len(grid.seeds))]
                            for fi in range(len(fault_labels))],
            # exact per-seed stranded populations at exit plus the
            # seed-aggregated view (max + exact mean, the
            # mean_over_seeds convention)
            stranded_pkts=[[grid.result(fi, 0, si).stranded_pkts
                            for si in range(len(grid.seeds))]
                           for fi in range(len(fault_labels))],
            stranded_max=[grid.sweep_result(fi).mean_over_seeds()[0]
                          .stranded_pkts
                          for fi in range(len(fault_labels))],
            stranded_mean=[grid.sweep_result(fi).mean_over_seeds()[0]
                           .stranded_mean
                           for fi in range(len(fault_labels))],
            compiles=grid.compile_count)
    # the acceptance check: adaptive >= minimal at every NONZERO fraction
    # (at zero both route minimally modulo sensor noise)
    ok = all(a >= m for a, m, f in zip(curves["adaptive"]["throughput"],
                                       curves["minimal"]["throughput"],
                                       fracs) if f > 0)
    return dict(
        scenario=spec.name,
        net=gmin.topology.label,
        channels=gmin.topology.build().num_channels,
        offered_per_chip=spec.axes.rates[0],
        pattern=gmin.traffic.label,
        seeds=list(spec.axes.seeds),
        cycles_per_lane=spec.axes.warmup + spec.axes.measure,
        fault_labels=fault_labels,
        fault_fracs=fracs,
        onset_cycles=[list(f.onsets) for f in spec.axes.faults],
        minimal=curves["minimal"],
        adaptive=curves["adaptive"],
        adaptive_ge_minimal=ok,
        compiles=[g.compile_count for g in res.grids],
        wall_s=res.wall_s,
        provenance=provenance(spec),
    )


def write(out: dict, path: str | None = None) -> str:
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_yield.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return os.path.abspath(path)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grid (g=9, long cycles)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    out = bench(fast=not args.full)
    path = write(out, args.out)
    print(json.dumps(out, indent=2))
    print(f"\nwrote {path}")
    if any(c > 1 for c in out["compiles"]):
        raise SystemExit(f"expected <= 1 compile per grid, got "
                         f"{out['compiles']}")
    if not out["adaptive_ge_minimal"]:
        raise SystemExit("adaptive misrouting fell below minimal routing "
                         "on the degraded network")


if __name__ == "__main__":
    main()
