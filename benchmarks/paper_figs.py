"""Benchmarks reproducing the paper's tables/figures on the flit-level
simulator.  Each function returns a list of result dicts; `benchmarks.run`
prints them as CSV.

Every figure is a registered scenario of the declarative experiment API
(`repro.exp.registry`): the functions here fetch (or rebuild, for
`fast=False` paper scale) the `ExperimentSpec`, lower it through
`run_experiment` — one batched-engine compile per (topology, routing,
traffic) grid — and reshape the seed-averaged records into the historical
CSV row schema.  No hand-wired `Simulator` grid loops remain.

Scales are reduced where noted (cycle counts / W-group counts) to fit the
single-CPU-core container; the claims checked are the paper's qualitative
and quantitative saturation ratios.
"""
from __future__ import annotations

from repro.core import topology as T
from repro.exp import registry as SC
from repro.exp.runner import run_experiment


def _run(spec):
    return run_experiment(spec).rows()


def _figrows(fig, spec, **extra_keys):
    """Lower `spec` and map its records to the CSV row schema."""
    rows = []
    for rec in _run(spec):
        row = dict(fig=fig, topo=rec["topology"], pattern=rec["pattern"],
                   offered=rec["offered"], throughput=rec["throughput"],
                   latency=rec["latency"], wall_s=rec["wall_s"])
        for k, src in extra_keys.items():
            row[k] = rec[src]
        rows.append(row)
    return rows


def fig10_local(fast=True):
    """Fig. 10(a-b): intra-C-group; (c-f): intra-W-group throughput."""
    return (_figrows("10a", SC.get_scenario("fig10a") if fast
                     else SC.fig10a_spec(fast=False))
            + _figrows("10cf", SC.get_scenario("fig10cf") if fast
                       else SC.fig10cf_spec(fast=False)))


def fig11_global(fast=True, g=None):
    """Fig. 11: global uniform / bit-reverse, radix-16 network.

    Full scale is g=41 (1312 chips); fast mode uses g=11 (352 chips),
    which preserves the 1B-vs-2B and switchless-vs-switch ordering."""
    spec = (SC.get_scenario("fig11") if fast and g is None
            else SC.fig11_spec(fast=fast, g=g))
    return _figrows("11", spec)


def fig12_scalability(fast=True):
    """Fig. 12: radix-32-class network (reduced W-group count on CPU)."""
    return _figrows("12", SC.get_scenario("fig12") if fast
                    else SC.fig12_spec(fast=False))


def fig13_misrouting(fast=True):
    """Fig. 13: minimal vs non-minimal (VAL / UGAL) on hotspot + WC."""
    spec = SC.get_scenario("fig13") if fast else SC.fig13_spec(fast=False)
    rows = []
    for rec in _run(spec):
        # historical schema: bare pattern name, mode column
        rows.append(dict(fig="13", pattern=rec["pattern_name"],
                         mode=rec["route_mode"],
                         offered=rec["offered"],
                         throughput=rec["throughput"],
                         latency=rec["latency"], wall_s=rec["wall_s"]))
    return rows


def fig14_allreduce(fast=True):
    """Fig. 14: ring AllReduce within C-group and W-group."""
    specs = ((SC.get_scenario(n) for n in
              ("fig14_cgroup_switchless", "fig14_cgroup_switch",
               "fig14_wgroup")) if fast else SC.fig14_specs(fast=False))
    rows = []
    for spec in specs:
        for rec in _run(spec):
            bi = rec["pattern_params"].get("bidirectional", False)
            rows.append(dict(
                fig="14", topo=rec["topology"],
                pattern="bi-ring" if bi else "uni-ring",
                offered=rec["offered"], throughput=rec["throughput"],
                latency=rec["latency"], wall_s=rec["wall_s"]))
    return rows


def fig15_energy(fast=True):
    """Fig. 15: average energy per transmission from simulated hop counts
    (Table II constants)."""
    from repro.core import analytical as A
    spec = SC.get_scenario("fig15") if fast else SC.fig15_spec(fast=False)
    mesh, local, glob, inj, ej = T.CH_TYPE_NAMES
    rows = []
    for rec in _run(spec):
        h = rec["avg_hops_by_type"]
        hops = {name: h[name] for name in (mesh, local, glob)}
        # switch-less terminals reach their router on-chip; the baseline's
        # terminal-to-switch hop is a cable
        key = ("term_onchip" if rec["topo_kind"] == "switchless"
               else "term_cable")
        hops[key] = h[inj] + h[ej]
        e = A.energy_per_packet_pj_per_bit(hops)
        rows.append(dict(fig="15", topo=rec["topology"],
                         mode=rec["route_mode"], energy_pj_per_bit=e,
                         avg_hops=sum(h.values()),
                         latency=rec["latency"]))
    return rows


def table3_case_study():
    """Table III: case-study cost comparison (closed form)."""
    from repro.core import analytical as A
    sl = A.switchless_case()
    df = A.dragonfly_slingshot_case()
    return [
        dict(fig="table3", system=c.name, switches=c.num_switches,
             cabinets=c.num_cabinets, processors=c.num_processors,
             cable_length_E=c.cable_length_E, t_local=c.t_local,
             t_global=c.t_global)
        for c in (df, sl)
    ]
