"""Benchmarks reproducing the paper's tables/figures on the flit-level
simulator.  Each function returns a list of result dicts; `benchmarks.run`
prints them as CSV.

Scales are reduced where noted (cycle counts / W-group counts) to fit the
single-CPU-core container; the claims checked are the paper's qualitative
and quantitative saturation ratios.
"""
from __future__ import annotations

import numpy as np

from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.simulator import SimConfig, Simulator


def _sweep(net, pattern, rates, cfg, inject_mask=None):
    """Load-latency curve; all rates run as ONE batched jitted scan.

    The reported per-row wall_s is the whole-sweep wall-clock (including
    the one-time jit compile) amortized over the rates: per-rate timings
    don't exist in the batched path."""
    sim = Simulator(net, cfg, pattern, inject_mask=inject_mask)
    grid = sim.sweep_grid(rates)
    dt = grid.wall_s / max(len(rates), 1)
    return [(res, dt) for res in grid.mean_over_seeds()]


def fig10_local(fast=True):
    """Fig. 10(a-b): intra-C-group; (c-f): intra-W-group throughput."""
    cyc = dict(warmup=400, measure=1200) if fast else \
        dict(warmup=2000, measure=8000)
    rows = []
    # (a) intra-C-group, uniform + bit-reverse
    p = T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=1)
    net = T.build_switchless(p, "cgroup")
    cfg = SimConfig(vcs_per_class=4, **cyc)
    for pname, pat in [("uniform", TR.uniform(net)),
                       ("bit_reverse", TR.bit_reverse(net))]:
        for res, dt in _sweep(net, pat, [1.0, 2.0, 3.0, 3.6], cfg):
            rows.append(dict(
                fig="10a", topo="switchless-cgroup", pattern=pname,
                offered=res.offered_per_chip,
                throughput=res.throughput_per_chip,
                latency=res.avg_latency, wall_s=dt))
    # (c-f) intra-W-group: switchless (1B/2B) vs switch-based
    nets = [("switchless-1B", T.build_switchless(
        T.SwitchlessParams(a=2, b=4, m=2, n=6, noc=2, g=1), "wg")),
        ("switchless-2B", T.build_switchless(
            T.SwitchlessParams(a=2, b=4, m=2, n=6, noc=2, g=1,
                               cg_bw_mult=2), "wg2")),
        ("switch-based", T.build_switch_dragonfly(
            T.SwitchDragonflyParams(t=4, l=7, gl=1, g=1), "wgd"))]
    cfg = SimConfig(vcs_per_class=2, **cyc)
    for tname, net in nets:
        for pname, pat in [("uniform", TR.uniform(net)),
                           ("bit_transpose", TR.bit_transpose(net))]:
            for res, dt in _sweep(net, pat, [0.5, 1.0, 1.5, 2.0], cfg):
                rows.append(dict(
                    fig="10cf", topo=tname, pattern=pname,
                    offered=res.offered_per_chip,
                    throughput=res.throughput_per_chip,
                    latency=res.avg_latency, wall_s=dt))
    return rows


def fig11_global(fast=True, g=None):
    """Fig. 11: global uniform / bit-reverse, radix-16 network.

    Full scale is g=41 (1312 chips); fast mode uses g=11 (352 chips),
    which preserves the 1B-vs-2B and switchless-vs-switch ordering."""
    cyc = dict(warmup=300, measure=900) if fast else \
        dict(warmup=2000, measure=8000)
    g = g or (11 if fast else None)
    rows = []
    nets = [
        ("switchless-1B", T.build_switchless(
            T.paper_radix16_switchless(g=g), "g1B")),
        ("switchless-2B", T.build_switchless(
            T.paper_radix16_switchless(g=g, cg_bw_mult=2), "g2B")),
        ("switch-based", T.build_switch_dragonfly(
            T.paper_radix16_dragonfly(g=g), "gdf")),
    ]
    cfg = SimConfig(vcs_per_class=2, **cyc)
    for tname, net in nets:
        for pname, mk in [("uniform", TR.uniform),
                          ("bit_reverse", TR.bit_reverse)]:
            for res, dt in _sweep(net, mk(net), [0.4, 0.7, 1.0], cfg):
                rows.append(dict(
                    fig="11", topo=tname, pattern=pname,
                    offered=res.offered_per_chip,
                    throughput=res.throughput_per_chip,
                    latency=res.avg_latency, wall_s=dt))
    return rows


def fig12_scalability(fast=True):
    """Fig. 12: radix-32-class network (reduced W-group count on CPU)."""
    g = 5 if fast else 29
    cyc = dict(warmup=250, measure=600) if fast else \
        dict(warmup=1000, measure=4000)
    rows = []
    nets = [
        ("switchless-1B", T.build_switchless(
            T.paper_radix32_switchless(g=g), "r32")),
        ("switchless-2B", T.build_switchless(
            T.paper_radix32_switchless(g=g, cg_bw_mult=2), "r32b")),
        ("switch-based", T.build_switch_dragonfly(
            T.paper_radix32_dragonfly(g=g), "r32d")),
    ]
    cfg = SimConfig(vcs_per_class=2, **cyc)
    for tname, net in nets:
        for res, dt in _sweep(net, TR.uniform(net), [0.4, 0.8], cfg):
            rows.append(dict(
                fig="12", topo=tname, pattern="uniform",
                offered=res.offered_per_chip,
                throughput=res.throughput_per_chip,
                latency=res.avg_latency, wall_s=dt))
    return rows


def fig13_misrouting(fast=True):
    """Fig. 13: minimal vs non-minimal (VAL / UGAL) on hotspot + WC."""
    cyc = dict(warmup=300, measure=800) if fast else \
        dict(warmup=2000, measure=8000)
    net = T.build_switchless(T.paper_radix16_switchless(), "mis16")
    rows = []
    wc = TR.worst_case(net)
    hot, mask = TR.hotspot(net, num_hot=4, seed=0)
    for mode in ("min", "val", "ugal"):
        cfg = SimConfig(route_mode=mode, vcs_per_class=2, **cyc)
        for res, dt in _sweep(net, wc, [0.2, 0.5], cfg):
            rows.append(dict(fig="13", pattern="worst_case", mode=mode,
                             offered=res.offered_per_chip,
                             throughput=res.throughput_per_chip,
                             latency=res.avg_latency, wall_s=dt))
        for res, dt in _sweep(net, hot, [0.2, 0.5], cfg,
                              inject_mask=mask):
            rows.append(dict(fig="13", pattern="hotspot", mode=mode,
                             offered=res.offered_per_chip,
                             throughput=res.throughput_per_chip,
                             latency=res.avg_latency, wall_s=dt))
    return rows


def fig14_allreduce(fast=True):
    """Fig. 14: ring AllReduce within C-group and W-group."""
    cyc = dict(warmup=400, measure=1200) if fast else \
        dict(warmup=2000, measure=8000)
    rows = []
    cases = [
        ("cgroup-switchless", T.build_switchless(
            T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=1), "arc"), 4),
        ("cgroup-switch", T.build_switch_dragonfly(
            T.SwitchDragonflyParams(t=4, l=0, gl=0, g=1), "ars"), 2),
        ("wgroup-switchless", T.build_switchless(
            T.SwitchlessParams(a=2, b=4, m=2, n=6, noc=2, g=1), "arw"), 2),
        ("wgroup-switchless-2B", T.build_switchless(
            T.SwitchlessParams(a=2, b=4, m=2, n=6, noc=2, g=1,
                               cg_bw_mult=2), "arw2"), 2),
        ("wgroup-switch", T.build_switch_dragonfly(
            T.SwitchDragonflyParams(t=4, l=7, gl=1, g=1), "arwd"), 2),
    ]
    for tname, net, vpc in cases:
        cfg = SimConfig(vcs_per_class=vpc, **cyc)
        for bi in (False, True):
            pat = TR.ring_allreduce(net, bidirectional=bi)
            rates = [1.0, 2.0, 3.0, 3.8] if "cgroup" in tname \
                else [0.6, 1.0, 1.6, 2.2]
            for res, dt in _sweep(net, pat, rates, cfg):
                rows.append(dict(
                    fig="14", topo=tname,
                    pattern="bi-ring" if bi else "uni-ring",
                    offered=res.offered_per_chip,
                    throughput=res.throughput_per_chip,
                    latency=res.avg_latency, wall_s=dt))
    return rows


def fig15_energy(fast=True):
    """Fig. 15: average energy per transmission from simulated hop counts
    (Table II constants)."""
    from repro.core import analytical as A
    cyc = dict(warmup=300, measure=800) if fast else \
        dict(warmup=1000, measure=4000)
    rows = []
    for mode in ("min", "val"):
        for tname, net, term_onchip in [
            ("switchless", T.build_switchless(
                T.paper_radix16_switchless(g=9), "e16"), True),
            ("switch-based", T.build_switch_dragonfly(
                T.paper_radix16_dragonfly(g=9), "e16d"), False),
        ]:
            cfg = SimConfig(route_mode=mode, vcs_per_class=2, **cyc)
            sim = Simulator(net, cfg, TR.uniform(net))
            res = sim.run(0.3)
            h = res.avg_hops_by_type
            mesh, local, glob, inj, ej = T.CH_TYPE_NAMES
            hops = {name: h[name] for name in (mesh, local, glob)}
            if term_onchip:
                hops["term_onchip"] = h[inj] + h[ej]
            else:
                hops["term_cable"] = h[inj] + h[ej]
            e = A.energy_per_packet_pj_per_bit(hops)
            rows.append(dict(fig="15", topo=tname, mode=mode,
                             energy_pj_per_bit=e,
                             avg_hops=sum(h.values()),
                             latency=res.avg_latency))
    return rows


def table3_case_study():
    """Table III: case-study cost comparison (closed form)."""
    from repro.core import analytical as A
    sl = A.switchless_case()
    df = A.dragonfly_slingshot_case()
    return [
        dict(fig="table3", system=c.name, switches=c.num_switches,
             cabinets=c.num_cabinets, processors=c.num_processors,
             cable_length_E=c.cable_length_E, t_local=c.t_local,
             t_global=c.t_global)
        for c in (df, sl)
    ]
