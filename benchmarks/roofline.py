"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), in seconds per step:

  compute    = FLOPs / (chips x 197 TFLOP/s bf16)
  memory     = HBM bytes / (chips x 819 GB/s)
  collective = per-chip collective bytes / 50 GB/s/link (flat ICI model),
               plus the switch-less-Dragonfly-fabric pricing for contrast.

FLOPs / HBM bytes / collective bytes are ANALYTIC (formulas below): XLA's
cost_analysis() counts scan bodies once (not x trip count), so raw HLO
numbers under-count by the layer count; the artifacts keep both and the
smoke-scale validation (tests) checks the analytic model against unrolled
HLO.  Collective bytes additionally come from the partitioned HLO with
metadata-based loop scaling, reported side by side.

Like every other benchmark, the run is described by a spec
(`repro.exp.roofline.RooflineSpec`: mesh tag, fabric model, bandwidth
multiplier, artifact dir) instead of hand-wired call sites:

    python -m benchmarks.roofline
    python -m benchmarks.roofline --mesh multi --fabric flat
    python -m benchmarks.roofline --spec my_roofline.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.base import shape_by_name
from repro.configs.registry import get_config
from repro.core.cost_model import (HBM_BW, ICI_BW_PER_LINK,
                                   PEAK_FLOPS_BF16)
from repro.exp.roofline import RooflineSpec

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "dryrun")


def _attn_layers(cfg):
    L = cfg.num_layers
    pat = cfg.block_pattern
    return sum(1 for i in range(L) if pat[i % len(pat)] in ("attn", "local"))


def _ssm_layers(cfg):
    L = cfg.num_layers
    pat = cfg.block_pattern
    return sum(1 for i in range(L) if pat[i % len(pat)] == "ssm")


def _rglru_layers(cfg):
    L = cfg.num_layers
    pat = cfg.block_pattern
    return sum(1 for i in range(L) if pat[i % len(pat)] == "rglru")


def analytic_cell(arch: str, shape_name: str, axis_sizes: dict,
                  int8_dispatch: bool = False) -> dict:
    """MODEL_FLOPS, HBM bytes and per-chip collective bytes for one cell."""
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    chips = 1
    for v in axis_sizes.values():
        chips *= v
    dp = chips // axis_sizes.get("model", 1)
    mp = axis_sizes.get("model", 1)
    pods = axis_sizes.get("pod", 1)

    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    d_attn = cfg.num_heads * cfg.hd
    La = _attn_layers(cfg)
    N_active = cfg.active_params()
    P_bytes = cfg.num_params() * 2                      # bf16 weights

    if shape.kind == "train":
        tokens = B * S
        flops = 6 * N_active * tokens
        ctx = min(S, cfg.local_window) if cfg.local_window else S
        flops += 12 * B * S * (ctx / 2) * d_attn * La   # causal attn f+b
        if cfg.ssm:
            s = cfg.ssm
            flops += 3 * _ssm_layers(cfg) * B * S * (
                4 * s.chunk * s.d_inner(d) / 2          # intra-chunk
                + 6 * s.d_inner(d) * s.d_state / s.head_dim * s.head_dim)
        # HBM per chip: weights f+b reads + grad + fp32 opt (m, v, master
        # each read+write) + activations (saved per layer, read in bwd)
        opt_bytes = cfg.num_params() * 4 * 3 * 2
        act_bytes = tokens * d * cfg.num_layers * 2 * 3  # save + 2 reads
        hbm = (3 * P_bytes + opt_bytes) + act_bytes
        # collectives per chip:
        tok_local = tokens / dp
        tp = 4 * tok_local * d * 2 * cfg.num_layers      # SP AG+RS, f+b
        fsdp = 3 * P_bytes / mp                          # AG f, AG b, RS g
        ep = 0.0
        if cfg.moe:
            db = 1 if int8_dispatch else 2
            ep = 8 * tok_local * cfg.moe.top_k * d * db \
                * (cfg.num_layers - cfg.first_dense)
        pod_b = 2 * P_bytes / (mp * (dp // pods)) * (pods - 1) if pods > 1 \
            else 0.0
        coll = {"model": tp + ep, "data": fsdp, "pod": pod_b}
    elif shape.kind == "prefill":
        tokens = B * S
        flops = 2 * N_active * tokens
        ctx = min(S, cfg.local_window) if cfg.local_window else S
        flops += 4 * B * S * (ctx / 2) * d_attn * La
        if cfg.ssm:
            s = cfg.ssm
            flops += _ssm_layers(cfg) * B * S * 4 * s.chunk \
                * s.d_inner(d) / 2
        hbm = P_bytes + tokens * d * cfg.num_layers * 2 \
            + 2 * B * ctx * cfg.num_kv_heads * cfg.hd * 2 * La  # KV write
        tok_local = tokens / dp
        tp = 2 * tok_local * d * 2 * cfg.num_layers
        ep = 0.0
        if cfg.moe:
            db = 1 if int8_dispatch else 2
            ep = 4 * tok_local * cfg.moe.top_k * d * db \
                * (cfg.num_layers - cfg.first_dense)
        coll = {"model": tp + ep, "data": 0.0, "pod": 0.0}
    else:  # decode: one token per sequence against a seq_len cache
        flops = 2 * N_active * B
        ctx = min(S, cfg.local_window) if cfg.local_window else S
        flops += 4 * B * ctx * d_attn * La
        kv_bytes = 2 * B * ctx * cfg.num_kv_heads * cfg.hd * 2 * La
        if cfg.ssm:
            s = cfg.ssm
            kv_bytes += _ssm_layers(cfg) * B * s.num_heads(d) \
                * s.head_dim * s.d_state * 4
        if cfg.rglru:
            kv_bytes += _rglru_layers(cfg) * B * (cfg.rglru.d_rnn or d) * 4
        hbm = P_bytes + kv_bytes
        # TP all-reduce of [B,1,d] per layer + EP dispatch of B tokens
        tp = 2 * (B / dp) * d * 2 * cfg.num_layers
        ep = 0.0
        if cfg.moe:
            ep = 2 * (B / dp) * cfg.moe.top_k * d * 2 \
                * (cfg.num_layers - cfg.first_dense)
        coll = {"model": tp + ep, "data": 0.0, "pod": 0.0}
    return {"model_flops": flops, "hbm_bytes": hbm, "coll_per_chip": coll,
            "chips": chips}


def roofline_row(art: dict, fabric=None) -> dict:
    arch, shape_name = art["arch"], art["shape"]
    axis_sizes = art["axis_sizes"]
    a = analytic_cell(arch, shape_name, axis_sizes,
                      int8_dispatch="int8" in art.get("mesh", ""))
    chips = a["chips"]
    compute_s = a["model_flops"] / (chips * PEAK_FLOPS_BF16)
    memory_s = a["hbm_bytes"] / (chips * HBM_BW)
    coll_flat = sum(a["coll_per_chip"].values()) / ICI_BW_PER_LINK
    wf = fabric or RooflineSpec().build_fabric()
    coll_wafer = sum(wf.collective_seconds(ax, b)
                     for ax, b in a["coll_per_chip"].items())
    hlo_coll = sum(art.get("collectives", {}).get("by_axis", {}).values())
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_flat}
    dom = max(terms, key=terms.get).replace("_s", "")
    step = max(compute_s, memory_s, coll_flat)
    return {
        "arch": arch, "shape": shape_name, "mesh": art["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_flat, "collective_wafer_s": coll_wafer,
        "dominant": dom,
        "roofline_frac": compute_s / step if step else 0.0,
        "model_flops": a["model_flops"],
        "hlo_flops_per_chip": art.get("flops", 0.0),
        "useful_ratio": a["model_flops"] / (art["flops"] * chips)
        if art.get("flops") else None,
        "hlo_coll_per_chip": hlo_coll,
        "coll_per_chip": a["coll_per_chip"],
        "temp_gb": art.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
        "status": art.get("status"),
    }


def run_spec(spec: RooflineSpec) -> list:
    """Lower a `RooflineSpec` to its roofline rows: read the matching
    dry-run artifacts and price every ok cell on the spec's fabric."""
    art_dir = spec.artifacts_dir or ART_DIR
    fabric = spec.build_fabric()
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir,
                                              f"*__{spec.mesh}*.json"))):
        art = json.load(open(path))
        if art.get("status") == "ok":
            rows.append(roofline_row(art, fabric=fabric))
        else:
            rows.append({"arch": art["arch"], "shape": art["shape"],
                         "mesh": art["mesh"], "status": art.get("status"),
                         "reason": art.get("reason",
                                           art.get("error", ""))[:60]})
    return rows


def load_rows(mesh="single"):
    """Historical entry point: the default spec at the given mesh tag."""
    return run_spec(RooflineSpec(mesh=mesh))


def format_table(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | coll s (flat) | "
           "coll s (wafer) | dominant | roofline frac | temp GB/chip |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        if r.get("status") != "ok" and "compute_s" not in r:
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                       f"{r.get('status')}: {r.get('reason', '')} | - | - |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['collective_wafer_s']:.4f} | {r['dominant']} | "
            f"{r['roofline_frac']:.2f} | {r['temp_gb']:.1f} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--spec", default=None,
                    help="path to a RooflineSpec JSON file")
    ap.add_argument("--mesh", default=None, choices=("single", "multi"),
                    help="one mesh tag (default: both)")
    ap.add_argument("--fabric", default="switchless",
                    choices=("switchless", "flat"))
    ap.add_argument("--cg-bw-mult", type=float, default=1.0)
    args = ap.parse_args(argv)
    if args.spec:
        with open(args.spec) as f:
            specs = [RooflineSpec.from_dict(json.load(f))]
    else:
        meshes = (args.mesh,) if args.mesh else ("single", "multi")
        specs = [RooflineSpec(mesh=m, fabric=args.fabric,
                              cg_bw_mult=args.cg_bw_mult) for m in meshes]
    for spec in specs:
        rows = run_spec(spec)
        if not rows:
            continue
        print(f"\n### Roofline ({spec.mesh}-pod, {spec.fabric} fabric)\n")
        print(format_table(rows))


if __name__ == "__main__":
    main()
