"""Benchmark harness: one function per paper table/figure + kernel
microbenchmarks + the dry-run roofline.  Prints ``name,us_per_call,
derived`` CSV rows.

    python -m benchmarks.run                 (repo root, pip install -e .)
    PYTHONPATH=src python -m benchmarks.run              (no install)
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_paper_figs(fast=True):
    from . import paper_figs as PF
    for fn in (PF.table3_case_study, PF.fig10_local, PF.fig11_global,
               PF.fig12_scalability, PF.fig13_misrouting,
               PF.fig14_allreduce, PF.fig15_energy):
        t0 = time.perf_counter()
        try:
            rows = fn(fast) if fn is not PF.table3_case_study else fn()
        except Exception as e:  # keep the harness going, but say WHERE
            print(f"--- {fn.__name__} failed ---", file=sys.stderr)
            traceback.print_exc(file=sys.stderr)
            _emit(fn.__name__, 0.0, f"ERROR:{e!r}")
            continue
        dt = (time.perf_counter() - t0) * 1e6
        for r in rows:
            tag = ";".join(f"{k}={v:.3f}" if isinstance(v, float)
                           else f"{k}={v}" for k, v in r.items()
                           if k not in ("fig", "wall_s"))
            _emit(f"fig{r['fig']}", dt / max(len(rows), 1), tag)


def bench_kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention import ops as fa
    from repro.kernels.rglru import ops as rg
    from repro.kernels.ssd_scan import ops as sd

    def timeit(f, *args, n=3):
        jax.block_until_ready(f(*args))
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(f(*args))
        return (time.perf_counter() - t0) / n * 1e6

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (1, 512, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 512, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 512, 2, 64), jnp.float32)
    us = timeit(lambda a, b, c: fa.flash_attention(a, b, c), q, k, v)
    _emit("kernel_flash_attention_interpret", us, "S=512;H=4;hd=64")

    x = jax.random.normal(ks[0], (1, 256, 4, 32), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 256, 4)))
    A = jnp.abs(jax.random.normal(ks[2], (4,))) + 0.1
    Bm = jax.random.normal(ks[3], (1, 256, 16))
    Cm = jax.random.normal(ks[4], (1, 256, 16))
    us = timeit(lambda *a: sd.ssd_scan(*a, chunk=64), x, dt, A, Bm, Cm)
    _emit("kernel_ssd_scan_interpret", us, "S=256;H=4;P=32;N=16")

    a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, 512, 256))) * 0.2 + 0.79
    b = jax.random.normal(ks[1], (1, 512, 256)) * 0.1
    us = timeit(lambda *args: rg.rglru_scan(*args, chunk=128, block_r=256),
                a, b)
    _emit("kernel_rglru_scan_interpret", us, "S=512;R=256")


def bench_simulator_throughput():
    """Simulator cycles/second (the evaluation engine's own speed)."""
    from repro.core import topology as T
    from repro.core import traffic as TR
    from repro.core.simulator import SimConfig, Simulator
    net = T.build_switchless(T.paper_radix16_switchless(g=11), "perf")
    cfg = SimConfig(warmup=100, measure=400, vcs_per_class=2)
    sim = Simulator(net, cfg, TR.uniform(net))
    sim.run(0.3)  # compile
    t0 = time.perf_counter()
    sim.run(0.3)
    dt = time.perf_counter() - t0
    cps = (cfg.warmup + cfg.measure) / dt
    _emit("simulator_cycles_per_s", dt * 1e6,
          f"cycles_per_s={cps:.0f};channels={net.num_channels}")


def bench_batched_sweep():
    """Batched (vmapped rate x seed) vs sequential sweep; records the
    engine's first perf-trajectory datapoint in BENCH_sweep.json."""
    from . import bench_sweep as BS
    out = BS.bench()
    BS.write(out)
    _emit("sweep_batched", out["batched_wall_s"] * 1e6,
          f"speedup_vs_seed={out['speedup']:.2f};"
          f"speedup_vs_seq={out['speedup_vs_engine_sequential']:.2f};"
          f"lanes={out['lanes']};"
          f"batched_cycles_per_s={out['batched_cycles_per_s']:.0f};"
          f"max_dev={out['max_throughput_deviation']:.4f}")


def bench_roofline():
    from . import roofline as R
    rows = R.load_rows("single")
    for r in rows:
        if r.get("status") == "ok" and "compute_s" in r:
            _emit(f"roofline_{r['arch']}_{r['shape']}", 0.0,
                  f"compute={r['compute_s']:.4f}s;"
                  f"memory={r['memory_s']:.4f}s;"
                  f"coll={r['collective_s']:.4f}s;dom={r['dominant']};"
                  f"frac={r['roofline_frac']:.2f}")


def main() -> None:
    fast = os.environ.get("BENCH_FULL", "0") != "1"
    print("name,us_per_call,derived")
    bench_kernels()
    bench_simulator_throughput()
    bench_batched_sweep()
    bench_paper_figs(fast=fast)
    bench_roofline()


if __name__ == "__main__":
    main()
