"""FROZEN perf baseline: the seed (pre-engine) monolithic simulator.

This is the PR-0 `repro.core.simulator` step/run loop, kept verbatim so
`bench_sweep.py` can measure the wall-clock the paper-figure sweep grid paid
BEFORE the modular batch-parallel engine existed.  Do not modernize it — its
whole value is staying identical to the seed.  Config/result types are
imported from the live module (their definitions are unchanged since seed);
the switch-less baseline route function is ALSO frozen here (the live one
gained packed-gather optimizations in the same PR, which would pollute the
baseline).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.topology import (EJECT, GLOBAL, INJECT, LOCAL, MESH,
                                 NUM_CH_TYPES, Network)
from repro.core.routing import make_route_fn as _live_make_route_fn
from repro.core.routing import meta_cg_count, meta_update, num_vcs
from repro.core.simulator import SimConfig, SimResult

INF32 = jnp.int32(2**31 - 1)


def _seed_switchless_baseline_route(net):
    """Alg. 1 with XY in-C-group routing; VC = #C-groups entered (4/6 VCs)."""
    t = net.tables
    node_wg = jnp.asarray(t["node_wg"])
    node_cg = jnp.asarray(t["node_cg"])
    node_cgg = jnp.asarray(t["node_cg_global"])
    node_x = jnp.asarray(t["node_x"])
    node_y = jnp.asarray(t["node_y"])
    node_mesh_ch = jnp.asarray(t["node_mesh_ch"])
    eject_ch = jnp.asarray(t["eject_ch"])
    ext_out = jnp.asarray(t["ext_out"])
    local_port = jnp.asarray(t["local_port"])
    glob_route_cg = jnp.asarray(t["glob_route_cg"])
    glob_route_port = jnp.asarray(t["glob_route_port"])
    glob_npar = jnp.asarray(t["glob_npar"])
    port_node_local = jnp.asarray(t["port_node_local"])
    term_node = jnp.asarray(t["term_node"])
    ch_type = jnp.asarray(net.ch_type)
    R = net.meta["R"]
    nodes_per_cg = net.meta["nodes_per_cg"]

    def route_vc(cur, dest_term, mis_wg, meta):
        dest_node = term_node[dest_term]
        wg_c = node_wg[cur]
        wg_d = node_wg[dest_node]
        mis_active = mis_wg >= 0
        tgt_wg = jnp.where(mis_active, mis_wg, wg_d)
        cg_c = node_cg[cur]
        cgg_c = node_cgg[cur]
        cgg_d = node_cgg[dest_node]
        cg_d = node_cg[dest_node]

        in_tgt_wg = wg_c == tgt_wg          # mis cleared on entry => == wg_d
        at_dest_cg = (cgg_c == cgg_d) & (~mis_active)

        # exit port selection (Alg. 1 steps); parallel global links per
        # W-group pair are spread across flows by destination hash
        par = dest_term % glob_npar[wg_c, tgt_wg]
        cg_gl = glob_route_cg[wg_c, tgt_wg, par]     # owner of global channel
        port_gl = glob_route_port[wg_c, tgt_wg, par]
        at_global_cg = cg_c == cg_gl
        peer_cg = jnp.where(in_tgt_wg, cg_d, cg_gl)
        port_lc = local_port[cg_c, peer_cg]
        use_global = (~in_tgt_wg) & at_global_cg
        port = jnp.where(use_global, port_gl, port_lc)
        to_terminal = at_dest_cg

        tgt_local = jnp.where(to_terminal,
                              dest_node % nodes_per_cg,
                              port_node_local[port])
        cur_local = cur % nodes_per_cg
        at_target = cur_local == tgt_local
        out_at_target = jnp.where(to_terminal, eject_ch[cur],
                                  ext_out[cgg_c, port])

        # XY (dimension-order): x first, then y.  DIRS = (N, E, S, W).
        tx = tgt_local % R
        ty = tgt_local // R
        x = node_x[cur]
        y = node_y[cur]
        dir_xy = jnp.where(
            x != tx, jnp.where(tx > x, 1, 3), jnp.where(ty > y, 2, 0))
        out_mesh = node_mesh_ch[cur, dir_xy]

        out_ch = jnp.where(at_target, out_at_target, out_mesh)
        new_meta = meta_update(meta, ch_type[out_ch])
        is_ej = ch_type[out_ch] == 4
        req_vc = jnp.where(is_ej, 0, meta_cg_count(new_meta))
        return out_ch, req_vc.astype(jnp.int32), new_meta

    return route_vc


def make_route_fn(net, vc_mode="baseline"):
    """Frozen seed route function where the seed had its own
    implementation (switch-less baseline VC scheme); other modes fall back
    to the live module — they are not on the benchmark path."""
    if net.meta["kind"] == "switchless" and vc_mode == "baseline":
        return _seed_switchless_baseline_route(net)
    return _live_make_route_fn(net, vc_mode)


def _build_static(net: Network, cfg: SimConfig):
    """Static (hashable) arrays + closures captured by the jitted step."""
    NV = num_vcs(net.meta["kind"], cfg.vc_mode, cfg.nonminimal) \
        * cfg.vcs_per_class
    E = net.num_channels
    T = net.num_terminals
    route_fn = make_route_fn(net, cfg.vc_mode)
    ser = (cfg.pkt_len + net.ch_bw - 1) // net.ch_bw  # serialization cycles
    wg_tbl = net.tables.get("node_wg", net.tables.get("node_grp"))
    # wg of the downstream node of each channel (for misroute clearing)
    ch_dst_wg = wg_tbl[np.clip(net.ch_dst, 0, net.num_nodes - 1)]
    consts = dict(
        NV=NV, E=E, T=T,
        ch_dst=jnp.asarray(net.ch_dst),
        ch_type=jnp.asarray(net.ch_type),
        ch_ser=jnp.asarray(ser),
        ch_lat=jnp.asarray(net.ch_lat),
        ch_dst_wg=jnp.asarray(ch_dst_wg),
        inject_ch=jnp.asarray(net.inject_ch),
        term_node=jnp.asarray(net.term_node),
        term_wg=jnp.asarray(wg_tbl[net.term_node]),
        num_wg=net.meta["g"],
    )
    return consts, route_fn


def make_state(net: Network, cfg: SimConfig, NV: int):
    E, T = net.num_channels, net.num_terminals
    S, Q = cfg.buf_pkts, cfg.srcq_pkts
    z = lambda *s: jnp.zeros(s, dtype=jnp.int32)
    return dict(
        # per-(channel, vc) input buffers (ring buffers of packets)
        b_dest=z(E, NV, S), b_itime=z(E, NV, S), b_mis=z(E, NV, S),
        b_meta=z(E, NV, S), b_ready=z(E, NV, S),
        b_head=z(E, NV), b_count=z(E, NV),
        # source queues
        s_dest=z(T, Q), s_itime=z(T, Q), s_mis=z(T, Q),
        s_head=z(T), s_count=z(T),
        ch_busy=z(E),
        # stats
        st=dict(delivered=z(), lat_sum=jnp.zeros((), jnp.float32),
                generated=z(), dropped=z(),
                hops=z(NUM_CH_TYPES)),
    )


def _make_step(net: Network, cfg: SimConfig, pattern, inject_mask=None):
    consts, route_fn = _build_static(net, cfg)
    NV, E, T = consts["NV"], consts["E"], consts["T"]
    S, Q = cfg.buf_pkts, cfg.srcq_pkts
    PKT = cfg.pkt_len
    inj_mask = (jnp.ones(T, dtype=bool) if inject_mask is None
                else jnp.asarray(inject_mask))
    num_wg = consts["num_wg"]
    term_wg = consts["term_wg"]
    glob_watch = None
    if cfg.route_mode == "ugal" and net.meta["kind"] == "switchless":
        # UGAL-G congestion sensors: for each (w-group, peer) the global
        # channel itself PLUS the mesh channels feeding its source router —
        # under adversarial load the backlog accumulates in those feeders,
        # not in the (fast-draining) downstream buffer of the link.
        t = net.tables
        ab = net.meta["ab"]
        g = net.meta["g"]
        gw = np.zeros((g, g, 5), dtype=np.int64)
        for w in range(g):
            for u in range(g):
                if u == w:
                    continue
                cg = t["glob_route_cg"][w, u, 0]
                port = t["glob_route_port"][w, u, 0]
                ch = t["ext_out"][w * ab + cg, port]
                src = net.ch_src[ch]
                feeders = [c for c in np.where(net.ch_dst == src)[0]
                           if net.ch_type[c] == 0][:4]       # MESH inputs
                sens = [ch] + list(feeders)
                gw[w, u, :len(sens)] = sens
        glob_watch = jnp.asarray(gw)
    elif cfg.route_mode == "ugal":
        t = net.tables
        g = net.meta["g"]
        gw = np.maximum(t["glob_out_ch"][:, :, :1], 0)
        glob_watch = jnp.asarray(
            np.concatenate([gw, np.zeros((g, g, 4), dtype=np.int64)],
                           axis=-1))

    def gen_mis(key, dest, st_bcount):
        """Misroute W-group per freshly generated packet (-1 = minimal)."""
        wg_s = term_wg
        wg_d = term_wg[dest]
        differ = wg_s != wg_d
        if cfg.route_mode == "min" or num_wg <= 2:
            return jnp.full((T,), -1, dtype=jnp.int32)
        cand = jax.random.randint(key, (T,), 0, num_wg).astype(jnp.int32)
        cand = jnp.where((cand == wg_s) | (cand == wg_d),
                         (cand + 1) % num_wg, cand)
        cand = jnp.where((cand == wg_s) | (cand == wg_d),
                         (cand + 1) % num_wg, cand)
        if cfg.route_mode == "val_restricted":
            # only misroute to W-groups strictly below the destination
            ok = (cand < wg_d) & (cand != wg_s)
            cand = jnp.where(ok, cand, -1)
        if cfg.route_mode == "ugal":
            occ = st_bcount.sum(axis=1)  # [E] total buffered packets
            q_min = occ[glob_watch[wg_s, jnp.maximum(wg_d, 0)]].sum(-1)
            q_non = occ[glob_watch[wg_s, jnp.maximum(cand, 0)]].sum(-1)
            take_nonmin = q_min > 2 * q_non + cfg.ugal_threshold
            cand = jnp.where(take_nonmin, cand, -1)
        return jnp.where(differ, cand, -1).astype(jnp.int32)

    def step(state, t_and_key_rate):
        t, key, rate_pkt = t_and_key_rate
        k_gen, k_dest, k_mis = jax.random.split(key, 3)

        # ---------------- injection ----------------
        gen = (jax.random.uniform(k_gen, (T,)) < rate_pkt) & inj_mask
        dest = pattern(k_dest, t).astype(jnp.int32)
        gen = gen & (dest != jnp.arange(T))  # fixed points are silent
        mis = gen_mis(k_mis, dest, state["b_count"])
        space = state["s_count"] < Q
        push = gen & space
        slot = (state["s_head"] + state["s_count"]) % Q
        idx = (jnp.arange(T), slot)
        s_dest = state["s_dest"].at[idx].set(
            jnp.where(push, dest, state["s_dest"][idx]))
        s_itime = state["s_itime"].at[idx].set(
            jnp.where(push, t, state["s_itime"][idx]))
        s_mis = state["s_mis"].at[idx].set(
            jnp.where(push, mis, state["s_mis"][idx]))
        s_count = state["s_count"] + push
        st = state["st"]
        st = dict(st, generated=st["generated"] + gen.sum(),
                  dropped=st["dropped"] + (gen & ~space).sum())

        # ---------------- requesters ----------------
        # buffer requesters: one per (channel, vc)
        bh = state["b_head"]                      # [E, NV]
        e_idx = jnp.arange(E)[:, None].repeat(NV, 1)
        v_idx = jnp.arange(NV)[None, :].repeat(E, 0)
        hslot = (e_idx, v_idx, bh)
        r_dest = state["b_dest"][hslot].reshape(-1)
        r_itime = state["b_itime"][hslot].reshape(-1)
        r_mis = state["b_mis"][hslot].reshape(-1)
        r_meta = state["b_meta"][hslot].reshape(-1)
        r_ready = state["b_ready"][hslot].reshape(-1)
        r_valid = ((state["b_count"] > 0).reshape(-1)
                   & (r_ready <= t)
                   & (consts["ch_type"][e_idx.reshape(-1)] != EJECT))
        cur_node = consts["ch_dst"][e_idx.reshape(-1)]
        out_ch, req_vc, new_meta = route_fn(cur_node, r_dest, r_mis, r_meta)

        # source-queue requesters: fixed out channel (the injection link)
        sq = (jnp.arange(T), state["s_head"])
        sq_dest = s_dest[sq]
        sq_itime = s_itime[sq]
        sq_mis = s_mis[sq]
        sq_valid = s_count > 0
        sq_out = consts["inject_ch"]
        sq_vc = jnp.zeros(T, jnp.int32)
        sq_meta = jnp.zeros(T, jnp.int32)

        a_dest = jnp.concatenate([r_dest, sq_dest])
        a_itime = jnp.concatenate([r_itime, sq_itime])
        a_mis = jnp.concatenate([r_mis, sq_mis])
        a_meta = jnp.concatenate([new_meta, sq_meta])
        a_out = jnp.concatenate([out_ch, sq_out]).astype(jnp.int32)
        a_vc = jnp.concatenate([req_vc, sq_vc]).astype(jnp.int32)
        a_valid = jnp.concatenate([r_valid, sq_valid])

        # expand deadlock class -> physical VC (least-occupied of the class)
        vpc = cfg.vcs_per_class
        if vpc > 1:
            base = a_vc * vpc
            occs = jnp.stack(
                [state["b_count"][a_out, base + i] for i in range(vpc)],
                axis=-1)
            a_vc = base + jnp.argmin(occs, axis=-1).astype(jnp.int32)

        # ---------------- constraints + arbitration ----------------
        a_type = consts["ch_type"][a_out]
        is_ej = a_type == EJECT
        credit = state["b_count"][a_out, a_vc] < S
        ok = a_valid & (state["ch_busy"][a_out] == 0) & (credit | is_ej)

        seg = jnp.where(ok, a_out, E)
        key1 = jnp.where(ok, a_itime, INF32)
        m1 = jax.ops.segment_min(key1, seg, num_segments=E + 1)
        tie = ok & (a_itime == m1[a_out])
        ridx = jnp.arange(a_out.shape[0], dtype=jnp.int32)
        key2 = jnp.where(tie, ridx, INF32)
        m2 = jax.ops.segment_min(key2, seg, num_segments=E + 1)
        win = tie & (ridx == m2[a_out])

        win_buf = win[:E * NV].reshape(E, NV)
        win_src = win[E * NV:]

        # ---------------- apply: pops ----------------
        b_head = (bh + win_buf) % S
        b_count = state["b_count"] - win_buf
        s_head = (state["s_head"] + win_src) % Q
        s_count = s_count - win_src

        # ---------------- apply: pushes ----------------
        w_push = win & ~is_ej
        # one winner per out channel => no index collisions among winners;
        # non-winners are routed to the out-of-bounds row E and dropped by
        # JAX scatter semantics.
        po = a_out
        pv = a_vc
        pslot = (state["b_head"][po, pv] + state["b_count"][po, pv]) % S
        # NOTE: use pre-pop head/count of the DESTINATION buffer; a pop on the
        # same buffer this cycle removes its head, not the tail we append to,
        # and the count delta composes (-1 pop, +1 push).
        # clear misroute on entering the intermediate W-group
        entered = (a_mis >= 0) & (consts["ch_dst_wg"][po] == a_mis)
        new_mis = jnp.where(entered, -1, a_mis)
        # virtual cut-through: the head is forwardable after the pipeline
        # latency; serialization is modeled by the channel busy time below.
        ready = t + consts["ch_lat"][po]
        po_push = jnp.where(w_push, po, E)
        tgt = (po_push, pv, pslot)

        def scat(arr, val):
            return arr.at[tgt].set(val, mode="drop")

        b_dest = scat(state["b_dest"], a_dest)
        b_itime = scat(state["b_itime"], a_itime)
        b_mis = scat(state["b_mis"], new_mis)
        b_meta = scat(state["b_meta"], a_meta)
        b_ready = scat(state["b_ready"], ready)
        b_count = b_count.at[(po_push, pv)].add(1, mode="drop")

        # channel busy (serialization) for every winner (incl. ejects);
        # ser - 1 because the winning cycle itself is the first busy slot
        po_win = jnp.where(win, po, E)
        ch_busy = jnp.maximum(state["ch_busy"] - 1, 0)
        ch_busy = ch_busy.at[po_win].set(consts["ch_ser"][po] - 1, mode="drop")

        # ---------------- stats ----------------
        w_ej = win & is_ej
        delivered = st["delivered"] + w_ej.sum()
        lat_sum = st["lat_sum"] + jnp.where(w_ej, (t - a_itime), 0).sum()
        hops = st["hops"] + jax.ops.segment_sum(
            win.astype(jnp.int32), jnp.where(win, a_type, NUM_CH_TYPES),
            num_segments=NUM_CH_TYPES + 1)[:NUM_CH_TYPES]
        st = dict(st, delivered=delivered, lat_sum=lat_sum, hops=hops)

        new_state = dict(
            b_dest=b_dest, b_itime=b_itime, b_mis=b_mis, b_meta=b_meta,
            b_ready=b_ready, b_head=b_head, b_count=b_count,
            s_dest=s_dest, s_itime=s_itime, s_mis=s_mis,
            s_head=s_head, s_count=s_count, ch_busy=ch_busy, st=st)
        return new_state, None

    return step, consts


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _run(step, cycles, reset_at, state0, rate_pkt, seed):

    def body(carry, t):
        state, key = carry
        key, sub = jax.random.split(key)
        state, _ = step(state, (t, sub, rate_pkt))
        # reset statistics at the end of warmup
        def zero_stats(st):
            return jax.tree.map(lambda x: jnp.zeros_like(x), st)
        st = jax.lax.cond(t == reset_at, zero_stats, lambda s: s, state["st"])
        state = dict(state, st=st)
        return (state, key), None

    key = jax.random.PRNGKey(seed)
    (state, _), _ = jax.lax.scan(body, (state0, key), jnp.arange(cycles))
    return state


class SeedSimulator:
    """Compile-once-per-(net, cfg, pattern) simulator; sweep rates cheaply."""

    def __init__(self, net: Network, cfg: SimConfig, pattern,
                 inject_mask=None):
        self.net, self.cfg = net, cfg
        self.terms_per_chip = net.num_terminals / net.num_chips
        self.step, self.consts = _make_step(net, cfg, pattern, inject_mask)
        self.NV = self.consts["NV"]
        n_inj = (int(np.asarray(inject_mask).sum()) if inject_mask is not None
                 else net.num_terminals)
        self._inj_frac = n_inj / net.num_terminals

    def run(self, offered_per_chip: float) -> SimResult:
        cfg = self.cfg
        rate_pkt = offered_per_chip / cfg.pkt_len / self.terms_per_chip
        if rate_pkt > 1.0 + 1e-9:
            raise ValueError(
                f"offered {offered_per_chip}/chip needs per-terminal packet "
                f"rate {rate_pkt:.2f} > 1")
        state0 = make_state(self.net, cfg, self.NV)
        cycles = cfg.warmup + cfg.measure
        state = _run(self.step, cycles, cfg.warmup,
                     state0, jnp.float32(rate_pkt), cfg.seed)
        st = jax.tree.map(np.asarray, state["st"])
        delivered = int(st["delivered"])
        chips = self.net.num_chips * self._inj_frac
        thr = delivered * cfg.pkt_len / cfg.measure / max(chips, 1e-9)
        lat = float(st["lat_sum"]) / max(delivered, 1)
        hops = {name: int(st["hops"][i])
                for i, name in enumerate(("mesh", "local", "global",
                                          "inject", "eject"))}
        avg_hops = {k: v / max(delivered, 1) for k, v in hops.items()}
        return SimResult(
            offered_per_chip=offered_per_chip, throughput_per_chip=thr,
            avg_latency=lat, delivered_pkts=delivered,
            generated_pkts=int(st["generated"]), dropped_pkts=int(st["dropped"]),
            hops_by_type=hops, avg_hops_by_type=avg_hops)

    def sweep(self, rates) -> list[SimResult]:
        return [self.run(r) for r in rates]


def saturation_throughput(results: list[SimResult]) -> float:
    """Max accepted throughput over a sweep (flits/cycle/chip)."""
    return max(r.throughput_per_chip for r in results)
