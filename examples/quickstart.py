"""Quickstart: build the paper's switch-less Dragonfly, check the
analytical model, run a small simulation through the declarative
experiment API, and price a training step on the wafer fabric.

Run from the repo root (after `pip install -e .`, or with the
single fallback `PYTHONPATH=src` when not installed):

    python -m examples.quickstart
"""
from repro.core import analytical as A
from repro.core import topology as T
from repro.core.cost_model import roofline, switchless_wafer_fabric
from repro.exp import (ExperimentSpec, RoutingSpec, SweepAxes,
                       TopologySpec, TrafficSpec, run_experiment)


def main():
    # 1. the paper's radix-16 evaluation network
    params = T.paper_radix16_switchless()
    print("== Switch-less Dragonfly (radix-16 eval config) ==")
    for k, v in A.summarize(params).items():
        print(f"  {k:10s} = {v}")

    # 2. a declarative experiment: one C-group under uniform traffic.
    # The spec is plain data (hashable, JSON round-trippable); the runner
    # lowers the whole load-latency curve to ONE batched jitted scan.
    spec = ExperimentSpec(
        name="quickstart",
        topologies=TopologySpec.switchless(a=1, b=1, m=2, n=6, noc=2, g=1,
                                           label="cgroup"),
        traffics=TrafficSpec("uniform"),
        routings=RoutingSpec(vcs_per_class=4),
        axes=SweepAxes(rates=(1.0, 2.0, 3.0), warmup=300, measure=900))
    net = spec.topologies[0].build()
    print(f"\n== intra-C-group simulation ({net.num_nodes} routers) ==")
    result = run_experiment(spec)
    for rec in result.rows():
        print(f"  offered {rec['offered']:.1f} flits/cyc/chip -> accepted "
              f"{rec['throughput']:.2f}, latency {rec['latency']:.1f} cyc")
    print(f"  (paper Fig. 10(a): saturation ~3.0; "
          f"compiles={result.compile_counts})")

    # 3. price a minicpm-2b training step on the wafer fabric
    from benchmarks.roofline import analytic_cell
    a = analytic_cell("minicpm-2b", "train_4k",
                      {"data": 16, "model": 16})
    rt = roofline(a["model_flops"], a["hbm_bytes"],
                  {k: v * a["chips"] for k, v in
                   a["coll_per_chip"].items()},
                  chips=a["chips"], fabric=switchless_wafer_fabric(),
                  model_flops=a["model_flops"])
    print("\n== minicpm-2b train_4k on one 256-chip wafer pod ==")
    print(f"  compute    {rt.compute_s * 1e3:8.2f} ms")
    print(f"  memory     {rt.memory_s * 1e3:8.2f} ms")
    print(f"  collective {rt.collective_s * 1e3:8.2f} ms (wafer fabric)")
    print(f"  dominant   {rt.dominant};  roofline frac "
          f"{rt.roofline_frac:.2f}")


if __name__ == "__main__":
    main()
