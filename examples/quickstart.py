"""Quickstart: build the paper's switch-less Dragonfly, check the
analytical model, run a small simulation, and price a training step on the
wafer fabric.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import analytical as A
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.cost_model import roofline, switchless_wafer_fabric
from repro.core.simulator import SimConfig, Simulator


def main():
    # 1. the paper's radix-16 evaluation network
    params = T.paper_radix16_switchless()
    print("== Switch-less Dragonfly (radix-16 eval config) ==")
    for k, v in A.summarize(params).items():
        print(f"  {k:10s} = {v}")

    net = T.build_switchless(T.SwitchlessParams(a=1, b=1, m=2, n=6,
                                                noc=2, g=1), "cgroup")
    print(f"\n== intra-C-group simulation ({net.num_nodes} routers) ==")
    sim = Simulator(net, SimConfig(warmup=300, measure=900,
                                   vcs_per_class=4), TR.uniform(net))
    # the whole load-latency curve runs as ONE batched jitted scan
    for r in sim.sweep([1.0, 2.0, 3.0]):
        print(f"  offered {r.offered_per_chip:.1f} flits/cyc/chip -> accepted "
              f"{r.throughput_per_chip:.2f}, latency {r.avg_latency:.1f} cyc")
    print("  (paper Fig. 10(a): saturation ~3.0)")

    # 3. price a minicpm-2b training step on the wafer fabric
    from benchmarks.roofline import analytic_cell
    a = analytic_cell("minicpm-2b", "train_4k",
                      {"data": 16, "model": 16})
    rt = roofline(a["model_flops"], a["hbm_bytes"],
                  {k: v * a["chips"] for k, v in
                   a["coll_per_chip"].items()},
                  chips=a["chips"], fabric=switchless_wafer_fabric(),
                  model_flops=a["model_flops"])
    print("\n== minicpm-2b train_4k on one 256-chip wafer pod ==")
    print(f"  compute    {rt.compute_s * 1e3:8.2f} ms")
    print(f"  memory     {rt.memory_s * 1e3:8.2f} ms")
    print(f"  collective {rt.collective_s * 1e3:8.2f} ms (wafer fabric)")
    print(f"  dominant   {rt.dominant};  roofline frac "
          f"{rt.roofline_frac:.2f}")


if __name__ == "__main__":
    main()
