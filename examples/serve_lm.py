"""Batched serving driver: prefill + greedy decode with KV/state caches
over batched requests (the serve_step the decode dry-run cells lower).

Run from the repo root (after `pip install -e .`, or `PYTHONPATH=src`):

    python -m examples.serve_lm --arch recurrentgemma-2b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import transformer as TF


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch + "-smoke")
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": prompts}
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_prefix, cfg.d_model)) * 0.02,
            cfg.jdtype)
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)) * 0.02, cfg.jdtype)

    max_len = S + args.gen + (cfg.num_prefix if cfg.frontend else 0)
    cache = TF.init_cache(cfg, B, max_len=max_len)

    @jax.jit
    def prefill(params, batch, cache):
        logits, cache, _ = TF.forward(params, cfg, batch, "prefill",
                                      cache=cache, attn_impl="naive",
                                      remat=False)
        return jnp.argmax(logits[:, -1:], -1).astype(jnp.int32), cache

    @jax.jit
    def decode(params, tok, cache, extra):
        b = {"tokens": tok, **extra}
        logits, cache, _ = TF.forward(params, cfg, b, "decode",
                                      cache=cache, attn_impl="naive",
                                      remat=False)
        return jnp.argmax(logits[:, -1:], -1).astype(jnp.int32), cache

    extra = {}
    if cfg.family == "encdec":
        extra["src_embeds"] = batch["src_embeds"]

    t0 = time.perf_counter()
    tok, cache = prefill(params, batch, cache)
    t_pref = time.perf_counter() - t0
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        tok, cache = decode(params, tok, cache, extra)
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name}  batch={B}  prompt={S}  gen={args.gen}")
    print(f"prefill: {t_pref * 1e3:.1f} ms   decode: "
          f"{t_dec / max(args.gen - 1, 1) * 1e3:.1f} ms/token")
    print("generated token ids (first request):",
          np.asarray(gen[0])[:12], "...")
    assert bool(jnp.isfinite(gen).all())
    print("OK")


if __name__ == "__main__":
    main()
