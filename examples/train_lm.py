"""End-to-end training driver: train a ~100M-param MiniCPM-family model
for a few hundred steps on the synthetic pipeline with checkpointing and
fault tolerance enabled.

Run from the repo root (after `pip install -e .`, or `PYTHONPATH=src`):

    python -m examples.train_lm --steps 300

On CPU this uses a width/depth-reduced config (~100M params at full vocab)
and a host mesh; on a real pod the same driver takes --arch minicpm-2b
with the production mesh.
"""
import argparse
import dataclasses

from repro.checkpoint.checkpointing import Checkpointer
from repro.configs.registry import get_config
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.optim.optimizer import OptConfig
from repro.runtime.fault_tolerance import (FailureInjector,
                                           FaultTolerantLoop,
                                           StragglerMonitor)
from repro.runtime.trainer import Trainer, TrainSetup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train-ckpt")
    ap.add_argument("--inject-failure-at", type=int, default=0)
    args = ap.parse_args()

    base = get_config(args.arch)
    cfg = dataclasses.replace(
        base, num_layers=args.layers, d_model=args.d_model,
        num_heads=8, num_kv_heads=8 if base.num_kv_heads == base.num_heads
        else 2, d_ff=args.d_model * 3 if base.d_ff else 0, head_dim=64)
    print(f"model: {cfg.name} reduced to "
          f"{cfg.num_params() / 1e6:.0f}M params "
          f"({cfg.num_layers}L x {cfg.d_model}d, vocab {cfg.vocab_size})")

    opt = OptConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps,
                    schedule=cfg.schedule, weight_decay=0.01)
    setup = TrainSetup(model=cfg, opt=opt, attn_impl="chunked", remat=False)
    mesh = make_host_mesh(model=1)
    data = SyntheticTokens(cfg.vocab_size, batch=args.batch,
                           seq_len=args.seq, seed=0)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    tr = Trainer(setup, mesh, data, checkpointer=ckpt, ckpt_every=50)
    mon = StragglerMonitor()

    if args.inject_failure_at:
        loop = FaultTolerantLoop(
            tr, FailureInjector(fail_at=(args.inject_failure_at,)), mon)
        loop.run(args.steps)
        print("fault-tolerance log:", loop.log)
        hist = tr.history
    else:
        def on_step(step, metrics, dt):
            mon.observe(step, dt)
            if step % 20 == 0 or step == 1:
                print(f"step {step:4d}  loss {metrics['loss']:.3f}  "
                      f"nll {metrics['nll']:.3f}  lr {metrics['lr']:.2e}  "
                      f"{dt * 1e3:.0f} ms")
        hist = tr.run(args.steps, on_step=on_step)

    first = sum(h["nll"] for h in hist[:10]) / 10
    last = sum(h["nll"] for h in hist[-10:]) / 10
    print(f"\nnll: {first:.3f} -> {last:.3f} over {len(hist)} steps "
          f"({len(mon.events)} straggler events)")
    assert last < first, "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
