"""repro: reproduction of "Switch-Less Dragonfly on Wafers".

Importing any `repro` submodule runs the host-parallelism setup below
FIRST, before JAX can initialize its backend — which is the only moment
the CPU device count can still be chosen.

REPRO_HOST_DEVICES=N (opt-in) splits the host CPU into N XLA devices
(`--xla_force_host_platform_device_count=N`), which the batched sweep
engine (`repro.core.engine.sweep`) uses to `shard_map` independent sweep
lanes across devices and the experiment runner (`repro.exp.runner`) uses
to round-robin independent grid cells.  Unset (the default) leaves JAX's
single-CPU-device behavior untouched; real multi-device backends (TPU)
need no flag and shard automatically.

REPRO_CPU_RUNTIME=legacy (opt-in) selects XLA:CPU's pre-thunk runtime
(`--xla_cpu_use_thunk_runtime=false`).  The engine's cycle loop is a
long scan of many small ops, which is exactly the shape the thunk
runtime's per-op dispatch overhead hurts most — on the bench_sweep grid
the legacy runtime is ~4x faster (see docs/performance.md and
BENCH_perf.json).  Results are bit-identical either way (same compiled
HLO, different executor).  Opt-in because the flag may not exist on
every XLA build; "thunks" explicitly keeps the default runtime.

Both knobs must be read BEFORE the backend exists, hence this module.

This module is also the ONLY place the library reads environment
variables (`repro.analysis` lint rule REPRO002): every other `REPRO_*`
knob goes through `env_int` below (or `env_raw` for the analysis
layer's misconfiguration audits), so the full knob surface is auditable
in one file — `REPRO_SHARD_MIN_WORK` / `REPRO_CHANNEL_SHARDS` /
`REPRO_SUPERSTEP` (`core.engine.sweep`), `REPRO_COMPACT_CAP`
(`core.engine.fused`), `REPRO_REAP_AGE` (`core.engine.state`: the
router-death reaper's process-wide park-age default when
`SimConfig.reap_age` is 0), `REPRO_RR_MAX_CHANNELS` (`exp.runner`), and
`REPRO_SERVE_WINDOW` / `REPRO_SERVE_PACK` (`exp.serve.service`) document
their semantics at their call sites.
"""
from __future__ import annotations

import os
import sys
import warnings


def env_int(name: str, default: int) -> int:
    """Integer environment knob; unset/empty/non-integer -> `default`.

    The single env-read helper of the library (lint rule REPRO002 keeps
    all `os.environ` access in this module, so the knob surface stays
    auditable in one place)."""
    raw = os.environ.get(name, "").strip()
    try:
        return int(raw) if raw else default
    except ValueError:
        return default


def env_raw(name: str) -> str | None:
    """Raw environment knob string, `None` when unset.

    For the analysis layer's misconfiguration audits (CAP_PIN /
    CAP_SUPERSTEP in `analysis.capacitypass`): those findings must see
    exactly what the operator typed, not the parsed fallback `env_int`
    would silently apply — the silent fallback is the thing being
    audited.  Runtime code keeps using `env_int`."""
    return os.environ.get(name)


def _flag_setup() -> None:
    add = []
    n = os.environ.get("REPRO_HOST_DEVICES")
    if n:
        try:
            count = int(n)
        except ValueError:
            raise ValueError(
                f"REPRO_HOST_DEVICES={n!r} is not an integer device count")
        if count < 1:
            raise ValueError(f"REPRO_HOST_DEVICES={count} must be >= 1")
        add.append(f"--xla_force_host_platform_device_count={count}")
    runtime = os.environ.get("REPRO_CPU_RUNTIME")
    if runtime not in (None, "", "legacy", "thunks"):
        raise ValueError(
            f"REPRO_CPU_RUNTIME={runtime!r} must be 'legacy' or 'thunks'")
    if runtime == "legacy":
        add.append("--xla_cpu_use_thunk_runtime=false")
    if not add:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    # an explicit XLA_FLAGS setting of the same flag wins over the knob
    add = [f for f in add if f.split("=")[0] not in flags]
    if not add:
        return
    if "jax" in sys.modules:
        # jax may already have initialized its backend, in which case the
        # flags below are read too late and silently do nothing
        warnings.warn(
            "REPRO_HOST_DEVICES/REPRO_CPU_RUNTIME set but jax was "
            "imported before repro; the flags may not take effect",
            RuntimeWarning, stacklevel=3)
    os.environ["XLA_FLAGS"] = " ".join([flags] + add).strip()


_flag_setup()
