"""Static verification + lint for the repro engine (docs/analysis.md).

Four passes, none of which runs a simulation cycle:

  spec     (`specpass`)    per-scenario proofs from the declarative
           spec: VC-scheme resolution, per-epoch CDG deadlock freedom,
           fault-schedule routability, and the fused grant's int32
           packed-key interval analysis (which grant form each scenario
           takes, surfaced instead of silently falling back).
  jaxpr    (`jaxprpass`)   abstract traces of every (step_impl, vc_mode,
           fault-kind) combination: dtype stability, scan-carry
           stability, scatter OOB-mode audit, and concrete batch-purity
           probes of the route kernels.
  compile  (`compilepass`) abstract lowering signatures per grid: the
           runner's one-compile-per-grid promise, certified from shapes
           alone.
  lint     (`lint`)        repo-specific AST rules REPRO001-004.

CLI: `python -m repro.analysis.check --all --lint` (the CI `analysis`
job's gate; exits nonzero on any unsuppressed error or warning).
Suppressions live exclusively in `allowlist.DEFAULT_ENTRIES` or an
`--allowlist` file — there is no inline escape hatch.
"""
from .allowlist import AllowEntry, Allowlist
from .findings import Finding, Report

__all__ = ["AllowEntry", "Allowlist", "Finding", "Report"]
