"""The lint/analysis allowlist: every suppression is an explicit,
reasoned entry here (or in a user-supplied allowlist file) — there is no
inline `# noqa`-style escape, so the full set of waived findings is
auditable in one place (docs/analysis.md documents the format).

An entry matches a finding when the rule id is equal and the finding's
location path ends with the entry's path (locations are
`path/to/file.py:LINE`; the entry path never carries a line number, a
waiver covers the file).  Matching findings stay in the report tagged
with the entry's reason; they stop gating.

File format (`--allowlist FILE`), one entry per line:

    RULE  path/suffix.py  reason text until end of line
    # comments and blank lines are ignored
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class AllowEntry:
    rule: str
    path: str       # suffix match against the finding's file path
    reason: str

    def matches(self, rule: str, path: str) -> bool:
        return rule == self.rule and path.endswith(self.path)


# The repo's standing waivers.  Keep this SHORT: an entry here is a
# documented debt, not a convenience.
DEFAULT_ENTRIES = (
    # The seed reference simulator is the frozen performance/parity
    # baseline (benchmarks/bench_sweep.py compares against it); it
    # predates the CH_TYPE constants and is deliberately kept byte-stable
    # so historical baseline numbers stay attributable to engine changes.
    AllowEntry("REPRO001", "benchmarks/seed_reference.py",
               "frozen seed baseline, kept byte-stable"),
)


class Allowlist:
    def __init__(self, entries=DEFAULT_ENTRIES):
        self.entries = tuple(entries)

    def match(self, finding) -> AllowEntry | None:
        path = finding.location.rsplit(":", 1)[0]
        for e in self.entries:
            if e.matches(finding.rule, path):
                return e
        return None

    @classmethod
    def load(cls, path: str | None = None) -> "Allowlist":
        """Default entries, plus `path`'s if given."""
        entries = list(DEFAULT_ENTRIES)
        if path:
            with open(path) as f:
                for i, line in enumerate(f, 1):
                    line = line.strip()
                    if not line or line.startswith("#"):
                        continue
                    parts = line.split(None, 2)
                    if len(parts) < 3:
                        raise ValueError(
                            f"{path}:{i}: allowlist entries are "
                            f"'RULE path reason...', got {line!r}")
                    entries.append(AllowEntry(*parts))
        return cls(entries)
