"""Capacity pass: interval analysis of the occupancy-compacted step.

For every `step_impl="compact"` (topology x routing) cell the pass sizes
the capacity ladder (`fused.capacity_ladder`) against a sound worst-case
live-row bound, and audits the superstep/epoch interaction — all static,
nothing compiles:

  CAP_PROVED     the worst-case live-row count provably fits the
                 starting rung C0, so the runtime escalation path is
                 dead code for this cell: no rerun can ever trigger.
                 The bound is exact interval arithmetic —

                     live <= T + min(ER*NV, cycles * T)   (capped at N)

                 — at most one live source row per terminal (T), plus
                 one live buffer row per non-empty (channel, VC) buffer,
                 itself bounded by both the buffer-row count (ER*NV) and
                 the total packets a run can create (the engine enforces
                 <= 1 packet per terminal per cycle; see
                 `sweep.offered_to_rate_pkt`).
  CAP_UNPROVEN   the sound bound exceeds C0 (true for every paper-scale
                 run: buffers alone dwarf N/4).  Reported as INFO, not a
                 gate: capacity overflow is DETECTED at runtime — the
                 step folds an exact, capacity-independent live-row
                 census into `SimStats.occ_peak` every cycle and the
                 sweep layer re-dispatches the whole grid at the next
                 ladder rung on a breach (`sweep._PendingLanes.finish`),
                 so results stay bit-identical to the oracle either way.
                 The finding carries the expected-occupancy estimate
                 (`cycles-in-flight x offered packet rate`) so a grossly
                 undersized REPRO_COMPACT_CAP pin is visible before the
                 run pays for the escalation rerun.
  CAP_EPOCH      warm-fault (epoch-scheduled) cells: proves the K-cycle
                 superstep cannot skip a fault onset.  The superstep
                 body resolves the epoch PER SUBSTEP — every cycle t in
                 [0, cycles) is enumerated with its own
                 `resolve_epoch(t)` no matter what K divides the run —
                 so an onset is applied at exactly its cycle even when
                 it lands mid-superstep.  Emitted as the proof record
                 (info) with the onset list.
  CAP_SUPERSTEP  REPRO_SUPERSTEP is set but does not divide this cell's
                 warmup+measure: `sweep.superstep` silently falls back
                 to K=1, so the requested unroll buys nothing.  A
                 warning — the env var is a deliberate operator action,
                 and the silent fallback is almost never what they
                 meant.
  CAP_PIN        REPRO_COMPACT_CAP is set but <= 0: `initial_capacity`
                 ignores it and starts at the default rung.  Warning,
                 same rationale.

Non-compact cells are skipped silently — the ladder, the census, and
the superstep epoch question only exist on the compact hot path.
"""
from __future__ import annotations

import math

from .. import env_raw
from ..core.engine.fused import (capacity_ladder, compact_rows,
                                 initial_capacity)
from ..core.engine.sweep import superstep
from ..exp.registry import get_scenario
from ..exp.spec import ExperimentSpec

PASS = "capacity"


def check_env(report) -> None:
    """One-shot audit of the compact-path env knobs (global, not
    per-scenario): values the runtime would silently ignore."""
    raw = env_raw("REPRO_COMPACT_CAP")
    if raw is not None:
        try:
            val = int(raw)
        except ValueError:
            val = 0
        if val <= 0:
            report.add(PASS, "CAP_PIN", "warning", "env:REPRO_COMPACT_CAP",
                       f"REPRO_COMPACT_CAP={raw!r} is not a positive "
                       f"integer: initial_capacity ignores it and starts "
                       f"at the default ceil(N/4) rung")


def _live_row_bound(N: int, ER: int, NV: int, T: int, cycles: int) -> int:
    """Sound worst-case live-row count (see module docstring)."""
    return min(N, T + min(ER * NV, cycles * T))


def check_spec(spec: ExperimentSpec, origin: str, report) -> None:
    """Run every capacity-pass check on one constructed spec."""
    cycles = spec.axes.warmup + spec.axes.measure
    for topo in spec.topologies:
        net = None
        for routing in spec.routings:
            if routing.step_impl != "compact":
                continue
            where = f"{origin} [{topo.label} x {routing.label}]"
            if net is None:
                net = topo.build()
            cfg = routing.to_simconfig(spec.axes)
            N = compact_rows(net, cfg)
            ER, T = net.first_eject, net.num_terminals
            NV = (N - T) // ER
            ladder = capacity_ladder(N)
            c0 = initial_capacity(N)
            bound = _live_row_bound(N, ER, NV, T, cycles)

            # expected occupancy: offered packets per cycle x the packet
            # lifetime the buffers can absorb (a sizing hint, NOT a
            # bound — the census + ladder rerun is the soundness story)
            terms_per_chip = net.num_terminals / net.num_chips
            rate_pkt = (max(spec.axes.rates) / routing.pkt_len
                        / terms_per_chip)
            est = min(N, math.ceil(rate_pkt * T) * routing.pkt_len
                      * routing.buf_pkts)

            if bound <= c0:
                report.add(
                    PASS, "CAP_PROVED", "info", where,
                    f"starting rung C0={c0} provably bounds the live "
                    f"rows: worst case {bound} = T({T}) + "
                    f"min(ER*NV={ER * NV}, cycles*T={cycles * T}) of "
                    f"N={N}; escalation is unreachable "
                    f"(ladder {ladder})")
            else:
                report.add(
                    PASS, "CAP_UNPROVEN", "info", where,
                    f"starting rung C0={c0} of N={N} is not statically "
                    f"provable (worst-case live rows {bound}); the "
                    f"runtime census (SimStats.occ_peak) + bit-identical "
                    f"ladder rerun is the checked safety net "
                    f"(ladder {ladder}, expected occupancy ~{est} at "
                    f"peak rate {max(spec.axes.rates)})")

            k = superstep(cycles)
            raw = env_raw("REPRO_SUPERSTEP")
            if raw is not None and raw.strip().isdigit() \
                    and int(raw) > 1 and k == 1:
                report.add(
                    PASS, "CAP_SUPERSTEP", "warning", where,
                    f"REPRO_SUPERSTEP={raw} does not divide "
                    f"warmup+measure={cycles}: the scan silently falls "
                    f"back to K=1 (pick a divisor of {cycles})")

            warm = [f for f in spec.axes.faults if f.onsets]
            for f in warm:
                # per-substep epoch resolution: cycle t is enumerated
                # with its own resolve_epoch(t) for ANY unroll K, so an
                # onset mid-superstep is applied at exactly its cycle
                stranded = [c for c in f.onsets if not 0 < c < cycles]
                if stranded:
                    report.add(
                        PASS, "CAP_EPOCH", "error", where,
                        f"fault onsets {stranded} outside (0, {cycles}): "
                        f"the epoch never resolves inside the run")
                else:
                    report.add(
                        PASS, "CAP_EPOCH", "info", where,
                        f"superstep K={k} cannot skip the "
                        f"{len(f.onsets)} onset(s) {f.onsets}: the "
                        f"unrolled body resolves the fault epoch per "
                        f"substep, so each onset lands on its exact "
                        f"cycle even mid-superstep")


def check_scenario(name: str, report) -> None:
    check_spec(get_scenario(name), f"scenario:{name}", report)
