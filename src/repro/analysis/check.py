"""`python -m repro.analysis.check` — the static verification CLI.

Runs the analysis passes (docs/analysis.md) without simulating a
single cycle and exits nonzero on any unsuppressed error OR warning:

    python -m repro.analysis.check --all --lint --serve  # the CI gate
    python -m repro.analysis.check --scenario fig11
    python -m repro.analysis.check --spec my_scenario.json
    python -m repro.analysis.check --serve               # serve buckets
    python -m repro.analysis.check --all --out report.json

`--spec FILE` is the admission test for external specs (and for future
`TopologySpec` builders / scenario PRs): a file that doesn't construct,
strands a fault epoch, pairs VC modes illegally, or overflows the fused
grant key fails here before anything compiles.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from . import allowlist as allowlist_mod
from .findings import Report


def repo_root() -> Path:
    """The checkout root: `src/repro/...` two parents up from the
    package when run from a source tree, else the CWD."""
    pkg = Path(__file__).resolve().parents[1]   # .../src/repro
    if pkg.parent.name == "src":
        return pkg.parent.parent
    return Path.cwd()


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="Static verification of the repro engine and its "
                    "experiment specs (no simulation cycles).")
    p.add_argument("--all", action="store_true",
                   help="check every registered scenario (spec + compile "
                        "passes) and audit the engine traces (jaxpr pass)")
    p.add_argument("--scenario", action="append", default=[],
                   metavar="NAME", help="check one registered scenario "
                   "(repeatable)")
    p.add_argument("--spec", action="append", default=[], metavar="FILE",
                   help="check a JSON ExperimentSpec file (repeatable)")
    p.add_argument("--lint", action="store_true",
                   help="run the REPRO001-004 AST lint over the repo")
    p.add_argument("--serve", action="store_true",
                   help="certify the repro.exp.serve one-compile-per-"
                        "bucket guarantee over the mixed smoke "
                        "submission (servepass)")
    p.add_argument("--pairs", type=int, default=None, metavar="N",
                   help="flow pairs per CDG deadlock proof (default 400)")
    p.add_argument("--out", metavar="FILE",
                   help="write the JSON report here")
    p.add_argument("--allowlist", metavar="FILE",
                   help="extra allowlist entries (RULE path reason)")
    p.add_argument("--root", metavar="DIR",
                   help="repo root to lint (default: auto-detected)")
    p.add_argument("--verbose", "-v", action="store_true",
                   help="print info findings (the proof log) too")
    return p


def run(args) -> Report:
    report = Report()
    t0 = time.time()

    scenario_names = list(args.scenario)
    if args.all:
        from ..exp.registry import list_scenarios
        scenario_names = list_scenarios()

    if scenario_names or args.spec:
        from . import capacitypass, compilepass, specpass
        kw = {} if args.pairs is None else {"n_pairs": args.pairs}
        capacitypass.check_env(report)
        for name in scenario_names:
            specpass.check_scenario(name, report, **kw)
            compilepass.check_scenario(name, report)
            capacitypass.check_scenario(name, report)
        for path in args.spec:
            specpass.check_spec_file(path, report, **kw)
        report.mark_pass("spec")
        report.mark_pass("compile")
        report.mark_pass("capacity")

    if args.all:
        from . import jaxprpass
        jaxprpass.run_jaxprpass(report)
        report.mark_pass("jaxpr")

    if args.serve:
        from . import servepass
        servepass.check_submission(servepass.SMOKE_SUBMISSION, report)
        report.mark_pass("serve")

    if args.lint:
        from .lint import run_lint
        root = Path(args.root) if args.root else repo_root()
        report.extend(run_lint(root))
        report.mark_pass("lint")

    report.apply_allowlist(allowlist_mod.Allowlist.load(args.allowlist))
    report.add("check", "CHECK_TIME", "info", "-",
               f"all passes in {time.time() - t0:.1f}s")
    return report


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if not (args.all or args.scenario or args.spec or args.lint
            or args.serve):
        build_parser().print_help()
        print("\nnothing selected: pass --all, --lint, --serve, "
              "--scenario, or --spec", file=sys.stderr)
        return 2
    report = run(args)
    if args.out:
        Path(args.out).write_text(report.to_json() + "\n")
    print(report.render(verbose=args.verbose))
    return 1 if report.failed else 0


if __name__ == "__main__":
    sys.exit(main())
