"""Compile-signature pass: statically certify the one-compile-per-grid
guarantee `repro.exp.runner` promises.

A grid (one topology x routing x traffic cell over the lane axes) is
dispatched as ONE jitted whole-sweep call: every lane — each (rate,
seed, fault) combination — must lower with the same abstract signature,
or XLA retraces per lane and the "exactly one compile per grid" promise
(and the AOT-cache accounting in BENCH_perf.json) silently breaks.

The pass reconstructs each grid's dispatch signature abstractly:

  * the batched `SimState` via `jax.eval_shape` over `make_state` — the
    paper-scale state is never allocated;
  * the stacked lane fault pytree via `build_lane`/`stack_lanes` on
    SHAPE PROXIES of the grid's fault specs (an empty `FaultSet` per
    cold spec, an empty-epoch `FaultSchedule` with the spec's onsets per
    warm spec — fault *content* never changes shapes, epoch COUNT does),
    with the runner's promotion rule applied (any scheduled lane
    promotes cold lanes to 1-epoch schedules) and heterogeneous epoch
    counts padded by `stack_lanes`;
  * the lane rate/key arrays by their known [B]-shapes.

  COMPILE_ONE  error: the grid's lanes do not stack into one dense
               pytree (structure mismatch across lanes) — the batched
               dispatch would fail or fan out into per-lane compiles.
  COMPILE_SIG  info: the scenario's distinct signature count, i.e. how
               many XLA compiles the whole scenario costs and how many
               grids reuse an earlier cell's AOT entry (the runner's
               `_SWEEP_CACHE` sharing, proved from shapes alone).
"""
from __future__ import annotations

import hashlib

import jax

from ..core.engine.state import build_lane, make_state, stack_lanes
from ..core.routing import num_vcs
from ..core.topology import FaultSchedule, FaultSet
from ..exp.registry import get_scenario
from ..exp.spec import ExperimentSpec

PASS = "compile"


def _shape_proxy(fault_spec):
    """A fault value with this spec's fl SHAPES but empty content."""
    if fault_spec.is_none:
        return None
    if fault_spec.onsets:
        return FaultSchedule(
            ((0, FaultSet()),)
            + tuple((c, FaultSet()) for c in fault_spec.onsets))
    return FaultSet()


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _sig_digest(*parts) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(repr(p).encode())
    return h.hexdigest()[:12]


def grid_signature(topo, routing, traffic, axes) -> str:
    """The abstract lowering signature of one grid's single dispatch.
    Raises on lane-structure mismatch (the COMPILE_ONE failure)."""
    net = topo.build()
    cfg = routing.to_simconfig(axes)
    NV = (num_vcs(topo.kind, cfg.vc_mode, cfg.nonminimal)
          * cfg.vcs_per_class)
    B = axes.lanes_per_grid

    proxies = [_shape_proxy(f) for f in axes.faults]
    if any(isinstance(p, FaultSchedule) for p in proxies):
        # the runner's promotion rule: one scheduled lane makes every
        # lane a schedule (cold sets become 1-epoch schedules)
        proxies = [p if isinstance(p, FaultSchedule)
                   else FaultSchedule(((0, p or FaultSet()),))
                   for p in proxies]
    lanes_fl = [build_lane(net, cfg, p) for p in proxies]
    per_lane = (len(axes.faults) > 1
                or any(f.per_seed and not f.is_none and len(axes.seeds) > 1
                       for f in axes.faults))
    lane_data = stack_lanes(lanes_fl) if len(lanes_fl) > 1 else lanes_fl[0]

    state_sds = jax.eval_shape(
        lambda: make_state(net, cfg, NV, (B,)))
    shapes = jax.tree.map(lambda s: (s.shape, str(s.dtype)),
                          (state_sds, _sds(lane_data)))
    return _sig_digest(
        topo.kind, topo.params, tuple(sorted(routing.to_dict().items())),
        traffic.to_dict(), axes.warmup + axes.measure, B, per_lane,
        jax.tree.structure(shapes), tuple(jax.tree.leaves(shapes)))


def check_spec(spec: ExperimentSpec, origin: str, report) -> None:
    sigs: dict = {}
    ok = True
    for topo in spec.topologies:
        for routing in spec.routings:
            for traffic in spec.traffics:
                where = (f"{origin} [{topo.label} x {routing.label} "
                         f"x {traffic.label}]")
                try:
                    sig = grid_signature(topo, routing, traffic,
                                         spec.axes)
                except Exception as e:
                    ok = False
                    report.add(
                        PASS, "COMPILE_ONE", "error", where,
                        f"grid lanes do not lower to one dispatch "
                        f"signature: {type(e).__name__}: {e}")
                    continue
                sigs.setdefault(sig, []).append(where)
    if ok and sigs:
        n_grids = sum(len(v) for v in sigs.values())
        report.add(
            PASS, "COMPILE_SIG", "info", origin,
            f"{n_grids} grid(s), {len(sigs)} distinct compile "
            f"signature(s): every grid lowers to exactly one dispatch; "
            f"{n_grids - len(sigs)} grid(s) reuse an earlier cell's "
            f"AOT-cached executable")


def check_scenario(name: str, report) -> None:
    check_spec(get_scenario(name), f"scenario:{name}", report)
