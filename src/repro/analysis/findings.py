"""The findings model every analysis pass reports through.

A `Finding` is one verified fact about the repo or a spec: an `error`
(an invariant is broken), a `warning` (legal but almost certainly not
what the author meant — e.g. a registered fused scenario that silently
falls back to the two-pass grant), or an `info` note (what the pass
proved, so a clean run still documents its coverage).  `Report` collects
them across passes, applies the allowlist (suppressed findings stay in
the report as `info` with their suppression reason — nothing silently
disappears), renders the human table, and serializes the JSON artifact
the CI `analysis` job uploads.

Exit-code contract (`Report.failed`): any unsuppressed error OR warning
fails the gate.  Warnings gate too by design — the spec pass's overflow
warning is exactly the "silent fallback" class this subsystem exists to
surface, so letting it pass CI would rebuild the problem.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field

SEVERITIES = ("error", "warning", "info")


@dataclass
class Finding:
    """One fact one pass established.

    pass_name  "spec" | "jaxpr" | "compile" | "lint"
    rule       stable rule id (REPRO001.., SPEC_*, JAXPR_*, COMPILE_*)
    severity   "error" | "warning" | "info"
    location   "path/to/file.py:123" or "scenario:fig11" — whatever the
               pass can anchor the finding to
    message    one human sentence
    suppressed / suppress_reason: set by the allowlist, never by passes
    """

    pass_name: str
    rule: str
    severity: str
    location: str
    message: str
    suppressed: bool = False
    suppress_reason: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity {self.severity!r} not in {SEVERITIES}")

    @property
    def gates(self) -> bool:
        """True when this finding fails the CI gate."""
        return (self.severity in ("error", "warning")
                and not self.suppressed)

    def render(self) -> str:
        tag = f"{self.severity.upper()}"
        if self.suppressed:
            tag = f"allowed({self.suppress_reason})"
        return f"[{self.pass_name}:{self.rule}] {tag} {self.location}: " \
               f"{self.message}"


@dataclass
class Report:
    """All findings of one `repro.analysis.check` invocation."""

    findings: list = field(default_factory=list)
    passes_run: list = field(default_factory=list)

    def add(self, pass_name: str, rule: str, severity: str, location: str,
            message: str) -> Finding:
        f = Finding(pass_name, rule, severity, location, message)
        self.findings.append(f)
        return f

    def extend(self, findings) -> None:
        self.findings.extend(findings)

    def mark_pass(self, name: str) -> None:
        if name not in self.passes_run:
            self.passes_run.append(name)

    def apply_allowlist(self, allowlist) -> None:
        """Suppress matching error/warning findings (they remain in the
        report, tagged with the entry's reason)."""
        for f in self.findings:
            if f.severity == "info" or f.suppressed:
                continue
            entry = allowlist.match(f)
            if entry is not None:
                f.suppressed = True
                f.suppress_reason = entry.reason

    @property
    def gating(self) -> list:
        return [f for f in self.findings if f.gates]

    @property
    def failed(self) -> bool:
        return bool(self.gating)

    def to_dict(self) -> dict:
        sev = {s: sum(1 for f in self.findings
                      if f.severity == s and not f.suppressed)
               for s in SEVERITIES}
        return dict(
            passes_run=list(self.passes_run),
            counts=dict(total=len(self.findings), gating=len(self.gating),
                        suppressed=sum(1 for f in self.findings
                                       if f.suppressed), **sev),
            failed=self.failed,
            findings=[asdict(f) for f in self.findings])

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def render(self, verbose: bool = False) -> str:
        """Human summary: gating findings always, the full proof log
        with `verbose`."""
        lines = []
        shown = self.findings if verbose else [
            f for f in self.findings if f.gates or f.suppressed]
        lines += [f.render() for f in shown]
        n = self.to_dict()["counts"]
        lines.append(
            f"passes: {', '.join(self.passes_run) or '(none)'} — "
            f"{n['total']} findings ({n['error']} errors, "
            f"{n['warning']} warnings, {n['suppressed']} allowlisted)")
        lines.append("FAILED" if self.failed else "OK")
        return "\n".join(lines)
