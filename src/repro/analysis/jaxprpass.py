"""Jaxpr pass: abstract-trace audits of the engine step and route
kernels.  Everything here runs on `jax.make_jaxpr` / `jax.eval_shape`
over `ShapeDtypeStruct`s — no FLOPs, no device buffers at network scale
— except the batch-purity probe, which runs the route kernels concretely
on a few dozen packets (microseconds).

The traced matrix is every `(step_impl, vc_mode, fault-kind)` combination
on one small switch-less net: {jnp, fused, compact} x {baseline, updown,
updown_merged} x {pristine, cold FaultSet, warm FaultSchedule} — 27
traces.  `grant_impl` stays "jnp" (tracing the Pallas grant would need a
real backend; its bit-equality to the jnp oracle is a runtime test,
`tests/test_kernels.py`).

  JAXPR_DTYPE  a 64-bit aval appears anywhere in the step's jaxpr.  The
               engine is int32/float32 by design (x64 is disabled, and
               the packed arbitration keys budget for int32 exactly —
               see `fused.grant_form`); a silent promotion would either
               crash under x64=False or desync the overflow analysis.
  JAXPR_CARRY  the step's output state avals differ from its input state
               avals — `lax.scan` would reject the carry, and under vmap
               a widened carry silently doubles peak memory.
  JAXPR_OOB    a SCATTER carrying PROMISE_IN_BOUNDS reached the step.
               Engine writes must keep XLA's safe OOB modes (`.at[]`
               defaults to FILL_OR_DROP, which silently drops the
               sentinel writes the alive-mask logic produces); a
               promise-in-bounds scatter turns an out-of-bounds sentinel
               into undefined behavior.  Gathers are exempt: plain
               `x[i]` indexing lowers to a PROMISE_IN_BOUNDS gather by
               design (jnp normalizes the indices first), so the pass
               only counts them in the info line.
  JAXPR_BATCH  a route kernel broke batch purity: routing packet i must
               not depend on packet j != i (the engine vmaps one kernel
               over lanes AND arbitrates whole channel grids in one
               call).  Probed concretely: full-batch output vs the same
               packets routed one at a time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine.state import build_lane, make_state
from ..core.engine.step import make_step
from ..core.routing.pipeline import make_pipeline
from ..core.simulator import SimConfig
from ..exp.spec import FaultSpec, TopologySpec, TrafficSpec

PASS = "jaxpr"

STEP_IMPLS = ("jnp", "fused", "compact")
VC_MODES = ("baseline", "updown", "updown_merged")
FAULT_KINDS = ("pristine", "cold", "warm")

# the trace network: small enough to trace in milliseconds, big enough
# to exercise every channel class (mesh, local, global, inject, eject)
TRACE_TOPO = TopologySpec.switchless(a=2, b=2, m=2, n=4, noc=2, g=3)

_WIDE = {jnp.dtype("int64"), jnp.dtype("uint64"), jnp.dtype("float64")}


def _fault_for(kind: str) -> FaultSpec | None:
    # GLOBAL-only link faults: routable under every VC mode, so the same
    # fault population serves the whole matrix
    if kind == "pristine":
        return None
    onsets = (4,) if kind == "warm" else ()
    return FaultSpec(kind="links", frac=0.2, types=("global",),
                     onsets=onsets)


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype),
        tree)


def _subjaxprs(value):
    if hasattr(value, "jaxpr"):        # ClosedJaxpr
        yield value.jaxpr
    elif hasattr(value, "eqns"):       # Jaxpr
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _subjaxprs(v)


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from _iter_eqns(sub)


def trace_combo(step_impl: str, vc_mode: str, fault_kind: str):
    """Abstractly trace one matrix cell; returns (jaxpr, out_sds,
    state_sds) — raises whatever the trace raises."""
    net = TRACE_TOPO.build()
    cfg = SimConfig(warmup=4, measure=12, vc_mode=vc_mode,
                    route_mode="min", vcs_per_class=1, step_impl=step_impl)
    pattern = TrafficSpec("uniform").resolve(net)
    step, consts = make_step(net, cfg, pattern)
    fs = _fault_for(fault_kind)
    faults = None if fs is None else fs.sample(net, vc_mode, 0)
    fl = build_lane(net, cfg, faults)
    state = make_state(net, cfg, consts["NV"])

    fn = lambda s, t, k, r, f: step(s, (t, k, r, f))[0]
    args = (_sds(state),
            jax.ShapeDtypeStruct((), jnp.int32),
            _sds(jax.random.PRNGKey(0)),
            jax.ShapeDtypeStruct((), jnp.float32),
            _sds(fl))
    jaxpr = jax.make_jaxpr(fn)(*args)
    out_sds = jax.eval_shape(fn, *args)
    return jaxpr, out_sds, args[0]


def check_combo(report, step_impl: str, vc_mode: str,
                fault_kind: str) -> None:
    where = f"trace:{step_impl}/{vc_mode}/{fault_kind}"
    try:
        jaxpr, out_sds, state_sds = trace_combo(
            step_impl, vc_mode, fault_kind)
    except Exception as e:  # a combo that doesn't trace is itself a bug
        report.add(PASS, "JAXPR_TRACE", "error", where,
                   f"step does not trace: {type(e).__name__}: {e}")
        return

    wide, n_eqns, oob = set(), 0, []
    for eqn in _iter_eqns(jaxpr.jaxpr):
        n_eqns += 1
        for var in eqn.outvars:
            dt = getattr(var.aval, "dtype", None)
            try:
                dt = None if dt is None else jnp.dtype(dt)
            except TypeError:  # extended dtypes (PRNG keys)
                continue
            if dt is not None and dt in _WIDE:
                wide.add(f"{eqn.primitive.name}->{dt.name}")
        if eqn.primitive.name.startswith("scatter"):
            mode = eqn.params.get("mode")
            if mode is not None and "PROMISE_IN_BOUNDS" in str(mode):
                oob.append(eqn.primitive.name)
    if wide:
        report.add(PASS, "JAXPR_DTYPE", "error", where,
                   f"64-bit values in the step jaxpr "
                   f"({', '.join(sorted(wide))}): the engine is "
                   f"int32/float32 by contract (the packed arbitration "
                   f"key budget assumes it)")
    if oob:
        report.add(PASS, "JAXPR_OOB", "error", where,
                   f"{len(oob)} scatter op(s) with PROMISE_IN_BOUNDS: "
                   f"engine writes rely on FILL_OR_DROP to discard the "
                   f"-1/E sentinel indices; a promised scatter makes "
                   f"them undefined behavior")

    in_tree = jax.tree.map(lambda s: (s.shape, str(s.dtype)), state_sds)
    out_tree = jax.tree.map(lambda s: (s.shape, str(s.dtype)), out_sds)
    if in_tree != out_tree:
        report.add(PASS, "JAXPR_CARRY", "error", where,
                   "step output state avals differ from input state "
                   "avals — lax.scan would reject this carry")
    else:
        report.add(PASS, "JAXPR_TRACE", "info", where,
                   f"{n_eqns} eqns; carry stable, no 64-bit values, "
                   f"all scatters use safe OOB modes")


def probe_batch_purity(route_call, fl, cur, dest, mis, meta) -> list:
    """Concretely compare full-batch routing against one-packet slices;
    returns the indices where any output differs (empty == pure).
    `route_call(fl, cur, dest, mis, meta) -> (out_ch, req_vc, meta')`."""
    full = route_call(fl, cur, dest, mis, meta)
    bad = []
    for i in range(len(cur)):
        s = slice(i, i + 1)
        row = route_call(fl, cur[s], dest[s], mis[s], meta[s])
        if any(not np.array_equal(np.asarray(f[i:i + 1]), np.asarray(r))
               for f, r in zip(full, row)):
            bad.append(i)
    return bad


def check_kernel_batch_purity(report, net, vc_mode: str, *,
                              kernel=None, B: int = 48) -> None:
    """JAXPR_BATCH probe for one net's route kernel (or an injected
    `kernel`, for fixture tests)."""
    where = f"kernel:{net.meta['kind']}/{vc_mode}"
    pipe = make_pipeline(net, vc_mode)
    route_call = kernel if kernel is not None else pipe.kernel
    fl = pipe.tables(None)
    rng = np.random.default_rng(7)
    term_node = np.asarray(net.term_node)
    cur = jnp.asarray(term_node[rng.integers(0, net.num_terminals, B)])
    dest = jnp.asarray(rng.integers(0, net.num_terminals, B), jnp.int32)
    mis = jnp.full((B,), -1, jnp.int32)
    meta = jnp.zeros((B,), jnp.int32)
    bad = probe_batch_purity(route_call, fl, cur, dest, mis, meta)
    if bad:
        report.add(PASS, "JAXPR_BATCH", "error", where,
                   f"route kernel is not batch-pure: packets "
                   f"{bad[:6]} route differently alone vs in a batch "
                   f"of {B} — the vmapped engine would route them "
                   f"wrong")
    else:
        report.add(PASS, "JAXPR_BATCH", "info", where,
                   f"batch-pure over {B} probe packets")


def run_jaxprpass(report) -> None:
    for step_impl in STEP_IMPLS:
        for vc_mode in VC_MODES:
            for fault_kind in FAULT_KINDS:
                check_combo(report, step_impl, vc_mode, fault_kind)
    net = TRACE_TOPO.build()
    for vc_mode in VC_MODES:
        check_kernel_batch_purity(report, net, vc_mode)
    dfly = TopologySpec.dragonfly(t=2, l=2, gl=2).build()
    check_kernel_batch_purity(report, dfly, "baseline")
