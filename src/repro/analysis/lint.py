"""AST lint: repo-specific rules the generic linters can't know.

REPRO001  magic channel-type literal: comparing a `ch_type`-ish value
          against a bare int instead of the MESH/LOCAL/GLOBAL/INJECT/
          EJECT constants (`core.topology`).  A literal silently
          desynchronizes if the channel-type encoding ever changes.
          Scope: src/repro, benchmarks, examples.
REPRO002  environment read outside `src/repro/__init__.py`: every knob
          must go through that module (`repro.env_int`) so the whole
          env surface — including the two that MUST be read before jax
          initializes — is auditable in one file.  Scope: src/repro.
REPRO003  Python-level `if`/`while` on a traced value (`jnp`/`jax`/
          `lax` appears in the test expression) inside the engine or
          routing packages: under `jit` this either crashes
          (TracerBoolConversionError) or, worse, silently bakes one
          branch into the compiled step.  Trace-time branches on Python
          values (pytree structure, config) are fine and don't match.
          Scope: src/repro/core/engine, src/repro/core/routing.
REPRO004  `sys.path.insert` in benchmarks/examples: they run as modules
          from the repo root (`python -m benchmarks.run`); path hacks
          mask broken imports and break installed-package runs.
          Scope: benchmarks, examples.

All rules are pure AST — no imports of the linted code, so lint runs in
milliseconds and can't be confused by import-time side effects.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .findings import Finding

PASS = "lint"

# directories linted, relative to the repo root
LINT_TREES = ("src/repro", "benchmarks", "examples")

# REPRO001: int literals that collide with the channel-type encoding
_CH_TYPE_RANGE = range(0, 5)
_CH_TYPE_HINTS = ("ch_type", "ch_typ")

_TRACED_ROOTS = {"jnp", "lax"}          # REPRO003 name roots
_TRACED_JAX = "jax"


def _iter_py(root: Path):
    for tree in LINT_TREES:
        base = root / tree
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            if "__pycache__" in p.parts:
                continue
            yield p


def _rel(root: Path, path: Path) -> str:
    try:
        return path.relative_to(root).as_posix()
    except ValueError:
        return path.as_posix()


def _names_in(node) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _is_ch_literal(node) -> bool:
    return (isinstance(node, ast.Constant)
            and type(node.value) is int
            and node.value in _CH_TYPE_RANGE)


def _mentions_ch_type(node) -> bool:
    for n in ast.walk(node):
        name = None
        if isinstance(n, ast.Name):
            name = n.id
        elif isinstance(n, ast.Attribute):
            name = n.attr
        if name and any(h in name for h in _CH_TYPE_HINTS):
            return True
    return False


def _check_repro001(tree, rel, out):
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare):
            continue
        sides = [node.left] + list(node.comparators)
        lits = [s for s in sides if _is_ch_literal(s)]
        others = [s for s in sides if not _is_ch_literal(s)]
        if lits and any(_mentions_ch_type(s) for s in others):
            out.append(Finding(
                PASS, "REPRO001", "error", f"{rel}:{node.lineno}",
                f"channel type compared against magic literal "
                f"{lits[0].value}; use the MESH/LOCAL/GLOBAL/INJECT/"
                f"EJECT constants from repro.core.topology"))


def _check_repro002(tree, rel, out):
    if rel == "src/repro/__init__.py":
        return
    if not rel.startswith("src/repro/"):
        return
    for node in ast.walk(tree):
        hit = None
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute):
                # os.environ.get(...) / os.getenv(...)
                if (f.attr == "get" and isinstance(f.value, ast.Attribute)
                        and f.value.attr == "environ"):
                    hit = "os.environ.get"
                elif (f.attr == "getenv"
                      and isinstance(f.value, ast.Name)
                      and f.value.id == "os"):
                    hit = "os.getenv"
        elif (isinstance(node, ast.Subscript)
              and isinstance(node.ctx, ast.Load)
              and isinstance(node.value, ast.Attribute)
              and node.value.attr == "environ"):
            hit = "os.environ[...]"
        if hit:
            out.append(Finding(
                PASS, "REPRO002", "error", f"{rel}:{node.lineno}",
                f"environment read ({hit}) outside src/repro/"
                f"__init__.py; route the knob through repro.env_int so "
                f"the env surface stays auditable in one module"))


def _check_repro003(tree, rel, out):
    if not (rel.startswith("src/repro/core/engine/")
            or rel.startswith("src/repro/core/routing/")):
        return
    for node in ast.walk(tree):
        if not isinstance(node, (ast.If, ast.While)):
            continue
        names = _names_in(node.test)
        if names & _TRACED_ROOTS or _TRACED_JAX in names:
            kind = "if" if isinstance(node, ast.If) else "while"
            out.append(Finding(
                PASS, "REPRO003", "error", f"{rel}:{node.lineno}",
                f"Python-level `{kind}` on a traced expression "
                f"({', '.join(sorted(names & (_TRACED_ROOTS | {_TRACED_JAX})))} "
                f"in the test): under jit this crashes or bakes one "
                f"branch into the compiled step; use jnp.where/"
                f"lax.cond, or branch on trace-time Python state"))


def _check_repro004(tree, rel, out):
    if not (rel.startswith("benchmarks/") or rel.startswith("examples/")):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if (isinstance(f, ast.Attribute) and f.attr == "insert"
                and isinstance(f.value, ast.Attribute)
                and f.value.attr == "path"
                and isinstance(f.value.value, ast.Name)
                and f.value.value.id == "sys"):
            out.append(Finding(
                PASS, "REPRO004", "error", f"{rel}:{node.lineno}",
                "sys.path.insert in benchmarks/examples: run them as "
                "modules from the repo root (python -m ...) instead of "
                "patching the import path"))


_CHECKS = (_check_repro001, _check_repro002, _check_repro003,
           _check_repro004)


def lint_file(path: Path, rel: str) -> list:
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [Finding(PASS, "REPRO000", "error",
                        f"{rel}:{e.lineno or 0}",
                        f"file does not parse: {e.msg}")]
    out: list = []
    for check in _CHECKS:
        check(tree, rel, out)
    return out


def run_lint(root: Path) -> list:
    """Lint every in-scope file under `root`; returns the findings plus
    one info summary."""
    findings: list = []
    n = 0
    for path in _iter_py(root):
        n += 1
        findings.extend(lint_file(path, _rel(root, path)))
    findings.append(Finding(
        PASS, "LINT_COVERAGE", "info", str(root),
        f"linted {n} files under {', '.join(LINT_TREES)} "
        f"({len(findings)} rule hits)"))
    return findings
