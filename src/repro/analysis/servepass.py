"""Serve pass: statically certify the one-compile-per-bucket guarantee
`repro.exp.serve` promises.

The service buckets every submitted lane by its compile-signature key
(`scheduler.BucketKey`) and packs heterogeneous tenants' lanes into
ghost-padded, fixed-width dispatches, claiming total compiles == number
of distinct buckets.  That claim has two failure modes this pass checks
without compiling anything:

  * a bucket's lanes don't stack into one dense pytree (the packed
    dispatch would fail or fan out) — SERVE_ONE, error;
  * a pack's lowered signature depends on WHICH lanes landed in it
    (e.g. an epoch count the bucket key failed to capture), so two packs
    of one bucket would retrace — SERVE_SIG, error.

The certification lowers a mixed submission exactly the way the service
does (`scheduler.lower_request`: runner cell order, runner lane order,
memoized fault sampling), chunks each bucket's units FIFO into
pack-sized groups, and compares every pack's abstract dispatch
signature — `jax.eval_shape` over the batched `SimState` plus the real
ghost-padded, epoch-pinned lane pytree — against the bucket's CANONICAL
signature built from the key alone (empty fault proxies: fault content
never changes shapes, epoch count does, and `BucketKey.epochs` pins it).
Every pack matching its bucket's canonical signature proves signatures
are a function of the key alone: total compiles == distinct buckets, no
matter how tenants interleave.

  SERVE_BUCKET  info: the submission's bucket/signature census — how
                many lanes, buckets, and therefore compiles the mixed
                submission costs.

CLI: `python -m repro.analysis.check --serve` runs the pass over the
registered smoke scenarios (`SMOKE_SUBMISSION`) — the same heterogeneous
mix (cold, cold-faulted, warm-faulted) the CI serve-smoke job replays
dynamically.
"""
from __future__ import annotations

import jax

from ..core.engine.state import build_lane, make_state, stack_lanes
from ..core.routing import num_vcs
from ..core.topology import FaultSchedule, FaultSet, as_fault_schedule
from ..exp.registry import get_scenario
from .compilepass import _sds, _sig_digest

PASS = "serve"

# the heterogeneous standing submission `--serve` certifies: a cold
# fault-free grid, a cold multi-fault grid, and a warm-fault grid —
# one bucket each, three distinct signatures
SMOKE_SUBMISSION = ("smoke", "smoke_faults", "smoke_warm_faults")


def _canonical_fsets(key) -> list:
    """The bucket's key-derived lane proxy: shapes depend only on the
    epoch count (0 = cold), never on fault content."""
    if key.epochs:
        return [FaultSchedule(tuple((c, FaultSet())
                                    for c in range(key.epochs)))]
    return [FaultSet()]


def pack_signature(key, fsets, pack: int) -> str:
    """The abstract lowering signature of one ghost-padded pack dispatch
    of bucket `key` holding lanes with fault states `fsets` — the exact
    lane form `packer.Pack.open` builds (promote-to-schedule when the
    bucket is warm, stack with the epoch count pinned, replicate the
    last lane into the ghost pad).  Raises on lane-structure mismatch
    (the SERVE_ONE failure)."""
    from ..exp.serve.scheduler import bucket_cfg

    net = key.topology.build()
    cfg = bucket_cfg(key)
    NV = (num_vcs(key.topology.kind, cfg.vc_mode, cfg.nonminimal)
          * cfg.vcs_per_class)
    B = max(pack, len(fsets))
    if key.epochs:
        fsets = [as_fault_schedule(f if f is not None else FaultSet())
                 for f in fsets]
    lanes_fl = [build_lane(net, cfg, f) for f in fsets]
    lanes_fl += [lanes_fl[-1]] * (B - len(lanes_fl))
    lane_data = stack_lanes(lanes_fl, epochs=key.epochs or None)

    state_sds = jax.eval_shape(lambda: make_state(net, cfg, NV, (B,)))
    shapes = jax.tree.map(lambda s: (s.shape, str(s.dtype)),
                          (state_sds, _sds(lane_data)))
    return _sig_digest(
        key.topology.kind, key.topology.params,
        tuple(sorted(key.routing.to_dict().items())),
        key.traffic.to_dict(), key.warmup, key.measure, B,
        jax.tree.structure(shapes), tuple(jax.tree.leaves(shapes)))


def check_submission(names, report, pack: int = 8) -> None:
    """Certify a mixed submission of registered scenarios lowers to
    exactly one dispatch signature per bucket at pack width `pack`."""
    from ..exp.serve.scheduler import lower_request

    origin = "serve:" + "+".join(names)
    by_bucket: dict = {}
    seq = 0
    for rid, name in enumerate(names, start=1):
        units, _ = lower_request(get_scenario(name), rid, "ci", seq)
        seq += len(units)
        for u in units:
            by_bucket.setdefault(u.bucket, []).append(u)

    ok = True
    sigs: set = set()
    for key, units in sorted(by_bucket.items(),
                             key=lambda kv: kv[1][0].seq):
        where = f"{origin} [{key.label}]"
        try:
            canon = pack_signature(key, _canonical_fsets(key), pack)
        except Exception as e:
            ok = False
            report.add(PASS, "SERVE_ONE", "error", where,
                       f"bucket's canonical lane form does not lower to "
                       f"one dispatch: {type(e).__name__}: {e}")
            continue
        sigs.add(canon)
        for i in range(0, len(units), pack):
            chunk = units[i:i + pack]
            try:
                sig = pack_signature(key, [u.fset for u in chunk], pack)
            except Exception as e:
                ok = False
                report.add(
                    PASS, "SERVE_ONE", "error", where,
                    f"pack of lanes {[u.key for u in chunk]} does not "
                    f"stack into one dispatch: {type(e).__name__}: {e}")
                continue
            if sig != canon:
                ok = False
                report.add(
                    PASS, "SERVE_SIG", "error", where,
                    f"pack of lanes {[u.key for u in chunk]} lowers to "
                    f"signature {sig} != the bucket's canonical {canon}: "
                    f"the bucket key does not capture everything the "
                    f"compiled signature depends on (a second compile "
                    f"per bucket at runtime)")
    if ok and by_bucket:
        n_units = sum(len(v) for v in by_bucket.values())
        report.add(
            PASS, "SERVE_BUCKET", "info", origin,
            f"{len(names)} spec(s), {n_units} lane(s), "
            f"{len(by_bucket)} bucket(s) -> {len(sigs)} compile "
            f"signature(s) at pack={pack}: every ghost-padded pack "
            f"lowers to its bucket's one canonical dispatch signature, "
            f"so total compiles == distinct buckets regardless of "
            f"tenant interleaving")
