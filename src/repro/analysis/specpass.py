"""Spec pass: static verification of experiment specs, no simulation.

For every scenario (registered name or `--spec FILE` JSON) the pass
establishes, per (topology x routing) cell family:

  SPEC_INVALID   the spec doesn't construct: `ExperimentSpec.from_dict`
                 rejected it (bad VC/route pairing, fault onset past the
                 run, unknown kinds, ...).  Registered scenarios can't
                 hit this — construction already ran at import — so it
                 only fires for file-loaded specs; the construction-time
                 validators are thereby the exact rule set this pass
                 enforces on external specs.
  SPEC_VC        the VC scheme resolves (`routing.num_vcs`) — reported
                 as info with the resolved VC count per class.
  SPEC_CDG       a channel-dependency-graph deadlock proof failed: the
                 pristine net, a sampled cold fault set, or some epoch
                 of a warm `FaultSchedule` traced a CDG cycle or crossed
                 a dead channel (`routing.verify.assert_deadlock_free`).
  SPEC_FAULTS    the fault population itself can't be sampled routably
                 (`topology.validate_faults` rejected the composition).
  SPEC_REPAIR    info: the schedule contains repair (shrinking) epochs;
                 every such transition was additionally proven
                 restart-safe for packets in flight across the table
                 swap (`verify.assert_transition_safe`) — this is how
                 `check --spec` certifies a repair schedule statically.
  SPEC_GRANT_OVERFLOW  a `step_impl="fused"` cell whose packed
                 age<<log2(N)|key arbitration key would overflow int32,
                 so the engine takes the two-pass grant instead of the
                 combined single-segment_min form.  Legal — the fallback
                 is exact — but a registered *fused* scenario that
                 silently loses its fused grant is almost never intended,
                 so this gates as a warning.  The taken form is also
                 surfaced at runtime (`SweepResult.grant_form` /
                 BENCH_perf.json); this pass catches it before anything
                 compiles.

Proofs are memoized across scenarios by network identity
`(kind, params)` — NOT by label, because e.g. fig10a and the fig14
C-group grids name the same net under different labels — so the
18-scenario `--all` run proves each distinct (net, vc scheme, fault
population) combination exactly once.
"""
from __future__ import annotations

import json

import numpy as np

from ..core.engine.fused import grant_form
from ..core.routing import num_vcs
from ..core.routing.verify import (assert_deadlock_free,
                                   assert_schedule_deadlock_free)
from ..core.topology import FaultSchedule
from ..exp.registry import get_scenario
from ..exp.spec import ExperimentSpec

PASS = "spec"

# proof memo: key -> CDG edge count (successes only; failures re-raise)
_PROOF_CACHE: dict = {}

DEFAULT_PAIRS = 400
DEFAULT_EXHAUSTIVE = 20_000


def _fault_key(f) -> tuple:
    return (f.kind, f.frac, f.num, f.num_clusters, f.radius, f.types,
            f.seed, f.per_seed, f.onsets, f.repairs)


def _prove(net, topo, vc_mode, nonminimal, fault_spec, lane_seed,
           n_pairs, exhaustive_limit) -> tuple:
    """One memoized deadlock proof; returns (edges, epochs, repairs,
    cached).  `repairs` counts the schedule's shrinking (repair)
    transitions, each additionally proven restart-safe for in-flight
    packets (`assert_schedule_deadlock_free(check_transitions=True)`)."""
    key = (topo.kind, topo.params, vc_mode, nonminimal,
           None if fault_spec is None else _fault_key(fault_spec),
           None if fault_spec is None else lane_seed,
           n_pairs, exhaustive_limit)
    if key in _PROOF_CACHE:
        return _PROOF_CACHE[key] + (True,)
    rng = np.random.default_rng(0)
    repairs = 0
    if fault_spec is None:
        edges = assert_deadlock_free(
            net, vc_mode, nonminimal, rng, n_pairs=n_pairs,
            exhaustive_limit=exhaustive_limit)
        epochs = 1
    else:
        sampled = fault_spec.sample(net, vc_mode, lane_seed)
        if isinstance(sampled, FaultSchedule):
            per_epoch = assert_schedule_deadlock_free(
                net, vc_mode, nonminimal, rng, sampled, n_pairs=n_pairs)
            edges, epochs = sum(per_epoch), len(per_epoch)
            repairs = sum(
                1 for i in range(1, sampled.num_epochs)
                if not sampled.repaired_at(i).is_empty)
        else:
            edges = assert_deadlock_free(
                net, vc_mode, nonminimal, rng, n_pairs=n_pairs,
                exhaustive_limit=exhaustive_limit, faults=sampled)
            epochs = 1
    _PROOF_CACHE[key] = (edges, epochs, repairs)
    return edges, epochs, repairs, False


def check_spec(spec: ExperimentSpec, origin: str, report, *,
               n_pairs: int = DEFAULT_PAIRS,
               exhaustive_limit: int = DEFAULT_EXHAUSTIVE) -> None:
    """Run every spec-pass check on one constructed spec."""
    faulty = [f for f in spec.axes.faults if not f.is_none]
    lane_seed = spec.axes.seeds[0]
    for topo in spec.topologies:
        for routing in spec.routings:
            where = f"{origin} [{topo.label} x {routing.label}]"
            nonmin = routing.route_mode != "min"
            try:
                nv = num_vcs(topo.kind, routing.vc_mode, nonmin)
            except (KeyError, ValueError) as e:
                report.add(PASS, "SPEC_VC", "error", where,
                           f"VC scheme does not resolve: {e}")
                continue
            report.add(
                PASS, "SPEC_VC", "info", where,
                f"{nv} VC classes x {routing.vcs_per_class} per class")

            net = topo.build()
            proofs, edges, cached, repairs = 0, 0, 0, 0
            try:
                e, _, _, hit = _prove(net, topo, routing.vc_mode, nonmin,
                                      None, lane_seed, n_pairs,
                                      exhaustive_limit)
                proofs, edges, cached = 1, e, int(hit)
                for f in faulty:
                    e, epochs, reps, hit = _prove(
                        net, topo, routing.vc_mode, nonmin, f, lane_seed,
                        n_pairs, exhaustive_limit)
                    proofs += epochs
                    edges += e
                    repairs += reps
                    cached += int(hit)
            except AssertionError as e:
                report.add(PASS, "SPEC_CDG", "error", where,
                           f"deadlock proof failed: {e}")
                continue
            except ValueError as e:
                report.add(PASS, "SPEC_FAULTS", "error", where,
                           f"fault population unroutable: {e}")
                continue
            report.add(
                PASS, "SPEC_CDG", "info", where,
                f"{proofs} epoch CDG(s) acyclic ({edges} dependency "
                f"edges, {cached} proof(s) shared with earlier "
                f"scenarios)")
            if repairs:
                report.add(
                    PASS, "SPEC_REPAIR", "info", where,
                    f"{repairs} repair (shrinking) transition(s) proven "
                    f"restart-safe for in-flight packets on the "
                    f"recovered subgraph")

            if routing.step_impl in ("fused", "compact"):
                cfg = routing.to_simconfig(spec.axes)
                form = grant_form(net, cfg)
                impl = routing.step_impl
                if form == "combined":
                    report.add(PASS, "SPEC_GRANT", "info", where,
                               f"{impl} step takes the combined "
                               "single-segment_min grant")
                else:
                    cycles = spec.axes.warmup + spec.axes.measure
                    report.add(
                        PASS, "SPEC_GRANT_OVERFLOW", "warning", where,
                        f"{impl} step falls back to the two-pass grant: "
                        f"the packed cycle<<log2(N)|key arbitration key "
                        f"overflows int32 at {cycles} cycles on this "
                        f"net (exact but ~2x the segment_min work; "
                        f"shrink warmup+measure or accept with an "
                        f"allowlist entry)")


def check_scenario(name: str, report, **kw) -> None:
    check_spec(get_scenario(name), f"scenario:{name}", report, **kw)


def check_spec_file(path: str, report, **kw) -> None:
    """Spec-pass a JSON spec file — the admission test for external /
    future scenarios (e.g. new `TopologySpec` builders): construction
    errors land as SPEC_INVALID instead of raising."""
    try:
        with open(path) as f:
            d = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        report.add(PASS, "SPEC_INVALID", "error", path,
                   f"unreadable spec file: {e}")
        return
    try:
        spec = ExperimentSpec.from_dict(d)
    except (ValueError, KeyError, TypeError) as e:
        report.add(PASS, "SPEC_INVALID", "error", path,
                   f"spec does not construct: {e}")
        return
    check_spec(spec, f"spec:{path}", report, **kw)
