"""Atomic on-disk snapshots of simulation state (npz + manifest,
retention-K) — the persistence layer behind `repro.exp.serve` and any
long `LaneSession` run that must survive preemption."""
from .checkpointing import Checkpointer, restore_sim_state, save_sim_state

__all__ = ["Checkpointer", "restore_sim_state", "save_sim_state"]
