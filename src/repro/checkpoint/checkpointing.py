"""Checkpointing: atomic on-disk snapshots of (params, opt_state, data
state, step), async save thread, restore with resharding onto a possibly
different mesh (elastic restart).

Format: one .npz per snapshot with flattened "path -> array" keys + a
small JSON manifest; writes go to a temp dir then rename (atomic), and a
retention policy keeps the newest K snapshots.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import numpy as np
import jax


def _path_key(k) -> str:
    """One path entry -> a stable string: DictKey carries `.key`,
    GetAttrKey (dataclass nodes like `SimState`) `.name`, SequenceKey
    `.idx`."""
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _flatten(tree, prefix=""):
    out = {}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = "/".join(_path_key(k) for k in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(tree, arrays, shardings=None):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        key = "/".join(_path_key(k) for k in path)
        arr = arrays[key]
        # plain Python scalars (e.g. a session's cycle counter) are
        # valid template leaves; their numpy dtype is the target
        tdtype = (np.dtype(leaf.dtype) if hasattr(leaf, "dtype")
                  else np.asarray(leaf).dtype)
        if arr.dtype != tdtype:
            if arr.dtype.kind == "V" and arr.dtype.itemsize == tdtype.itemsize:
                # np.savez stores ml_dtypes (bfloat16) as raw void bytes;
                # reinterpret the BYTES through the template dtype.  Only
                # void arrays take this path: a typed mismatch (e.g. an
                # int32 snapshot restored into a float32 template) must
                # CONVERT, not reinterpret — `.view` there would silently
                # scramble every value (regression-tested in
                # tests/test_checkpoint.py).
                arr = arr.view(tdtype)
            else:
                arr = arr.astype(tdtype)
        leaves.append(arr)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.device_put(restored, shardings)
    return restored


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: dict, blocking: bool = True,
             extra: dict | None = None):
        """state: pytree; fetched to host before the async write.  `extra`
        is an optional JSON-serializable payload stored in the snapshot
        manifest (e.g. the serve loop's queue/session bookkeeping) and
        handed back by `restore(..., with_extra=True)` / `manifest()`."""
        host_state = jax.tree.map(np.asarray, state)  # device->host now
        if blocking:
            self._write(step, host_state, extra)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, extra),
                daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state: dict,
               extra: dict | None = None):
        tmp = os.path.join(self.dir, f".tmp-{step}-{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        arrays = _flatten(host_state)
        np.savez(os.path.join(tmp, "state.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "time": time.time(),
                       "keys": sorted(arrays),
                       "extra": extra}, f)
        final = os.path.join(self.dir, f"step-{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        snaps = self.list_steps()
        for s in snaps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def list_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.list_steps()
        return steps[-1] if steps else None

    def manifest(self, step: int | None = None) -> dict:
        """The JSON manifest of a snapshot (latest by default)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step-{step:08d}", "manifest.json")
        with open(path) as f:
            return json.load(f)

    def restore(self, template: dict, step: int | None = None,
                shardings=None) -> tuple[dict, int]:
        """Restore into the structure of `template`, placing shards per
        `shardings` (which may correspond to a different mesh than the one
        the snapshot was written from — elastic restart)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step-{step:08d}", "state.npz")
        with np.load(path) as z:
            arrays = {k: z[k] for k in z.files}
        return _unflatten_into(template, arrays, shardings), step


# ---------------------------------------------------------------------------
# Public SimState snapshot API (the engine/serve entry points)
# ---------------------------------------------------------------------------

def save_sim_state(directory: str, step: int, state, *,
                   extra: dict | None = None, keep: int = 3) -> str:
    """Write one atomic snapshot of a simulation-state pytree (e.g. a
    `LaneSession.export()` dict: `SimState` arrays + lane keys + cycle)
    under `directory/step-XXXXXXXX/`, keeping the newest `keep`
    snapshots.  `extra` rides along in the manifest (JSON).  Returns the
    snapshot directory path."""
    ckpt = Checkpointer(directory, keep=keep)
    ckpt.save(step, state, blocking=True, extra=extra)
    return os.path.join(directory, f"step-{step:08d}")


def restore_sim_state(directory: str, template, step: int | None = None):
    """Restore a `save_sim_state` snapshot into the structure (shapes +
    dtypes) of `template`; returns `(state, extra, step)` for the
    requested snapshot (latest by default).  Restored integer/float
    counters are exact — a resumed run continues bit-identically."""
    ckpt = Checkpointer(directory)
    state, step = ckpt.restore(template, step=step)
    extra = ckpt.manifest(step).get("extra")
    return state, extra, step
