"""Checkpointing: atomic on-disk snapshots of (params, opt_state, data
state, step), async save thread, restore with resharding onto a possibly
different mesh (elastic restart).

Format: one .npz per snapshot with flattened "path -> array" keys + a
small JSON manifest; writes go to a temp dir then rename (atomic), and a
retention policy keeps the newest K snapshots.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import numpy as np
import jax


def _flatten(tree, prefix=""):
    out = {}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(tree, arrays, shardings=None):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = arrays[key]
        tdtype = np.dtype(leaf.dtype)
        if arr.dtype != tdtype:
            # np.savez stores ml_dtypes (bfloat16) as raw void bytes;
            # reinterpret through the template dtype
            if arr.dtype.itemsize == tdtype.itemsize:
                arr = arr.view(tdtype)
            else:
                arr = arr.astype(tdtype)
        leaves.append(arr)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.device_put(restored, shardings)
    return restored


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: dict, blocking: bool = True):
        """state: pytree dict; fetched to host before the async write."""
        host_state = jax.tree.map(np.asarray, state)  # device->host now
        if blocking:
            self._write(step, host_state)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state), daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state: dict):
        tmp = os.path.join(self.dir, f".tmp-{step}-{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        arrays = _flatten(host_state)
        np.savez(os.path.join(tmp, "state.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "time": time.time(),
                       "keys": sorted(arrays)}, f)
        final = os.path.join(self.dir, f"step-{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        snaps = self.list_steps()
        for s in snaps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def list_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template: dict, step: int | None = None,
                shardings=None) -> tuple[dict, int]:
        """Restore into the structure of `template`, placing shards per
        `shardings` (which may correspond to a different mesh than the one
        the snapshot was written from — elastic restart)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step-{step:08d}", "state.npz")
        with np.load(path) as z:
            arrays = {k: z[k] for k in z.files}
        return _unflatten_into(template, arrays, shardings), step
