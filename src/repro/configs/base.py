"""Model / run configuration dataclasses and the architecture registry."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # expert dispatch payload: "bf16" or "int8" (per-token-scaled
    # quantization of the all-to-all, beyond-paper perf lever)
    dispatch: str = "bf16"


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    d_rnn: int = 0               # 0 -> d_model
    d_conv: int = 4
    c: float = 8.0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    # attention
    rope_theta: float = 1e4
    rope_frac: float = 1.0       # chatglm "2d" RoPE rotates half the dims
    use_bias: bool = False
    tie_embeddings: bool = False
    local_window: int = 0        # 0 = full attention
    # per-layer block kinds, cycled over the depth: "attn" | "rglru" | "ssm"
    block_pattern: tuple = ("attn",)
    # FFN kind per layer: "dense" everywhere unless moe is set; the first
    # `first_dense` layers stay dense (DeepSeekMoE)
    moe: MoEConfig | None = None
    first_dense: int = 0
    # SSM / hybrid
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    # encoder-decoder
    encoder_layers: int = 0
    # modality frontend stub: number of prefix embeddings in input_specs
    frontend: str | None = None      # "vision" | "audio"
    num_prefix: int = 0
    # numerics
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # schedule hint (minicpm -> wsd)
    schedule: str = "cosine"
    # whether long_500k applies (sub-quadratic sequence mixing)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def num_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        emb = V * d * (1 if self.tie_embeddings else 2)
        total = emb
        enc = self.encoder_layers
        for i in range(L + enc):
            kind = self.block_pattern[i % len(self.block_pattern)] \
                if i < L else "attn"
            if kind in ("attn", "local", "enc"):
                attn = d * self.hd * (self.num_heads + 2 * self.num_kv_heads) \
                    + self.num_heads * self.hd * d
            elif kind == "rglru":
                r = self.rglru.d_rnn or d
                attn = 2 * d * r + r * d + 3 * r
            else:  # ssm
                s = self.ssm
                di = s.d_inner(d)
                attn = d * (2 * di + 2 * s.d_state + s.num_heads(d)) + di * d
            if self.moe is not None and i >= self.first_dense and i < L:
                e = self.moe
                ffp = e.num_experts * 3 * d * e.d_expert \
                    + e.num_shared * 3 * d * e.d_expert + d * e.num_experts
            else:
                ffp = 3 * d * ff if ff else 0
            total += attn + ffp
        if enc and i >= L:
            pass
        return total

    def active_params(self) -> int:
        """Parameters touched per token (MoE: only routed top-k)."""
        if self.moe is None:
            return self.num_params()
        d, L = self.d_model, self.num_layers
        e = self.moe
        full = self.num_params()
        all_expert = (L - self.first_dense) * e.num_experts * 3 * d * e.d_expert
        active_expert = (L - self.first_dense) * e.top_k * 3 * d * e.d_expert
        return full - all_expert + active_expert


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


LM_SHAPES = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    kw = dict(
        num_layers=min(cfg.num_layers, 2 * len(cfg.block_pattern)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 1,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
        local_window=min(cfg.local_window, 32) if cfg.local_window else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        num_prefix=min(cfg.num_prefix, 4),
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, d_expert=32,
            num_shared=min(cfg.moe.num_shared, 1))
        kw["first_dense"] = min(cfg.first_dense, 1)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16,
                                        chunk=16)
    if cfg.rglru is not None:
        kw["rglru"] = dataclasses.replace(cfg.rglru, d_rnn=64)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
