"""The 10 assigned architectures, exact public-literature configs.

Every entry is selectable via --arch <id>; `input_specs` produces
ShapeDtypeStruct stand-ins for the dry-run (no allocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import (LM_SHAPES, MoEConfig, ModelConfig, RGLRUConfig,
                   ShapeConfig, SSMConfig, shape_by_name, smoke_config)


def _pad_vocab(v: int, mult: int = 256) -> int:
    """Pad vocab to a multiple of 256 so the embedding/logits shard across
    the 16-way model axis (Megatron-style vocab padding).  The true vocab
    sizes are documented per-arch; padding adds <0.6% parameters."""
    return ((v + mult - 1) // mult) * mult


def minicpm_2b() -> ModelConfig:
    # [arXiv:2404.06395] 40L d=2304 36H (kv=36) ff=5760 V=122753, WSD sched
    return ModelConfig(
        name="minicpm-2b", family="dense", num_layers=40, d_model=2304,
        num_heads=36, num_kv_heads=36, d_ff=5760,
        vocab_size=_pad_vocab(122753),
        tie_embeddings=True, schedule="wsd")


def chatglm3_6b() -> ModelConfig:
    # [arXiv:2406.12793] 28L d=4096 32H (kv=2) ff=13696 V=65024, 2D RoPE
    return ModelConfig(
        name="chatglm3-6b", family="dense", num_layers=28, d_model=4096,
        num_heads=32, num_kv_heads=2, d_ff=13696, vocab_size=_pad_vocab(65024),
        rope_frac=0.5, use_bias=False)


def llama32_3b() -> ModelConfig:
    # [hf:meta-llama/Llama-3.2] 28L d=3072 24H (kv=8) ff=8192 V=128256
    return ModelConfig(
        name="llama3.2-3b", family="dense", num_layers=28, d_model=3072,
        num_heads=24, num_kv_heads=8, d_ff=8192, vocab_size=_pad_vocab(128256),
        rope_theta=5e5, tie_embeddings=True)


def command_r_35b() -> ModelConfig:
    # [hf:CohereForAI/c4ai-command-r-v01] 40L d=8192 64H (kv=8) ff=22528
    return ModelConfig(
        name="command-r-35b", family="dense", num_layers=40, d_model=8192,
        num_heads=64, num_kv_heads=8, d_ff=22528, vocab_size=_pad_vocab(256000),
        use_bias=False, rope_theta=8e6)


def mamba2_780m() -> ModelConfig:
    # [arXiv:2405.21060] 48L d=1536 attn-free, ssm_state=128
    return ModelConfig(
        name="mamba2-780m", family="ssm", num_layers=48, d_model=1536,
        num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=_pad_vocab(50280),
        head_dim=64, block_pattern=("ssm",),
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      chunk=128),
        subquadratic=True)


def phi3_vision_4b() -> ModelConfig:
    # [hf:microsoft/Phi-3-vision-128k-instruct] 32L d=3072 32H ff=8192
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm", num_layers=32, d_model=3072,
        num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=_pad_vocab(32064),
        frontend="vision", num_prefix=576)  # 24x24 CLIP patch stub


def deepseek_moe_16b() -> ModelConfig:
    # [arXiv:2401.06066] 28L d=2048 16H ff_expert=1408, 2 shared + 64
    # routed top-6, first layer dense (d_ff = 4*2048 = 8192... the public
    # config uses 10944 for the dense layer; we keep 4d)
    return ModelConfig(
        name="deepseek-moe-16b", family="moe", num_layers=28, d_model=2048,
        num_heads=16, num_kv_heads=16, d_ff=8192, vocab_size=_pad_vocab(102400),
        moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2),
        first_dense=1)


def qwen3_moe_235b() -> ModelConfig:
    # [hf:Qwen/Qwen3 family] 94L d=4096 64H (kv=4) ff_expert=1536,
    # 128 experts top-8
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe", num_layers=94,
        d_model=4096, num_heads=64, num_kv_heads=4, d_ff=0,
        vocab_size=_pad_vocab(151936), head_dim=128,
        moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536))


def seamless_m4t_medium() -> ModelConfig:
    # [arXiv:2308.11596] enc-dec 12L+12L d=1024 16H ff=4096 V=256206
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec", num_layers=12,
        d_model=1024, num_heads=16, num_kv_heads=16, d_ff=4096,
        vocab_size=_pad_vocab(256206), encoder_layers=12, frontend="audio",
        num_prefix=0)


def recurrentgemma_2b() -> ModelConfig:
    # [arXiv:2402.19427] 26L d=2560 10H (kv=1) ff=7680, RG-LRU + local
    # attention 1:2 (pattern rglru, rglru, local-attn), window 2048
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid", num_layers=26,
        d_model=2560, num_heads=10, num_kv_heads=1, d_ff=7680,
        vocab_size=_pad_vocab(256000), head_dim=256, local_window=2048,
        block_pattern=("rglru", "rglru", "local"),
        rglru=RGLRUConfig(d_rnn=2560, d_conv=4),
        tie_embeddings=True, subquadratic=True)


ARCHS = {
    c.name: f for f, c in
    [(f, f()) for f in (minicpm_2b, chatglm3_6b, llama32_3b, command_r_35b,
                        mamba2_780m, phi3_vision_4b, deepseek_moe_16b,
                        qwen3_moe_235b, seamless_m4t_medium,
                        recurrentgemma_2b)]
}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return smoke_config(get_config(name[:-len("-smoke")]))
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]()


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell applies (DESIGN.md Sec. 4)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: O(L^2) at 512K not deployable"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                batch_override: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    f = cfg.jdtype
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.frontend == "vision":
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix, cfg.d_model), f)
        if cfg.family == "encdec":
            specs["src_embeds"] = jax.ShapeDtypeStruct(
                (B, S // 4, cfg.d_model), f)  # audio frames ~4x shorter
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.frontend == "vision":
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix, cfg.d_model), f)
        if cfg.family == "encdec":
            specs["src_embeds"] = jax.ShapeDtypeStruct(
                (B, S // 4, cfg.d_model), f)
        return specs
    # decode: one new token against a seq_len cache
    specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.family == "encdec":
        specs["memory"] = jax.ShapeDtypeStruct((B, S // 4, cfg.d_model), f)
    return specs
