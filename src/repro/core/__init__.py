"""Core library: the paper's contribution (switch-less Dragonfly on wafers).

Topology construction, analytical models (Eqs. 1-7, Table II/III), routing
(Alg. 1 + VC reduction), the flit-level JAX network simulator, traffic
patterns, topology-aware collectives, and the fabric cost model used by the
training-stack roofline.
"""
from . import analytical, collectives, cost_model, routing, simulator
from . import topology, traffic
from .topology import (Network, SwitchDragonflyParams, SwitchlessParams,
                       build_switch_dragonfly, build_switchless)
from .simulator import SimConfig, SimResult, Simulator

__all__ = [
    "analytical", "collectives", "cost_model", "routing", "simulator",
    "topology", "traffic", "Network", "SwitchDragonflyParams",
    "SwitchlessParams", "build_switch_dragonfly", "build_switchless",
    "SimConfig", "SimResult", "Simulator",
]
