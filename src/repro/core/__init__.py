"""Core library: the paper's contribution (switch-less Dragonfly on wafers).

Topology construction, analytical models (Eqs. 1-7, Table II/III), routing
(Alg. 1 + VC reduction), the flit-level JAX network simulator, traffic
patterns, topology-aware collectives, and the fabric cost model used by the
training-stack roofline.
"""
from . import analytical, collectives, cost_model, engine, routing, simulator
from . import topology, traffic
from .topology import (CH_TYPE_NAMES, Network, SwitchDragonflyParams,
                       SwitchlessParams, build_switch_dragonfly,
                       build_switchless)
from .engine import BatchedSweep, SimState, SweepResult
from .simulator import SimConfig, SimResult, Simulator

__all__ = [
    "analytical", "collectives", "cost_model", "engine", "routing",
    "simulator", "topology", "traffic", "CH_TYPE_NAMES", "Network",
    "SwitchDragonflyParams", "SwitchlessParams", "build_switch_dragonfly",
    "build_switchless", "BatchedSweep", "SimState", "SweepResult",
    "SimConfig", "SimResult", "Simulator",
]
