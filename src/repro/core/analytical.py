"""Analytical models from the paper: Eqs. (1)-(7), Table II hop costs,
Table III case-study cost comparison, and the Fig. 15 energy model.

All quantities are closed-form; they double as property-test oracles for the
topology builder and as roofline inputs for the training-fabric cost model.
"""
from __future__ import annotations

from dataclasses import dataclass

from .topology import SwitchlessParams, SwitchDragonflyParams

# --- Table II: rough per-hop costs -----------------------------------------
HOP_LATENCY_NS = {
    "global": 150.0,     # H_g  optical cable (+ToF, excluded as in paper)
    "local": 150.0,      # H_l  copper cable
    "sr": 5.0,           # H_sr RDL on-wafer / SR-LR conversion
    "on_chip": 1.0,      # metal layer
}
HOP_ENERGY_PJ_PER_BIT = {
    "global": 20.0,
    "local": 20.0,
    "sr": 2.0,
    "on_chip": 0.1,
    # Sec. V-C: "assume an intra-C-group hop takes 1pj/bit on average"
    "cg_avg": 1.0,
}


# --- Eqs. (1)-(7) -----------------------------------------------------------

def total_chiplets(p: SwitchlessParams) -> int:
    """Eq. (1): N = a b m^2 [ab(mn - ab + 1) + 1] (at maximum g)."""
    ab, m, n = p.ab, p.m, p.n
    return ab * m * m * (ab * (m * n - ab + 1) + 1)


def global_throughput_bound(p: SwitchlessParams) -> float:
    """Eq. (2): T_global < (mn - ab + 1) / m^2  [flits/cycle/chip]."""
    return (p.m * p.n - p.ab + 1) / (p.m * p.m)


def is_balanced_config(p: SwitchlessParams) -> bool:
    """Eq. (3): n = 3m and ab = 2 m^2."""
    return p.n == 3 * p.m and p.ab == 2 * p.m * p.m


def local_throughput_bound(p: SwitchlessParams) -> float:
    """Eq. (4): T_local < ab / m^2  [flits/cycle/chip]."""
    return p.ab / (p.m * p.m)


def cgroup_throughput_bound(p: SwitchlessParams) -> float:
    """Eq. (5): T_cg < n / m  [flits/cycle/chip]."""
    return p.n / p.m


def cgroup_bisection(p: SwitchlessParams) -> float:
    """Eq. (6): B_cg = n m / 2 = k / 2  [flits/cycle] (full-duplex)."""
    return p.n * p.m / 2


@dataclass(frozen=True)
class Diameter:
    """Hop-count diameter decomposition."""
    global_hops: int
    local_hops: int
    sr_hops: int
    term_hops: int = 0  # switch-based terminal<->switch hops (H_l*)

    def latency_ns(self) -> float:
        return (self.global_hops * HOP_LATENCY_NS["global"]
                + (self.local_hops + self.term_hops) * HOP_LATENCY_NS["local"]
                + self.sr_hops * HOP_LATENCY_NS["sr"])


def switchless_diameter(p: SwitchlessParams) -> Diameter:
    """Eq. (7): D = H_g + 2 H_l + (8m - 2) H_sr."""
    return Diameter(global_hops=1, local_hops=2, sr_hops=8 * p.m - 2)


def switchless_single_wgroup_diameter(p: SwitchlessParams) -> Diameter:
    """Sec. III-D1: single fully-connected W-group, D = H_l + (4m-2) H_sr."""
    return Diameter(global_hops=0, local_hops=1, sr_hops=4 * p.m - 2)


def dragonfly_diameter() -> Diameter:
    """Traditional Dragonfly: H_g + 2 H_l + 2 H_l* (terminal hops)."""
    return Diameter(global_hops=1, local_hops=2, sr_hops=0, term_hops=2)


# --- Sec. III-C / Table III case-study cost model ---------------------------

@dataclass(frozen=True)
class CaseStudy:
    name: str
    num_switches: int
    num_cabinets: int
    num_processors: int
    cable_count: int          # inter-cabinet cables (N in the table)
    cable_length_E: float     # total length in units of E (datacenter edge)
    t_local: float
    t_global: float


def dragonfly_slingshot_case() -> CaseStudy:
    """Table III 'Dragonfly (Slingshot)' row.

    64-port switches 16:31:17 split -> groups of 32 switches, 545 groups,
    512 terminals/group -> 279040 processors; 17440 switches; 64 blades x 2
    nodes + 8 ToR switches -> 2180 cabinets.
    """
    switches = 545 * 32
    processors = 545 * 32 * 16
    # links: terminal links N = 279040 excluded (intra-cabinet); local links
    # 32*31/2*545 = 270,320; global links 545*544/2 = 148,240.  Table counts
    # N=698K total endpoints' cables and 154K*E inter-cabinet length.
    local_links = 545 * 32 * 31 // 2
    global_links = 545 * 544 // 2
    cable_count = processors + local_links + global_links
    return CaseStudy(
        name="dragonfly-slingshot", num_switches=switches, num_cabinets=2180,
        num_processors=processors, cable_count=cable_count,
        cable_length_E=154e3, t_local=1.0, t_global=1.0)


def switchless_case(p: SwitchlessParams | None = None) -> CaseStudy:
    """Table III 'Switch-less Dragonfly' row: n=12, m=4, a=4, b=8.

    0 switches; 8 wafers/cabinet -> ceil(545*8/8)=545 cabinets; inter-cabinet
    cables are the global links only (W-group = 1 cabinet), local intra-
    W-group links are intra-cabinet.
    """
    from .topology import paper_table3_switchless
    p = p or paper_table3_switchless()
    g = p.g_max
    n_wafers = g * p.b
    cabinets = n_wafers // p.b  # one W-group (8 wafers) per cabinet
    global_links = g * (g - 1) // 2
    local_links = g * (p.ab * (p.ab - 1) // 2)
    return CaseStudy(
        name="switchless-dragonfly", num_switches=0, num_cabinets=cabinets,
        num_processors=total_chiplets(p),
        cable_count=global_links + local_links,
        cable_length_E=72e3,
        t_local=local_throughput_bound(p), t_global=1.0)


# --- Fig. 15 energy model ----------------------------------------------------

def energy_per_packet_pj_per_bit(hops_by_type: dict[str, float]) -> float:
    """Average transmission energy from per-type average hop counts.

    hops_by_type keys: 'mesh' (intra-C-group, priced at cg_avg=1 pj/bit per
    Sec. V-C), 'local'/'global' (20 pj/bit), 'inject'/'eject'.
    Switch-based terminal links (inject/eject over cables) cost 20 pj/bit;
    switch-less inject/eject are on-chip (0.1 pj/bit).
    """
    e = HOP_ENERGY_PJ_PER_BIT
    total = 0.0
    total += hops_by_type.get("mesh", 0.0) * e["cg_avg"]
    total += hops_by_type.get("local", 0.0) * e["local"]
    total += hops_by_type.get("global", 0.0) * e["global"]
    total += hops_by_type.get("term_cable", 0.0) * e["local"]
    total += hops_by_type.get("term_onchip", 0.0) * e["on_chip"]
    return total


# --- sanity helpers ----------------------------------------------------------

def summarize(p: SwitchlessParams) -> dict:
    return dict(
        a=p.a, b=p.b, m=p.m, n=p.n, k=p.k, ab=p.ab, h=p.h,
        g_max=p.g_max, N=total_chiplets(p),
        T_global=global_throughput_bound(p),
        T_local=local_throughput_bound(p),
        T_cg=cgroup_throughput_bound(p),
        B_cg=cgroup_bisection(p),
        balanced=is_balanced_config(p),
        diameter=switchless_diameter(p),
    )


def dragonfly_scale(p: SwitchDragonflyParams) -> dict:
    return dict(groups=p.num_groups, chips=p.num_chips, radix=p.radix)
