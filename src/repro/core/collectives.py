"""Topology-aware collectives: the paper's AllReduce schedules (Sec. III-B4,
Fig. 4, Fig. 14) as real JAX collectives for the training stack.

Three layers:
  * `ring_all_reduce` / `bidir_ring_all_reduce` — explicit ring schedules
    built on `lax.ppermute` (the Fig. 14 algorithms).  The bidirectional
    variant halves the message and pushes the halves in opposite directions,
    which on the wafer fabric doubles effective injection (the paper's
    4-ports-per-chip argument).
  * `hierarchical_psum` — reduce-scatter on the on-wafer axis, cross-wafer
    psum on the scattered shards, all-gather back (Fig. 4(b) transposed to
    mesh axes).  This keeps the high-volume phases on the highest-bandwidth
    tier, Eq. (3)'s load-balance argument applied to ML collectives.
  * `psum_2d` — 2D algorithm over two mesh axes (row phase then column
    phase), the O(sqrt(N)) schedule of Fig. 4(b).

All functions must run inside `shard_map` with the named axes bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


def _axis_size(axis_name: str) -> int:
    if hasattr(lax, "axis_size"):       # jax >= 0.4.32... renamed over time
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)       # portable fallback


def ring_all_reduce(x: jax.Array, axis_name: str):
    """Unidirectional ring allreduce via ppermute (reduce-scatter +
    all-gather), 2(n-1) steps, each moving |x|/n bytes per link."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    idx = lax.axis_index(axis_name)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    pad = (-x.shape[0]) % n
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x
    chunks = xp.reshape(n, -1, *xp.shape[1:])

    # reduce-scatter: explicit n-1 ppermute steps (n = mesh axis size, small
    # and static).  After step n-1 this rank holds the fully reduced chunk
    # at position (idx + 1) % n.
    acc = None
    send = chunks[idx]
    for i in range(1, n):
        recv = lax.ppermute(send, axis_name, fwd)
        pos = (idx - i + n) % n
        if i < n - 1:
            send = recv + chunks[pos]
        else:
            acc = recv + chunks[pos]
    # all-gather: circulate the reduced chunk n-1 more steps
    out_chunks = jnp.zeros_like(chunks)
    pos = (idx - (n - 1) + n) % n
    out_chunks = out_chunks.at[pos].set(acc)
    send = acc
    for i in range(n - 1):
        recv = lax.ppermute(send, axis_name, fwd)
        pos = (idx - (n - 1) - (i + 1)) % n
        out_chunks = out_chunks.at[pos].set(recv)
        send = recv
    y = out_chunks.reshape(-1, *xp.shape[1:])
    return y[:x.shape[0]] if pad else y


def bidir_ring_all_reduce(x: jax.Array, axis_name: str):
    """Bidirectional ring: halves travel in opposite directions (Fig. 14)."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    half = x.shape[0] // 2
    a, b = x[:half], x[half:]
    y1 = ring_all_reduce(a, axis_name)
    # reverse direction: relabel ranks by flipping the permutation
    y2 = _ring_all_reduce_rev(b, axis_name)
    return jnp.concatenate([y1, y2], axis=0)


def _ring_all_reduce_rev(x: jax.Array, axis_name: str):
    n = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    bwd = [(i, (i - 1) % n) for i in range(n)]
    pad = (-x.shape[0]) % n
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x
    chunks = xp.reshape(n, -1, *xp.shape[1:])
    acc = None
    send = chunks[idx]
    for i in range(1, n):
        recv = lax.ppermute(send, axis_name, bwd)
        pos = (idx + i) % n
        if i < n - 1:
            send = recv + chunks[pos]
        else:
            acc = recv + chunks[pos]
    # acc = fully reduced chunk (idx - 1) % n
    out_chunks = jnp.zeros_like(chunks)
    out_chunks = out_chunks.at[(idx - 1) % n].set(acc)
    send = acc
    for i in range(n - 1):
        recv = lax.ppermute(send, axis_name, bwd)
        pos = (idx + i) % n
        out_chunks = out_chunks.at[pos].set(recv)
        send = recv
    y = out_chunks.reshape(-1, *xp.shape[1:])
    return y[:x.shape[0]] if pad else y


def hierarchical_psum(x: jax.Array, wafer_axis: str, cross_axes):
    """Reduce-scatter on-wafer -> cross-wafer psum -> all-gather on-wafer.

    The heavy 2(n-1)/n traffic stays on the on-wafer tier; the cross-wafer
    tier moves only 1/n of the bytes per device.
    """
    if isinstance(cross_axes, str):
        cross_axes = (cross_axes,)
    n = _axis_size(wafer_axis)
    pad = (-x.shape[0]) % n
    orig = x.shape[0]
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    s = lax.psum_scatter(x, wafer_axis, scatter_dimension=0, tiled=True)
    s = lax.psum(s, cross_axes)
    y = lax.all_gather(s, wafer_axis, axis=0, tiled=True)
    return y[:orig] if pad else y


def psum_2d(x: jax.Array, row_axis: str, col_axis: str):
    """Fig. 4(b): 2D algorithm — reduce along rows then columns, scattered,
    then gather back; latency O(sqrt(N)) instead of O(N)."""
    n = _axis_size(row_axis)
    pad = (-x.shape[0]) % n
    orig = x.shape[0]
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    s = lax.psum_scatter(x, row_axis, scatter_dimension=0, tiled=True)
    s = lax.psum(s, col_axis)
    y = lax.all_gather(s, row_axis, axis=0, tiled=True)
    return y[:orig] if pad else y


def reduce_scatter(x: jax.Array, axis_name: str):
    return lax.psum_scatter(x, axis_name, scatter_dimension=0, tiled=True)


def all_gather(x: jax.Array, axis_name: str):
    return lax.all_gather(x, axis_name, axis=0, tiled=True)
