"""Fabric cost model: prices collective traffic per mesh axis under

  (i)  the flat grading-spec ICI model (50 GB/s/link, 2D torus-ish), and
  (ii) the switch-less Dragonfly wafer fabric of the paper (on-wafer UCIe
       mesh per C-group, LR SerDes local links per W-group, global links
       across W-groups).

Axis->tier mapping (DESIGN.md Sec. 2): "model" -> on-wafer (C-group),
"data" -> intra-W-group local links, "pod" -> global links.
"""
from __future__ import annotations

from dataclasses import dataclass

# grading-spec hardware constants (TPU-v5e-like)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW_PER_LINK = 50e9            # bytes/s per link (flat model)
ICI_LINKS_PER_CHIP = 4            # 2D torus: 4 links usable per chip

# paper Sec. V-A1 fabric numbers (bytes/s)
ONWAFER_PORT_BW = 4096e9 / 8      # 512 GB/s per on-wafer channel (128x UCIe)
LR_PORT_BW = 896e9 / 8            # 112 GB/s per off-wafer SerDes port


@dataclass(frozen=True)
class FabricTier:
    name: str
    link_bw: float           # bytes/s per link
    links_per_chip: float    # links usable by one chip on this tier


@dataclass(frozen=True)
class Fabric:
    """Per-mesh-axis tier table."""
    name: str
    tiers: dict  # axis name -> FabricTier

    def tier(self, axis: str) -> FabricTier:
        return self.tiers.get(axis, self.tiers["_default"])

    def collective_seconds(self, axis: str, bytes_per_chip: float) -> float:
        """Time to move `bytes_per_chip` over the given axis's tier."""
        t = self.tier(axis)
        return bytes_per_chip / (t.link_bw * t.links_per_chip)


def flat_ici_fabric() -> Fabric:
    t = FabricTier("ici", ICI_BW_PER_LINK, 1.0)
    return Fabric("flat-ici", {"_default": t})


def switchless_wafer_fabric(cg_bw_mult: float = 1.0) -> Fabric:
    """The paper's fabric: per-chip on-wafer bandwidth is n/4-ports-per-edge
    x 512 GB/s (we count 2 usable mesh links per chip per direction of
    travel, conservative); local/global links are 112 GB/s SerDes with
    multiple ports per chip available through the C-group (injection not
    capped at one link — the switch-less advantage)."""
    return Fabric("switchless-wafer", {
        "model": FabricTier("on-wafer", ONWAFER_PORT_BW * cg_bw_mult, 2.0),
        "data": FabricTier("wgroup-local", LR_PORT_BW, 2.0),
        "pod": FabricTier("global", LR_PORT_BW, 1.0),
        "_default": FabricTier("global", LR_PORT_BW, 1.0),
    })


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    collective_bytes_per_chip: float
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def step_time_overlap_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the compute roofline achieved assuming perfect
        overlap: compute_s / max(all terms)."""
        m = self.step_time_overlap_s
        return self.compute_s / m if m > 0 else 0.0


def roofline(flops: float, hbm_bytes: float, collective_bytes_by_axis: dict,
             chips: int, fabric: Fabric | None = None,
             model_flops: float = 0.0) -> RooflineTerms:
    """Three-term roofline from dry-run artifacts.

    flops/hbm_bytes are whole-program (all chips) numbers from
    cost_analysis(); collective_bytes_by_axis maps mesh axis -> total bytes
    crossing that axis (whole program).
    """
    fabric = fabric or flat_ici_fabric()
    compute_s = flops / (chips * PEAK_FLOPS_BF16)
    memory_s = hbm_bytes / (chips * HBM_BW)
    coll_s = 0.0
    coll_bytes = 0.0
    for axis, byts in collective_bytes_by_axis.items():
        per_chip = byts / chips
        coll_bytes += per_chip
        coll_s += fabric.collective_seconds(axis, per_chip)
    return RooflineTerms(compute_s=compute_s, memory_s=memory_s,
                         collective_s=coll_s, flops=flops,
                         hbm_bytes=hbm_bytes,
                         collective_bytes_per_chip=coll_bytes,
                         model_flops=model_flops)
