"""Modular batch-parallel simulation engine.

The cycle is decomposed into explicit phases over a pytree `SimState`:

    inject    packet generation + misroute decision + source-queue push
    arbitrate routing, VC expansion, credit check, age-based grant
    apply     pops / pushes / misroute clearing / serialization
    stats     delivered / latency / hop accumulators

`step.make_step` wires them into one pure cycle function; `sweep.BatchedSweep`
vmaps it over a (rate x seed) lane grid so an entire load-latency curve runs
in a single jitted `lax.scan`.  `repro.core.simulator` is the thin
compatibility facade over this package.
"""
from .state import (SimState, SimStats, build_consts, build_lane,
                    epoch_index, is_scheduled, lane_epoch, make_state,
                    resolve_epoch, stack_lanes)
from .arbitrate import Requests, make_arbitrate_fn
from .inject import (make_inject_fn, make_misroute_fn, build_ugal_watch,
                     ugal_queue_len)
from .apply import make_apply_fn
from .stats import accumulate, finalize, zero_stats
from .step import make_step, run_scan
from .sweep import (BatchedSweep, LaneRun, LaneSession, SweepResult,
                    clear_aot_cache, compile_counter, lane_mesh,
                    run_scan_batched, superstep)

__all__ = [
    "SimState", "SimStats", "Requests", "build_consts", "build_lane",
    "epoch_index", "is_scheduled", "lane_epoch", "resolve_epoch",
    "make_state", "stack_lanes", "make_arbitrate_fn", "make_inject_fn",
    "make_misroute_fn", "build_ugal_watch", "ugal_queue_len",
    "make_apply_fn", "accumulate", "finalize", "zero_stats", "make_step",
    "run_scan", "BatchedSweep", "LaneRun", "LaneSession", "SweepResult",
    "clear_aot_cache", "compile_counter", "lane_mesh",
    "run_scan_batched", "superstep",
]
