"""Apply phase: commit the granted movements — pop winners from their
buffers / source queues, push them into the downstream (channel, VC) buffer,
clear satisfied misroutes, stamp cut-through readiness, and charge channel
serialization (credit-based flow control reserved the slot at grant time).
"""
from __future__ import annotations

import jax.numpy as jnp

from ..topology import EJECT, Network
from .arbitrate import Requests
from .state import SimState


def make_apply_fn(net: Network, cfg, consts):
    E, NV, ER = consts["E"], consts["NV"], consts["E_req"]
    S, Q = cfg.buf_pkts, cfg.srcq_pkts

    def apply_moves(state: SimState, req: Requests, win, won_ch,
                    t, reap=None) -> SimState:
        win_buf = win[:ER * NV].reshape(ER, NV)
        win_src = win[ER * NV:]
        # reaped rows pop exactly like winners (head advance + count
        # decrement) but push nowhere and charge no serialization; the
        # masks are disjoint (a winner's out channel is live, a reap
        # victim's is -1 or dead).  Reap can hit source rows too — a
        # source head whose injection channel died is undeliverable —
        # so both the buffer and the source pops widen.
        pop_buf = (win_buf if reap is None
                   else win_buf | reap[:ER * NV].reshape(ER, NV))
        pop_src = win_src if reap is None else win_src | reap[ER * NV:]

        # pops (the trailing eject rows never pop: concat keeps them dense)
        b_head = jnp.concatenate(
            [(state.b_head[:ER] + pop_buf) % S, state.b_head[ER:]])
        b_count = jnp.concatenate(
            [state.b_count[:ER] - pop_buf, state.b_count[ER:]])
        s_head = (state.s_head + pop_src) % Q
        s_count = state.s_count - pop_src

        # pushes
        is_ej = req.otype == EJECT
        w_push = win & ~is_ej
        # one winner per out channel => no index collisions among winners;
        # non-winners are routed to the out-of-bounds row E and dropped by
        # JAX scatter semantics.
        po = req.out
        pv = req.vc
        pslot = (state.b_head[po, pv] + req.ovc_count) % S
        # NOTE: use pre-pop head/count of the DESTINATION buffer; a pop on the
        # same buffer this cycle removes its head, not the tail we append to,
        # and the count delta composes (-1 pop, +1 push).
        # clear misroute on entering the intermediate W-group
        entered = (req.mis >= 0) & (req.odst_wg == req.mis)
        new_mis = jnp.where(entered, -1, req.mis)
        # virtual cut-through: the head is forwardable after the pipeline
        # latency; serialization is modeled by the channel busy time below.
        ready = t + req.olat
        po_push = jnp.where(w_push, po, E)
        # ONE scatter writes the whole packed record (field order F_DEST,
        # F_ITIME, F_MIS, F_META, F_READY — see state.py); scatters lower to
        # per-row loops on CPU, so 1 row of 5 values beats 5 rows of 1.
        new_pkt = jnp.stack([req.dest, req.itime, new_mis, req.meta, ready],
                            axis=-1)
        b_pkt = state.b_pkt.at[(po_push, pv, pslot)].set(new_pkt, mode="drop")
        b_count = b_count.at[(po_push, pv)].add(1, mode="drop")

        # channel busy (serialization) for every winner (incl. ejects);
        # ser - 1 because the winning cycle itself is the first busy slot.
        # `won_ch` is the dense per-channel grant mask, so this is a pure
        # elementwise update (a busy channel can't grant: ok requires
        # busy == 0, hence no overwrite conflict).
        ch_busy = jnp.where(won_ch, consts["ch_ser"] - 1,
                            jnp.maximum(state.ch_busy - 1, 0))

        return state.replace(
            b_pkt=b_pkt, b_head=b_head, b_count=b_count,
            s_head=s_head, s_count=s_count, ch_busy=ch_busy)

    return apply_moves
