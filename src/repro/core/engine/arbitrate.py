"""Arbitration phase: gather per-(channel, VC) and per-source-queue
requesters, route them, expand deadlock class to physical VC, apply
credit/busy constraints, and grant one winner per output channel by
age-based (oldest-first) segment-min arbitration.

The request vector is ordered [E_req*NV buffer heads, then T source queues]
(E_req = first eject channel id); `win[:E_req*NV]` / `win[E_req*NV:]` is the
contract the apply phase relies on.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp

from ..topology import EJECT, Network
from .state import (F_DEST, F_ITIME, F_META, F_MIS, F_READY, INF32,
                    SimState)

# the valid `cfg.grant_impl` values — the single source of truth
# (SimConfig and exp.RoutingSpec validate against this)
GRANT_IMPLS = ("jnp", "pallas")


@jax.tree_util.register_dataclass
@dataclass
class Requests:
    """One row per potential packet movement this cycle ([E_req*NV + T])."""

    dest: jax.Array       # destination terminal
    itime: jax.Array      # generation cycle (arbitration age key)
    mis: jax.Array        # misroute W-group (-1 = minimal)
    meta: jax.Array       # routing meta AFTER the requested hop
    out: jax.Array        # requested output channel
    vc: jax.Array         # requested downstream physical VC
    valid: jax.Array      # bool: the row holds a forwardable packet
    # gathered per-row properties of the requested output channel
    # (one packed ch_tbl gather; reused by grant, stats, and apply)
    otype: jax.Array      # channel type of `out`
    odst_wg: jax.Array    # W-group of the downstream node of `out`
    olat: jax.Array       # pipeline latency of `out`
    ovc_count: jax.Array  # occupancy of the requested (out, vc) buffer
                          # (set by expand_vcs; feeds credit check + push slot)

    def replace(self, **kw) -> "Requests":
        return replace(self, **kw)


def gather_requests(state: SimState, consts, route_kernel, fl,
                    t) -> Requests:
    """Head-of-line packets of every non-eject (channel, VC) buffer + source
    queue.  Eject channels are the trailing id block and never hold packets,
    so restricting the grid to [:E_req] is a free slice that shrinks every
    downstream row-wise op.  `fl` carries the lane's fault-dependent
    routing tables into the route kernel."""
    NV, T, ER = consts["NV"], consts["T"], consts["E_req"]
    bh = state.b_head[:ER]                         # [E_req, NV]
    e_idx = jnp.arange(ER)[:, None].repeat(NV, 1)
    v_idx = jnp.arange(NV)[None, :].repeat(ER, 0)
    # ONE gather pulls the whole packed head record per (channel, VC)
    head_pkt = state.b_pkt[(e_idx, v_idx, bh)].reshape(ER * NV, -1)
    r_dest = head_pkt[:, F_DEST]
    r_itime = head_pkt[:, F_ITIME]
    r_mis = head_pkt[:, F_MIS]
    r_meta = head_pkt[:, F_META]
    r_ready = head_pkt[:, F_READY]
    r_valid = ((state.b_count[:ER] > 0).reshape(-1) & (r_ready <= t))
    cur_node = consts["ch_dst"][e_idx.reshape(-1)]
    out_ch, req_vc, new_meta = route_kernel(fl, cur_node, r_dest, r_mis,
                                            r_meta)

    # source-queue requesters: fixed out channel (the injection link)
    sq_pkt = state.s_pkt[(jnp.arange(T), state.s_head)]   # [T, 3]
    zeros_t = jnp.zeros(T, jnp.int32)
    out = jnp.concatenate([out_ch, consts["inject_ch"]]).astype(jnp.int32)
    otbl = consts["ch_tbl"][out]                          # [N, 3]
    return Requests(
        dest=jnp.concatenate([r_dest, sq_pkt[:, F_DEST]]),
        itime=jnp.concatenate([r_itime, sq_pkt[:, F_ITIME]]),
        mis=jnp.concatenate([r_mis, sq_pkt[:, F_MIS]]),
        meta=jnp.concatenate([new_meta, zeros_t]),
        out=out,
        vc=jnp.concatenate([req_vc, zeros_t]).astype(jnp.int32),
        valid=jnp.concatenate([r_valid, state.s_count > 0]),
        otype=otbl[:, 0], odst_wg=otbl[:, 1], olat=otbl[:, 2],
        ovc_count=jnp.zeros_like(out))


def expand_vcs(req: Requests, state: SimState, cfg) -> Requests:
    """Deadlock class -> physical VC: least-occupied VC of the class.

    Also records the chosen buffer's occupancy (`ovc_count`) so the credit
    check and the push-slot computation read it densely instead of
    re-gathering b_count.  The class's `vpc` occupancies come back in ONE
    `[N, vpc]` gather (gathers lower to per-row loops on CPU, so one row
    of `vpc` values beats `vpc` rows of one — same reasoning as the
    packed `b_pkt` record)."""
    vpc = cfg.vcs_per_class
    if vpc <= 1:
        return req.replace(ovc_count=state.b_count[req.out, req.vc])
    base = req.vc * vpc
    vc_idx = base[:, None] + jnp.arange(vpc, dtype=jnp.int32)[None, :]
    occs = state.b_count[req.out[:, None], vc_idx]          # [N, vpc]
    return req.replace(
        vc=base + jnp.argmin(occs, axis=-1).astype(jnp.int32),
        ovc_count=jnp.min(occs, axis=-1))


def age_based_grant(req: Requests, state: SimState, consts, buf_pkts: int,
                    ch_alive=None):
    """One winner per output channel, oldest `itime` first (ids break ties).

    Returns (win, won_ch): the boolean winner mask aligned with the request
    vector, and the dense per-channel mask of output channels that granted a
    winner this cycle (a channel with any eligible requester always grants
    exactly one — `m1 != INF` — which gives apply the serialization update
    without another scatter).

    `ch_alive` (the lane's fault mask) makes dead channels ungrantable —
    fault-aware routing never requests one, so this is defence in depth
    that also covers hand-built states in tests.  A request for the -1
    non-channel (a packet STRANDED by a warm fault: its router or target
    died mid-run, see the updown kernel) is likewise never granted — the
    packet stays buffered and accounted in-flight.
    """
    E = consts["E"]
    is_ej = req.otype == EJECT
    credit = req.ovc_count < buf_pkts
    ok = req.valid & (req.out >= 0) \
        & (state.ch_busy[req.out] == 0) & (credit | is_ej)
    if ch_alive is not None:
        ok = ok & ch_alive[req.out]

    seg = jnp.where(ok, req.out, E)
    key1 = jnp.where(ok, req.itime, INF32)
    m1 = jax.ops.segment_min(key1, seg, num_segments=E + 1)
    tie = ok & (req.itime == m1[req.out])
    ridx = jnp.arange(req.out.shape[0], dtype=jnp.int32)
    key2 = jnp.where(tie, ridx, INF32)
    m2 = jax.ops.segment_min(key2, seg, num_segments=E + 1)
    win = tie & (ridx == m2[req.out])
    won_ch = m1[:E] != INF32
    return win, won_ch


def make_arbitrate_fn(net: Network, cfg, consts, route_kernel):
    """Returns arbitrate(state, t, fl) -> (Requests, win_mask, won_ch_mask).

    `cfg.grant_impl` selects the grant implementation: "jnp" (default) is
    `age_based_grant` above — the `jax.ops.segment_min` path that doubles
    as the oracle; "pallas" is the fused netsim kernel
    (`repro.kernels.netsim`), bit-identical by the parity tests and the
    TPU-ready fast path (interpret mode on CPU)."""
    impl = getattr(cfg, "grant_impl", "jnp")
    if impl == "pallas":
        from ...kernels.netsim.ops import grant as netsim_grant

        def grant_fn(req, state, ch_alive):
            return netsim_grant(
                req.out, req.itime, req.valid, req.ovc_count,
                req.otype == EJECT, state.ch_busy, ch_alive,
                buf_pkts=cfg.buf_pkts)
    elif impl == "jnp":
        def grant_fn(req, state, ch_alive):
            return age_based_grant(req, state, consts, cfg.buf_pkts,
                                   ch_alive)
    else:
        raise ValueError(f"unknown grant_impl {impl!r}; "
                         f"valid: {GRANT_IMPLS}")

    def arbitrate(state, t, fl):
        req = gather_requests(state, consts, route_kernel, fl, t)
        req = expand_vcs(req, state, cfg)
        win, won_ch = grant_fn(req, state, fl["ch_alive"])
        return req, win, won_ch

    return arbitrate
