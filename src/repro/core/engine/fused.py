"""The fused cycle step (`SimConfig.step_impl="fused"`).

`step_impl="jnp"` (`step.make_step`) is the classic phase pipeline and
stays the oracle.  This module is the restructured hot path, bit-identical
to the oracle by construction (integer ops only, same eligibility algebra,
same tie-break order), built around two observations:

ROUTE ONCE PER HOP, NOT ONCE PER CYCLE.  A packet's route out of a
channel — output channel, requested VC class, next routing meta — is a
pure function of (its record fields, the channel it sits in, the lane's
fault data), NOT of the cycle count.  The oracle re-evaluates it for
every one of the ``N = E_req*NV + T`` head rows every cycle; the fused
step evaluates it exactly once per hop, densely over the E winner rows
when a packet is PUSHED, and caches the three outputs in the packet
record (`state.F_OUT`/`F_CLS`/`F_META2`, the fused-only record tail) —
the request phase then reads routes out of the same gather that reads
the payload.  Epoch-scheduled (warm-fault) lanes fall back to per-cycle
routing: a cached decision could straddle an epoch boundary.  The
fallback is a trace-time branch on the lane pytree structure
(`state.is_scheduled`); cold-fault and pristine lanes — every paper
figure sweep — take the cached path.

ONE WINNER PER CHANNEL DRIVES EVERYTHING.  Age-based grant yields at
most one winner per output channel, so grant and apply are driven from
a dense per-channel winner table instead of per-request-row scatters:

  * grant is ONE `segment_min` into E (+1 junk) segments of the packed
    ``itime * R2 + row`` key (lexicographic min IS oldest-age,
    smallest-row-id; the step falls back to the oracle's two-pass
    age-then-priority form when the packed key would overflow int32).
    Credit/eject eligibility is ONE vectorized per-row gather of the
    dense per-(channel, class) credit table; busy/alive are dense
    per-channel masks applied after the reduction.
  * winners' records come from two E-row gathers (buffer heads / source
    queues) selected by the winner row id; pops are recovered per row
    by comparing each row's output channel's winner id against its own
    row id (a vectorized gather + compare — scatter-free); the push is
    the single E-row scatter left in the cycle.

The winner's physical VC and target occupancy (the oracle's `expand_vcs`
outputs) are reconstructed channel-dense from the per-class occupancy
min/argmin tables — the winning row requested exactly the
least-occupied VC of its class, so the dense lookup is the same value.
Stats are accumulated channel-dense from the winner table; the sums are
exact int32, so they equal the oracle's row sums bit for bit.

Channel sharding (the 2-D ``(lanes, shards)`` mesh, `engine.sweep`): with
``shards=K`` and a shard axis name, each device owns one contiguous block
of the channel-id space — the eject-channel block trails the id space, so
the partition is a plain slice.  The BIG state arrays (`b_pkt`, `s_pkt`)
are block-partitioned on their channel/terminal axis; the small
credit/serialization state (`b_count`, `b_head`, `ch_busy`, `s_head`,
`s_count`) stays replicated and is advanced identically on every shard
from the exchanged winner table.  The halo exchange at the phase boundary
is exactly two collectives + one scalar:

  * `lax.pmin` of the dense ``[E']`` per-channel grant minima (each
    shard reduces its own request rows; a channel's eligible rows may
    live on any shard — its buffer rows on the channel-owner shard, its
    injection row on the terminal-owner shard),
  * `lax.psum` of the dense ``[E', 5]`` winner-record table (exactly one
    shard owns each winning row; everyone else contributes zeros), and
  * `lax.psum` of the scalar stranded-request gauge.

Row priorities use GLOBAL channel/terminal ids (buffer row (c, v) has
priority ``c*NV + v``, source row t has ``E'*NV + t``), so the sharded
run's winners — and therefore every counter — are bit-identical to the
single-device run, lane for lane and cycle for cycle (pinned by
tests/test_channel_sharding.py; the priority VALUES differ from the
unsharded row ids, but the relative order of eligible rows is the same:
buffer rows sort by (channel, vc) and precede source rows in both
schemes, so every age tie resolves to the same packet).  Non-dividing
channel/terminal counts are padded with ghost entries (dead, never
eligible, zero stats).

`cfg.grant_impl="pallas"` routes the grant reduction of the UNSHARDED
fused step through the `repro.kernels.netsim` `cycle_core` Pallas kernel
(interpret mode on CPU, compiled on TPU); the sharded variant always
uses the jnp segment-min partials, because the global minimum only
exists after the `pmin` exchange.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ... import env_int
from ..topology import EJECT, NUM_CH_TYPES, Network
from ..traffic import as_pattern
from .inject import make_inject_fn, make_misroute_fn
from .state import (F_CLS, F_DEST, F_ITIME, F_META, F_META2, F_MIS,
                    F_OUT, F_READY, INF32, build_consts, is_scheduled,
                    resolve_epoch, resolve_reap_age)
from .stats import live_rows

# winner-record columns (the dense [E, 5] table exchanged across shards):
# destination, generation cycle, misroute wg, meta-to-store, class
W_DEST, W_ITIME, W_MIS, W_META, W_CLS = range(5)
NUM_W_FIELDS = 5


def _pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _pad1(x, pad, fill=0):
    x = np.asarray(x)
    if pad == 0:
        return jnp.asarray(x)
    return jnp.asarray(np.concatenate(
        [x, np.full((pad,) + x.shape[1:], fill, x.dtype)]))


def grant_form(net: Network, cfg, shards: int = 1) -> str:
    """Which grant form the fused step compiles for this (net, cfg):
    ``"combined"`` — one packed ``itime * R2 + prio`` segment-min — or
    ``"two_pass"`` — the oracle's age-then-priority fallback, taken when
    the packed key could exceed int32 (``cycles * R2 + R2 - 1``).

    Single source of truth for the overflow predicate: the step builders
    below, `SweepResult.grant_form` reporting, and the static spec pass
    (`repro.analysis`) all call this instead of re-deriving the interval
    bound.  ``shards`` matters because the K-way channel shard packs
    GLOBAL row priorities over the ghost-padded ``Ep * NV + Tp`` id
    space, a strictly larger modulus than the unsharded request grid's
    ``E_req * NV + T``.
    """
    from ..routing import num_vcs
    NV = (num_vcs(net.meta["kind"], cfg.vc_mode, cfg.nonminimal)
          * cfg.vcs_per_class)
    if shards <= 1:
        N = net.first_eject * NV + net.num_terminals
    else:
        ch_pad, term_pad = fused_pad(net, shards)
        N = ((net.num_channels + ch_pad) * NV
             + net.num_terminals + term_pad)
    R2 = _pow2(N)
    cycles = cfg.warmup + cfg.measure
    return ("combined" if cycles * R2 + (R2 - 1) < 2**31 - 1
            else "two_pass")


def fused_pad(net: Network, shards: int) -> tuple[int, int]:
    """(ch_pad, term_pad) ghost padding a K-way channel shard needs so
    each shard's block is dense (`make_state(..., ch_pad, term_pad)` pads
    the state arrays; the step pads its own static tables)."""
    E, T = net.num_channels, net.num_terminals
    return _round_up(E, shards) - E, _round_up(T, shards) - T


def make_fused_step(net: Network, cfg, pattern, inject_mask=None, *,
                    shards: int = 1, shard_axis: str = "shards"):
    """Returns (step, consts); signature-compatible with `step.make_step`.

    ``shards=1`` is the single-device fused step (a drop-in for the
    oracle step).  ``shards=K > 1`` builds the channel-sharded variant
    meant to run INSIDE a `shard_map` over a mesh axis named
    `shard_axis`; its state must be padded to the sharded sizes
    (`make_state(..., ch_pad=..., term_pad=...)` with `fused_pad`)."""
    pattern, inject_mask = as_pattern(pattern, inject_mask)
    consts, route_kernel = build_consts(net, cfg)
    if shards <= 1:
        step = _make_unsharded(net, cfg, pattern, inject_mask, consts,
                               route_kernel)
    else:
        step = _make_sharded(net, cfg, pattern, inject_mask, consts,
                             route_kernel, shards, shard_axis)
    return step, consts


def _occ_tables(b_count, NC, vpc):
    """Per-(channel, class) least-occupied-VC tables: (occ_min [E, NC],
    occ_arg [E, NC]).  Dense elementwise; `jnp.argmin` picks the first
    minimum exactly like the oracle's `expand_vcs` row gather."""
    E = b_count.shape[0]
    occ = b_count.reshape(E, NC, vpc)
    return occ.min(-1), jnp.argmin(occ, -1).astype(jnp.int32)


def _winner_vc(wcls, occ_min, occ_arg, NC, vpc):
    """(wvc [E], wovc [E]) for the winner table: the winning row asked
    for the least-occupied VC of its class, so a dense one-hot select
    over the NC class columns reproduces `expand_vcs`' per-row values."""
    csel = wcls[:, None] == jnp.arange(NC, dtype=jnp.int32)[None, :]
    wovc = jnp.where(csel, occ_min, 0).sum(1)
    wvc = wcls * vpc + jnp.where(csel, occ_arg, 0).sum(1)
    return wvc, wovc


def _row_elig(elig_ck, out, cls, E):
    """Vectorized per-row credit/eject eligibility: one gather of the
    dense [E, NC] table at each row's (output channel, class)."""
    return elig_ck[(jnp.clip(out, 0, E - 1), cls)]


def _grant(ok, out, itime, prio, ch_ok, E, R2, use_combined):
    """Per-channel age-based grant over the request rows: one (or, in
    the two-pass int32-overflow fallback, two) segment_min into E (+1
    junk) segments, then the dense busy/alive channel mask.  Returns
    (won_ch [E], wprio [E]): the winner's row priority per granting
    channel."""
    seg = jnp.where(ok, out, E)
    if use_combined:
        key = jnp.where(ok, itime * R2 + prio, INF32)
        m = jax.ops.segment_min(key, seg, num_segments=E + 1)[:E]
        m = jnp.where(ch_ok, m, INF32)
        won_ch = m != INF32
        return won_ch, jnp.where(won_ch, m & (R2 - 1), 0)
    m1 = jax.ops.segment_min(jnp.where(ok, itime, INF32), seg,
                             num_segments=E + 1)
    tie = ok & (itime == m1[jnp.where(ok, out, 0)])
    m2 = jax.ops.segment_min(jnp.where(tie, prio, INF32), seg,
                             num_segments=E + 1)[:E]
    won_ch = ch_ok & (m1[:E] != INF32)
    return won_ch, jnp.where(won_ch, m2, 0)


def compact_rows(net: Network, cfg) -> int:
    """N, the unsharded request-row count (`E_req * NV + T`) — the
    compact step's capacity ladder is sized against this."""
    from ..routing import num_vcs
    NV = (num_vcs(net.meta["kind"], cfg.vc_mode, cfg.nonminimal)
          * cfg.vcs_per_class)
    return net.first_eject * NV + net.num_terminals


def capacity_ladder(N: int) -> tuple[int, ...]:
    """The compact step's capacity rungs for an N-row request grid:
    ``ceil(N/8) < ceil(N/4) < ceil(N/2) < N`` (deduplicated for tiny N).
    Each rung is a distinct compiled executable; the top rung C = N can
    never overflow, so the escalation walk always terminates."""
    return tuple(sorted({-(-N // 8), -(-N // 4), -(-N // 2), N}))


def next_rung(N: int, floor: int) -> int:
    """The smallest ladder rung >= `floor` (the escalation target when a
    run's `occ_peak` reached `floor`); N when `floor` exceeds the top."""
    for r in capacity_ladder(N):
        if r >= floor:
            return r
    return N


def initial_capacity(N: int) -> int:
    """The rung a compact step starts at: the smallest ladder rung that
    covers REPRO_COMPACT_CAP when set (so ``REPRO_COMPACT_CAP=1`` pins
    the bottom rung and a large value pins C = N), else ``ceil(N/4)`` —
    paper-figure sweeps peak well under N/4 live rows even at
    saturation, with headroom to spare (see docs/performance.md)."""
    cap = env_int("REPRO_COMPACT_CAP", 0)
    if cap > 0:
        return next_rung(N, min(cap, N))
    ladder = capacity_ladder(N)
    return ladder[1] if len(ladder) > 1 else ladder[0]


def make_compact_step(net: Network, cfg, pattern, inject_mask=None, *,
                      capacity: int | None = None):
    """The occupancy-compacted fused step (`cfg.step_impl="compact"`):
    returns (step, consts), signature-compatible with `step.make_step`.

    Identical cycle semantics to the unsharded fused step, but the
    request phase first COMPACTS the live rows (non-empty (channel, vc)
    buffers + non-empty source queues) into a statically-bounded active
    set of `capacity` C rows, so the head gather, the route fallback,
    the packed segment-min grant key, and the pop decode all run over C
    rows instead of all ``N = E_req*NV + T`` — per-cycle cost tracks
    OCCUPANCY, not network capacity.  The compaction is a stable
    partition (cumsum of the live mask + one binary-search gather), so
    active slot k holds the k-th live row in the oracle's row order and
    each slot's grant priority is its GLOBAL row id — the packed
    ``itime * R2 + prio`` keys, and therefore every winner and every
    counter, are bit-identical to the oracle's whenever C bounds the
    live set.

    C not bounding the live set is DETECTED, never silent: the step
    folds the exact live-row census (computed densely, independent of
    C) into `SimStats.occ_peak` every cycle, and the sweep layer
    re-dispatches the whole grid at the next ladder rung when a run's
    peak crossed its rung (`sweep._PendingLanes.finish`) — the rerun is
    deterministic, so escalated results are still bit-identical to the
    oracle.  `capacity=None` starts at `initial_capacity(N)`
    (REPRO_COMPACT_CAP pins the starting rung).

    Not channel-shardable (the active set is a global permutation);
    warm-fault (epoch-scheduled) lanes fall back to per-cycle routing
    over the C active rows, exactly like the fused step does over N.
    """
    pattern, inject_mask = as_pattern(pattern, inject_mask)
    consts, route_kernel = build_consts(net, cfg)
    N = consts["E_req"] * consts["NV"] + consts["T"]
    C = initial_capacity(N) if capacity is None else int(capacity)
    if not 1 <= C <= N:
        raise ValueError(f"compact capacity {C} outside [1, {N}]")
    step = _make_compact(net, cfg, pattern, inject_mask, consts,
                         route_kernel, C)
    # reporting hooks for the sweep layer (rung bookkeeping without
    # re-deriving the row count)
    step.compact_capacity = C
    step.compact_rows = N
    return step, consts


def _make_compact(net, cfg, pattern, inject_mask, consts, route_kernel,
                  C):
    inject = make_inject_fn(net, cfg, consts, pattern, inject_mask)
    NV, E, T, ER = consts["NV"], consts["E"], consts["T"], consts["E_req"]
    S, Q = cfg.buf_pkts, cfg.srcq_pkts
    vpc = cfg.vcs_per_class
    NC = NV // vpc
    N = ER * NV + T
    R2 = _pow2(N)
    use_combined = grant_form(net, cfg) == "combined"
    use_pallas = getattr(cfg, "grant_impl", "jnp") == "pallas" \
        and use_combined
    if use_pallas:
        from ...kernels.netsim.ops import cycle_core
    reap_age = resolve_reap_age(cfg)   # 0 = reaper off (trace-time)

    ch_dst = consts["ch_dst"]
    ch_tbl = consts["ch_tbl"]
    ch_type, ch_dst_wg, ch_lat = (ch_tbl[:, 0], ch_tbl[:, 1],
                                  ch_tbl[:, 2])
    ch_ser = consts["ch_ser"]
    is_ej_ch = ch_type == EJECT
    inject_ch = consts["inject_ch"]
    slot_iota = jnp.arange(C, dtype=jnp.int32)
    ch_iota = jnp.arange(E, dtype=jnp.int32)
    row_iota = jnp.arange(N, dtype=jnp.int32)
    vc_iota = jnp.arange(NV, dtype=jnp.int32)
    type_iota = jnp.arange(NUM_CH_TYPES, dtype=jnp.int32)

    def step(state, t_key_rate_fl):
        t, key, rate_pkt, fl = t_key_rate_fl
        cached = not is_scheduled(fl)   # trace-time, as in the fused step
        fl = resolve_epoch(fl, t)
        state = inject(state, t, key, rate_pkt, fl)

        # live-row census + stable compaction.  `occ` is EXACT (dense,
        # independent of C) — it feeds the occ_peak certificate the
        # escalation check relies on.  The live-mask prefix sum is built
        # two-level so the serial scans stay short (an NV-wide axis
        # cumsum vectorized over the ER channels, then channel- and
        # terminal-level cumsums); live row r lands in active slot
        # cs[r]-1 by a stable scatter (the dispatch planner runs compact
        # lanes sequentially, where the unbatched scatter beats the
        # binary-search gather form — vmapped lanes would invert that,
        # but they take the mesh path).  Slots past the live count keep
        # the N sentinel, so `aid` stays sorted (stable compaction
        # preserves row order) for the winner-slot search below.
        lb = (state.b_count[:ER] > 0).astype(jnp.int32)     # [ER, NV]
        within = jnp.cumsum(lb, axis=-1)
        ch_tot = within[:, -1]
        base = jnp.cumsum(ch_tot)                           # [ER]
        scs = jnp.cumsum((state.s_count > 0).astype(jnp.int32))
        occ = base[-1] + scs[-1]
        cs = jnp.concatenate(
            [((base - ch_tot)[:, None] + within).reshape(-1),
             base[-1] + scs])                               # [N]
        live = jnp.concatenate(
            [lb.reshape(-1) > 0, state.s_count > 0])        # [N]
        aid = jnp.full((C,), N, jnp.int32).at[
            jnp.where(live, cs - 1, C)].set(row_iota, mode="drop")  # [C]
        slot_ok = slot_iota < jnp.minimum(occ, C)

        # per-slot request assembly: ONE C-row head gather (the fused
        # step's ER*NV-row gather, shrunk to the live set) + one C-row
        # source-queue gather, merged by slot kind
        is_buf = aid < ER * NV
        e = jnp.clip(aid // NV, 0, ER - 1)
        v = jnp.clip(aid, 0, ER * NV - 1) % NV
        tt = jnp.clip(aid - ER * NV, 0, T - 1)
        bh = state.b_head[(e, v)]                            # [C]
        brec = state.b_pkt[(e, v, bh)]                       # [C, 8]
        srec = state.s_pkt[(tt, state.s_head[tt])]           # [C, 3]
        ready = ~is_buf | (brec[:, F_READY] <= t)
        valid = slot_ok & ready
        if cached:
            out_b, cls_b, meta2_b = (brec[:, F_OUT], brec[:, F_CLS],
                                     brec[:, F_META2])
        else:
            out_b, cls_b, meta2_b = route_kernel(
                fl, ch_dst[e], brec[:, F_DEST], brec[:, F_MIS],
                brec[:, F_META])
        out = jnp.where(is_buf, out_b, inject_ch[tt]).astype(jnp.int32)
        cls = jnp.where(is_buf, cls_b, 0).astype(jnp.int32)
        itime = jnp.where(is_buf, brec[:, F_ITIME], srec[:, F_ITIME])
        dest = jnp.where(is_buf, brec[:, F_DEST], srec[:, F_DEST])
        mis = jnp.where(is_buf, brec[:, F_MIS], srec[:, F_MIS])
        meta2 = jnp.where(is_buf, meta2_b, 0).astype(jnp.int32)
        rowok = valid & (out >= 0)
        # router-death reaper over the active rows: undeliverable rows
        # (parked on -1 OR requesting a dead channel — see
        # stats.undeliverable_mask) are live, so whenever occ <= C they
        # are ALL in the active set — the reap mask is exact under the
        # same occ_peak certificate that covers the grant
        if reap_age:
            undel = valid & ((out < 0)
                             | ~fl["ch_alive"][jnp.clip(out, 0, E - 1)])
            reap = undel & (t - itime >= reap_age)
        else:
            undel = reap = None
        prio = aid      # the global row id IS the oracle's tie-break

        # grant over the C active rows — same segments, same packed
        # keys, same winners as the fused step's N-row reduction
        occ_min, occ_arg = _occ_tables(state.b_count, NC, vpc)
        elig_ck = (occ_min < S) | is_ej_ch[:, None]
        ok = rowok & _row_elig(elig_ck, out, cls, E)
        ch_ok = (state.ch_busy == 0) & fl["ch_alive"]
        if use_pallas:
            won_ch, wprio, win_slot = cycle_core(out, itime, ok, ch_ok,
                                                 r2=R2, prio=prio)
        else:
            won_ch, wprio = _grant(ok, out, itime, prio, ch_ok, E, R2,
                                   use_combined)
            win_slot = None

        # dense winner table: map each granting channel's winning row
        # id back to its active slot (aid is sorted, so one binary
        # search), then ONE [E, 5]-gather of the compacted records
        wslot_i = jnp.clip(
            jnp.searchsorted(aid, wprio, side="left"), 0, C - 1)
        crec = jnp.stack([dest, itime, mis, meta2, cls], axis=-1)
        w = crec[wslot_i]                                     # [E, 5]
        wdest, witime = w[:, W_DEST], w[:, W_ITIME]
        wmis, wmeta, wcls = w[:, W_MIS], w[:, W_META], w[:, W_CLS]
        wvc, wovc = _winner_vc(wcls, occ_min, occ_arg, NC, vpc)
        entered = (wmis >= 0) & (ch_dst_wg == wmis)
        wmis = jnp.where(entered, -1, wmis)
        push = won_ch & ~is_ej_ch
        whead = state.b_head[(ch_iota, jnp.clip(wvc, 0, NV - 1))]
        wslot = (whead + wovc) % S
        if cached:
            out2, cls2, meta2_n = route_kernel(fl, ch_dst, wdest, wmis,
                                               wmeta)
            tail = [out2.astype(jnp.int32), cls2.astype(jnp.int32),
                    meta2_n.astype(jnp.int32)]
        else:
            z = jnp.zeros_like(wdest)
            tail = [z, z, z]
        new_rec = jnp.stack(
            [wdest, witime, wmis, wmeta, t + ch_lat] + tail, axis=-1)
        pe = jnp.where(push, ch_iota, E)
        b_pkt = state.b_pkt.at[(pe, wvc, wslot)].set(new_rec,
                                                     mode="drop")

        # pops: the fused step's N-row gather+compare shrinks to C; the
        # per-(channel, vc) / per-terminal pop bookkeeping stays in the
        # dense one-hot form — XLA:CPU vectorizes the [E, NV] rebuilds
        # well, while the equivalent scatter chains lower to slow
        # row-at-a-time loops (measured ~2x worse)
        if win_slot is None:
            wprio_eff = jnp.where(won_ch, wprio, -1)
            won_slot = rowok & (wprio_eff[jnp.clip(out, 0, E - 1)]
                                == aid)
        else:
            won_slot = win_slot
        # reaped rows pop like winners but push nowhere (masks disjoint:
        # a winner's out channel is live, a reap victim's is -1 or
        # dead); source rows are reapable too — a source head whose
        # injection channel died can never be granted
        pop_slot = won_slot if reap is None else won_slot | reap
        pe_b = jnp.where(pop_slot & is_buf, e, E)
        pop1 = jnp.zeros((E, NV), jnp.int32).at[(pe_b, v)].add(
            1, mode="drop")
        b_head = (state.b_head + pop1) % S
        vc_oh = wvc[:, None] == vc_iota[None, :]
        b_count = (state.b_count - pop1
                   + (push[:, None] & vc_oh).astype(jnp.int32))
        ts_m = jnp.where(pop_slot & ~is_buf, tt, T)
        pop_s = jnp.zeros((T,), jnp.int32).at[ts_m].add(1, mode="drop")
        s_head = (state.s_head + pop_s) % Q
        s_count = state.s_count - pop_s
        ch_busy = jnp.where(won_ch, ch_ser - 1,
                            jnp.maximum(state.ch_busy - 1, 0))

        # stats, channel-dense like the fused step; `stranded` counts
        # over the active rows (stranded rows are live, so they are all
        # in the active set whenever occ <= C)
        st = state.stats
        w_ej = won_ch & is_ej_ch
        hops = (won_ch[:, None]
                & (ch_type[:, None] == type_iota[None, :]))
        if reap is None:
            stranded = (valid & (out < 0)).sum().astype(jnp.int32)
            reaped = st.reaped
        else:
            stranded = (undel & ~reap).sum().astype(jnp.int32)
            reaped = st.reaped + reap.sum().astype(jnp.int32)
        st = st.replace(
            delivered=st.delivered + w_ej.sum(),
            lat_sum=st.lat_sum + jnp.where(w_ej, t - witime, 0).sum(),
            hops=st.hops + hops.astype(jnp.int32).sum(0),
            stranded=stranded, reaped=reaped,
            occ_peak=jnp.maximum(st.occ_peak, occ))
        return state.replace(
            b_pkt=b_pkt, b_head=b_head, b_count=b_count,
            s_head=s_head, s_count=s_count, ch_busy=ch_busy,
            stats=st), None

    return step


def _make_unsharded(net, cfg, pattern, inject_mask, consts, route_kernel):
    inject = make_inject_fn(net, cfg, consts, pattern, inject_mask)
    NV, E, T, ER = consts["NV"], consts["E"], consts["T"], consts["E_req"]
    S, Q = cfg.buf_pkts, cfg.srcq_pkts
    vpc = cfg.vcs_per_class
    NC = NV // vpc
    N = ER * NV + T
    R2 = _pow2(N)
    # the combined int32 key needs headroom for the largest (itime, prio)
    # pair; fall back to the oracle's two-pass form when it would overflow
    # (`grant_form` is the shared predicate; the chosen form is surfaced
    # in `SweepResult.grant_form` and checked statically by the spec pass)
    use_combined = grant_form(net, cfg) == "combined"
    use_pallas = getattr(cfg, "grant_impl", "jnp") == "pallas" \
        and use_combined
    if use_pallas:
        from ...kernels.netsim.ops import cycle_core
    reap_age = resolve_reap_age(cfg)   # 0 = reaper off (trace-time)

    ch_dst = consts["ch_dst"]
    ch_tbl = consts["ch_tbl"]
    ch_type, ch_dst_wg, ch_lat = (ch_tbl[:, 0], ch_tbl[:, 1],
                                  ch_tbl[:, 2])
    ch_ser = consts["ch_ser"]
    is_ej_ch = ch_type == EJECT
    inject_ch = consts["inject_ch"]
    e_idx = jnp.arange(ER)[:, None].repeat(NV, 1)
    v_idx = jnp.arange(NV)[None, :].repeat(ER, 0)
    cur_rows = ch_dst[e_idx.reshape(-1)]
    zeros_t = jnp.zeros(T, jnp.int32)
    prio = jnp.arange(N, dtype=jnp.int32)
    row_id = prio
    ch_iota = jnp.arange(E, dtype=jnp.int32)
    vc_iota = jnp.arange(NV, dtype=jnp.int32)
    type_iota = jnp.arange(NUM_CH_TYPES, dtype=jnp.int32)

    def step(state, t_key_rate_fl):
        t, key, rate_pkt, fl = t_key_rate_fl
        cached = not is_scheduled(fl)   # trace-time: see module docstring
        fl = resolve_epoch(fl, t)
        state = inject(state, t, key, rate_pkt, fl)
        occ = live_rows(state)

        # request rows, in the oracle's order ([:ER]*NV buffer heads,
        # then T source queues) — `prio` IS the oracle's tie-break row id
        bh = state.b_head[:ER]
        head = state.b_pkt[(e_idx, v_idx, bh)].reshape(ER * NV, -1)
        r_valid = ((state.b_count[:ER] > 0).reshape(-1)
                   & (head[:, F_READY] <= t))
        if cached:
            out_b, cls_b, meta2_b = (head[:, F_OUT], head[:, F_CLS],
                                     head[:, F_META2])
        else:
            out_b, cls_b, meta2_b = route_kernel(
                fl, cur_rows, head[:, F_DEST], head[:, F_MIS],
                head[:, F_META])
        sq = state.s_pkt[(jnp.arange(T), state.s_head)]
        out = jnp.concatenate([out_b, inject_ch]).astype(jnp.int32)
        cls = jnp.concatenate([cls_b, zeros_t]).astype(jnp.int32)
        itime = jnp.concatenate([head[:, F_ITIME], sq[:, F_ITIME]])
        valid = jnp.concatenate([r_valid, state.s_count > 0])
        rowok = valid & (out >= 0)
        # router-death reaper: undeliverable rows (parked on -1 OR
        # requesting a dead channel) past the park age — disjoint from
        # winners, which need a live channel (see stats.reap_mask)
        if reap_age:
            undel = valid & ((out < 0)
                             | ~fl["ch_alive"][jnp.clip(out, 0, E - 1)])
            reap = undel & (t - itime >= reap_age)
        else:
            undel = reap = None

        # grant: per-row credit gather, one segment-min, dense channel
        # mask; at most one winner (row priority) per output channel
        occ_min, occ_arg = _occ_tables(state.b_count, NC, vpc)
        elig_ck = (occ_min < S) | is_ej_ch[:, None]
        ok = rowok & _row_elig(elig_ck, out, cls, E)
        ch_ok = (state.ch_busy == 0) & fl["ch_alive"]
        if use_pallas:
            won_ch, wprio, win_row = cycle_core(out, itime, ok, ch_ok,
                                                r2=R2)
        else:
            won_ch, wprio = _grant(ok, out, itime, prio, ch_ok, E, R2,
                                   use_combined)
            win_row = None

        # dense winner table: two E-row gathers (buffer / source rows)
        is_buf = wprio < ER * NV
        bclip = jnp.clip(wprio, 0, ER * NV - 1)
        wb = head[bclip]
        ws = sq[jnp.clip(wprio - ER * NV, 0, T - 1)]
        wdest = jnp.where(is_buf, wb[:, F_DEST], ws[:, F_DEST])
        witime = jnp.where(is_buf, wb[:, F_ITIME], ws[:, F_ITIME])
        wmis = jnp.where(is_buf, wb[:, F_MIS], ws[:, F_MIS])
        wmeta = jnp.where(
            is_buf,
            wb[:, F_META2] if cached else meta2_b[bclip],
            0).astype(jnp.int32)
        wcls = jnp.where(
            is_buf,
            wb[:, F_CLS] if cached else cls_b[bclip],
            0).astype(jnp.int32)
        wvc, wovc = _winner_vc(wcls, occ_min, occ_arg, NC, vpc)
        entered = (wmis >= 0) & (ch_dst_wg == wmis)
        wmis = jnp.where(entered, -1, wmis)
        push = won_ch & ~is_ej_ch
        vc_oh = wvc[:, None] == vc_iota[None, :]
        whead = jnp.where(vc_oh, state.b_head, 0).sum(1)
        wslot = (whead + wovc) % S
        if cached:
            # the route-once-per-hop evaluation: the pushed packet's
            # next-hop decision, dense over the E winner rows, with the
            # same (cleared-mis, meta-to-store) inputs the oracle feeds
            # its head-time call
            out2, cls2, meta2 = route_kernel(fl, ch_dst, wdest, wmis,
                                             wmeta)
            tail = [out2.astype(jnp.int32), cls2.astype(jnp.int32),
                    meta2.astype(jnp.int32)]
        else:
            z = jnp.zeros_like(wdest)
            tail = [z, z, z]
        new_rec = jnp.stack(
            [wdest, witime, wmis, wmeta, t + ch_lat] + tail, axis=-1)
        pe = jnp.where(push, ch_iota, E)
        b_pkt = state.b_pkt.at[(pe, wvc, wslot)].set(new_rec,
                                                     mode="drop")

        # pops, recovered per row by comparing each row's output
        # channel's winner id against its own row id — a vectorized
        # gather + compare, no scatter (the Pallas core already emits
        # this mask from the same comparison inside the kernel)
        if win_row is None:
            wprio_eff = jnp.where(won_ch, wprio, -1)
            won_row = rowok & (wprio_eff[jnp.clip(out, 0, E - 1)]
                               == row_id)
        else:
            won_row = win_row
        # reaped rows pop like winners but push nowhere (disjoint masks:
        # a winner's out channel is live, a reap victim's is -1 or
        # dead); the source tail is reapable too, so pop_s widens
        pop_row = won_row if reap is None else won_row | reap
        pop1 = jnp.pad(
            pop_row[: ER * NV].reshape(ER, NV).astype(jnp.int32),
            ((0, E - ER), (0, 0)))
        b_head = (state.b_head + pop1) % S
        b_count = (state.b_count - pop1
                   + (push[:, None] & vc_oh).astype(jnp.int32))
        pop_s = pop_row[ER * NV:].astype(jnp.int32)
        s_head = (state.s_head + pop_s) % Q
        s_count = state.s_count - pop_s
        ch_busy = jnp.where(won_ch, ch_ser - 1,
                            jnp.maximum(state.ch_busy - 1, 0))

        # stats, channel-dense (bit-equal to the oracle's row sums: the
        # winners biject the granting channels and the sums are int32)
        st = state.stats
        w_ej = won_ch & is_ej_ch
        hops = (won_ch[:, None]
                & (ch_type[:, None] == type_iota[None, :]))
        if reap is None:
            stranded = (valid & (out < 0)).sum().astype(jnp.int32)
            reaped = st.reaped
        else:
            stranded = (undel & ~reap).sum().astype(jnp.int32)
            reaped = st.reaped + reap.sum().astype(jnp.int32)
        st = st.replace(
            delivered=st.delivered + w_ej.sum(),
            lat_sum=st.lat_sum + jnp.where(w_ej, t - witime, 0).sum(),
            hops=st.hops + hops.astype(jnp.int32).sum(0),
            stranded=stranded, reaped=reaped,
            occ_peak=jnp.maximum(st.occ_peak, occ))
        return state.replace(
            b_pkt=b_pkt, b_head=b_head, b_count=b_count,
            s_head=s_head, s_count=s_count, ch_busy=ch_busy,
            stats=st), None

    return step


def _make_sharded(net, cfg, pattern, inject_mask, consts, route_kernel,
                  K, axis):
    """The channel-sharded step: runs inside `shard_map`, owns the
    ``[Ek, NV, S, 8]`` / ``[Tk, Q, 3]`` blocks of `b_pkt` / `s_pkt` for
    its shard index, keeps the rest of the state replicated, and
    exchanges the per-channel grant minima (`pmin`) + winner records
    (`psum`) at the phase boundary."""
    NV, E, T = consts["NV"], consts["E"], consts["T"]
    S, Q = cfg.buf_pkts, cfg.srcq_pkts
    vpc = cfg.vcs_per_class
    NC = NV // vpc
    ch_pad, term_pad = fused_pad(net, K)
    Ep, Tp = E + ch_pad, T + term_pad
    Ek, Tk = Ep // K, Tp // K
    R2 = _pow2(Ep * NV + Tp)                 # global-priority modulus
    use_combined = grant_form(net, cfg, K) == "combined"
    reap_age = resolve_reap_age(cfg)         # 0 = reaper off (trace-time)

    # padded static tables (ghost channels: dead, type -1; ghost
    # terminals: no injection channel, never generate)
    nn = net.num_nodes
    ch_dst = _pad1(np.clip(net.ch_dst, 0, nn - 1), ch_pad)
    tbl = np.asarray(consts["ch_tbl"])
    ch_type = _pad1(tbl[:, 0], ch_pad, -1)
    ch_dst_wg = _pad1(tbl[:, 1], ch_pad)
    ch_lat = _pad1(tbl[:, 2], ch_pad)
    ser = np.broadcast_to(np.asarray(consts["ch_ser"]), (E,))
    ch_ser = _pad1(ser, ch_pad, 1)
    inject_ch = _pad1(np.asarray(consts["inject_ch"]), term_pad, -1)
    is_ej_ch = ch_type == EJECT
    gen_mis = make_misroute_fn(net, cfg, consts)
    inj_mask = (jnp.ones(T, dtype=bool) if inject_mask is None
                else jnp.asarray(inject_mask).astype(bool))

    e_loc = jnp.arange(Ek)[:, None].repeat(NV, 1)
    v_idx = jnp.arange(NV)[None, :].repeat(Ek, 0)
    zeros_tk = jnp.zeros(Tk, jnp.int32)
    vc_iota = jnp.arange(NV, dtype=jnp.int32)
    type_iota = jnp.arange(NUM_CH_TYPES, dtype=jnp.int32)
    t_iota = jnp.arange(T, dtype=jnp.int32)

    def _sl(x, start, size):
        return jax.lax.dynamic_slice_in_dim(x, start, size, 0)

    def inject(state, t, key, rate_pkt, fl, t0):
        # full-T generation, replicated: every shard draws the identical
        # Bernoulli/destination/misroute streams (`inject.make_inject_fn`
        # verbatim), then only the local s_pkt block takes the push
        k_gen, k_dest, k_mis = jax.random.split(key, 3)
        alive = fl["term_alive"]
        gen = (jax.random.uniform(k_gen, (T,)) < rate_pkt) & inj_mask
        dest = pattern(k_dest, t).astype(jnp.int32)
        gen = gen & (dest != t_iota)
        gen = gen & alive & alive[dest]
        mis = gen_mis(k_mis, dest, state.b_count, fl)
        space = state.s_count[:T] < Q
        push = gen & space
        slot = (state.s_head[:T] + state.s_count[:T]) % Q
        new_rec = jnp.stack(
            [dest, jnp.full((T,), t, jnp.int32), mis], axis=-1)
        pushP = jnp.pad(push, (0, term_pad))
        slotP = jnp.pad(slot, (0, term_pad))
        recP = jnp.pad(new_rec, ((0, term_pad), (0, 0)))
        push_l = _sl(pushP, t0, Tk)
        idx = (jnp.arange(Tk), _sl(slotP, t0, Tk))
        rec_l = jnp.where(push_l[:, None], _sl(recP, t0, Tk),
                          state.s_pkt[idx])
        st = state.stats
        st = st.replace(generated=st.generated + gen.sum(),
                        dropped=st.dropped + (gen & ~space).sum())
        return state.replace(s_pkt=state.s_pkt.at[idx].set(rec_l),
                             s_count=state.s_count + pushP, stats=st)

    def step(state, t_key_rate_fl):
        t, key, rate_pkt, fl = t_key_rate_fl
        cached = not is_scheduled(fl)
        fl = resolve_epoch(fl, t)
        sid = jax.lax.axis_index(axis).astype(jnp.int32)
        c0, t0 = sid * Ek, sid * Tk
        state = inject(state, t, key, rate_pkt, fl, t0)
        # replicated counts (ghost rows stay zero), so every shard sees
        # the same global live-row census — no collective needed
        occ = live_rows(state)
        alive = jnp.pad(fl["ch_alive"], (0, ch_pad))

        # local request rows over the shard's channel/terminal blocks;
        # priorities are GLOBAL ids, so tie-breaks match everywhere
        cid = c0 + jnp.arange(Ek, dtype=jnp.int32)
        bh_l = _sl(state.b_head, c0, Ek)
        head = state.b_pkt[(e_loc, v_idx, bh_l)].reshape(Ek * NV, -1)
        r_valid = ((_sl(state.b_count, c0, Ek) > 0).reshape(-1)
                   & (head[:, F_READY] <= t))
        if cached:
            out_b, cls_b, meta2_b = (head[:, F_OUT], head[:, F_CLS],
                                     head[:, F_META2])
        else:
            cur = ch_dst[(cid[:, None].repeat(NV, 1)).reshape(-1)]
            out_b, cls_b, meta2_b = route_kernel(
                fl, cur, head[:, F_DEST], head[:, F_MIS],
                head[:, F_META])
        sq = state.s_pkt[(jnp.arange(Tk), _sl(state.s_head, t0, Tk))]
        out = jnp.concatenate(
            [out_b, _sl(inject_ch, t0, Tk)]).astype(jnp.int32)
        cls = jnp.concatenate([cls_b, zeros_tk]).astype(jnp.int32)
        itime = jnp.concatenate([head[:, F_ITIME], sq[:, F_ITIME]])
        valid = jnp.concatenate(
            [r_valid, _sl(state.s_count, t0, Tk) > 0])
        prio = jnp.concatenate(
            [(cid[:, None] * NV + vc_iota[None, :]).reshape(-1),
             Ep * NV + t0 + jnp.arange(Tk, dtype=jnp.int32)])
        rowok = valid & (out >= 0)
        # router-death reaper over the LOCAL rows (ghost rows are never
        # valid): undeliverable rows — parked on -1 OR requesting a
        # channel this epoch's fault set killed (dead eject at a dead
        # router; dead injection channel under a dead terminal's head)
        if reap_age:
            undel = valid & ((out < 0)
                             | ~alive[jnp.clip(out, 0, Ep - 1)])
            reap = undel & (t - itime >= reap_age)
        else:
            undel = reap = None

        # grant: per-row credit gather (replicated tables), local
        # segment-min partials, then the [E'] pmin halo exchange
        occ_min, occ_arg = _occ_tables(state.b_count, NC, vpc)
        elig_ck = (occ_min < S) | is_ej_ch[:, None]
        ok = rowok & _row_elig(elig_ck, out, cls, Ep)
        ch_ok = (state.ch_busy == 0) & alive
        seg = jnp.where(ok, out, Ep)
        if use_combined:
            key_g = jnp.where(ok, itime * R2 + prio, INF32)
            m = jax.ops.segment_min(key_g, seg, num_segments=Ep + 1)
            m = jax.lax.pmin(m[:Ep], axis)
            m = jnp.where(ch_ok, m, INF32)
            won_ch = m != INF32
            wprio = jnp.where(won_ch, m & (R2 - 1), 0)
        else:
            m1 = jax.lax.pmin(jax.ops.segment_min(
                jnp.where(ok, itime, INF32), seg,
                num_segments=Ep + 1)[:Ep], axis)
            # the age tie can span shards: re-mask the local rows
            # against the GLOBAL per-channel age before the prio pass
            tie = ok & (itime == m1[jnp.where(ok, out, 0)])
            m2 = jax.lax.pmin(jax.ops.segment_min(
                jnp.where(tie, prio, INF32), seg,
                num_segments=Ep + 1)[:Ep], axis)
            won_ch = ch_ok & (m1 != INF32)
            wprio = jnp.where(won_ch, m2, 0)

        # winner-record halo exchange: the shard owning each winning row
        # gathers its record, psum merges (losers contribute zeros)
        is_buf = wprio < Ep * NV
        se = wprio // NV
        sv = wprio % NV
        ts = wprio - Ep * NV
        lrow = jnp.where(is_buf, (se - c0) * NV + sv, ts - t0)
        mine = won_ch & jnp.where(is_buf,
                                  (se >= c0) & (se < c0 + Ek),
                                  (ts >= t0) & (ts < t0 + Tk))
        bclip = jnp.clip(lrow, 0, Ek * NV - 1)
        wb = head[bclip]
        ws = sq[jnp.clip(lrow, 0, Tk - 1)]
        meta2b = (wb[:, F_META2] if cached
                  else meta2_b[bclip].astype(jnp.int32))
        clsb = (wb[:, F_CLS] if cached
                else cls_b[bclip].astype(jnp.int32))
        rec = jnp.where(
            is_buf[:, None],
            jnp.stack([wb[:, F_DEST], wb[:, F_ITIME], wb[:, F_MIS],
                       meta2b, clsb], axis=-1),
            jnp.stack([ws[:, F_DEST], ws[:, F_ITIME], ws[:, F_MIS],
                       jnp.zeros_like(ts), jnp.zeros_like(ts)],
                      axis=-1))
        w = jax.lax.psum(jnp.where(mine[:, None], rec, 0), axis)
        wdest, witime = w[:, W_DEST], w[:, W_ITIME]
        wmis, wmeta, wcls = w[:, W_MIS], w[:, W_META], w[:, W_CLS]
        wvc, wovc = _winner_vc(wcls, occ_min, occ_arg, NC, vpc)
        entered = (wmis >= 0) & (ch_dst_wg == wmis)
        wmis = jnp.where(entered, -1, wmis)
        push = won_ch & ~is_ej_ch
        vc_oh = wvc[:, None] == vc_iota[None, :]
        whead = jnp.where(vc_oh, state.b_head, 0).sum(1)
        wslot = (whead + wovc) % S

        # replicated credit/head bookkeeping, reconstructed identically
        # on every shard from the exchanged winner table
        se_m = jnp.where(won_ch & is_buf, se, Ep)
        pop1 = jnp.zeros((Ep, NV), jnp.int32).at[(se_m, sv)].add(
            1, mode="drop")
        if reap is not None:
            # reap pops: only the owning shard sees a row's reap
            # decision, but head/count state is replicated, so the reap
            # pop table is exchanged like the winner records (shards
            # own disjoint channel blocks, so psum is a concatenation)
            pop1 = pop1 + jax.lax.psum(
                jax.lax.dynamic_update_slice_in_dim(
                    jnp.zeros((Ep, NV), jnp.int32),
                    reap[:Ek * NV].reshape(Ek, NV).astype(jnp.int32),
                    c0, axis=0), axis)
        b_head = (state.b_head + pop1) % S
        ts_m = jnp.where(won_ch & ~is_buf, ts, Tp)
        pop_s = jnp.zeros((Tp,), jnp.int32).at[ts_m].add(1, mode="drop")
        if reap is not None:
            # source-queue reap pops: like the buffer reap pops above,
            # the decision is shard-local but s_head/s_count are
            # replicated, so the pop vector is psum-exchanged (shards
            # own disjoint terminal blocks — psum is a concatenation)
            pop_s = pop_s + jax.lax.psum(
                jax.lax.dynamic_update_slice_in_dim(
                    jnp.zeros((Tp,), jnp.int32),
                    reap[Ek * NV:].astype(jnp.int32), t0, axis=0),
                axis)
        s_head = (state.s_head + pop_s) % Q
        s_count = state.s_count - pop_s
        b_count = (state.b_count - pop1
                   + (push[:, None] & vc_oh).astype(jnp.int32))
        ch_busy = jnp.where(won_ch, ch_ser - 1,
                            jnp.maximum(state.ch_busy - 1, 0))

        # local pushes: the shard's slice of the winner table, with the
        # route-once-per-hop evaluation on the local rows
        push_l = _sl(push, c0, Ek)
        wdest_l = _sl(wdest, c0, Ek)
        wmis_l = _sl(wmis, c0, Ek)
        wmeta_l = _sl(wmeta, c0, Ek)
        base = [wdest_l, _sl(witime, c0, Ek), wmis_l, wmeta_l,
                t + _sl(ch_lat, c0, Ek)]
        if cached:
            out2, cls2, meta2 = route_kernel(
                fl, _sl(ch_dst, c0, Ek), wdest_l, wmis_l, wmeta_l)
            tail = [out2.astype(jnp.int32), cls2.astype(jnp.int32),
                    meta2.astype(jnp.int32)]
        else:
            z = jnp.zeros_like(wdest_l)
            tail = [z, z, z]
        new_rec = jnp.stack(base + tail, axis=-1)
        pe = jnp.where(push_l, jnp.arange(Ek, dtype=jnp.int32), Ek)
        b_pkt = state.b_pkt.at[
            (pe, _sl(wvc, c0, Ek), _sl(wslot, c0, Ek))].set(
            new_rec, mode="drop")

        st = state.stats
        w_ej = won_ch & is_ej_ch
        hops = (won_ch[:, None]
                & (ch_type[:, None] == type_iota[None, :]))
        if reap is None:
            stranded = jax.lax.psum(
                (valid & (out < 0)).sum().astype(jnp.int32), axis)
            reaped = st.reaped
        else:
            stranded = jax.lax.psum(
                (undel & ~reap).sum().astype(jnp.int32), axis)
            reaped = st.reaped + jax.lax.psum(
                reap.sum().astype(jnp.int32), axis)
        st = st.replace(
            delivered=st.delivered + w_ej.sum(),
            lat_sum=st.lat_sum + jnp.where(w_ej, t - witime, 0).sum(),
            hops=st.hops + hops.astype(jnp.int32).sum(0),
            stranded=stranded, reaped=reaped,
            occ_peak=jnp.maximum(st.occ_peak, occ))
        return state.replace(
            b_pkt=b_pkt, b_head=b_head, b_count=b_count,
            s_head=s_head, s_count=s_count, ch_busy=ch_busy,
            stats=st), None

    return step
