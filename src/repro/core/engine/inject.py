"""Injection phase: Bernoulli packet generation, the misroute decision
(VAL / restricted-VAL / UGAL-G with congestion sensors), and the source-queue
push.  Also accounts generated/dropped packets.

The phase reads the pre-cycle buffer occupancy (`state.b_count`) for the
UGAL sensors and writes only the source-queue fields + stats, so it composes
with the arbitration phase that runs after it in the same cycle: a packet
pushed into an empty source queue this cycle is immediately eligible to
request the injection channel (matching the monolithic simulator).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ..topology import MESH, Network


def build_ugal_watch(net: Network, cfg):
    """UGAL-G congestion sensors: channels whose buffered load proxies the
    (w-group -> peer) global path quality.

    For the switch-less network each (w, u) entry lists the global channel
    itself PLUS the mesh channels feeding its source router — under
    adversarial load the backlog accumulates in those feeders, not in the
    (fast-draining) downstream buffer of the link.  Returns an int array
    [g, g, 5] of channel ids (0-padded), or None when UGAL is off.
    """
    if cfg.route_mode != "ugal":
        return None
    t = net.tables
    g = net.meta["g"]
    if net.meta["kind"] == "switchless":
        ab = net.meta["ab"]
        gw = np.zeros((g, g, 5), dtype=np.int64)
        for w in range(g):
            for u in range(g):
                if u == w:
                    continue
                cg = t["glob_route_cg"][w, u, 0]
                port = t["glob_route_port"][w, u, 0]
                ch = t["ext_out"][w * ab + cg, port]
                src = net.ch_src[ch]
                feeders = [c for c in np.where(net.ch_dst == src)[0]
                           if net.ch_type[c] == MESH][:4]
                sens = [ch] + list(feeders)
                gw[w, u, :len(sens)] = sens
        return jnp.asarray(gw)
    gw = np.maximum(t["glob_out_ch"][:, :, :1], 0)
    return jnp.asarray(
        np.concatenate([gw, np.zeros((g, g, 4), dtype=np.int64)], axis=-1))


def make_misroute_fn(net: Network, cfg, consts):
    """Returns gen_mis(key, dest[T], b_count[E, NV]) -> mis_wg[T].

    -1 means route minimally; otherwise the intermediate W-group the packet
    must visit first (cleared by the apply phase on entry).
    """
    T = consts["T"]
    num_wg = consts["num_wg"]
    term_wg = consts["term_wg"]
    glob_watch = build_ugal_watch(net, cfg)

    def gen_mis(key, dest, b_count):
        wg_s = term_wg
        wg_d = term_wg[dest]
        differ = wg_s != wg_d
        if cfg.route_mode == "min" or num_wg <= 2:
            return jnp.full((T,), -1, dtype=jnp.int32)
        cand = jax.random.randint(key, (T,), 0, num_wg).astype(jnp.int32)
        cand = jnp.where((cand == wg_s) | (cand == wg_d),
                         (cand + 1) % num_wg, cand)
        cand = jnp.where((cand == wg_s) | (cand == wg_d),
                         (cand + 1) % num_wg, cand)
        if cfg.route_mode == "val_restricted":
            # only misroute to W-groups strictly below the destination
            ok = (cand < wg_d) & (cand != wg_s)
            cand = jnp.where(ok, cand, -1)
        if cfg.route_mode == "ugal":
            occ = b_count.sum(axis=1)  # [E] total buffered packets
            q_min = occ[glob_watch[wg_s, jnp.maximum(wg_d, 0)]].sum(-1)
            q_non = occ[glob_watch[wg_s, jnp.maximum(cand, 0)]].sum(-1)
            take_nonmin = q_min > 2 * q_non + cfg.ugal_threshold
            cand = jnp.where(take_nonmin, cand, -1)
        return jnp.where(differ, cand, -1).astype(jnp.int32)

    return gen_mis


def make_inject_fn(net: Network, cfg, consts, pattern, inject_mask=None):
    """Returns inject(state, t, key, rate_pkt) -> state."""
    T = consts["T"]
    Q = cfg.srcq_pkts
    inj_mask = (jnp.ones(T, dtype=bool) if inject_mask is None
                else jnp.asarray(inject_mask))
    gen_mis = make_misroute_fn(net, cfg, consts)

    def inject(state, t, key, rate_pkt):
        k_gen, k_dest, k_mis = jax.random.split(key, 3)
        gen = (jax.random.uniform(k_gen, (T,)) < rate_pkt) & inj_mask
        dest = pattern(k_dest, t).astype(jnp.int32)
        gen = gen & (dest != jnp.arange(T))  # fixed points are silent
        mis = gen_mis(k_mis, dest, state.b_count)
        space = state.s_count < Q
        push = gen & space
        slot = (state.s_head + state.s_count) % Q
        idx = (jnp.arange(T), slot)
        # one gather + one scatter for the packed (dest, itime, mis) record
        new_rec = jnp.stack(
            [dest, jnp.full((T,), t, jnp.int32), mis], axis=-1)
        rec = jnp.where(push[:, None], new_rec, state.s_pkt[idx])
        s_pkt = state.s_pkt.at[idx].set(rec)
        st = state.stats
        st = st.replace(generated=st.generated + gen.sum(),
                        dropped=st.dropped + (gen & ~space).sum())
        return state.replace(s_pkt=s_pkt,
                             s_count=state.s_count + push, stats=st)

    return inject
