"""Injection phase: Bernoulli packet generation, the misroute decision
(VAL / restricted-VAL / UGAL-G with congestion sensors), and the source-queue
push.  Also accounts generated/dropped packets.

The phase reads the pre-cycle buffer occupancy (`state.b_count`) for the
UGAL sensors and writes only the source-queue fields + stats, so it composes
with the arbitration phase that runs after it in the same cycle: a packet
pushed into an empty source queue this cycle is immediately eligible to
request the injection channel (matching the monolithic simulator).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ..topology import MESH, FaultSet, Network


def build_ugal_watch(net: Network, cfg, faults: FaultSet | None = None):
    """UGAL-G congestion sensors: channels whose buffered load proxies the
    (w-group -> peer) global path quality.

    For the switch-less network each (w, u) entry lists the global channel
    itself PLUS the mesh channels feeding its source router — under
    adversarial load the backlog accumulates in those feeders, not in the
    (fast-draining) downstream buffer of the link.  Returns an int array
    [g, g, 5] of channel ids, or None when UGAL is off.

    Unused sensor slots hold the sentinel -1 and are masked out of the
    occupancy sum (0-padding would silently add channel 0's backlog to
    every entry with fewer than 5 feeders and bias the min-vs-nonmin
    comparison).  With `faults`, each entry watches the first ALIVE
    parallel global link and only its alive feeders.
    """
    if cfg.route_mode != "ugal":
        return None
    t = net.tables
    g = net.meta["g"]
    faults = faults or FaultSet()
    ch_alive = faults.ch_alive(net)
    gw = np.full((g, g, 5), -1, dtype=np.int64)
    if net.meta["kind"] == "switchless":
        ab = net.meta["ab"]
        npar = t["glob_route_cg"].shape[-1]
        for w in range(g):
            for u in range(g):
                if u == w:
                    continue
                ch = -1
                for r in range(npar):
                    cg = t["glob_route_cg"][w, u, r]
                    if cg < 0:
                        continue
                    cand = t["ext_out"][w * ab + cg, t["glob_route_port"][w, u, r]]
                    if cand >= 0 and ch_alive[cand]:
                        ch = cand
                        break
                if ch < 0:
                    continue
                src = net.ch_src[ch]
                feeders = [c for c in np.where(net.ch_dst == src)[0]
                           if net.ch_type[c] == MESH and ch_alive[c]][:4]
                sens = [ch] + list(feeders)
                gw[w, u, :len(sens)] = sens
        return jnp.asarray(gw)
    out_ch = t["glob_out_ch"]
    npar = out_ch.shape[-1]
    for w in range(g):
        for u in range(g):
            if u == w:
                continue
            for r in range(npar):
                cand = out_ch[w, u, r]
                if cand >= 0 and ch_alive[cand]:
                    gw[w, u, 0] = cand
                    break
    return jnp.asarray(gw)


def ugal_queue_len(occ, watch_entry):
    """Masked sensor sum: total buffered packets over the (>= 0) sensor
    channels of one watch entry; -1 sentinel slots contribute zero."""
    vals = occ[jnp.maximum(watch_entry, 0)]
    return jnp.where(watch_entry >= 0, vals, 0).sum(-1)


def make_misroute_fn(net: Network, cfg, consts):
    """Returns gen_mis(key, dest[T], b_count[E, NV], fl) -> mis_wg[T].

    -1 means route minimally; otherwise the intermediate W-group the packet
    must visit first (cleared by the apply phase on entry).  The UGAL
    sensor table comes from the per-lane `fl` dict so faulted lanes watch
    their surviving links.

    Fault-aware adaptive stage (all non-minimal modes): a candidate
    intermediate W-group is masked out unless BOTH misroute hops
    (source -> candidate, candidate -> destination) keep an alive global
    link (`fl["glob_ok"]`), and under UGAL the candidate's sensed queue is
    inflated by `fl["wg_penalty"]` — an additive congestion penalty
    proportional to the fraction of the candidate W-group's internal
    channels that died — so traffic is biased away from W-groups whose
    up*/down* connectivity is degraded.  Both tables are identity on a
    pristine network, leaving fault-free decisions bit-for-bit unchanged.
    """
    T = consts["T"]
    num_wg = consts["num_wg"]
    term_wg = consts["term_wg"]

    def gen_mis(key, dest, b_count, fl):
        wg_s = term_wg
        wg_d = term_wg[dest]
        differ = wg_s != wg_d
        if cfg.route_mode == "min" or num_wg <= 2:
            return jnp.full((T,), -1, dtype=jnp.int32)
        cand = jax.random.randint(key, (T,), 0, num_wg).astype(jnp.int32)
        cand = jnp.where((cand == wg_s) | (cand == wg_d),
                         (cand + 1) % num_wg, cand)
        cand = jnp.where((cand == wg_s) | (cand == wg_d),
                         (cand + 1) % num_wg, cand)
        # fault-aware candidate mask: both misroute hops must keep an
        # alive global link on the current epoch's surviving network
        ok_path = fl["glob_ok"][wg_s, jnp.maximum(cand, 0)] \
            & fl["glob_ok"][jnp.maximum(cand, 0), wg_d]
        cand = jnp.where(ok_path, cand, -1)
        if cfg.route_mode == "val_restricted":
            # only misroute to W-groups strictly below the destination
            ok = (cand < wg_d) & (cand != wg_s) & (cand >= 0)
            cand = jnp.where(ok, cand, -1)
        if cfg.route_mode == "ugal":
            glob_watch = fl["ugal_watch"]
            occ = b_count.sum(axis=1)  # [E] total buffered packets
            q_min = ugal_queue_len(occ, glob_watch[wg_s, jnp.maximum(wg_d, 0)])
            q_non = ugal_queue_len(occ, glob_watch[wg_s, jnp.maximum(cand, 0)])
            q_non = q_non + fl["wg_penalty"][jnp.maximum(cand, 0)]
            take_nonmin = (q_min > 2 * q_non + cfg.ugal_threshold) \
                & (cand >= 0)
            cand = jnp.where(take_nonmin, cand, -1)
        return jnp.where(differ, cand, -1).astype(jnp.int32)

    return gen_mis


def make_inject_fn(net: Network, cfg, consts, pattern, inject_mask=None):
    """Returns inject(state, t, key, rate_pkt, fl) -> state.

    Dead terminals (routers killed by the lane's fault set) neither inject
    nor are injected TO: a generated packet whose destination terminal is
    dead is suppressed like a permutation fixed point, so every packet that
    enters a degraded network can be delivered.
    """
    T = consts["T"]
    Q = cfg.srcq_pkts
    inj_mask = (jnp.ones(T, dtype=bool) if inject_mask is None
                else jnp.asarray(inject_mask))
    gen_mis = make_misroute_fn(net, cfg, consts)

    def inject(state, t, key, rate_pkt, fl):
        k_gen, k_dest, k_mis = jax.random.split(key, 3)
        alive = fl["term_alive"]
        gen = (jax.random.uniform(k_gen, (T,)) < rate_pkt) & inj_mask
        dest = pattern(k_dest, t).astype(jnp.int32)
        gen = gen & (dest != jnp.arange(T))  # fixed points are silent
        gen = gen & alive & alive[dest]      # dead endpoints are silent
        mis = gen_mis(k_mis, dest, state.b_count, fl)
        space = state.s_count < Q
        push = gen & space
        slot = (state.s_head + state.s_count) % Q
        idx = (jnp.arange(T), slot)
        # one gather + one scatter for the packed (dest, itime, mis) record
        new_rec = jnp.stack(
            [dest, jnp.full((T,), t, jnp.int32), mis], axis=-1)
        rec = jnp.where(push[:, None], new_rec, state.s_pkt[idx])
        s_pkt = state.s_pkt.at[idx].set(rec)
        st = state.stats
        st = st.replace(generated=st.generated + gen.sum(),
                        dropped=st.dropped + (gen & ~space).sum())
        return state.replace(s_pkt=s_pkt,
                             s_count=state.s_count + push, stats=st)

    return inject
