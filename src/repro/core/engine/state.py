"""Simulation state (pytree) and static model constants.

`SimState` is the single carry of the cycle loop: every field is a
fixed-shape jnp array, so the whole state is a JAX pytree that can be
`lax.scan`-carried, `jax.vmap`-batched over a (rate x seed) sweep axis, and
donated across scan steps to keep memory flat.  An optional leading batch
axis on every array is the contract the phase functions obey: they never
reshape across axis 0, so `vmap` over axis 0 is always legal.

`build_consts` packages the static (per-network, per-config) arrays the
phases close over; these carry no batch axis and are captured by the jitted
step, not threaded through the carry.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np
import jax
import jax.numpy as jnp

from ..topology import NUM_CH_TYPES, FaultSet, Network
from ..routing import make_route_kernel, num_vcs, route_tables

INF32 = jnp.int32(2**31 - 1)

# payload-field indices of the packed per-packet record in `SimState.b_pkt`.
# Packing all five fields into one trailing axis turns the five head gathers
# and five push scatters of the monolithic simulator into ONE gather and ONE
# scatter per cycle — scatter/gather lower to per-row loops on CPU, so row
# count, not element count, is what the hot loop pays for.
F_DEST, F_ITIME, F_MIS, F_META, F_READY = range(5)
NUM_FIELDS = 5
NUM_SRC_FIELDS = 3      # source-queue records pack (dest, itime, mis)


@jax.tree_util.register_dataclass
@dataclass
class SimStats:
    """Measurement accumulators (zeroed at the end of warmup)."""

    delivered: jax.Array      # [] packets ejected
    lat_sum: jax.Array        # [] float32 sum of generation->ejection cycles
    generated: jax.Array      # [] packets generated (incl. dropped)
    dropped: jax.Array        # [] source-queue overflow
    hops: jax.Array           # [NUM_CH_TYPES] channel traversals by type

    def replace(self, **kw) -> "SimStats":
        return replace(self, **kw)

    @classmethod
    def zeros(cls, batch: tuple[int, ...] = ()) -> "SimStats":
        z = lambda *s: jnp.zeros(batch + s, dtype=jnp.int32)
        return cls(delivered=z(), lat_sum=jnp.zeros(batch, jnp.float32),
                   generated=z(), dropped=z(), hops=z(NUM_CH_TYPES))


@jax.tree_util.register_dataclass
@dataclass
class SimState:
    """All mutable router/terminal state, over (channel E, VC NV, slot S)
    and (terminal T, source-queue slot Q); ring buffers of packets."""

    # per-(channel, vc) input buffers; the trailing axis packs the packet
    # record (F_DEST destination terminal, F_ITIME generation cycle,
    # F_MIS misroute W-group (-1 = minimal), F_META routing meta bitfield,
    # F_READY cycle the head becomes forwardable)
    b_pkt: jax.Array          # [E, NV, S, NUM_FIELDS]
    b_head: jax.Array         # [E, NV] ring head
    b_count: jax.Array        # [E, NV] occupancy (packets)
    # per-terminal source queues (trailing axis: F_DEST, F_ITIME, F_MIS)
    s_pkt: jax.Array          # [T, Q, NUM_SRC_FIELDS]
    s_head: jax.Array         # [T]
    s_count: jax.Array        # [T]
    ch_busy: jax.Array        # [E] serialization busy countdown
    stats: SimStats

    def replace(self, **kw) -> "SimState":
        return replace(self, **kw)


def make_state(net: Network, cfg, NV: int,
               batch: tuple[int, ...] = ()) -> SimState:
    """Fresh (empty-network) state; `batch` prepends sweep axes."""
    E, T = net.num_channels, net.num_terminals
    S, Q = cfg.buf_pkts, cfg.srcq_pkts
    z = lambda *s: jnp.zeros(batch + s, dtype=jnp.int32)
    return SimState(
        b_pkt=z(E, NV, S, NUM_FIELDS),
        b_head=z(E, NV), b_count=z(E, NV),
        s_pkt=z(T, Q, NUM_SRC_FIELDS),
        s_head=z(T), s_count=z(T),
        ch_busy=z(E),
        stats=SimStats.zeros(batch))


def build_consts(net: Network, cfg):
    """Static (per-net, per-cfg) arrays + the route KERNEL.

    Everything here is batch-free: phase functions gather from these with
    (possibly batched) indices, which keeps them pure under `vmap`.  The
    fault-dependent data (routing tables, alive masks) is deliberately NOT
    here — it lives in the per-lane `fl` dict (`build_lane`) threaded
    through the step arguments, so one compiled step serves lanes with
    different fault sets.
    """
    NV = num_vcs(net.meta["kind"], cfg.vc_mode, cfg.nonminimal) \
        * cfg.vcs_per_class
    E = net.num_channels
    T = net.num_terminals
    route_kernel = make_route_kernel(net, cfg.vc_mode)
    ser = (cfg.pkt_len + net.ch_bw - 1) // net.ch_bw  # serialization cycles
    wg_tbl = net.tables.get("node_wg", net.tables.get("node_grp"))
    # wg of the downstream node of each channel (for misroute clearing)
    ch_dst_wg = wg_tbl[np.clip(net.ch_dst, 0, net.num_nodes - 1)]
    consts = dict(
        NV=NV, E=E, T=T,
        # eject channels are the trailing id block (Network.validate); they
        # never request, so the request grid covers only [:E_req]
        E_req=net.first_eject,
        ch_dst=jnp.asarray(net.ch_dst),
        ch_ser=jnp.asarray(ser),
        # packed per-channel record (type, dst_wg, lat): the request phase
        # gathers it ONCE per requester instead of three separate row
        # gathers spread over arbitrate/stats/apply
        ch_tbl=jnp.stack([jnp.asarray(net.ch_type),
                          jnp.asarray(ch_dst_wg),
                          jnp.asarray(net.ch_lat)], axis=-1),
        inject_ch=jnp.asarray(net.inject_ch),
        term_node=jnp.asarray(net.term_node),
        term_wg=jnp.asarray(wg_tbl[net.term_node]),
        num_wg=net.meta["g"],
    )
    return consts, route_kernel


def build_lane(net: Network, cfg, faults: FaultSet | None = None) -> dict:
    """Per-lane fault data (the `fl` pytree): alive masks + fault-dependent
    routing tables (+ UGAL sensors when adaptive routing is on).

    One lane describes ONE degraded (or pristine) network.  The dict is a
    JAX pytree with a fixed structure per (net, cfg), so `stack_lanes` can
    prepend a lane axis and `run_scan_batched` can vmap the step over lanes
    carrying DIFFERENT fault sets in a single compile.  The `SimState`
    itself needs no fault information: buffers start empty and dead
    channels simply never grant.
    """
    from .inject import build_ugal_watch  # local import: step imports both
    faults = faults or FaultSet()
    fl = dict(
        ch_alive=jnp.asarray(faults.ch_alive(net)),
        term_alive=jnp.asarray(faults.term_alive(net)),
    )
    fl.update(route_tables(net, cfg.vc_mode, faults))
    if cfg.route_mode == "ugal":
        fl["ugal_watch"] = build_ugal_watch(net, cfg, faults)
    return fl


def stack_lanes(lanes: list[dict]) -> dict:
    """Stack per-lane fault dicts into one lane-axis pytree [B, ...]."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *lanes)
