"""Simulation state (pytree) and static model constants.

`SimState` is the single carry of the cycle loop: every field is a
fixed-shape jnp array, so the whole state is a JAX pytree that can be
`lax.scan`-carried, `jax.vmap`-batched over a (rate x seed) sweep axis, and
donated across scan steps to keep memory flat.  An optional leading batch
axis on every array is the contract the phase functions obey: they never
reshape across axis 0, so `vmap` over axis 0 is always legal.

`build_consts` packages the static (per-network, per-config) arrays the
phases close over; these carry no batch axis and are captured by the jitted
step, not threaded through the carry.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np
import jax
import jax.numpy as jnp

from ... import env_int
from ..topology import (NUM_CH_TYPES, FaultSchedule, FaultSet, Network,
                        glob_pair_alive, wg_channel_alive_frac)
from ..routing import make_route_kernel, num_vcs, route_tables

INF32 = jnp.int32(2**31 - 1)

# payload-field indices of the packed per-packet record in `SimState.b_pkt`.
# Packing all five fields into one trailing axis turns the five head gathers
# and five push scatters of the monolithic simulator into ONE gather and ONE
# scatter per cycle — scatter/gather lower to per-row loops on CPU, so row
# count, not element count, is what the hot loop pays for.
F_DEST, F_ITIME, F_MIS, F_META, F_READY = range(5)
NUM_FIELDS = 5
NUM_SRC_FIELDS = 3      # source-queue records pack (dest, itime, mis)

# the fused step (`cfg.step_impl="fused"`) extends the record with the
# CACHED next-hop route decision: a packet's route out of a channel is a
# pure function of (the packet, the channel, the lane's fault epoch), so
# the fused step evaluates it ONCE when the packet is pushed (E winner
# rows) instead of for every head row every cycle, and stores the output
# channel, requested VC class, and next routing meta alongside the
# payload.  Epoch-scheduled (warm-fault) lanes can't cache — the epoch
# in effect at head time isn't known at push time — so the fused step
# falls back to per-cycle routing there and these fields stay zero.
# The occupancy-compacted step (`step_impl="compact"`) carries the same
# cached tail.
F_OUT, F_CLS, F_META2 = 5, 6, 7
NUM_FUSED_FIELDS = 8

# step impls whose records carry the cached-route tail
CACHED_ROUTE_IMPLS = ("fused", "compact")


def resolve_reap_age(cfg) -> int:
    """Effective router-death reaper park age for this run (cycles).

    `cfg.reap_age` wins when nonzero; otherwise the process-wide
    REPRO_REAP_AGE default applies.  0 disables the reaper entirely —
    the branch is TRACE-TIME, so a disabled reaper compiles the exact
    step the pre-reaper engine compiled (no extra ops, bit-identical).

    Age is measured as ``t - itime`` (cycles since generation), which
    upper-bounds the time a packet has been PARKED on the -1
    non-channel (a packet cannot strand before it exists): no packet
    ever stays parked longer than `reap_age` cycles, though a packet
    that traveled before stranding is reaped correspondingly earlier.
    Using generation age avoids a per-slot park-time state array and
    keeps the reap decision a pure function of the request row.
    """
    age = int(getattr(cfg, "reap_age", 0))
    return age if age > 0 else env_int("REPRO_REAP_AGE", 0)


@jax.tree_util.register_dataclass
@dataclass
class SimStats:
    """Measurement accumulators (zeroed at the end of warmup).

    All fields are cumulative counters except `stranded`, a per-cycle
    GAUGE: the number of head-of-line requests currently parked on the
    -1 non-channel (packets a warm fault left with no route, see the
    updown kernel).  Its final value is the stranded population at exit
    — previously only inferable as "in flight when the run ended".

    `reaped` is the router-death reaper's cumulative drop counter
    (`resolve_reap_age`): parked packets whose age reached the park age
    are removed from their buffers and tallied here, DISJOINT from
    `dropped` (source-queue overflow), so exact conservation is
    ``generated == delivered + dropped + reaped + in-flight`` at every
    cycle — including across repair-epoch boundaries, where a table
    swap can unstrand a parked packet before the reaper reaches it.
    With the reaper on, `stranded` gauges the POST-reap parked
    population of the cycle.

    `occ_peak` is a high-water mark, not a per-measure counter: the
    maximum number of LIVE request rows (non-empty (channel, vc)
    buffers + non-empty source queues, taken right after inject) any
    cycle of the run saw.  It spans warmup too (`stats.zero_stats`
    preserves it across the reset): the occupancy-compacted step
    (`step_impl="compact"`, fused.py) uses it to certify post-run that
    its capacity rung C bounded the live set for the WHOLE run, and a
    warmup-phase overflow is just as invalidating as a measured one.
    Every step impl computes it from the same dense counts, so it is
    part of the bit-identity contract like any other counter.
    """

    delivered: jax.Array      # [] packets ejected
    lat_sum: jax.Array        # [] float32 sum of generation->ejection cycles
    generated: jax.Array      # [] packets generated (incl. dropped)
    dropped: jax.Array        # [] source-queue overflow
    stranded: jax.Array       # [] gauge: requests parked on the -1 channel
    reaped: jax.Array         # [] packets the reaper dropped (age-based)
    occ_peak: jax.Array       # [] high-water mark of live request rows
    hops: jax.Array           # [NUM_CH_TYPES] channel traversals by type

    def replace(self, **kw) -> "SimStats":
        return replace(self, **kw)

    @classmethod
    def zeros(cls, batch: tuple[int, ...] = ()) -> "SimStats":
        z = lambda *s: jnp.zeros(batch + s, dtype=jnp.int32)
        return cls(delivered=z(), lat_sum=jnp.zeros(batch, jnp.float32),
                   generated=z(), dropped=z(), stranded=z(), reaped=z(),
                   occ_peak=z(), hops=z(NUM_CH_TYPES))


@jax.tree_util.register_dataclass
@dataclass
class SimState:
    """All mutable router/terminal state, over (channel E, VC NV, slot S)
    and (terminal T, source-queue slot Q); ring buffers of packets."""

    # per-(channel, vc) input buffers; the trailing axis packs the packet
    # record (F_DEST destination terminal, F_ITIME generation cycle,
    # F_MIS misroute W-group (-1 = minimal), F_META routing meta bitfield,
    # F_READY cycle the head becomes forwardable)
    b_pkt: jax.Array          # [E, NV, S, NUM_FIELDS]
    b_head: jax.Array         # [E, NV] ring head
    b_count: jax.Array        # [E, NV] occupancy (packets)
    # per-terminal source queues (trailing axis: F_DEST, F_ITIME, F_MIS)
    s_pkt: jax.Array          # [T, Q, NUM_SRC_FIELDS]
    s_head: jax.Array         # [T]
    s_count: jax.Array        # [T]
    ch_busy: jax.Array        # [E] serialization busy countdown
    stats: SimStats

    def replace(self, **kw) -> "SimState":
        return replace(self, **kw)


def make_state(net: Network, cfg, NV: int,
               batch: tuple[int, ...] = (), *,
               ch_pad: int = 0, term_pad: int = 0) -> SimState:
    """Fresh (empty-network) state; `batch` prepends sweep axes.

    `ch_pad` / `term_pad` append GHOST channels/terminals (used by the
    channel-sharded fused step so every shard's block is dense; see
    `fused.fused_pad`).  Ghosts start empty, are dead in every alive
    mask, and never inject — an all-zero state is already correct for
    them.

    The record width follows `cfg.step_impl`: the fused and compact
    steps carry the cached route fields (`NUM_FUSED_FIELDS`), the
    oracle the base payload (`NUM_FIELDS`)."""
    E, T = net.num_channels + ch_pad, net.num_terminals + term_pad
    S, Q = cfg.buf_pkts, cfg.srcq_pkts
    nf = (NUM_FUSED_FIELDS
          if getattr(cfg, "step_impl", "jnp") in CACHED_ROUTE_IMPLS
          else NUM_FIELDS)
    z = lambda *s: jnp.zeros(batch + s, dtype=jnp.int32)
    return SimState(
        b_pkt=z(E, NV, S, nf),
        b_head=z(E, NV), b_count=z(E, NV),
        s_pkt=z(T, Q, NUM_SRC_FIELDS),
        s_head=z(T), s_count=z(T),
        ch_busy=z(E),
        stats=SimStats.zeros(batch))


def build_consts(net: Network, cfg):
    """Static (per-net, per-cfg) arrays + the route KERNEL.

    Everything here is batch-free: phase functions gather from these with
    (possibly batched) indices, which keeps them pure under `vmap`.  The
    fault-dependent data (routing tables, alive masks) is deliberately NOT
    here — it lives in the per-lane `fl` dict (`build_lane`) threaded
    through the step arguments, so one compiled step serves lanes with
    different fault sets.
    """
    NV = num_vcs(net.meta["kind"], cfg.vc_mode, cfg.nonminimal) \
        * cfg.vcs_per_class
    E = net.num_channels
    T = net.num_terminals
    route_kernel = make_route_kernel(net, cfg.vc_mode)
    ser = (cfg.pkt_len + net.ch_bw - 1) // net.ch_bw  # serialization cycles
    wg_tbl = net.tables.get("node_wg", net.tables.get("node_grp"))
    # wg of the downstream node of each channel (for misroute clearing)
    ch_dst_wg = wg_tbl[np.clip(net.ch_dst, 0, net.num_nodes - 1)]
    consts = dict(
        NV=NV, E=E, T=T,
        # eject channels are the trailing id block (Network.validate); they
        # never request, so the request grid covers only [:E_req]
        E_req=net.first_eject,
        ch_dst=jnp.asarray(net.ch_dst),
        ch_ser=jnp.asarray(ser),
        # packed per-channel record (type, dst_wg, lat): the request phase
        # gathers it ONCE per requester instead of three separate row
        # gathers spread over arbitrate/stats/apply
        ch_tbl=jnp.stack([jnp.asarray(net.ch_type),
                          jnp.asarray(ch_dst_wg),
                          jnp.asarray(net.ch_lat)], axis=-1),
        inject_ch=jnp.asarray(net.inject_ch),
        term_node=jnp.asarray(net.term_node),
        term_wg=jnp.asarray(wg_tbl[net.term_node]),
        num_wg=net.meta["g"],
    )
    return consts, route_kernel


# additive UGAL congestion penalty per unit of W-group degradation: a
# candidate intermediate W-group that lost fraction d of its internal
# (mesh + local) channels reads round(SCALE * d) extra buffered packets on
# its sensor, biasing the adaptive misroute away from degraded W-groups.
# Zero on a pristine network, so fault-free UGAL decisions are unchanged.
UGAL_WG_PENALTY_SCALE = 16


def build_lane(net: Network, cfg,
               faults: FaultSet | FaultSchedule | None = None) -> dict:
    """Per-lane fault data (the `fl` pytree): alive masks + fault-dependent
    routing tables (+ adaptive-misroute tables for the non-minimal modes,
    + UGAL sensors when adaptive routing is on).

    One lane describes ONE degraded (or pristine) network.  With a
    `FaultSchedule` the lane is EPOCH-STACKED: every array carries a
    leading `[P]` epoch axis plus an `epoch_start [P]` int32 vector, and
    the step resolves the active epoch by the traced cycle number
    (`resolve_epoch`) before the phases run — mid-run link death is just
    the epoch index advancing.

    The dict is a JAX pytree with a fixed structure per (net, cfg,
    schedule shape), so `stack_lanes` can prepend a lane axis and
    `run_scan_batched` can vmap the step over lanes carrying DIFFERENT
    fault sets (or schedules) in a single compile.  The `SimState` itself
    needs no fault information: buffers start empty and dead channels
    simply never grant.
    """
    if isinstance(faults, FaultSchedule):
        from ..routing import stack_epoch_dicts
        starts, fl = stack_epoch_dicts(
            [_build_epoch(net, cfg, f) for _, f in faults.epochs],
            (c for c, _ in faults.epochs))
        fl["epoch_start"] = starts
        return fl
    return _build_epoch(net, cfg, faults)


def _build_epoch(net: Network, cfg, faults: FaultSet | None) -> dict:
    """The flat (single-epoch) lane dict for one cold fault set."""
    from .inject import build_ugal_watch  # local import: step imports both
    faults = faults or FaultSet()
    fl = dict(
        ch_alive=jnp.asarray(faults.ch_alive(net)),
        term_alive=jnp.asarray(faults.term_alive(net)),
    )
    fl.update(route_tables(net, cfg.vc_mode, faults))
    if cfg.route_mode != "min":
        # fault-aware adaptive misroute stage: candidate intermediate
        # W-groups must keep alive global connectivity on both misroute
        # hops, and degraded W-groups are biased against in proportion to
        # their lost internal channels (see inject.make_misroute_fn)
        fl["glob_ok"] = jnp.asarray(glob_pair_alive(net, faults))
        frac = wg_channel_alive_frac(net, faults)
        fl["wg_penalty"] = jnp.asarray(
            np.round(UGAL_WG_PENALTY_SCALE * (1.0 - frac)).astype(np.int32))
    if cfg.route_mode == "ugal":
        fl["ugal_watch"] = build_ugal_watch(net, cfg, faults)
    return fl


def is_scheduled(fl: dict) -> bool:
    """True when the lane dict is epoch-stacked (carries `epoch_start`)."""
    return "epoch_start" in fl


def epoch_index(fl: dict, t):
    """Traced index of the epoch in effect at cycle `t` (int32 scalar)."""
    return (jnp.sum(t >= fl["epoch_start"]) - 1).astype(jnp.int32)


def lane_epoch(fl: dict, idx):
    """Slice one epoch out of an epoch-stacked lane dict; `idx` may be a
    traced scalar (the gather on the leading axis stays jit/vmap-legal)."""
    return {k: v[idx] for k, v in fl.items() if k != "epoch_start"}


def resolve_epoch(fl: dict, t):
    """The lane's fault data in effect at cycle `t`: a no-op for flat
    (cold) lanes, an epoch gather for scheduled ones.  The branch is
    trace-time (pytree structure is static under jit)."""
    if not is_scheduled(fl):
        return fl
    return lane_epoch(fl, epoch_index(fl, t))


def stack_lanes(lanes: list[dict], epochs: int | None = None) -> dict:
    """Stack per-lane fault dicts into one lane-axis pytree [B, ...].

    Epoch-stacked lanes with differing epoch counts are padded to the
    longest schedule by repeating their final epoch with an unreachable
    onset cycle, so heterogeneous warm-fault grids still stack into one
    dense `[B, P, ...]` pytree (and one compile).  `epochs` pins the
    padded epoch count to AT LEAST that many — window-session packers
    use it so every pack of a bucket stacks to the same [B, P, ...]
    shapes regardless of which lanes happened to land in it."""
    if lanes and is_scheduled(lanes[0]):
        P = max(int(l["epoch_start"].shape[0]) for l in lanes)
        if epochs is not None:
            P = max(P, epochs)
        lanes = [_pad_epochs(l, P) for l in lanes]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *lanes)


def _pad_epochs(fl: dict, P: int) -> dict:
    p = P - int(fl["epoch_start"].shape[0])
    if p == 0:
        return fl
    out = {k: jnp.concatenate([v] + [v[-1:]] * p) for k, v in fl.items()
           if k != "epoch_start"}
    out["epoch_start"] = jnp.concatenate(
        [fl["epoch_start"], jnp.full((p,), INF32, dtype=jnp.int32)])
    return out
