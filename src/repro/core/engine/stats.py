"""Stats phase: delivered/latency/hop accumulators and the conversion of
raw counters into a `SimResult` (per sweep lane)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ..topology import CH_TYPE_NAMES, EJECT, NUM_CH_TYPES
from .arbitrate import Requests
from .state import SimStats


def undeliverable_mask(req: Requests, ch_alive):
    """Head-of-line rows that can NEVER be granted in the current fault
    epoch: parked on the -1 non-channel (routing found no live path), or
    requesting a channel the epoch's fault set killed.  The second case
    covers the two zombie classes the ``out < 0`` test misses — a packet
    buffered AT its dead destination router requests that router's
    (dead) eject channel, and a source head whose terminal's router died
    requests its (dead) injection channel.  These rows are the reaper's
    candidate population and, with the reaper on, the `stranded` gauge's
    population; a later repair epoch revives them (the mask is
    re-evaluated every cycle against the epoch's ``ch_alive``)."""
    dead_out = ~ch_alive[jnp.clip(req.out, 0, ch_alive.shape[0] - 1)]
    return req.valid & ((req.out < 0) | dead_out)


def reap_mask(req: Requests, t, reap_age: int, ch_alive):
    """The rows the router-death reaper drops this cycle: undeliverable
    head-of-line requests whose generation age reached the park age
    (`state.resolve_reap_age` for the age semantics).  Disjoint from the
    grant mask by construction — a winner needs a LIVE ``out >= 0``
    channel (the grant is masked by ``ch_alive``), a reap victim has
    none — so reap pops compose with winner pops without collisions, on
    buffer rows and source rows alike."""
    return undeliverable_mask(req, ch_alive) & ((t - req.itime) >= reap_age)


def accumulate(stats: SimStats, req: Requests, win, consts, t,
               reap=None, ch_alive=None) -> SimStats:
    """Fold this cycle's granted movements into the accumulators.

    `reap` (the reaper's drop mask, or None when the reaper is off —
    a trace-time switch) moves its rows out of the `stranded` gauge
    and into the cumulative `reaped` counter, keeping
    ``generated == delivered + dropped + reaped + in-flight`` exact.
    With the reaper on, `ch_alive` must be the epoch's channel liveness
    so the gauge counts the full undeliverable population (including
    dead-out rows) — otherwise reaped dead-out rows would read as a
    negative gauge contribution.  With the reaper off the gauge keeps
    its original parked-only (``out < 0``) definition, preserving
    bit-identity with the pre-reaper step."""
    w_ej = win & (req.otype == EJECT)
    delivered = stats.delivered + w_ej.sum()
    lat_sum = stats.lat_sum + jnp.where(w_ej, (t - req.itime), 0).sum()
    # dense one-hot instead of segment_sum: NUM_CH_TYPES is tiny and
    # segment ops lower to per-row scatter loops on CPU
    onehot = win[:, None] & (req.otype[:, None] == jnp.arange(NUM_CH_TYPES))
    hops = stats.hops + onehot.astype(jnp.int32).sum(0)
    # gauge, not a counter: head-of-line requests parked on the -1
    # non-channel THIS cycle (warm-fault strandings; arbitration never
    # grants them, so the last cycle's value is the population at exit).
    # With the reaper on, the gauge counts the POST-reap population.
    if reap is None:
        parked = req.valid & (req.out < 0)
        stranded = parked.sum().astype(jnp.int32)
        return stats.replace(delivered=delivered, lat_sum=lat_sum,
                             hops=hops, stranded=stranded)
    parked = undeliverable_mask(req, ch_alive)
    stranded = (parked & ~reap).sum().astype(jnp.int32)
    reaped = stats.reaped + reap.sum().astype(jnp.int32)
    return stats.replace(delivered=delivered, lat_sum=lat_sum, hops=hops,
                         stranded=stranded, reaped=reaped)


def live_rows(state) -> jax.Array:
    """The number of LIVE request rows right now: non-empty
    (channel, vc) buffers + non-empty source queues.  This is the
    quantity the occupancy-compacted step (`fused.make_compact_step`)
    must bound with its capacity rung C, so EVERY step impl folds it
    into `SimStats.occ_peak` from the same dense counts — eject-channel
    and ghost rows never hold packets, so summing the full arrays
    matches the `[:E_req]` request grid exactly."""
    return ((state.b_count > 0).sum()
            + (state.s_count > 0).sum()).astype(jnp.int32)


def track_occ(stats: SimStats, state) -> SimStats:
    """Fold the current live-row count into the `occ_peak` high-water
    mark (called right after inject by every step impl)."""
    return stats.replace(occ_peak=jnp.maximum(stats.occ_peak,
                                              live_rows(state)))


def zero_stats(stats: SimStats) -> SimStats:
    """Warmup reset (shape/dtype-preserving, vmap/batch-safe).

    `occ_peak` survives the reset: it is a whole-run high-water mark —
    the compacted step's capacity certificate must cover warmup cycles
    too (an overflow during warmup corrupts the state the measured
    phase starts from)."""
    z = jax.tree.map(jnp.zeros_like, stats)
    return z.replace(occ_peak=stats.occ_peak)


def finalize(stats: SimStats, cfg, offered_per_chip: float, chips: float):
    """Raw (host) counters of ONE sweep lane -> a `SimResult`.

    Imported lazily to avoid a cycle: `simulator` is the facade over this
    package.
    """
    from ..simulator import SimResult
    st = jax.tree.map(np.asarray, stats)
    delivered = int(st.delivered)
    thr = delivered * cfg.pkt_len / cfg.measure / max(chips, 1e-9)
    lat = float(st.lat_sum) / max(delivered, 1)
    hops = {name: int(st.hops[i]) for i, name in enumerate(CH_TYPE_NAMES)}
    avg_hops = {k: v / max(delivered, 1) for k, v in hops.items()}
    return SimResult(
        offered_per_chip=offered_per_chip, throughput_per_chip=thr,
        avg_latency=lat, delivered_pkts=delivered,
        generated_pkts=int(st.generated), dropped_pkts=int(st.dropped),
        hops_by_type=hops, avg_hops_by_type=avg_hops,
        stranded_pkts=int(st.stranded),
        stranded_mean=float(st.stranded),
        reaped_pkts=int(st.reaped),
        occupancy_peak=int(st.occ_peak))
