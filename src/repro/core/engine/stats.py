"""Stats phase: delivered/latency/hop accumulators and the conversion of
raw counters into a `SimResult` (per sweep lane)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ..topology import CH_TYPE_NAMES, EJECT, NUM_CH_TYPES
from .arbitrate import Requests
from .state import SimStats


def accumulate(stats: SimStats, req: Requests, win, consts, t) -> SimStats:
    """Fold this cycle's granted movements into the accumulators."""
    w_ej = win & (req.otype == EJECT)
    delivered = stats.delivered + w_ej.sum()
    lat_sum = stats.lat_sum + jnp.where(w_ej, (t - req.itime), 0).sum()
    # dense one-hot instead of segment_sum: NUM_CH_TYPES is tiny and
    # segment ops lower to per-row scatter loops on CPU
    onehot = win[:, None] & (req.otype[:, None] == jnp.arange(NUM_CH_TYPES))
    hops = stats.hops + onehot.astype(jnp.int32).sum(0)
    # gauge, not a counter: head-of-line requests parked on the -1
    # non-channel THIS cycle (warm-fault strandings; arbitration never
    # grants them, so the last cycle's value is the population at exit)
    stranded = (req.valid & (req.out < 0)).sum().astype(jnp.int32)
    return stats.replace(delivered=delivered, lat_sum=lat_sum, hops=hops,
                         stranded=stranded)


def zero_stats(stats: SimStats) -> SimStats:
    """Warmup reset (shape/dtype-preserving, vmap/batch-safe)."""
    return jax.tree.map(jnp.zeros_like, stats)


def finalize(stats: SimStats, cfg, offered_per_chip: float, chips: float):
    """Raw (host) counters of ONE sweep lane -> a `SimResult`.

    Imported lazily to avoid a cycle: `simulator` is the facade over this
    package.
    """
    from ..simulator import SimResult
    st = jax.tree.map(np.asarray, stats)
    delivered = int(st.delivered)
    thr = delivered * cfg.pkt_len / cfg.measure / max(chips, 1e-9)
    lat = float(st.lat_sum) / max(delivered, 1)
    hops = {name: int(st.hops[i]) for i, name in enumerate(CH_TYPE_NAMES)}
    avg_hops = {k: v / max(delivered, 1) for k, v in hops.items()}
    return SimResult(
        offered_per_chip=offered_per_chip, throughput_per_chip=thr,
        avg_latency=lat, delivered_pkts=delivered,
        generated_pkts=int(st.generated), dropped_pkts=int(st.dropped),
        hops_by_type=hops, avg_hops_by_type=avg_hops,
        stranded_pkts=int(st.stranded))
