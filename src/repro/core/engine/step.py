"""One simulated cycle, wired from the phase modules:

    inject -> arbitrate (route + VC expansion + grant) -> apply -> stats

`make_step` returns a pure function `step(state, (t, key, rate_pkt, fl))`
whose carry is the pytree `SimState`; `fl` is the lane's fault data
(`state.build_lane`: alive masks + fault-dependent routing tables) — an
explicit traced argument rather than a closure constant, so the batched
sweep can vmap one compiled step over lanes with different fault sets.
`run_scan` advances one lane `cycles` times inside one jitted `lax.scan`,
donating the state so buffers are reused in place.  Both are
`vmap`-compatible over a leading batch axis (see `sweep.py`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..topology import Network
from ..traffic import as_pattern
from .apply import make_apply_fn
from .arbitrate import make_arbitrate_fn
from .inject import make_inject_fn
from .state import build_consts, resolve_epoch, resolve_reap_age
from .stats import accumulate, reap_mask, track_occ, zero_stats

# the valid `cfg.step_impl` values — the single source of truth
# (SimConfig and exp.RoutingSpec validate against this): "jnp" is the
# phase pipeline below (the oracle), "fused" the per-channel-winner
# restructuring in `fused.py` (bit-identical; the paper-scale fast
# path), "compact" the occupancy-compacted fused step (also fused.py:
# live rows compacted into a capacity-C active set before arbitration,
# bit-identical with a post-run capacity certificate — see
# `fused.make_compact_step` and the sweep's escalation ladder)
STEP_IMPLS = ("jnp", "fused", "compact")


def make_step(net: Network, cfg, pattern, inject_mask=None):
    """Returns (step, consts);
    step(state, (t, key, rate_pkt, fl)) -> (state, None).

    `pattern` may be a bare sampler or a normalized `TrafficPattern`
    pair; a pattern-borne inject mask (e.g. hotspot's hot-source mask)
    composes with the explicit `inject_mask` argument.

    When `fl` is epoch-stacked (a `FaultSchedule` lane, see
    `state.build_lane`), the step first resolves the traced epoch index
    from `t` and hands the phases that epoch's alive masks and routing
    tables — mid-run link death is the epoch index advancing, and every
    in-flight packet is re-routed on the surviving subgraph from the next
    cycle on (buffered packets are preserved, never dropped)."""
    impl = getattr(cfg, "step_impl", "jnp")
    if impl == "fused":
        from .fused import make_fused_step
        return make_fused_step(net, cfg, pattern, inject_mask)
    if impl == "compact":
        from .fused import make_compact_step
        return make_compact_step(net, cfg, pattern, inject_mask)
    if impl != "jnp":
        raise ValueError(f"unknown step_impl {impl!r}; "
                         f"valid: {STEP_IMPLS}")
    pattern, inject_mask = as_pattern(pattern, inject_mask)
    consts, route_kernel = build_consts(net, cfg)
    inject = make_inject_fn(net, cfg, consts, pattern, inject_mask)
    arbitrate = make_arbitrate_fn(net, cfg, consts, route_kernel)
    apply_moves = make_apply_fn(net, cfg, consts)
    # router-death reaper (trace-time: 0 compiles the pre-reaper step)
    reap_age = resolve_reap_age(cfg)

    def step(state, t_key_rate_fl):
        t, key, rate_pkt, fl = t_key_rate_fl
        fl = resolve_epoch(fl, t)
        state = inject(state, t, key, rate_pkt, fl)
        stats = track_occ(state.stats, state)
        req, win, won_ch = arbitrate(state, t, fl)
        alive = fl["ch_alive"]
        reap = (reap_mask(req, t, reap_age, alive)
                if reap_age else None)
        stats = accumulate(stats, req, win, consts, t, reap=reap,
                           ch_alive=alive if reap_age else None)
        state = apply_moves(state, req, win, won_ch, t, reap=reap)
        return state.replace(stats=stats), None

    return step, consts


@functools.partial(jax.jit, static_argnums=(0, 1, 2), donate_argnums=(3,))
def run_scan(step, cycles, reset_at, state0, rate_pkt, key, fl):
    """Advance one lane `cycles` steps; stats are zeroed after warmup."""

    def body(carry, t):
        state, key = carry
        key, sub = jax.random.split(key)
        state, _ = step(state, (t, sub, rate_pkt, fl))
        st = jax.lax.cond(t == reset_at, zero_stats, lambda s: s, state.stats)
        return (state.replace(stats=st), key), None

    (state, _), _ = jax.lax.scan(body, (state0, key), jnp.arange(cycles))
    return state
