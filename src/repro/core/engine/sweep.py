"""Batched load-latency sweeps: `jax.vmap` the engine step over a
(rate x seed) lane axis and run the WHOLE sweep in a single jitted
`lax.scan` — one compilation, one device dispatch per curve, instead of one
sequential `scan` per offered rate.

    sweep = BatchedSweep(net, cfg, pattern)
    grid = sweep.run(rates=[0.2, 0.4, ...], seeds=(0, 1))
    grid.result(i, j)            # SimResult for (rates[i], seeds[j])
    grid.mean_over_seeds()       # list[SimResult], one per rate
    grid.saturation_throughput() # scalar, seed-averaged

Lane (i, j) reproduces `Simulator.run(rates[i])` with `seed=seeds[j]`
bit-for-bit: the per-lane key chain is identical and `vmap` does not change
the per-lane math.

Fault grids: because the fault-dependent data (alive masks + routing
tables, `state.build_lane`) is an explicit step argument, lanes may carry
DIFFERENT fault sets — `run_faults` stacks one lane per (fault set, seed)
and runs a whole failure-rate x seed grid of degraded networks in the same
single compile (see benchmarks/bench_faults.py).

`run_lanes` is the fully general axis: every lane is an independent
(offered rate, seed, fault set) triple, so rate sweeps, seed replication,
and fault grids are all the same one-compile dispatch.  `run` and
`run_faults` are reshaping conveniences over it, and the declarative
experiment runner (`repro.exp.runner`) lowers every `ExperimentSpec` grid
to exactly one `run_lanes` call.

Device parallelism: lanes are independent, so with more than one device
(`REPRO_HOST_DEVICES=N` forces N XLA host devices on CPU; real TPU
backends need no flag) the lane axis is `shard_map`ped across the device
mesh — communication-free SPMD.  Lane counts that do not divide the
device count are padded with GHOST lanes (offered rate 0, dropped before
finalize), so the shard is always dense; each real lane's math is
untouched, keeping sharded runs bit-identical to single-device runs.
Grids too small to amortize the per-cycle shard_map dispatch (fewer than
`REPRO_SHARD_MIN_WORK` lane-cycles, default 4096) skip the lane shard
and run single-device — the chosen placement is recorded in
`SweepResult.placement` (and the perf-benchmark records).

Channel sharding (`REPRO_CHANNEL_SHARDS=K`, fused step only): the mesh
becomes 2-D ``(lanes, shards)`` — each lane's channel-id space is
block-partitioned across K shard devices and the step exchanges
per-channel grant minima / winner records at the phase boundary (see
`engine.fused`).  The big state arrays (`b_pkt`, `s_pkt`) partition on
their channel/terminal axis; everything else stays replicated across
the shard axis.  Ghost channel/terminal padding makes non-dividing
counts dense; `SweepResult.pad_fraction` reports the padded share of
the state so perf records can account for it.

Every dispatch goes through an AOT compile cache, which (a) makes the
compile-vs-run wall-time split exact (`SweepResult.compile_s` /
`wall_s`) and (b) lets `run_lanes_async` return before the result is
materialized, so the experiment runner can round-robin independent grid
cells across devices (see `repro.exp.runner`).
"""
from __future__ import annotations

import functools
import inspect
import time
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# shard_map moved out of jax.experimental (and its replication-check
# kwarg was renamed) across JAX releases; resolve whichever this
# installation has so the engine imports everywhere.
try:
    from jax import shard_map as _shard_map          # modern JAX
except ImportError:                                  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
_SHMAP_PARAMS = inspect.signature(_shard_map).parameters
_SHMAP_NOCHECK = ({"check_rep": False} if "check_rep" in _SHMAP_PARAMS
                  else {"check_vma": False} if "check_vma" in _SHMAP_PARAMS
                  else {})

from ... import env_int
from ..topology import (FaultSchedule, FaultSet, Network, as_fault_schedule,
                        compose_faults, final_faults)
from ..traffic import as_pattern
from .fused import fused_pad, grant_form, make_fused_step
from .state import build_lane, make_state, stack_lanes
from .stats import finalize, zero_stats
from .step import make_step

# Monotone count of batched-scan (re)traces.  `_scan_lanes` bumps it at
# TRACE time (Python side effects run once per compilation, never per
# execution), so a delta across a call counts exactly the compiles that
# call triggered — unlike the private `_cache_size` jit API, which is
# absent on some JAX versions and silently made
# `SweepResult.compile_count` lie as 0.
_TRACE_COUNT = [0]

# AOT executable cache: one compiled batched scan per (step closure,
# cycle budget, lane-shape signature, mesh/device placement).  Explicit
# AOT (`jit(...).lower(...).compile()`) instead of plain `jit` calls
# buys the exact compile-vs-run wall split and executables that can be
# dispatched without blocking (async cell round-robin).
_AOT_CACHE: dict = {}


def compile_counter() -> int:
    """Compilations of the batched scan so far in this process."""
    return _TRACE_COUNT[0]


def clear_aot_cache() -> None:
    """Drop the compiled-executable cache (tests / memory)."""
    _AOT_CACHE.clear()


def host_devices() -> list:
    """The devices the lane axis may spread over (all JAX devices)."""
    return jax.devices()


def shard_min_work() -> int:
    """Minimum (real lanes x cycles) for the automatic lane shard_map to
    pay for its per-cycle dispatch overhead; smaller grids run
    single-device.  Override with REPRO_SHARD_MIN_WORK (0 = always
    shard, as the sharding bit-identity tests do)."""
    return env_int("REPRO_SHARD_MIN_WORK", 4096)


def channel_shards() -> int:
    """Requested channel-shard count K (REPRO_CHANNEL_SHARDS, default 1).
    Only honored by fused-step (`cfg.step_impl="fused"`) dispatches with
    K devices available per lane row."""
    return max(env_int("REPRO_CHANNEL_SHARDS", 1), 1)


def lane_mesh(shards: int = 1) -> Mesh | None:
    """The device mesh for a dispatch: 1-D ``("lanes",)`` over the host
    devices, or 2-D ``("lanes", "shards")`` with `shards` > 1 (each lane
    row owns a K-device channel shard).  None when the process only has
    one device (the common un-forced CPU case)."""
    devs = host_devices()
    nd = len(devs)
    if nd <= 1:
        return None
    if shards > 1:
        if nd % shards:
            raise ValueError(
                f"REPRO_CHANNEL_SHARDS={shards} does not divide the "
                f"{nd} host devices")
        return Mesh(np.array(devs).reshape(nd // shards, shards),
                    ("lanes", "shards"))
    return Mesh(np.array(devs), ("lanes",))


def _key_chain(key, cycles: int):
    """The per-cycle subkeys of one lane, pre-generated outside the main
    scan: `key_{t+1}, sub_t = split(key_t)` — the exact chain the cycle
    loop used to compute inline, hoisted so the simulation scan body no
    longer interleaves a `vmap(split)` with the engine phases."""

    def split(k, _):
        k, sub = jax.random.split(k)
        return k, sub

    _, subs = jax.lax.scan(split, key, None, length=cycles)
    return subs                                            # [cycles, 2]


def _scan_lanes(step, cycles, reset_at, per_lane_faults,
                state0, rate_pkt, keys, lanes):
    """Advance B lanes in lockstep; state0/keys/rate_pkt carry axis 0 = B.

    `lanes` is the fault pytree (`build_lane`): lane-stacked ([B, ...],
    `per_lane_faults=True`) when the lanes model different degraded
    networks, or a single shared lane dict broadcast across the batch.
    """
    _TRACE_COUNT[0] += 1  # trace-time side effect == one compilation
    lane_axis = 0 if per_lane_faults else None
    subkeys = jax.vmap(_key_chain, in_axes=(0, None),
                       out_axes=1)(keys, cycles)           # [cycles, B, 2]

    def body(state, t_subs):
        t, subs = t_subs
        state, _ = jax.vmap(
            lambda s, k, r, f: step(s, (t, k, r, f)),
            in_axes=(0, 0, 0, lane_axis))(state, subs, rate_pkt, lanes)
        st = jax.lax.cond(t == reset_at, zero_stats, lambda s: s, state.stats)
        return state.replace(stats=st), None

    state, _ = jax.lax.scan(body, state0, (jnp.arange(cycles), subkeys))
    return state


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 7),
                   donate_argnums=(3,))
def run_scan_batched(step, cycles, reset_at, state0, rate_pkt, keys, lanes,
                     per_lane_faults: bool):
    """Single-device batched scan (kept as the stable public entry point;
    `BatchedSweep` itself dispatches through the AOT cache, which adds
    device sharding and the compile/run wall split)."""
    return _scan_lanes(step, cycles, reset_at, per_lane_faults,
                       state0, rate_pkt, keys, lanes)


def _make_dispatch_fn(step, cycles, reset_at, per_lane_faults, mesh,
                      state_spec=None):
    """The jittable whole-sweep function, `shard_map`ped over the lane
    axis when a mesh is given (lanes are independent: no collectives, so
    partitioning axis 0 is communication-free SPMD).  `state_spec` is a
    per-leaf PartitionSpec tree for the state (the 2-D channel-sharded
    mesh partitions `b_pkt`/`s_pkt` on their channel axis and replicates
    the rest across the shard axis); the default partitions every leaf
    on the lane axis only."""
    f = functools.partial(_scan_lanes, step, cycles, reset_at,
                          per_lane_faults)
    if mesh is not None:
        lane_spec = PartitionSpec("lanes")
        if state_spec is None:
            state_spec = lane_spec
        data_spec = lane_spec if per_lane_faults else PartitionSpec()
        f = _shard_map(f, mesh=mesh,
                       in_specs=(state_spec, lane_spec, lane_spec,
                                 data_spec),
                       out_specs=state_spec, **_SHMAP_NOCHECK)
    return jax.jit(f, donate_argnums=(0,))


def _sig(tree) -> tuple:
    """Hashable shape/dtype signature of a pytree (AOT cache key part)."""
    return (jax.tree.structure(tree),
            tuple((l.shape, str(l.dtype)) for l in jax.tree.leaves(tree)))


def offered_to_rate_pkt(offered_per_chip: float, cfg,
                        terms_per_chip: float) -> float:
    """Offered flits/cycle/chip -> per-terminal packet-generation rate.

    Shared by the facade `Simulator.run` and `BatchedSweep`; raises when the
    offered load would need more than one packet per terminal per cycle.
    """
    rate = offered_per_chip / cfg.pkt_len / terms_per_chip
    if rate > 1.0 + 1e-9:
        raise ValueError(
            f"offered {offered_per_chip}/chip needs per-terminal packet "
            f"rate {rate:.2f} > 1")
    return rate


class LaneRun(NamedTuple):
    """The outcome of one `run_lanes` dispatch."""

    results: list          # one SimResult per lane, in lane order
    wall_s: float          # execution wall time (compile excluded)
    compile_s: float       # trace + compile wall time (0.0 on cache hit)
    compile_count: int     # jit compilations this dispatch triggered
    fault_sets: list       # composed per-lane fault states (None=pristine)
    placement: str = "single"   # "single" | "lanes:L" | "lanes:L,shards:K"
    pad_fraction: float = 0.0   # ghost share of the dispatched state
    grant_form: str = "two_pass"   # "combined" | "two_pass" (see fused.py)


@dataclass
class SweepResult:
    """SimResults on the (rate x seed) grid, plus curve-level reductions.

    For fault sweeps (`BatchedSweep.run_faults`) the row axis is the fault
    grid instead of the rate grid: `rates[i]` repeats the common offered
    load and `fault_fracs[i]` labels row i with its failed-link fraction.

    `wall_s` is EXECUTION time only; trace + compile time is `compile_s`
    (0.0 when the dispatch was an executable-cache hit), so first-call
    timings no longer conflate the two.
    """

    rates: list[float]
    seeds: list[int]
    results: list[list]        # [num_rates][num_seeds] of SimResult
    compile_count: int = 0     # jit compilations this sweep triggered
    wall_s: float = 0.0
    compile_s: float = 0.0
    fault_fracs: list | None = None   # per-row failed-link fraction (faults)
    placement: str = "single"  # device placement the dispatch chose
    pad_fraction: float = 0.0  # ghost (lane + channel pad) state share
    # grant arbitration form the dispatch compiled: "combined" (the fused
    # step's single packed segment-min) or "two_pass" (the age-then-
    # priority oracle form — also what the fused step falls back to when
    # the packed key would overflow int32; `fused.grant_form` decides,
    # and the static spec pass reports/warns per scenario)
    grant_form: str = "two_pass"

    def result(self, rate_idx: int, seed_idx: int = 0):
        return self.results[rate_idx][seed_idx]

    def flat(self):
        return [r for row in self.results for r in row]

    def mean_over_seeds(self) -> list:
        """One seed-averaged SimResult per rate.

        Rates/latencies are means over the seed lanes; packet counters are
        floor-averaged (NOT summed) so they stay comparable to a single
        `Simulator.run`."""
        from ..simulator import SimResult
        out = []
        for row in self.results:
            n = len(row)
            hops = {k: sum(r.hops_by_type[k] for r in row) // n
                    for k in row[0].hops_by_type}
            avg_hops = {k: float(np.mean([r.avg_hops_by_type[k] for r in row]))
                        for k in row[0].avg_hops_by_type}
            out.append(SimResult(
                offered_per_chip=row[0].offered_per_chip,
                throughput_per_chip=float(
                    np.mean([r.throughput_per_chip for r in row])),
                avg_latency=float(np.mean([r.avg_latency for r in row])),
                delivered_pkts=sum(r.delivered_pkts for r in row) // n,
                generated_pkts=sum(r.generated_pkts for r in row) // n,
                dropped_pkts=sum(r.dropped_pkts for r in row) // n,
                hops_by_type=hops, avg_hops_by_type=avg_hops,
                stranded_pkts=sum(r.stranded_pkts for r in row) // n))
        return out

    def saturation_throughput(self) -> float:
        """Max seed-averaged accepted throughput over the sweep."""
        return max(r.throughput_per_chip for r in self.mean_over_seeds())


class _LanePlan:
    """A prepared, placed, and compiled — but not yet executed — lane
    dispatch (`BatchedSweep.warm_compile`).  Single-use: execution
    donates the plan's initial state buffer.  `compile_s` and
    `compile_count` are zero when the executable came from the AOT
    cache."""

    __slots__ = ("lane_triples", "fault_sets", "args", "compiled",
                 "compile_s", "compile_count", "placement",
                 "pad_fraction", "grant_form", "used")

    def __init__(self, lane_triples, fault_sets, args, compiled,
                 compile_s, compile_count, placement, pad_fraction,
                 grant_form):
        self.lane_triples = lane_triples
        self.fault_sets = fault_sets
        self.args = args
        self.compiled = compiled
        self.compile_s = compile_s
        self.compile_count = compile_count
        self.placement = placement
        self.pad_fraction = pad_fraction
        self.grant_form = grant_form
        self.used = False


class _PendingLanes:
    """A dispatched-but-unmaterialized `run_lanes` call.

    The compiled executable has been enqueued (JAX dispatch is async);
    `finish()` blocks on the device result and builds the per-lane
    `SimResult`s.  `wall_s` therefore measures dispatch -> materialized,
    which for overlapped (round-robined) cells includes time the device
    spent interleaved with other work.
    """

    def __init__(self, sweep, stats, num_lanes, lane_triples, fault_sets,
                 compile_s, compile_count, t0, placement, pad_fraction,
                 grant_form):
        self._sweep, self._stats = sweep, stats
        self._B, self._lanes = num_lanes, lane_triples
        self._fsets = fault_sets
        self._compile_s, self._compiles = compile_s, compile_count
        self._t0 = t0
        self._placement, self._pad_frac = placement, pad_fraction
        self._grant_form = grant_form

    def finish(self) -> LaneRun:
        stats = jax.tree.map(np.asarray, self._stats)      # blocks
        wall = time.perf_counter() - self._t0
        cfg = self._sweep.cfg
        pick = lambda i: jax.tree.map(lambda x: x[i], stats)
        results = [finalize(pick(i), cfg, self._lanes[i][0],
                            self._sweep._chips(self._fsets[i]))
                   for i in range(self._B)]     # ghost pad lanes excluded
        return LaneRun(results, wall, self._compile_s, self._compiles,
                       self._fsets, self._placement, self._pad_frac,
                       self._grant_form)


class BatchedSweep:
    """Compile-once sweep runner over a (rate x seed) lane grid.

    The step closure is shared with `Simulator` (same phases, same consts);
    `route_fn` and the traffic pattern only ever see per-lane data, so the
    whole cycle is batch-pure and legal to `vmap`.  `faults` degrades every
    lane with one fault set; `run_faults` runs a grid of different fault
    sets in one compile.
    """

    def __init__(self, net: Network, cfg, pattern, inject_mask=None,
                 step=None, consts=None, faults: FaultSet | None = None,
                 lane=None):
        self.net, self.cfg = net, cfg
        pattern = as_pattern(pattern, inject_mask)
        if step is None:
            step, consts = make_step(net, cfg, pattern)
        self.step, self.consts = step, consts
        self.NV = consts["NV"]
        self._pattern = pattern
        self._sharded_steps: dict[int, object] = {}
        self.faults = faults
        self.lane0 = build_lane(net, cfg, faults) if lane is None else lane
        self.terms_per_chip = net.num_terminals / net.num_chips
        self._inj_mask = (np.ones(net.num_terminals, dtype=bool)
                          if pattern.inject_mask is None
                          else np.asarray(pattern.inject_mask).astype(bool))

    def _rate_pkt(self, offered_per_chip: float) -> float:
        return offered_to_rate_pkt(offered_per_chip, self.cfg,
                                   self.terms_per_chip)

    def _sharded_step(self, K: int):
        """The K-way channel-sharded fused step (memoized: one build per
        shard count, so repeat dispatches hit the AOT cache)."""
        step = self._sharded_steps.get(K)
        if step is None:
            step, _ = make_fused_step(self.net, self.cfg, self._pattern,
                                      shards=K)
            self._sharded_steps[K] = step
        return step

    def _chips(self, faults) -> float:
        """Accepted-throughput divisor: chips weighted by the fraction of
        terminals that actually inject (mask AND alive).  A schedule
        reports its FINAL epoch — the steady-state degraded network."""
        faults = final_faults(faults)
        alive = (self._inj_mask if faults is None
                 else self._inj_mask & faults.term_alive(self.net))
        return self.net.num_chips * alive.sum() / self.net.num_terminals

    def _plan(self, lanes, device=None) -> "_LanePlan":
        """Prepare, place, and compile (cache-aware) ONE batched scan
        over the (ghost-padded) lane axis — without executing it.

        `device=None` shards lanes over the full device mesh (no-op with
        one device); an explicit `device` pins the whole dispatch there
        (the runner's cell round-robin).  The returned plan is
        single-use: executing it donates its initial state buffer.
        """
        lane_triples, lane_rates, lane_keys, lane_data, per_lane_faults, \
            fsets = self._prepare_lanes(lanes)
        cfg = self.cfg
        B = int(lane_rates.shape[0])
        cycles = cfg.warmup + cfg.measure
        fused = getattr(cfg, "step_impl", "jnp") == "fused"
        K = channel_shards() if (fused and device is None) else 1
        mesh = lane_mesh(K) if K > 1 else None
        if mesh is None:
            K = 1       # < K devices: channel sharding can't apply
            small = B * cycles < shard_min_work()
            if device is None and B > 1 and not small:
                mesh = lane_mesh()
        step = self._sharded_step(K) if K > 1 else self.step
        # the arbitration form this dispatch compiles: the oracle step IS
        # the two-pass form; the fused step picks per `fused.grant_form`
        gform = grant_form(self.net, cfg, K) if fused else "two_pass"
        ch_pad, term_pad = fused_pad(self.net, K) if K > 1 else (0, 0)
        nd = int(mesh.shape["lanes"]) if mesh is not None else 1
        pad = (-B) % nd
        if mesh is None:
            placement = "single"
        elif K > 1:
            placement = f"lanes:{nd},shards:{K}"
        else:
            placement = f"lanes:{nd}"
        E = self.net.num_channels
        pad_fraction = 1.0 - (B * E) / ((B + pad) * (E + ch_pad))
        if pad:
            # ghost lanes: offered rate 0 (inject generates nothing), any
            # valid key/fault data; their stats are never read back
            lane_rates = jnp.concatenate(
                [lane_rates, jnp.zeros((pad,), lane_rates.dtype)])
            lane_keys = jnp.concatenate(
                [lane_keys,
                 jnp.broadcast_to(lane_keys[:1],
                                  (pad,) + lane_keys.shape[1:])])
            if per_lane_faults:
                lane_data = jax.tree.map(
                    lambda x: jnp.concatenate(
                        [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])]),
                    lane_data)
        state0 = make_state(self.net, cfg, self.NV, batch=(B + pad,),
                            ch_pad=ch_pad, term_pad=term_pad)
        state_spec = None
        if K > 1:
            # 2-D placement: the big per-channel/per-terminal arrays
            # partition on their second axis, the rest replicates
            # across the shard axis
            state_spec = jax.tree.map(lambda _: PartitionSpec("lanes"),
                                      state0)
            state_spec = state_spec.replace(
                b_pkt=PartitionSpec("lanes", "shards"),
                s_pkt=PartitionSpec("lanes", "shards"))
        if mesh is not None:
            lane_sh = NamedSharding(mesh, PartitionSpec("lanes"))
            repl_sh = NamedSharding(mesh, PartitionSpec())
            if state_spec is None:
                state0 = jax.device_put(state0, lane_sh)
            else:
                # PartitionSpec subclasses tuple, so the spec tree can't
                # be tree-mapped over — build a NamedSharding-leaf tree
                sh_tree = jax.tree.map(lambda _: lane_sh, state0)
                sh_tree = sh_tree.replace(
                    b_pkt=NamedSharding(
                        mesh, PartitionSpec("lanes", "shards")),
                    s_pkt=NamedSharding(
                        mesh, PartitionSpec("lanes", "shards")))
                state0 = jax.tree.map(jax.device_put, state0, sh_tree)
            lane_rates = jax.device_put(lane_rates, lane_sh)
            lane_keys = jax.device_put(lane_keys, lane_sh)
            lane_data = jax.device_put(
                lane_data, lane_sh if per_lane_faults else repl_sh)
        elif device is not None:
            state0, lane_rates, lane_keys, lane_data = jax.device_put(
                (state0, lane_rates, lane_keys, lane_data), device)
        cache_key = (step, cycles, cfg.warmup, per_lane_faults, mesh,
                     device, _sig((state0, lane_rates, lane_keys,
                                   lane_data)))
        compiled = _AOT_CACHE.get(cache_key)
        compile_s = 0.0
        compiles = 0
        if compiled is None:
            fn = _make_dispatch_fn(step, cycles, cfg.warmup,
                                   per_lane_faults, mesh, state_spec)
            before = _TRACE_COUNT[0]
            t0 = time.perf_counter()
            compiled = fn.lower(state0, lane_rates, lane_keys,
                                lane_data).compile()
            compile_s = time.perf_counter() - t0
            compiles = _TRACE_COUNT[0] - before
            _AOT_CACHE[cache_key] = compiled
        return _LanePlan(lane_triples, fsets,
                         (state0, lane_rates, lane_keys, lane_data),
                         compiled, compile_s, compiles, placement,
                         pad_fraction, gform)

    def _prepare_lanes(self, lanes):
        """Compose/sample per-lane fault data; returns the dense lane
        arrays plus the composed fault states."""
        cfg = self.cfg
        lanes = list(lanes)
        if not lanes:
            raise ValueError("run_lanes needs >= 1 lane")
        base = self.faults
        fsets = [compose_faults(base, f) for _, _, f in lanes]
        if any(isinstance(f, FaultSchedule) for f in fsets):
            fsets = [as_fault_schedule(f) for f in fsets]
        lane_rates = jnp.asarray([self._rate_pkt(r) for r, _, _ in lanes],
                                 dtype=jnp.float32)
        lane_keys = jnp.stack(
            [jax.random.PRNGKey(int(s)) for _, s, _ in lanes])
        if len(set(fsets)) == 1:
            lane_data = (self.lane0 if fsets[0] == base
                         else build_lane(self.net, cfg, fsets[0]))
            per_lane = False
        else:
            # FaultSet is frozen/hashable: build each distinct lane once
            # even when many lanes share one fault set
            memo = {}
            for f in fsets:
                if f not in memo:
                    memo[f] = build_lane(self.net, cfg, f)
            lane_data = stack_lanes([memo[f] for f in fsets])
            per_lane = True
        return lanes, lane_rates, lane_keys, lane_data, per_lane, fsets

    def warm_compile(self, lanes, device=None) -> "_LanePlan":
        """Prepare and compile the lane grid without executing it.

        The experiment runner warms EVERY cell before dispatching any
        execution, so a round-robined cell's wall_s window never
        contains another cell's host-blocking compilation; the returned
        plan is then handed back to `run_lanes_async(plan=...)`, reusing
        the prepared lane arrays (no second fault-table build)."""
        return self._plan(lanes, device=device)

    def run_lanes_async(self, lanes=None, device=None,
                        plan: "_LanePlan | None" = None) -> _PendingLanes:
        """Dispatch the lane grid without blocking on the result.

        Compilation (cache-miss only) still blocks the host, but the
        execution is enqueued asynchronously — the caller can dispatch
        further independent grids (e.g. on other devices) and `finish()`
        them in order.  `device` pins the whole grid to one device
        instead of sharding it over the mesh; `plan` executes an
        already-warm `warm_compile` plan instead of preparing anew."""
        if plan is None:
            plan = self._plan(lanes, device=device)
        if plan.used:
            raise ValueError(
                "a lane plan is single-use: its initial state buffer is "
                "donated at execution — warm_compile a fresh one")
        plan.used = True
        t0 = time.perf_counter()
        state = plan.compiled(*plan.args)
        plan.args = None      # the donated state buffer is gone anyway
        return _PendingLanes(self, state.stats, len(plan.lane_triples),
                             plan.lane_triples, plan.fault_sets,
                             plan.compile_s, plan.compile_count, t0,
                             plan.placement, plan.pad_fraction,
                             plan.grant_form)

    def run_lanes(self, lanes, device=None) -> LaneRun:
        """The fully general lane axis: one compiled batched scan over an
        arbitrary list of `(offered_per_chip, seed, faults)` lane triples,
        where `faults` is a `FaultSet`, a warm `FaultSchedule`, or None.

        Each lane's fault state COMPOSES on top of the sweep's base
        `faults` (`None` means "just the base faults").  When any lane
        carries a `FaultSchedule`, EVERY lane is promoted to a schedule
        (cold sets become single-epoch schedules) so the lane pytrees
        share one epoch-stacked structure — a mixed warm/cold
        (rates x seeds x schedules) grid still stacks into one dense
        batch.  When every composed lane ends up with the same fault state
        the shared-lane fast path is used (the fault pytree broadcasts
        instead of stacking), otherwise each distinct state builds its
        lane tables once and the step vmaps over the stacked lane axis —
        either way ONE dispatch, at most one jit compile.

        With multiple devices the lane axis is `shard_map`ped across
        them (ghost-padded to a device multiple); results stay lane-for-
        lane bit-identical to the single-device run.

        Returns a `LaneRun` (`results` one `SimResult` per lane in
        order, the compile/run wall split, and the composed per-lane
        fault states).
        """
        return self.run_lanes_async(lanes, device=device).finish()

    def run(self, rates, seeds=None) -> SweepResult:
        cfg = self.cfg
        rates = [float(r) for r in rates]
        seeds = [cfg.seed] if seeds is None else [int(s) for s in seeds]
        R, S = len(rates), len(seeds)
        if R * S == 0:
            raise ValueError(
                f"sweep needs >= 1 rate and >= 1 seed (got {R} rates, "
                f"{S} seeds)")
        run = self.run_lanes([(r, s, None) for r in rates for s in seeds])
        flat = run.results
        results = [[flat[i * S + j] for j in range(S)] for i in range(R)]
        return SweepResult(rates=rates, seeds=seeds, results=results,
                           compile_count=run.compile_count,
                           wall_s=run.wall_s, compile_s=run.compile_s,
                           placement=run.placement,
                           pad_fraction=run.pad_fraction,
                           grant_form=run.grant_form)

    def run_faults(self, offered_per_chip: float, fault_grid,
                   seeds=None) -> SweepResult:
        """Degraded-throughput grid: one lane per (fault set, seed), all at
        the same offered load, in ONE compiled batched scan.

        `fault_grid` is a list of rows; row i is either one `FaultSet` /
        warm `FaultSchedule` (shared by every seed lane of that row) or a
        per-seed list `[FaultSet | FaultSchedule, ...]` (e.g.
        independently sampled failures per seed).  Rows map to
        `SweepResult.results` rows; `fault_fracs[i]` records row i's mean
        failed-link fraction (a schedule reports its final epoch).

        When the sweep itself was constructed with `faults`, every grid
        entry COMPOSES on top of that base set (an empty-FaultSet row
        means "just the base faults", not "pristine"); an invalid
        composition raises from `validate_faults`.
        """
        cfg = self.cfg
        seeds = [cfg.seed] if seeds is None else [int(s) for s in seeds]
        S = len(seeds)
        rows = [list(fs) if isinstance(fs, (list, tuple)) else [fs] * S
                for fs in fault_grid]
        if not rows or any(len(r) != S for r in rows):
            raise ValueError("fault_grid rows must match the seed count")
        F = len(rows)
        run = self.run_lanes(
            [(offered_per_chip, seeds[j], rows[i][j])
             for i in range(F) for j in range(S)])
        flat, fsets = run.results, run.fault_sets
        results = [[flat[i * S + j] for j in range(S)] for i in range(F)]
        fracs = [float(np.mean(
            [0.0 if f is None
             else final_faults(f).frac_links_failed(self.net)
             for f in fsets[i * S:(i + 1) * S]])) for i in range(F)]
        return SweepResult(rates=[offered_per_chip] * F, seeds=seeds,
                           results=results, compile_count=run.compile_count,
                           wall_s=run.wall_s, compile_s=run.compile_s,
                           fault_fracs=fracs, placement=run.placement,
                           pad_fraction=run.pad_fraction,
                           grant_form=run.grant_form)
