"""Batched load-latency sweeps: `jax.vmap` the engine step over a
(rate x seed) lane axis and run the WHOLE sweep in a single jitted
`lax.scan` — one compilation, one device dispatch per curve, instead of one
sequential `scan` per offered rate.

    sweep = BatchedSweep(net, cfg, pattern)
    grid = sweep.run(rates=[0.2, 0.4, ...], seeds=(0, 1))
    grid.result(i, j)            # SimResult for (rates[i], seeds[j])
    grid.mean_over_seeds()       # list[SimResult], one per rate
    grid.saturation_throughput() # scalar, seed-averaged

Lane (i, j) reproduces `Simulator.run(rates[i])` with `seed=seeds[j]`
bit-for-bit: the per-lane key chain is identical and `vmap` does not change
the per-lane math.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from ..topology import Network
from .state import make_state
from .stats import finalize, zero_stats
from .step import make_step


@functools.partial(jax.jit, static_argnums=(0, 1, 2), donate_argnums=(3,))
def run_scan_batched(step, cycles, reset_at, state0, rate_pkt, keys):
    """Advance B lanes in lockstep; state0/keys/rate_pkt carry axis 0 = B."""

    def body(carry, t):
        state, keys = carry
        splits = jax.vmap(jax.random.split)(keys)          # [B, 2, 2]
        keys, subs = splits[:, 0], splits[:, 1]
        state, _ = jax.vmap(
            lambda s, k, r: step(s, (t, k, r)))(state, subs, rate_pkt)
        st = jax.lax.cond(t == reset_at, zero_stats, lambda s: s, state.stats)
        return (state.replace(stats=st), keys), None

    (state, _), _ = jax.lax.scan(body, (state0, keys), jnp.arange(cycles))
    return state


def offered_to_rate_pkt(offered_per_chip: float, cfg,
                        terms_per_chip: float) -> float:
    """Offered flits/cycle/chip -> per-terminal packet-generation rate.

    Shared by the facade `Simulator.run` and `BatchedSweep`; raises when the
    offered load would need more than one packet per terminal per cycle.
    """
    rate = offered_per_chip / cfg.pkt_len / terms_per_chip
    if rate > 1.0 + 1e-9:
        raise ValueError(
            f"offered {offered_per_chip}/chip needs per-terminal packet "
            f"rate {rate:.2f} > 1")
    return rate


def _jit_cache_size() -> int:
    """Entry count of run_scan_batched's jit cache (0 if the private JAX
    introspection API is unavailable)."""
    try:
        return run_scan_batched._cache_size()
    except AttributeError:
        return 0


@dataclass
class SweepResult:
    """SimResults on the (rate x seed) grid, plus curve-level reductions."""

    rates: list[float]
    seeds: list[int]
    results: list[list]        # [num_rates][num_seeds] of SimResult
    compile_count: int = 0     # jit compilations this sweep triggered
    wall_s: float = 0.0

    def result(self, rate_idx: int, seed_idx: int = 0):
        return self.results[rate_idx][seed_idx]

    def flat(self):
        return [r for row in self.results for r in row]

    def mean_over_seeds(self) -> list:
        """One seed-averaged SimResult per rate.

        Rates/latencies are means over the seed lanes; packet counters are
        floor-averaged (NOT summed) so they stay comparable to a single
        `Simulator.run`."""
        from ..simulator import SimResult
        out = []
        for row in self.results:
            n = len(row)
            hops = {k: sum(r.hops_by_type[k] for r in row) // n
                    for k in row[0].hops_by_type}
            avg_hops = {k: float(np.mean([r.avg_hops_by_type[k] for r in row]))
                        for k in row[0].avg_hops_by_type}
            out.append(SimResult(
                offered_per_chip=row[0].offered_per_chip,
                throughput_per_chip=float(
                    np.mean([r.throughput_per_chip for r in row])),
                avg_latency=float(np.mean([r.avg_latency for r in row])),
                delivered_pkts=sum(r.delivered_pkts for r in row) // n,
                generated_pkts=sum(r.generated_pkts for r in row) // n,
                dropped_pkts=sum(r.dropped_pkts for r in row) // n,
                hops_by_type=hops, avg_hops_by_type=avg_hops))
        return out

    def saturation_throughput(self) -> float:
        """Max seed-averaged accepted throughput over the sweep."""
        return max(r.throughput_per_chip for r in self.mean_over_seeds())


class BatchedSweep:
    """Compile-once sweep runner over a (rate x seed) lane grid.

    The step closure is shared with `Simulator` (same phases, same consts);
    `route_fn` and the traffic pattern only ever see per-lane data, so the
    whole cycle is batch-pure and legal to `vmap`.
    """

    def __init__(self, net: Network, cfg, pattern, inject_mask=None,
                 step=None, consts=None):
        self.net, self.cfg = net, cfg
        if step is None:
            step, consts = make_step(net, cfg, pattern, inject_mask)
        self.step, self.consts = step, consts
        self.NV = consts["NV"]
        self.terms_per_chip = net.num_terminals / net.num_chips
        n_inj = (int(np.asarray(inject_mask).sum()) if inject_mask is not None
                 else net.num_terminals)
        self._inj_frac = n_inj / net.num_terminals

    def _rate_pkt(self, offered_per_chip: float) -> float:
        return offered_to_rate_pkt(offered_per_chip, self.cfg,
                                   self.terms_per_chip)

    @staticmethod
    def _lane_sharding(B: int):
        """NamedSharding splitting the lane axis over host devices (or None).

        Lanes are independent, so partitioning axis 0 is communication-free
        SPMD: with `--xla_force_host_platform_device_count=N` (or real
        multi-device backends) the whole sweep parallelizes across cores.
        """
        devs = jax.devices()
        if len(devs) <= 1 or B % len(devs) != 0:
            return None
        from jax.sharding import Mesh, NamedSharding, PartitionSpec
        mesh = Mesh(np.array(devs), ("lanes",))
        return NamedSharding(mesh, PartitionSpec("lanes"))

    def run(self, rates, seeds=None) -> SweepResult:
        import time
        cfg = self.cfg
        rates = [float(r) for r in rates]
        seeds = [cfg.seed] if seeds is None else [int(s) for s in seeds]
        R, S = len(rates), len(seeds)
        B = R * S
        if B == 0:
            raise ValueError(
                f"sweep needs >= 1 rate and >= 1 seed (got {R} rates, "
                f"{S} seeds)")
        lane_rates = jnp.asarray(
            [self._rate_pkt(r) for r in rates for _ in seeds],
            dtype=jnp.float32)
        lane_keys = jnp.stack(
            [jax.random.PRNGKey(s) for _ in rates for s in seeds])
        state0 = make_state(self.net, cfg, self.NV, batch=(B,))
        sharding = self._lane_sharding(B)
        if sharding is not None:
            state0 = jax.device_put(state0, sharding)
            lane_rates = jax.device_put(lane_rates, sharding)
            lane_keys = jax.device_put(lane_keys, sharding)
        cycles = cfg.warmup + cfg.measure
        misses0 = _jit_cache_size()
        t0 = time.perf_counter()
        state = run_scan_batched(self.step, cycles, cfg.warmup,
                                 state0, lane_rates, lane_keys)
        stats = jax.tree.map(np.asarray, state.stats)
        wall = time.perf_counter() - t0
        compiles = _jit_cache_size() - misses0
        chips = self.net.num_chips * self._inj_frac
        lane = lambda i: jax.tree.map(lambda x: x[i], stats)
        results = [[finalize(lane(i * S + j), cfg, rates[i], chips)
                    for j in range(S)] for i in range(R)]
        return SweepResult(rates=rates, seeds=seeds, results=results,
                           compile_count=compiles, wall_s=wall)
