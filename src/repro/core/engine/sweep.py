"""Batched load-latency sweeps: `jax.vmap` the engine step over a
(rate x seed) lane axis and run the WHOLE sweep in a single jitted
`lax.scan` — one compilation, one device dispatch per curve, instead of one
sequential `scan` per offered rate.

    sweep = BatchedSweep(net, cfg, pattern)
    grid = sweep.run(rates=[0.2, 0.4, ...], seeds=(0, 1))
    grid.result(i, j)            # SimResult for (rates[i], seeds[j])
    grid.mean_over_seeds()       # list[SimResult], one per rate
    grid.saturation_throughput() # scalar, seed-averaged

Lane (i, j) reproduces `Simulator.run(rates[i])` with `seed=seeds[j]`
bit-for-bit: the per-lane key chain is identical and `vmap` does not change
the per-lane math.

Fault grids: because the fault-dependent data (alive masks + routing
tables, `state.build_lane`) is an explicit step argument, lanes may carry
DIFFERENT fault sets — `run_faults` stacks one lane per (fault set, seed)
and runs a whole failure-rate x seed grid of degraded networks in the same
single compile (see benchmarks/bench_faults.py).

`run_lanes` is the fully general axis: every lane is an independent
(offered rate, seed, fault set) triple, so rate sweeps, seed replication,
and fault grids are all the same one-compile dispatch.  `run` and
`run_faults` are reshaping conveniences over it, and the declarative
experiment runner (`repro.exp.runner`) lowers every `ExperimentSpec` grid
to exactly one `run_lanes` call.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from ..topology import (FaultSchedule, FaultSet, Network, as_fault_schedule,
                        compose_faults, final_faults)
from ..traffic import as_pattern
from .state import build_lane, make_state, stack_lanes
from .stats import finalize, zero_stats
from .step import make_step

# Monotone count of `run_scan_batched` (re)traces.  The body below bumps it
# at TRACE time (Python side effects run once per jit compilation, never per
# execution), so a delta across a call counts exactly the compiles that call
# triggered — unlike the private `_cache_size` jit API, which is absent on
# some JAX versions and silently made `SweepResult.compile_count` lie as 0.
_TRACE_COUNT = [0]


def compile_counter() -> int:
    """Compilations of `run_scan_batched` so far in this process."""
    return _TRACE_COUNT[0]


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 7),
                   donate_argnums=(3,))
def run_scan_batched(step, cycles, reset_at, state0, rate_pkt, keys, lanes,
                     per_lane_faults: bool):
    """Advance B lanes in lockstep; state0/keys/rate_pkt carry axis 0 = B.

    `lanes` is the fault pytree (`build_lane`): lane-stacked ([B, ...],
    `per_lane_faults=True`) when the lanes model different degraded
    networks, or a single shared lane dict broadcast across the batch.
    """
    _TRACE_COUNT[0] += 1  # trace-time side effect == one jit compilation
    lane_axis = 0 if per_lane_faults else None

    def body(carry, t):
        state, keys = carry
        splits = jax.vmap(jax.random.split)(keys)          # [B, 2, 2]
        keys, subs = splits[:, 0], splits[:, 1]
        state, _ = jax.vmap(
            lambda s, k, r, f: step(s, (t, k, r, f)),
            in_axes=(0, 0, 0, lane_axis))(state, subs, rate_pkt, lanes)
        st = jax.lax.cond(t == reset_at, zero_stats, lambda s: s, state.stats)
        return (state.replace(stats=st), keys), None

    (state, _), _ = jax.lax.scan(body, (state0, keys), jnp.arange(cycles))
    return state


def offered_to_rate_pkt(offered_per_chip: float, cfg,
                        terms_per_chip: float) -> float:
    """Offered flits/cycle/chip -> per-terminal packet-generation rate.

    Shared by the facade `Simulator.run` and `BatchedSweep`; raises when the
    offered load would need more than one packet per terminal per cycle.
    """
    rate = offered_per_chip / cfg.pkt_len / terms_per_chip
    if rate > 1.0 + 1e-9:
        raise ValueError(
            f"offered {offered_per_chip}/chip needs per-terminal packet "
            f"rate {rate:.2f} > 1")
    return rate


@dataclass
class SweepResult:
    """SimResults on the (rate x seed) grid, plus curve-level reductions.

    For fault sweeps (`BatchedSweep.run_faults`) the row axis is the fault
    grid instead of the rate grid: `rates[i]` repeats the common offered
    load and `fault_fracs[i]` labels row i with its failed-link fraction.
    """

    rates: list[float]
    seeds: list[int]
    results: list[list]        # [num_rates][num_seeds] of SimResult
    compile_count: int = 0     # jit compilations this sweep triggered
    wall_s: float = 0.0
    fault_fracs: list | None = None   # per-row failed-link fraction (faults)

    def result(self, rate_idx: int, seed_idx: int = 0):
        return self.results[rate_idx][seed_idx]

    def flat(self):
        return [r for row in self.results for r in row]

    def mean_over_seeds(self) -> list:
        """One seed-averaged SimResult per rate.

        Rates/latencies are means over the seed lanes; packet counters are
        floor-averaged (NOT summed) so they stay comparable to a single
        `Simulator.run`."""
        from ..simulator import SimResult
        out = []
        for row in self.results:
            n = len(row)
            hops = {k: sum(r.hops_by_type[k] for r in row) // n
                    for k in row[0].hops_by_type}
            avg_hops = {k: float(np.mean([r.avg_hops_by_type[k] for r in row]))
                        for k in row[0].avg_hops_by_type}
            out.append(SimResult(
                offered_per_chip=row[0].offered_per_chip,
                throughput_per_chip=float(
                    np.mean([r.throughput_per_chip for r in row])),
                avg_latency=float(np.mean([r.avg_latency for r in row])),
                delivered_pkts=sum(r.delivered_pkts for r in row) // n,
                generated_pkts=sum(r.generated_pkts for r in row) // n,
                dropped_pkts=sum(r.dropped_pkts for r in row) // n,
                hops_by_type=hops, avg_hops_by_type=avg_hops))
        return out

    def saturation_throughput(self) -> float:
        """Max seed-averaged accepted throughput over the sweep."""
        return max(r.throughput_per_chip for r in self.mean_over_seeds())


class BatchedSweep:
    """Compile-once sweep runner over a (rate x seed) lane grid.

    The step closure is shared with `Simulator` (same phases, same consts);
    `route_fn` and the traffic pattern only ever see per-lane data, so the
    whole cycle is batch-pure and legal to `vmap`.  `faults` degrades every
    lane with one fault set; `run_faults` runs a grid of different fault
    sets in one compile.
    """

    def __init__(self, net: Network, cfg, pattern, inject_mask=None,
                 step=None, consts=None, faults: FaultSet | None = None,
                 lane=None):
        self.net, self.cfg = net, cfg
        pattern = as_pattern(pattern, inject_mask)
        if step is None:
            step, consts = make_step(net, cfg, pattern)
        self.step, self.consts = step, consts
        self.NV = consts["NV"]
        self.faults = faults
        self.lane0 = build_lane(net, cfg, faults) if lane is None else lane
        self.terms_per_chip = net.num_terminals / net.num_chips
        self._inj_mask = (np.ones(net.num_terminals, dtype=bool)
                          if pattern.inject_mask is None
                          else np.asarray(pattern.inject_mask).astype(bool))

    def _rate_pkt(self, offered_per_chip: float) -> float:
        return offered_to_rate_pkt(offered_per_chip, self.cfg,
                                   self.terms_per_chip)

    def _chips(self, faults) -> float:
        """Accepted-throughput divisor: chips weighted by the fraction of
        terminals that actually inject (mask AND alive).  A schedule
        reports its FINAL epoch — the steady-state degraded network."""
        faults = final_faults(faults)
        alive = (self._inj_mask if faults is None
                 else self._inj_mask & faults.term_alive(self.net))
        return self.net.num_chips * alive.sum() / self.net.num_terminals

    @staticmethod
    def _lane_sharding(B: int):
        """NamedSharding splitting the lane axis over host devices (or None).

        Lanes are independent, so partitioning axis 0 is communication-free
        SPMD: with `--xla_force_host_platform_device_count=N` (or real
        multi-device backends) the whole sweep parallelizes across cores.
        """
        devs = jax.devices()
        if len(devs) <= 1 or B % len(devs) != 0:
            return None
        from jax.sharding import Mesh, NamedSharding, PartitionSpec
        mesh = Mesh(np.array(devs), ("lanes",))
        return NamedSharding(mesh, PartitionSpec("lanes"))

    def _run_lanes(self, lane_rates, lane_keys, lanes, per_lane_faults):
        """One `run_scan_batched` dispatch; returns (stats [B], wall_s,
        compiles)."""
        import time
        cfg = self.cfg
        B = len(lane_rates)
        state0 = make_state(self.net, cfg, self.NV, batch=(B,))
        sharding = self._lane_sharding(B)
        if sharding is not None:
            state0 = jax.device_put(state0, sharding)
            lane_rates = jax.device_put(lane_rates, sharding)
            lane_keys = jax.device_put(lane_keys, sharding)
            if per_lane_faults:
                lanes = jax.device_put(lanes, sharding)
        cycles = cfg.warmup + cfg.measure
        compiles0 = compile_counter()
        t0 = time.perf_counter()
        state = run_scan_batched(self.step, cycles, cfg.warmup,
                                 state0, lane_rates, lane_keys, lanes,
                                 per_lane_faults)
        stats = jax.tree.map(np.asarray, state.stats)
        wall = time.perf_counter() - t0
        return stats, wall, compile_counter() - compiles0

    def run_lanes(self, lanes):
        """The fully general lane axis: one compiled batched scan over an
        arbitrary list of `(offered_per_chip, seed, faults)` lane triples,
        where `faults` is a `FaultSet`, a warm `FaultSchedule`, or None.

        Each lane's fault state COMPOSES on top of the sweep's base
        `faults` (`None` means "just the base faults").  When any lane
        carries a `FaultSchedule`, EVERY lane is promoted to a schedule
        (cold sets become single-epoch schedules) so the lane pytrees
        share one epoch-stacked structure — a mixed warm/cold
        (rates x seeds x schedules) grid still stacks into one dense
        batch.  When every composed lane ends up with the same fault state
        the shared-lane fast path is used (the fault pytree broadcasts
        instead of stacking), otherwise each distinct state builds its
        lane tables once and the step vmaps over the stacked lane axis —
        either way ONE `run_scan_batched` dispatch, at most one jit
        compile.

        Returns `(results, wall_s, compiles, fault_sets)` where `results`
        is one `SimResult` per lane (in order) and `fault_sets` holds the
        composed per-lane fault states (None = pristine).
        """
        cfg = self.cfg
        lanes = list(lanes)
        if not lanes:
            raise ValueError("run_lanes needs >= 1 lane")
        base = self.faults
        fsets = [compose_faults(base, f) for _, _, f in lanes]
        if any(isinstance(f, FaultSchedule) for f in fsets):
            fsets = [as_fault_schedule(f) for f in fsets]
        lane_rates = jnp.asarray([self._rate_pkt(r) for r, _, _ in lanes],
                                 dtype=jnp.float32)
        lane_keys = jnp.stack(
            [jax.random.PRNGKey(int(s)) for _, s, _ in lanes])
        if len(set(fsets)) == 1:
            lane_data = (self.lane0 if fsets[0] == base
                         else build_lane(self.net, cfg, fsets[0]))
            per_lane = False
        else:
            # FaultSet is frozen/hashable: build each distinct lane once
            # even when many lanes share one fault set
            memo = {}
            for f in fsets:
                if f not in memo:
                    memo[f] = build_lane(self.net, cfg, f)
            lane_data = stack_lanes([memo[f] for f in fsets])
            per_lane = True
        stats, wall, compiles = self._run_lanes(
            lane_rates, lane_keys, lane_data, per_lane_faults=per_lane)
        pick = lambda i: jax.tree.map(lambda x: x[i], stats)
        results = [finalize(pick(i), cfg, lanes[i][0], self._chips(fsets[i]))
                   for i in range(len(lanes))]
        return results, wall, compiles, fsets

    def run(self, rates, seeds=None) -> SweepResult:
        cfg = self.cfg
        rates = [float(r) for r in rates]
        seeds = [cfg.seed] if seeds is None else [int(s) for s in seeds]
        R, S = len(rates), len(seeds)
        if R * S == 0:
            raise ValueError(
                f"sweep needs >= 1 rate and >= 1 seed (got {R} rates, "
                f"{S} seeds)")
        flat, wall, compiles, _ = self.run_lanes(
            [(r, s, None) for r in rates for s in seeds])
        results = [[flat[i * S + j] for j in range(S)] for i in range(R)]
        return SweepResult(rates=rates, seeds=seeds, results=results,
                           compile_count=compiles, wall_s=wall)

    def run_faults(self, offered_per_chip: float, fault_grid,
                   seeds=None) -> SweepResult:
        """Degraded-throughput grid: one lane per (fault set, seed), all at
        the same offered load, in ONE compiled batched scan.

        `fault_grid` is a list of rows; row i is either one `FaultSet` /
        warm `FaultSchedule` (shared by every seed lane of that row) or a
        per-seed list `[FaultSet | FaultSchedule, ...]` (e.g.
        independently sampled failures per seed).  Rows map to
        `SweepResult.results` rows; `fault_fracs[i]` records row i's mean
        failed-link fraction (a schedule reports its final epoch).

        When the sweep itself was constructed with `faults`, every grid
        entry COMPOSES on top of that base set (an empty-FaultSet row
        means "just the base faults", not "pristine"); an invalid
        composition raises from `validate_faults`.
        """
        cfg = self.cfg
        seeds = [cfg.seed] if seeds is None else [int(s) for s in seeds]
        S = len(seeds)
        rows = [list(fs) if isinstance(fs, (list, tuple)) else [fs] * S
                for fs in fault_grid]
        if not rows or any(len(r) != S for r in rows):
            raise ValueError("fault_grid rows must match the seed count")
        F = len(rows)
        flat, wall, compiles, fsets = self.run_lanes(
            [(offered_per_chip, seeds[j], rows[i][j])
             for i in range(F) for j in range(S)])
        results = [[flat[i * S + j] for j in range(S)] for i in range(F)]
        fracs = [float(np.mean(
            [0.0 if f is None
             else final_faults(f).frac_links_failed(self.net)
             for f in fsets[i * S:(i + 1) * S]])) for i in range(F)]
        return SweepResult(rates=[offered_per_chip] * F, seeds=seeds,
                           results=results, compile_count=compiles,
                           wall_s=wall, fault_fracs=fracs)
