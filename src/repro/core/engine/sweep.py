"""Batched load-latency sweeps: `jax.vmap` the engine step over a
(rate x seed) lane axis and run the WHOLE sweep in a single jitted
`lax.scan` — one compilation, one device dispatch per curve, instead of one
sequential `scan` per offered rate.

    sweep = BatchedSweep(net, cfg, pattern)
    grid = sweep.run(rates=[0.2, 0.4, ...], seeds=(0, 1))
    grid.result(i, j)            # SimResult for (rates[i], seeds[j])
    grid.mean_over_seeds()       # list[SimResult], one per rate
    grid.saturation_throughput() # scalar, seed-averaged

Lane (i, j) reproduces `Simulator.run(rates[i])` with `seed=seeds[j]`
bit-for-bit: the per-lane key chain is identical and `vmap` does not change
the per-lane math.

Fault grids: because the fault-dependent data (alive masks + routing
tables, `state.build_lane`) is an explicit step argument, lanes may carry
DIFFERENT fault sets — `run_faults` stacks one lane per (fault set, seed)
and runs a whole failure-rate x seed grid of degraded networks in the same
single compile (see benchmarks/bench_faults.py).

`run_lanes` is the fully general axis: every lane is an independent
(offered rate, seed, fault set) triple, so rate sweeps, seed replication,
and fault grids are all the same one-compile dispatch.  `run` and
`run_faults` are reshaping conveniences over it, and the declarative
experiment runner (`repro.exp.runner`) lowers every `ExperimentSpec` grid
to exactly one `run_lanes` call.

Device parallelism: lanes are independent, so with more than one device
(`REPRO_HOST_DEVICES=N` forces N XLA host devices on CPU; real TPU
backends need no flag) the lane axis is `shard_map`ped across the device
mesh — communication-free SPMD.  Lane counts that do not divide the
device count are padded with GHOST lanes (offered rate 0, dropped before
finalize), so the shard is always dense; each real lane's math is
untouched, keeping sharded runs bit-identical to single-device runs.
Grids too small to amortize the per-cycle shard_map dispatch (fewer than
`REPRO_SHARD_MIN_WORK` lane-cycles, default 4096) skip the lane shard
and run single-device — the chosen placement is recorded in
`SweepResult.placement` (and the perf-benchmark records).

Channel sharding (`REPRO_CHANNEL_SHARDS=K`, fused step only): the mesh
becomes 2-D ``(lanes, shards)`` — each lane's channel-id space is
block-partitioned across K shard devices and the step exchanges
per-channel grant minima / winner records at the phase boundary (see
`engine.fused`).  The big state arrays (`b_pkt`, `s_pkt`) partition on
their channel/terminal axis; everything else stays replicated across
the shard axis.  Ghost channel/terminal padding makes non-dividing
counts dense; `SweepResult.pad_fraction` reports the padded share of
the state so perf records can account for it.

Occupancy compaction (`cfg.step_impl="compact"`): the dispatch layer
owns the capacity LADDER.  A compact dispatch compiles the step at one
rung C (default ceil(N/4); REPRO_COMPACT_CAP pins the start), and
`finish()` checks the run's exact live-row census (`SimStats.occ_peak`)
against it — a breach re-dispatches the WHOLE grid at the next rung up
(`fused.next_rung`), so results handed back are always bit-identical to
the oracle; the rerun count is surfaced as `SweepResult.escalations`.
K-cycle supersteps (REPRO_SUPERSTEP, `superstep()`) unroll K cycles
inside the scan body — per-substep warmup/epoch/window conds keep K > 1
bit-identical to K = 1.

Every dispatch goes through an AOT compile cache, which (a) makes the
compile-vs-run wall-time split exact (`SweepResult.compile_s` /
`wall_s`) and (b) lets `run_lanes_async` return before the result is
materialized, so the experiment runner can round-robin independent grid
cells across devices (see `repro.exp.runner`).
"""
from __future__ import annotations

import functools
import inspect
import time
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# shard_map moved out of jax.experimental (and its replication-check
# kwarg was renamed) across JAX releases; resolve whichever this
# installation has so the engine imports everywhere.
try:
    from jax import shard_map as _shard_map          # modern JAX
except ImportError:                                  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
_SHMAP_PARAMS = inspect.signature(_shard_map).parameters
_SHMAP_NOCHECK = ({"check_rep": False} if "check_rep" in _SHMAP_PARAMS
                  else {"check_vma": False} if "check_vma" in _SHMAP_PARAMS
                  else {})

from ... import env_int
from ..topology import (FaultSchedule, FaultSet, Network, as_fault_schedule,
                        compose_faults, final_faults)
from ..traffic import as_pattern
from .fused import (fused_pad, grant_form, make_compact_step,
                    make_fused_step, next_rung)
from .state import build_lane, make_state, stack_lanes
from .stats import finalize, zero_stats
from .step import make_step

# Monotone count of batched-scan (re)traces.  `_scan_lanes` bumps it at
# TRACE time (Python side effects run once per compilation, never per
# execution), so a delta across a call counts exactly the compiles that
# call triggered — unlike the private `_cache_size` jit API, which is
# absent on some JAX versions and silently made
# `SweepResult.compile_count` lie as 0.
_TRACE_COUNT = [0]

# AOT executable cache: one compiled batched scan per (step closure,
# cycle budget, lane-shape signature, mesh/device placement).  Explicit
# AOT (`jit(...).lower(...).compile()`) instead of plain `jit` calls
# buys the exact compile-vs-run wall split and executables that can be
# dispatched without blocking (async cell round-robin).
_AOT_CACHE: dict = {}


def compile_counter() -> int:
    """Compilations of the batched scan so far in this process."""
    return _TRACE_COUNT[0]


def clear_aot_cache() -> None:
    """Drop the compiled-executable cache (tests / memory)."""
    _AOT_CACHE.clear()


def host_devices() -> list:
    """The devices the lane axis may spread over (all JAX devices)."""
    return jax.devices()


def shard_min_work() -> int:
    """Minimum (real lanes x cycles) for the automatic lane shard_map to
    pay for its per-cycle dispatch overhead; smaller grids run
    single-device.  Override with REPRO_SHARD_MIN_WORK (0 = always
    shard, as the sharding bit-identity tests do)."""
    return env_int("REPRO_SHARD_MIN_WORK", 4096)


def channel_shards() -> int:
    """Requested channel-shard count K (REPRO_CHANNEL_SHARDS, default 1).
    Only honored by fused-step (`cfg.step_impl="fused"`) dispatches with
    K devices available per lane row."""
    return max(env_int("REPRO_CHANNEL_SHARDS", 1), 1)


def superstep(span: int | None = None) -> int:
    """K-cycle superstep unroll factor (REPRO_SUPERSTEP, default 1).

    With K > 1 the batched scan advances K cycles per scan iteration —
    the K steps are Python-unrolled inside the scan body, so XLA fuses
    across cycle boundaries and the compact step's route-once cache
    (record fields, carried in the state) flows through the unroll with
    no scan-carry round-trip between the K substeps.  Each substep keeps
    its OWN absolute cycle `t` (warmup reset, fault-epoch resolution,
    and window `t_end` masking are all per-substep conds), so unrolling
    cannot skip a warm-fault epoch boundary or the stats reset — the
    result is bit-identical to K = 1 (pinned by tests, proved by the
    analysis capacity pass).

    `span` is the scan length the caller wants to unroll (the cycle
    budget, or a session's window); K falls back to 1 when it does not
    divide `span` (the reshape needs whole supersteps).
    """
    k = max(env_int("REPRO_SUPERSTEP", 1), 1)
    if span is not None and span % k:
        return 1
    return k


def lane_mesh(shards: int = 1) -> Mesh | None:
    """The device mesh for a dispatch: 1-D ``("lanes",)`` over the host
    devices, or 2-D ``("lanes", "shards")`` with `shards` > 1 (each lane
    row owns a K-device channel shard).  None when the process only has
    one device (the common un-forced CPU case)."""
    devs = host_devices()
    nd = len(devs)
    if nd <= 1:
        return None
    if shards > 1:
        if nd % shards:
            raise ValueError(
                f"REPRO_CHANNEL_SHARDS={shards} does not divide the "
                f"{nd} host devices")
        return Mesh(np.array(devs).reshape(nd // shards, shards),
                    ("lanes", "shards"))
    return Mesh(np.array(devs), ("lanes",))


def _key_chain(key, cycles: int):
    """The per-cycle subkeys of one lane, pre-generated outside the main
    scan: `key_{t+1}, sub_t = split(key_t)` — the exact chain the cycle
    loop used to compute inline, hoisted so the simulation scan body no
    longer interleaves a `vmap(split)` with the engine phases."""

    def split(k, _):
        k, sub = jax.random.split(k)
        return k, sub

    _, subs = jax.lax.scan(split, key, None, length=cycles)
    return subs                                            # [cycles, 2]


def _key_chain_seq(key, cycles: int):
    """`_key_chain` plus every intermediate key: `keys_seq[i]` is the lane
    key after i splits (`keys_seq[0] == key`), so a window that runs only
    r <= cycles real cycles can hand `keys_seq[r]` to the next window and
    the whole windowed run replays the uninterrupted subkey chain
    bit-for-bit."""

    def split(k, _):
        k2, sub = jax.random.split(k)
        return k2, (k2, sub)

    _, (ks, subs) = jax.lax.scan(split, key, None, length=cycles)
    return jnp.concatenate([key[None], ks]), subs   # [cycles+1, 2], [cycles, 2]


def _scan_lanes(step, cycles, reset_at, per_lane_faults, K,
                state0, rate_pkt, keys, lanes):
    """Advance B lanes in lockstep; state0/keys/rate_pkt carry axis 0 = B.

    `lanes` is the fault pytree (`build_lane`): lane-stacked ([B, ...],
    `per_lane_faults=True`) when the lanes model different degraded
    networks, or a single shared lane dict broadcast across the batch.

    `K` is the superstep unroll factor (must divide `cycles`; see
    `superstep`): the scan runs cycles/K iterations of K Python-unrolled
    substeps, each with its own absolute `t` — per-substep warmup reset
    and (inside the step) fault-epoch resolution keep the result
    bit-identical to K = 1.
    """
    _TRACE_COUNT[0] += 1  # trace-time side effect == one compilation
    lane_axis = 0 if per_lane_faults else None
    subkeys = jax.vmap(_key_chain, in_axes=(0, None),
                       out_axes=1)(keys, cycles)           # [cycles, B, 2]
    ts = jnp.arange(cycles).reshape(cycles // K, K)
    subkeys = subkeys.reshape((cycles // K, K) + subkeys.shape[1:])

    def body(state, t_subs):
        ts_k, subs_k = t_subs
        for i in range(K):
            t = ts_k[i]
            state, _ = jax.vmap(
                lambda s, k, r, f: step(s, (t, k, r, f)),
                in_axes=(0, 0, 0, lane_axis))(state, subs_k[i], rate_pkt,
                                              lanes)
            st = jax.lax.cond(t == reset_at, zero_stats, lambda s: s,
                              state.stats)
            state = state.replace(stats=st)
        return state, None

    state, _ = jax.lax.scan(body, state0, (ts, subkeys))
    return state


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 7),
                   donate_argnums=(3,))
def run_scan_batched(step, cycles, reset_at, state0, rate_pkt, keys, lanes,
                     per_lane_faults: bool):
    """Single-device batched scan (kept as the stable public entry point;
    `BatchedSweep` itself dispatches through the AOT cache, which adds
    device sharding, supersteps, and the compile/run wall split)."""
    return _scan_lanes(step, cycles, reset_at, per_lane_faults, 1,
                       state0, rate_pkt, keys, lanes)


def _scan_lanes_seq(step, cycles, reset_at, per_lane_faults, K,
                    state0, rate_pkt, keys, lanes):
    """`_scan_lanes` with the lane axis OUTSIDE the cycle scan: one
    `lax.map` over lanes, each lane running its own full-cycle scan.

    Bit-identical to the vmapped form — lanes are independent and the
    per-lane key chain is the same — but each lane's gathers/scatters
    run unbatched, which is how the compact step's occupancy-gather
    pipeline is fastest on CPU: batching the active-set gathers over
    lanes defeats XLA:CPU's contiguous-gather lowering (measured ~25%
    per-lane overhead at fig11 scale), and a single host core gains
    nothing from the lockstep form anyway.  Selected by the dispatch
    planner for single-device compact runs only; meshes keep the
    lockstep form (shard_map partitions the lane axis)."""
    _TRACE_COUNT[0] += 1  # trace-time side effect == one compilation
    subkeys = jax.vmap(_key_chain, in_axes=(0, None))(keys, cycles)
    ts = jnp.arange(cycles).reshape(cycles // K, K)

    def one_lane(st0_subs_rate_fl):
        st0, subs, rate, fl = st0_subs_rate_fl
        subs_r = subs.reshape((cycles // K, K) + subs.shape[1:])

        def body(state, t_subs):
            ts_k, subs_k = t_subs
            for i in range(K):
                t = ts_k[i]
                state, _ = step(state, (t, subs_k[i], rate, fl))
                st = jax.lax.cond(t == reset_at, zero_stats,
                                  lambda s: s, state.stats)
                state = state.replace(stats=st)
            return state, None

        return jax.lax.scan(body, st0, (ts, subs_r))[0]

    if per_lane_faults:
        return jax.lax.map(one_lane, (state0, subkeys, rate_pkt, lanes))
    return jax.lax.map(
        lambda args: one_lane(args + (lanes,)),
        (state0, subkeys, rate_pkt))


def _make_dispatch_fn(step, cycles, reset_at, per_lane_faults, mesh,
                      state_spec=None, K=1):
    """The jittable whole-sweep function, `shard_map`ped over the lane
    axis when a mesh is given (lanes are independent: no collectives, so
    partitioning axis 0 is communication-free SPMD).  `state_spec` is a
    per-leaf PartitionSpec tree for the state (the 2-D channel-sharded
    mesh partitions `b_pkt`/`s_pkt` on their channel axis and replicates
    the rest across the shard axis); the default partitions every leaf
    on the lane axis only."""
    scan_form = (_scan_lanes_seq
                 if mesh is None and getattr(step, "compact_capacity", 0)
                 else _scan_lanes)
    f = functools.partial(scan_form, step, cycles, reset_at,
                          per_lane_faults, K)
    if mesh is not None:
        lane_spec = PartitionSpec("lanes")
        if state_spec is None:
            state_spec = lane_spec
        data_spec = lane_spec if per_lane_faults else PartitionSpec()
        f = _shard_map(f, mesh=mesh,
                       in_specs=(state_spec, lane_spec, lane_spec,
                                 data_spec),
                       out_specs=state_spec, **_SHMAP_NOCHECK)
    return jax.jit(f, donate_argnums=(0,))


def _scan_window(step, window, reset_at, per_lane_faults, K,
                 state0, keys, t0, t_end, rate_pkt, lanes):
    """Advance B lanes exactly `window` scan iterations starting at
    absolute cycle `t0`, masking iterations at or past `t_end` to a
    no-op (`lax.cond` keeps the carried state untouched), and return the
    advanced `(state, keys)` pair.

    The fixed iteration count is what makes windowed execution compile
    ONCE per lane signature: every window of a run — including the final
    partial one — dispatches the same executable with different traced
    `t0`/`t_end` scalars.  Keys advance only for the real cycles
    (`keys_seq` gather), so chaining windows replays the exact subkey
    chain of the one-shot `_scan_lanes` run and the windowed result is
    bit-identical to the uninterrupted one.

    `K` supersteps the window scan like `_scan_lanes` (must divide
    `window`); the `t < t_end` no-op mask stays PER SUBSTEP, so a
    partial final window masks exactly the same cycles as K = 1.
    """
    _TRACE_COUNT[0] += 1  # trace-time side effect == one compilation
    lane_axis = 0 if per_lane_faults else None
    keys_seq, subkeys = jax.vmap(_key_chain_seq, in_axes=(0, None),
                                 out_axes=(1, 1))(keys, window)
    # keys_seq [window+1, B, 2], subkeys [window, B, 2]
    ts = (t0 + jnp.arange(window)).reshape(window // K, K)
    subs_r = subkeys.reshape((window // K, K) + subkeys.shape[1:])

    def body(state, t_subs):
        ts_k, subs_k = t_subs
        for i in range(K):
            t, subs = ts_k[i], subs_k[i]

            def advance(st):
                st, _ = jax.vmap(
                    lambda s, k, r, f: step(s, (t, k, r, f)),
                    in_axes=(0, 0, 0, lane_axis))(st, subs, rate_pkt,
                                                  lanes)
                stats = jax.lax.cond(t == reset_at, zero_stats,
                                     lambda s: s, st.stats)
                return st.replace(stats=stats)

            state = jax.lax.cond(t < t_end, advance, lambda st: st, state)
        return state, None

    state, _ = jax.lax.scan(body, state0, (ts, subs_r))
    real = jnp.clip(t_end - t0, 0, window)
    return state, keys_seq[real]


def _make_window_fn(step, window, reset_at, per_lane_faults, mesh, K=1):
    """The jittable one-window function, `shard_map`ped over the lane
    axis when a mesh is given (mirrors `_make_dispatch_fn`; the traced
    `t0`/`t_end` scalars replicate across devices).  State and keys are
    donated — each window consumes the previous window's buffers."""
    f = functools.partial(_scan_window, step, window, reset_at,
                          per_lane_faults, K)
    if mesh is not None:
        lane_spec = PartitionSpec("lanes")
        scal_spec = PartitionSpec()
        data_spec = lane_spec if per_lane_faults else scal_spec
        f = _shard_map(f, mesh=mesh,
                       in_specs=(lane_spec, lane_spec, scal_spec,
                                 scal_spec, lane_spec, data_spec),
                       out_specs=(lane_spec, lane_spec), **_SHMAP_NOCHECK)
    return jax.jit(f, donate_argnums=(0, 1))


def _sig(tree) -> tuple:
    """Hashable shape/dtype signature of a pytree (AOT cache key part)."""
    return (jax.tree.structure(tree),
            tuple((l.shape, str(l.dtype)) for l in jax.tree.leaves(tree)))


def offered_to_rate_pkt(offered_per_chip: float, cfg,
                        terms_per_chip: float) -> float:
    """Offered flits/cycle/chip -> per-terminal packet-generation rate.

    Shared by the facade `Simulator.run` and `BatchedSweep`; raises when the
    offered load would need more than one packet per terminal per cycle.
    """
    rate = offered_per_chip / cfg.pkt_len / terms_per_chip
    if rate > 1.0 + 1e-9:
        raise ValueError(
            f"offered {offered_per_chip}/chip needs per-terminal packet "
            f"rate {rate:.2f} > 1")
    return rate


class LaneRun(NamedTuple):
    """The outcome of one `run_lanes` dispatch."""

    results: list          # one SimResult per lane, in lane order
    wall_s: float          # execution wall time (compile excluded)
    compile_s: float       # trace + compile wall time (0.0 on cache hit)
    compile_count: int     # jit compilations this dispatch triggered
    fault_sets: list       # composed per-lane fault states (None=pristine)
    placement: str = "single"   # "single" | "lanes:L" | "lanes:L,shards:K"
    pad_fraction: float = 0.0   # ghost share of the dispatched state
    grant_form: str = "two_pass"   # "combined" | "two_pass" (see fused.py)
    occupancy_peak: int = 0     # max live request rows over the real lanes
    compact_capacity: int = 0   # compact step's final ladder rung (0=dense)
    superstep: int = 1          # K-cycle unroll the dispatch compiled
    escalations: int = 0        # capacity-ladder reruns this run needed
    # compiles spent on ABANDONED (breached) rungs: kept out of
    # `compile_count` so the one-compile-per-grid accounting stays exact
    # per executable — each ladder rung is its own executable
    escalation_compiles: int = 0


@dataclass
class SweepResult:
    """SimResults on the (rate x seed) grid, plus curve-level reductions.

    For fault sweeps (`BatchedSweep.run_faults`) the row axis is the fault
    grid instead of the rate grid: `rates[i]` repeats the common offered
    load and `fault_fracs[i]` labels row i with its failed-link fraction.

    `wall_s` is EXECUTION time only; trace + compile time is `compile_s`
    (0.0 when the dispatch was an executable-cache hit), so first-call
    timings no longer conflate the two.
    """

    rates: list[float]
    seeds: list[int]
    results: list[list]        # [num_rates][num_seeds] of SimResult
    compile_count: int = 0     # jit compilations this sweep triggered
    wall_s: float = 0.0
    compile_s: float = 0.0
    fault_fracs: list | None = None   # per-row failed-link fraction (faults)
    placement: str = "single"  # device placement the dispatch chose
    pad_fraction: float = 0.0  # ghost (lane + channel pad) state share
    # grant arbitration form the dispatch compiled: "combined" (the fused
    # step's single packed segment-min) or "two_pass" (the age-then-
    # priority oracle form — also what the fused step falls back to when
    # the packed key would overflow int32; `fused.grant_form` decides,
    # and the static spec pass reports/warns per scenario)
    grant_form: str = "two_pass"
    # occupancy / compaction telemetry (see engine.fused.make_compact_step):
    # peak live request rows over the whole grid, the compact step's FINAL
    # capacity rung (0 for the dense steps), the K-cycle superstep the
    # dispatch compiled, and how many capacity-ladder reruns were needed
    occupancy_peak: int = 0
    compact_capacity: int = 0
    superstep: int = 1
    escalations: int = 0
    # compiles the abandoned rungs cost (separate from `compile_count`:
    # every rung is its own executable, so the per-grid count stays 1)
    escalation_compiles: int = 0

    def result(self, rate_idx: int, seed_idx: int = 0):
        return self.results[rate_idx][seed_idx]

    def flat(self):
        return [r for row in self.results for r in row]

    def mean_over_seeds(self) -> list:
        """One seed-averaged SimResult per rate.

        Rates/latencies are means over the seed lanes; packet counters are
        floor-averaged (NOT summed) so they stay comparable to a single
        `Simulator.run`.  Reliability gauges are different: `stranded_pkts`
        reports the exact per-lane MAX (a floor-averaged mean hid single
        stranded wafers — 1 stranded packet across 8 seeds floored to 0),
        with the exact mean in the float `stranded_mean`; `occupancy_peak`
        is likewise the max."""
        from ..simulator import SimResult
        out = []
        for row in self.results:
            n = len(row)
            hops = {k: sum(r.hops_by_type[k] for r in row) // n
                    for k in row[0].hops_by_type}
            avg_hops = {k: float(np.mean([r.avg_hops_by_type[k] for r in row]))
                        for k in row[0].avg_hops_by_type}
            out.append(SimResult(
                offered_per_chip=row[0].offered_per_chip,
                throughput_per_chip=float(
                    np.mean([r.throughput_per_chip for r in row])),
                avg_latency=float(np.mean([r.avg_latency for r in row])),
                delivered_pkts=sum(r.delivered_pkts for r in row) // n,
                generated_pkts=sum(r.generated_pkts for r in row) // n,
                dropped_pkts=sum(r.dropped_pkts for r in row) // n,
                hops_by_type=hops, avg_hops_by_type=avg_hops,
                stranded_pkts=max(r.stranded_pkts for r in row),
                stranded_mean=float(
                    np.mean([r.stranded_pkts for r in row])),
                reaped_pkts=sum(r.reaped_pkts for r in row) // n,
                occupancy_peak=max(r.occupancy_peak for r in row)))
        return out

    def saturation_throughput(self) -> float:
        """Max seed-averaged accepted throughput over the sweep."""
        return max(r.throughput_per_chip for r in self.mean_over_seeds())


class _LanePlan:
    """A prepared, placed, and compiled — but not yet executed — lane
    dispatch (`BatchedSweep.warm_compile`).  Single-use: execution
    donates the plan's initial state buffer.  `compile_s` and
    `compile_count` are zero when the executable came from the AOT
    cache."""

    __slots__ = ("lane_triples", "fault_sets", "args", "compiled",
                 "compile_s", "compile_count", "placement",
                 "pad_fraction", "grant_form", "capacity", "rows",
                 "superstep", "device", "used")

    def __init__(self, lane_triples, fault_sets, args, compiled,
                 compile_s, compile_count, placement, pad_fraction,
                 grant_form, capacity=0, rows=0, superstep=1,
                 device=None):
        self.lane_triples = lane_triples
        self.fault_sets = fault_sets
        self.args = args
        self.compiled = compiled
        self.compile_s = compile_s
        self.compile_count = compile_count
        self.placement = placement
        self.pad_fraction = pad_fraction
        self.grant_form = grant_form
        self.capacity = capacity      # compact rung this plan compiled
        self.rows = rows              # N, the dense request-row count
        self.superstep = superstep
        self.device = device          # pinned device (escalation reruns)
        self.used = False


class _PendingLanes:
    """A dispatched-but-unmaterialized `run_lanes` call.

    The compiled executable has been enqueued (JAX dispatch is async);
    `finish()` blocks on the device result and builds the per-lane
    `SimResult`s.  `wall_s` therefore measures dispatch -> materialized,
    which for overlapped (round-robined) cells includes time the device
    spent interleaved with other work.
    """

    def __init__(self, sweep, stats, num_lanes, lane_triples, fault_sets,
                 compile_s, compile_count, t0, placement, pad_fraction,
                 grant_form, capacity=0, rows=0, superstep=1,
                 device=None):
        self._sweep, self._stats = sweep, stats
        self._B, self._lanes = num_lanes, lane_triples
        self._fsets = fault_sets
        self._compile_s, self._compiles = compile_s, compile_count
        self._t0 = t0
        self._placement, self._pad_frac = placement, pad_fraction
        self._grant_form = grant_form
        self._capacity, self._rows = capacity, rows
        self._superstep = superstep
        self._device = device

    def finish(self) -> LaneRun:
        stats = jax.tree.map(np.asarray, self._stats)      # blocks
        wall = time.perf_counter() - self._t0
        cfg = self._sweep.cfg
        occ = int(np.max(stats.occ_peak[:self._B]))
        if self._capacity and occ > self._capacity:
            # capacity breach: the live set outgrew this rung, so every
            # cycle after the crossing arbitrated over a TRUNCATED active
            # set — nothing from this run can be trusted (or reused).
            # Re-dispatch the WHOLE grid at the next ladder rung; the
            # rerun is deterministic (same lanes, same keys), so the
            # escalated result is bit-identical to the oracle.  `occ` is
            # exact (the census is computed densely, independent of C),
            # and the top rung C = N cannot breach, so the walk
            # terminates.
            rung = next_rung(self._rows, occ)
            self._sweep._capacity_floor = max(
                self._sweep._capacity_floor, rung)
            redo = self._sweep.run_lanes_async(
                self._lanes, device=self._device, capacity=rung).finish()
            return redo._replace(
                wall_s=redo.wall_s + wall,
                compile_s=redo.compile_s + self._compile_s,
                escalations=redo.escalations + 1,
                escalation_compiles=(redo.escalation_compiles
                                     + self._compiles))
        pick = lambda i: jax.tree.map(lambda x: x[i], stats)
        results = [finalize(pick(i), cfg, self._lanes[i][0],
                            self._sweep._chips(self._fsets[i]))
                   for i in range(self._B)]     # ghost pad lanes excluded
        return LaneRun(results, wall, self._compile_s, self._compiles,
                       self._fsets, self._placement, self._pad_frac,
                       self._grant_form, occ, self._capacity,
                       self._superstep)


class LaneSession:
    """A paused, resumable lane dispatch advanced window-by-window.

    Created by `BatchedSweep.start_lanes`.  Unlike `run_lanes` — which
    scans the whole cycle budget in one dispatch — a session holds the
    live `SimState` (and the per-lane PRNG keys) between fixed-length
    window dispatches, so a long-lived caller (`repro.exp.serve`) can
    stream incremental stats after every window, checkpoint the state
    mid-run, and interleave many independent sessions on one process.
    Chained windows replay the one-shot run's per-cycle subkey chain
    exactly, so `finish()` is bit-identical to `run_lanes` on the same
    lane triples (pinned by tests/test_serve.py).

    `export()` snapshots the session's dynamic state to host numpy
    arrays; `BatchedSweep.start_lanes(..., restore=exported)` resumes a
    fresh session from a snapshot — resumed runs reproduce the
    uninterrupted run bit-for-bit because the state arrays, the lane
    keys, and the absolute cycle count are the entire dynamic state.
    """

    __slots__ = ("sweep", "lane_triples", "fault_sets", "window", "total",
                 "cycle", "state", "keys", "compiled", "placement",
                 "pad_fraction", "grant_form", "compile_s", "compile_count",
                 "num_lanes", "capacity", "superstep", "_rate_pkt_dev",
                 "_lane_data")

    def __init__(self, sweep, lane_triples, fault_sets, window, total,
                 cycle, state, keys, compiled, rate_pkt, lane_data,
                 placement, pad_fraction, grant_form, compile_s,
                 compile_count, capacity=0, superstep=1):
        self.sweep = sweep
        self.lane_triples = lane_triples
        self.fault_sets = fault_sets
        self.window = window
        self.total = total
        self.cycle = cycle
        self.state = state
        self.keys = keys
        self.compiled = compiled
        self._rate_pkt_dev = rate_pkt
        self._lane_data = lane_data
        self.placement = placement
        self.pad_fraction = pad_fraction
        self.grant_form = grant_form
        self.compile_s = compile_s
        self.compile_count = compile_count
        self.capacity = capacity      # compact rung (0 for dense steps)
        self.superstep = superstep
        self.num_lanes = len(lane_triples)

    def done(self) -> bool:
        return self.cycle >= self.total

    def advance(self) -> int:
        """Run one window (`window` cycles, clipped at the total budget);
        returns the new absolute cycle count."""
        if self.done():
            return self.cycle
        t0 = jnp.asarray(self.cycle, jnp.int32)
        t_end = jnp.asarray(self.total, jnp.int32)
        self.state, self.keys = self.compiled(
            self.state, self.keys, t0, t_end, self._rate_pkt_dev,
            self._lane_data)
        self.cycle = min(self.cycle + self.window, self.total)
        return self.cycle

    def stats_host(self):
        """The current per-lane `SimStats` counters as host numpy arrays
        (leading axis = padded lane count; real lanes are the first
        `num_lanes` rows).  Blocks on any in-flight window."""
        return jax.tree.map(np.asarray, self.state.stats)

    def lane_stats(self, i: int):
        """Real lane i's current counters (host)."""
        st = self.stats_host()
        return jax.tree.map(lambda x: x[i], st)

    def export(self) -> dict:
        """Snapshot the session's full dynamic state to host arrays:
        `{"state": SimState-of-numpy, "keys": [Bp, 2] uint32,
        "cycle": int}` — everything `restore=` needs for a bit-identical
        resume (the static side is rebuilt from the lane triples)."""
        return dict(state=jax.tree.map(np.asarray, self.state),
                    keys=np.asarray(self.keys),
                    cycle=int(self.cycle))

    def finish(self) -> LaneRun:
        """Per-lane `SimResult`s once the cycle budget is exhausted —
        the same shape of answer `run_lanes` returns (wall_s is not
        tracked per-window; reported as 0.0)."""
        if not self.done():
            raise ValueError(
                f"session at cycle {self.cycle}/{self.total}: advance() "
                f"to the full budget before finish()")
        stats = self.stats_host()
        cfg = self.sweep.cfg
        occ = int(np.max(stats.occ_peak[:self.num_lanes]))
        if self.capacity and occ > self.capacity:
            # a windowed session cannot escalate (its exported snapshots
            # and streamed stats already reflect the truncated active
            # set), so a breach is a hard error with the fix spelled out
            raise RuntimeError(
                f"compact capacity {self.capacity} overflowed: the live "
                f"set peaked at {occ} rows — windowed sessions cannot "
                f"re-dispatch at a larger ladder rung mid-run; rerun "
                f"with REPRO_COMPACT_CAP>={occ} (or step_impl='fused')")
        pick = lambda i: jax.tree.map(lambda x: x[i], stats)
        results = [finalize(pick(i), cfg, self.lane_triples[i][0],
                            self.sweep._chips(self.fault_sets[i]))
                   for i in range(self.num_lanes)]
        return LaneRun(results, 0.0, self.compile_s, self.compile_count,
                       self.fault_sets, self.placement, self.pad_fraction,
                       self.grant_form, occ, self.capacity,
                       self.superstep)


class BatchedSweep:
    """Compile-once sweep runner over a (rate x seed) lane grid.

    The step closure is shared with `Simulator` (same phases, same consts);
    `route_fn` and the traffic pattern only ever see per-lane data, so the
    whole cycle is batch-pure and legal to `vmap`.  `faults` degrades every
    lane with one fault set; `run_faults` runs a grid of different fault
    sets in one compile.
    """

    def __init__(self, net: Network, cfg, pattern, inject_mask=None,
                 step=None, consts=None, faults: FaultSet | None = None,
                 lane=None):
        self.net, self.cfg = net, cfg
        pattern = as_pattern(pattern, inject_mask)
        if step is None:
            step, consts = make_step(net, cfg, pattern)
        self.step, self.consts = step, consts
        self.NV = consts["NV"]
        self._pattern = pattern
        self._sharded_steps: dict[int, object] = {}
        self._compact_steps: dict[int, object] = {}
        self._capacity_floor = 0    # highest escalated rung seen so far
        self.faults = faults
        self.lane0 = build_lane(net, cfg, faults) if lane is None else lane
        self.terms_per_chip = net.num_terminals / net.num_chips
        self._inj_mask = (np.ones(net.num_terminals, dtype=bool)
                          if pattern.inject_mask is None
                          else np.asarray(pattern.inject_mask).astype(bool))

    def _rate_pkt(self, offered_per_chip: float) -> float:
        return offered_to_rate_pkt(offered_per_chip, self.cfg,
                                   self.terms_per_chip)

    def _sharded_step(self, K: int):
        """The K-way channel-sharded fused step (memoized: one build per
        shard count, so repeat dispatches hit the AOT cache)."""
        step = self._sharded_steps.get(K)
        if step is None:
            step, _ = make_fused_step(self.net, self.cfg, self._pattern,
                                      shards=K)
            self._sharded_steps[K] = step
        return step

    def _compact_step(self, C: int):
        """The capacity-C compact step (memoized per ladder rung: the
        base `self.step` for its own rung, a fresh build otherwise — so
        an escalation's first rerun compiles once and later reruns at
        the same rung hit the AOT cache)."""
        step = self._compact_steps.get(C)
        if step is None:
            if getattr(self.step, "compact_capacity", None) == C:
                step = self.step
            else:
                step, _ = make_compact_step(self.net, self.cfg,
                                            self._pattern, capacity=C)
            self._compact_steps[C] = step
        return step

    def _chips(self, faults) -> float:
        """Accepted-throughput divisor: chips weighted by the fraction of
        terminals that actually inject (mask AND alive).  A schedule
        reports its FINAL epoch — the steady-state degraded network."""
        faults = final_faults(faults)
        alive = (self._inj_mask if faults is None
                 else self._inj_mask & faults.term_alive(self.net))
        return self.net.num_chips * alive.sum() / self.net.num_terminals

    def _plan(self, lanes, device=None, capacity=None) -> "_LanePlan":
        """Prepare, place, and compile (cache-aware) ONE batched scan
        over the (ghost-padded) lane axis — without executing it.

        `device=None` shards lanes over the full device mesh (no-op with
        one device); an explicit `device` pins the whole dispatch there
        (the runner's cell round-robin).  `capacity` overrides the
        compact step's ladder rung (the escalation rerun path; ignored
        for the dense steps).  The returned plan is single-use:
        executing it donates its initial state buffer.
        """
        lane_triples, lane_rates, lane_keys, lane_data, per_lane_faults, \
            fsets = self._prepare_lanes(lanes)
        cfg = self.cfg
        B = int(lane_rates.shape[0])
        cycles = cfg.warmup + cfg.measure
        impl = getattr(cfg, "step_impl", "jnp")
        fused = impl == "fused"
        compact = impl == "compact"
        K = channel_shards() if (fused and device is None) else 1
        mesh = lane_mesh(K) if K > 1 else None
        if mesh is None:
            K = 1       # < K devices: channel sharding can't apply
            small = B * cycles < shard_min_work()
            if device is None and B > 1 and not small:
                mesh = lane_mesh()
        if K > 1:
            step = self._sharded_step(K)
        elif compact and capacity is not None:
            step = self._compact_step(int(capacity))
        elif compact and self._capacity_floor:
            # warm start: an earlier dispatch of this sweep escalated, so
            # later dispatches start straight at the proven rung instead
            # of re-breaching the default one every run
            step = self._compact_step(self._capacity_floor)
        else:
            step = self.step
        # the arbitration form this dispatch compiles: the oracle step IS
        # the two-pass form; the fused/compact steps pick per
        # `fused.grant_form`
        gform = (grant_form(self.net, cfg, K) if fused or compact
                 else "two_pass")
        cap = getattr(step, "compact_capacity", 0)
        kss = superstep(cycles)
        ch_pad, term_pad = fused_pad(self.net, K) if K > 1 else (0, 0)
        nd = int(mesh.shape["lanes"]) if mesh is not None else 1
        pad = (-B) % nd
        if mesh is None:
            placement = "single"
        elif K > 1:
            placement = f"lanes:{nd},shards:{K}"
        else:
            placement = f"lanes:{nd}"
        E = self.net.num_channels
        pad_fraction = 1.0 - (B * E) / ((B + pad) * (E + ch_pad))
        if pad:
            # ghost lanes: offered rate 0 (inject generates nothing), any
            # valid key/fault data; their stats are never read back
            lane_rates = jnp.concatenate(
                [lane_rates, jnp.zeros((pad,), lane_rates.dtype)])
            lane_keys = jnp.concatenate(
                [lane_keys,
                 jnp.broadcast_to(lane_keys[:1],
                                  (pad,) + lane_keys.shape[1:])])
            if per_lane_faults:
                lane_data = jax.tree.map(
                    lambda x: jnp.concatenate(
                        [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])]),
                    lane_data)
        state0 = make_state(self.net, cfg, self.NV, batch=(B + pad,),
                            ch_pad=ch_pad, term_pad=term_pad)
        state_spec = None
        if K > 1:
            # 2-D placement: the big per-channel/per-terminal arrays
            # partition on their second axis, the rest replicates
            # across the shard axis
            state_spec = jax.tree.map(lambda _: PartitionSpec("lanes"),
                                      state0)
            state_spec = state_spec.replace(
                b_pkt=PartitionSpec("lanes", "shards"),
                s_pkt=PartitionSpec("lanes", "shards"))
        if mesh is not None:
            lane_sh = NamedSharding(mesh, PartitionSpec("lanes"))
            repl_sh = NamedSharding(mesh, PartitionSpec())
            if state_spec is None:
                state0 = jax.device_put(state0, lane_sh)
            else:
                # PartitionSpec subclasses tuple, so the spec tree can't
                # be tree-mapped over — build a NamedSharding-leaf tree
                sh_tree = jax.tree.map(lambda _: lane_sh, state0)
                sh_tree = sh_tree.replace(
                    b_pkt=NamedSharding(
                        mesh, PartitionSpec("lanes", "shards")),
                    s_pkt=NamedSharding(
                        mesh, PartitionSpec("lanes", "shards")))
                state0 = jax.tree.map(jax.device_put, state0, sh_tree)
            lane_rates = jax.device_put(lane_rates, lane_sh)
            lane_keys = jax.device_put(lane_keys, lane_sh)
            lane_data = jax.device_put(
                lane_data, lane_sh if per_lane_faults else repl_sh)
        elif device is not None:
            state0, lane_rates, lane_keys, lane_data = jax.device_put(
                (state0, lane_rates, lane_keys, lane_data), device)
        cache_key = (step, cycles, cfg.warmup, per_lane_faults, mesh,
                     device, kss, _sig((state0, lane_rates, lane_keys,
                                        lane_data)))
        compiled = _AOT_CACHE.get(cache_key)
        compile_s = 0.0
        compiles = 0
        if compiled is None:
            fn = _make_dispatch_fn(step, cycles, cfg.warmup,
                                   per_lane_faults, mesh, state_spec, kss)
            before = _TRACE_COUNT[0]
            t0 = time.perf_counter()
            compiled = fn.lower(state0, lane_rates, lane_keys,
                                lane_data).compile()
            compile_s = time.perf_counter() - t0
            compiles = _TRACE_COUNT[0] - before
            _AOT_CACHE[cache_key] = compiled
        return _LanePlan(lane_triples, fsets,
                         (state0, lane_rates, lane_keys, lane_data),
                         compiled, compile_s, compiles, placement,
                         pad_fraction, gform, cap,
                         getattr(step, "compact_rows", 0), kss, device)

    def _prepare_lanes(self, lanes, force_stack: bool = False,
                       epochs: int | None = None):
        """Compose/sample per-lane fault data; returns the dense lane
        arrays plus the composed fault states.  `force_stack` always
        stacks the lane axis even when every lane shares one fault state
        — window sessions use it so a bucket's dispatch signature never
        depends on which tenants' lanes happened to be packed together.
        `epochs` forces the schedule (epoch-stacked) lane form padded to
        at least that many epochs, even for an all-cold lane list, so
        every pack of a warm bucket keeps one dispatch signature."""
        cfg = self.cfg
        lanes = list(lanes)
        if not lanes:
            raise ValueError("run_lanes needs >= 1 lane")
        base = self.faults
        fsets = [compose_faults(base, f) for _, _, f in lanes]
        if (epochs is not None
                or any(isinstance(f, FaultSchedule) for f in fsets)):
            fsets = [as_fault_schedule(f) for f in fsets]
        lane_rates = jnp.asarray([self._rate_pkt(r) for r, _, _ in lanes],
                                 dtype=jnp.float32)
        lane_keys = jnp.stack(
            [jax.random.PRNGKey(int(s)) for _, s, _ in lanes])
        if len(set(fsets)) == 1 and not force_stack:
            lane_data = (self.lane0 if fsets[0] == base
                         else build_lane(self.net, cfg, fsets[0]))
            per_lane = False
        else:
            # FaultSet is frozen/hashable: build each distinct lane once
            # even when many lanes share one fault set
            memo = {}
            for f in fsets:
                if f not in memo:
                    memo[f] = build_lane(self.net, cfg, f)
            lane_data = stack_lanes([memo[f] for f in fsets],
                                    epochs=epochs)
            per_lane = True
        return lanes, lane_rates, lane_keys, lane_data, per_lane, fsets

    def warm_compile(self, lanes, device=None) -> "_LanePlan":
        """Prepare and compile the lane grid without executing it.

        The experiment runner warms EVERY cell before dispatching any
        execution, so a round-robined cell's wall_s window never
        contains another cell's host-blocking compilation; the returned
        plan is then handed back to `run_lanes_async(plan=...)`, reusing
        the prepared lane arrays (no second fault-table build)."""
        return self._plan(lanes, device=device)

    def start_lanes(self, lanes, *, window: int, device=None,
                    pad_to: int | None = None, force_stack: bool = False,
                    epochs: int | None = None,
                    restore: dict | None = None) -> LaneSession:
        """Open a window-sliced `LaneSession` over `lanes` instead of
        scanning the whole cycle budget at once.

        `window` is the fixed per-dispatch cycle count: every window —
        including the final partial one — runs the SAME compiled
        executable (cycles past the budget are masked no-ops), so a
        session costs at most one compile per lane signature no matter
        how its total budget divides.  `pad_to` ghost-pads the lane axis
        up to a fixed batch size (rate-0 lanes, dropped from results) so
        heterogeneous packings of the same signature share one
        executable; `force_stack` pins the per-lane fault axis stacked
        and `epochs` pins the schedule form padded to a fixed epoch
        count, both for the same reason.  `restore` resumes from a prior
        session's
        `export()` snapshot (same lane triples required) — the resumed
        run is bit-identical to the uninterrupted one.

        Sessions ignore `REPRO_CHANNEL_SHARDS` (the 2-D fused-step mesh
        is a whole-run dispatch); the lane axis still `shard_map`s over
        multi-device hosts when the padded batch divides the mesh.
        """
        if window < 1:
            raise ValueError(f"window must be >= 1 cycles, got {window}")
        lane_triples, lane_rates, lane_keys, lane_data, per_lane_faults, \
            fsets = self._prepare_lanes(lanes, force_stack=force_stack,
                                        epochs=epochs)
        cfg = self.cfg
        B = int(lane_rates.shape[0])
        if pad_to is not None and pad_to < B:
            raise ValueError(f"pad_to={pad_to} < {B} lanes")
        target = max(B, pad_to or 0)
        cycles = cfg.warmup + cfg.measure
        mesh = None
        if device is None and target > 1 \
                and target * cycles >= shard_min_work():
            mesh = lane_mesh()
        nd = int(mesh.shape["lanes"]) if mesh is not None else 1
        Bp = target + (-target) % nd
        pad = Bp - B
        placement = "single" if mesh is None else f"lanes:{nd}"
        impl = getattr(cfg, "step_impl", "jnp")
        gform = (grant_form(self.net, cfg, 1) if impl in ("fused", "compact")
                 else "two_pass")
        step = self.step
        if impl == "compact" and self._capacity_floor:
            # sessions cannot escalate mid-run (finish() raises on a
            # breach), so start at the highest rung this sweep has ever
            # had to escalate to
            step = self._compact_step(self._capacity_floor)
        cap = getattr(step, "compact_capacity", 0)
        kss = superstep(window)
        if pad:
            lane_rates = jnp.concatenate(
                [lane_rates, jnp.zeros((pad,), lane_rates.dtype)])
            lane_keys = jnp.concatenate(
                [lane_keys,
                 jnp.broadcast_to(lane_keys[:1],
                                  (pad,) + lane_keys.shape[1:])])
            if per_lane_faults:
                lane_data = jax.tree.map(
                    lambda x: jnp.concatenate(
                        [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])]),
                    lane_data)
        state0 = make_state(self.net, cfg, self.NV, batch=(Bp,))
        cycle = 0
        if restore is not None:
            want = _sig((state0, lane_keys))
            got = _sig((restore["state"], restore["keys"]))
            if want != got:
                raise ValueError(
                    "restore snapshot does not match this session's lane "
                    "signature (different lane count, padding, or config)")
            state0 = jax.tree.map(jnp.asarray, restore["state"])
            lane_keys = jnp.asarray(restore["keys"])
            cycle = int(restore["cycle"])
            if not 0 <= cycle <= cycles:
                raise ValueError(
                    f"restore cycle {cycle} outside [0, {cycles}]")
        t0 = jnp.asarray(cycle, jnp.int32)
        t_end = jnp.asarray(cycles, jnp.int32)
        if mesh is not None:
            lane_sh = NamedSharding(mesh, PartitionSpec("lanes"))
            repl_sh = NamedSharding(mesh, PartitionSpec())
            state0 = jax.device_put(state0, lane_sh)
            lane_rates = jax.device_put(lane_rates, lane_sh)
            lane_keys = jax.device_put(lane_keys, lane_sh)
            lane_data = jax.device_put(
                lane_data, lane_sh if per_lane_faults else repl_sh)
        elif device is not None:
            state0, lane_rates, lane_keys, lane_data = jax.device_put(
                (state0, lane_rates, lane_keys, lane_data), device)
        cache_key = ("window", step, window, cfg.warmup,
                     per_lane_faults, mesh, device, kss,
                     _sig((state0, lane_keys, t0, t_end, lane_rates,
                           lane_data)))
        compiled = _AOT_CACHE.get(cache_key)
        compile_s = 0.0
        compiles = 0
        if compiled is None:
            fn = _make_window_fn(step, window, cfg.warmup,
                                 per_lane_faults, mesh, kss)
            before = _TRACE_COUNT[0]
            t_c = time.perf_counter()
            compiled = fn.lower(state0, lane_keys, t0, t_end, lane_rates,
                                lane_data).compile()
            compile_s = time.perf_counter() - t_c
            compiles = _TRACE_COUNT[0] - before
            _AOT_CACHE[cache_key] = compiled
        return LaneSession(self, lane_triples, fsets, window, cycles,
                           cycle, state0, lane_keys, compiled, lane_rates,
                           lane_data, placement, 1.0 - B / Bp, gform,
                           compile_s, compiles, cap, kss)

    def run_lanes_async(self, lanes=None, device=None,
                        plan: "_LanePlan | None" = None,
                        capacity=None) -> _PendingLanes:
        """Dispatch the lane grid without blocking on the result.

        Compilation (cache-miss only) still blocks the host, but the
        execution is enqueued asynchronously — the caller can dispatch
        further independent grids (e.g. on other devices) and `finish()`
        them in order.  `device` pins the whole grid to one device
        instead of sharding it over the mesh; `plan` executes an
        already-warm `warm_compile` plan instead of preparing anew;
        `capacity` pins the compact step's ladder rung (the escalation
        rerun re-enters here with the next rung up)."""
        if plan is None:
            plan = self._plan(lanes, device=device, capacity=capacity)
        if plan.used:
            raise ValueError(
                "a lane plan is single-use: its initial state buffer is "
                "donated at execution — warm_compile a fresh one")
        plan.used = True
        t0 = time.perf_counter()
        state = plan.compiled(*plan.args)
        plan.args = None      # the donated state buffer is gone anyway
        return _PendingLanes(self, state.stats, len(plan.lane_triples),
                             plan.lane_triples, plan.fault_sets,
                             plan.compile_s, plan.compile_count, t0,
                             plan.placement, plan.pad_fraction,
                             plan.grant_form, plan.capacity, plan.rows,
                             plan.superstep, plan.device)

    def run_lanes(self, lanes, device=None) -> LaneRun:
        """The fully general lane axis: one compiled batched scan over an
        arbitrary list of `(offered_per_chip, seed, faults)` lane triples,
        where `faults` is a `FaultSet`, a warm `FaultSchedule`, or None.

        Each lane's fault state COMPOSES on top of the sweep's base
        `faults` (`None` means "just the base faults").  When any lane
        carries a `FaultSchedule`, EVERY lane is promoted to a schedule
        (cold sets become single-epoch schedules) so the lane pytrees
        share one epoch-stacked structure — a mixed warm/cold
        (rates x seeds x schedules) grid still stacks into one dense
        batch.  When every composed lane ends up with the same fault state
        the shared-lane fast path is used (the fault pytree broadcasts
        instead of stacking), otherwise each distinct state builds its
        lane tables once and the step vmaps over the stacked lane axis —
        either way ONE dispatch, at most one jit compile.

        With multiple devices the lane axis is `shard_map`ped across
        them (ghost-padded to a device multiple); results stay lane-for-
        lane bit-identical to the single-device run.

        Returns a `LaneRun` (`results` one `SimResult` per lane in
        order, the compile/run wall split, and the composed per-lane
        fault states).
        """
        return self.run_lanes_async(lanes, device=device).finish()

    def run(self, rates, seeds=None) -> SweepResult:
        cfg = self.cfg
        rates = [float(r) for r in rates]
        seeds = [cfg.seed] if seeds is None else [int(s) for s in seeds]
        R, S = len(rates), len(seeds)
        if R * S == 0:
            raise ValueError(
                f"sweep needs >= 1 rate and >= 1 seed (got {R} rates, "
                f"{S} seeds)")
        run = self.run_lanes([(r, s, None) for r in rates for s in seeds])
        flat = run.results
        results = [[flat[i * S + j] for j in range(S)] for i in range(R)]
        return SweepResult(rates=rates, seeds=seeds, results=results,
                           compile_count=run.compile_count,
                           wall_s=run.wall_s, compile_s=run.compile_s,
                           placement=run.placement,
                           pad_fraction=run.pad_fraction,
                           grant_form=run.grant_form,
                           occupancy_peak=run.occupancy_peak,
                           compact_capacity=run.compact_capacity,
                           superstep=run.superstep,
                           escalations=run.escalations,
                           escalation_compiles=run.escalation_compiles)

    def run_faults(self, offered_per_chip: float, fault_grid,
                   seeds=None) -> SweepResult:
        """Degraded-throughput grid: one lane per (fault set, seed), all at
        the same offered load, in ONE compiled batched scan.

        `fault_grid` is a list of rows; row i is either one `FaultSet` /
        warm `FaultSchedule` (shared by every seed lane of that row) or a
        per-seed list `[FaultSet | FaultSchedule, ...]` (e.g.
        independently sampled failures per seed).  Rows map to
        `SweepResult.results` rows; `fault_fracs[i]` records row i's mean
        failed-link fraction (a schedule reports its final epoch).

        When the sweep itself was constructed with `faults`, every grid
        entry COMPOSES on top of that base set (an empty-FaultSet row
        means "just the base faults", not "pristine"); an invalid
        composition raises from `validate_faults`.
        """
        cfg = self.cfg
        seeds = [cfg.seed] if seeds is None else [int(s) for s in seeds]
        S = len(seeds)
        rows = [list(fs) if isinstance(fs, (list, tuple)) else [fs] * S
                for fs in fault_grid]
        if not rows or any(len(r) != S for r in rows):
            raise ValueError("fault_grid rows must match the seed count")
        F = len(rows)
        run = self.run_lanes(
            [(offered_per_chip, seeds[j], rows[i][j])
             for i in range(F) for j in range(S)])
        flat, fsets = run.results, run.fault_sets
        results = [[flat[i * S + j] for j in range(S)] for i in range(F)]
        fracs = [float(np.mean(
            [0.0 if f is None
             else final_faults(f).frac_links_failed(self.net)
             for f in fsets[i * S:(i + 1) * S]])) for i in range(F)]
        return SweepResult(rates=[offered_per_chip] * F, seeds=seeds,
                           results=results, compile_count=run.compile_count,
                           wall_s=run.wall_s, compile_s=run.compile_s,
                           fault_fracs=fracs, placement=run.placement,
                           pad_fraction=run.pad_fraction,
                           grant_form=run.grant_form,
                           occupancy_peak=run.occupancy_peak,
                           compact_capacity=run.compact_capacity,
                           superstep=run.superstep,
                           escalations=run.escalations,
                           escalation_compiles=run.escalation_compiles)
