"""Routing for the switch-less Dragonfly (paper Sec. IV) and the
switch-based baseline.

Route functions are pure, vectorizable jnp functions usable both inside the
jitted simulator and (via numpy inputs) by the offline path tracer that
builds the channel-dependency graph for the deadlock-freedom tests.

BATCH PURITY CONTRACT: a route function may only gather from the static
tables it closes over; it must never reduce over, reshape, or branch on the
shape of its packet-vector arguments.  `engine.sweep.BatchedSweep` vmaps the
whole cycle over a (rate x seed) lane axis, so any cross-packet coupling
here would silently change batched results (guarded by
tests/test_engine.py::test_route_fn_batch_pure).

FAULT AWARENESS: the fault-dependent tables (parallel-global re-pick,
per-W-group up*/down* next hops) are NOT closure constants — they live in
the `fl` dict produced by `route_tables(net, vc_mode, faults)` and are an
explicit first argument of the kernels (`make_route_kernel`), so a batched
sweep can stack them over a lane axis and run a failure-rate x seed grid in
one compile.  `make_route_fn` binds a kernel to one network's tables and
keeps the historical 4-argument closure signature.

Packet routing state ("meta" int32 bitfield):
  bits 0..2  cg_count  number of inter-C-group channels traversed so far
  bits 3..4  g_count   number of global channels traversed so far
  bit  5     via_ext   entered the current C-group through an external port

VC schemes (Sec. IV-A/B):
  baseline : VC = cg_count; 4 VCs minimal / 6 VCs non-minimal.
  reduced  : up*/down* labeling (Properties 1-2).  VC0 source C-group,
             VC1 intermediate C-group of the source W-group, VC2 anywhere in
             the destination W-group, VC3 intermediate (misroute) W-group.
             3 VCs when misroutes are restricted to lower W-groups
             ("reduced_restricted"), 4 otherwise ("reduced").
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .topology import (EJECT, GLOBAL, INJECT, LOCAL, MESH, FaultSet,
                       Network, validate_faults, wgroup_adjacency,
                       _wired_global_links)

# --- meta bitfield helpers ---------------------------------------------------

def meta_cg_count(meta):
    return meta & 0x7


def meta_g_count(meta):
    return (meta >> 3) & 0x3


def meta_via_ext(meta):
    return (meta >> 5) & 0x1


def meta_update(meta, ch_type):
    """Packet meta after traversing a channel of the given type."""
    is_ext = (ch_type == LOCAL) | (ch_type == GLOBAL)
    cg = jnp.minimum(meta_cg_count(meta) + is_ext, 7)
    g = jnp.minimum(meta_g_count(meta) + (ch_type == GLOBAL), 3)
    via = is_ext.astype(meta.dtype)
    keep_mesh = (ch_type == MESH)
    via = jnp.where(keep_mesh, meta_via_ext(meta), via)
    # INJECT resets everything (fresh packet): handled by sim (meta=0).
    return (cg | (g << 3) | (via << 5)).astype(meta.dtype)


def num_vcs(kind: str, vc_mode: str, nonminimal: bool) -> int:
    if kind == "switchless":
        if vc_mode == "baseline":
            return 6 if nonminimal else 4
        if vc_mode == "updown":
            # W-group-wide up*/down* (Autonet-style): one VC per W-group
            # visited.  2 VCs minimal, 3 non-minimal.
            return 3 if nonminimal else 2
        if vc_mode == "updown_merged":
            # misroutes restricted to W-groups below the destination merge
            # the intermediate and destination W-group VCs: 2 VCs total.
            return 2
        raise ValueError(vc_mode)
    if kind == "dragonfly":
        return 6 if nonminimal else 4  # per-hop increment scheme
    raise ValueError(kind)


# --- fault-dependent routing tables ------------------------------------------

def route_tables(net: Network, vc_mode: str = "baseline",
                 faults: FaultSet | None = None) -> dict:
    """Fault-dependent routing tables (the `fl` pytree the kernels read).

    Always contains the parallel-global-link re-pick tables
    (`glob_cnt [g, g]`, `glob_idx [g, g, npar]`: flows spread over the
    ALIVE parallel links of each W-group pair by destination hash); for the
    up*/down* modes it adds the per-W-group tables recomputed on the
    surviving graph (`ud_rank [g, NW]`, `ud_nh [g, NW, NW, 2]`).

    For a pristine network the tables reproduce the un-faulted routing
    bit-for-bit (`glob_idx` is the identity, `glob_cnt == glob_npar`).
    The kernels take this dict as an explicit argument, so a batched sweep
    can stack it over a lane axis and vmap one compiled step over lanes
    with DIFFERENT fault sets (see engine/sweep.py).
    """
    faults = faults or FaultSet()
    if not faults.is_empty:
        validate_faults(net, faults, vc_mode)
    ch_alive = faults.ch_alive(net)
    g = net.meta["g"]
    wired = _wired_global_links(net)                      # [g, g, npar]
    npar = wired.shape[-1]
    ok = (wired >= 0) & ch_alive[np.maximum(wired, 0)]
    cnt = ok.sum(-1)
    idx = np.zeros((g, g, npar), dtype=np.int64)
    for w in range(g):
        for u in range(g):
            alive = np.flatnonzero(ok[w, u])
            idx[w, u, :len(alive)] = alive
    fl = dict(glob_cnt=jnp.asarray(np.maximum(cnt, 1)),
              glob_idx=jnp.asarray(idx))
    if net.meta["kind"] == "switchless" and vc_mode != "baseline":
        rank, nh = build_updown_tables(net, faults=faults)
        fl["ud_rank"] = jnp.asarray(rank)
        fl["ud_nh"] = jnp.asarray(nh)
    return fl


# --- switch-less Dragonfly route function -----------------------------------

def make_route_kernel(net: Network, vc_mode: str = "baseline"):
    """Returns kernel(fl, cur_node, dest_term, mis_wg, meta)
    -> (out_ch, req_vc, new_meta).

    `fl` is the fault-dependent table dict of `route_tables` (an explicit
    argument, NOT a closure constant, so the engine can vmap one compiled
    kernel over per-lane fault sets).  mis_wg == -1 means no (remaining)
    misroute; the simulator clears it when the packet enters the
    intermediate W-group.  `out_ch` is a channel id (MESH / LOCAL / GLOBAL
    / EJECT).  `req_vc` is the VC of the downstream buffer the packet will
    occupy.
    """
    if net.meta["kind"] != "switchless":
        return _make_dragonfly_kernel(net)
    if vc_mode == "baseline":
        return _make_switchless_baseline(net)
    if vc_mode in ("updown", "updown_merged"):
        return _make_switchless_updown(net, vc_mode)
    raise ValueError(vc_mode)




def _make_switchless_baseline(net: Network):
    """Alg. 1 with XY in-C-group routing; VC = #C-groups entered (4/6 VCs)."""
    t = net.tables
    node_wg = jnp.asarray(t["node_wg"])
    node_cg = jnp.asarray(t["node_cg"])
    node_cgg = jnp.asarray(t["node_cg_global"])
    node_x = jnp.asarray(t["node_x"])
    node_y = jnp.asarray(t["node_y"])
    node_mesh_ch = jnp.asarray(t["node_mesh_ch"])
    eject_ch = jnp.asarray(t["eject_ch"])
    ext_out = jnp.asarray(t["ext_out"])
    local_port = jnp.asarray(t["local_port"])
    port_node_local = jnp.asarray(t["port_node_local"])
    term_node = jnp.asarray(t["term_node"])
    ch_type = jnp.asarray(net.ch_type)
    R = net.meta["R"]
    nodes_per_cg = net.meta["nodes_per_cg"]
    # packed gathers: destination-indexed node record and the (cg, port)
    # record of the global exit — one dynamic row gather each instead of
    # three/two (row count, not width, is what CPU gather loops pay for)
    dnode_tbl = jnp.stack([node_wg, node_cgg, node_cg], axis=-1)   # [V, 3]
    glob_tbl = jnp.stack([jnp.asarray(t["glob_route_cg"]),
                          jnp.asarray(t["glob_route_port"])], axis=-1)

    def route_vc(fl, cur, dest_term, mis_wg, meta):
        dest_node = term_node[dest_term]
        dtbl = dnode_tbl[dest_node]
        wg_c = node_wg[cur]
        wg_d = dtbl[..., 0]
        mis_active = mis_wg >= 0
        tgt_wg = jnp.where(mis_active, mis_wg, wg_d)
        cg_c = node_cg[cur]
        cgg_c = node_cgg[cur]
        cgg_d = dtbl[..., 1]
        cg_d = dtbl[..., 2]

        in_tgt_wg = wg_c == tgt_wg          # mis cleared on entry => == wg_d
        at_dest_cg = (cgg_c == cgg_d) & (~mis_active)

        # exit port selection (Alg. 1 steps); parallel global links per
        # W-group pair are spread across flows by destination hash over the
        # ALIVE links (fl re-picks around dead parallel globals)
        par = fl["glob_idx"][wg_c, tgt_wg,
                             dest_term % fl["glob_cnt"][wg_c, tgt_wg]]
        gtbl = glob_tbl[wg_c, tgt_wg, par]
        cg_gl = gtbl[..., 0]                         # owner of global channel
        port_gl = gtbl[..., 1]
        at_global_cg = cg_c == cg_gl
        peer_cg = jnp.where(in_tgt_wg, cg_d, cg_gl)
        port_lc = local_port[cg_c, peer_cg]
        use_global = (~in_tgt_wg) & at_global_cg
        port = jnp.where(use_global, port_gl, port_lc)
        to_terminal = at_dest_cg

        tgt_local = jnp.where(to_terminal,
                              dest_node % nodes_per_cg,
                              port_node_local[port])
        cur_local = cur % nodes_per_cg
        at_target = cur_local == tgt_local
        out_at_target = jnp.where(to_terminal, eject_ch[cur],
                                  ext_out[cgg_c, port])

        # XY (dimension-order): x first, then y.  DIRS = (N, E, S, W).
        tx = tgt_local % R
        ty = tgt_local // R
        x = node_x[cur]
        y = node_y[cur]
        dir_xy = jnp.where(
            x != tx, jnp.where(tx > x, 1, 3), jnp.where(ty > y, 2, 0))
        out_mesh = node_mesh_ch[cur, dir_xy]

        out_ch = jnp.where(at_target, out_at_target, out_mesh)
        new_meta = meta_update(meta, ch_type[out_ch])
        is_ej = ch_type[out_ch] == EJECT
        req_vc = jnp.where(is_ej, 0, meta_cg_count(new_meta))
        return out_ch, req_vc.astype(jnp.int32), new_meta

    return route_vc


def _updown_single(NW: int, nbrs, alive: np.ndarray):
    """up*/down* tables over ONE W-group graph restricted to alive routers.

    Autonet-style: rank routers by BFS (depth, id) from the lowest-id alive
    router; a channel u->w is *up* iff rank(w) < rank(u).  Legal paths take
    all up hops before any down hop, which makes the channel dependency
    graph acyclic for ANY (sub)graph — so rebuilding the tables on a
    degraded W-group preserves deadlock freedom by construction.

    Returns (rank [NW], nh [NW, NW, 2]); dead routers keep the trailing
    ranks and -1 next-hops (they are never a source, hop, or target).
    """
    depth = np.full(NW, -1)
    root = int(np.flatnonzero(alive)[0])
    depth[root] = 0
    q = [root]
    while q:
        u = q.pop(0)
        for w, _ in nbrs[u]:
            if depth[w] < 0:
                depth[w] = depth[u] + 1
                q.append(w)
    assert (depth[alive] >= 0).all(), \
        "surviving W-group graph must be connected"
    # alive routers ordered by (depth, id); dead routers pushed to the end
    key = np.where(alive, depth, NW) * NW + np.arange(NW)
    rank = np.argsort(np.argsort(key))

    INF = 10**9
    f1 = np.full((NW, NW), INF, dtype=np.int64)   # down-phase distance
    nh1 = np.full((NW, NW), -1, dtype=np.int32)
    np.fill_diagonal(f1, 0)
    order_desc = np.argsort(-rank)
    for u in order_desc:
        for w, wt in nbrs[u]:
            if rank[w] > rank[u]:  # down edge
                cand = wt + f1[w]
                upd = cand < f1[u]
                f1[u][upd] = cand[upd]
                nh1[u][upd] = w
    f0 = f1.copy()
    nh0 = nh1.copy()
    order_asc = np.argsort(rank)
    for u in order_asc:
        for w, wt in nbrs[u]:
            if rank[w] < rank[u]:  # up edge
                cand = wt + f0[w]
                upd = cand < f0[u]
                f0[u][upd] = cand[upd]
                nh0[u][upd] = w
    live = np.ix_(alive, alive)
    assert (f0[live][~np.eye(int(alive.sum()), dtype=bool)] < INF).all(), \
        "up*/down* must connect all alive routers"
    nh = np.stack([nh0, nh1], axis=-1)
    return rank.astype(np.int32), nh


def build_updown_tables(net: Network, faults: FaultSet | None = None):
    """Per-W-group all-pairs up*/down* next-hop tables.

    Pristine W-groups share one table (computed once, tiled); W-groups
    touched by `faults` get their tables recomputed on the surviving
    subgraph, which is how the up*/down* modes route around dead mesh
    channels, dead local links, and dead routers.

    Returns (rank [g, NW], nh [g, NW, NW, 2]) where nh[wg, u, v, phase] is
    the next wg-local router towards v (phase 1 = a down hop was already
    taken).
    """
    meta = net.meta
    ab, npc = meta["ab"], meta["nodes_per_cg"]
    g = meta["g"]
    NW = ab * npc
    faults = faults or FaultSet()
    # W-groups the fault set touches, straight from its members (dead
    # routers, dead mesh/local channels); only those need a rebuild
    touched = {int(r) // NW for r in faults.dead_routers}
    touched |= {int(net.ch_src[c]) // NW for c in faults.dead_ch
                if net.ch_type[c] in (MESH, LOCAL)}
    pristine_adj, _ = wgroup_adjacency(net, wgs=[0])
    base = _updown_single(NW, pristine_adj[0], np.ones(NW, dtype=bool))
    rank = np.repeat(base[0][None], g, axis=0)
    nh = np.repeat(base[1][None], g, axis=0)
    if touched:
        adj, alive = wgroup_adjacency(net, faults, wgs=touched)
        for wg in sorted(touched):
            rank[wg], nh[wg] = _updown_single(NW, adj[wg], alive[wg])
    return rank, nh


def _make_switchless_updown(net: Network, vc_mode: str):
    """W-group-wide up*/down* routing: 2 VCs minimal / 3 non-minimal
    ("updown"), or 2 VCs with misroutes restricted to W-groups below the
    destination ("updown_merged").  The per-W-group rank/next-hop tables
    come from `fl` (rebuilt on the surviving subgraph when faulted)."""
    t = net.tables
    node_wg = jnp.asarray(t["node_wg"])
    node_mesh_ch = jnp.asarray(t["node_mesh_ch"])
    eject_ch = jnp.asarray(t["eject_ch"])
    ext_out = jnp.asarray(t["ext_out"])
    local_port = jnp.asarray(t["local_port"])
    glob_route_cg = jnp.asarray(t["glob_route_cg"])
    glob_route_port = jnp.asarray(t["glob_route_port"])
    port_node_local = jnp.asarray(t["port_node_local"])
    term_node = jnp.asarray(t["term_node"])
    ch_type = jnp.asarray(net.ch_type)
    R = net.meta["R"]
    npc = net.meta["nodes_per_cg"]
    ab = net.meta["ab"]
    NW = ab * npc
    merged = vc_mode == "updown_merged"
    PHASE = 1 << 6

    def route_vc(fl, cur, dest_term, mis_wg, meta):
        rank, nh = fl["ud_rank"], fl["ud_nh"]
        dest_node = term_node[dest_term]
        wg_c = node_wg[cur]
        wg_d = node_wg[dest_node]
        mis_active = mis_wg >= 0
        tgt_wg = jnp.where(mis_active, mis_wg, wg_d)
        in_final = (wg_c == wg_d) & (~mis_active)
        u = cur % NW

        par = fl["glob_idx"][wg_c, tgt_wg,
                             dest_term % fl["glob_cnt"][wg_c, tgt_wg]]
        cg_gl = glob_route_cg[wg_c, tgt_wg, par]
        port_gl = glob_route_port[wg_c, tgt_wg, par]
        v_exit = cg_gl * npc + port_node_local[port_gl]
        v = jnp.where(in_final, dest_node % NW, v_exit)
        arrived = u == v
        out_arr = jnp.where(in_final, eject_ch[cur],
                            ext_out[wg_c * ab + cg_gl, port_gl])

        phase = (meta >> 6) & 1
        w = nh[wg_c, u, v, phase]
        same_cg = (u // npc) == (w // npc)
        ux, uy = (u % npc) % R, (u % npc) // R
        wx, wy = (w % npc) % R, (w % npc) // R
        dir_idx = jnp.where(wy < uy, 0, jnp.where(wx > ux, 1,
                  jnp.where(wy > uy, 2, 3)))
        out_mesh = node_mesh_ch[cur, dir_idx]
        out_local = ext_out[wg_c * ab + u // npc,
                            local_port[u // npc, w // npc]]
        out_step = jnp.where(same_cg, out_mesh, out_local)
        out_ch = jnp.where(arrived, out_arr, out_step)

        new_meta = meta_update(meta, ch_type[out_ch])
        went_down = phase | (rank[wg_c, w] > rank[wg_c, u])
        is_glob = ch_type[out_ch] == GLOBAL  # GLOBAL resets the phase
        new_phase = jnp.where(is_glob, 0,
                              jnp.where(arrived, phase, went_down))
        new_meta = (new_meta & ~PHASE) | (new_phase.astype(jnp.int32) << 6)

        g = meta_g_count(new_meta)
        req_vc = jnp.minimum(g, 1) if merged else jnp.minimum(g, 2)
        is_ej = ch_type[out_ch] == EJECT
        req_vc = jnp.where(is_ej, 0, req_vc)
        return out_ch, req_vc.astype(jnp.int32), new_meta

    return route_vc


# --- switch-based Dragonfly route function ----------------------------------

def _make_dragonfly_kernel(net: Network):
    t = net.tables
    node_grp = jnp.asarray(t["node_grp"])
    node_idx = jnp.asarray(t["node_idx"])
    local_ch = jnp.asarray(t["local_ch"])
    glob_route_sw = jnp.asarray(t["glob_route_sw"])
    glob_out_ch = jnp.asarray(t["glob_out_ch"])
    eject_sw_term = jnp.asarray(t["eject_sw_term"])
    term_node = jnp.asarray(t["term_node"])
    term_slot = jnp.asarray(t["term_slot"])
    ch_type = jnp.asarray(net.ch_type)

    def route_vc(fl, cur, dest_term, mis_wg, meta):
        dest_sw = term_node[dest_term]
        grp_c = node_grp[cur]
        grp_d = node_grp[dest_sw]
        mis_active = mis_wg >= 0
        tgt_grp = jnp.where(mis_active, mis_wg, grp_d)

        at_dest_sw = (cur == dest_sw) & (~mis_active)
        par = fl["glob_idx"][grp_c, tgt_grp,
                             dest_term % fl["glob_cnt"][grp_c, tgt_grp]]
        sw_gl = glob_route_sw[grp_c, tgt_grp, par]
        in_tgt = grp_c == tgt_grp
        peer_sw = jnp.where(in_tgt, dest_sw, sw_gl)
        use_global = (~in_tgt) & (cur == sw_gl)

        out_ch = jnp.where(
            at_dest_sw, eject_sw_term[cur, term_slot[dest_term]],
            jnp.where(use_global, glob_out_ch[grp_c, tgt_grp, par],
                      local_ch[cur, node_idx[peer_sw]]))
        new_meta = meta_update(meta, ch_type[out_ch])
        req_vc = meta_cg_count(new_meta)  # per-hop increment scheme
        is_ej = ch_type[out_ch] == EJECT
        req_vc = jnp.where(is_ej, 0, req_vc)
        return out_ch, req_vc.astype(jnp.int32), new_meta

    return route_vc


def make_route_fn(net: Network, vc_mode: str = "baseline",
                  faults: FaultSet | None = None):
    """Route closure route(cur, dest_term, mis_wg, meta) over the
    (possibly degraded) network: the kind-dispatched kernel bound to this
    network's (possibly faulted) tables.  Minimal, non-minimal, and UGAL
    modes all route around the faults via the rebuilt tables
    (`route_tables`)."""
    kernel = make_route_kernel(net, vc_mode)
    fl = route_tables(net, vc_mode, faults)
    return lambda cur, dest, mis, meta: kernel(fl, cur, dest, mis, meta)


# --- offline path tracing + channel dependency graph ------------------------

def trace_paths(net: Network, route_fn, src_terms: np.ndarray,
                dst_terms: np.ndarray, mis_wgs: np.ndarray,
                max_hops: int | None = None):
    """Walk packets hop-by-hop with no contention.

    Returns (channels [B, H], vcs [B, H], lengths [B]) with -1 padding.
    """
    import jax
    B = len(src_terms)
    if max_hops is None:
        R = net.meta.get("R", 2)
        max_hops = 8 * (4 * R + 4) + 16
    term_node = net.term_node
    node_wg_tbl = net.tables.get("node_wg", net.tables.get("node_grp"))
    ch_dst = net.ch_dst
    ch_typ = net.ch_type

    step = jax.jit(lambda cur, dst, mis, meta: route_fn(cur, dst, mis, meta))

    cur = term_node[src_terms].copy()
    meta = np.zeros(B, dtype=np.int32)
    mis = mis_wgs.astype(np.int32).copy()
    # misroute is pointless/undefined if src and dst share the W-group
    same = node_wg_tbl[term_node[src_terms]] == node_wg_tbl[term_node[dst_terms]]
    mis = np.where(same, -1, mis)
    done = np.zeros(B, dtype=bool)
    chans = np.full((B, max_hops), -1, dtype=np.int64)
    vcs = np.full((B, max_hops), -1, dtype=np.int32)
    for hstep in range(max_hops):
        if done.all():
            break
        out_ch, vc, new_meta = map(np.asarray, step(
            jnp.asarray(cur), jnp.asarray(dst_terms), jnp.asarray(mis),
            jnp.asarray(meta)))
        act = ~done
        chans[act, hstep] = out_ch[act]
        vcs[act, hstep] = vc[act]
        nxt = ch_dst[out_ch]
        is_eject = ch_typ[out_ch] == EJECT
        # clear mis on entering the intermediate W-group
        entered_mis = (mis >= 0) & (node_wg_tbl[np.clip(nxt, 0, net.num_nodes - 1)] == mis) \
            & ~is_eject
        mis = np.where(act & entered_mis, -1, mis)
        meta = np.where(act, new_meta, meta)
        cur = np.where(act & ~is_eject, nxt, cur)
        done = done | (act & is_eject)
    if not done.all():
        bad = np.where(~done)[0][:5]
        raise RuntimeError(
            f"paths did not terminate within {max_hops} hops; e.g. "
            f"src={src_terms[bad]}, dst={dst_terms[bad]}, mis={mis_wgs[bad]}")
    lengths = (chans >= 0).sum(axis=1)
    return chans, vcs, lengths


def build_cdg(chans: np.ndarray, vcs: np.ndarray):
    """Channel-dependency graph over (channel, vc) pairs from traced paths."""
    import networkx as nx
    B, H = chans.shape
    g = nx.DiGraph()
    c0, v0 = chans[:, :-1], vcs[:, :-1]
    c1, v1 = chans[:, 1:], vcs[:, 1:]
    valid = (c0 >= 0) & (c1 >= 0)
    a = np.stack([c0[valid], v0[valid], c1[valid], v1[valid]], axis=1)
    a = np.unique(a, axis=0)
    g.add_edges_from(((int(r[0]), int(r[1])), (int(r[2]), int(r[3])))
                     for r in a)
    return g


def assert_deadlock_free(net: Network, vc_mode: str, nonminimal: bool,
                         rng: np.random.Generator, n_pairs: int = 4000,
                         exhaustive_limit: int = 250_000,
                         faults: FaultSet | None = None) -> int:
    """Trace flows and assert the CDG is acyclic.  Returns #edges checked.

    With `faults`, flows run between alive terminals on the degraded
    network; the trace additionally asserts no path crosses a dead channel
    (re-proving deadlock freedom AND fault avoidance on the survivors).
    """
    import networkx as nx
    route_fn = make_route_fn(net, vc_mode, faults)
    T = net.num_terminals
    terms = (np.arange(T) if faults is None
             else np.flatnonzero(faults.term_alive(net)))
    TA = len(terms)
    if TA * TA <= exhaustive_limit and not nonminimal:
        si, di = np.divmod(np.arange(TA * TA), TA)
        s, d = terms[si], terms[di]
        keep = s != d
        s, d = s[keep], d[keep]
    else:
        s = terms[rng.integers(0, TA, size=n_pairs)]
        d = terms[rng.integers(0, TA, size=n_pairs)]
        keep = s != d
        s, d = s[keep], d[keep]
    if nonminimal:
        wg_tbl = net.tables.get("node_wg", net.tables.get("node_grp"))
        g = int(wg_tbl.max()) + 1
        wg_s = wg_tbl[net.term_node[s]]
        wg_d = wg_tbl[net.term_node[d]]
        if vc_mode == "updown_merged":
            # misroute only to W-groups strictly below the destination
            hi = np.maximum(wg_d, 1)
            mis = rng.integers(0, hi)
            bad = (mis == wg_s) | (mis == wg_d) | (wg_d == 0)
            mis = np.where(bad, -1, mis)
        else:
            mis = rng.integers(0, g, size=len(s))
            bad = (mis == wg_s) | (mis == wg_d)
            mis = np.where(bad, -1, mis)
    else:
        mis = np.full(len(s), -1, dtype=np.int64)
    chans, vcs, _ = trace_paths(net, route_fn, s, d, mis)
    if faults is not None:
        alive = faults.ch_alive(net)
        used = chans[chans >= 0]
        if not alive[used].all():
            bad = np.unique(used[~alive[used]])
            raise AssertionError(
                f"faulted routing crossed dead channels {bad[:8]} "
                f"({net.name}, vc_mode={vc_mode})")
    cdg = build_cdg(chans, vcs)
    if not nx.is_directed_acyclic_graph(cdg):
        cyc = nx.find_cycle(cdg)
        raise AssertionError(
            f"CDG cycle for {net.name} vc_mode={vc_mode} "
            f"nonmin={nonminimal}: {cyc[:12]}")
    return cdg.number_of_edges()
