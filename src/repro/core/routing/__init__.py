"""Routing for the switch-less Dragonfly (paper Sec. IV) and the
switch-based baseline — as a package of pluggable pipeline stages.

Layout (the 581-line module this package replaced kept all of this in one
file; the public API is unchanged — `make_route_fn`, `route_tables`,
`assert_deadlock_free` et al. import exactly as before):

    vcs.py        VC schemes (`num_vcs`) + the packet meta bitfield
    tables.py     fault-dependent routing tables (`route_tables`,
                  `build_updown_tables`) and their per-epoch stacking for
                  time-varying `FaultSchedule`s (`stack_epoch_tables`)
    kernels/      one module per scheme (baseline XY / up*-down* /
                  switch-based dragonfly), all obeying the same
                  batch-pure `kernel(fl, cur, dest, mis, meta)` protocol
    pipeline.py   `RoutePipeline` (the protocol object) + the historical
                  `make_route_kernel` / `make_route_fn` entry points
    verify.py     offline path tracing, CDG construction, and the
                  deadlock-freedom proofs — per fault set
                  (`assert_deadlock_free`) and per epoch of a schedule
                  (`assert_schedule_deadlock_free`)

FAULT AWARENESS: the fault-dependent tables (parallel-global re-pick,
per-W-group up*/down* next hops) are NOT closure constants — they live in
the `fl` dict produced by `route_tables(net, vc_mode, faults)` and are an
explicit first argument of the kernels, so a batched sweep can stack them
over a lane axis (different fault sets per lane) or an epoch axis (a
`FaultSchedule`'s mid-run link deaths) and run the whole grid in one
compile.  `make_route_fn` binds a kernel to one network's tables and keeps
the historical 4-argument closure signature.
"""
from .vcs import (PHASE_BIT, meta_cg_count, meta_g_count, meta_update,
                  meta_via_ext, num_vcs)
from .tables import (build_updown_tables, route_tables, stack_epoch_dicts,
                     stack_epoch_tables, _updown_single)
from .pipeline import (RoutePipeline, make_pipeline, make_route_fn,
                       make_route_kernel)
from .verify import (assert_deadlock_free, assert_schedule_deadlock_free,
                     build_cdg, trace_paths)

__all__ = [
    "PHASE_BIT", "meta_cg_count", "meta_g_count", "meta_update",
    "meta_via_ext", "num_vcs",
    "build_updown_tables", "route_tables", "stack_epoch_dicts",
    "stack_epoch_tables",
    "RoutePipeline", "make_pipeline", "make_route_fn", "make_route_kernel",
    "assert_deadlock_free", "assert_schedule_deadlock_free", "build_cdg",
    "trace_paths",
]
