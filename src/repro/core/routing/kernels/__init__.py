"""Route kernels, one module per scheme.

Every kernel obeys the same `RoutePipeline` calling convention
(`kernel(fl, cur, dest_term, mis_wg, meta)`, see `..pipeline`): the
fault-dependent tables `fl` are an explicit argument, never a closure
constant, so the engine can vmap one compiled kernel over per-lane fault
sets and select among per-epoch tables of a `FaultSchedule` by a traced
epoch index.

BATCH PURITY CONTRACT: a kernel may only gather from the static tables it
closes over (and the `fl` dict it is handed); it must never reduce over,
reshape, or branch on the shape of its packet-vector arguments.
`engine.sweep.BatchedSweep` vmaps the whole cycle over a (rate x seed x
fault) lane axis, so any cross-packet coupling here would silently change
batched results (guarded by tests/test_engine.py::test_route_fn_batch_pure).
"""
from .baseline import make_baseline_kernel
from .updown import make_updown_kernel
from .dragonfly import make_dragonfly_kernel

__all__ = ["make_baseline_kernel", "make_updown_kernel",
           "make_dragonfly_kernel"]
