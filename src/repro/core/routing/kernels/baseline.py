"""Switch-less Dragonfly baseline kernel: Alg. 1 with XY in-C-group
routing; VC = #C-groups entered (4 VCs minimal / 6 non-minimal)."""
from __future__ import annotations

import jax.numpy as jnp

from ...topology import EJECT, Network
from ..vcs import meta_cg_count, meta_update


def make_baseline_kernel(net: Network):
    """kernel(fl, cur, dest_term, mis_wg, meta) -> (out_ch, req_vc, meta')."""
    t = net.tables
    node_wg = jnp.asarray(t["node_wg"])
    node_cg = jnp.asarray(t["node_cg"])
    node_cgg = jnp.asarray(t["node_cg_global"])
    node_x = jnp.asarray(t["node_x"])
    node_y = jnp.asarray(t["node_y"])
    node_mesh_ch = jnp.asarray(t["node_mesh_ch"])
    eject_ch = jnp.asarray(t["eject_ch"])
    ext_out = jnp.asarray(t["ext_out"])
    local_port = jnp.asarray(t["local_port"])
    port_node_local = jnp.asarray(t["port_node_local"])
    term_node = jnp.asarray(t["term_node"])
    ch_type = jnp.asarray(net.ch_type)
    R = net.meta["R"]
    nodes_per_cg = net.meta["nodes_per_cg"]
    # packed gathers: destination-indexed node record and the (cg, port)
    # record of the global exit — one dynamic row gather each instead of
    # three/two (row count, not width, is what CPU gather loops pay for)
    dnode_tbl = jnp.stack([node_wg, node_cgg, node_cg], axis=-1)   # [V, 3]
    glob_tbl = jnp.stack([jnp.asarray(t["glob_route_cg"]),
                          jnp.asarray(t["glob_route_port"])], axis=-1)

    def route_vc(fl, cur, dest_term, mis_wg, meta):
        dest_node = term_node[dest_term]
        dtbl = dnode_tbl[dest_node]
        wg_c = node_wg[cur]
        wg_d = dtbl[..., 0]
        mis_active = mis_wg >= 0
        tgt_wg = jnp.where(mis_active, mis_wg, wg_d)
        cg_c = node_cg[cur]
        cgg_c = node_cgg[cur]
        cgg_d = dtbl[..., 1]
        cg_d = dtbl[..., 2]

        in_tgt_wg = wg_c == tgt_wg          # mis cleared on entry => == wg_d
        at_dest_cg = (cgg_c == cgg_d) & (~mis_active)

        # exit port selection (Alg. 1 steps); parallel global links per
        # W-group pair are spread across flows by destination hash over the
        # ALIVE links (fl re-picks around dead parallel globals)
        par = fl["glob_idx"][wg_c, tgt_wg,
                             dest_term % fl["glob_cnt"][wg_c, tgt_wg]]
        gtbl = glob_tbl[wg_c, tgt_wg, par]
        cg_gl = gtbl[..., 0]                         # owner of global channel
        port_gl = gtbl[..., 1]
        at_global_cg = cg_c == cg_gl
        peer_cg = jnp.where(in_tgt_wg, cg_d, cg_gl)
        port_lc = local_port[cg_c, peer_cg]
        use_global = (~in_tgt_wg) & at_global_cg
        port = jnp.where(use_global, port_gl, port_lc)
        to_terminal = at_dest_cg

        tgt_local = jnp.where(to_terminal,
                              dest_node % nodes_per_cg,
                              port_node_local[port])
        cur_local = cur % nodes_per_cg
        at_target = cur_local == tgt_local
        out_at_target = jnp.where(to_terminal, eject_ch[cur],
                                  ext_out[cgg_c, port])

        # XY (dimension-order): x first, then y.  DIRS = (N, E, S, W).
        tx = tgt_local % R
        ty = tgt_local // R
        x = node_x[cur]
        y = node_y[cur]
        dir_xy = jnp.where(
            x != tx, jnp.where(tx > x, 1, 3), jnp.where(ty > y, 2, 0))
        out_mesh = node_mesh_ch[cur, dir_xy]

        out_ch = jnp.where(at_target, out_at_target, out_mesh)
        new_meta = meta_update(meta, ch_type[out_ch])
        is_ej = ch_type[out_ch] == EJECT
        req_vc = jnp.where(is_ej, 0, meta_cg_count(new_meta))
        return out_ch, req_vc.astype(jnp.int32), new_meta

    return route_vc
