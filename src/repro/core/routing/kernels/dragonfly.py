"""Switch-based Dragonfly kernel (the paper's baseline, Kim et al. 2008):
minimal l-g-l with optional Valiant group misroute; per-hop VC increment."""
from __future__ import annotations

import jax.numpy as jnp

from ...topology import EJECT, Network
from ..vcs import meta_cg_count, meta_update


def make_dragonfly_kernel(net: Network):
    """kernel(fl, cur, dest_term, mis_wg, meta) -> (out_ch, req_vc, meta')."""
    t = net.tables
    node_grp = jnp.asarray(t["node_grp"])
    node_idx = jnp.asarray(t["node_idx"])
    local_ch = jnp.asarray(t["local_ch"])
    glob_route_sw = jnp.asarray(t["glob_route_sw"])
    glob_out_ch = jnp.asarray(t["glob_out_ch"])
    eject_sw_term = jnp.asarray(t["eject_sw_term"])
    term_node = jnp.asarray(t["term_node"])
    term_slot = jnp.asarray(t["term_slot"])
    ch_type = jnp.asarray(net.ch_type)

    def route_vc(fl, cur, dest_term, mis_wg, meta):
        dest_sw = term_node[dest_term]
        grp_c = node_grp[cur]
        grp_d = node_grp[dest_sw]
        mis_active = mis_wg >= 0
        tgt_grp = jnp.where(mis_active, mis_wg, grp_d)

        at_dest_sw = (cur == dest_sw) & (~mis_active)
        par = fl["glob_idx"][grp_c, tgt_grp,
                             dest_term % fl["glob_cnt"][grp_c, tgt_grp]]
        sw_gl = glob_route_sw[grp_c, tgt_grp, par]
        in_tgt = grp_c == tgt_grp
        peer_sw = jnp.where(in_tgt, dest_sw, sw_gl)
        use_global = (~in_tgt) & (cur == sw_gl)

        out_ch = jnp.where(
            at_dest_sw, eject_sw_term[cur, term_slot[dest_term]],
            jnp.where(use_global, glob_out_ch[grp_c, tgt_grp, par],
                      local_ch[cur, node_idx[peer_sw]]))
        new_meta = meta_update(meta, ch_type[out_ch])
        req_vc = meta_cg_count(new_meta)  # per-hop increment scheme
        is_ej = ch_type[out_ch] == EJECT
        req_vc = jnp.where(is_ej, 0, req_vc)
        return out_ch, req_vc.astype(jnp.int32), new_meta

    return route_vc
