"""Switch-less up*/down* kernels: W-group-wide up*/down* routing over the
per-W-group rank/next-hop tables of `fl` (rebuilt on the surviving
subgraph when faulted).  2 VCs minimal / 3 non-minimal ("updown"), or
2 VCs with misroutes restricted to W-groups below the destination
("updown_merged")."""
from __future__ import annotations

import jax.numpy as jnp

from ...topology import EJECT, GLOBAL, Network
from ..vcs import PHASE_BIT, meta_g_count, meta_update


def make_updown_kernel(net: Network, vc_mode: str):
    """kernel(fl, cur, dest_term, mis_wg, meta) -> (out_ch, req_vc, meta')."""
    t = net.tables
    node_wg = jnp.asarray(t["node_wg"])
    node_mesh_ch = jnp.asarray(t["node_mesh_ch"])
    eject_ch = jnp.asarray(t["eject_ch"])
    ext_out = jnp.asarray(t["ext_out"])
    local_port = jnp.asarray(t["local_port"])
    glob_route_cg = jnp.asarray(t["glob_route_cg"])
    glob_route_port = jnp.asarray(t["glob_route_port"])
    port_node_local = jnp.asarray(t["port_node_local"])
    term_node = jnp.asarray(t["term_node"])
    ch_type = jnp.asarray(net.ch_type)
    R = net.meta["R"]
    npc = net.meta["nodes_per_cg"]
    ab = net.meta["ab"]
    NW = ab * npc
    merged = vc_mode == "updown_merged"

    def route_vc(fl, cur, dest_term, mis_wg, meta):
        rank, nh = fl["ud_rank"], fl["ud_nh"]
        dest_node = term_node[dest_term]
        wg_c = node_wg[cur]
        wg_d = node_wg[dest_node]
        mis_active = mis_wg >= 0
        tgt_wg = jnp.where(mis_active, mis_wg, wg_d)
        in_final = (wg_c == wg_d) & (~mis_active)
        u = cur % NW

        par = fl["glob_idx"][wg_c, tgt_wg,
                             dest_term % fl["glob_cnt"][wg_c, tgt_wg]]
        cg_gl = glob_route_cg[wg_c, tgt_wg, par]
        port_gl = glob_route_port[wg_c, tgt_wg, par]
        v_exit = cg_gl * npc + port_node_local[port_gl]
        v = jnp.where(in_final, dest_node % NW, v_exit)
        arrived = u == v
        out_arr = jnp.where(in_final, eject_ch[cur],
                            ext_out[wg_c * ab + cg_gl, port_gl])

        phase = (meta >> 6) & 1
        # one row gather pulls both phases' next hops; select by phase.
        # WARM-FAULT RECOVERY: when an epoch swap rebuilt the tables, a
        # packet that had already taken a down hop may find its down-only
        # continuation gone (nh == -1) — restart it on the full up*/down*
        # path (phase 0), which reaches every alive target of a connected
        # surviving W-group.  If even that is -1 (the packet sits at a
        # router that died, or its target died), the packet STRANDS: it
        # emits the -1 non-channel, which arbitration never grants, so it
        # stays buffered and accounted in-flight instead of corrupting a
        # gather.  Cold lanes never take either branch.
        nh_uv = nh[wg_c, u, v]                     # [..., 2]
        w_ph = jnp.where(phase == 1, nh_uv[..., 1], nh_uv[..., 0])
        restart = w_ph < 0
        w = jnp.where(restart, nh_uv[..., 0], w_ph)
        phase = jnp.where(restart, 0, phase)
        stranded = w < 0
        w = jnp.maximum(w, 0)                      # safe gather index only
        same_cg = (u // npc) == (w // npc)
        ux, uy = (u % npc) % R, (u % npc) // R
        wx, wy = (w % npc) % R, (w % npc) // R
        dir_idx = jnp.where(wy < uy, 0, jnp.where(wx > ux, 1,
                  jnp.where(wy > uy, 2, 3)))
        out_mesh = node_mesh_ch[cur, dir_idx]
        out_local = ext_out[wg_c * ab + u // npc,
                            local_port[u // npc, w // npc]]
        out_step = jnp.where(same_cg, out_mesh, out_local)
        out_ch = jnp.where(arrived, out_arr, out_step)
        out_ch = jnp.where(stranded & ~arrived, -1, out_ch)

        new_meta = meta_update(meta, ch_type[out_ch])
        went_down = phase | (rank[wg_c, w] > rank[wg_c, u])
        is_glob = ch_type[out_ch] == GLOBAL  # GLOBAL resets the phase
        new_phase = jnp.where(is_glob, 0,
                              jnp.where(arrived, phase, went_down))
        new_meta = (new_meta & ~PHASE_BIT) \
            | (new_phase.astype(jnp.int32) << 6)

        g = meta_g_count(new_meta)
        req_vc = jnp.minimum(g, 1) if merged else jnp.minimum(g, 2)
        is_ej = ch_type[out_ch] == EJECT
        req_vc = jnp.where(is_ej, 0, req_vc)
        return out_ch, req_vc.astype(jnp.int32), new_meta

    return route_vc
