"""`RoutePipeline`: the single protocol every route kernel plugs into.

A pipeline is (network, vc_mode, kernel) where the kernel is a pure,
batch-pure function

    kernel(fl, cur_node, dest_term, mis_wg, meta) -> (out_ch, req_vc, meta')

whose fault-dependent tables `fl` are an explicit traced argument (the
dict of `tables.route_tables`).  Because the kernel never closes over
fault state, the same compiled kernel serves:

  * the pristine network (`fl` from `route_tables(net, vc_mode)`),
  * one cold fault set per lane (lane-stacked `fl`, `engine.sweep`),
  * a time-varying `FaultSchedule` — `epoch_tables` stacks one table set
    per epoch and the engine selects the active epoch's slice by a traced
    epoch index before calling the kernel.

`make_route_kernel` / `make_route_fn` keep the historical functional API;
`make_pipeline` returns the pipeline object new code should prefer.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from ..topology import FaultSchedule, FaultSet, Network
from .kernels import (make_baseline_kernel, make_dragonfly_kernel,
                      make_updown_kernel)
from .tables import route_tables, stack_epoch_tables
from .vcs import num_vcs


@dataclass(frozen=True, eq=False)
class RoutePipeline:
    """One network's routing scheme as a pluggable pipeline stage."""

    net: Network = field(repr=False)
    vc_mode: str
    kernel: Callable = field(repr=False)

    def num_vcs(self, nonminimal: bool) -> int:
        """Deadlock classes this scheme needs (before `vcs_per_class`)."""
        return num_vcs(self.net.meta["kind"], self.vc_mode, nonminimal)

    def tables(self, faults: FaultSet | None = None) -> dict:
        """Fault-dependent tables for one epoch (pristine when None)."""
        return route_tables(self.net, self.vc_mode, faults)

    def epoch_tables(self, schedule: FaultSchedule) -> tuple:
        """(epoch_start [P], epoch-stacked tables) for a warm schedule."""
        return stack_epoch_tables(self.net, self.vc_mode, schedule)

    def bind(self, faults: FaultSet | None = None):
        """Historical 4-argument closure over one epoch's tables."""
        fl = self.tables(faults)
        kernel = self.kernel
        return lambda cur, dest, mis, meta: kernel(fl, cur, dest, mis, meta)

    def __call__(self, fl, cur, dest_term, mis_wg, meta):
        return self.kernel(fl, cur, dest_term, mis_wg, meta)


def make_pipeline(net: Network, vc_mode: str = "baseline") -> RoutePipeline:
    """Kind-dispatched `RoutePipeline` for one network."""
    if net.meta["kind"] != "switchless":
        kernel = make_dragonfly_kernel(net)
    elif vc_mode == "baseline":
        kernel = make_baseline_kernel(net)
    elif vc_mode in ("updown", "updown_merged"):
        kernel = make_updown_kernel(net, vc_mode)
    else:
        raise ValueError(vc_mode)
    return RoutePipeline(net=net, vc_mode=vc_mode, kernel=kernel)


def make_route_kernel(net: Network, vc_mode: str = "baseline"):
    """Returns kernel(fl, cur_node, dest_term, mis_wg, meta)
    -> (out_ch, req_vc, new_meta).

    `fl` is the fault-dependent table dict of `route_tables` (an explicit
    argument, NOT a closure constant, so the engine can vmap one compiled
    kernel over per-lane fault sets).  mis_wg == -1 means no (remaining)
    misroute; the simulator clears it when the packet enters the
    intermediate W-group.  `out_ch` is a channel id (MESH / LOCAL / GLOBAL
    / EJECT).  `req_vc` is the VC of the downstream buffer the packet will
    occupy.
    """
    return make_pipeline(net, vc_mode).kernel


def make_route_fn(net: Network, vc_mode: str = "baseline",
                  faults: FaultSet | None = None):
    """Route closure route(cur, dest_term, mis_wg, meta) over the
    (possibly degraded) network: the kind-dispatched kernel bound to this
    network's (possibly faulted) tables.  Minimal, non-minimal, and UGAL
    modes all route around the faults via the rebuilt tables
    (`route_tables`)."""
    return make_pipeline(net, vc_mode).bind(faults)
