"""Fault-dependent routing tables (the `fl` pytree the kernels read).

A kernel never closes over fault state: everything a fault can change —
the parallel-global re-pick tables, the per-W-group up*/down* next hops —
lives in the dict built here and is passed to the kernel as its explicit
first argument.  That is what lets a batched sweep stack the tables over a
lane axis (different fault sets per lane) or over an EPOCH axis (a
time-varying `FaultSchedule`, see `stack_epoch_tables`) and run everything
through one compiled step.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..topology import (FaultSet, FaultSchedule, LOCAL, MESH, Network,
                        validate_faults, wgroup_adjacency,
                        _wired_global_links)


def route_tables(net: Network, vc_mode: str = "baseline",
                 faults: FaultSet | None = None) -> dict:
    """Fault-dependent routing tables for ONE fault epoch.

    Always contains the parallel-global-link re-pick tables
    (`glob_cnt [g, g]`, `glob_idx [g, g, npar]`: flows spread over the
    ALIVE parallel links of each W-group pair by destination hash); for the
    up*/down* modes it adds the per-W-group tables recomputed on the
    surviving graph (`ud_rank [g, NW]`, `ud_nh [g, NW, NW, 2]`).

    For a pristine network the tables reproduce the un-faulted routing
    bit-for-bit (`glob_idx` is the identity, `glob_cnt == glob_npar`).
    The kernels take this dict as an explicit argument, so a batched sweep
    can stack it over a lane axis and vmap one compiled step over lanes
    with DIFFERENT fault sets (see engine/sweep.py).
    """
    faults = faults or FaultSet()
    if not faults.is_empty:
        validate_faults(net, faults, vc_mode)
    ch_alive = faults.ch_alive(net)
    g = net.meta["g"]
    wired = _wired_global_links(net)                      # [g, g, npar]
    npar = wired.shape[-1]
    ok = (wired >= 0) & ch_alive[np.maximum(wired, 0)]
    cnt = ok.sum(-1)
    idx = np.zeros((g, g, npar), dtype=np.int64)
    for w in range(g):
        for u in range(g):
            alive = np.flatnonzero(ok[w, u])
            idx[w, u, :len(alive)] = alive
    fl = dict(glob_cnt=jnp.asarray(np.maximum(cnt, 1)),
              glob_idx=jnp.asarray(idx))
    if net.meta["kind"] == "switchless" and vc_mode != "baseline":
        rank, nh = build_updown_tables(net, faults=faults)
        fl["ud_rank"] = jnp.asarray(rank)
        fl["ud_nh"] = jnp.asarray(nh)
    return fl


def stack_epoch_dicts(per_epoch: list, onset_cycles) -> tuple:
    """THE epoch-stacking primitive: one dict of arrays per epoch ->
    `(epoch_start [P] int32, stacked)` with a leading `[P, ...]` epoch
    axis on every array.  Both the routing layer (`stack_epoch_tables`)
    and the engine's lane builder (`engine.state.build_lane`) stack
    through here, so the epoch format has a single definition.
    """
    stacked = {k: jnp.stack([d[k] for d in per_epoch])
               for k in per_epoch[0]}
    starts = jnp.asarray(list(onset_cycles), dtype=jnp.int32)
    return starts, stacked


def stack_epoch_tables(net: Network, vc_mode: str,
                       schedule: FaultSchedule) -> tuple:
    """Per-epoch routing tables of a `FaultSchedule`, stacked on axis 0.

    Returns `(epoch_start [P] int32, tables)` where every array in
    `tables` carries a leading epoch axis `[P, ...]`.  A traced epoch
    index (`epoch_start`-searched from the cycle number) selects the
    active epoch's slice inside the jitted step — the kernels themselves
    stay epoch-oblivious.

    Each epoch builds from its own FULL fault state, so the stacking is
    direction-agnostic: a repair epoch (fault set SHRINKS — links or
    routers coming back) simply rebuilds its tables on the larger
    recovered subgraph, and every table shape is fault-independent, so
    grow and shrink epochs stack into the same dense `[P, ...]` form.
    """
    return stack_epoch_dicts(
        [route_tables(net, vc_mode, f) for _, f in schedule.epochs],
        (c for c, _ in schedule.epochs))


# --- per-W-group up*/down* tables --------------------------------------------

def _updown_single(NW: int, nbrs, alive: np.ndarray):
    """up*/down* tables over ONE W-group graph restricted to alive routers.

    Autonet-style: rank routers by BFS (depth, id) from the lowest-id alive
    router; a channel u->w is *up* iff rank(w) < rank(u).  Legal paths take
    all up hops before any down hop, which makes the channel dependency
    graph acyclic for ANY (sub)graph — so rebuilding the tables on a
    degraded W-group preserves deadlock freedom by construction.

    Returns (rank [NW], nh [NW, NW, 2]); dead routers keep the trailing
    ranks and -1 next-hops (they are never a source, hop, or target).
    """
    depth = np.full(NW, -1)
    root = int(np.flatnonzero(alive)[0])
    depth[root] = 0
    q = [root]
    while q:
        u = q.pop(0)
        for w, _ in nbrs[u]:
            if depth[w] < 0:
                depth[w] = depth[u] + 1
                q.append(w)
    assert (depth[alive] >= 0).all(), \
        "surviving W-group graph must be connected"
    # alive routers ordered by (depth, id); dead routers pushed to the end
    key = np.where(alive, depth, NW) * NW + np.arange(NW)
    rank = np.argsort(np.argsort(key))

    INF = 10**9
    f1 = np.full((NW, NW), INF, dtype=np.int64)   # down-phase distance
    nh1 = np.full((NW, NW), -1, dtype=np.int32)
    np.fill_diagonal(f1, 0)
    order_desc = np.argsort(-rank)
    for u in order_desc:
        for w, wt in nbrs[u]:
            if rank[w] > rank[u]:  # down edge
                cand = wt + f1[w]
                upd = cand < f1[u]
                f1[u][upd] = cand[upd]
                nh1[u][upd] = w
    f0 = f1.copy()
    nh0 = nh1.copy()
    order_asc = np.argsort(rank)
    for u in order_asc:
        for w, wt in nbrs[u]:
            if rank[w] < rank[u]:  # up edge
                cand = wt + f0[w]
                upd = cand < f0[u]
                f0[u][upd] = cand[upd]
                nh0[u][upd] = w
    live = np.ix_(alive, alive)
    assert (f0[live][~np.eye(int(alive.sum()), dtype=bool)] < INF).all(), \
        "up*/down* must connect all alive routers"
    nh = np.stack([nh0, nh1], axis=-1)
    return rank.astype(np.int32), nh


def build_updown_tables(net: Network, faults: FaultSet | None = None):
    """Per-W-group all-pairs up*/down* next-hop tables.

    Pristine W-groups share one table (computed once, tiled); W-groups
    touched by `faults` get their tables recomputed on the surviving
    subgraph, which is how the up*/down* modes route around dead mesh
    channels, dead local links, and dead routers.

    Returns (rank [g, NW], nh [g, NW, NW, 2]) where nh[wg, u, v, phase] is
    the next wg-local router towards v (phase 1 = a down hop was already
    taken).
    """
    meta = net.meta
    ab, npc = meta["ab"], meta["nodes_per_cg"]
    g = meta["g"]
    NW = ab * npc
    faults = faults or FaultSet()
    # W-groups the fault set touches, straight from its members (dead
    # routers, dead mesh/local channels); only those need a rebuild
    touched = {int(r) // NW for r in faults.dead_routers}
    touched |= {int(net.ch_src[c]) // NW for c in faults.dead_ch
                if net.ch_type[c] in (MESH, LOCAL)}
    pristine_adj, _ = wgroup_adjacency(net, wgs=[0])
    base = _updown_single(NW, pristine_adj[0], np.ones(NW, dtype=bool))
    rank = np.repeat(base[0][None], g, axis=0)
    nh = np.repeat(base[1][None], g, axis=0)
    if touched:
        adj, alive = wgroup_adjacency(net, faults, wgs=touched)
        for wg in sorted(touched):
            rank[wg], nh[wg] = _updown_single(NW, adj[wg], alive[wg])
    return rank, nh
