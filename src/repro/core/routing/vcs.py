"""VC schemes and the packet routing-meta bitfield (paper Sec. IV-A/B).

Packet routing state ("meta" int32 bitfield):
  bits 0..2  cg_count  number of inter-C-group channels traversed so far
  bits 3..4  g_count   number of global channels traversed so far
  bit  5     via_ext   entered the current C-group through an external port
  bit  6     phase     up*/down* phase (set once a down hop was taken)

VC schemes:
  baseline : VC = cg_count; 4 VCs minimal / 6 VCs non-minimal.
  reduced  : up*/down* labeling (Properties 1-2).  VC0 source C-group,
             VC1 intermediate C-group of the source W-group, VC2 anywhere in
             the destination W-group, VC3 intermediate (misroute) W-group.
             3 VCs when misroutes are restricted to lower W-groups
             ("reduced_restricted"), 4 otherwise ("reduced").
"""
from __future__ import annotations

import jax.numpy as jnp

from ..topology import GLOBAL, LOCAL, MESH

# up*/down* phase bit (set by the updown kernel once a down hop was taken)
PHASE_BIT = 1 << 6


def meta_cg_count(meta):
    return meta & 0x7


def meta_g_count(meta):
    return (meta >> 3) & 0x3


def meta_via_ext(meta):
    return (meta >> 5) & 0x1


def meta_update(meta, ch_type):
    """Packet meta after traversing a channel of the given type."""
    is_ext = (ch_type == LOCAL) | (ch_type == GLOBAL)
    cg = jnp.minimum(meta_cg_count(meta) + is_ext, 7)
    g = jnp.minimum(meta_g_count(meta) + (ch_type == GLOBAL), 3)
    via = is_ext.astype(meta.dtype)
    keep_mesh = (ch_type == MESH)
    via = jnp.where(keep_mesh, meta_via_ext(meta), via)
    # INJECT resets everything (fresh packet): handled by sim (meta=0).
    return (cg | (g << 3) | (via << 5)).astype(meta.dtype)


def num_vcs(kind: str, vc_mode: str, nonminimal: bool) -> int:
    if kind == "switchless":
        if vc_mode == "baseline":
            return 6 if nonminimal else 4
        if vc_mode == "updown":
            # W-group-wide up*/down* (Autonet-style): one VC per W-group
            # visited.  2 VCs minimal, 3 non-minimal.
            return 3 if nonminimal else 2
        if vc_mode == "updown_merged":
            # misroutes restricted to W-groups below the destination merge
            # the intermediate and destination W-group VCs: 2 VCs total.
            return 2
        raise ValueError(vc_mode)
    if kind == "dragonfly":
        return 6 if nonminimal else 4  # per-hop increment scheme
    raise ValueError(kind)
