"""Offline verification: path tracing, the channel-dependency graph, and
the deadlock-freedom proofs — per fault set and per epoch of a
`FaultSchedule`.

Route functions are pure, vectorizable jnp functions usable both inside
the jitted simulator and (via numpy inputs) by the hop-by-hop tracer here
that builds the CDG for the deadlock-freedom tests.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..topology import EJECT, FaultSchedule, FaultSet, Network
from .pipeline import make_route_fn
from .vcs import PHASE_BIT


def trace_paths(net: Network, route_fn, src_terms: np.ndarray,
                dst_terms: np.ndarray, mis_wgs: np.ndarray,
                max_hops: int | None = None,
                start_nodes: np.ndarray | None = None,
                meta0: np.ndarray | None = None):
    """Walk packets hop-by-hop with no contention.

    `start_nodes`/`meta0` resume packets mid-flight: the walk starts at
    an arbitrary router with an arbitrary routing-meta bitfield instead
    of fresh (meta 0) at `src_terms`' routers — the epoch-transition
    proofs use this to model packets in flight across a table swap.

    Returns (channels [B, H], vcs [B, H], lengths [B]) with -1 padding.
    """
    import jax
    B = len(src_terms)
    if max_hops is None:
        R = net.meta.get("R", 2)
        max_hops = 8 * (4 * R + 4) + 16
    term_node = net.term_node
    node_wg_tbl = net.tables.get("node_wg", net.tables.get("node_grp"))
    ch_dst = net.ch_dst
    ch_typ = net.ch_type

    step = jax.jit(lambda cur, dst, mis, meta: route_fn(cur, dst, mis, meta))

    cur = (term_node[src_terms].copy() if start_nodes is None
           else np.asarray(start_nodes, dtype=np.int64).copy())
    meta = (np.zeros(B, dtype=np.int32) if meta0 is None
            else np.asarray(meta0, dtype=np.int32).copy())
    mis = mis_wgs.astype(np.int32).copy()
    # misroute is pointless/undefined if src and dst share the W-group
    same = node_wg_tbl[cur] == node_wg_tbl[term_node[dst_terms]]
    mis = np.where(same, -1, mis)
    done = np.zeros(B, dtype=bool)
    chans = np.full((B, max_hops), -1, dtype=np.int64)
    vcs = np.full((B, max_hops), -1, dtype=np.int32)
    for hstep in range(max_hops):
        if done.all():
            break
        out_ch, vc, new_meta = map(np.asarray, step(
            jnp.asarray(cur), jnp.asarray(dst_terms), jnp.asarray(mis),
            jnp.asarray(meta)))
        act = ~done
        chans[act, hstep] = out_ch[act]
        vcs[act, hstep] = vc[act]
        nxt = ch_dst[out_ch]
        is_eject = ch_typ[out_ch] == EJECT
        # clear mis on entering the intermediate W-group
        entered_mis = (mis >= 0) & (node_wg_tbl[np.clip(nxt, 0, net.num_nodes - 1)] == mis) \
            & ~is_eject
        mis = np.where(act & entered_mis, -1, mis)
        meta = np.where(act, new_meta, meta)
        cur = np.where(act & ~is_eject, nxt, cur)
        done = done | (act & is_eject)
    if not done.all():
        bad = np.where(~done)[0][:5]
        raise RuntimeError(
            f"paths did not terminate within {max_hops} hops; e.g. "
            f"src={src_terms[bad]}, dst={dst_terms[bad]}, mis={mis_wgs[bad]}")
    lengths = (chans >= 0).sum(axis=1)
    return chans, vcs, lengths


def build_cdg(chans: np.ndarray, vcs: np.ndarray):
    """Channel-dependency graph over (channel, vc) pairs from traced paths."""
    import networkx as nx
    B, H = chans.shape
    g = nx.DiGraph()
    c0, v0 = chans[:, :-1], vcs[:, :-1]
    c1, v1 = chans[:, 1:], vcs[:, 1:]
    valid = (c0 >= 0) & (c1 >= 0)
    a = np.stack([c0[valid], v0[valid], c1[valid], v1[valid]], axis=1)
    a = np.unique(a, axis=0)
    g.add_edges_from(((int(r[0]), int(r[1])), (int(r[2]), int(r[3])))
                     for r in a)
    return g


def assert_deadlock_free(net: Network, vc_mode: str, nonminimal: bool,
                         rng: np.random.Generator, n_pairs: int = 4000,
                         exhaustive_limit: int = 250_000,
                         faults: FaultSet | None = None) -> int:
    """Trace flows and assert the CDG is acyclic.  Returns #edges checked.

    With `faults`, flows run between alive terminals on the degraded
    network; the trace additionally asserts no path crosses a dead channel
    (re-proving deadlock freedom AND fault avoidance on the survivors).
    """
    import networkx as nx
    route_fn = make_route_fn(net, vc_mode, faults)
    T = net.num_terminals
    terms = (np.arange(T) if faults is None
             else np.flatnonzero(faults.term_alive(net)))
    TA = len(terms)
    if TA * TA <= exhaustive_limit and not nonminimal:
        si, di = np.divmod(np.arange(TA * TA), TA)
        s, d = terms[si], terms[di]
        keep = s != d
        s, d = s[keep], d[keep]
    else:
        s = terms[rng.integers(0, TA, size=n_pairs)]
        d = terms[rng.integers(0, TA, size=n_pairs)]
        keep = s != d
        s, d = s[keep], d[keep]
    if nonminimal:
        wg_tbl = net.tables.get("node_wg", net.tables.get("node_grp"))
        g = int(wg_tbl.max()) + 1
        wg_s = wg_tbl[net.term_node[s]]
        wg_d = wg_tbl[net.term_node[d]]
        if vc_mode == "updown_merged":
            # misroute only to W-groups strictly below the destination
            hi = np.maximum(wg_d, 1)
            mis = rng.integers(0, hi)
            bad = (mis == wg_s) | (mis == wg_d) | (wg_d == 0)
            mis = np.where(bad, -1, mis)
        else:
            mis = rng.integers(0, g, size=len(s))
            bad = (mis == wg_s) | (mis == wg_d)
            mis = np.where(bad, -1, mis)
    else:
        mis = np.full(len(s), -1, dtype=np.int64)
    chans, vcs, _ = trace_paths(net, route_fn, s, d, mis)
    if faults is not None:
        alive = faults.ch_alive(net)
        used = chans[chans >= 0]
        if not alive[used].all():
            bad = np.unique(used[~alive[used]])
            raise AssertionError(
                f"faulted routing crossed dead channels {bad[:8]} "
                f"({net.name}, vc_mode={vc_mode})")
    cdg = build_cdg(chans, vcs)
    if not nx.is_directed_acyclic_graph(cdg):
        cyc = nx.find_cycle(cdg)
        raise AssertionError(
            f"CDG cycle for {net.name} vc_mode={vc_mode} "
            f"nonmin={nonminimal}: {cyc[:12]}")
    return cdg.number_of_edges()


def assert_transition_safe(net: Network, vc_mode: str, nonminimal: bool,
                           rng: np.random.Generator,
                           prev_faults: FaultSet, next_faults: FaultSet,
                           n_pairs: int = 2000) -> int:
    """Prove one epoch transition safe for packets already in flight.

    A packet crossing an epoch boundary keeps its routing meta (the
    up*/down* phase bit, VC-class counters) but resumes on the NEW
    epoch's tables.  Per-epoch acyclicity only covers fresh injections
    (meta 0); this check additionally traces RESUMED packets — parked at
    an arbitrary router shared by both epochs, down-phase bit set, one
    global hop banked — and asserts (a) every resume terminates (the
    down-only walk strictly descends the new epoch's rank, and a missing
    down continuation restarts on the full up*/down* path, which is
    acyclic by construction on any connected subgraph), (b) no resume
    crosses a channel dead in the next epoch, and (c) the CDG over fresh
    AND resumed flows together is acyclic.  Repair (shrinking)
    transitions are the interesting case — the rank order is recomputed
    on the recovered subgraph, and formerly stranded packets come back to
    life mid-walk — but the proof holds for growth transitions too and is
    run for every adjacent epoch pair.  Returns the combined CDG edge
    count.
    """
    import networkx as nx
    route_fn = make_route_fn(
        net, vc_mode, None if next_faults.is_empty else next_faults)
    nodes_both = np.flatnonzero(prev_faults.node_alive(net)
                                & next_faults.node_alive(net))
    terms_next = np.flatnonzero(next_faults.term_alive(net))
    if len(nodes_both) == 0 or len(terms_next) == 0:
        return 0
    # fresh flows of the next epoch (meta 0, injected at alive terminals)
    s = terms_next[rng.integers(0, len(terms_next), size=n_pairs)]
    d = terms_next[rng.integers(0, len(terms_next), size=n_pairs)]
    keep = s != d
    s, d = s[keep], d[keep]
    mis = np.full(len(s), -1, dtype=np.int64)
    chans_f, vcs_f, _ = trace_paths(net, route_fn, s, d, mis)
    # resumed flows: parked mid-walk at a router both epochs kept, with
    # the down-phase bit set and one global + one external hop banked —
    # the canonical "descending toward the destination when the tables
    # swapped underneath it" state (GLOBAL hops reset the phase, so a
    # carried phase bit implies the packet is past its last global hop)
    u = nodes_both[rng.integers(0, len(nodes_both), size=n_pairs)]
    dr = terms_next[rng.integers(0, len(terms_next), size=n_pairs)]
    keep = net.term_node[dr] != u
    if vc_mode == "updown_merged":
        # only REACHABLE resumed states: with the banked global hop the
        # merged scheme has already spent its one VC increment, and a
        # g_count >= 1 packet outside its destination W-group can only
        # exist in a W-group at or below the destination's (misroutes
        # are restricted to strictly-below W-groups; the direct global
        # hop lands in the destination W-group).  Sampling states above
        # the destination would manufacture VC1 cross-W-group cycles no
        # engine packet can produce.
        wg_tbl = net.tables.get("node_wg", net.tables.get("node_grp"))
        keep &= wg_tbl[u] <= wg_tbl[net.term_node[dr]]
    u, dr = u[keep], dr[keep]
    meta0 = np.full(len(u), PHASE_BIT | (1 << 3) | 1, dtype=np.int32)
    chans_r, vcs_r, _ = trace_paths(
        net, route_fn, dr, dr, np.full(len(u), -1, dtype=np.int64),
        start_nodes=u, meta0=meta0)
    alive = next_faults.ch_alive(net)
    used = chans_r[chans_r >= 0]
    if not alive[used].all():
        bad = np.unique(used[~alive[used]])
        raise AssertionError(
            f"resumed packets crossed dead channels {bad[:8]} after the "
            f"epoch swap ({net.name}, vc_mode={vc_mode})")
    H = max(chans_f.shape[1], chans_r.shape[1])
    pad = lambda a: np.pad(a, ((0, 0), (0, H - a.shape[1])),
                           constant_values=-1)
    cdg = build_cdg(np.concatenate([pad(chans_f), pad(chans_r)]),
                    np.concatenate([pad(vcs_f), pad(vcs_r)]))
    if not nx.is_directed_acyclic_graph(cdg):
        cyc = nx.find_cycle(cdg)
        raise AssertionError(
            f"CDG cycle across epoch transition for {net.name} "
            f"vc_mode={vc_mode}: {cyc[:12]}")
    return cdg.number_of_edges()


def assert_schedule_deadlock_free(net: Network, vc_mode: str,
                                  nonminimal: bool,
                                  rng: np.random.Generator,
                                  schedule: FaultSchedule,
                                  n_pairs: int = 4000,
                                  check_transitions: bool = True) -> list:
    """`assert_deadlock_free` re-proven for EVERY epoch of a warm-fault
    schedule: each epoch's surviving network must be deadlock-free and
    fault-avoiding on its own.  (Packets in flight across an epoch
    boundary are re-routed on the new epoch's tables, so acyclicity per
    epoch is the invariant the engine's drain semantics rely on.)

    With `check_transitions` (the default) every adjacent epoch pair is
    additionally proven safe for packets IN FLIGHT across the swap
    (`assert_transition_safe`) — mandatory for repair schedules, where a
    resumed down-phase walk runs against a recomputed rank order.

    Returns the per-epoch CDG edge counts.
    """
    edges = []
    for cycle, faults in schedule.epochs:
        edges.append(assert_deadlock_free(
            net, vc_mode, nonminimal, rng, n_pairs=n_pairs,
            faults=None if faults.is_empty else faults))
    if check_transitions:
        for (_, prev), (_, nxt) in zip(schedule.epochs,
                                       schedule.epochs[1:]):
            if prev == nxt:
                continue    # static schedule: nothing swaps
            assert_transition_safe(net, vc_mode, nonminimal, rng,
                                   prev, nxt,
                                   n_pairs=max(200, n_pairs // 4))
    return edges
