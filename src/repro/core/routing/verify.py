"""Offline verification: path tracing, the channel-dependency graph, and
the deadlock-freedom proofs — per fault set and per epoch of a
`FaultSchedule`.

Route functions are pure, vectorizable jnp functions usable both inside
the jitted simulator and (via numpy inputs) by the hop-by-hop tracer here
that builds the CDG for the deadlock-freedom tests.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..topology import EJECT, FaultSchedule, FaultSet, Network
from .pipeline import make_route_fn


def trace_paths(net: Network, route_fn, src_terms: np.ndarray,
                dst_terms: np.ndarray, mis_wgs: np.ndarray,
                max_hops: int | None = None):
    """Walk packets hop-by-hop with no contention.

    Returns (channels [B, H], vcs [B, H], lengths [B]) with -1 padding.
    """
    import jax
    B = len(src_terms)
    if max_hops is None:
        R = net.meta.get("R", 2)
        max_hops = 8 * (4 * R + 4) + 16
    term_node = net.term_node
    node_wg_tbl = net.tables.get("node_wg", net.tables.get("node_grp"))
    ch_dst = net.ch_dst
    ch_typ = net.ch_type

    step = jax.jit(lambda cur, dst, mis, meta: route_fn(cur, dst, mis, meta))

    cur = term_node[src_terms].copy()
    meta = np.zeros(B, dtype=np.int32)
    mis = mis_wgs.astype(np.int32).copy()
    # misroute is pointless/undefined if src and dst share the W-group
    same = node_wg_tbl[term_node[src_terms]] == node_wg_tbl[term_node[dst_terms]]
    mis = np.where(same, -1, mis)
    done = np.zeros(B, dtype=bool)
    chans = np.full((B, max_hops), -1, dtype=np.int64)
    vcs = np.full((B, max_hops), -1, dtype=np.int32)
    for hstep in range(max_hops):
        if done.all():
            break
        out_ch, vc, new_meta = map(np.asarray, step(
            jnp.asarray(cur), jnp.asarray(dst_terms), jnp.asarray(mis),
            jnp.asarray(meta)))
        act = ~done
        chans[act, hstep] = out_ch[act]
        vcs[act, hstep] = vc[act]
        nxt = ch_dst[out_ch]
        is_eject = ch_typ[out_ch] == EJECT
        # clear mis on entering the intermediate W-group
        entered_mis = (mis >= 0) & (node_wg_tbl[np.clip(nxt, 0, net.num_nodes - 1)] == mis) \
            & ~is_eject
        mis = np.where(act & entered_mis, -1, mis)
        meta = np.where(act, new_meta, meta)
        cur = np.where(act & ~is_eject, nxt, cur)
        done = done | (act & is_eject)
    if not done.all():
        bad = np.where(~done)[0][:5]
        raise RuntimeError(
            f"paths did not terminate within {max_hops} hops; e.g. "
            f"src={src_terms[bad]}, dst={dst_terms[bad]}, mis={mis_wgs[bad]}")
    lengths = (chans >= 0).sum(axis=1)
    return chans, vcs, lengths


def build_cdg(chans: np.ndarray, vcs: np.ndarray):
    """Channel-dependency graph over (channel, vc) pairs from traced paths."""
    import networkx as nx
    B, H = chans.shape
    g = nx.DiGraph()
    c0, v0 = chans[:, :-1], vcs[:, :-1]
    c1, v1 = chans[:, 1:], vcs[:, 1:]
    valid = (c0 >= 0) & (c1 >= 0)
    a = np.stack([c0[valid], v0[valid], c1[valid], v1[valid]], axis=1)
    a = np.unique(a, axis=0)
    g.add_edges_from(((int(r[0]), int(r[1])), (int(r[2]), int(r[3])))
                     for r in a)
    return g


def assert_deadlock_free(net: Network, vc_mode: str, nonminimal: bool,
                         rng: np.random.Generator, n_pairs: int = 4000,
                         exhaustive_limit: int = 250_000,
                         faults: FaultSet | None = None) -> int:
    """Trace flows and assert the CDG is acyclic.  Returns #edges checked.

    With `faults`, flows run between alive terminals on the degraded
    network; the trace additionally asserts no path crosses a dead channel
    (re-proving deadlock freedom AND fault avoidance on the survivors).
    """
    import networkx as nx
    route_fn = make_route_fn(net, vc_mode, faults)
    T = net.num_terminals
    terms = (np.arange(T) if faults is None
             else np.flatnonzero(faults.term_alive(net)))
    TA = len(terms)
    if TA * TA <= exhaustive_limit and not nonminimal:
        si, di = np.divmod(np.arange(TA * TA), TA)
        s, d = terms[si], terms[di]
        keep = s != d
        s, d = s[keep], d[keep]
    else:
        s = terms[rng.integers(0, TA, size=n_pairs)]
        d = terms[rng.integers(0, TA, size=n_pairs)]
        keep = s != d
        s, d = s[keep], d[keep]
    if nonminimal:
        wg_tbl = net.tables.get("node_wg", net.tables.get("node_grp"))
        g = int(wg_tbl.max()) + 1
        wg_s = wg_tbl[net.term_node[s]]
        wg_d = wg_tbl[net.term_node[d]]
        if vc_mode == "updown_merged":
            # misroute only to W-groups strictly below the destination
            hi = np.maximum(wg_d, 1)
            mis = rng.integers(0, hi)
            bad = (mis == wg_s) | (mis == wg_d) | (wg_d == 0)
            mis = np.where(bad, -1, mis)
        else:
            mis = rng.integers(0, g, size=len(s))
            bad = (mis == wg_s) | (mis == wg_d)
            mis = np.where(bad, -1, mis)
    else:
        mis = np.full(len(s), -1, dtype=np.int64)
    chans, vcs, _ = trace_paths(net, route_fn, s, d, mis)
    if faults is not None:
        alive = faults.ch_alive(net)
        used = chans[chans >= 0]
        if not alive[used].all():
            bad = np.unique(used[~alive[used]])
            raise AssertionError(
                f"faulted routing crossed dead channels {bad[:8]} "
                f"({net.name}, vc_mode={vc_mode})")
    cdg = build_cdg(chans, vcs)
    if not nx.is_directed_acyclic_graph(cdg):
        cyc = nx.find_cycle(cdg)
        raise AssertionError(
            f"CDG cycle for {net.name} vc_mode={vc_mode} "
            f"nonmin={nonminimal}: {cyc[:12]}")
    return cdg.number_of_edges()


def assert_schedule_deadlock_free(net: Network, vc_mode: str,
                                  nonminimal: bool,
                                  rng: np.random.Generator,
                                  schedule: FaultSchedule,
                                  n_pairs: int = 4000) -> list:
    """`assert_deadlock_free` re-proven for EVERY epoch of a warm-fault
    schedule: each epoch's surviving network must be deadlock-free and
    fault-avoiding on its own.  (Packets in flight across an epoch
    boundary are re-routed on the new epoch's tables, so acyclicity per
    epoch is the invariant the engine's drain semantics rely on.)

    Returns the per-epoch CDG edge counts.
    """
    edges = []
    for cycle, faults in schedule.epochs:
        edges.append(assert_deadlock_free(
            net, vc_mode, nonminimal, rng, n_pairs=n_pairs,
            faults=None if faults.is_empty else faults))
    return edges
