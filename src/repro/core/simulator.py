"""Flit-level network simulator in JAX (paper Sec. V) — facade.

Dataflow re-architecture of a cycle-accurate NoC simulator for XLA: all
router state lives in fixed-shape arrays over (channel, VC, slot) and one
`lax.scan` advances the whole network a cycle at a time.

The cycle itself is implemented by the modular engine under
``repro.core.engine`` (inject -> arbitrate -> apply -> stats over a pytree
``SimState``); this module keeps the stable public API: ``SimConfig``,
``SimResult``, ``Simulator`` (compile-once, per-rate ``run``), and a
``sweep`` that now executes the whole load-latency curve as ONE batched
`lax.scan` via ``engine.sweep.BatchedSweep``.

``Simulator`` is the imperative compatibility facade.  New scenario code
should describe runs declaratively with ``repro.exp`` (``ExperimentSpec``
-> ``run_experiment``), which lowers topology x traffic x routing x fault
grids onto the same engine with one compile per grid; benchmarks and
examples in this repo construct their runs that way.

Microarchitecture model
  * input-queued routers, virtual cut-through at packet granularity
    (PKT flits move together; a packet is visible downstream after the
    channel pipeline latency; the channel stays busy PKT/bw cycles, which
    models serialization and heterogeneous link bandwidth);
  * per-(channel, VC) input buffers of `buf_pkts` packets (32-flit buffers
    with 4-flit packets by default, as Table IV);
  * age-based (oldest-first) output arbitration, one packet per output
    channel per cycle;
  * credit-based flow control (a slot is reserved at send time);
  * terminals inject through an explicit 1 flit/cycle injection channel and
    eject through a 1 flit/cycle ejection channel — in the switch-based
    baseline this is the single terminal-to-switch link that caps injection
    at 1 flit/cycle/chip, in the switch-less network every on-wafer router
    owns one, giving the paper's 4 injection ports per chip.

Routing modes: "min", "val" (Valiant non-minimal), "val_restricted"
(misroute only to lower W-groups; pairs with the 3-VC reduced scheme), and
"ugal" (UGAL-G adaptive; beyond-paper extension).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax

from .topology import FaultSchedule, FaultSet, Network, compose_faults
from .engine.arbitrate import GRANT_IMPLS
from .engine.state import build_lane, make_state as _engine_make_state
from .engine.step import STEP_IMPLS, make_step, run_scan
from .engine.stats import finalize
from .engine.sweep import (BatchedSweep, SweepResult, offered_to_rate_pkt)


@dataclass(frozen=True)
class SimConfig:
    pkt_len: int = 4          # flits per packet (Table IV)
    buf_pkts: int = 8         # input buffer: 32 flits / 4 = 8 packets
    srcq_pkts: int = 64       # source queue depth (packets)
    vcs_per_class: int = 2    # physical VCs per deadlock class (HOL relief)
    warmup: int = 2000
    measure: int = 8000
    vc_mode: str = "baseline"          # "baseline" | "updown" | "updown_merged"
    route_mode: str = "min"            # "min" | "val" | "val_restricted" | "ugal"
    ugal_threshold: int = 3
    seed: int = 0
    # arbitration grant implementation: "jnp" (the jax.ops.segment_min
    # path, default and oracle) or "pallas" (the fused netsim kernel,
    # `repro.kernels.netsim` — bit-identical, TPU-ready fast path)
    grant_impl: str = "jnp"
    # cycle-step implementation: "jnp" (the modular phase pipeline,
    # default and oracle), "fused" (the per-channel-winner fused step,
    # `engine.fused` — bit-identical, and the only step the 2-D
    # (lanes x shards) channel-sharded mesh can run), or "compact"
    # (the fused step with live rows compacted into a capacity-C active
    # set before arbitration — bit-identical, occupancy-proportional;
    # see `engine.fused.make_compact_step` and REPRO_COMPACT_CAP)
    step_impl: str = "jnp"
    # router-death reaper park age (cycles): packets parked on the -1
    # non-channel (destination dead / unroutable) are dropped once their
    # generation age reaches this, tallied in `SimStats.reaped` /
    # `SimResult.reaped_pkts` — disjoint from `dropped`, so
    # ``generated == delivered + dropped + reaped + in-flight`` stays
    # exact.  0 disables the reaper (the step compiles no reap logic);
    # the REPRO_REAP_AGE env knob supplies a process-wide default when
    # the config leaves it off.  See `engine.state.resolve_reap_age`.
    reap_age: int = 0

    def __post_init__(self):
        if self.grant_impl not in GRANT_IMPLS:
            raise ValueError(
                f"unknown grant_impl {self.grant_impl!r}; "
                f"valid: {GRANT_IMPLS}")
        if self.step_impl not in STEP_IMPLS:
            raise ValueError(
                f"unknown step_impl {self.step_impl!r}; "
                f"valid: {STEP_IMPLS}")
        if self.reap_age < 0:
            raise ValueError(f"reap_age must be >= 0, got {self.reap_age}")

    @property
    def nonminimal(self) -> bool:
        return self.route_mode != "min"


@dataclass
class SimResult:
    offered_per_chip: float
    throughput_per_chip: float     # accepted/delivered flits per cycle per chip
    avg_latency: float             # cycles, generation -> ejection
    delivered_pkts: int
    generated_pkts: int
    dropped_pkts: int              # source-queue overflow (backlog)
    hops_by_type: dict
    avg_hops_by_type: dict = field(default_factory=dict)
    stranded_pkts: int = 0         # parked on the -1 non-channel at exit
                                   # (warm faults left them unroutable);
                                   # seed-averaged rows report the exact
                                   # per-lane MAX (see mean_over_seeds)
    stranded_mean: float = 0.0     # exact mean of stranded_pkts over the
                                   # seed lanes (== stranded_pkts for a
                                   # single lane)
    reaped_pkts: int = 0           # dropped by the router-death reaper
                                   # (age-based; disjoint from dropped)
    occupancy_peak: int = 0        # high-water mark of live request rows
                                   # (whole run incl. warmup; the compact
                                   # step's capacity certificate)

    def row(self) -> str:
        return (f"{self.offered_per_chip:.3f},{self.throughput_per_chip:.3f},"
                f"{self.avg_latency:.1f}")


def make_state(net: Network, cfg: SimConfig, NV: int):
    """Compat shim: fresh engine `SimState` (was an ad-hoc dict)."""
    return _engine_make_state(net, cfg, NV)


class Simulator:
    """Compile-once-per-(net, cfg, pattern) simulator; sweep rates cheaply.

    ``run`` executes one offered rate sequentially (one `lax.scan`);
    ``sweep`` batches every (rate, seed) lane into a single scan.
    """

    def __init__(self, net: Network, cfg: SimConfig, pattern,
                 inject_mask=None,
                 faults: FaultSet | FaultSchedule | None = None):
        from .traffic import as_pattern
        self.net, self.cfg = net, cfg
        self.terms_per_chip = net.num_terminals / net.num_chips
        pattern = as_pattern(pattern, inject_mask)  # mask rides the pattern
        self.step, self.consts = make_step(net, cfg, pattern)
        self.NV = self.consts["NV"]
        self.faults = faults
        self.lane = build_lane(net, cfg, faults)
        self._batched = BatchedSweep(net, cfg, pattern,
                                     step=self.step, consts=self.consts,
                                     faults=faults, lane=self.lane)

    def run(self, offered_per_chip: float, seed: int | None = None,
            faults: FaultSet | FaultSchedule | None = None) -> SimResult:
        """One offered rate, sequentially.  `faults` (a cold set or a warm
        schedule) composes on top of the instance fault state for this run
        only (same semantics as `sweep_faults` grid entries) — fault data
        is a traced step argument, so switching fault sets reuses the
        compiled scan (a schedule's epoch-stacked lane compiles once per
        epoch-count shape)."""
        cfg = self.cfg
        rate_pkt = offered_to_rate_pkt(offered_per_chip, cfg,
                                       self.terms_per_chip)
        state0 = _engine_make_state(self.net, cfg, self.NV)
        cycles = cfg.warmup + cfg.measure
        key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
        if faults is None:
            lane, chips = self.lane, self._batched._chips(self.faults)
        else:
            faults = compose_faults(self.faults, faults)
            lane = build_lane(self.net, cfg, faults)
            chips = self._batched._chips(faults)
        state = run_scan(self.step, cycles, cfg.warmup,
                         state0, jax.numpy.float32(rate_pkt), key, lane)
        return finalize(state.stats, cfg, offered_per_chip, chips)

    def sweep(self, rates, seeds=None) -> list[SimResult]:
        """Batched load-latency curve; one jit compile + one device dispatch.

        Returns one (seed-averaged) `SimResult` per rate, in order, matching
        the historical ``[self.run(r) for r in rates]`` contract.
        """
        return self.sweep_grid(rates, seeds).mean_over_seeds()

    def sweep_grid(self, rates, seeds=None) -> SweepResult:
        """Full (rate x seed) grid of `SimResult`s plus sweep metadata."""
        return self._batched.run(rates, seeds)

    def sweep_faults(self, offered_per_chip: float, fault_grid,
                     seeds=None) -> SweepResult:
        """Degraded-throughput grid: one lane per (fault set, seed) at a
        fixed offered load, all in one compiled batched scan (see
        `BatchedSweep.run_faults`)."""
        return self._batched.run_faults(offered_per_chip, fault_grid, seeds)


def saturation_throughput(results: list[SimResult]) -> float:
    """Max accepted throughput over a sweep (flits/cycle/chip)."""
    return max(r.throughput_per_chip for r in results)
