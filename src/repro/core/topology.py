"""Switch-less Dragonfly on Wafers: topology construction.

Implements the 5-level hierarchy of the paper (chiplet -> C-group -> wafer ->
W-group -> system) as a concrete router/channel graph, plus the traditional
switch-based Dragonfly baseline the paper compares against.

Construction is numpy; the simulator converts to jnp.  All channels are
directed.  Channel types:

  MESH   on-wafer short-reach hop inside a C-group (H_sr)
  LOCAL  intra-W-group C-group-to-C-group link (H_l, long-reach)
  GLOBAL inter-W-group link (H_g, long-reach)
  INJECT terminal -> router
  EJECT  router -> terminal

Channel-id layout contract: EJECT channels form the TRAILING id block
(checked by `Network.validate`).  Eject channels own no input buffers and
never appear as requesters, so the simulation engine shrinks its per-cycle
request grid to `[:first_eject]` with a free slice instead of a masked
gather (see engine/arbitrate.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

MESH, LOCAL, GLOBAL, INJECT, EJECT = 0, 1, 2, 3, 4
CH_TYPE_NAMES = ("mesh", "local", "global", "inject", "eject")
NUM_CH_TYPES = 5


@dataclass
class Network:
    """A directed channel graph with terminals, consumed by the simulator."""

    name: str
    num_nodes: int
    num_terminals: int
    num_chips: int
    term_node: np.ndarray      # [T] router node hosting terminal t
    term_chip: np.ndarray      # [T] chip id of terminal t (for /chip rates)
    ch_src: np.ndarray         # [E]
    ch_dst: np.ndarray         # [E]
    ch_bw: np.ndarray          # [E] flits/cycle
    ch_lat: np.ndarray         # [E] cycles of pipeline latency
    ch_type: np.ndarray        # [E] MESH/LOCAL/GLOBAL/INJECT/EJECT
    inject_ch: np.ndarray      # [T] channel id terminal->router
    eject_ch: np.ndarray       # [V] channel id router->terminal (-1 if none)
    tables: dict = field(default_factory=dict)  # routing tables (np arrays)
    meta: dict = field(default_factory=dict)

    @property
    def num_channels(self) -> int:
        return int(len(self.ch_src))

    @property
    def first_eject(self) -> int:
        """First channel id of the trailing EJECT block (== #non-eject)."""
        return self.num_channels - int((self.ch_type == EJECT).sum())

    def validate(self) -> None:
        E = self.num_channels
        assert self.ch_dst.shape == (E,) and self.ch_type.shape == (E,)
        assert (self.ch_bw > 0).all() and (self.ch_lat >= 1).all()
        assert self.term_node.shape == (self.num_terminals,)
        # every terminal has an inject channel pointing at its router
        assert (self.ch_dst[self.inject_ch] == self.term_node).all()
        assert (self.ch_type[self.inject_ch] == INJECT).all()
        # eject channels are the trailing id block (engine slicing contract)
        assert (self.ch_type[self.first_eject:] == EJECT).all()


# ---------------------------------------------------------------------------
# Fault injection: degraded wafers
# ---------------------------------------------------------------------------
#
# Wafer-scale integration makes dead routers (known-good-die yield) and dead
# links (post-bond defects) the norm, not the exception.  A `FaultSet` names
# the dead channels and routers of one degraded network; the routing layer
# (`routing.route_tables`) rebuilds its fault-dependent tables on the
# surviving graph and the engine threads per-lane alive masks through the
# phase pipeline (see docs/faults.md).  A `FaultSet` alone is a COLD fault
# population (broken before cycle 0); a `FaultSchedule` sequences fault
# epochs over time — links dying mid-run while traffic is in flight — and
# is validated per epoch so the surviving network stays routable at every
# stage.

@dataclass(frozen=True)
class FaultSet:
    """Dead channels and dead routers of one degraded network.

    `dead_ch` holds explicitly failed channel ids; `dead_routers` holds
    failed router node ids.  A dead router implicitly kills every channel
    incident to it (mesh/local/global in and out, plus the inject/eject
    links of its terminals) — `ch_alive` folds both in.
    """

    dead_ch: tuple = ()
    dead_routers: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "dead_ch",
                           tuple(sorted(set(int(c) for c in self.dead_ch))))
        object.__setattr__(
            self, "dead_routers",
            tuple(sorted(set(int(r) for r in self.dead_routers))))

    @classmethod
    def empty(cls) -> "FaultSet":
        return cls()

    @property
    def is_empty(self) -> bool:
        return not self.dead_ch and not self.dead_routers

    def union(self, other: "FaultSet") -> "FaultSet":
        return FaultSet(self.dead_ch + other.dead_ch,
                        self.dead_routers + other.dead_routers)

    def node_alive(self, net: Network) -> np.ndarray:
        """Bool [V]: router survives."""
        alive = np.ones(net.num_nodes, dtype=bool)
        if self.dead_routers:
            alive[list(self.dead_routers)] = False
        return alive

    def ch_alive(self, net: Network) -> np.ndarray:
        """Bool [E]: channel survives (explicit death + incident router
        death; a terminal's inject channel dies with its router because its
        `ch_dst` is the router, its eject because its `ch_src` is)."""
        alive = np.ones(net.num_channels, dtype=bool)
        if self.dead_ch:
            alive[list(self.dead_ch)] = False
        if self.dead_routers:
            dr = np.asarray(self.dead_routers)
            alive &= ~np.isin(net.ch_src, dr)
            alive &= ~np.isin(net.ch_dst, dr)
        return alive

    def term_alive(self, net: Network) -> np.ndarray:
        """Bool [T]: terminal can inject AND eject (its router, injection
        channel, and ejection channel all survive).  A terminal with a
        dead eject channel must count as dead in both directions —
        otherwise it stays a legal destination whose packets can never
        drain and head-of-line-block the router."""
        ch_alive = self.ch_alive(net)
        return (self.node_alive(net)[net.term_node]
                & ch_alive[net.inject_ch]
                & ch_alive[term_eject_channel(net)])

    def frac_links_failed(self, net: Network) -> float:
        """Fraction of fabric links (mesh/local/global) that are dead."""
        fabric = net.ch_type <= GLOBAL
        return float((~self.ch_alive(net))[fabric].sum() / fabric.sum())


@dataclass(frozen=True)
class FaultSchedule:
    """Time-varying fault state: an ordered list of `(cycle, FaultSet)`
    epochs.  Epoch i's fault set is the FULL fault state in effect from
    `epochs[i][0]` until the next epoch's onset cycle (not a delta), so
    the lifecycle history is explicit: an epoch whose population GROWS
    is wear-out (links dying mid-run), one whose population SHRINKS is a
    repair (links/routers coming back — wafer rework, lane re-bonding, a
    rebooted router).  Both directions rebuild the per-epoch routing
    tables on that epoch's surviving subgraph (`stack_epoch_tables`), and
    both are certified per epoch by `validate`/the CDG spec pass; repair
    transitions additionally get an up*/down* phase-restart safety proof
    (`routing.verify.assert_schedule_deadlock_free`).

    The first epoch must start at cycle 0 (a pristine network is the
    single epoch `(0, FaultSet())`; a cold fault set is `cold(faults)`).
    Hashable and equality-comparable like `FaultSet`, so batched sweeps
    can memoize per-schedule lane tables.
    """

    epochs: tuple = ((0, FaultSet()),)

    def __post_init__(self):
        eps = []
        for c, f in self.epochs:
            if isinstance(f, (list, tuple)):
                f = FaultSet(*f)
            if not isinstance(f, FaultSet):
                raise ValueError(f"epoch fault entry {f!r} is not a FaultSet")
            eps.append((int(c), f))
        if not eps:
            raise ValueError("a FaultSchedule needs >= 1 epoch")
        if eps[0][0] != 0:
            raise ValueError(
                f"the first epoch must start at cycle 0, got {eps[0][0]}")
        cycles = [c for c, _ in eps]
        if any(b <= a for a, b in zip(cycles, cycles[1:])):
            raise ValueError(
                f"epoch onset cycles must be strictly increasing: {cycles}")
        object.__setattr__(self, "epochs", tuple(eps))

    @classmethod
    def cold(cls, faults: "FaultSet | None" = None) -> "FaultSchedule":
        """The single-epoch schedule equivalent to a cold fault set."""
        return cls(((0, faults or FaultSet()),))

    @property
    def num_epochs(self) -> int:
        return len(self.epochs)

    @property
    def final(self) -> FaultSet:
        """The fault state of the last epoch (the most degraded network —
        throughput divisors and failed-link fractions report this one)."""
        return self.epochs[-1][1]

    @property
    def is_static(self) -> bool:
        """True when every epoch carries the same fault set (the schedule
        is equivalent to a cold `FaultSet` — the parity baseline)."""
        return all(f == self.epochs[0][1] for _, f in self.epochs)

    @property
    def is_empty(self) -> bool:
        return all(f.is_empty for _, f in self.epochs)

    @property
    def has_repair(self) -> bool:
        """True when some epoch transition removes a fault (a dead channel
        or router comes back).  A transition may grow and shrink at once
        (one link repaired while another dies); any removal counts."""
        for (_, a), (_, b) in zip(self.epochs, self.epochs[1:]):
            if not (set(a.dead_ch) <= set(b.dead_ch)
                    and set(a.dead_routers) <= set(b.dead_routers)):
                return True
        return False

    @property
    def is_monotone(self) -> bool:
        """True when the fault population only ever accumulates (classic
        wear-out — every epoch's set contains its predecessor's)."""
        return not self.has_repair

    def repaired_at(self, i: int) -> FaultSet:
        """The faults epoch i REMOVED relative to epoch i-1 (the repair
        delta; empty for growth-only transitions).  i must be >= 1."""
        a, b = self.epochs[i - 1][1], self.epochs[i][1]
        return FaultSet(tuple(set(a.dead_ch) - set(b.dead_ch)),
                        tuple(set(a.dead_routers) - set(b.dead_routers)))

    def epoch_at(self, cycle: int) -> int:
        """Index of the epoch in effect at `cycle` (host-side mirror of
        the engine's traced epoch selection)."""
        idx = 0
        for i, (c, _) in enumerate(self.epochs):
            if cycle >= c:
                idx = i
        return idx

    def union_base(self, base: "FaultSet | None") -> "FaultSchedule":
        """Compose a base (cold) fault set into every epoch."""
        if base is None or base.is_empty:
            return self
        return FaultSchedule(tuple((c, f.union(base))
                                   for c, f in self.epochs))

    def validate(self, net: Network, vc_mode: str = "updown") -> list:
        """`validate_faults` per epoch — the surviving network must stay
        routable at EVERY stage of the schedule.  Returns the per-epoch
        summary dicts."""
        out = []
        for c, f in self.epochs:
            try:
                out.append(validate_faults(net, f, vc_mode)
                           if not f.is_empty
                           else dict(dead_channels=0, dead_routers=0,
                                     alive_terminals=net.num_terminals))
            except ValueError as e:
                raise ValueError(
                    f"schedule epoch at cycle {c} is unroutable: {e}"
                ) from None
        return out


def as_fault_schedule(f) -> FaultSchedule:
    """Promote None / `FaultSet` / `FaultSchedule` to a `FaultSchedule`."""
    if f is None:
        return FaultSchedule.cold()
    if isinstance(f, FaultSet):
        return FaultSchedule.cold(f)
    if isinstance(f, FaultSchedule):
        return f
    raise TypeError(f"expected FaultSet/FaultSchedule/None, got {type(f)}")


def final_faults(f) -> "FaultSet | None":
    """The steady-state fault set of None / `FaultSet` / `FaultSchedule`
    (None stays None; a schedule reports its last epoch)."""
    if f is None or isinstance(f, FaultSet):
        return f
    return f.final


def compose_faults(base, extra):
    """Compose two fault states (None / `FaultSet` / `FaultSchedule`).

    Set x set unions; if either side is a schedule the result is a
    schedule over the merged onset cycles, each epoch the union of the
    states the two sides hold at that cycle."""
    if extra is None:
        return base
    if base is None:
        return extra
    if isinstance(base, FaultSchedule) or isinstance(extra, FaultSchedule):
        bs, es = as_fault_schedule(base), as_fault_schedule(extra)
        cycles = sorted({c for c, _ in bs.epochs}
                        | {c for c, _ in es.epochs})
        return FaultSchedule(tuple(
            (c, bs.epochs[bs.epoch_at(c)][1]
                .union(es.epochs[es.epoch_at(c)][1])) for c in cycles))
    return base.union(extra)


def wg_channel_alive_frac(net: Network, faults: "FaultSet | None"
                          ) -> np.ndarray:
    """float [g]: surviving fraction of each W-group's internal
    (mesh + local) channels — the `weight` the fault-aware adaptive
    misroute stage uses to bias candidate intermediate W-groups away from
    degraded groups.  1.0 everywhere on a pristine network; the
    switch-based Dragonfly counts its intra-group local channels."""
    g = net.meta["g"]
    faults = faults or FaultSet()
    ch_alive = faults.ch_alive(net)
    intra = (net.ch_type == MESH) | (net.ch_type == LOCAL)
    if net.meta["kind"] == "switchless":
        NW = net.meta["ab"] * net.meta["nodes_per_cg"]
        grp = net.ch_src // NW
    else:
        grp = net.ch_src // net.meta["spg"]
    out = np.ones(g, dtype=np.float64)
    for w in range(g):
        sel = intra & (grp == w)
        if sel.any():
            out[w] = ch_alive[sel].sum() / sel.sum()
    return out


def glob_pair_alive(net: Network, faults: "FaultSet | None") -> np.ndarray:
    """bool [g, g]: the (w -> u) W-group pair keeps >= 1 alive wired
    global link (diagonal and unwired pairs read True — they are never a
    misroute hop).  Masks the adaptive misroute candidate set."""
    g = net.meta["g"]
    faults = faults or FaultSet()
    if g <= 1:
        return np.ones((g, g), dtype=bool)
    ch_alive = faults.ch_alive(net)
    wired = _wired_global_links(net)
    any_wired = (wired >= 0).any(-1)
    any_alive = ((wired >= 0) & ch_alive[np.maximum(wired, 0)]).any(-1)
    return ~any_wired | any_alive


def term_eject_channel(net: Network) -> np.ndarray:
    """int [T]: ejection channel id of each terminal (both builders wire
    eject channel of terminal t with ch_dst == V + t).  Cached on
    `net.tables` — it depends only on the network."""
    cached = net.tables.get("_term_eject")
    if cached is None:
        te = np.full(net.num_terminals, -1, dtype=np.int64)
        ejs = np.where(net.ch_type == EJECT)[0]
        te[net.ch_dst[ejs] - net.num_nodes] = ejs
        assert (te >= 0).all()
        cached = net.tables["_term_eject"] = te
    return cached


def reverse_fabric_channel(net: Network) -> np.ndarray:
    """int [E]: id of the opposite-direction mesh/local channel (-1 for
    global/inject/eject or unpaired).  A physical wafer defect kills the
    whole link bundle, i.e. both directions — samplers and validation use
    this pairing to keep mesh/local faults symmetric.  Cached on
    `net.tables` (the greedy samplers validate per candidate)."""
    cached = net.tables.get("_rev_fabric")
    if cached is not None:
        return cached
    rev = np.full(net.num_channels, -1, dtype=np.int64)
    pair = {}
    for e in np.where((net.ch_type == MESH) | (net.ch_type == LOCAL))[0]:
        pair[(net.ch_src[e], net.ch_dst[e], net.ch_type[e])] = e
    for (s, d, ty), e in pair.items():
        r = pair.get((d, s, ty), -1)
        rev[e] = r
    net.tables["_rev_fabric"] = rev
    return rev


def _wired_global_links(net: Network) -> np.ndarray:
    """int [g, g, npar] outgoing global channel id per (wg, peer, parallel
    index), -1 where unwired.  Works for both network kinds; cached on
    `net.tables`."""
    cached = net.tables.get("_wired_glob")
    if cached is not None:
        return cached
    t = net.tables
    g = net.meta["g"]
    if net.meta["kind"] == "switchless":
        ab = net.meta["ab"]
        cg = t["glob_route_cg"]                      # [g, g, npar]
        port = t["glob_route_port"]
        npar = cg.shape[-1]
        out = np.full((g, g, npar), -1, dtype=np.int64)
        for w in range(g):
            for u in range(g):
                if u == w:
                    continue
                for r in range(npar):
                    if cg[w, u, r] < 0:
                        continue
                    ch = t["ext_out"][w * ab + cg[w, u, r], port[w, u, r]]
                    out[w, u, r] = ch
    else:
        out = t["glob_out_ch"].copy()
    net.tables["_wired_glob"] = out
    return out


def validate_faults(net: Network, faults: FaultSet,
                    vc_mode: str = "updown",
                    check_wgs=None) -> dict:
    """Raise ValueError if `faults` leaves the network unroutable.

    Invariants checked:
      * at least one alive terminal;
      * every wired W-group pair keeps >= 1 alive outgoing global link
        (minimal routes re-pick among the surviving parallel links);
      * mesh/local faults are direction-symmetric (a physical defect kills
        the whole link bundle; one-directional death could leave the
        W-group weakly but not strongly connected, which up*/down* cannot
        route);
      * the surviving (mesh + local) graph of every W-group is connected
        over its alive routers (up*/down* tables are rebuilt on it);
      * `vc_mode="baseline"` (deterministic XY + fixed local ports) only
        tolerates GLOBAL-link faults — mesh/local/router faults need the
        up*/down* modes, switch-based Dragonfly networks tolerate GLOBAL
        faults only.

    `check_wgs` restricts the (Python-BFS) W-group connectivity check to
    the given W-group ids — the greedy samplers pass just the W-group a
    candidate touches, which keeps sampling linear instead of quadratic
    in the fault count.  `None` checks every W-group.

    Returns a small summary dict (counts) on success.
    """
    ch_alive = faults.ch_alive(net)
    term_alive = faults.term_alive(net)
    if not term_alive.any():
        raise ValueError("faults kill every terminal")
    dead = ~ch_alive
    rev = reverse_fabric_channel(net)
    paired = rev >= 0
    asym = paired & (dead != dead[np.maximum(rev, 0)])
    if asym.any():
        raise ValueError(
            f"mesh/local faults must kill both directions of a link "
            f"(channels {np.flatnonzero(asym)[:6]} died one-way)")
    kind = net.meta["kind"]
    nonglobal_dead = (dead & (net.ch_type != GLOBAL)).any() \
        or bool(faults.dead_routers)
    if kind == "dragonfly" and nonglobal_dead:
        raise ValueError(
            "switch-based Dragonfly fault model supports GLOBAL-link "
            "faults only (local links have no alternative path)")
    if kind == "switchless" and vc_mode == "baseline" and nonglobal_dead:
        raise ValueError(
            "vc_mode='baseline' routes deterministically inside W-groups "
            "and only tolerates GLOBAL-link faults; use the up*/down* "
            "modes for mesh/local/router faults")
    # every wired W-group pair keeps an alive outgoing global link
    g = net.meta["g"]
    if g > 1:
        wired = _wired_global_links(net)
        alive_cnt = ((wired >= 0) & ch_alive[np.maximum(wired, 0)]).sum(-1)
        wired_cnt = (wired >= 0).sum(-1)
        bad = (wired_cnt > 0) & (alive_cnt == 0)
        if bad.any():
            w, u = np.argwhere(bad)[0]
            raise ValueError(
                f"faults kill every global link W-group {w} -> {u}")
    # surviving W-group graphs stay connected over alive routers
    if kind == "switchless":
        for wg, comp in _wgroup_components(net, faults,
                                           wgs=check_wgs).items():
            if comp > 1:
                raise ValueError(
                    f"faults disconnect the surviving graph of W-group "
                    f"{wg} ({comp} components)")
    return dict(dead_channels=int(dead.sum()),
                dead_routers=len(faults.dead_routers),
                alive_terminals=int(term_alive.sum()))


def wgroup_adjacency(net: Network, faults: FaultSet | None = None,
                     wgs=None):
    """Per-W-group alive adjacency over wg-local router ids.

    Returns (adj, alive) where adj[wg] maps u -> list of (v, weight) over
    surviving mesh/local channels and alive[wg] is the bool router-alive
    mask, both in wg-local ids (u = node % (ab * nodes_per_cg)).  With
    `wgs`, only those W-groups get adjacency lists (the rest stay empty)
    — the incremental-validation fast path."""
    assert net.meta["kind"] == "switchless"
    faults = faults or FaultSet()
    ab, npc = net.meta["ab"], net.meta["nodes_per_cg"]
    NW = ab * npc
    g = net.meta["g"]
    ch_alive = faults.ch_alive(net)
    node_alive = faults.node_alive(net)
    intra = (net.ch_type == MESH) | (net.ch_type == LOCAL)
    keep = intra & ch_alive
    if wgs is not None:
        keep &= np.isin(net.ch_src // NW, np.asarray(list(wgs)))
    eids = np.where(keep)[0]
    src, dst = net.ch_src[eids], net.ch_dst[eids]
    wgt = np.where(net.ch_type[eids] == MESH, 1, 4)
    adj = [[[] for _ in range(NW)] for _ in range(g)]
    for s, d, w in zip(src, dst, wgt):
        if node_alive[s] and node_alive[d]:
            adj[s // NW][s % NW].append((d % NW, int(w)))
    alive = node_alive.reshape(g, NW)
    return adj, alive


def _wgroup_components(net: Network, faults: FaultSet,
                       wgs=None) -> dict:
    """Connected-component count of the surviving graph, per W-group
    (all of them, or just `wgs`)."""
    wg_list = list(range(net.meta["g"])) if wgs is None else sorted(wgs)
    adj, alive = wgroup_adjacency(net, faults, wgs=wg_list)
    out = {}
    for wg in wg_list:
        al = alive[wg]
        seen = ~al.copy()
        comps = 0
        for root in np.where(al)[0]:
            if seen[root]:
                continue
            comps += 1
            stack = [root]
            seen[root] = True
            while stack:
                u = stack.pop()
                for v, _ in adj[wg][u]:
                    if not seen[v]:
                        seen[v] = True
                        stack.append(v)
        out[wg] = comps
    return out


def _greedy_valid(net: Network, candidates, vc_mode: str,
                  routers: bool = False,
                  base: FaultSet | None = None) -> FaultSet:
    """Accumulate faults one candidate at a time on top of `base`,
    skipping any that would break `validate_faults` — degraded networks
    stay routable by construction.  A non-router candidate may be a
    channel id or a tuple of channel ids that die together (both
    directions of a link).

    Each step validates incrementally: the per-W-group connectivity BFS
    only covers the W-group(s) the candidate touches (the vectorized
    global/terminal/symmetry checks always run), so sampling stays
    ~linear in the fault count instead of quadratic."""
    cur = base or FaultSet()
    if base is not None and not base.is_empty:
        validate_faults(net, base, vc_mode)   # base checked in full once
    switchless = net.meta["kind"] == "switchless"
    NW = (net.meta["ab"] * net.meta["nodes_per_cg"]) if switchless else 1
    for c in candidates:
        if routers:
            trial = FaultSet(cur.dead_ch, cur.dead_routers + (int(c),))
            touched = {int(c) // NW} if switchless else None
        else:
            chs = tuple(int(x) for x in np.atleast_1d(c) if int(x) >= 0)
            trial = FaultSet(cur.dead_ch + chs, cur.dead_routers)
            touched = {int(net.ch_src[ch]) // NW for ch in chs
                       if net.ch_type[ch] in (MESH, LOCAL)} \
                if switchless else None
        try:
            validate_faults(net, trial, vc_mode, check_wgs=touched)
        except ValueError:
            continue
        cur = trial
    return cur


def sample_link_faults(net: Network, frac: float,
                       rng: np.random.Generator,
                       types=(MESH, LOCAL, GLOBAL),
                       vc_mode: str = "updown",
                       base: FaultSet | None = None) -> FaultSet:
    """Kill ~`frac` of the fabric links of the given types, uniformly at
    random, skipping kills that would disconnect the surviving network.

    Mesh/local links die as whole bundles (both directions at once, see
    `reverse_fabric_channel`); global links die per direction.  `base`
    composes on top of existing faults (the result includes them and
    stays valid as a whole)."""
    rev = reverse_fabric_channel(net)
    cand = np.where(np.isin(net.ch_type, np.asarray(types))
                    & ((rev < 0) | (np.arange(net.num_channels) < rev)))[0]
    n = int(round(frac * len(cand)))
    if n == 0:
        return base or FaultSet()
    picks = rng.choice(cand, size=min(n, len(cand)), replace=False)
    return _greedy_valid(net, [(c, rev[c]) for c in picks], vc_mode,
                         base=base)


def sample_router_faults(net: Network, num: int,
                         rng: np.random.Generator,
                         vc_mode: str = "updown",
                         base: FaultSet | None = None) -> FaultSet:
    """Kill up to `num` whole routers (known-good-die yield loss), skipping
    kills that would disconnect the surviving network."""
    picks = rng.choice(net.num_nodes, size=min(num, net.num_nodes),
                      replace=False)
    return _greedy_valid(net, picks, vc_mode, routers=True, base=base)


def sample_cluster_faults(net: Network, rng: np.random.Generator,
                          num_clusters: int = 1, radius: int = 1,
                          vc_mode: str = "updown",
                          base: FaultSet | None = None) -> FaultSet:
    """Clustered defect regions: kill the routers within Chebyshev
    `radius` of a random centre router of a random C-group (defects on a
    wafer are spatially correlated, not iid)."""
    assert net.meta["kind"] == "switchless"
    R = net.meta["R"]
    npc = net.meta["nodes_per_cg"]
    num_cg = net.meta["num_cgroups"]
    picks = []
    for _ in range(num_clusters):
        cgg = int(rng.integers(0, num_cg))
        cx, cy = int(rng.integers(0, R)), int(rng.integers(0, R))
        for y in range(max(0, cy - radius), min(R, cy + radius + 1)):
            for x in range(max(0, cx - radius), min(R, cx + radius + 1)):
                picks.append(cgg * npc + y * R + x)
    order = rng.permutation(len(picks))
    return _greedy_valid(net, [picks[i] for i in order], vc_mode,
                         routers=True, base=base)


# ---------------------------------------------------------------------------
# Switch-less Dragonfly on wafers
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SwitchlessParams:
    """Paper notation (Sec. III).

    a   C-groups per wafer
    b   wafers per W-group
    m   chiplets per C-group edge (C-group is m x m chiplets)
    n   interconnection interfaces per chiplet (n/4 per edge)
    noc on-chiplet network edge size (eval uses 2 -> 2x2 routers per chiplet)
    g   number of W-groups; None -> maximum ab*h+1
    cg_bw_mult  intra-C-group (on-wafer) bandwidth multiplier ("2B/4B" runs)
    """

    a: int
    b: int
    m: int
    n: int
    noc: int = 2
    g: int | None = None
    cg_bw_mult: int = 1
    lr_latency: int = 8
    sr_latency: int = 1
    # routers per chip override: by default a chip is a noc x noc router tile;
    # set e.g. 2 to model chips owning 2 routers (radix-32 equivalence where
    # the C-group hosts 8 chips on a 4x4 router grid).
    chip_routers: int | None = None

    @property
    def k(self) -> int:
        """External ports of a C-group (Sec. III-A2: k = n*m)."""
        return self.n * self.m

    @property
    def ab(self) -> int:
        return self.a * self.b

    @property
    def h(self) -> int:
        """Global ports per C-group: h = k - ab + 1 (Sec. III-A4)."""
        return self.k - self.ab + 1

    @property
    def g_max(self) -> int:
        """Max W-groups: g = ab*h + 1 (Sec. III-A4)."""
        return self.ab * self.h + 1

    @property
    def num_wgroups(self) -> int:
        g = self.g_max if self.g is None else self.g
        if not (1 <= g <= self.g_max):
            raise ValueError(f"g={g} outside [1,{self.g_max}]")
        return g

    @property
    def R(self) -> int:
        """Router-grid edge size of a C-group."""
        return self.m * self.noc

    @property
    def routers_per_chip(self) -> int:
        if self.chip_routers is not None:
            return self.chip_routers
        return self.noc * self.noc

    @property
    def chips_per_cgroup(self) -> int:
        rr = self.R * self.R
        assert rr % self.routers_per_chip == 0
        return rr // self.routers_per_chip

    @property
    def num_chips(self) -> int:
        return self.chips_per_cgroup * self.ab * self.num_wgroups

    @property
    def N_eq1(self) -> int:
        """Eq. (1): N = a*b*m^2 * g with g at maximum."""
        return self.ab * self.m * self.m * self.g_max


def _perimeter_walk(R: int) -> list[tuple[int, int]]:
    """Clockwise walk of the R x R grid perimeter starting at (0, 0).

    Returns 4*(R-1) (x, y) positions (x = column, y = row, row 0 at top).
    This is the polar-system labeling of Fig. 8(c): ports are ordered along
    this walk, which makes port-to-port ring routing monotone in the label.
    """
    if R == 1:
        return [(0, 0)]
    walk = []
    for x in range(R - 1):
        walk.append((x, 0))          # top edge, left->right
    for y in range(R - 1):
        walk.append((R - 1, y))      # right edge, top->bottom
    for x in range(R - 1, 0, -1):
        walk.append((x, R - 1))      # bottom edge, right->left
    for y in range(R - 1, 0, -1):
        walk.append((0, y))          # left edge, bottom->top
    return walk


def build_switchless(p: SwitchlessParams, name: str = "switchless") -> Network:
    """Build the switch-less Dragonfly router/channel graph + routing tables."""
    R = p.R
    ab, k, g = p.ab, p.k, p.num_wgroups
    if p.h < 1:
        raise ValueError(f"h={p.h} < 1: k={p.k} too small for ab={ab}")
    n_local = ab - 1
    perim = _perimeter_walk(R)
    P = len(perim)
    # Distribute the k ports evenly along the perimeter walk (polar labels).
    # k may exceed P (several ports per perimeter router, cf. Fig. 9 where a
    # chiplet edge carries multiple channels); floor keeps labels monotone
    # along the walk so the polar up*/down* ordering is preserved.
    port_pos = np.floor(np.arange(k) * P / k).astype(np.int64)
    port_xy = np.array([perim[i] for i in port_pos], dtype=np.int64)  # [k,2]

    num_cg = ab * g
    nodes_per_cg = R * R
    V = num_cg * nodes_per_cg
    T = V  # one terminal per router (chiplet core)

    def node_id(wg: int, cg: int, x: int, y: int) -> int:
        return ((wg * ab + cg) * nodes_per_cg) + y * R + x

    # --- node / terminal metadata -------------------------------------
    idx = np.arange(V)
    node_cg_global = idx // nodes_per_cg
    node_wg = node_cg_global // ab
    node_cg = node_cg_global % ab
    node_local = idx % nodes_per_cg
    node_x = node_local % R
    node_y = node_local // R
    if p.chip_routers is None:
        # chip id: chiplets are noc x noc router tiles
        chip_x = node_x // p.noc
        chip_y = node_y // p.noc
        node_chip = node_cg_global * p.chips_per_cgroup + chip_y * p.m + chip_x
    else:
        node_chip = node_cg_global * p.chips_per_cgroup + \
            node_local // p.chip_routers
    term_node = idx.copy()
    term_chip = node_chip.copy()

    # --- channels ------------------------------------------------------
    src, dst, bw, lat, typ = [], [], [], [], []

    def add(s, d, b, l, t):
        src.append(s); dst.append(d); bw.append(b); lat.append(l); typ.append(t)
        return len(src) - 1

    # mesh channels, per C-group: node -> 4 neighbours (N,E,S,W order)
    DIRS = ((0, -1), (1, 0), (0, 1), (-1, 0))  # N, E, S, W in (dx, dy)
    node_mesh_ch = np.full((V, 4), -1, dtype=np.int64)
    for cgg in range(num_cg):
        wg, cg = divmod(cgg, ab)
        for y in range(R):
            for x in range(R):
                s = node_id(wg, cg, x, y)
                for di, (dx, dy) in enumerate(DIRS):
                    nx, ny = x + dx, y + dy
                    if 0 <= nx < R and 0 <= ny < R:
                        c = add(s, node_id(wg, cg, nx, ny),
                                p.cg_bw_mult, p.sr_latency, MESH)
                        node_mesh_ch[s, di] = c

    # inject channels (ejects are added LAST: trailing-block contract)
    inject_ch = np.zeros(T, dtype=np.int64)
    for t in range(T):
        inject_ch[t] = add(V + t, term_node[t], 1, 1, INJECT)  # src id unused

    # port labeling and the local/global split (Fig. 6):
    # ports 0..n_local-1 are LOCAL (to the other ab-1 C-groups of the W-group),
    # ports n_local..k-1 are GLOBAL.  Property 2 ordering: within the polar
    # walk the local ports to lower C-groups come first, then globals, then
    # local ports to higher C-groups.  We realize it by mapping: local port j
    # of C-group c connects to C-group (c + 1 + j) mod ab ... see below; and
    # placing globals in the middle of the label range.
    # Concretely we order port labels:
    #   labels [0, cg)             -> local ports to C-groups 0..cg-1 (down)
    #   labels [cg, cg + h)        -> global ports
    #   labels [cg + h, k)         -> local ports to C-groups cg+1..ab-1 (up)
    # which satisfies Property 2 exactly.
    local_port = np.full((ab, ab), -1, dtype=np.int64)   # [cg, peer_cg] -> port
    global_ports = np.zeros((ab, p.h), dtype=np.int64)   # [cg, j] -> port label
    for cg in range(ab):
        for peer in range(ab):
            if peer < cg:
                local_port[cg, peer] = peer
            elif peer > cg:
                local_port[cg, peer] = p.h + peer - 1
        for j in range(p.h):
            global_ports[cg, j] = cg + j  # labels cg..cg+h-1 are global
    # NOTE: with this scheme label ranges depend on cg; all labels < k.

    # external channel endpoints: ext_out[cgg, port] = channel id
    ext_out = np.full((num_cg, k), -1, dtype=np.int64)

    # local links: within each W-group, C-groups fully connected
    for wg in range(g):
        for c1 in range(ab):
            for c2 in range(ab):
                if c1 == c2:
                    continue
                p1 = local_port[c1, c2]
                s = node_id(wg, c1, *port_xy[p1])
                d_port = local_port[c2, c1]
                d = node_id(wg, c2, *port_xy[d_port])
                ch = add(s, d, 1, p.lr_latency, LOCAL)
                ext_out[wg * ab + c1, p1] = ch

    # global links: W-groups fully connected (Sec. III-A4).  Port q of
    # W-group w (q = cg*h + j in [0, ab*h)) connects toward W-group
    # (w + q + 1) mod g.  When g < g_max the surplus ports wrap around and
    # give PARALLEL links per W-group pair; all of them are wired (routing
    # spreads flows across them by destination hash).
    npar = max(1, (ab * p.h) // max(g - 1, 1)) if g > 1 else 1
    glob_route_cg = np.full((g, g, npar), -1, dtype=np.int64)
    glob_route_port = np.full((g, g, npar), -1, dtype=np.int64)
    glob_npar = np.ones((g, g), dtype=np.int64)
    if g > 1:
        for wg in range(g):
            cnt = np.zeros(g, dtype=np.int64)
            for q in range(ab * p.h):
                peer = (wg + q + 1) % g
                if peer == wg or cnt[peer] >= npar:
                    continue
                cg, j = divmod(q, p.h)
                glob_route_cg[wg, peer, cnt[peer]] = cg
                glob_route_port[wg, peer, cnt[peer]] = global_ports[cg, j]
                cnt[peer] += 1
            glob_npar[wg] = np.maximum(cnt, 1)
        # parallel index r of (wg, peer) pairs with r-th link of (peer, wg)
        for wg in range(g):
            for peer in range(g):
                if peer == wg:
                    continue
                for r in range(npar):
                    cg = glob_route_cg[wg, peer, r]
                    if cg < 0 or glob_route_cg[peer, wg, r] < 0:
                        continue
                    port = glob_route_port[wg, peer, r]
                    s = node_id(wg, cg, *port_xy[port])
                    pcg = glob_route_cg[peer, wg, r]
                    pport = glob_route_port[peer, wg, r]
                    d = node_id(peer, pcg, *port_xy[pport])
                    ch = add(s, d, 1, p.lr_latency, GLOBAL)
                    ext_out[wg * ab + cg, port] = ch
        # routable parallel count = links wired in BOTH directions
        glob_npar = np.minimum(glob_npar, glob_npar.T)
        np.fill_diagonal(glob_npar, 1)

    # eject channels last: the engine slices requesters to [:first_eject]
    eject_ch = np.full(V, -1, dtype=np.int64)
    for t in range(T):
        eject_ch[t] = add(term_node[t], V + t, 1, 1, EJECT)

    # --- routing tables --------------------------------------------------
    # perimeter position of each node (-1 if interior) for ring routing
    perim_pos = np.full(V, -1, dtype=np.int64)
    pos_of_xy = {xy: i for i, xy in enumerate(perim)}
    for v in range(V):
        xy = (int(node_x[v]), int(node_y[v]))
        if xy in pos_of_xy:
            perim_pos[v] = pos_of_xy[xy]
    # ring next/prev direction index (into DIRS) for each perimeter position
    ring_next_dir = np.zeros(P, dtype=np.int64)
    ring_prev_dir = np.zeros(P, dtype=np.int64)
    for i in range(P):
        x0, y0 = perim[i]
        x1, y1 = perim[(i + 1) % P]
        ring_next_dir[i] = DIRS.index((int(np.sign(x1 - x0)), int(np.sign(y1 - y0))))
        ring_prev_dir[(i + 1) % P] = DIRS.index((int(np.sign(x0 - x1)), int(np.sign(y0 - y1))))
    # port -> (node-local x, y), port -> perimeter pos
    port_node_local = port_xy[:, 1] * R + port_xy[:, 0]
    port_perim_pos = port_pos.copy()

    # snake (boustrophedon) order of chips for ring embeddings: consecutive
    # chips in the ring are physically adjacent on the wafer
    if p.chip_routers is None:
        cm = p.m  # chip grid is m x m
        snake_local = []
        for cy in range(cm):
            xs = range(cm) if cy % 2 == 0 else range(cm - 1, -1, -1)
            snake_local.extend(cy * cm + cx for cx in xs)
    else:
        snake_local = list(range(p.chips_per_cgroup))
    cpc = p.chips_per_cgroup
    chip_ring_order = np.concatenate([
        cgg * cpc + np.asarray(snake_local) for cgg in range(num_cg)])

    tables = dict(
        node_wg=node_wg, node_cg=node_cg, node_cg_global=node_cg_global,
        node_x=node_x, node_y=node_y,
        node_mesh_ch=node_mesh_ch, eject_ch=eject_ch,
        ext_out=ext_out, local_port=local_port,
        glob_route_cg=glob_route_cg, glob_route_port=glob_route_port,
        glob_npar=glob_npar,
        port_node_local=port_node_local, port_perim_pos=port_perim_pos,
        perim_pos=perim_pos, ring_next_dir=ring_next_dir,
        ring_prev_dir=ring_prev_dir,
        term_node=term_node,
        chip_ring_order=chip_ring_order,
        wg_term_base=np.arange(g) * ab * nodes_per_cg,
    )
    meta = dict(kind="switchless", params=dataclasses.asdict(p), R=R, ab=ab,
                k=k, h=p.h, g=g, nodes_per_cg=nodes_per_cg,
                terms_per_wg=ab * nodes_per_cg,
                terms_per_chip=p.routers_per_chip,
                num_cgroups=num_cg)

    net = Network(
        name=name, num_nodes=V, num_terminals=T, num_chips=int(p.num_chips),
        term_node=term_node, term_chip=term_chip,
        ch_src=np.array(src), ch_dst=np.array(dst),
        ch_bw=np.array(bw, dtype=np.int64), ch_lat=np.array(lat, dtype=np.int64),
        ch_type=np.array(typ, dtype=np.int64),
        inject_ch=inject_ch, eject_ch=eject_ch, tables=tables, meta=meta)
    net.validate()
    return net


# ---------------------------------------------------------------------------
# Traditional switch-based Dragonfly (baseline, Kim et al. 2008)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SwitchDragonflyParams:
    """Standard Dragonfly: radix = t + l + gl per switch.

    t terminals/switch, l local ports (group has l+1 switches), gl global
    ports/switch.  Groups: g <= (l+1)*gl + 1.
    """

    t: int
    l: int
    gl: int
    g: int | None = None
    lr_latency: int = 8

    @property
    def radix(self) -> int:
        return self.t + self.l + self.gl

    @property
    def switches_per_group(self) -> int:
        return self.l + 1

    @property
    def g_max(self) -> int:
        return self.switches_per_group * self.gl + 1

    @property
    def num_groups(self) -> int:
        g = self.g_max if self.g is None else self.g
        if not (1 <= g <= self.g_max):
            raise ValueError(f"g={g} outside [1,{self.g_max}]")
        return g

    @property
    def num_chips(self) -> int:
        return self.t * self.switches_per_group * self.num_groups


def build_switch_dragonfly(p: SwitchDragonflyParams,
                           name: str = "dragonfly") -> Network:
    """Ideal-router switch-based Dragonfly (paper's baseline)."""
    g = p.num_groups
    spg = p.switches_per_group
    V = g * spg                      # switch nodes
    T = V * p.t                      # terminals

    term_node = np.repeat(np.arange(V), p.t)
    term_chip = np.arange(T)         # every terminal is a chip

    src, dst, bw, lat, typ = [], [], [], [], []

    def add(s, d, b, l, t):
        src.append(s); dst.append(d); bw.append(b); lat.append(l); typ.append(t)
        return len(src) - 1

    inject_ch = np.zeros(T, dtype=np.int64)
    for t_ in range(T):
        inject_ch[t_] = add(V + t_, term_node[t_], 1, 1, INJECT)

    # local links: full mesh within each group
    local_ch = np.full((V, spg), -1, dtype=np.int64)  # [switch, peer_idx]
    for grp in range(g):
        base = grp * spg
        for i in range(spg):
            for j in range(spg):
                if i == j:
                    continue
                local_ch[base + i, j] = add(base + i, base + j, 1,
                                            p.lr_latency, LOCAL)

    # global links: group w port q -> group (w + q + 1) mod g; port q lives
    # on switch q // gl.  Surplus ports when g < g_max wrap into parallel
    # links per group pair, all wired.
    npar = max(1, (spg * p.gl) // max(g - 1, 1)) if g > 1 else 1
    glob_route_sw = np.full((g, g, npar), -1, dtype=np.int64)
    glob_out_ch = np.full((g, g, npar), -1, dtype=np.int64)
    glob_npar = np.ones((g, g), dtype=np.int64)
    if g > 1:
        for grp in range(g):
            cnt = np.zeros(g, dtype=np.int64)
            for q in range(spg * p.gl):
                peer = (grp + q + 1) % g
                if peer == grp or cnt[peer] >= npar:
                    continue
                glob_route_sw[grp, peer, cnt[peer]] = grp * spg + q // p.gl
                cnt[peer] += 1
            glob_npar[grp] = np.maximum(cnt, 1)
        for grp in range(g):
            for peer in range(g):
                if peer == grp:
                    continue
                for r in range(npar):
                    sw = glob_route_sw[grp, peer, r]
                    psw = glob_route_sw[peer, grp, r]
                    if sw < 0 or psw < 0:
                        continue
                    glob_out_ch[grp, peer, r] = add(sw, psw, 1,
                                                    p.lr_latency, GLOBAL)
        glob_npar = np.minimum(glob_npar, glob_npar.T)
        np.fill_diagonal(glob_npar, 1)

    # eject channels last (trailing-block contract, cf. build_switchless)
    eject_sw_term = np.full((V, p.t), -1, dtype=np.int64)  # per-terminal eject
    for t_ in range(T):
        sw = term_node[t_]
        eject_sw_term[sw, t_ % p.t] = add(sw, V + t_, 1, 1, EJECT)

    eject_ch = np.full(V, -1, dtype=np.int64)  # first eject per switch (unused)
    tables = dict(
        node_grp=np.arange(V) // spg, node_idx=np.arange(V) % spg,
        local_ch=local_ch, glob_route_sw=glob_route_sw,
        glob_out_ch=glob_out_ch, glob_npar=glob_npar,
        eject_sw_term=eject_sw_term,
        term_node=term_node, term_slot=np.arange(T) % p.t,
        chip_ring_order=np.arange(T),
        grp_term_base=np.arange(g) * spg * p.t,
    )
    meta = dict(kind="dragonfly", params=dataclasses.asdict(p), g=g, spg=spg,
                terms_per_grp=spg * p.t, terms_per_chip=1)
    net = Network(
        name=name, num_nodes=V, num_terminals=T, num_chips=T,
        term_node=term_node, term_chip=term_chip,
        ch_src=np.array(src), ch_dst=np.array(dst),
        ch_bw=np.array(bw, dtype=np.int64), ch_lat=np.array(lat, dtype=np.int64),
        ch_type=np.array(typ, dtype=np.int64),
        inject_ch=inject_ch, eject_ch=eject_ch, tables=tables, meta=meta)
    net.validate()
    return net


# --- canonical evaluation configurations (Sec. V-A4) -----------------------

def paper_radix16_switchless(g: int | None = None, cg_bw_mult: int = 1,
                             noc: int = 2) -> SwitchlessParams:
    """2x2 chiplets with 2x2 on-chiplet NoC; 12 external ports (7 local +
    5 global); 8 C-groups per W-group; 41 W-groups, 1312 chips."""
    return SwitchlessParams(a=2, b=4, m=2, n=6, noc=noc, g=g,
                            cg_bw_mult=cg_bw_mult)


def paper_radix16_dragonfly(g: int | None = None) -> SwitchDragonflyParams:
    """Radix-16 switch split 4:7:5 -> (41 groups, 1312 chips)."""
    return SwitchDragonflyParams(t=4, l=7, gl=5, g=g)


def paper_radix32_switchless(g: int | None = None, cg_bw_mult: int = 1
                             ) -> SwitchlessParams:
    """Radix-32-equivalent: 24 external ports (15 local + 9 global),
    16 C-groups per W-group, 8 chips per C-group -> 145 groups, 18560 chips.

    ab=16, k=nm=24 -> h=9, g_max=145.  The 4x4 router grid (m=2 chiplets with
    2x2 NoCs) hosts 8 chips of 2 routers each (chip_routers=2), matching the
    paper's 8 terminals per radix-32 switch: N = 8*16*145 = 18560.
    """
    return SwitchlessParams(a=4, b=4, m=2, n=12, noc=2, g=g,
                            cg_bw_mult=cg_bw_mult, chip_routers=2)


def paper_radix32_dragonfly(g: int | None = None) -> SwitchDragonflyParams:
    """Radix-32 switch split 8:15:9 -> (145 groups, 18560 chips)."""
    return SwitchDragonflyParams(t=8, l=15, gl=9, g=g)


def paper_table3_switchless() -> SwitchlessParams:
    """Sec. III-C case study: n=12, m=4, a=4, b=8 -> N=279040."""
    return SwitchlessParams(a=4, b=8, m=4, n=12, noc=1)
