"""Traffic patterns for the network simulator (paper Sec. V-A3).

A pattern is a closure `sample(key, t) -> dest[T]` giving, for every source
terminal, the destination terminal it would use for a packet generated this
cycle.  Permutation patterns ignore the key.

Normalized protocol: every public factory returns a `TrafficPattern`
`(sample, inject_mask)` pair (this fixed the historical asymmetry where
`hotspot` returned a bare tuple while everything else returned a bare
sampler).  `TrafficPattern` is itself callable (it delegates to `sample`),
so legacy call sites that treat the factory result as the sampler keep
working; sites that care about masked injection (hotspot confines sources
to the hot W-groups) unpack the pair or use `as_pattern`.  `PATTERNS` is
the by-name registry the declarative experiment layer (`repro.exp`)
resolves `TrafficSpec`s against via `make_pattern`.
"""
from __future__ import annotations

import inspect
from typing import Callable, NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from .topology import Network


class TrafficPattern(NamedTuple):
    """Normalized traffic pattern: per-lane sampler + optional source mask.

    `sample(key, t) -> dest[T]`; `inject_mask` is a bool [T] numpy array of
    terminals allowed to inject, or None for "all terminals".  The tuple is
    callable (delegates to `sample`) so it can be passed anywhere a bare
    sampler was accepted.
    """

    sample: Callable
    inject_mask: object = None

    def __call__(self, key, t):
        return self.sample(key, t)


def as_pattern(pattern, inject_mask=None) -> TrafficPattern:
    """Normalize a sampler / (sample, mask) pair into a `TrafficPattern`.

    An explicit `inject_mask` composes (AND) with the pattern's own mask,
    so masking a hotspot pattern further restricts the hot sources.
    Idempotent on already-normalized patterns.
    """
    if isinstance(pattern, TrafficPattern):
        sample, mask = pattern.sample, pattern.inject_mask
    elif isinstance(pattern, tuple):
        sample, mask = pattern
    else:
        sample, mask = pattern, None
    if inject_mask is not None:
        extra = np.asarray(inject_mask).astype(bool)
        mask = extra if mask is None \
            else np.asarray(mask).astype(bool) & extra
    return TrafficPattern(sample, mask)


def _bits(n: int) -> int:
    return max(1, int(np.ceil(np.log2(max(n, 2)))))


def _guard(dest: np.ndarray, T: int) -> np.ndarray:
    """Out-of-range destinations (non-power-of-two T) map to self; the
    simulator treats dest == src as "don't inject" (permutation fixed
    points are silent)."""
    src = np.arange(len(dest))
    return np.where(dest >= T, src, dest)


def uniform(net: Network) -> TrafficPattern:
    T = net.num_terminals

    def sample(key, t):
        src = jnp.arange(T)
        d = jax.random.randint(key, (T,), 0, T - 1)
        return jnp.where(d >= src, d + 1, d)  # uniform over T-1 others

    return TrafficPattern(sample)


def _perm_pattern(dest_np: np.ndarray) -> TrafficPattern:
    dest = jnp.asarray(dest_np)

    def sample(key, t):
        return dest

    return TrafficPattern(sample)


def bit_reverse(net: Network):
    T = net.num_terminals
    b = _bits(T)
    src = np.arange(T)
    d = np.zeros(T, dtype=np.int64)
    for i in range(b):
        d |= (((src >> i) & 1) << (b - 1 - i))
    return _perm_pattern(_guard(d, T))


def bit_shuffle(net: Network):
    """Rotate address bits left by one."""
    T = net.num_terminals
    b = _bits(T)
    src = np.arange(T)
    d = ((src << 1) | (src >> (b - 1))) & ((1 << b) - 1)
    return _perm_pattern(_guard(d, T))


def bit_transpose(net: Network):
    """Swap upper/lower halves of the address bits."""
    T = net.num_terminals
    b = _bits(T)
    h = b // 2
    src = np.arange(T)
    lo = src & ((1 << h) - 1)
    hi = src >> h
    d = (lo << (b - h)) | hi
    return _perm_pattern(_guard(d, T))


def _terms_per_group(net: Network) -> int:
    for key in ("terms_per_wg", "terms_per_grp"):
        if key in net.meta:
            return net.meta[key]
    raise KeyError(
        "group-structured traffic needs net.meta['terms_per_wg'] "
        "(switchless) or net.meta['terms_per_grp'] (dragonfly); "
        f"neither is set (meta keys: {sorted(net.meta)})")


def _num_groups(net: Network) -> int:
    return net.meta["g"]


def hotspot(net: Network, num_hot: int = 4, seed: int = 0) -> TrafficPattern:
    """Communication confined to `num_hot` of the W-groups (Sec. V-A3b):
    sources in hot groups send to random terminals of the other hot groups.
    The returned pattern carries the hot-source `inject_mask`."""
    g = _num_groups(net)
    tpg = _terms_per_group(net)
    rng = np.random.default_rng(seed)
    hot = np.sort(rng.choice(g, size=min(num_hot, g), replace=False))
    hot_j = jnp.asarray(hot)
    T = net.num_terminals
    src_wg = np.arange(T) // tpg
    is_hot = jnp.asarray(np.isin(src_wg, hot))

    def sample(key, t):
        k1, k2 = jax.random.split(key)
        wsel = jax.random.randint(k1, (T,), 0, len(hot))
        off = jax.random.randint(k2, (T,), 0, tpg)
        dest = hot_j[wsel] * tpg + off
        # non-hot sources still draw a hot destination (they won't inject if
        # the benchmark masks them; keeping them hot-bound matches "conducts
        # communications within four of all W-groups").
        return dest

    return TrafficPattern(sample, np.asarray(is_hot))


def worst_case(net: Network) -> TrafficPattern:
    """Adversarial WC: node in W-group i sends to random node of W-group
    i+1 (Sec. V-A3b / Kim et al.)."""
    g = _num_groups(net)
    tpg = _terms_per_group(net)
    T = net.num_terminals
    src_wg = jnp.asarray(np.arange(T) // tpg)

    def sample(key, t):
        off = jax.random.randint(key, (T,), 0, tpg)
        return ((src_wg + 1) % g) * tpg + off

    return TrafficPattern(sample)


def ring_allreduce(net: Network, bidirectional: bool = False) -> TrafficPattern:
    """Ring AllReduce traffic (Sec. V-A3c): chip i sends to chip (i+1) mod C
    (uni) or alternates between (i-1) and (i+1) (bi).

    The ring follows the snake (boustrophedon) order of chips on the wafer,
    so consecutive chips are physically adjacent.  Terminal-level embedding:
    terminal j of chip i targets terminal j of the neighbouring chip, which
    exercises all parallel chip-to-chip paths the wafer provides (the
    paper's "four injection/ejection ports per chip").
    """
    T = net.num_terminals
    C = net.num_chips
    tpc = net.meta.get("terms_per_chip", 1)
    assert T == C * tpc
    order = net.tables.get("chip_ring_order", np.arange(C))
    ring_pos = np.empty(C, dtype=np.int64)
    ring_pos[order] = np.arange(C)  # chip -> position in ring
    # terminals of each chip (ids are NOT contiguous per chip: they follow
    # the router raster); slot j of a chip is its j-th terminal by id
    chip = net.term_chip
    chip_terms = np.full((C, tpc), -1, dtype=np.int64)
    fill = np.zeros(C, dtype=np.int64)
    slot = np.zeros(T, dtype=np.int64)
    for t_ in range(T):
        c = chip[t_]
        slot[t_] = fill[c]
        chip_terms[c, fill[c]] = t_
        fill[c] += 1
    nxt_chip = order[(ring_pos[chip] + 1) % C]
    prv_chip = order[(ring_pos[chip] - 1) % C]
    nxt = chip_terms[nxt_chip, slot]
    prv = chip_terms[prv_chip, slot]
    nxt_j, prv_j = jnp.asarray(nxt), jnp.asarray(prv)

    if not bidirectional:
        def sample(key, t):
            return nxt_j
    else:
        def sample(key, t):
            coin = jax.random.bernoulli(key, 0.5, (T,))
            return jnp.where(coin, nxt_j, prv_j)

    return TrafficPattern(sample)


def batched(sample):
    """Lift a pattern `sample(key, t) -> dest[T]` to a batched-key path:
    `sample_b(keys[B, 2], t) -> dest[B, T]`.

    This is the contract the batch-parallel engine relies on: patterns are
    pure per-lane functions of their key, so a `vmap` over the key axis is
    the whole lift.  Permutation patterns (key-independent) broadcast."""
    if isinstance(sample, TrafficPattern):
        sample = sample.sample
    return jax.vmap(sample, in_axes=(0, None))


def split_lanes(key, num_lanes: int):
    """Per-lane PRNG keys [B, 2] for a batched sweep."""
    return jax.random.split(key, num_lanes)


# By-name registry: factory(net, **params) -> TrafficPattern.  This is the
# resolution surface of `repro.exp.TrafficSpec` — register new patterns
# here and they become addressable from declarative experiment specs.
PATTERNS = {
    "uniform": uniform,
    "bit_reverse": bit_reverse,
    "bit_shuffle": bit_shuffle,
    "bit_transpose": bit_transpose,
    "worst_case": worst_case,
    "hotspot": hotspot,
    "ring_allreduce": ring_allreduce,
}


def validate_pattern_params(name: str, params: dict) -> None:
    """Raise ValueError for an unknown pattern name or parameters that do
    not bind to the factory's signature (spec-construction-time check)."""
    if name not in PATTERNS:
        raise ValueError(
            f"unknown traffic pattern {name!r}; registered: "
            f"{sorted(PATTERNS)}")
    try:
        inspect.signature(PATTERNS[name]).bind(None, **params)
    except TypeError as e:
        raise ValueError(f"bad params for pattern {name!r}: {e}") from None


def make_pattern(net: Network, name: str, **params) -> TrafficPattern:
    """Resolve a registered pattern by name (normalized protocol: always a
    `TrafficPattern` pair, mask included)."""
    validate_pattern_params(name, params)
    return as_pattern(PATTERNS[name](net, **params))
