"""Traffic patterns for the network simulator (paper Sec. V-A3).

A pattern is a closure `sample(key, t) -> dest[T]` giving, for every source
terminal, the destination terminal it would use for a packet generated this
cycle.  Permutation patterns ignore the key.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .topology import Network


def _bits(n: int) -> int:
    return max(1, int(np.ceil(np.log2(max(n, 2)))))


def _guard(dest: np.ndarray, T: int) -> np.ndarray:
    """Out-of-range destinations (non-power-of-two T) map to self; the
    simulator treats dest == src as "don't inject" (permutation fixed
    points are silent)."""
    src = np.arange(len(dest))
    return np.where(dest >= T, src, dest)


def uniform(net: Network):
    T = net.num_terminals

    def sample(key, t):
        src = jnp.arange(T)
        d = jax.random.randint(key, (T,), 0, T - 1)
        return jnp.where(d >= src, d + 1, d)  # uniform over T-1 others

    return sample


def _perm_pattern(dest_np: np.ndarray):
    dest = jnp.asarray(dest_np)

    def sample(key, t):
        return dest

    return sample


def bit_reverse(net: Network):
    T = net.num_terminals
    b = _bits(T)
    src = np.arange(T)
    d = np.zeros(T, dtype=np.int64)
    for i in range(b):
        d |= (((src >> i) & 1) << (b - 1 - i))
    return _perm_pattern(_guard(d, T))


def bit_shuffle(net: Network):
    """Rotate address bits left by one."""
    T = net.num_terminals
    b = _bits(T)
    src = np.arange(T)
    d = ((src << 1) | (src >> (b - 1))) & ((1 << b) - 1)
    return _perm_pattern(_guard(d, T))


def bit_transpose(net: Network):
    """Swap upper/lower halves of the address bits."""
    T = net.num_terminals
    b = _bits(T)
    h = b // 2
    src = np.arange(T)
    lo = src & ((1 << h) - 1)
    hi = src >> h
    d = (lo << (b - h)) | hi
    return _perm_pattern(_guard(d, T))


def _terms_per_group(net: Network) -> int:
    return net.meta.get("terms_per_wg", net.meta.get("terms_per_grp"))


def _num_groups(net: Network) -> int:
    return net.meta["g"]


def hotspot(net: Network, num_hot: int = 4, seed: int = 0):
    """Communication confined to `num_hot` of the W-groups (Sec. V-A3b):
    sources in hot groups send to random terminals of the other hot groups."""
    g = _num_groups(net)
    tpg = _terms_per_group(net)
    rng = np.random.default_rng(seed)
    hot = np.sort(rng.choice(g, size=min(num_hot, g), replace=False))
    hot_j = jnp.asarray(hot)
    T = net.num_terminals
    src_wg = np.arange(T) // tpg
    is_hot = jnp.asarray(np.isin(src_wg, hot))

    def sample(key, t):
        k1, k2 = jax.random.split(key)
        wsel = jax.random.randint(k1, (T,), 0, len(hot))
        off = jax.random.randint(k2, (T,), 0, tpg)
        dest = hot_j[wsel] * tpg + off
        # non-hot sources still draw a hot destination (they won't inject if
        # the benchmark masks them; keeping them hot-bound matches "conducts
        # communications within four of all W-groups").
        return dest

    return sample, np.asarray(is_hot)


def worst_case(net: Network):
    """Adversarial WC: node in W-group i sends to random node of W-group
    i+1 (Sec. V-A3b / Kim et al.)."""
    g = _num_groups(net)
    tpg = _terms_per_group(net)
    T = net.num_terminals
    src_wg = jnp.asarray(np.arange(T) // tpg)

    def sample(key, t):
        off = jax.random.randint(key, (T,), 0, tpg)
        return ((src_wg + 1) % g) * tpg + off

    return sample


def ring_allreduce(net: Network, bidirectional: bool = False):
    """Ring AllReduce traffic (Sec. V-A3c): chip i sends to chip (i+1) mod C
    (uni) or alternates between (i-1) and (i+1) (bi).

    The ring follows the snake (boustrophedon) order of chips on the wafer,
    so consecutive chips are physically adjacent.  Terminal-level embedding:
    terminal j of chip i targets terminal j of the neighbouring chip, which
    exercises all parallel chip-to-chip paths the wafer provides (the
    paper's "four injection/ejection ports per chip").
    """
    T = net.num_terminals
    C = net.num_chips
    tpc = net.meta.get("terms_per_chip", 1)
    assert T == C * tpc
    order = net.tables.get("chip_ring_order", np.arange(C))
    ring_pos = np.empty(C, dtype=np.int64)
    ring_pos[order] = np.arange(C)  # chip -> position in ring
    # terminals of each chip (ids are NOT contiguous per chip: they follow
    # the router raster); slot j of a chip is its j-th terminal by id
    chip = net.term_chip
    chip_terms = np.full((C, tpc), -1, dtype=np.int64)
    fill = np.zeros(C, dtype=np.int64)
    slot = np.zeros(T, dtype=np.int64)
    for t_ in range(T):
        c = chip[t_]
        slot[t_] = fill[c]
        chip_terms[c, fill[c]] = t_
        fill[c] += 1
    nxt_chip = order[(ring_pos[chip] + 1) % C]
    prv_chip = order[(ring_pos[chip] - 1) % C]
    nxt = chip_terms[nxt_chip, slot]
    prv = chip_terms[prv_chip, slot]
    nxt_j, prv_j = jnp.asarray(nxt), jnp.asarray(prv)

    if not bidirectional:
        def sample(key, t):
            return nxt_j
    else:
        def sample(key, t):
            coin = jax.random.bernoulli(key, 0.5, (T,))
            return jnp.where(coin, nxt_j, prv_j)

    return sample


def batched(sample):
    """Lift a pattern `sample(key, t) -> dest[T]` to a batched-key path:
    `sample_b(keys[B, 2], t) -> dest[B, T]`.

    This is the contract the batch-parallel engine relies on: patterns are
    pure per-lane functions of their key, so a `vmap` over the key axis is
    the whole lift.  Permutation patterns (key-independent) broadcast."""
    return jax.vmap(sample, in_axes=(0, None))


def split_lanes(key, num_lanes: int):
    """Per-lane PRNG keys [B, 2] for a batched sweep."""
    return jax.random.split(key, num_lanes)


PATTERNS = {
    "uniform": uniform,
    "bit_reverse": bit_reverse,
    "bit_shuffle": bit_shuffle,
    "bit_transpose": bit_transpose,
    "worst_case": worst_case,
}
