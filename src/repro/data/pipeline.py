"""Deterministic synthetic token pipeline: seeded PRNG stream, sharded by
the data axis, double-buffered host prefetch.

The stream is a mixture of Zipf-distributed tokens with local n-gram
structure so cross-entropy actually decreases during the example runs
(pure-uniform tokens would pin the loss at log V).
"""
from __future__ import annotations

import queue
import threading

import numpy as np
import jax
import jax.numpy as jnp


class SyntheticTokens:
    """Batch iterator of (tokens, labels) with next-token labels."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, shard_index: int = 0, num_shards: int = 1,
                 order: int = 3):
        self.V = vocab_size
        self.B = batch
        self.S = seq_len
        self.seed = seed
        self.shard_index = shard_index
        self.num_shards = num_shards
        rng = np.random.default_rng(seed)
        # fixed random n-gram transition structure (shared across shards)
        self.order = order
        self.table = rng.integers(0, vocab_size,
                                  size=(997,)).astype(np.int64)
        ranks = np.arange(1, vocab_size + 1)
        zipf = 1.0 / ranks ** 1.1
        self.zipf = zipf / zipf.sum()
        self._step = 0

    def _gen(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * self.num_shards
            + self.shard_index)
        B, S, V = self.B, self.S, self.V
        noise = rng.choice(V, size=(B, S), p=self.zipf)
        toks = noise.copy()
        # inject learnable structure: with p=0.5 the next token is a
        # deterministic hash of the previous one
        det = (self.table[toks[:, :-1] % 997] + toks[:, :-1]) % V
        coin = rng.random((B, S - 1)) < 0.5
        toks[:, 1:] = np.where(coin, det, toks[:, 1:])
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        labels[:, -1] = -1  # no target for the last position
        return {"tokens": toks.astype(np.int32),
                "labels": labels.astype(np.int32)}

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        b = self._gen(self._step)
        self._step += 1
        return b

    def state(self) -> dict:
        return {"step": self._step}

    def restore(self, state: dict) -> None:
        self._step = int(state["step"])


class Prefetcher:
    """Double-buffered background prefetch (host thread)."""

    def __init__(self, it, depth: int = 2):
        self.it = it
        self.q = queue.Queue(maxsize=depth)
        self.done = False
        self.t = threading.Thread(target=self._fill, daemon=True)
        self.t.start()

    def _fill(self):
        try:
            for item in self.it:
                if self.done:
                    return
                self.q.put(item)
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def stop(self):
        self.done = True
