"""Declarative experiment API (see docs/experiments.md).

Specs describe the paper's scenario grids (topology x traffic x routing x
faults x rates x seeds) as frozen, JSON-round-trippable dataclasses; the
registry names the paper's Fig. 10-15 grids plus benchmark/smoke grids;
the runner lowers any spec onto the batch-parallel engine with one compile
per grid.

    from repro.exp import get_scenario, run_experiment
    result = run_experiment(get_scenario("fig10a"))
    for row in result.rows(): ...

CLI: ``python -m repro.exp.run --scenario smoke``.
"""
from .spec import (ExperimentSpec, FaultSpec, ReaperSpec, RoutingSpec,
                   SweepAxes, TopologySpec, TrafficSpec)
from .registry import (get_scenario, list_scenarios, register_scenario)
from .runner import (Cell, ExperimentResult, GridResult, cells,
                     clear_caches, run_experiment)
from .provenance import provenance, spec_hash
from .roofline import RooflineSpec
from .fleet import FleetSpec, FleetResult, fleet_inbox, run_fleet

__all__ = [
    "ExperimentSpec", "FaultSpec", "ReaperSpec", "RoutingSpec",
    "SweepAxes", "TopologySpec", "TrafficSpec", "RooflineSpec",
    "FleetSpec", "FleetResult", "fleet_inbox", "run_fleet",
    "get_scenario", "list_scenarios", "register_scenario",
    "Cell", "ExperimentResult", "GridResult", "cells", "clear_caches",
    "run_experiment", "provenance", "spec_hash",
]

# `repro.exp.serve` (the persistent service) and `repro.exp.windows`
# (the shared JSONL schema) are imported as submodules on demand —
# serving pulls in the checkpoint layer, which batch users don't need.
