"""Wafer-fleet Monte Carlo: yield distributions over sampled defect maps.

A `FleetSpec` describes a FLEET of wafers — hundreds of independently
sampled defect maps and fault/repair schedules (clustered manufacturing
defects, wear-out onset curves, router death, repair epochs) — all
running the same workload at the same offered load.  It lowers onto the
existing experiment machinery by the identity

    one Monte Carlo sample == one sweep-seed lane

Every fault level is a `FaultSpec` with `per_seed=True`, so seed lane
`s` draws its OWN defect map from stream ``1000 * level_seed + s``; the
fleet's `samples` count simply becomes the seed axis.  The whole fleet
is therefore one `ExperimentSpec` whose grid runs through
`BatchedSweep.run_lanes`' single-compile lane dispatch: hundreds of
distinct defect maps and repair schedules share ONE executable per
(topology x routing x traffic) cell (fault data is a traced argument;
heterogeneous epoch counts pad to one `[B, P, ...]` shape), and the
per-grid `compile_count` in the results certifies it.

`run_fleet` computes the yield distribution per fault level —
p10/p50/p90 of delivered throughput over the sampled wafers, the yield
fraction against a pristine-median threshold, and the reliability
counters (stranded / reaped) the router-death reaper maintains.
`benchmarks/bench_fleet.py` serializes these records to
BENCH_fleet.json; `fleet_inbox` re-emits the same fleet as a
multi-tenant `repro.exp.serve` inbox (one tenant per wafer), which
makes the fleet double as a serve-scheduler stress test: every wafer's
lanes land in the same signature bucket and pack across tenants.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass

import numpy as np

from .spec import (ExperimentSpec, FaultSpec, RoutingSpec, SweepAxes,
                   TopologySpec, TrafficSpec, _seq)
from .runner import ExperimentResult, run_experiment


@dataclass(frozen=True)
class FleetSpec:
    """A Monte Carlo wafer fleet (see module docstring).

    samples     number of independently sampled wafers (defect maps);
                becomes the sweep-seed axis, so every non-pristine level
                must sample `per_seed` (validated here — a shared map
                would collapse the distribution to one point).
    levels      the fault levels to distribute over, each a `FaultSpec`
                (typically: a pristine reference, clustered defects with
                wear-out `onsets`, router death, `repairs` epochs).
    offered     offered load (flits/cycle/chip) every wafer runs at.
    yield_threshold
                a wafer "yields" when its throughput reaches this
                fraction of the pristine level's median throughput
                (only meaningful when a pristine level is present).
    """

    name: str
    topology: TopologySpec
    routing: RoutingSpec
    levels: tuple
    samples: int = 8
    traffic: TrafficSpec = TrafficSpec("uniform")
    offered: float = 0.5
    warmup: int = 100
    measure: int = 400
    yield_threshold: float = 0.5
    notes: str = ""

    def __post_init__(self):
        if isinstance(self.topology, dict):
            object.__setattr__(self, "topology",
                               TopologySpec.from_dict(self.topology))
        if isinstance(self.routing, dict):
            object.__setattr__(self, "routing",
                               RoutingSpec.from_dict(self.routing))
        if isinstance(self.traffic, dict):
            object.__setattr__(self, "traffic",
                               TrafficSpec.from_dict(self.traffic))
        object.__setattr__(self, "levels", _seq(self.levels, FaultSpec))
        if not self.name:
            raise ValueError("fleet needs a name")
        if self.samples < 1:
            raise ValueError(f"need >= 1 sample, got {self.samples}")
        if not self.levels:
            raise ValueError("need >= 1 fault level (use FaultSpec() "
                             "for a pristine reference)")
        for f in self.levels:
            if not f.is_none and not f.per_seed:
                raise ValueError(
                    f"fleet level {f.label!r} has per_seed=False: every "
                    "sample would draw the SAME defect map, collapsing "
                    "the Monte Carlo distribution to one point")
        if not 0.0 < self.yield_threshold <= 1.0:
            raise ValueError(
                f"yield_threshold must be in (0, 1], got "
                f"{self.yield_threshold}")

    def to_experiment(self) -> ExperimentSpec:
        """The fleet as one standard `ExperimentSpec` grid: sample i is
        seed lane i.  Registered fleets are therefore covered by every
        spec-level gate (`repro.analysis.check --spec` proves each
        level's schedule — including repair transitions — statically)."""
        return ExperimentSpec(
            name=self.name,
            topologies=self.topology,
            traffics=self.traffic,
            routings=self.routing,
            axes=SweepAxes(rates=(self.offered,),
                           seeds=tuple(range(self.samples)),
                           faults=self.levels,
                           warmup=self.warmup, measure=self.measure),
            notes=self.notes or f"wafer-fleet Monte Carlo "
                                f"({self.samples} samples)")

    def to_dict(self) -> dict:
        return dict(
            name=self.name, topology=self.topology.to_dict(),
            routing=self.routing.to_dict(),
            levels=[f.to_dict() for f in self.levels],
            samples=self.samples, traffic=self.traffic.to_dict(),
            offered=self.offered, warmup=self.warmup,
            measure=self.measure, yield_threshold=self.yield_threshold,
            notes=self.notes)

    @classmethod
    def from_dict(cls, d: dict) -> "FleetSpec":
        return cls(**dict(d, levels=tuple(d["levels"])))


@dataclass
class FleetResult:
    """Per-level yield distributions plus the underlying experiment."""

    fleet: FleetSpec
    experiment: ExperimentResult
    records: list       # one dict per (grid cell, fault level)


def _quantiles(xs) -> dict:
    q10, q50, q90 = np.percentile(np.asarray(xs, dtype=float),
                                  [10.0, 50.0, 90.0])
    return dict(p10=float(q10), p50=float(q50), p90=float(q90))


def run_fleet(fleet: FleetSpec, verbose: bool = False) -> FleetResult:
    """Run the whole fleet (one batched dispatch per grid cell) and fold
    the per-wafer results into yield-distribution records.

    Each record covers one (cell, fault level) pair over all `samples`
    wafers: throughput/latency quantiles, the yield fraction against
    the pristine median, exact stranded max/mean, total reaped packets,
    and the compile count of the grid the samples shared."""
    exp = run_experiment(fleet.to_experiment(), verbose=verbose)
    records = []
    for g in exp.grids:
        # the pristine reference median for the yield threshold (None
        # when the fleet carries no pristine level)
        base_p50 = None
        for fi, f in enumerate(fleet.levels):
            if f.is_none:
                base_p50 = _quantiles(
                    [r.throughput_per_chip
                     for r in g.results[fi][0]])["p50"]
                break
        for fi, f in enumerate(fleet.levels):
            row = g.results[fi][0]              # [samples] SimResults
            thr = [r.throughput_per_chip for r in row]
            rec = dict(
                fleet=fleet.name,
                topology=g.topology.label,
                route_mode=g.routing.route_mode,
                vc_mode=g.routing.vc_mode,
                pattern=g.traffic.label,
                level=f.label,
                fault_frac=g.fault_fracs[fi],
                samples=len(row),
                offered=fleet.offered,
                throughput=_quantiles(thr),
                latency=_quantiles([r.avg_latency for r in row]),
                stranded_max=max(r.stranded_pkts for r in row),
                stranded_mean=float(np.mean([r.stranded_pkts
                                             for r in row])),
                reaped_total=sum(r.reaped_pkts for r in row),
                dropped_total=sum(r.dropped_pkts for r in row),
                compile_count=g.compile_count,
                placement=g.placement,
                grant_form=g.grant_form,
                wall_s=g.wall_s)
            if base_p50 is not None and base_p50 > 0:
                cut = fleet.yield_threshold * base_p50
                rec["yield_frac"] = float(
                    np.mean([t >= cut for t in thr]))
                rec["yield_threshold"] = fleet.yield_threshold
            records.append(rec)
    return FleetResult(fleet=fleet, experiment=exp, records=records)


def fleet_inbox(fleet: FleetSpec, directory: str,
                tenant_prefix: str = "wafer") -> list:
    """Write the fleet as a multi-tenant `repro.exp.serve` inbox: one
    submission file per sampled wafer, each a single-seed slice of the
    fleet's experiment under its own tenant.  Every wafer's lanes carry
    the same (topology, routing, traffic, cycles) signature, so the
    serve scheduler's signature-bucketed packer packs them ACROSS
    tenants into shared executables — the fleet doubles as a
    multi-tenant packing stress test.  Returns the written paths:

        python -m repro.exp.serve --inbox DIR --out results.jsonl
    """
    exp = fleet.to_experiment()
    os.makedirs(directory, exist_ok=True)
    width = len(str(fleet.samples - 1))
    paths = []
    for si in range(fleet.samples):
        sub = dataclasses.replace(
            exp, name=f"{fleet.name}-s{si}",
            axes=dataclasses.replace(exp.axes, seeds=(si,)))
        path = os.path.join(directory,
                            f"{fleet.name}-{si:0{width}d}.json")
        with open(path, "w") as fh:
            json.dump({"tenant": f"{tenant_prefix}{si}",
                       "spec": sub.to_dict()}, fh)
        paths.append(path)
    return paths


# ---------------------------------------------------------------------------
# Registered fleets
# ---------------------------------------------------------------------------

def smoke_fleet(fast: bool = True) -> FleetSpec:
    """The CI fleet: a full reliability lifecycle at smoke scale.

    Three fault levels on the small up*/down*-routable wafer — a
    pristine reference, clustered wear-out that GROWS over two onsets
    and then REPAIRS (one shrink epoch, statically proven restart-safe
    by `check --spec`), and mid-run router death with the reaper
    draining the stranded population.  8 samples fast (the CI
    fleet-smoke budget), 128 full (a real distribution)."""
    samples = 8 if fast else 128
    wm = (61, 251) if fast else (200, 1200)
    c = wm[0] + wm[1]
    onsets = (c // 4, c // 2)
    repairs = (3 * c // 4,)
    return FleetSpec(
        name="smoke_fleet",
        topology=TopologySpec.switchless(
            a=2, b=2, m=2, n=4, noc=2, g=5, label="fleet-smoke"),
        routing=RoutingSpec(route_mode="min", vc_mode="updown",
                            vcs_per_class=2,
                            reaper={"park_age": c // 4}),
        levels=(
            FaultSpec(),
            FaultSpec(kind="clusters", num_clusters=2, radius=1, seed=3,
                      onsets=onsets, repairs=repairs),
            FaultSpec(kind="routers", num=2, seed=5,
                      onsets=(onsets[0],)),
        ),
        samples=samples, offered=0.45,
        warmup=wm[0], measure=wm[1],
        notes="CI wafer-fleet smoke: clustered wear-out + repair + "
              "router death with the reaper on")


def _register() -> None:
    from .registry import register_scenario
    # registering the LOWERED experiment makes every spec-level gate —
    # `check --spec` (per-epoch CDG proofs + repair restart-safety),
    # the scenario CLI, the serve registry path — cover the fleet with
    # no fleet-specific plumbing
    register_scenario(smoke_fleet().to_experiment())


_register()
