"""Provenance records for benchmark artifacts.

Every BENCH_*.json this repo writes carries a `provenance` block — the
git revision, JAX version, backend platform, and the SHA-256 of the
serialized `ExperimentSpec` that produced the numbers — so a benchmark
file is attributable to an exact code + spec + backend triple without
relying on the commit that happened to check it in.
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess


def git_revision(repo_dir: str | None = None) -> tuple:
    """`(rev, dirty)`: the current git commit and whether the tree has
    local edits.  The dirty flag is a separate boolean — not a '-dirty'
    suffix — so `git_rev` is always a parseable 40-hex revision tools
    can feed straight back to git.  `('unknown', False)` outside a git
    checkout."""
    if repo_dir is None:
        repo_dir = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
    try:
        rev = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=repo_dir, check=True,
            capture_output=True, text=True, timeout=10).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=repo_dir, check=True,
            capture_output=True, text=True, timeout=10).stdout.strip()
        return rev, bool(dirty)
    except Exception:
        return "unknown", False


def spec_hash(spec) -> str:
    """SHA-256 of the canonical (sorted-key) JSON form of an
    `ExperimentSpec` — stable across processes and field ordering."""
    payload = json.dumps(spec.to_dict(), sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()


def provenance(spec=None) -> dict:
    """The provenance block benchmarks embed in their BENCH_*.json."""
    import jax
    rev, dirty = git_revision()
    out = dict(
        git_rev=rev,
        dirty=dirty,
        jax_version=jax.__version__,
        backend=jax.default_backend(),
        platform=jax.devices()[0].platform,
    )
    if spec is not None:
        out["spec_sha256"] = spec_hash(spec)
    return out
