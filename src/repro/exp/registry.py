"""Named-scenario registry: the paper's Fig. 10-15 evaluation grids, the
benchmark grids (`bench_sweep`, `bench_faults`), and tiny smoke variants,
all as registered `ExperimentSpec`s.

Each scenario has a public builder (`fig11_spec(fast=False, g=41)` etc.)
for non-default scales; the registry holds the default (fast, CPU-sized)
instances.  `register_scenario` is the extension point every future
scenario PR plugs into — a registered spec is addressable by name from
benchmarks, tests, and the CLI (`python -m repro.exp.run --scenario X`),
and is serialized/round-tripped by the scenario smoke job in CI.
"""
from __future__ import annotations

from .spec import (ExperimentSpec, FaultSpec, RoutingSpec, SweepAxes,
                   TopologySpec, TrafficSpec)

_SCENARIOS: dict = {}
_BUILDERS: dict = {}


def register_scenario(spec: ExperimentSpec, *, replace: bool = False,
                      builder=None) -> ExperimentSpec:
    """Register `spec` under `spec.name`; duplicate names raise unless
    `replace=True`.  `builder` is the scenario's scale factory
    (`builder(fast=...) -> ExperimentSpec`), which backs the CLI's
    `--fast` / `--full` axis; scenarios without one only run at their
    registered default scale."""
    if spec.name in _SCENARIOS and not replace:
        raise ValueError(f"scenario {spec.name!r} already registered")
    _SCENARIOS[spec.name] = spec
    if builder is not None:
        _BUILDERS[spec.name] = builder
    return spec


def get_scenario(name: str, fast: bool | None = None) -> ExperimentSpec:
    """The registered spec (default), or the scenario rebuilt through its
    `*_spec(fast=...)` builder when `fast` is given."""
    if name not in _SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{list_scenarios()}")
    if fast is None:
        return _SCENARIOS[name]
    builder = _BUILDERS.get(name)
    if builder is None:
        raise KeyError(
            f"scenario {name!r} has no fast/full builder; run it without "
            f"--fast/--full (builders exist for: {sorted(_BUILDERS)})")
    return builder(fast=fast)


def list_scenarios() -> list:
    return sorted(_SCENARIOS)


# ---------------------------------------------------------------------------
# Paper figures (Sec. V).  `fast` trims cycle counts (and for the global
# figures, W-group counts) to single-CPU-core scale while preserving the
# orderings the paper claims; `fast=False` is the paper-scale grid.
# ---------------------------------------------------------------------------

def _cycles(fast, fast_wm, full_wm=(2000, 8000)):
    wm = fast_wm if fast else full_wm
    return dict(warmup=wm[0], measure=wm[1])


def fig10a_spec(fast: bool = True) -> ExperimentSpec:
    """Fig. 10(a-b): intra-C-group uniform / bit-reverse."""
    return ExperimentSpec(
        name="fig10a",
        topologies=TopologySpec.switchless(
            a=1, b=1, m=2, n=6, noc=2, g=1, label="switchless-cgroup"),
        traffics=(TrafficSpec("uniform"), TrafficSpec("bit_reverse")),
        routings=RoutingSpec(vcs_per_class=4),
        axes=SweepAxes(rates=(1.0, 2.0, 3.0, 3.6),
                       **_cycles(fast, (400, 1200))),
        notes="paper Fig. 10(a-b): saturation ~3.0 flits/cycle/chip")


def fig10cf_spec(fast: bool = True) -> ExperimentSpec:
    """Fig. 10(c-f): intra-W-group, switchless 1B/2B vs switch-based."""
    return ExperimentSpec(
        name="fig10cf",
        topologies=(
            TopologySpec.switchless(a=2, b=4, m=2, n=6, noc=2, g=1,
                                    label="switchless-1B"),
            TopologySpec.switchless(a=2, b=4, m=2, n=6, noc=2, g=1,
                                    cg_bw_mult=2, label="switchless-2B"),
            TopologySpec.dragonfly(t=4, l=7, gl=1, g=1,
                                   label="switch-based")),
        traffics=(TrafficSpec("uniform"), TrafficSpec("bit_transpose")),
        routings=RoutingSpec(vcs_per_class=2),
        axes=SweepAxes(rates=(0.5, 1.0, 1.5, 2.0),
                       **_cycles(fast, (400, 1200))))


def fig11_spec(fast: bool = True, g: int | None = None) -> ExperimentSpec:
    """Fig. 11: global uniform / bit-reverse on the radix-16 network.
    Full scale is g=41 (1312 chips); fast uses g=11 (352 chips)."""
    g = g or (11 if fast else None)
    return ExperimentSpec(
        name="fig11",
        topologies=(
            TopologySpec.preset("radix16_switchless", g=g,
                                label="switchless-1B"),
            TopologySpec.preset("radix16_switchless", g=g, cg_bw_mult=2,
                                label="switchless-2B"),
            TopologySpec.preset("radix16_dragonfly", g=g,
                                label="switch-based")),
        traffics=(TrafficSpec("uniform"), TrafficSpec("bit_reverse")),
        routings=RoutingSpec(vcs_per_class=2),
        axes=SweepAxes(rates=(0.4, 0.7, 1.0), **_cycles(fast, (300, 900))))


def fig12_spec(fast: bool = True) -> ExperimentSpec:
    """Fig. 12: radix-32-class scalability (reduced W-groups on CPU)."""
    g = 5 if fast else 29
    return ExperimentSpec(
        name="fig12",
        topologies=(
            TopologySpec.preset("radix32_switchless", g=g,
                                label="switchless-1B"),
            TopologySpec.preset("radix32_switchless", g=g, cg_bw_mult=2,
                                label="switchless-2B"),
            TopologySpec.preset("radix32_dragonfly", g=g,
                                label="switch-based")),
        traffics=TrafficSpec("uniform"),
        routings=RoutingSpec(vcs_per_class=2),
        axes=SweepAxes(rates=(0.4, 0.8),
                       **_cycles(fast, (250, 600), (1000, 4000))))


def fig13_spec(fast: bool = True) -> ExperimentSpec:
    """Fig. 13: minimal vs non-minimal (VAL / UGAL) on hotspot + WC,
    full-size radix-16 switch-less network."""
    return ExperimentSpec(
        name="fig13",
        topologies=TopologySpec.preset("radix16_switchless",
                                       label="switchless"),
        traffics=(TrafficSpec("worst_case"),
                  TrafficSpec("hotspot",
                              params=(("num_hot", 4), ("seed", 0)))),
        routings=(RoutingSpec(route_mode="min", vcs_per_class=2),
                  RoutingSpec(route_mode="val", vcs_per_class=2),
                  RoutingSpec(route_mode="ugal", vcs_per_class=2)),
        axes=SweepAxes(rates=(0.2, 0.5), **_cycles(fast, (300, 800))))


def fig14_specs(fast: bool = True) -> tuple:
    """Fig. 14: ring AllReduce within C-group and W-group.  Three specs
    because vcs_per_class and the rate grid differ per topology class."""
    cyc = _cycles(fast, (400, 1200))
    ring = (TrafficSpec("ring_allreduce",
                        params=(("bidirectional", False),)),
            TrafficSpec("ring_allreduce",
                        params=(("bidirectional", True),)))
    cg_rates = (1.0, 2.0, 3.0, 3.8)
    wg_rates = (0.6, 1.0, 1.6, 2.2)
    return (
        ExperimentSpec(
            name="fig14_cgroup_switchless",
            topologies=TopologySpec.switchless(
                a=1, b=1, m=2, n=6, noc=2, g=1, label="cgroup-switchless"),
            traffics=ring, routings=RoutingSpec(vcs_per_class=4),
            axes=SweepAxes(rates=cg_rates, **cyc)),
        ExperimentSpec(
            name="fig14_cgroup_switch",
            topologies=TopologySpec.dragonfly(t=4, l=0, gl=0, g=1,
                                              label="cgroup-switch"),
            traffics=ring, routings=RoutingSpec(vcs_per_class=2),
            axes=SweepAxes(rates=cg_rates, **cyc)),
        ExperimentSpec(
            name="fig14_wgroup",
            topologies=(
                TopologySpec.switchless(a=2, b=4, m=2, n=6, noc=2, g=1,
                                        label="wgroup-switchless"),
                TopologySpec.switchless(a=2, b=4, m=2, n=6, noc=2, g=1,
                                        cg_bw_mult=2,
                                        label="wgroup-switchless-2B"),
                TopologySpec.dragonfly(t=4, l=7, gl=1, g=1,
                                       label="wgroup-switch")),
            traffics=ring, routings=RoutingSpec(vcs_per_class=2),
            axes=SweepAxes(rates=wg_rates, **cyc)))


def fig15_spec(fast: bool = True) -> ExperimentSpec:
    """Fig. 15: hop counts for the energy model (min vs VAL, g=9)."""
    return ExperimentSpec(
        name="fig15",
        topologies=(
            TopologySpec.preset("radix16_switchless", g=9,
                                label="switchless"),
            TopologySpec.preset("radix16_dragonfly", g=9,
                                label="switch-based")),
        traffics=TrafficSpec("uniform"),
        routings=(RoutingSpec(route_mode="min", vcs_per_class=2),
                  RoutingSpec(route_mode="val", vcs_per_class=2)),
        axes=SweepAxes(rates=(0.3,),
                       **_cycles(fast, (300, 800), (1000, 4000))))


# ---------------------------------------------------------------------------
# Benchmark + smoke grids
# ---------------------------------------------------------------------------

def bench_sweep_spec(rates=(0.2, 0.4, 0.6, 0.8, 1.0, 1.2, 1.4, 1.6),
                     seeds=(0, 1, 2), warmup: int = 100,
                     measure: int = 500) -> ExperimentSpec:
    """The engine-perf sweep of benchmarks/bench_sweep.py."""
    return ExperimentSpec(
        name="bench_sweep",
        topologies=TopologySpec.switchless(
            a=1, b=1, m=2, n=6, noc=2, g=1, label="bench-sweep"),
        traffics=TrafficSpec("uniform"),
        routings=RoutingSpec(vcs_per_class=2),
        axes=SweepAxes(rates=rates, seeds=seeds,
                       warmup=warmup, measure=measure))


def bench_faults_spec(fracs=(0.0, 0.04, 0.08, 0.12, 0.16), seeds=(0, 1),
                      offered: float = 0.55, warmup: int = 300,
                      measure: int = 1500) -> ExperimentSpec:
    """The degraded-wafer grid of benchmarks/bench_faults.py: one
    independently sampled link-fault set per (failure rate, seed) lane
    (FaultSpec i seeds its stream at 1000*i + lane seed, the historical
    convention)."""
    return ExperimentSpec(
        name="bench_faults",
        topologies=TopologySpec.switchless(
            a=2, b=2, m=2, n=4, noc=2, g=5, label="bench-faults"),
        traffics=TrafficSpec("uniform"),
        routings=RoutingSpec(route_mode="min", vc_mode="updown",
                             vcs_per_class=2),
        axes=SweepAxes(
            rates=(offered,), seeds=seeds,
            faults=tuple(FaultSpec(kind="links", frac=f, seed=i)
                         for i, f in enumerate(fracs)),
            warmup=warmup, measure=measure))


def smoke_spec() -> ExperimentSpec:
    """A seconds-scale scenario for CI smoke runs and quick local checks."""
    return ExperimentSpec(
        name="smoke",
        topologies=TopologySpec.switchless(
            a=1, b=1, m=2, n=6, noc=2, g=1, label="smoke-cgroup"),
        traffics=TrafficSpec("uniform"),
        routings=RoutingSpec(vcs_per_class=2),
        axes=SweepAxes(rates=(0.5, 1.5), warmup=50, measure=200))


def smoke_fused_spec() -> ExperimentSpec:
    """The smoke grid on the fused cycle step (`step_impl="fused"`).

    Doubles as the channel-sharding smoke: under `REPRO_HOST_DEVICES=2`
    (or more) with `REPRO_CHANNEL_SHARDS=2`, the fused dispatch
    block-partitions each lane's channel space across shard devices —
    results stay bit-identical to the single-device run (CI runs both
    and the channel-sharding test pins the equality)."""
    return ExperimentSpec(
        name="smoke_fused",
        topologies=TopologySpec.switchless(
            a=1, b=1, m=2, n=6, noc=2, g=3, label="smoke-fused"),
        traffics=TrafficSpec("uniform"),
        routings=RoutingSpec(vcs_per_class=2, step_impl="fused"),
        axes=SweepAxes(rates=(0.5, 1.5), warmup=50, measure=200),
        notes="smoke on the fused step (channel-shardable)")


def smoke_compact_spec() -> ExperimentSpec:
    """The smoke grid on the occupancy-compacted step
    (`step_impl="compact"`): live rows are compacted into a
    capacity-C active set before arbitration (C starts at a
    `fused.capacity_ladder` rung; breaches escalate to the next rung
    with a bit-identical whole-grid rerun).  CI runs this next to
    `smoke_fused` and the parity tests pin all three step impls
    bit-identical; the analysis capacity pass proves/annotates the
    rung choice statically."""
    return ExperimentSpec(
        name="smoke_compact",
        topologies=TopologySpec.switchless(
            a=1, b=1, m=2, n=6, noc=2, g=3, label="smoke-compact"),
        traffics=TrafficSpec("uniform"),
        routings=RoutingSpec(vcs_per_class=2, step_impl="compact"),
        axes=SweepAxes(rates=(0.5, 1.5), warmup=50, measure=200),
        notes="smoke on the occupancy-compacted step (capacity ladder)")


def smoke_fig10a_spec() -> ExperimentSpec:
    """Fig. 10(a) topology + patterns at smoke scale: the tier-1 parity
    fixture (run_experiment vs legacy Simulator.sweep, lane-for-lane)."""
    spec = fig10a_spec(fast=True)
    return ExperimentSpec(
        name="smoke_fig10a",
        topologies=spec.topologies, traffics=spec.traffics,
        routings=spec.routings,
        axes=SweepAxes(rates=(1.0, 3.0), seeds=(0, 1),
                       warmup=61, measure=251),
        notes="fig10a at smoke scale (tier-1 parity fixture)")


def smoke_faults_spec() -> ExperimentSpec:
    """A tiny fault grid (tier-1 compile-accounting fixture)."""
    return ExperimentSpec(
        name="smoke_faults",
        topologies=TopologySpec.switchless(
            a=2, b=2, m=2, n=4, noc=2, g=5, label="smoke-faults"),
        traffics=TrafficSpec("uniform"),
        routings=RoutingSpec(route_mode="min", vc_mode="updown",
                             vcs_per_class=2),
        axes=SweepAxes(rates=(0.5,), seeds=(0, 1),
                       faults=(FaultSpec(),
                               FaultSpec(kind="links", frac=0.08, seed=1)),
                       warmup=67, measure=241))


# ---------------------------------------------------------------------------
# Warm faults (time-varying `FaultSchedule`s: links die mid-run)
# ---------------------------------------------------------------------------

def smoke_warm_faults_spec() -> ExperimentSpec:
    """Warm-fault smoke: a quarter of the global links die at cycle 151
    while traffic is in flight, adaptive (UGAL) routing re-routes the
    survivors.  Tier-1 + CI fixture for the time-varying fault path (one
    grid, one compile, 2-epoch schedules).  Global-only faults keep the
    schedule routable under ALL THREE vc_modes, which is what the
    per-epoch deadlock-freedom test sweeps."""
    return ExperimentSpec(
        name="smoke_warm_faults",
        topologies=TopologySpec.switchless(
            a=2, b=2, m=2, n=4, noc=2, g=5, label="smoke-warm"),
        traffics=TrafficSpec("uniform"),
        routings=RoutingSpec(route_mode="ugal", vc_mode="baseline",
                             vcs_per_class=1),
        axes=SweepAxes(rates=(0.5,), seeds=(0, 1),
                       faults=(FaultSpec(),
                               FaultSpec(kind="links", types=("global",),
                                         frac=0.25, seed=2, onsets=(151,))),
                       warmup=71, measure=311),
        notes="warm faults: 25% of global links die mid-run (smoke)")


def yield_curve_spec(fast: bool = True, fracs=(0.15, 0.3, 0.45),
                     offered: float = 0.8) -> ExperimentSpec:
    """Yield-vs-throughput on the paper's radix-32-class network (2B
    on-wafer bandwidth): a growing fraction of the global links dies
    MID-RUN under adversarial (worst-case) traffic, minimal vs. adaptive
    (UGAL) routing.  Minimal routing pays the dead parallel links of each
    W-group pair directly; the fault-aware adaptive stage re-routes
    around them, so delivered throughput degrades more gracefully —
    `benchmarks/bench_yield.py` records the two curves in
    BENCH_yield.json.  Fast scale: g=3 W-groups, short cycles; full:
    g=9, paper-scale cycle budget."""
    g = 3 if fast else 9
    wm = (120, 480) if fast else (800, 3200)
    onset = wm[0] + wm[1] // 4
    return ExperimentSpec(
        name="yield_curve",
        topologies=TopologySpec.preset("radix32_switchless", g=g,
                                       cg_bw_mult=2,
                                       label="radix32-switchless-2B"),
        traffics=TrafficSpec("worst_case"),
        routings=(RoutingSpec(route_mode="min", vc_mode="baseline",
                              vcs_per_class=1),
                  RoutingSpec(route_mode="ugal", vc_mode="baseline",
                              vcs_per_class=1)),
        axes=SweepAxes(
            rates=(offered,), seeds=(0, 1),
            faults=(FaultSpec(),) + tuple(
                FaultSpec(kind="links", types=("global",), frac=f,
                          seed=7 + i, onsets=(onset,))
                for i, f in enumerate(fracs)),
            warmup=wm[0], measure=wm[1]),
        notes="yield curve: global links die mid-run, minimal vs adaptive")


def _register_defaults() -> None:
    register_scenario(fig10a_spec(), builder=fig10a_spec)
    register_scenario(fig10cf_spec(), builder=fig10cf_spec)
    register_scenario(fig11_spec(), builder=fig11_spec)
    register_scenario(fig12_spec(), builder=fig12_spec)
    register_scenario(fig13_spec(), builder=fig13_spec)
    for i, spec in enumerate(fig14_specs()):
        register_scenario(spec,
                          builder=lambda fast=True, _i=i: fig14_specs(fast)[_i])
    register_scenario(fig15_spec(), builder=fig15_spec)
    register_scenario(yield_curve_spec(), builder=yield_curve_spec)
    for spec in (bench_sweep_spec(), bench_faults_spec(), smoke_spec(),
                 smoke_fused_spec(), smoke_compact_spec(),
                 smoke_fig10a_spec(),
                 smoke_faults_spec(), smoke_warm_faults_spec()):
        register_scenario(spec)


_register_defaults()
