"""Declarative spec for the dry-run roofline analysis.

`benchmarks/roofline.py` used to hand-wire its inputs (the artifacts glob,
the mesh tag, the fabric model).  `RooflineSpec` names them the same way
`ExperimentSpec` names a simulation grid: a frozen, validated, JSON-
round-trippable value object the benchmark lowers from — so the exp API
covers every benchmark in the repo, and a roofline run is reproducible
from its serialized spec alone (`python -m benchmarks.roofline --spec f.json`).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

FABRICS = ("switchless", "flat")
MESHES = ("single", "multi")


@dataclass(frozen=True)
class RooflineSpec:
    """One roofline table: which dry-run cells, priced on which fabric.

    mesh           artifact mesh tag ("single" | "multi")
    fabric         collective pricing model: the paper's switch-less wafer
                   fabric or the flat grading-spec ICI model
    cg_bw_mult     on-wafer bandwidth multiplier of the wafer fabric
                   (the paper's 1B/2B axis)
    artifacts_dir  override for the dry-run artifact directory ("" = the
                   repo default artifacts/dryrun)
    """

    mesh: str = "single"
    fabric: str = "switchless"
    cg_bw_mult: float = 1.0
    artifacts_dir: str = ""

    def __post_init__(self):
        if self.mesh not in MESHES:
            raise ValueError(f"unknown mesh {self.mesh!r}; valid: {MESHES}")
        if self.fabric not in FABRICS:
            raise ValueError(
                f"unknown fabric {self.fabric!r}; valid: {FABRICS}")
        if self.cg_bw_mult <= 0:
            raise ValueError(f"cg_bw_mult must be > 0, got {self.cg_bw_mult}")
        object.__setattr__(self, "cg_bw_mult", float(self.cg_bw_mult))

    def build_fabric(self):
        """The concrete `cost_model.Fabric` this spec prices with."""
        from ..core.cost_model import flat_ici_fabric, switchless_wafer_fabric
        if self.fabric == "flat":
            return flat_ici_fabric()
        return switchless_wafer_fabric(cg_bw_mult=self.cg_bw_mult)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "RooflineSpec":
        return cls(**d)
