"""Run an experiment from the command line.

    python -m repro.exp.run --list
    python -m repro.exp.run --scenario smoke
    python -m repro.exp.run --scenario fig11 --fast
    python -m repro.exp.run --scenario fig10a --out BENCH_fig10a.json
    python -m repro.exp.run --spec my_experiment.json

A registered scenario is executed FROM ITS JSON FORM (serialize ->
deserialize -> run), so every CLI invocation also proves the spec
round-trips; `--spec` runs an arbitrary spec file with the same schema
(`ExperimentSpec.to_dict`).  `--fast` / `--full` rebuild the scenario
through its `*_spec(fast=...)` builder (trimmed-CPU vs. paper scale);
without either flag the registered default instance runs unchanged.
Results are written as ``BENCH_<name>.json`` (override with ``--out``)
with a provenance block (git rev, JAX version, backend, spec hash) and
printed as CSV rows.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import registry
from .provenance import provenance
from .runner import run_experiment
from .spec import ExperimentSpec

_CSV_COLS = ("topology", "pattern", "route_mode", "vc_mode", "fault",
             "offered", "throughput", "latency")


def _fmt(v) -> str:
    return f"{v:.4f}" if isinstance(v, float) else str(v)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.exp.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--scenario", help="registered scenario name")
    g.add_argument("--spec", help="path to an ExperimentSpec JSON file")
    g.add_argument("--list", action="store_true",
                   help="list registered scenarios and exit")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default BENCH_<name>.json)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-grid progress on stderr")
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--fast", action="store_true",
                       help="rebuild the scenario at trimmed CPU scale "
                            "through its *_spec(fast=True) builder")
    scale.add_argument("--full", action="store_true",
                       help="rebuild the scenario at paper scale "
                            "(*_spec(fast=False))")
    args = ap.parse_args(argv)

    if args.list:
        for name in registry.list_scenarios():
            spec = registry.get_scenario(name)
            print(f"{name:24s} grids={spec.num_grids:3d} "
                  f"lanes/grid={spec.axes.lanes_per_grid:3d}  {spec.notes}")
        return 0

    fast = True if args.fast else (False if args.full else None)
    if args.scenario:
        # round-trip through JSON: the run below executes the scenario
        # from its serialized form, not the in-memory registry object
        try:
            picked = registry.get_scenario(args.scenario, fast=fast)
        except KeyError as e:
            print(f"ERROR: {e}", file=sys.stderr)
            return 2
        payload = json.dumps(picked.to_dict())
        spec = ExperimentSpec.from_dict(json.loads(payload))
    else:
        if fast is not None:
            print("ERROR: --fast/--full only apply to registered "
                  "scenarios (--scenario)", file=sys.stderr)
            return 2
        with open(args.spec) as f:
            spec = ExperimentSpec.from_dict(json.load(f))

    result = run_experiment(spec, verbose=not args.quiet)
    rows = result.rows()

    out_path = args.out or f"BENCH_{spec.name}.json"
    with open(out_path, "w") as f:
        json.dump(dict(
            spec=spec.to_dict(),
            provenance=provenance(spec),
            rows=[{k: v for k, v in r.items() if k != "avg_hops_by_type"}
                  for r in rows],
            compile_counts=result.compile_counts,
            max_compiles_per_grid=result.max_compiles_per_grid,
            wall_s=result.wall_s), f, indent=2)

    print(",".join(_CSV_COLS))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in _CSV_COLS))
    print(f"\nwrote {out_path}  (grids={len(result.grids)}, "
          f"compiles={result.compile_counts}, wall={result.wall_s:.1f}s)",
          file=sys.stderr)
    if result.max_compiles_per_grid > 1:
        print("ERROR: a grid compiled more than once", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
