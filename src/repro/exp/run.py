"""Run an experiment from the command line.

    python -m repro.exp.run --list
    python -m repro.exp.run --scenario smoke
    python -m repro.exp.run --scenario fig11 --fast
    python -m repro.exp.run --scenario fig10a --out BENCH_fig10a.json
    python -m repro.exp.run --spec my_experiment.json

A registered scenario is executed FROM ITS JSON FORM (serialize ->
deserialize -> run), so every CLI invocation also proves the spec
round-trips; `--spec` runs an arbitrary spec file with the same schema
(`ExperimentSpec.to_dict`).  `--fast` / `--full` rebuild the scenario
through its `*_spec(fast=...)` builder (trimmed-CPU vs. paper scale);
without either flag the registered default instance runs unchanged.
Results are written as ``BENCH_<name>.json`` (override with ``--out``)
with a provenance block (git rev, JAX version, backend, spec hash) and
printed as CSV rows.  ``--jsonl PATH`` additionally emits the per-lane
window/result records of the `repro.exp.serve` schema
(`repro.exp.windows`), so batch and serve artifacts diff line-for-line.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import registry
from . import windows as W
from .provenance import provenance, spec_hash
from .runner import run_experiment
from .spec import ExperimentSpec

_CSV_COLS = ("topology", "pattern", "route_mode", "vc_mode", "fault",
             "offered", "throughput", "latency")


def _fmt(v) -> str:
    return f"{v:.4f}" if isinstance(v, float) else str(v)


def write_jsonl(result, path: str) -> int:
    """Emit an `ExperimentResult` as the serve-schema JSONL stream
    (`repro.exp.windows`): one meta/request header, then per lane the
    run's FINAL window record plus its result record, then a done
    record.  A batch artifact and a `repro.exp.serve` artifact for the
    same spec are schema-identical line formats — and their `result`
    records are value-identical, because serve runs are bit-identical
    to batch runs (tests/test_serve.py)."""
    spec = result.spec
    n = 0
    with open(path, "w") as f:
        def emit(rec):
            nonlocal n
            f.write(W.dumps(rec) + "\n")
            n += 1
        lanes = sum(len(g.fault_labels) * len(g.rates) * len(g.seeds)
                    for g in result.grids)
        emit(W.meta_record("run", provenance(spec)))
        emit(W.request_record(request=1, tenant="batch",
                              scenario=spec.name,
                              spec_sha256=spec_hash(spec), lanes=lanes))
        warmup, measure = spec.axes.warmup, spec.axes.measure
        for ci, g in enumerate(result.grids):
            R, S = len(g.rates), len(g.seeds)
            for fi, flabel in enumerate(g.fault_labels):
                for ri, rate in enumerate(g.rates):
                    for si, seed in enumerate(g.seeds):
                        meta = W.lane_meta(
                            scenario=spec.name, tenant="batch",
                            request=1, cell=ci,
                            lane=(fi * R + ri) * S + si,
                            topology=g.topology.label,
                            topo_kind=g.topology.kind,
                            pattern=g.traffic.label,
                            route_mode=g.routing.route_mode,
                            vc_mode=g.routing.vc_mode, fault=flabel,
                            offered=rate, seed=seed)
                        res = g.results[fi][ri][si]
                        emit(W.window_from_result(
                            meta, res, warmup=warmup, measure=measure))
                        emit(W.result_record(meta, res))
        emit(W.done_record(request=1, tenant="batch", scenario=spec.name,
                           lanes=lanes))
    return n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.exp.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--scenario", help="registered scenario name")
    g.add_argument("--spec", help="path to an ExperimentSpec JSON file")
    g.add_argument("--list", action="store_true",
                   help="list registered scenarios and exit")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default BENCH_<name>.json)")
    ap.add_argument("--jsonl", default=None, metavar="PATH",
                    help="also emit per-lane window/result records as "
                         "JSONL (the repro.exp.serve schema)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-grid progress on stderr")
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--fast", action="store_true",
                       help="rebuild the scenario at trimmed CPU scale "
                            "through its *_spec(fast=True) builder")
    scale.add_argument("--full", action="store_true",
                       help="rebuild the scenario at paper scale "
                            "(*_spec(fast=False))")
    args = ap.parse_args(argv)

    if args.list:
        for name in registry.list_scenarios():
            spec = registry.get_scenario(name)
            print(f"{name:24s} grids={spec.num_grids:3d} "
                  f"lanes/grid={spec.axes.lanes_per_grid:3d}  {spec.notes}")
        return 0

    fast = True if args.fast else (False if args.full else None)
    if args.scenario:
        # round-trip through JSON: the run below executes the scenario
        # from its serialized form, not the in-memory registry object
        try:
            picked = registry.get_scenario(args.scenario, fast=fast)
        except KeyError as e:
            print(f"ERROR: {e}", file=sys.stderr)
            return 2
        payload = json.dumps(picked.to_dict())
        spec = ExperimentSpec.from_dict(json.loads(payload))
    else:
        if fast is not None:
            print("ERROR: --fast/--full only apply to registered "
                  "scenarios (--scenario)", file=sys.stderr)
            return 2
        with open(args.spec) as f:
            spec = ExperimentSpec.from_dict(json.load(f))

    result = run_experiment(spec, verbose=not args.quiet)
    rows = result.rows()

    if args.jsonl:
        n = write_jsonl(result, args.jsonl)
        print(f"wrote {args.jsonl} ({n} records)", file=sys.stderr)

    out_path = args.out or f"BENCH_{spec.name}.json"
    with open(out_path, "w") as f:
        json.dump(dict(
            spec=spec.to_dict(),
            provenance=provenance(spec),
            rows=[{k: v for k, v in r.items() if k != "avg_hops_by_type"}
                  for r in rows],
            compile_counts=result.compile_counts,
            max_compiles_per_grid=result.max_compiles_per_grid,
            wall_s=result.wall_s), f, indent=2)

    print(",".join(_CSV_COLS))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in _CSV_COLS))
    print(f"\nwrote {out_path}  (grids={len(result.grids)}, "
          f"compiles={result.compile_counts}, wall={result.wall_s:.1f}s)",
          file=sys.stderr)
    if result.max_compiles_per_grid > 1:
        print("ERROR: a grid compiled more than once", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
