"""Lower an `ExperimentSpec` onto the batch-parallel engine.

`run_experiment(spec)` iterates the outer-product cells
(topology x routing x traffic) and runs each cell's whole lane grid
(faults x rates x seeds) as ONE `BatchedSweep.run_lanes` dispatch — at
most one jit compile per grid, by construction.  Cells that share an
identical step function (same topology, routing, traffic, and cycle
budget) reuse one compiled `BatchedSweep` through a process-wide cache,
so re-running a spec (or running a spec that overlaps an earlier one)
costs zero new compiles.

Device parallelism: with one device (the un-forced CPU default) cells
run serially and each grid shards nothing.  With multiple devices
(`REPRO_HOST_DEVICES=N`, or a real multi-device backend) a single-cell
spec shard_maps its lane axis over the whole mesh, while a multi-cell
spec ROUND-ROBINS cells across devices instead: every cell's grid is
dispatched asynchronously to device `i % ndev` and materialized
afterwards, so independent grids execute concurrently (dispatch is
async; only compilation serializes on the host).  Cells on
paper-scale networks (more channels than `REPRO_RR_MAX_CHANNELS`,
default 1024) opt out of the round-robin and run serially on the
default device — overlapped execution of multi-MB channel states
thrashes shared caches and measures SLOWER than serial (see
`rr_max_channels`).  Either way results are lane-for-lane identical to
the serial single-device run — device placement never changes per-lane
math.

`cells(spec)` exposes the same lowering without running anything — the
hook benchmarks use to build sequential/legacy baselines from the exact
(net, cfg, pattern) a spec denotes.
"""
from __future__ import annotations

import sys
from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np

from .. import env_int
from ..core.engine.sweep import BatchedSweep, SweepResult
from ..core.simulator import SimConfig, SimResult
from ..core.topology import Network, final_faults
from ..core.traffic import TrafficPattern
from .spec import (ExperimentSpec, FaultSpec, RoutingSpec, SweepAxes,
                   TopologySpec, TrafficSpec)

# Process-wide compiled-sweep cache: one `BatchedSweep` (hence one engine
# step closure, hence one jit cache entry) per distinct cell.  Keyed by
# the specs themselves — they are frozen/hashable, that's the point.
_SWEEP_CACHE: dict = {}
# Sampled fault sets, keyed by (topology, fault spec, vc_mode, lane seed):
# greedy-validated sampling is the slow part, and the same population is
# reused across every routing/traffic cell that shares a vc_mode.
_FAULT_CACHE: dict = {}


def rr_max_channels() -> int:
    """`REPRO_RR_MAX_CHANNELS` (default 1024): cells whose network has
    more channels than this run on the DEFAULT device serially instead
    of round-robining.  Round-robin overlaps independent grids, which
    pays off for small cells; for paper-scale networks the concurrently
    executing cells evict each other's multi-MB channel state from
    cache and the 'parallel' run comes out slower than the serial one
    (fig11 measured ~20% slower round-robined on forced host devices).
    The per-cell decision is visible in `GridResult.placement` /
    `run_experiment(verbose=True)`."""
    return env_int("REPRO_RR_MAX_CHANNELS", 1024)


def clear_caches() -> None:
    """Drop the compiled-sweep, fault-sample, and built-network caches
    (tests / memory)."""
    from . import spec as _spec
    _SWEEP_CACHE.clear()
    _FAULT_CACHE.clear()
    _spec._NET_CACHE.clear()


class Cell(NamedTuple):
    """One lowered outer-product cell of an experiment."""

    topology: TopologySpec
    routing: RoutingSpec
    traffic: TrafficSpec
    net: Network
    cfg: SimConfig
    pattern: TrafficPattern


def cells(spec: ExperimentSpec):
    """Yield the lowered (net, cfg, pattern) cells of `spec`, in run
    order (topology-major, then routing, then traffic)."""
    for topo in spec.topologies:
        net = topo.build()
        for routing in spec.routings:
            cfg = routing.to_simconfig(spec.axes)
            for traffic in spec.traffics:
                yield Cell(topo, routing, traffic, net, cfg,
                           traffic.resolve(net))


@dataclass
class GridResult:
    """One cell's (faults x rates x seeds) grid of `SimResult`s."""

    topology: TopologySpec
    routing: RoutingSpec
    traffic: TrafficSpec
    rates: list
    seeds: list
    fault_labels: list          # [F]
    fault_fracs: list           # [F] mean failed-link fraction over seeds
    results: list               # [F][R][S] of SimResult
    compile_count: int = 0
    wall_s: float = 0.0         # execution wall (compile excluded); for
                                # round-robined cells this spans dispatch
                                # -> materialized, overlapping other cells
    compile_s: float = 0.0      # trace+compile wall (0.0 on cache reuse)
    placement: str = "single"   # device layout the grid actually ran on
                                # ("single" | "lanes:L" | "lanes:L,shards:K")
    pad_fraction: float = 0.0   # ghost fraction of the dispatched
                                # lane x channel grid (placement padding)
    grant_form: str = "two_pass"   # arbitration form the grid compiled
                                # ("combined" | "two_pass"; fused steps
                                # fall back to two_pass on int32 packed-
                                # key overflow — see fused.grant_form)
    occupancy_peak: int = 0     # max live request rows over the grid
    compact_capacity: int = 0   # compact ladder rung (0 = dense step)
    superstep: int = 1          # K-cycle unroll the grid compiled
    escalations: int = 0        # capacity-ladder reruns (compact step)
    escalation_compiles: int = 0   # compiles spent on abandoned rungs
                                   # (kept out of compile_count: each
                                   # rung is its own executable)

    def result(self, fault_idx: int, rate_idx: int,
               seed_idx: int = 0) -> SimResult:
        return self.results[fault_idx][rate_idx][seed_idx]

    def sweep_result(self, fault_idx: int = 0) -> SweepResult:
        """One fault row as a legacy `SweepResult` (rate x seed grid)."""
        return SweepResult(rates=list(self.rates), seeds=list(self.seeds),
                           results=self.results[fault_idx],
                           compile_count=self.compile_count,
                           wall_s=self.wall_s, placement=self.placement,
                           pad_fraction=self.pad_fraction,
                           grant_form=self.grant_form,
                           occupancy_peak=self.occupancy_peak,
                           compact_capacity=self.compact_capacity,
                           superstep=self.superstep,
                           escalations=self.escalations,
                           escalation_compiles=self.escalation_compiles)


@dataclass
class ExperimentResult:
    """All grids of one experiment plus flat, seed-averaged records."""

    spec: ExperimentSpec
    grids: list = field(default_factory=list)

    @property
    def wall_s(self) -> float:
        return sum(g.wall_s for g in self.grids)

    @property
    def compile_s(self) -> float:
        return sum(g.compile_s for g in self.grids)

    @property
    def compile_counts(self) -> list:
        return [g.compile_count for g in self.grids]

    @property
    def max_compiles_per_grid(self) -> int:
        return max(self.compile_counts, default=0)

    def rows(self) -> list:
        """Seed-averaged records, one per (grid, fault, rate) — the flat
        table benchmarks print and the CLI serializes.  `wall_s` is the
        grid wall-clock amortized over its rows (per-lane timings don't
        exist in a batched dispatch)."""
        out = []
        for g in self.grids:
            F, R = len(g.fault_labels), len(g.rates)
            dt = g.wall_s / max(F * R, 1)
            for fi in range(F):
                for ri, res in enumerate(g.sweep_result(fi)
                                         .mean_over_seeds()):
                    out.append(dict(
                        scenario=self.spec.name,
                        topology=g.topology.label,
                        topo_kind=g.topology.kind,
                        pattern=g.traffic.label,
                        pattern_name=g.traffic.pattern,
                        pattern_params=dict(g.traffic.params),
                        route_mode=g.routing.route_mode,
                        vc_mode=g.routing.vc_mode,
                        fault=g.fault_labels[fi],
                        fault_frac=g.fault_fracs[fi],
                        offered=g.rates[ri],
                        throughput=res.throughput_per_chip,
                        latency=res.avg_latency,
                        delivered_pkts=res.delivered_pkts,
                        generated_pkts=res.generated_pkts,
                        dropped_pkts=res.dropped_pkts,
                        # exact per-lane max + exact mean (see
                        # SweepResult.mean_over_seeds) and the reaper's
                        # cumulative kill count
                        stranded_pkts=res.stranded_pkts,
                        stranded_mean=res.stranded_mean,
                        reaped_pkts=res.reaped_pkts,
                        avg_hops_by_type=res.avg_hops_by_type,
                        compile_count=g.compile_count,
                        placement=g.placement,
                        pad_fraction=g.pad_fraction,
                        grant_form=g.grant_form,
                        occupancy_peak=res.occupancy_peak,
                        compact_capacity=g.compact_capacity,
                        superstep=g.superstep,
                        escalations=g.escalations,
                        escalation_compiles=g.escalation_compiles,
                        wall_s=dt))
        return out


def _fault_rows(spec: ExperimentSpec, topo: TopologySpec, net: Network,
                vc_mode: str):
    """[F][S] composed fault sets (None = pristine), memoized."""
    rows = []
    for f in spec.axes.faults:
        row = []
        for s in spec.axes.seeds:
            key = (topo, f, vc_mode, s if f.per_seed else None)
            if key not in _FAULT_CACHE:
                _FAULT_CACHE[key] = f.sample(net, vc_mode, s)
            row.append(_FAULT_CACHE[key])
        rows.append(row)
    return rows


def run_experiment(spec: ExperimentSpec, verbose: bool = False
                   ) -> ExperimentResult:
    """Run every grid of `spec`; each grid is one batched-engine dispatch
    (compile_count <= 1 per grid, == 0 on shared-compile reuse).

    Multi-cell specs on multi-device hosts round-robin their cells over
    the devices (async dispatch, materialized after all cells are in
    flight); single-cell specs shard the lane axis over the whole mesh
    inside `run_lanes` instead."""
    import jax

    axes = spec.axes
    rates, seeds = list(axes.rates), list(axes.seeds)
    R, S, F = len(rates), len(seeds), len(axes.faults)
    result = ExperimentResult(spec)
    cell_list = list(cells(spec))
    devs = jax.devices()
    # round-robin cells onto devices only when there are enough cells to
    # occupy them; with fewer cells than devices, sharding each cell's
    # lane axis over the whole mesh uses the machine better than pinning
    # cells to single devices and idling the rest
    round_robin = len(devs) > 1 and len(cell_list) >= len(devs)
    # pass 1: lower every cell's grid and warm the AOT executable cache.
    # All host-blocking compilation happens HERE, before any execution is
    # in flight, so the per-cell wall_s measured below is execution only
    # (a round-robined cell's window never spans another cell's compile).
    plans = []
    for i, cell in enumerate(cell_list):
        key = (cell.topology, cell.routing, cell.traffic,
               axes.warmup, axes.measure, seeds[0])
        sweep = _SWEEP_CACHE.get(key)
        if sweep is None:
            sweep = _SWEEP_CACHE[key] = BatchedSweep(
                cell.net, cell.cfg, cell.pattern)
        frows = _fault_rows(spec, cell.topology, cell.net,
                            cell.routing.vc_mode)
        lanes = [(r, s, frows[fi][si])
                 for fi in range(F)
                 for r in rates
                 for si, s in enumerate(seeds)]
        device = (devs[i % len(devs)]
                  if round_robin
                  and cell.net.num_channels <= rr_max_channels()
                  else None)
        plans.append((cell, sweep, device,
                      sweep.warm_compile(lanes, device=device)))
    # pass 2: dispatch every cell (async; plans are already compiled)
    pending = []
    for cell, sweep, device, plan in plans:
        if verbose:
            where = f" -> {device}" if device is not None else ""
            print(f"[exp:{spec.name}] {cell.topology.label} "
                  f"{cell.routing.label} {cell.traffic.label}: "
                  f"{len(plan.lane_triples)} lanes{where} "
                  f"(compiles={plan.compile_count}) ...",
                  file=sys.stderr, flush=True)
        pending.append((cell, sweep.run_lanes_async(plan=plan)))
    # pass 3: materialize, in dispatch order
    for cell, pend in pending:
        run = pend.finish()
        compile_s, compiles = run.compile_s, run.compile_count
        flat, fsets = run.results, run.fault_sets
        results = [[[flat[(fi * R + ri) * S + si] for si in range(S)]
                    for ri in range(R)] for fi in range(F)]
        fracs = [float(np.mean(
            [0.0 if f is None
             else final_faults(f).frac_links_failed(cell.net)
             for f in fsets[fi * R * S:(fi * R * S) + S]]))
            for fi in range(F)]
        result.grids.append(GridResult(
            topology=cell.topology, routing=cell.routing,
            traffic=cell.traffic, rates=rates, seeds=seeds,
            fault_labels=[f.label for f in axes.faults],
            fault_fracs=fracs, results=results,
            compile_count=compiles, wall_s=run.wall_s,
            compile_s=compile_s,
            placement=getattr(run, "placement", "single"),
            pad_fraction=getattr(run, "pad_fraction", 0.0),
            grant_form=getattr(run, "grant_form", "two_pass"),
            occupancy_peak=getattr(run, "occupancy_peak", 0),
            compact_capacity=getattr(run, "compact_capacity", 0),
            superstep=getattr(run, "superstep", 1),
            escalations=getattr(run, "escalations", 0),
            escalation_compiles=getattr(run, "escalation_compiles", 0)))
        if verbose:
            print(f"[exp:{spec.name}]   {cell.topology.label} "
                  f"{cell.routing.label} {cell.traffic.label} done in "
                  f"{run.wall_s:.1f}s (compiles={compiles}, "
                  f"compile_s={compile_s:.1f})",
                  file=sys.stderr, flush=True)
    return result
