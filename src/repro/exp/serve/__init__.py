"""repro.exp.serve: a persistent, multi-tenant simulation service.

Submitted `ExperimentSpec`s are bucketed by compiled signature
(`scheduler.BucketKey`), packed into device-filling windowed dispatches
(`packer.Pack` over `LaneSession`s, ghost-padded, tenant-fair), streamed
as JSONL window/result records (`repro.exp.windows` — schema-shared with
`python -m repro.exp.run --jsonl`), and checkpointed/resumed
bit-identically through `repro.checkpoint`.  See docs/serve.md.

    from repro.exp.serve import SimService
    svc = SimService(out="serve.jsonl")
    rid = svc.submit(get_scenario("smoke"))
    svc.run()

CLI: ``python -m repro.exp.serve --inbox specs/ --out serve.jsonl``.
"""
from .scheduler import (BucketKey, LaneUnit, Scheduler, bucket_cfg,
                        bucket_sweep, clear_serve_caches, lower_request)
from .packer import Pack
from .service import SimService, serve_pack, serve_window

__all__ = [
    "BucketKey", "LaneUnit", "Pack", "Scheduler", "SimService",
    "bucket_cfg", "bucket_sweep", "clear_serve_caches", "lower_request",
    "serve_pack", "serve_window",
]
