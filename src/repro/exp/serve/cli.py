"""Run the simulation service from the command line.

    python -m repro.exp.serve --inbox specs/ --out serve.jsonl
    python -m repro.exp.serve --stdin --out serve.jsonl < specs.jsonl
    python -m repro.exp.serve --inbox specs/ --state-dir ckpt \\
        --checkpoint-every 2 --max-rounds 3 --out serve.jsonl
    python -m repro.exp.serve --resume --state-dir ckpt --out serve.jsonl

Specs are JSON: either a bare `ExperimentSpec.to_dict()` payload, a
`{"scenario": "smoke"}` registry reference, or either form wrapped as
`{"tenant": "alice", "spec": ...}`.  `--inbox DIR` reads `*.json` files
in sorted name order (one submission each); `--stdin` reads JSONL, one
submission per line; the two compose.  `--max-rounds N` stops after N
service rounds, leaving a final snapshot when `--state-dir` is set —
the kill half of CI's kill+resume smoke; `--resume` rebuilds the
service from the latest snapshot (new submissions may still be added)
and APPENDS to `--out`.  Exit status 0 when the queue drained, 3 when
`--max-rounds` stopped it early (resumable).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .. import registry
from ..spec import ExperimentSpec
from .service import SimService


def _parse_submission(payload: dict) -> tuple[str, ExperimentSpec]:
    tenant = "default"
    if "tenant" in payload or "spec" in payload:
        tenant = payload.get("tenant", "default")
        payload = payload.get("spec", payload)
    if isinstance(payload, str) or "scenario" in payload:
        name = payload if isinstance(payload, str) else payload["scenario"]
        return tenant, registry.get_scenario(name)
    return tenant, ExperimentSpec.from_dict(payload)


def _read_inbox(path: str):
    for name in sorted(os.listdir(path)):
        if name.endswith(".json"):
            with open(os.path.join(path, name)) as f:
                yield _parse_submission(json.load(f))


def _read_stdin():
    for line in sys.stdin:
        line = line.strip()
        if line:
            yield _parse_submission(json.loads(line))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.exp.serve", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--inbox", help="directory of *.json submissions "
                                    "(sorted name order)")
    ap.add_argument("--stdin", action="store_true",
                    help="read JSONL submissions from stdin")
    ap.add_argument("--out", required=True,
                    help="JSONL output path (appended to under --resume)")
    ap.add_argument("--state-dir", default=None,
                    help="checkpoint directory (enables snapshots)")
    ap.add_argument("--resume", action="store_true",
                    help="rebuild from the latest snapshot in --state-dir")
    ap.add_argument("--max-rounds", type=int, default=None,
                    help="stop after N rounds (leaves a snapshot)")
    ap.add_argument("--window", type=int, default=None,
                    help="cycles per window (default REPRO_SERVE_WINDOW)")
    ap.add_argument("--pack", type=int, default=None,
                    help="lanes per pack (default REPRO_SERVE_PACK)")
    ap.add_argument("--max-active", type=int, default=None,
                    help="bound concurrent sessions (default unbounded)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot every N rounds (0 = only at exit)")
    ap.add_argument("--keep", type=int, default=3,
                    help="snapshot retention (newest K)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress progress on stderr")
    args = ap.parse_args(argv)

    if args.resume:
        if not args.state_dir:
            print("ERROR: --resume needs --state-dir", file=sys.stderr)
            return 2
        svc = SimService.resume(args.state_dir, out=args.out,
                                verbose=not args.quiet)
    else:
        svc = SimService(out=args.out, window=args.window, pack=args.pack,
                         max_active=args.max_active,
                         state_dir=args.state_dir,
                         checkpoint_every=args.checkpoint_every,
                         keep=args.keep, verbose=not args.quiet)
    with svc:
        if args.inbox:
            for tenant, spec in _read_inbox(args.inbox):
                svc.submit(spec, tenant=tenant)
        if args.stdin:
            for tenant, spec in _read_stdin():
                svc.submit(spec, tenant=tenant)
        if svc.idle:
            print("ERROR: nothing to run (no submissions, no resumed "
                  "work)", file=sys.stderr)
            return 2
        rounds = svc.run(max_rounds=args.max_rounds)
        drained = svc.idle
        print(f"[serve] {rounds} rounds, "
              f"{'queue drained' if drained else 'stopped with work left'}"
              f" (compile {svc.compile_s:.1f}s) -> {args.out}",
              file=sys.stderr)
    return 0 if drained else 3


if __name__ == "__main__":
    raise SystemExit(main())
