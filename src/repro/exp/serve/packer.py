"""Device-filling packs: heterogeneous lanes -> one windowed session.

A `Pack` wraps one `LaneSession` over up to `pack` lane units from ANY
mix of requests/tenants that share a bucket signature.  Every pack of a
bucket dispatches the SAME executable:

  * `pad_to=pack` ghost-pads short packs to the fixed batch size
    (rate-0 lanes whose stats are never read back);
  * `force_stack=True` keeps the per-lane fault axis stacked even when
    the packed lanes happen to share one fault set;
  * `epochs=bucket.epochs` pins warm buckets to a fixed epoch-stacked
    lane form.

Per-lane math is vmapped and independent, so a lane's counters are
bit-identical no matter which other tenants' lanes share its pack —
the packing-bit-identity guarantee tests/test_serve.py pins against
per-spec `run_experiment` calls.
"""
from __future__ import annotations

import jax

from .scheduler import BucketKey, bucket_sweep


class Pack:
    """One active windowed dispatch of `units` (real lanes, in order)."""

    __slots__ = ("sid", "bucket", "units", "sweep", "session", "chips",
                 "prev_cycle", "device")

    def __init__(self, sid: int, bucket: BucketKey, units: list,
                 session, sweep, device=None):
        self.sid = sid
        self.bucket = bucket
        self.units = units
        self.sweep = sweep
        self.session = session
        self.device = device          # pinned device (None = engine default)
        # accepted-throughput divisor per real lane (mask AND alive)
        self.chips = [sweep._chips(f)
                      for f in session.fault_sets[:len(units)]]
        self.prev_cycle = session.cycle

    @classmethod
    def open(cls, sid: int, bucket: BucketKey, units: list, *,
             window: int, pack: int, restore: dict | None = None,
             device=None) -> "Pack":
        """`device` pins the whole pack's dispatch to one device (the
        service round-robins concurrent packs across the host devices —
        see `service.pack_device`); None keeps the engine's default
        placement.  Placement never changes per-lane math, so packs are
        bit-identical wherever they land."""
        sweep = bucket_sweep(bucket)
        session = sweep.start_lanes(
            [u.triple() for u in units], window=window,
            pad_to=max(pack, len(units)), force_stack=True,
            epochs=bucket.epochs or None, restore=restore,
            device=device)
        return cls(sid, bucket, units, session, sweep, device)

    @property
    def done(self) -> bool:
        return self.session.done()

    def advance(self) -> tuple[int, int]:
        """One window; returns the (start, end) cycle range covered."""
        self.prev_cycle = self.session.cycle
        return self.prev_cycle, self.session.advance()

    def lane_stats(self):
        """(unit, host-SimStats) pairs for the real lanes — the window-
        record source.  Blocks on the in-flight window."""
        stats = self.session.stats_host()
        return [(u, jax.tree.map(lambda x, i=i: x[i], stats))
                for i, u in enumerate(self.units)]

    def finish(self):
        """(unit, SimResult) pairs once the budget is exhausted."""
        run = self.session.finish()
        return list(zip(self.units, run.results))

    def export(self) -> dict:
        return self.session.export()
