"""Signature bucketing and the fairness policy of the serve loop.

Every submitted `ExperimentSpec` lowers to per-cell lane units exactly
the way `repro.exp.runner` lowers a batch run (same cell order, same
lane order, same memoized fault sampling), and each unit is tagged with
its compile-signature bucket:

    BucketKey = (topology, routing, traffic, warmup, measure, epochs)

Everything the compiled window executable's signature can depend on is
in the key — the step closure (topology x routing x traffic), the cycle
budget baked into the warmup-reset constant, and the epoch-stacked lane
form (0 = cold; P >= 1 = warm schedules padded to P epochs).  Sweep
seeds are deliberately NOT in the key: the engine step never reads
`cfg.seed` (lane PRNG keys are per-lane data), so the bucket's
`BatchedSweep` normalizes it to 0 and requests that differ only in
seeds share one executable.  Lanes from any mix of tenants that land in
one bucket can be packed into one device-filling dispatch
(`packer.Pack`) and hit the same AOT cache entry — total compiles ==
number of distinct buckets, which `repro.analysis --serve` certifies.

Fairness: pending units queue per bucket in global admission order
(`seq`).  When session slots are bounded (`max_active`), candidate
packs are activated lowest-(tenant-load, seq) first — a tenant with
fewer active sessions wins a free slot even if a flood of earlier
submissions from a big tenant is still queued, so small tenants age
ahead instead of starving.  Active sessions then advance round-robin,
one window per round each, which bounds any request's completion time
by its own cycle budget regardless of backlog.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from ...core.simulator import SimConfig
from ...core.topology import FaultSchedule
from ..runner import _fault_rows, cells
from ..spec import ExperimentSpec, RoutingSpec, TopologySpec, TrafficSpec

# Serve-side compiled-sweep cache: one `BatchedSweep` per bucket
# signature (seed-normalized, unlike the runner's per-spec cache) so
# every request of a bucket reuses one step closure — the precondition
# for AOT executable-cache hits across tenants.
_SERVE_SWEEPS: dict = {}


def clear_serve_caches() -> None:
    """Drop the serve sweep cache (tests / memory); the runner caches
    are separate (`repro.exp.clear_caches`)."""
    _SERVE_SWEEPS.clear()


@dataclass(frozen=True)
class BucketKey:
    """The compiled-signature equivalence class of a lane."""

    topology: TopologySpec
    routing: RoutingSpec
    traffic: TrafficSpec
    warmup: int
    measure: int
    epochs: int = 0     # 0 = cold fault sets; P >= 1 = epoch-stacked to P

    @property
    def label(self) -> str:
        tag = f"{self.topology.label}/{self.routing.label}" \
              f"/{self.traffic.label}/c{self.warmup}+{self.measure}"
        return tag + (f"/warm{self.epochs}" if self.epochs else "")


def bucket_cfg(key: BucketKey) -> SimConfig:
    """The bucket's engine config: the cell's `SimConfig` with the seed
    normalized to 0 (the step never reads it — per-lane PRNG keys are
    lane data — so seed-only-different requests share one compile)."""
    r = key.routing
    return SimConfig(
        pkt_len=r.pkt_len, buf_pkts=r.buf_pkts, srcq_pkts=r.srcq_pkts,
        vcs_per_class=r.vcs_per_class, warmup=key.warmup,
        measure=key.measure, vc_mode=r.vc_mode, route_mode=r.route_mode,
        ugal_threshold=r.ugal_threshold, seed=0, grant_impl=r.grant_impl,
        step_impl=r.step_impl)


def bucket_sweep(key: BucketKey):
    """The bucket's (memoized) `BatchedSweep` — one step closure per
    signature, shared by every request and pack of the bucket."""
    from ...core.engine.sweep import BatchedSweep
    skey = (key.topology, key.routing, key.traffic, key.warmup,
            key.measure)
    sweep = _SERVE_SWEEPS.get(skey)
    if sweep is None:
        net = key.topology.build()
        sweep = _SERVE_SWEEPS[skey] = BatchedSweep(
            net, bucket_cfg(key), key.traffic.resolve(net))
    return sweep


@dataclass(eq=False)
class LaneUnit:
    """One lane of one request's cell: the packing/accounting unit."""

    seq: int            # global admission order (fairness/aging)
    rid: int
    tenant: str
    cell: int           # cell index within the request's spec
    lane: int           # lane index within the cell (runner lane order)
    bucket: BucketKey
    rate: float         # offered flits/cycle/chip
    seed: int
    fset: object        # composed FaultSet | FaultSchedule | None
    fault: str          # fault spec label (record identity)

    @property
    def key(self) -> tuple:
        return (self.rid, self.cell, self.lane)

    def triple(self) -> tuple:
        return (self.rate, self.seed, self.fset)


def lower_request(spec: ExperimentSpec, rid: int, tenant: str,
                  seq0: int) -> tuple[list[LaneUnit], list[dict]]:
    """Lower a spec to lane units + per-cell record metadata, replicating
    the batch runner's lowering bit-for-bit: same `cells()` order, same
    `(fault x rate x seed)` lane order, same memoized fault sampling —
    so a unit's per-lane math is identical no matter which path runs it.
    """
    axes = spec.axes
    rates, seeds = list(axes.rates), list(axes.seeds)
    units: list[LaneUnit] = []
    cells_meta: list[dict] = []
    seq = seq0
    for ci, cell in enumerate(cells(spec)):
        cells_meta.append(dict(
            topology=cell.topology.label, topo_kind=cell.topology.kind,
            pattern=cell.traffic.label, route_mode=cell.routing.route_mode,
            vc_mode=cell.routing.vc_mode))
        frows = _fault_rows(spec, cell.topology, cell.net,
                            cell.routing.vc_mode)
        # the cell's lane form: warm if ANY lane carries a schedule, with
        # every lane padded to the cell's max epoch count — exactly what
        # the batch runner's `_prepare_lanes` + `stack_lanes` produce
        epochs = max((len(f.epochs) for row in frows for f in row
                      if isinstance(f, FaultSchedule)), default=0)
        bucket = BucketKey(cell.topology, cell.routing, cell.traffic,
                           axes.warmup, axes.measure, epochs)
        li = 0
        for fi, fspec in enumerate(axes.faults):
            for r in rates:
                for si, s in enumerate(seeds):
                    units.append(LaneUnit(
                        seq=seq, rid=rid, tenant=tenant, cell=ci,
                        lane=li, bucket=bucket, rate=r, seed=s,
                        fset=frows[fi][si], fault=fspec.label))
                    seq += 1
                    li += 1
    return units, cells_meta


@dataclass
class Scheduler:
    """Per-bucket FIFO queues + the tenant-aware activation policy."""

    pack: int
    buckets: dict = field(default_factory=dict)   # BucketKey -> deque

    def add(self, units) -> None:
        for u in units:
            self.buckets.setdefault(u.bucket, deque()).append(u)

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self.buckets.values())

    def _candidates(self) -> list:
        """One candidate pack per non-empty bucket: its oldest up-to-
        `pack` pending units (FIFO within the bucket)."""
        out = []
        for key, q in self.buckets.items():
            if q:
                out.append((key, [q[i] for i in range(min(self.pack,
                                                          len(q)))]))
        return out

    def take_packs(self, tenant_active: dict, slots: int | None) -> list:
        """Pop up to `slots` packs (None = every pending unit), picking
        lowest (tenant-load, oldest-seq) first.  A pack's tenant load is
        the MINIMUM of its members' active-session counts: packing with
        a loaded tenant never penalizes the idle one whose lanes age in
        the same bucket."""
        active = dict(tenant_active)
        out = []
        while slots is None or slots > 0:
            cand = self._candidates()
            if not cand:
                break
            key, units = min(
                cand, key=lambda c: (min(active.get(u.tenant, 0)
                                         for u in c[1]),
                                     c[1][0].seq))
            q = self.buckets[key]
            for _ in units:
                q.popleft()
            out.append((key, units))
            for u in units:
                active[u.tenant] = active.get(u.tenant, 0) + 1
            if slots is not None:
                slots -= 1
        return out

    def export(self) -> list:
        """Pending units as (rid, cell, lane, seq) rows, bucket-FIFO
        order flattened by seq — the checkpoint bookkeeping form."""
        rows = [(u.rid, u.cell, u.lane, u.seq)
                for q in self.buckets.values() for u in q]
        return [list(r) for r in sorted(rows, key=lambda r: r[3])]
