"""`SimService`: the long-lived, multi-tenant simulation service loop.

In-process API:

    svc = SimService(out="serve.jsonl", state_dir="ckpt")
    rid = svc.submit(get_scenario("smoke"), tenant="alice")
    svc.run()                       # drive to completion
    results = svc.results(rid)      # [cell][lane] of SimResult

One `run` round = (1) activate pending packs into window sessions
(tenant-fair, see `scheduler`), (2) advance every active session by one
window (round-robin), streaming a `window` record per real lane, (3)
finish exhausted sessions, streaming `result`/`done` records, (4)
checkpoint every `checkpoint_every` rounds.  Because the engine's
windowed sessions replay the one-shot PRNG chain exactly and pack
composition never enters a lane's math, per-lane results are
bit-identical to individual `run_experiment` calls, and the total
compile count equals the number of distinct signature buckets.

Checkpoint/resume: `checkpoint()` writes every active session's
exported state into ONE atomic snapshot (`repro.checkpoint`, npz +
manifest, retention-K) with the full queue/bookkeeping as the manifest
`extra`; `SimService.resume(state_dir)` rebuilds the service — requests
re-lower deterministically, pending lanes re-queue in admission order,
active sessions restore bit-identically — so a killed service resumed
from its latest snapshot appends the exact records the uninterrupted
run would have written.

Knobs (both via `repro.env_int`, flags/kwargs override):
`REPRO_SERVE_WINDOW` (cycles per window, default 128) and
`REPRO_SERVE_PACK` (lanes per pack, default 8).
"""
from __future__ import annotations

import sys
from dataclasses import dataclass, field

from ... import env_int
from ...checkpoint import Checkpointer, save_sim_state
from ..provenance import provenance, spec_hash
from ..spec import ExperimentSpec
from .. import windows as W
from .packer import Pack
from .scheduler import Scheduler, bucket_cfg, lower_request


def serve_window() -> int:
    """`REPRO_SERVE_WINDOW` (default 128): cycles advanced per session
    per round — the streaming/checkpoint granularity.  Every window of
    a bucket runs one fixed-size executable (partial windows are masked
    no-ops), so the choice never changes results or compile counts."""
    return max(1, env_int("REPRO_SERVE_WINDOW", 128))


def serve_pack() -> int:
    """`REPRO_SERVE_PACK` (default 8): lanes per packed dispatch.  Short
    packs ghost-pad up to this size so every pack of a bucket shares
    one executable; larger packs amortize dispatch overhead, smaller
    ones reduce padding waste."""
    return max(1, env_int("REPRO_SERVE_PACK", 8))


def pack_device(sid: int):
    """Deterministic round-robin placement for pack `sid`: with N > 1
    host devices (`REPRO_HOST_DEVICES`, or a real multi-device backend)
    pack sid pins its whole dispatch to device ``(sid - 1) % N``, so
    concurrent packs of different buckets execute on DIFFERENT devices
    (dispatch is async; only compilation serializes on the host).  The
    choice is a pure function of the sid, and sids are checkpointed —
    a resumed pack lands back on the same device.  None on
    single-device hosts (the engine's default placement).  Placement
    never changes per-lane math; results stay bit-identical."""
    import jax
    devs = jax.devices()
    if len(devs) <= 1:
        return None
    return devs[(sid - 1) % len(devs)]


@dataclass
class _Request:
    rid: int
    tenant: str
    spec: ExperimentSpec
    units: list
    cells_meta: list
    done: set = field(default_factory=set)      # finished (cell, lane)
    results: dict = field(default_factory=dict)  # (cell, lane) -> SimResult

    @property
    def complete(self) -> bool:
        return len(self.done) == len(self.units)


class SimService:
    """A persistent queue of `ExperimentSpec`s over one warm engine."""

    def __init__(self, *, out=None, window: int | None = None,
                 pack: int | None = None, max_active: int | None = None,
                 state_dir: str | None = None, checkpoint_every: int = 0,
                 keep: int = 3, verbose: bool = False,
                 _resumed: bool = False):
        self.window = int(window) if window else serve_window()
        self.pack = int(pack) if pack else serve_pack()
        self.max_active = max_active
        self.state_dir = state_dir
        self.checkpoint_every = int(checkpoint_every)
        self.keep = int(keep)
        self.verbose = verbose
        self._sched = Scheduler(pack=self.pack)
        self._requests: dict[int, _Request] = {}
        self._active: dict[int, Pack] = {}
        self._seq = 0
        self._next_rid = 1
        self._next_sid = 1
        self._round = 0
        self.compile_s = 0.0
        self._out = None
        self._own_out = False
        if out is not None:
            if hasattr(out, "write"):
                self._out = out
            else:
                self._out = open(out, "a" if _resumed else "w")
                self._own_out = True
            if not _resumed:
                self._emit(W.meta_record("serve", provenance(),
                                         window=self.window,
                                         pack=self.pack))

    # -- submission ---------------------------------------------------------

    def submit(self, spec: ExperimentSpec, tenant: str = "default") -> int:
        """Queue every lane of `spec`; returns the request id."""
        rid = self._next_rid
        self._next_rid += 1
        units, cells_meta = lower_request(spec, rid, tenant, self._seq)
        self._seq += len(units)
        req = _Request(rid, tenant, spec, units, cells_meta)
        self._requests[rid] = req
        self._sched.add(units)
        self._emit(W.request_record(
            request=rid, tenant=tenant, scenario=spec.name,
            spec_sha256=spec_hash(spec), lanes=len(units)))
        self._log(f"request {rid} ({tenant}): {spec.name}, "
                  f"{len(units)} lanes")
        return rid

    # -- the service loop ---------------------------------------------------

    @property
    def idle(self) -> bool:
        return not self._active and self._sched.pending == 0

    def step(self) -> bool:
        """One round: activate, advance every session one window, finish,
        checkpoint.  Returns True while work remains."""
        if self.idle:
            return False
        self._round += 1
        self._activate()
        for sid in sorted(self._active):
            pk = self._active[sid]
            start, end = pk.advance()
            cfg = bucket_cfg(pk.bucket)
            for i, (u, stats) in enumerate(pk.lane_stats()):
                self._emit(W.window_from_stats(
                    self._meta(u), stats, cycle_start=start,
                    cycle_end=end, cfg=cfg, chips=pk.chips[i]))
        for sid in [s for s, p in self._active.items() if p.done]:
            self._finish(self._active.pop(sid))
        if (self.state_dir and self.checkpoint_every
                and self._round % self.checkpoint_every == 0
                and not self.idle):
            self.checkpoint()
        return not self.idle

    def run(self, max_rounds: int | None = None) -> int:
        """Drive rounds until the queue drains (or `max_rounds`); always
        leaves a final snapshot when a `state_dir` is configured, so a
        `--max-rounds` kill is resumable from the exact stop point."""
        rounds = 0
        while (max_rounds is None or rounds < max_rounds) and self.step():
            rounds += 1
        if self.state_dir and not self.idle:
            self.checkpoint()
        return rounds

    def _activate(self) -> None:
        slots = (None if self.max_active is None
                 else self.max_active - len(self._active))
        tenant_active: dict = {}
        for pk in self._active.values():
            for t in {u.tenant for u in pk.units}:
                tenant_active[t] = tenant_active.get(t, 0) + 1
        for bucket, units in self._sched.take_packs(tenant_active, slots):
            sid = self._next_sid
            self._next_sid += 1
            pk = Pack.open(sid, bucket, units, window=self.window,
                           pack=self.pack, device=pack_device(sid))
            self.compile_s += pk.session.compile_s
            self._active[sid] = pk
            self._log(f"pack {sid}: {len(units)} lanes "
                      f"(+{pk.session.pad_fraction:.0%} ghost) "
                      f"[{bucket.label}]"
                      + (f" @ {pk.device}" if pk.device is not None
                         else "")
                      + (f" compiled in {pk.session.compile_s:.1f}s"
                         if pk.session.compile_count else ""))

    def _finish(self, pk: Pack) -> None:
        for u, res in pk.finish():
            req = self._requests[u.rid]
            req.results[(u.cell, u.lane)] = res
            req.done.add((u.cell, u.lane))
            self._emit(W.result_record(self._meta(u), res))
            if req.complete:
                self._emit(W.done_record(
                    request=req.rid, tenant=req.tenant,
                    scenario=req.spec.name, lanes=len(req.units)))
                self._log(f"request {req.rid} ({req.tenant}) done: "
                          f"{req.spec.name}")

    # -- results ------------------------------------------------------------

    def results(self, rid: int) -> list:
        """[cell][lane] of `SimResult` for a completed request (None for
        lanes finished before a resume snapshot — their records are in
        the JSONL stream of the earlier process)."""
        req = self._requests[rid]
        ncells = len(req.cells_meta)
        per_cell = [0] * ncells
        for u in req.units:
            per_cell[u.cell] = max(per_cell[u.cell], u.lane + 1)
        return [[req.results.get((ci, li))
                 for li in range(per_cell[ci])] for ci in range(ncells)]

    # -- checkpoint / resume ------------------------------------------------

    def checkpoint(self) -> str:
        """One atomic snapshot: every active session's state plus the
        complete queue bookkeeping (manifest `extra`), retention-K."""
        if not self.state_dir:
            raise ValueError("SimService has no state_dir")
        state = {f"s{sid}": pk.export()
                 for sid, pk in self._active.items()}
        extra = dict(
            version=1, round=self._round, seq=self._seq,
            next_rid=self._next_rid, next_sid=self._next_sid,
            window=self.window, pack=self.pack,
            max_active=self.max_active,
            checkpoint_every=self.checkpoint_every, keep=self.keep,
            requests=[dict(rid=r.rid, tenant=r.tenant,
                           spec=r.spec.to_dict(),
                           done=sorted(list(d) for d in r.done))
                      for r in self._requests.values()],
            active=[dict(sid=sid,
                         units=[list(u.key) for u in pk.units])
                    for sid, pk in sorted(self._active.items())],
            pending=self._sched.export())
        path = save_sim_state(self.state_dir, self._round, state,
                              extra=extra, keep=self.keep)
        self._log(f"checkpoint @ round {self._round} -> {path}")
        return path

    @classmethod
    def resume(cls, state_dir: str, *, out=None, verbose: bool = False
               ) -> "SimService":
        """Rebuild a service from its latest snapshot.  Requests
        re-lower deterministically (same cell/lane order, same memoized
        fault sampling), pending lanes re-queue in admission order, and
        each active session restores its exact `SimState`/keys/cycle —
        the resumed run is bit-identical to the uninterrupted one."""
        ckpt = Checkpointer(state_dir)
        extra = ckpt.manifest().get("extra")
        if not extra:
            raise FileNotFoundError(
                f"no serve bookkeeping in the snapshots under {state_dir}")
        svc = cls(out=out, window=extra["window"], pack=extra["pack"],
                  max_active=extra["max_active"], state_dir=state_dir,
                  checkpoint_every=extra["checkpoint_every"],
                  keep=extra["keep"], verbose=verbose, _resumed=True)
        svc._round = extra["round"]
        svc._seq = extra["seq"]
        svc._next_rid = extra["next_rid"]
        svc._next_sid = extra["next_sid"]
        unit_index: dict = {}
        for r in extra["requests"]:
            spec = ExperimentSpec.from_dict(r["spec"])
            units, cells_meta = lower_request(spec, r["rid"], r["tenant"],
                                              0)
            req = _Request(r["rid"], r["tenant"], spec, units, cells_meta)
            req.done = {tuple(d) for d in r["done"]}
            svc._requests[r["rid"]] = req
            for u in units:
                unit_index[u.key] = u
        for rid, cell, lane, seq in extra["pending"]:
            u = unit_index[(rid, cell, lane)]
            u.seq = seq
        svc._sched.add(
            sorted((unit_index[(rid, cell, lane)]
                    for rid, cell, lane, _ in extra["pending"]),
                   key=lambda u: u.seq))
        # restore active sessions: open fresh packs to get the snapshot
        # template (shapes/dtypes), pull the arrays back in, then reopen
        # each pack from its restored state (the second open hits the
        # same AOT executable — no recompilation)
        fresh = {}
        for row in extra["active"]:
            units = [unit_index[tuple(k)] for k in row["units"]]
            fresh[row["sid"]] = Pack.open(
                row["sid"], units[0].bucket, units,
                window=svc.window, pack=svc.pack,
                device=pack_device(row["sid"]))
            svc.compile_s += fresh[row["sid"]].session.compile_s
        if fresh:
            template = {f"s{sid}": pk.export()
                        for sid, pk in fresh.items()}
            restored, _ = ckpt.restore(template)
            for sid, pk in fresh.items():
                snap = restored[f"s{sid}"]
                snap["cycle"] = int(snap["cycle"])
                svc._active[sid] = Pack.open(
                    sid, pk.bucket, pk.units, window=svc.window,
                    pack=svc.pack, restore=snap,
                    device=pack_device(sid))
        svc._log(f"resumed @ round {svc._round}: "
                 f"{len(svc._active)} sessions, "
                 f"{svc._sched.pending} pending lanes")
        return svc

    # -- plumbing -----------------------------------------------------------

    def _meta(self, u) -> dict:
        req = self._requests[u.rid]
        cm = req.cells_meta[u.cell]
        return W.lane_meta(scenario=req.spec.name, tenant=u.tenant,
                           request=u.rid, cell=u.cell, lane=u.lane,
                           fault=u.fault, offered=u.rate, seed=u.seed,
                           **cm)

    def _emit(self, rec: dict) -> None:
        if self._out is not None:
            self._out.write(W.dumps(rec) + "\n")
            self._out.flush()

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(f"[serve] {msg}", file=sys.stderr, flush=True)

    def close(self) -> None:
        if self._own_out and self._out is not None:
            self._out.close()
            self._out = None

    def __enter__(self) -> "SimService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
