"""Declarative experiment specs (see docs/experiments.md).

The paper's evaluation is a grid of scenarios — topology variant x traffic
pattern x routing/VC mode x offered load x fault set.  An `ExperimentSpec`
names one such grid declaratively:

    spec = ExperimentSpec(
        name="fig10a",
        topologies=TopologySpec.switchless(a=1, b=1, m=2, n=6, noc=2, g=1),
        traffics=(TrafficSpec("uniform"), TrafficSpec("bit_reverse")),
        routings=RoutingSpec(vcs_per_class=4),
        axes=SweepAxes(rates=(1.0, 2.0, 3.0, 3.6), warmup=400, measure=1200))

All spec classes are frozen dataclasses: hashable (usable as cache keys),
equality-comparable, validated at construction (bad route/VC pairings,
out-of-range fault rates, unknown patterns all raise `ValueError` before
anything runs), and JSON round-trippable —
`ExperimentSpec.from_dict(spec.to_dict()) == spec` holds exactly, because
free-form parameter dicts are canonicalized to sorted key/value pair
tuples at construction.

Lowering semantics (implemented by `repro.exp.runner`):

  * `topologies x routings x traffics` is the OUTER product: each cell
    gets its own engine step closure (different nets / VC schemes /
    samplers compile separately, identical cells share one compile);
  * `axes.faults x axes.rates x axes.seeds` is the LANE product: inside a
    cell, every combination is one vmapped lane of a single
    `BatchedSweep.run_lanes` dispatch — exactly one compile per grid.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from ..core import topology as T
from ..core import traffic as TR
from ..core.engine.arbitrate import GRANT_IMPLS
from ..core.engine.step import STEP_IMPLS
from ..core.simulator import SimConfig
from ..core.topology import FaultSchedule, FaultSet, Network

SCHEMA_VERSION = 1

TOPO_KINDS = ("switchless", "dragonfly")
ROUTE_MODES = ("min", "val", "val_restricted", "ugal")
VC_MODES = ("baseline", "updown", "updown_merged")
FAULT_KINDS = ("none", "links", "routers", "clusters")
LINK_TYPES = {"mesh": T.MESH, "local": T.LOCAL, "global": T.GLOBAL}


def _pairs(params) -> tuple:
    """Canonical sorted (key, value) pair tuple for free-form params —
    hashable, order-independent, JSON round-trip stable."""
    d = dict(params)
    out = []
    for k in sorted(d):
        v = d[k]
        if isinstance(v, (list, tuple)):
            v = tuple(v)
        out.append((str(k), v))
    return tuple(out)


def _seq(x, cls) -> tuple:
    """Coerce a single spec / dict or a sequence of them to a tuple of
    `cls` instances (singletons are a convenience for one-axis specs)."""
    if isinstance(x, cls) or isinstance(x, dict):
        x = (x,)
    return tuple(cls.from_dict(e) if isinstance(e, dict) else e for e in x)


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------

_PRESETS = {
    "radix16_switchless": T.paper_radix16_switchless,
    "radix16_dragonfly": T.paper_radix16_dragonfly,
    "radix32_switchless": T.paper_radix32_switchless,
    "radix32_dragonfly": T.paper_radix32_dragonfly,
}

_NET_CACHE: dict = {}


@dataclass(frozen=True)
class TopologySpec:
    """One network variant: builder kind + full builder-params pairs.

    `params` is canonicalized through the builder's params dataclass
    (`SwitchlessParams` / `SwitchDragonflyParams`) at construction, so two
    specs naming the same network compare equal even when one spelled out
    defaults and the other didn't — and invalid parameters (unknown
    fields, `g` out of range, `h < 1`) raise here, not at build time.
    """

    kind: str
    params: tuple = ()
    label: str = ""

    def __post_init__(self):
        if self.kind not in TOPO_KINDS:
            raise ValueError(
                f"unknown topology kind {self.kind!r}; valid: {TOPO_KINDS}")
        p = self._params_obj(dict(_pairs(self.params)))
        object.__setattr__(self, "params", _pairs(dataclasses.asdict(p)))
        object.__setattr__(self, "label", self.label or self._default_label())

    def _params_obj(self, kw=None):
        cls = (T.SwitchlessParams if self.kind == "switchless"
               else T.SwitchDragonflyParams)
        try:
            p = cls(**(dict(self.params) if kw is None else kw))
        except TypeError as e:
            raise ValueError(f"bad {self.kind} params: {e}") from None
        # trigger range validation eagerly (raises ValueError)
        if self.kind == "switchless":
            p.num_wgroups
            if p.h < 1:
                raise ValueError(
                    f"h={p.h} < 1: k={p.k} too small for ab={p.ab}")
        else:
            p.num_groups
        return p

    def _default_label(self) -> str:
        d = dict(self.params)
        if self.kind == "switchless":
            tag = f"a{d['a']}b{d['b']}m{d['m']}n{d['n']}g{d['g']}"
            if d.get("cg_bw_mult", 1) > 1:
                tag += f"x{d['cg_bw_mult']}B"
        else:
            tag = f"t{d['t']}l{d['l']}gl{d['gl']}g{d['g']}"
        return f"{self.kind}-{tag}"

    @classmethod
    def switchless(cls, label: str = "", **params) -> "TopologySpec":
        return cls("switchless", _pairs(params), label)

    @classmethod
    def dragonfly(cls, label: str = "", **params) -> "TopologySpec":
        return cls("dragonfly", _pairs(params), label)

    @classmethod
    def preset(cls, name: str, label: str = "", **overrides
               ) -> "TopologySpec":
        """A paper evaluation configuration by name (`radix16_switchless`,
        `radix16_dragonfly`, `radix32_switchless`, `radix32_dragonfly`);
        `overrides` pass through to the preset factory (e.g. `g=11`,
        `cg_bw_mult=2`)."""
        if name not in _PRESETS:
            raise ValueError(
                f"unknown preset {name!r}; valid: {sorted(_PRESETS)}")
        p = _PRESETS[name](**overrides)
        kind = ("switchless" if isinstance(p, T.SwitchlessParams)
                else "dragonfly")
        return cls(kind, _pairs(dataclasses.asdict(p)), label or name)

    def build(self) -> Network:
        """Build (memoized per spec) the concrete router/channel graph."""
        net = _NET_CACHE.get(self)
        if net is None:
            p = self._params_obj()
            build = (T.build_switchless if self.kind == "switchless"
                     else T.build_switch_dragonfly)
            net = _NET_CACHE[self] = build(p, self.label)
        return net

    def to_dict(self) -> dict:
        return dict(kind=self.kind, params=dict(self.params),
                    label=self.label)

    @classmethod
    def from_dict(cls, d: dict) -> "TopologySpec":
        return cls(d["kind"], _pairs(d.get("params", {})),
                   d.get("label", ""))


# ---------------------------------------------------------------------------
# Traffic
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrafficSpec:
    """A traffic pattern by registry name (`repro.core.traffic.PATTERNS`)
    plus factory parameters.  Resolution always yields the normalized
    `(sample, inject_mask)` protocol — the hotspot mask travels with the
    pattern, no caller-side special-casing."""

    pattern: str
    params: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "params", _pairs(self.params))
        TR.validate_pattern_params(self.pattern, dict(self.params))

    @property
    def label(self) -> str:
        if not self.params:
            return self.pattern
        args = ",".join(f"{k}={v}" for k, v in self.params)
        return f"{self.pattern}({args})"

    def resolve(self, net: Network) -> TR.TrafficPattern:
        return TR.make_pattern(net, self.pattern, **dict(self.params))

    def to_dict(self) -> dict:
        return dict(pattern=self.pattern, params=dict(self.params))

    @classmethod
    def from_dict(cls, d: dict) -> "TrafficSpec":
        return cls(d["pattern"], _pairs(d.get("params", {})))


# ---------------------------------------------------------------------------
# Routing / router microarchitecture
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ReaperSpec:
    """Age-based router-death reaper policy (docs/faults.md).

    A packet whose destination died mid-run parks on the stranded gauge
    and holds its buffer slot forever; the reaper DROPS such a parked
    packet once its age reaches `park_age` cycles (counted to the
    `reaped` counter, so conservation stays exact:
    generated == delivered + dropped + reaped + in-flight).  `park_age`
    0 (the default) disables the reaper — stranding keeps its historical
    park-forever semantics and the step compiles no reap logic.  The
    env knob `REPRO_REAP_AGE` supplies a process-wide default when the
    config leaves the reaper off (`repro.env_int`)."""

    park_age: int = 0

    def __post_init__(self):
        if self.park_age < 0:
            raise ValueError(
                f"park_age must be >= 0 (0 disables the reaper), got "
                f"{self.park_age}")

    def to_dict(self) -> dict:
        return dict(park_age=self.park_age)

    @classmethod
    def from_dict(cls, d: dict) -> "ReaperSpec":
        return cls(**d)


@dataclass(frozen=True)
class RoutingSpec:
    """Routing algorithm + VC scheme + router microarchitecture knobs.

    Construction enforces the route/VC compatibility the deadlock proofs
    rely on: `updown_merged` merges the intermediate- and destination-
    W-group VCs, so only restricted misroutes (`min` / `val_restricted`)
    keep its channel-dependency graph acyclic.
    """

    route_mode: str = "min"
    vc_mode: str = "baseline"
    vcs_per_class: int = 2
    ugal_threshold: int = 3
    pkt_len: int = 4
    buf_pkts: int = 8
    srcq_pkts: int = 64
    # arbitration grant implementation: "jnp" (segment_min path, the
    # default and oracle) | "pallas" (fused repro.kernels.netsim kernel)
    grant_impl: str = "jnp"
    # cycle-step implementation: "jnp" (phase-pipeline oracle) | "fused"
    # (route-once-per-hop fused step, the perf path; supports channel
    # sharding via REPRO_CHANNEL_SHARDS)
    step_impl: str = "jnp"
    # router-death reaper policy (park-forever off by default)
    reaper: ReaperSpec = ReaperSpec()

    def __post_init__(self):
        if isinstance(self.reaper, dict):
            object.__setattr__(self, "reaper",
                               ReaperSpec.from_dict(self.reaper))
        if not isinstance(self.reaper, ReaperSpec):
            raise ValueError(
                f"reaper must be a ReaperSpec, got {self.reaper!r}")
        if self.grant_impl not in GRANT_IMPLS:
            raise ValueError(
                f"unknown grant_impl {self.grant_impl!r}; "
                f"valid: {GRANT_IMPLS}")
        if self.step_impl not in STEP_IMPLS:
            raise ValueError(
                f"unknown step_impl {self.step_impl!r}; "
                f"valid: {STEP_IMPLS}")
        if self.route_mode not in ROUTE_MODES:
            raise ValueError(
                f"unknown route_mode {self.route_mode!r}; "
                f"valid: {ROUTE_MODES}")
        if self.vc_mode not in VC_MODES:
            raise ValueError(
                f"unknown vc_mode {self.vc_mode!r}; valid: {VC_MODES}")
        if (self.vc_mode == "updown_merged"
                and self.route_mode not in ("min", "val_restricted")):
            raise ValueError(
                "vc_mode 'updown_merged' merges the intermediate- and "
                "destination-W-group VCs; unrestricted misrouting "
                f"(route_mode {self.route_mode!r}) would close a CDG "
                "cycle — use 'min' or 'val_restricted'")
        for fld in ("vcs_per_class", "pkt_len", "buf_pkts", "srcq_pkts"):
            if getattr(self, fld) < 1:
                raise ValueError(f"{fld} must be >= 1, got "
                                 f"{getattr(self, fld)}")
        if self.ugal_threshold < 0:
            raise ValueError("ugal_threshold must be >= 0")

    @property
    def label(self) -> str:
        return f"{self.route_mode}/{self.vc_mode}"

    def to_simconfig(self, axes: "SweepAxes") -> SimConfig:
        return SimConfig(
            pkt_len=self.pkt_len, buf_pkts=self.buf_pkts,
            srcq_pkts=self.srcq_pkts, vcs_per_class=self.vcs_per_class,
            warmup=axes.warmup, measure=axes.measure,
            vc_mode=self.vc_mode, route_mode=self.route_mode,
            ugal_threshold=self.ugal_threshold, seed=axes.seeds[0],
            grant_impl=self.grant_impl, step_impl=self.step_impl,
            reap_age=self.reaper.park_age)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)   # nests reaper as a plain dict

    @classmethod
    def from_dict(cls, d: dict) -> "RoutingSpec":
        d = dict(d)
        if "reaper" in d:
            d["reaper"] = ReaperSpec.from_dict(d["reaper"])
        return cls(**d)


# ---------------------------------------------------------------------------
# Faults
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultSpec:
    """One sampled fault population of the degraded-wafer model.

    kind      "none" (pristine), "links" (kill ~`frac` of the fabric links
              of `types`), "routers" (kill `num` routers), "clusters"
              (kill `num_clusters` Chebyshev-`radius` defect blobs)
    seed      sampling-stream base; with `per_seed` (default) every sweep
              seed lane draws an INDEPENDENT fault set from stream
              `1000 * seed + lane_seed` (the convention of
              benchmarks/bench_faults.py), otherwise all lanes share one.
    onsets    the WARM (schedule) form: strictly increasing cycle numbers
              at which the fault population grows.  Empty (default) means
              cold faults from cycle 0; with onsets `(c1, .., ck)` the
              sampled result is a `FaultSchedule` — pristine until `c1`,
              then a monotone-growing fault set reaching the full
              population (`frac` / `num` / `num_clusters`) at `ck`, each
              epoch validated routable on top of the previous one.
    repairs   the REPAIR (shrinking) extension: strictly increasing cycle
              numbers, all past the last onset, at which the population
              shrinks again.  Repair j reverts the j-th most recent
              growth increment (LIFO — last broken, first fixed), so
              every repair epoch's fault set is one of the already-
              validated wear-out states; `len(repairs)` up to
              `len(onsets)` (equal means the wafer fully recovers).
    """

    kind: str = "none"
    frac: float = 0.0
    num: int = 0
    num_clusters: int = 1
    radius: int = 1
    types: tuple = ("mesh", "local", "global")
    seed: int = 0
    per_seed: bool = True
    onsets: tuple = ()
    repairs: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "types", tuple(self.types))
        object.__setattr__(self, "frac", float(self.frac))
        object.__setattr__(self, "onsets",
                           tuple(int(c) for c in self.onsets))
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; valid: {FAULT_KINDS}")
        if not 0.0 <= self.frac <= 1.0:
            raise ValueError(f"fault frac {self.frac} outside [0, 1]")
        if self.num < 0:
            raise ValueError(f"fault num must be >= 0, got {self.num}")
        if self.num_clusters < 1 or self.radius < 0:
            raise ValueError("need num_clusters >= 1 and radius >= 0")
        bad = set(self.types) - set(LINK_TYPES)
        if bad:
            raise ValueError(
                f"unknown link types {sorted(bad)}; valid: "
                f"{sorted(LINK_TYPES)}")
        object.__setattr__(self, "repairs",
                           tuple(int(c) for c in self.repairs))
        if self.onsets:
            if self.kind == "none":
                raise ValueError("onsets need a fault kind to schedule "
                                 "(kind='none' is pristine)")
            if any(c <= 0 for c in self.onsets):
                raise ValueError(
                    f"onset cycles must be > 0 (cycle 0 is the cold "
                    f"epoch), got {self.onsets}")
            if any(b <= a for a, b in zip(self.onsets, self.onsets[1:])):
                raise ValueError(
                    f"onset cycles must be strictly increasing: "
                    f"{self.onsets}")
        if self.repairs:
            if not self.onsets:
                raise ValueError(
                    "repairs revert warm growth increments and need "
                    "onsets to revert (a cold population has no "
                    "increment history)")
            if len(self.repairs) > len(self.onsets):
                raise ValueError(
                    f"{len(self.repairs)} repairs would revert more than "
                    f"the {len(self.onsets)} growth increment(s) sampled")
            if any(b <= a for a, b in zip(self.repairs, self.repairs[1:])):
                raise ValueError(
                    f"repair cycles must be strictly increasing: "
                    f"{self.repairs}")
            if self.repairs[0] <= self.onsets[-1]:
                raise ValueError(
                    f"repairs must start after the last onset "
                    f"({self.onsets[-1]}), got {self.repairs}")

    @property
    def is_none(self) -> bool:
        return self.kind == "none"

    @property
    def is_warm(self) -> bool:
        """True for the schedule form (mid-run fault onset/repair)."""
        return bool(self.onsets)

    @property
    def event_cycles(self) -> tuple:
        """Every mid-run epoch-swap cycle (onsets then repairs)."""
        return self.onsets + self.repairs

    @property
    def needs_updown(self) -> bool:
        """True when sampling may kill mesh/local links or routers, which
        only the up*/down* VC modes on the switch-less fabric can route
        around (`topology.validate_faults`)."""
        if self.kind == "none":
            return False
        if self.kind == "links":
            return bool(set(self.types) & {"mesh", "local"})
        return True

    @property
    def label(self) -> str:
        if self.kind == "none":
            return "pristine"
        if self.kind == "links":
            tag = f"links:{self.frac:g}"
        elif self.kind == "routers":
            tag = f"routers:{self.num}"
        else:
            tag = f"clusters:{self.num_clusters}r{self.radius}"
        if self.onsets:
            tag += "@" + ",".join(str(c) for c in self.onsets)
        if self.repairs:
            tag += "~" + ",".join(str(c) for c in self.repairs)
        return tag

    def sample(self, net: Network, vc_mode: str, lane_seed: int = 0
               ) -> FaultSet | FaultSchedule | None:
        """Draw this population for one sweep-seed lane: None for the
        pristine spec, a cold `FaultSet` without `onsets`, a warm
        `FaultSchedule` with them.  Degraded nets stay routable at every
        epoch by the samplers' greedy validation (each warm increment
        composes on top of the previous epoch via `base=`); repair
        epochs revert increments LIFO, so each shrunken state is one the
        growth phase already validated."""
        if self.kind == "none":
            return None
        rng = np.random.default_rng(
            1000 * self.seed + lane_seed if self.per_seed else self.seed)
        if not self.onsets:
            return self._sample_increment(net, vc_mode, rng, 1, 1, None)
        k = len(self.onsets)
        states = [FaultSet()]       # growth history: states[i] after onset i
        epochs = [(0, states[0])]
        for i, c in enumerate(self.onsets):
            states.append(self._sample_increment(net, vc_mode, rng,
                                                 i + 1, k, states[-1]))
            epochs.append((c, states[-1]))
        for j, c in enumerate(self.repairs):
            epochs.append((c, states[k - 1 - j]))
        return FaultSchedule(tuple(epochs))

    def _sample_increment(self, net: Network, vc_mode: str, rng,
                          i: int, k: int, base: FaultSet | None) -> FaultSet:
        """Grow the population to i/k of its full size on top of `base`
        (i == k == 1 is the cold one-shot draw)."""
        if self.kind == "links":
            types = tuple(LINK_TYPES[t] for t in self.types)
            return T.sample_link_faults(net, self.frac / k, rng,
                                        types=types, vc_mode=vc_mode,
                                        base=base)
        if self.kind == "routers":
            delta = round(self.num * i / k) - round(self.num * (i - 1) / k)
            return T.sample_router_faults(net, delta, rng, vc_mode=vc_mode,
                                          base=base)
        delta = (round(self.num_clusters * i / k)
                 - round(self.num_clusters * (i - 1) / k))
        return T.sample_cluster_faults(net, rng, num_clusters=delta,
                                       radius=self.radius, vc_mode=vc_mode,
                                       base=base)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["types"] = list(self.types)
        d["onsets"] = list(self.onsets)
        d["repairs"] = list(self.repairs)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSpec":
        return cls(**d)


# ---------------------------------------------------------------------------
# Sweep axes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SweepAxes:
    """The lane axes of every grid: offered rates x sweep seeds x fault
    populations, plus the per-lane cycle budget."""

    rates: tuple
    seeds: tuple = (0,)
    faults: tuple = (FaultSpec(),)
    warmup: int = 2000
    measure: int = 8000

    def __post_init__(self):
        object.__setattr__(self, "rates",
                           tuple(float(r) for r in self.rates))
        object.__setattr__(self, "seeds",
                           tuple(int(s) for s in self.seeds))
        object.__setattr__(self, "faults", _seq(self.faults, FaultSpec))
        if not self.rates:
            raise ValueError("need >= 1 offered rate")
        if any(r <= 0 for r in self.rates):
            raise ValueError(f"offered rates must be > 0, got {self.rates}")
        if not self.seeds:
            raise ValueError("need >= 1 seed")
        if not self.faults:
            raise ValueError("need >= 1 fault spec (use FaultSpec() for "
                             "pristine)")
        if self.warmup < 0 or self.measure < 1:
            raise ValueError("need warmup >= 0 and measure >= 1")
        cycles = self.warmup + self.measure
        for f in self.faults:
            if f.event_cycles and max(f.event_cycles) >= cycles:
                raise ValueError(
                    f"fault spec {f.label!r} schedules an epoch swap at "
                    f"cycle {max(f.event_cycles)}, past the {cycles}-cycle "
                    f"run (warmup + measure) — the epoch would never "
                    f"activate while accounting reports its degradation")

    @property
    def lanes_per_grid(self) -> int:
        return len(self.rates) * len(self.seeds) * len(self.faults)

    def to_dict(self) -> dict:
        return dict(rates=list(self.rates), seeds=list(self.seeds),
                    faults=[f.to_dict() for f in self.faults],
                    warmup=self.warmup, measure=self.measure)

    @classmethod
    def from_dict(cls, d: dict) -> "SweepAxes":
        return cls(rates=tuple(d["rates"]),
                   seeds=tuple(d.get("seeds", (0,))),
                   faults=tuple(FaultSpec.from_dict(f)
                                for f in d.get("faults", ({"kind": "none"},))),
                   warmup=d.get("warmup", 2000),
                   measure=d.get("measure", 8000))


# ---------------------------------------------------------------------------
# The composed experiment
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ExperimentSpec:
    """One declarative experiment: outer-product cells
    (`topologies x routings x traffics`) over shared lane axes.

    Cross-axis compatibility is validated at construction: the
    switch-based Dragonfly baseline only supports the baseline VC scheme
    and GLOBAL-link faults, and mesh/local/router faults require an
    up*/down* VC mode (matching `topology.validate_faults`), so an
    invalid grid fails before any network is built.
    """

    name: str
    topologies: tuple
    traffics: tuple
    routings: tuple
    axes: SweepAxes
    notes: str = ""

    def __post_init__(self):
        if not self.name:
            raise ValueError("experiment needs a name")
        object.__setattr__(self, "topologies",
                           _seq(self.topologies, TopologySpec))
        object.__setattr__(self, "traffics", _seq(self.traffics, TrafficSpec))
        object.__setattr__(self, "routings", _seq(self.routings, RoutingSpec))
        if isinstance(self.axes, dict):
            object.__setattr__(self, "axes", SweepAxes.from_dict(self.axes))
        if not (self.topologies and self.traffics and self.routings):
            raise ValueError("need >= 1 topology, traffic, and routing spec")
        faulty = [f for f in self.axes.faults if not f.is_none]
        for topo in self.topologies:
            for r in self.routings:
                if topo.kind == "dragonfly" and r.vc_mode != "baseline":
                    raise ValueError(
                        f"vc_mode {r.vc_mode!r} is a switch-less up*/down* "
                        f"scheme; the dragonfly baseline ({topo.label}) "
                        "only supports 'baseline'")
            for f in faulty:
                if f.kind == "clusters" and topo.kind != "switchless":
                    raise ValueError(
                        "clustered (wafer-defect) faults only exist on the "
                        "switch-less topology")
                if f.needs_updown:
                    if topo.kind == "dragonfly":
                        raise ValueError(
                            "the switch-based Dragonfly fault model "
                            "supports GLOBAL-link faults only "
                            f"(fault spec {f.label!r})")
                    for r in self.routings:
                        if r.vc_mode == "baseline":
                            raise ValueError(
                                f"fault spec {f.label!r} can kill "
                                "mesh/local links or routers, which "
                                "vc_mode 'baseline' cannot route around — "
                                "use 'updown' or 'updown_merged'")

    @property
    def num_grids(self) -> int:
        return (len(self.topologies) * len(self.routings)
                * len(self.traffics))

    @property
    def num_lanes(self) -> int:
        return self.num_grids * self.axes.lanes_per_grid

    def with_axes(self, **kw) -> "ExperimentSpec":
        """A copy with some `SweepAxes` fields replaced (e.g. trimmed
        cycle counts for a smoke run)."""
        return dataclasses.replace(
            self, axes=dataclasses.replace(self.axes, **kw))

    def to_dict(self) -> dict:
        return dict(
            version=SCHEMA_VERSION,
            name=self.name,
            topologies=[t.to_dict() for t in self.topologies],
            traffics=[t.to_dict() for t in self.traffics],
            routings=[r.to_dict() for r in self.routings],
            axes=self.axes.to_dict(),
            notes=self.notes)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        version = d.get("version", SCHEMA_VERSION)
        if version != SCHEMA_VERSION:
            raise ValueError(
                f"unsupported experiment schema version {version} "
                f"(this build reads {SCHEMA_VERSION})")
        return cls(
            name=d["name"],
            topologies=tuple(TopologySpec.from_dict(t)
                             for t in d["topologies"]),
            traffics=tuple(TrafficSpec.from_dict(t) for t in d["traffics"]),
            routings=tuple(RoutingSpec.from_dict(r) for r in d["routings"]),
            axes=SweepAxes.from_dict(d["axes"]),
            notes=d.get("notes", ""))
