"""Shared JSONL record schema for incremental stat windows.

Both output paths of the experiment layer write the SAME records through
this module, so batch and serve artifacts are schema-identical and can
be diffed line-for-line:

  * `repro.exp.serve` streams one `window` record per lane per advanced
    window (cumulative counters since the warmup reset) and one `result`
    record per finished lane;
  * `python -m repro.exp.run --jsonl` emits each lane's FINAL window
    (the whole run as one window) plus the same `result` record.

Record kinds (every record carries `kind` + `schema`):

  meta     one header per artifact: source ("serve" | "run"), provenance
  request  one per accepted submission: request id, tenant, spec hash
  window   cumulative per-lane counters at a cycle boundary
  result   the lane's final `SimResult` fields
  done     one per completed request

Windowed throughput divides delivered flits by the MEASURED cycles so
far (`cycle_end - warmup`), which makes the final window's throughput
and latency exactly equal the `result` record's (`stats.finalize`
divides by `measure` — the same denominator once the budget is
exhausted).  Records carry no timestamps: a resumed service appends
byte-identical lines to the ones the uninterrupted run would have
written (pinned by CI's serve-smoke job).
"""
from __future__ import annotations

import json

SCHEMA_VERSION = 1


def lane_meta(*, scenario: str, tenant: str, request: int, cell: int,
              lane: int, topology: str, topo_kind: str, pattern: str,
              route_mode: str, vc_mode: str, fault: str, offered: float,
              seed: int) -> dict:
    """The identity block shared by a lane's window and result records."""
    return dict(scenario=scenario, tenant=tenant, request=request,
                cell=cell, lane=lane, topology=topology,
                topo_kind=topo_kind, pattern=pattern,
                route_mode=route_mode, vc_mode=vc_mode, fault=fault,
                offered=offered, seed=seed)


def meta_record(source: str, provenance: dict | None = None, **kw) -> dict:
    return dict(kind="meta", schema=SCHEMA_VERSION, source=source,
                provenance=provenance or {}, **kw)


def request_record(*, request: int, tenant: str, scenario: str,
                   spec_sha256: str, lanes: int) -> dict:
    return dict(kind="request", schema=SCHEMA_VERSION, request=request,
                tenant=tenant, scenario=scenario, spec_sha256=spec_sha256,
                lanes=lanes)


def done_record(*, request: int, tenant: str, scenario: str,
                lanes: int) -> dict:
    return dict(kind="done", schema=SCHEMA_VERSION, request=request,
                tenant=tenant, scenario=scenario, lanes=lanes)


def window_record(meta: dict, *, cycle_start: int, cycle_end: int,
                  warmup: int, pkt_len: int, chips: float, delivered: int,
                  generated: int, dropped: int, stranded: int,
                  lat_sum: float | None = None,
                  latency: float | None = None) -> dict:
    """One lane's cumulative counters at the `cycle_end` boundary.

    `latency` overrides the `lat_sum / delivered` average when the
    caller only has the already-averaged value (the batch path's
    `SimResult`); the two are the same number by construction.
    """
    measured = max(int(cycle_end) - int(warmup), 0)
    thr = delivered * pkt_len / max(measured, 1) / max(chips, 1e-9)
    if latency is None:
        latency = float(lat_sum) / max(delivered, 1)
    return dict(kind="window", schema=SCHEMA_VERSION, **meta,
                cycle_start=int(cycle_start), cycle_end=int(cycle_end),
                cycles_measured=measured, delivered_pkts=int(delivered),
                generated_pkts=int(generated), dropped_pkts=int(dropped),
                stranded_pkts=int(stranded), throughput=thr,
                latency=latency)


def window_from_stats(meta: dict, stats, *, cycle_start: int,
                      cycle_end: int, cfg, chips: float) -> dict:
    """The serve path: a window record from one lane's raw host
    `SimStats` counters (cumulative since the warmup reset)."""
    return window_record(
        meta, cycle_start=cycle_start, cycle_end=cycle_end,
        warmup=cfg.warmup, pkt_len=cfg.pkt_len, chips=chips,
        delivered=int(stats.delivered), generated=int(stats.generated),
        dropped=int(stats.dropped), stranded=int(stats.stranded),
        lat_sum=float(stats.lat_sum))


def window_from_result(meta: dict, result, *, warmup: int,
                       measure: int) -> dict:
    """The batch path: the run's final window, reconstructed from a
    `SimResult`.  Throughput recomputes through the same formula the
    serve path uses; with `cycle_end = warmup + measure` the denominator
    is `measure`, so the value equals `result.throughput_per_chip`
    exactly (both divide `delivered * pkt_len` by `measure * chips`)."""
    cycles = warmup + measure
    rec = window_record(
        meta, cycle_start=0, cycle_end=cycles, warmup=warmup,
        pkt_len=1, chips=1.0, delivered=result.delivered_pkts,
        generated=result.generated_pkts, dropped=result.dropped_pkts,
        stranded=result.stranded_pkts, latency=result.avg_latency)
    rec["throughput"] = result.throughput_per_chip  # verbatim, no re-div
    return rec


def result_record(meta: dict, result) -> dict:
    """One lane's final `SimResult` as a flat record."""
    return dict(kind="result", schema=SCHEMA_VERSION, **meta,
                throughput=result.throughput_per_chip,
                latency=result.avg_latency,
                delivered_pkts=result.delivered_pkts,
                generated_pkts=result.generated_pkts,
                dropped_pkts=result.dropped_pkts,
                stranded_pkts=result.stranded_pkts,
                hops_by_type=dict(result.hops_by_type),
                avg_hops_by_type=dict(result.avg_hops_by_type))


def dumps(rec: dict) -> str:
    """Canonical one-line form (sorted keys, no whitespace): identical
    records serialize to identical bytes, so resumed-vs-uninterrupted
    artifacts can be compared as text."""
    return json.dumps(rec, sort_keys=True, separators=(",", ":"))
