"""Pallas TPU kernels for the compute hot spots of the assigned
architectures: flash attention (GQA + sliding window), Mamba-2 SSD chunked
scan, RG-LRU linear recurrence.  Each kernel ships kernel.py (pallas_call +
BlockSpec VMEM tiling), ops.py (jit wrapper), ref.py (pure-jnp oracle).
The paper itself has no kernel-level compute contribution (its hot loop is
the network simulator, which is pure vectorized JAX); these kernels serve
the training/serving substrate the interconnect feeds.
"""
