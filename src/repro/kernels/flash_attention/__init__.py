from . import ops, ref
