"""Pallas TPU flash attention (causal GQA, optional sliding window).

Grid: (batch, q_heads, num_q_blocks, num_k_blocks) with the K dimension
innermost and sequential ("arbitrary"); the online-softmax state (m, l,
acc) lives in VMEM scratch and persists across the K iterations of one
(b, h, q) cell — the canonical TPU flash pattern.  KV blocks for GQA are
indexed by qh // group so grouped heads share the same KV tiles.

Block shapes are MXU-aligned: q/k tiles (block_q x head_dim) with
head_dim padded to a multiple of 128 by the ops.py wrapper.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, block_q, block_k, num_k_blocks, seq_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # skip fully-masked tiles (upper triangle / outside the window)
    run = k_start < seq_k
    if causal:
        run &= k_start <= q_start + block_q - 1
        if window is not None:
            run &= k_start + block_k - 1 > q_start - window

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)     # [bq, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)     # [bk, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _emit():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           block_q=128, block_k=128, interpret=True):
    """q: [B, Sq, H, hd]; k/v: [B, Sk, KV, hd].  hd multiple of 128 and
    Sq/Sk multiples of the block sizes are the caller's responsibility
    (ops.py pads)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    groups = H // KV
    scale = 1.0 / math.sqrt(hd)
    nq = Sq // block_q
    nk = Sk // block_k

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_k_blocks=nk, seq_k=Sk)

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h // groups, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h // groups, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),     # l (running sum)
            pltpu.VMEM((block_q, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q, k, v)
    return out
