"""jit'd public wrapper: pads head_dim to a lane multiple and sequence
lengths to block multiples, dispatches to the Pallas kernel (interpret
mode automatically on non-TPU backends)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, causal=True, window=None, block_q=128,
                    block_k=128, interpret=None):
    """q: [B, Sq, H, hd]; k/v: [B, Sk, KV, hd] -> [B, Sq, H, hd]."""
    if interpret is None:
        interpret = _should_interpret()
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Sk))
    hd_pad = (-hd) % 128
    sq_pad = (-Sq) % bq
    sk_pad = (-Sk) % bk

    def pad(x, s_pad):
        return jnp.pad(x, ((0, 0), (0, s_pad), (0, 0), (0, hd_pad)))

    qp, kp, vp = pad(q, sq_pad), pad(k, sk_pad), pad(v, sk_pad)
    if hd_pad:
        # keep softmax scale consistent with the true head_dim
        qp = qp * jnp.sqrt((hd + hd_pad) / hd).astype(qp.dtype)
    o = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=interpret)
    return o[:, :Sq, :, :hd]
