"""Pure-jnp oracle for the flash attention kernel: materialized scores,
fp32 softmax, GQA by explicit repeat.  Deliberately independent of the
chunked/online implementation in models/layers.py."""
import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal=True, window=None):
    """q: [B, Sq, H, hd]; k/v: [B, Sk, KV, hd]."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    groups = H // KV
    k = jnp.repeat(k, groups, axis=2)
    v = jnp.repeat(v, groups, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
