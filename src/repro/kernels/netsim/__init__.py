"""Fused Pallas kernel for the network-simulator arbitration hot spot.

The engine's grant stage (`repro.core.engine.arbitrate.age_based_grant`)
is a chain of row-wise masking (credit / busy / alive / validity) and two
`jax.ops.segment_min` passes (oldest `itime` wins, row ids break ties) —
on CPU each segment op lowers to a per-row scatter loop, and on TPU the
unfused chain round-trips HBM between every op.  `netsim.ops.grant` fuses
the whole stage into ONE `pallas_call`: eligibility masking plus both
segment-min passes run as VPU-friendly broadcast-compare reductions over
(row-chunk x channel) tiles, with the per-channel minima persisted in
VMEM scratch across the grid.

`netsim.ops.cycle_core` extends the same design to the fused cycle step
(`SimConfig(step_impl="fused")`): the packed key `itime * R2 + row`
collapses the two segment-min passes into a single accumulation, and
the emit phase produces the full per-channel winner table AND the
per-row pop mask — the complete set of arbitration decisions the fused
step's apply phase consumes — in one grid.

Selected by `SimConfig(grant_impl="pallas")`; the default "jnp" path is
the oracle, and `ref.grant_ref` mirrors it standalone.  Bit-identical in
interpret mode (CPU) by tests/test_netsim_kernel.py and
tests/test_fused_step.py; interpret=False is the TPU fast path.
"""
from .ops import cycle_core, grant
from .ref import grant_ref

__all__ = ["cycle_core", "grant", "grant_ref"]
