"""Pallas kernels for the cycle-step arbitration hot spot.

Two entry points share one design: segment ops recast as
broadcast-compare reductions — a `[chunk, Es]` one-hot of requested
channels against a channel-id iota — so there is no scatter anywhere:
everything is VPU elementwise work plus row-axis minima, with the
per-channel minima persisted in VMEM scratch across the grid.  All
inputs are int32 (bools widened by ops.py); keys must stay below
INF32 = 2^31 - 1.  vmap (the engine batches lanes) adds a leading batch
grid dimension via the standard pallas batching rule; the scratch
re-initialization at (phase 0, chunk 0) makes each lane's accumulation
independent.

`_kernel` (ops.grant): the standalone two-pass grant — per-row
eligibility (valid & routable & not-busy & (credit | eject) & alive)
plus BOTH segment-min passes.  Grid `(3 phases, row chunks)`:

  phase 0   accumulate m1[c] = min itime over eligible rows requesting c
  phase 1   accumulate m2[c] = min row id over rows tying m1[c]
  phase 2   emit win[row] = tie & (row id == m2[out_row]) and
            won_ch[c] = m1[c] != INF

`_cycle_kernel` (ops.cycle_core): the fused cycle step's grant + apply
decisions in ONE pass over the rows — the packed key
``itime * R2 + prio`` makes (oldest age, smallest priority) a single
lexicographic min, so one accumulation phase replaces the two-pass
chain, and the emit phase produces the complete per-channel winner
table (`won_ch`, winner priority `wprio`) AND the per-row pop mask
that drive the fused step's apply phase.  `prio` is an explicit row
input: the dense fused step feeds the row iota, the occupancy-compacted
step feeds each active slot's GLOBAL row id.  Grid
`(2 phases, row chunks)`:

  phase 0   accumulate m[c] = min (itime * R2 + prio) over rows with
            `ok` requesting c
  phase 1   emit, after the dense busy/alive channel mask:
            won_ch[c] = m[c] != INF, wprio[c] = m[c] & (R2-1), and
            win[row] = ok & (m[out_row] == key_row)

Later phases re-derive row masks from the same inputs instead of
storing a `[N]` intermediate — recompute is cheaper than another VMEM
round-trip, and bit-exactness is trivial (integer ops only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# plain int (not a jnp scalar): pallas kernels may not capture array
# constants, and int32 promotion keeps the comparisons exact
INF32 = 2**31 - 1

# renamed across JAX versions (TPUCompilerParams -> CompilerParams)
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(out_ref, itime_ref, valid_ref, ovc_ref, isej_ref,
            busy_ref, alive_ref, win_ref, won_ref, m1_ref, m2_ref,
            *, chunk, num_seg, buf_pkts):
    phase = pl.program_id(0)
    ci = pl.program_id(1)

    out = out_ref[0, :]                                    # [C]
    seg_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, num_seg), 1)
    onehot = out[:, None] == seg_ids                       # [C, Es]

    # eligibility: the credit/busy/alive masking, with the per-channel
    # gathers (`busy[out]`, `alive[out]`) recast as one-hot row sums
    busy_row = jnp.sum(jnp.where(onehot, busy_ref[0, :][None, :], 0), axis=1)
    alive_row = jnp.sum(jnp.where(onehot, alive_ref[0, :][None, :], 0),
                        axis=1)
    credit = (ovc_ref[0, :] < buf_pkts) | (isej_ref[0, :] != 0)
    ok = ((valid_ref[0, :] != 0) & (out >= 0) & (busy_row == 0)
          & credit & (alive_row != 0))
    mask = onehot & ok[:, None]
    itime = itime_ref[0, :]

    @pl.when((phase == 0) & (ci == 0))
    def _init_m1():
        m1_ref[...] = jnp.full_like(m1_ref, INF32)

    @pl.when(phase == 0)
    def _pass_age():
        cmin = jnp.min(jnp.where(mask, itime[:, None], INF32), axis=0)
        m1_ref[...] = jnp.minimum(m1_ref[...], cmin[None, :])

    # m1 gathered back per row: exactly one one-hot match per valid row,
    # so the masked sum IS the gather (stranded out=-1 rows sum to 0 and
    # are already masked out by `ok`)
    m1_row = jnp.sum(jnp.where(onehot, m1_ref[0, :][None, :], 0), axis=1)
    tie = ok & (itime == m1_row)
    ridx = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)

    @pl.when((phase == 1) & (ci == 0))
    def _init_m2():
        m2_ref[...] = jnp.full_like(m2_ref, INF32)

    @pl.when(phase == 1)
    def _pass_tiebreak():
        cmin = jnp.min(
            jnp.where(mask & tie[:, None], ridx[:, None], INF32), axis=0)
        m2_ref[...] = jnp.minimum(m2_ref[...], cmin[None, :])

    @pl.when(phase == 2)
    def _emit():
        m2_row = jnp.sum(jnp.where(onehot, m2_ref[0, :][None, :], 0),
                         axis=1)
        win_ref[0, :] = (tie & (ridx == m2_row)).astype(jnp.int32)
        won_ref[...] = (m1_ref[...] != INF32).astype(jnp.int32)


def grant_pallas(out, itime, valid, ovc, isej, busy, alive,
                 *, buf_pkts, chunk, interpret=True):
    """Raw tiled dispatch; padding/reshaping is ops.py's responsibility.

    Row inputs are `[nc, chunk]` int32 (padded rows carry valid=0);
    `busy`/`alive` are `[1, Es]` int32 with Es a lane-width multiple of
    E + 1.  Returns (win `[nc, chunk]`, won_ch `[1, Es]`) int32 masks.
    """
    nc, C = out.shape
    Es = busy.shape[1]
    kern = functools.partial(_kernel, chunk=C, num_seg=Es,
                             buf_pkts=buf_pkts)
    row = pl.BlockSpec((1, C), lambda p, c: (c, 0))
    chan = pl.BlockSpec((1, Es), lambda p, c: (0, 0))
    win, won = pl.pallas_call(
        kern,
        grid=(3, nc),
        in_specs=[row, row, row, row, row, chan, chan],
        out_specs=[row, chan],
        out_shape=[jax.ShapeDtypeStruct((nc, C), jnp.int32),
                   jax.ShapeDtypeStruct((1, Es), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, Es), jnp.int32),
                        pltpu.VMEM((1, Es), jnp.int32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(out, itime, valid, ovc, isej, busy, alive)
    return win, won


def _cycle_kernel(out_ref, itime_ref, ok_ref, prio_ref, chok_ref,
                  win_ref, won_ref, wprio_ref, m_ref,
                  *, chunk, num_seg, r2):
    phase = pl.program_id(0)
    ci = pl.program_id(1)

    out = out_ref[0, :]                                    # [C]
    ok = ok_ref[0, :] != 0
    seg_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, num_seg), 1)
    onehot = out[:, None] == seg_ids                       # [C, Es]
    # the tie-break priority is an explicit input (the compacted step
    # feeds GLOBAL row ids of its active slots; the dense fused step
    # feeds the plain row iota) — unique over ok rows, so the packed
    # key stays a total order per channel
    prio = prio_ref[0, :]
    # packed lexicographic key (age, priority); garbage itime on !ok
    # rows may wrap, but the where() keeps only in-range keys < INF32
    key = jnp.where(ok, itime_ref[0, :] * r2 + prio, INF32)

    @pl.when((phase == 0) & (ci == 0))
    def _init_m():
        m_ref[...] = jnp.full_like(m_ref, INF32)

    @pl.when(phase == 0)
    def _accumulate():
        cmin = jnp.min(
            jnp.where(onehot & ok[:, None], key[:, None], INF32), axis=0)
        m_ref[...] = jnp.minimum(m_ref[...], cmin[None, :])

    @pl.when(phase == 1)
    def _emit():
        # dense channel mask applied once, after the reduction: a busy /
        # dead / padded channel (chok=0) grants nobody
        m = jnp.where(chok_ref[0, :] != 0, m_ref[0, :], INF32)  # [Es]
        won = m != INF32
        won_ref[0, :] = won.astype(jnp.int32)
        wprio_ref[0, :] = jnp.where(won, m & (r2 - 1), 0)
        # pop mask: keys are unique per row, so a row wins iff its key
        # equals its channel's masked minimum (one-hot sum == gather)
        m_row = jnp.sum(jnp.where(onehot, m[None, :], 0), axis=1)
        win_ref[0, :] = (ok & (m_row == key)).astype(jnp.int32)


def cycle_core_pallas(out, itime, ok, prio, ch_ok, *, r2,
                      interpret=True):
    """Raw tiled dispatch; padding/reshaping is ops.py's responsibility.

    Row inputs are `[nc, chunk]` int32 (padded rows carry ok=0, and
    `itime * r2 + prio` must be < INF32 on ok rows, with `prio` unique
    over ok rows); `ch_ok` is `[1, Es]` int32 with Es a lane-width
    multiple of E + 1.  Returns
    (win `[nc, chunk]`, won_ch `[1, Es]`, wprio `[1, Es]`) int32.
    """
    nc, C = out.shape
    Es = ch_ok.shape[1]
    kern = functools.partial(_cycle_kernel, chunk=C, num_seg=Es, r2=r2)
    row = pl.BlockSpec((1, C), lambda p, c: (c, 0))
    chan = pl.BlockSpec((1, Es), lambda p, c: (0, 0))
    win, won, wprio = pl.pallas_call(
        kern,
        grid=(2, nc),
        in_specs=[row, row, row, row, chan],
        out_specs=[row, chan, chan],
        out_shape=[jax.ShapeDtypeStruct((nc, C), jnp.int32),
                   jax.ShapeDtypeStruct((1, Es), jnp.int32),
                   jax.ShapeDtypeStruct((1, Es), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, Es), jnp.int32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(out, itime, ok, prio, ch_ok)
    return win, won, wprio
