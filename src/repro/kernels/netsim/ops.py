"""Padding/layout wrapper: engine-facing entry point for the fused grant.

Pads the request rows to a whole number of row chunks (ghost rows are
`valid=0`, so they never win) and the channel axis to a lane-width
multiple of E + 1 (the +1 is the overflow segment ineligible rows map
to), widens the bool masks to int32 for the kernel, and slices the masks
back.  Called from inside the (jitted, vmapped) engine step, so it is a
plain traceable function — no jit of its own.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import grant_pallas

_CHUNK = 128      # rows per grid step; [chunk, Es] tiles stay VPU-sized
_LANE = 128       # channel-axis padding multiple (TPU lane width)


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def grant(out, itime, valid, ovc_count, is_eject, ch_busy, ch_alive,
          *, buf_pkts: int, chunk: int = _CHUNK, interpret: bool | None = None):
    """Drop-in fused replacement for the engine's `age_based_grant` /
    `ref.grant_ref`: same arguments as the oracle, same
    (win [N] bool, won_ch [E] bool) result, one `pallas_call`.

    `interpret=None` auto-selects: compiled on TPU, interpreter elsewhere
    (the CPU path is for parity, not speed — `grant_impl="jnp"` stays the
    CPU fast path)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N = out.shape[0]
    E = ch_busy.shape[0]
    C = min(chunk, _round_up(N, 8))
    nc = -(-N // C)
    rpad = nc * C - N
    Es = _round_up(E + 1, _LANE)

    def rows(x, fill=0):
        x = x.astype(jnp.int32)
        if rpad:
            x = jnp.concatenate(
                [x, jnp.full((rpad,), fill, dtype=jnp.int32)])
        return x.reshape(nc, C)

    def chan(x):
        x = x.astype(jnp.int32)
        return jnp.pad(x, (0, Es - E)).reshape(1, Es)

    win, won = grant_pallas(
        rows(out, fill=-1), rows(itime), rows(valid), rows(ovc_count),
        rows(is_eject), chan(ch_busy), chan(ch_alive),
        buf_pkts=buf_pkts, chunk=C, interpret=interpret)
    return (win.reshape(-1)[:N].astype(bool),
            won[0, :E].astype(bool))
