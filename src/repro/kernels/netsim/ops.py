"""Padding/layout wrappers: engine-facing entry points for the netsim
kernels (`grant` — the standalone two-pass arbitration; `cycle_core` —
the fused cycle step's packed-key grant + pop decisions).

Both pad the request rows to a whole number of row chunks (ghost rows
are `valid=0` / `ok=0`, so they never win) and the channel axis to a
lane-width multiple of E + 1 (the +1 is the overflow segment ineligible
rows map to), widen the bool masks to int32 for the kernel, and slice
the masks back.  Called from inside the (jitted, vmapped) engine step,
so they are plain traceable functions — no jit of their own.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import cycle_core_pallas, grant_pallas

_CHUNK = 128      # rows per grid step; [chunk, Es] tiles stay VPU-sized
_LANE = 128       # channel-axis padding multiple (TPU lane width)


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def grant(out, itime, valid, ovc_count, is_eject, ch_busy, ch_alive,
          *, buf_pkts: int, chunk: int = _CHUNK, interpret: bool | None = None):
    """Drop-in fused replacement for the engine's `age_based_grant` /
    `ref.grant_ref`: same arguments as the oracle, same
    (win [N] bool, won_ch [E] bool) result, one `pallas_call`.

    `interpret=None` auto-selects: compiled on TPU, interpreter elsewhere
    (the CPU path is for parity, not speed — `grant_impl="jnp"` stays the
    CPU fast path)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N = out.shape[0]
    E = ch_busy.shape[0]
    C = min(chunk, _round_up(N, 8))
    nc = -(-N // C)
    rpad = nc * C - N
    Es = _round_up(E + 1, _LANE)

    def rows(x, fill=0):
        x = x.astype(jnp.int32)
        if rpad:
            x = jnp.concatenate(
                [x, jnp.full((rpad,), fill, dtype=jnp.int32)])
        return x.reshape(nc, C)

    def chan(x):
        x = x.astype(jnp.int32)
        return jnp.pad(x, (0, Es - E)).reshape(1, Es)

    win, won = grant_pallas(
        rows(out, fill=-1), rows(itime), rows(valid), rows(ovc_count),
        rows(is_eject), chan(ch_busy), chan(ch_alive),
        buf_pkts=buf_pkts, chunk=C, interpret=interpret)
    return (win.reshape(-1)[:N].astype(bool),
            won[0, :E].astype(bool))


def cycle_core(out, itime, ok, ch_ok, *, r2: int, prio=None,
               chunk: int = _CHUNK, interpret: bool | None = None):
    """Fused-step arbitration core: one `pallas_call` computing the
    channel winner table and the per-row pop mask from the packed key
    ``itime * r2 + prio``.

    `ok` is the complete per-row eligibility (valid & routable & credit
    & alive — the fused step computes it from its cached routes), and
    `ch_ok` the dense per-channel mask (not busy & alive).  `prio` is
    the per-row tie-break priority, unique over ok rows; when omitted
    it defaults to the row iota (the dense fused step's tie-break — the
    occupancy-compacted step passes each active slot's GLOBAL row id so
    the winner ids match the oracle bit-for-bit).  `r2` must be a power
    of two > max(prio) with ``max(itime) * r2 + r2 - 1 < 2^31 - 1`` (the
    caller guards this and falls back to the two-pass jnp grant when the
    cycle budget would overflow).  Returns
    (won_ch [E] bool, wprio [E] int32 winner priority, win [N] bool).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N = out.shape[0]
    E = ch_ok.shape[0]
    C = min(chunk, _round_up(N, 8))
    nc = -(-N // C)
    rpad = nc * C - N
    Es = _round_up(E + 1, _LANE)
    if prio is None:
        prio = jnp.arange(N, dtype=jnp.int32)

    def rows(x, fill=0):
        x = x.astype(jnp.int32)
        if rpad:
            x = jnp.concatenate(
                [x, jnp.full((rpad,), fill, dtype=jnp.int32)])
        return x.reshape(nc, C)

    win, won, wprio = cycle_core_pallas(
        rows(out, fill=-1), rows(itime), rows(ok), rows(prio),
        jnp.pad(ch_ok.astype(jnp.int32), (0, Es - E)).reshape(1, Es),
        r2=r2, interpret=interpret)
    return (won[0, :E].astype(bool), wprio[0, :E],
            win.reshape(-1)[:N].astype(bool))
