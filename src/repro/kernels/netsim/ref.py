"""Pure-jnp oracle for the fused grant kernel.

Mirrors `repro.core.engine.arbitrate.age_based_grant` exactly (the
`jax.ops.segment_min` two-pass arbitration), but over raw arrays instead
of the engine's `Requests` record, so the kernel parity tests can drive
both implementations from one set of inputs.  Integer keys and exact
min/tie-break semantics make "bit-identical" well-defined: there is no
floating-point reassociation anywhere in this stage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF32 = jnp.int32(2**31 - 1)


def grant_ref(out, itime, valid, ovc_count, is_eject, ch_busy, ch_alive,
              *, buf_pkts: int):
    """One winner per output channel, oldest `itime` first, row ids break
    ties.

    out        [N] int32  requested output channel (-1 = stranded, never
                          granted)
    itime      [N] int32  generation cycle (age key)
    valid      [N] bool   the row holds a forwardable packet
    ovc_count  [N] int32  occupancy of the requested downstream buffer
    is_eject   [N] bool   the requested channel is an ejection channel
                          (always has credit)
    ch_busy    [E] int32  per-channel serialization countdown
    ch_alive   [E] bool   per-channel fault mask

    Returns (win [N] bool, won_ch [E] bool).
    """
    E = ch_busy.shape[0]
    credit = ovc_count < buf_pkts
    ok = valid & (out >= 0) & (ch_busy[out] == 0) & (credit | is_eject)
    ok = ok & ch_alive[out]

    seg = jnp.where(ok, out, E)
    key1 = jnp.where(ok, itime, INF32)
    m1 = jax.ops.segment_min(key1, seg, num_segments=E + 1)
    tie = ok & (itime == m1[out])
    ridx = jnp.arange(out.shape[0], dtype=jnp.int32)
    key2 = jnp.where(tie, ridx, INF32)
    m2 = jax.ops.segment_min(key2, seg, num_segments=E + 1)
    win = tie & (ridx == m2[out])
    won_ch = m1[:E] != INF32
    return win, won_ch
