from . import ops, ref
