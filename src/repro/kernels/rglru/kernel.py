"""Pallas TPU kernel for the RG-LRU diagonal linear recurrence
(Griffin, arXiv:2402.19427):  h_t = a_t * h_{t-1} + b_t.

The gate/input projections run outside on the MXU; this kernel is the
memory-bound recurrent scan the Griffin paper writes a custom kernel for.
Grid: (batch, channel_blocks, chunks) with chunks sequential and the
hidden state persisted in VMEM scratch; channels are tiled to the lane
width so the scan runs as VPU vector ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, h_ref, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(l, h):
        a = a_ref[0, l, :]
        b = b_ref[0, l, :]
        h = a * h + b
        o_ref[0, l, :] = h
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[0, :])
    h_ref[0, :] = h


def rglru_scan_pallas(a, b, *, chunk=256, block_r=512, interpret=True):
    """a, b: [B, S, R] fp32 -> h: [B, S, R].  S % chunk == 0 and
    R % block_r == 0 are the wrapper's responsibility."""
    B, S, R = a.shape
    nc = S // chunk
    nr = R // block_r
    kern = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=(B, nr, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_r), lambda b_, r, c: (b_, c, r)),
            pl.BlockSpec((1, chunk, block_r), lambda b_, r, c: (b_, c, r)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_r),
                               lambda b_, r, c: (b_, c, r)),
        out_shape=jax.ShapeDtypeStruct((B, S, R), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_r), jnp.float32)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(a, b)
