"""jit'd wrapper: pads (a=1, b=0 are identity steps) and dispatches."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import rglru_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "block_r",
                                             "interpret"))
def rglru_scan(a, b, chunk=256, block_r=512, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, R = a.shape
    c = min(chunk, S)
    br = min(block_r, R)
    s_pad = (-S) % c
    r_pad = (-R) % br
    if s_pad or r_pad:
        a = jnp.pad(a, ((0, 0), (0, s_pad), (0, r_pad)),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, s_pad), (0, r_pad)))
    h = rglru_scan_pallas(a, b, chunk=c, block_r=br, interpret=interpret)
    return h[:, :S, :R]
