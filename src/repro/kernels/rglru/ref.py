"""Sequential oracle for the diagonal linear recurrence."""
import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b):
    """h_t = a_t h_{t-1} + b_t.  a, b: [B, S, R]."""
    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    a_t = a.transpose(1, 0, 2)
    b_t = b.transpose(1, 0, 2)
    h0 = jnp.zeros_like(a[:, 0])
    _, hs = jax.lax.scan(step, h0, (a_t, b_t))
    return hs.transpose(1, 0, 2)
