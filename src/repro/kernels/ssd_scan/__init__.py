from . import ops, ref
