"""Pallas TPU kernel for the Mamba-2 SSD chunked scan (arXiv:2405.21060).

Grid: (batch, heads, num_chunks) with the chunk dimension innermost and
sequential; the inter-chunk SSM state [head_dim, d_state] persists in VMEM
scratch.  Within a chunk the intra-chunk term is two MXU matmuls
([L,N]x[N,L] decay-masked, then [L,L]x[L,P]), exactly the "state-space
duality" formulation the paper tiles for tensor cores — re-tiled here for
the MXU with fp32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
            chunk, seq_len):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    L = chunk
    x = x_ref[0, :, 0, :].astype(jnp.float32)        # [L, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # [L]
    A = a_ref[0].astype(jnp.float32)                 # scalar decay rate
    Bm = b_ref[0].astype(jnp.float32)                # [L, N]
    Cm = c_ref[0].astype(jnp.float32)                # [L, N]

    # zero padded steps (dt = 0 -> identity transition, no contribution)
    pos = ci * L + jax.lax.iota(jnp.int32, L)
    dt = jnp.where(pos < seq_len, dt, 0.0)

    la = -A * dt                                     # per-step log decay
    cum = jnp.cumsum(la)                             # [L]

    # intra-chunk: y_i = sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j x_j
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    att = jnp.exp(jnp.where(jj <= ii, seg, -1e30))  # mask before exp
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, L]
    w = cb * att * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [L, P]

    # inter-chunk: y_i += exp(cum_i) * C_i . S_prev^T
    s_prev = state_ref[...]                          # [P, N]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, s_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [L, P]

    # state update: S = exp(cum_L) S_prev + x^T (exp(cum_L - cum_j) dt_j B_j)
    decay_tail = jnp.exp(cum[-1] - cum) * dt         # [L]
    state_ref[...] = jnp.exp(cum[-1]) * s_prev + jax.lax.dot_general(
        x, decay_tail[:, None] * Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # [P, N]

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_scan_pallas(x, dt, A, Bm, Cm, *, chunk=128, interpret=True):
    """x: [B, S, H, P]; dt: [B, S, H] (post-softplus); A: [H] (positive);
    Bm/Cm: [B, S, N].  S must be a multiple of `chunk` (ops.py pads)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    kern = functools.partial(_kernel, chunk=chunk, seq_len=S)
    grid = (B, H, nc)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, dt, A, Bm, Cm)
