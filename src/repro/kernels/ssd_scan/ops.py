"""jit'd wrapper: pads S to the chunk multiple and dispatches."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, chunk=128, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, H, P = x.shape
    c = min(chunk, S) if S >= 8 else S
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=c, interpret=interpret)
    return y[:, :S]
