"""Sequential (step-by-step) SSD oracle — independent of any chunking."""
import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm):
    """x: [B, S, H, P]; dt: [B, S, H]; A: [H]; Bm/Cm: [B, S, N].

    s_t = exp(-A dt_t) s_{t-1} + dt_t * (x_t outer B_t);  y_t = C_t . s_t
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(s, inp):
        xt, dtt, bt, ct = inp        # [B,H,P], [B,H], [B,N], [B,N]
        a = jnp.exp(-A[None, :] * dtt)                       # [B,H]
        upd = dtt[..., None, None] * (xt[..., :, None] *
                                      bt[:, None, None, :])  # [B,H,P,N]
        s = s * a[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", ct, s)
        return s, y

    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    s_final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), s_final
