import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first initialization.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from functools import partial  # noqa: E402

import numpy as np   # noqa: E402
import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs.base import LM_SHAPES, shape_by_name  # noqa: E402
from repro.configs.registry import (ARCHS, cell_applicable,  # noqa: E402
                                    get_config, input_specs)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import transformer as TF  # noqa: E402
from repro.optim.optimizer import OptConfig  # noqa: E402
from repro.runtime import sharding as SH  # noqa: E402
from repro.runtime.hlo_analysis import collective_bytes  # noqa: E402
from repro.runtime.trainer import (TrainSetup, make_decode_step,  # noqa: E402
                                   make_prefill_step, make_train_step)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def _axis_sizes(mesh):
    return {name: int(size) for name, size in
            zip(mesh.axis_names, mesh.devices.shape)}


def _eval_shapes(fn, *args):
    return jax.eval_shape(fn, *args)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               save_hlo: bool = False, mesh_shape: tuple | None = None,
               tag: str | None = None, moe_dispatch: str = "bf16",
               microbatch: int = 1):
    """Lower + compile one (arch x shape x mesh) cell; returns the artifact
    dict (raises on real failures).

    mesh_shape: optional (data, model) override at 256 chips (perf
    iteration: TP-degree tuning).  moe_dispatch: "bf16" | "int8" selects
    quantized expert dispatch (perf iteration)."""
    import jax as _jax
    cfg = get_config(arch)
    if moe_dispatch != "bf16" and cfg.moe is not None:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe,
                                               dispatch=moe_dispatch))
    shape = shape_by_name(shape_name)
    mesh_tag = tag or ("multi" if multi_pod else "single")
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                "status": "skipped", "reason": why}
    if mesh_shape is not None:
        mesh = _jax.make_mesh(mesh_shape, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    axis_sizes = _axis_sizes(mesh)
    setup = TrainSetup(model=cfg, opt=OptConfig(), attn_impl="chunked",
                       microbatch=microbatch)

    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_sds = _eval_shapes(partial(TF.init_params, cfg=cfg), key_sds)
    pspecs = SH.tree_param_specs(params_sds, mesh)
    batch_sds = input_specs(cfg, shape)
    bspecs = SH.batch_specs(batch_sds, mesh)

    t0 = time.time()
    if shape.kind == "train":
        from repro.optim.optimizer import init_opt_state
        opt_sds = _eval_shapes(init_opt_state, params_sds)
        ospecs = {
            "master": SH.opt_state_specs(pspecs, params_sds, mesh),
            "m": SH.opt_state_specs(pspecs, params_sds, mesh),
            "v": SH.opt_state_specs(pspecs, params_sds, mesh),
            "step": P(),
        }
        fn = make_train_step(setup, mesh)
        jfn = jax.jit(fn,
                      in_shardings=(SH.shardings(pspecs, mesh),
                                    SH.shardings(ospecs, mesh),
                                    SH.shardings(bspecs, mesh)),
                      donate_argnums=(0, 1))
        lowered = jfn.lower(params_sds, opt_sds, batch_sds)
    else:
        B = shape.global_batch
        cache_len = shape.seq_len
        cache_sds = _eval_shapes(
            partial(TF.init_cache, cfg, B, cache_len))
        cspecs = SH.cache_specs(cache_sds, mesh)
        if shape.kind == "prefill":
            fn = make_prefill_step(setup, mesh)
        else:
            fn = make_decode_step(setup, mesh)
        jfn = jax.jit(fn,
                      in_shardings=(SH.shardings(pspecs, mesh),
                                    SH.shardings(bspecs, mesh),
                                    SH.shardings(cspecs, mesh)),
                      donate_argnums=(2,))
        lowered = jfn.lower(params_sds, batch_sds, cache_sds)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # loop trip counts for scaling collectives found inside scan bodies:
    # outermost = the layer-group scan, inner = chunk scans (SSD/attention)
    from repro.models.transformer import _segments
    _, groups, _, _ = _segments(cfg)
    inner = 1
    if cfg.ssm is not None and shape.kind != "decode":
        inner = max(inner, shape.seq_len // cfg.ssm.chunk)
    elif shape.kind != "decode":
        inner = max(inner, shape.seq_len // 512)  # chunked attention q-map
    coll = collective_bytes(hlo, axis_sizes,
                            loop_trips=(max(groups, 1), inner))

    mem_d = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes"):
        mem_d[k] = int(getattr(mem, k, 0))
    # per-device totals (all sizes reported by XLA are per device on CPU
    # with SPMD partitioning)
    art = {
        "arch": arch, "shape": shape_name,
        "mesh": mesh_tag,
        "status": "ok",
        "axis_sizes": axis_sizes,
        "chips": int(np.prod(mesh.devices.shape)),
        "kind": shape.kind,
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "cost_analysis_keys": sorted(cost)[:40],
        "memory": mem_d,
        "collectives": {"by_op": coll["by_op"], "by_axis": coll["by_axis"],
                        "num_ops": len(coll["ops"])},
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "params": cfg.num_params(),
        "active_params": cfg.active_params(),
    }
    if save_hlo:
        art["hlo_len"] = len(hlo)
    return art


def cell_path(arch, shape_name, multi_pod):
    tag = "multi" if multi_pod else "single"
    return os.path.join(ART_DIR, f"{arch}__{shape_name}__{tag}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(ART_DIR, exist_ok=True)
    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = [s.name for s in LM_SHAPES] if args.shape == "all" \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                path = cell_path(arch, shape_name, multi_pod)
                if os.path.exists(path) and not args.force:
                    print(f"[skip-cached] {os.path.basename(path)}")
                    continue
                tag = "multi" if multi_pod else "single"
                print(f"[lower] {arch} x {shape_name} x {tag} ...",
                      flush=True)
                try:
                    art = lower_cell(arch, shape_name, multi_pod)
                except Exception as e:
                    failures += 1
                    art = {"arch": arch, "shape": shape_name, "mesh": tag,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-3000:]}
                    print(f"  ERROR: {e!r}", flush=True)
                with open(path, "w") as f:
                    json.dump(art, f, indent=1)
                if art["status"] == "ok":
                    print(f"  ok: flops={art['flops']:.3e} "
                          f"coll={art['collectives']['by_axis']} "
                          f"compile={art['t_compile_s']}s", flush=True)
                elif art["status"] == "skipped":
                    print(f"  skipped: {art['reason']}", flush=True)
    print(f"done; failures={failures}")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
