"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state.  The single-pod mesh is 16x16 = 256 chips ("data", "model"); the
multi-pod mesh is 2x16x16 = 512 chips ("pod", "data", "model").

Fabric mapping (DESIGN.md): one wafer-scale W-group hosts a pod; the
"model" axis rides the on-wafer C-group meshes, "data" the intra-W-group
local links, "pod" the global links of the switch-less Dragonfly.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model = model or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh((n // model, model), ("data", "model"))
