"""Serving launcher: --arch <id>, batched prefill + greedy decode against
KV/state caches (the steps the decode dry-run cells lower).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config
from repro.models import transformer as TF


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch + ("-smoke" if args.smoke else ""))
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_prefix, cfg.d_model)) * 0.02,
            cfg.jdtype)
    extra = {}
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)) * 0.02, cfg.jdtype)
        extra["src_embeds"] = batch["src_embeds"]
    max_len = S + args.gen + (cfg.num_prefix if cfg.frontend else 0)
    cache = TF.init_cache(cfg, B, max_len=max_len)

    impl = "naive" if args.smoke else "chunked"

    @jax.jit
    def prefill(params, batch, cache):
        logits, cache, _ = TF.forward(params, cfg, batch, "prefill",
                                      cache=cache, attn_impl=impl,
                                      remat=False)
        return jnp.argmax(logits[:, -1:], -1).astype(jnp.int32), cache

    @jax.jit
    def decode(params, tok, cache):
        logits, cache, _ = TF.forward(params, cfg, {"tokens": tok, **extra},
                                      "decode", cache=cache,
                                      attn_impl="naive", remat=False)
        return jnp.argmax(logits[:, -1:], -1).astype(jnp.int32), cache

    t0 = time.perf_counter()
    tok, cache = prefill(params, batch, cache)
    t_pref = time.perf_counter() - t0
    toks = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        tok, cache = decode(params, tok, cache)
        toks.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"{cfg.name}: prefill {t_pref * 1e3:.1f} ms, decode "
          f"{t_dec / max(args.gen - 1, 1) * 1e3:.1f} ms/token")
    print("tokens[0]:", np.asarray(out[0])[:12])
    assert bool(jnp.isfinite(out).all())


if __name__ == "__main__":
    main()
