"""Production training launcher: --arch <id> on the host or production
mesh, with checkpointing, fault tolerance and straggler monitoring.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --steps 100 --batch 8 --seq 128 [--smoke]

--smoke uses the reduced same-family config (CPU-sized); without it the
full architecture config is used (requires real accelerators).
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint.checkpointing import Checkpointer
from repro.configs.registry import ARCHS, get_config
from repro.data.pipeline import Prefetcher, SyntheticTokens
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim.optimizer import OptConfig
from repro.runtime.fault_tolerance import (FailureInjector,
                                           FaultTolerantLoop,
                                           StragglerMonitor)
from repro.runtime.trainer import Trainer, TrainSetup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro-ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch + ("-smoke" if args.smoke else ""))
    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                    total_steps=args.steps, schedule=cfg.schedule)
    setup = TrainSetup(model=cfg, opt=opt,
                       attn_impl="naive" if args.smoke else "chunked",
                       remat=not args.smoke, microbatch=args.microbatch)
    mesh = make_production_mesh(multi_pod=args.multi_pod) \
        if args.production_mesh else make_host_mesh(model=1)
    data = Prefetcher(SyntheticTokens(cfg.vocab_size, args.batch, args.seq))
    # Prefetcher wraps the stream; Trainer needs state()/restore() from the
    # underlying stream for checkpointing
    data.state = data.it.state
    data.restore = data.it.restore
    ckpt = Checkpointer(args.ckpt_dir, keep=3)
    tr = Trainer(setup, mesh, data, checkpointer=ckpt,
                 ckpt_every=args.ckpt_every)
    mon = StragglerMonitor()

    def on_step(step, metrics, dt):
        mon.observe(step, dt)
        if step % 10 == 0 or step == 1:
            print(f"step {step:5d}  loss {metrics['loss']:.3f}  "
                  f"lr {metrics['lr']:.2e}  {dt * 1e3:.0f} ms", flush=True)

    if args.fail_at:
        loop = FaultTolerantLoop(tr, FailureInjector(fail_at=(args.fail_at,)),
                                 mon)
        loop.run(args.steps)
        print("recovery log:", loop.log)
    else:
        tr.run(args.steps, on_step=on_step)
    print(f"done at step {tr.step}; straggler events: {len(mon.events)}")


if __name__ == "__main__":
    main()
