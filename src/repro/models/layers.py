"""Functional layer library (no flax): params are nested dicts of jnp
arrays; every layer is an (init, apply) pair.

Attention supports three implementations selected by `attn_impl`:
  naive   - materialized scores (reference / tiny smoke shapes)
  chunked - online-softmax over KV blocks in pure jnp (lowers on any
            backend with flash-attention-like memory; used by the dry-run)
  kernel  - Pallas TPU flash attention (src/repro/kernels), interpret=True
            on CPU for tests
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, dtype, scale):
    x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return (x * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, use_bias=False, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": truncated_normal(key, (d_in, d_out), dtype, scale)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


# --- rotary embeddings -------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, rot_dim: int | None = None):
    rot = rot_dim or head_dim
    inv = 1.0 / (theta ** (np.arange(0, rot, 2) / rot))
    return jnp.asarray(inv, jnp.float32)


def apply_rope(x, positions, inv_freq, rot_dim: int | None = None):
    """x: [..., seq, heads, head_dim]; positions: [..., seq].

    rot_dim < head_dim rotates only the first rot_dim dims (ChatGLM-style
    2D/partial RoPE)."""
    hd = x.shape[-1]
    rot = rot_dim or hd
    xr, xp = x[..., :rot], x[..., rot:]
    ang = positions[..., :, None].astype(jnp.float32) * inv_freq  # [..., S, rot/2]
    sin = jnp.sin(ang)[..., :, None, :]
    cos = jnp.cos(ang)[..., :, None, :]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1) if rot < hd \
        else out.astype(x.dtype)


# --- attention ---------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    use_bias: bool = False
    rope_theta: float = 1e4
    rope_frac: float = 1.0        # fraction of head_dim rotated
    causal: bool = True
    window: int | None = None     # local attention window
    attn_impl: str = "chunked"
    chunk_q: int = 512
    chunk_k: int = 1024


def attention_init(key, cfg: AttnConfig, dtype):
    ks = jax.random.split(key, 4)
    H, KV, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "q": dense_init(ks[0], d, H * hd, dtype, cfg.use_bias),
        "k": dense_init(ks[1], d, KV * hd, dtype, cfg.use_bias),
        "v": dense_init(ks[2], d, KV * hd, dtype, cfg.use_bias),
        "o": dense_init(ks[3], H * hd, d, dtype, cfg.use_bias,
                        scale=1.0 / math.sqrt(H * hd)),
    }


def _repeat_kv(k, groups):
    # k: [B, S, KV, hd] -> [B, S, KV*groups, hd]
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def naive_attention(q, k, v, causal=True, window=None, q_offset=0):
    """q: [B, Sq, H, hd]; k/v: [B, Sk, H, hd] (already GQA-expanded)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def chunked_attention(q, k, v, causal=True, window=None, q_offset=0,
                      chunk_q=512, chunk_k=1024):
    """Online-softmax flash attention in pure jnp: O(Sq*hd) memory."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    cq = min(chunk_q, Sq)
    ck = min(chunk_k, Sk)
    pad_q = (-Sq) % cq
    pad_k = (-Sk) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // cq, k.shape[1] // ck
    qs = q.reshape(B, nq, cq, H, hd).transpose(1, 0, 3, 2, 4)  # [nq,B,H,cq,hd]
    ks = k.reshape(B, nk, ck, H, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, nk, ck, H, hd).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / math.sqrt(hd)

    def q_block(qi, qb):
        qpos = qi * cq + jnp.arange(cq) + q_offset

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, kb, vb = inp
            kpos = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb).astype(jnp.float32) \
                * scale
            msk = (kpos[None, :] < Sk)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                msk &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(msk[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, H, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # [B,H,cq,hd]

    outs = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, nq * cq, H, hd)
    return out[:, :Sq].astype(v.dtype)


def attention_apply(p, cfg: AttnConfig, x, positions, inv_freq, cache=None,
                    mesh_axes=None, kv_memory=None):
    """x: [B, S, D].  cache: dict(k, v, idx) for decode.  kv_memory: [B, Sm, D]
    for cross-attention (encoder memory); RoPE is skipped for cross-attn."""
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(p["q"], x).reshape(B, S, H, hd)
    src = kv_memory if kv_memory is not None else x
    Sk = src.shape[1]
    k = dense(p["k"], src).reshape(B, Sk, KV, hd)
    v = dense(p["v"], src).reshape(B, Sk, KV, hd)
    cross = kv_memory is not None

    if not cross:
        rot = int(hd * cfg.rope_frac)
        if rot > 0:
            q = apply_rope(q, positions, inv_freq, rot)
            kpos = positions if cache is None else positions
            k = apply_rope(k, kpos, inv_freq, rot)

    q_offset = 0
    decode = cache is not None and not cross and S == 1
    prefill_cache = cache is not None and not cross and S > 1
    if decode:
        # append one token to the (possibly rolling) cache
        idx = cache["idx"]          # absolute position of the new token
        base = cache.get("base", jnp.zeros((), jnp.int32))
        W = cache["k"].shape[1]
        pos = (idx - base) % W if cfg.window is not None else idx
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        k, v = ck, cv
        new_cache = {"k": ck, "v": cv, "idx": idx + 1, "base": base}
        q_offset = idx
    elif prefill_cache:
        # populate the cache with the (last W) computed k/v; attention
        # below runs on the local k/v, not the buffer
        W = cache["k"].shape[1]
        kw = k[:, -W:] if W < Sk else k
        vw = v[:, -W:] if W < Sk else v
        pad = W - kw.shape[1]
        if pad > 0:
            kw = jnp.pad(kw, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vw = jnp.pad(vw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        base = jnp.asarray(max(0, Sk - W), jnp.int32)
        new_cache = {"k": kw.astype(cache["k"].dtype),
                     "v": vw.astype(cache["v"].dtype),
                     "idx": jnp.asarray(Sk, jnp.int32), "base": base}
    else:
        new_cache = None

    groups = H // KV
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)

    if decode:
        # decode attention: mask out unwritten cache slots
        W = k.shape[1]
        kpos = jnp.arange(W)
        valid = kpos < jnp.minimum(q_offset + 1, W)
        scale = 1.0 / math.sqrt(hd)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) \
            * scale
        logits = jnp.where(valid[None, None, None], logits, -1e30)
        pr = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", pr.astype(v.dtype), v)
    elif cfg.attn_impl == "naive" or cross:
        o = naive_attention(q, k, v, causal=cfg.causal and not cross,
                            window=cfg.window)
    elif cfg.attn_impl == "chunked":
        o = chunked_attention(q, k, v, causal=cfg.causal, window=cfg.window,
                              chunk_q=cfg.chunk_q, chunk_k=cfg.chunk_k)
    elif cfg.attn_impl == "kernel":
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(q, k, v, causal=cfg.causal,
                                   window=cfg.window)
    else:
        raise ValueError(cfg.attn_impl)
    out = dense(p["o"], o.reshape(B, S, H * hd))
    return out, new_cache


# --- FFN ---------------------------------------------------------------------

def swiglu_init(key, d_model, d_ff, dtype, use_bias=False):
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], d_model, d_ff, dtype, use_bias),
        "wg": dense_init(ks[1], d_model, d_ff, dtype, use_bias),
        "wo": dense_init(ks[2], d_ff, d_model, dtype, use_bias,
                         scale=1.0 / math.sqrt(d_ff)),
    }


def swiglu(p, x):
    return dense(p["wo"], jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x))
