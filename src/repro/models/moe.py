"""Mixture-of-Experts FFN with sort-free capacity dispatch (EP-shardable).

Dispatch is scatter/gather based (not the dense GShard one-hot einsum):
tokens are routed to per-expert capacity buffers via a cumulative-position
scatter; experts run as a batched einsum over the stacked expert weights
(sharded over the "model" axis = expert parallelism); results are gathered
back and combined with the top-k gates.  Overflowing tokens are dropped
(standard capacity-factor semantics), which keeps every shape static for
XLA.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from .layers import dense_init, truncated_normal


def moe_init(key, d_model: int, mcfg: MoEConfig, dtype):
    ks = jax.random.split(key, 5)
    E, F = mcfg.num_experts, mcfg.d_expert
    scale_in = 1.0 / math.sqrt(d_model)
    scale_out = 1.0 / math.sqrt(F)
    p = {
        "router": truncated_normal(ks[0], (d_model, E), jnp.float32,
                                   scale_in),
        "wi": truncated_normal(ks[1], (E, d_model, F), dtype, scale_in),
        "wg": truncated_normal(ks[2], (E, d_model, F), dtype, scale_in),
        "wo": truncated_normal(ks[3], (E, F, d_model), dtype, scale_out),
    }
    if mcfg.num_shared:
        from .layers import swiglu_init
        p["shared"] = swiglu_init(ks[4], d_model,
                                  mcfg.num_shared * F, dtype)
    return p


def moe_apply(p, x, mcfg: MoEConfig):
    """x: [B, S, D] -> (y, aux_loss)."""
    B, S, D = x.shape
    T = B * S
    E, K = mcfg.num_experts, mcfg.top_k
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"])            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)                      # [T, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    pe = probs.mean(axis=0)
    fe = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(fe * pe) * mcfg.router_aux_weight

    # capacity position of every (token, slot) within its expert; the
    # floor keeps tiny (decode) batches drop-free
    C = max(int(math.ceil(T * K * mcfg.capacity_factor / E)),
            min(T * K, 16))
    flat_e = eidx.reshape(-1)                                  # [T*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                       # [T*K, E]
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C

    tok = jnp.repeat(jnp.arange(T), K)
    e_safe = jnp.where(keep, flat_e, E)                        # E -> dropped
    slot = jnp.minimum(pos, C - 1)

    if mcfg.dispatch == "int8":
        # quantized all-to-all payload: each capacity slot holds exactly
        # one token, so scatter-add acts as scatter-set and int8 is exact
        # w.r.t. its own rounding.  Per-token scales ride along (4/D
        # relative overhead).
        amax = jnp.maximum(jnp.abs(xt.astype(jnp.float32)).max(-1), 1e-6)
        scl = amax / 127.0                                     # [T]
        xq = jnp.clip(jnp.round(xt.astype(jnp.float32) / scl[:, None]),
                      -127, 127).astype(jnp.int8)
        buf = jnp.zeros((E + 1, C, D), jnp.int8).at[e_safe, slot].add(
            xq[tok], mode="drop")
        sbuf = jnp.zeros((E + 1, C), jnp.float32).at[e_safe, slot].add(
            scl[tok], mode="drop")
        xe = (buf[:E].astype(jnp.float32)
              * sbuf[:E][..., None]).astype(x.dtype)
    else:
        buf = jnp.zeros((E + 1, C, D), x.dtype)
        buf = buf.at[e_safe, slot].add(xt[tok])
        xe = buf[:E]                                           # [E, C, D]

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])                # [E, C, D]

    if mcfg.dispatch == "int8":
        ymax = jnp.maximum(jnp.abs(ye.astype(jnp.float32)).max(-1), 1e-6)
        yscl = ymax / 127.0                                    # [E, C]
        yq = jnp.clip(jnp.round(ye.astype(jnp.float32) / yscl[..., None]),
                      -127, 127).astype(jnp.int8)
        yk = (yq[jnp.minimum(e_safe, E - 1), slot].astype(jnp.float32)
              * yscl[jnp.minimum(e_safe, E - 1), slot][:, None]
              ).astype(x.dtype)
    else:
        yk = ye[jnp.minimum(e_safe, E - 1), slot]
    yk = jnp.where(keep[:, None], yk, 0.0)
    y = (yk.reshape(T, K, D) * gates[..., None].astype(x.dtype)).sum(axis=1)

    if "shared" in p:
        from .layers import swiglu
        y = y + swiglu(p["shared"], xt)
    return y.reshape(B, S, D), aux
