"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

r_t = sigmoid(W_a x_t); i_t = sigmoid(W_x x_t)
a_t = a^(c * r_t)  with  a = sigmoid(Lambda)  (per-channel)
h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Sequence mode uses an associative scan (log-depth on TPU); decode mode is
the exact single-step recurrence.  The block wraps the LRU with the
Griffin recurrent-block structure: linear -> (branch x | branch gate),
causal conv1d on x, RG-LRU, gated output projection.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from .layers import dense_init, dense, truncated_normal
from .ssm import _causal_conv


def rglru_init(key, d_model: int, rcfg: RGLRUConfig, dtype):
    ks = jax.random.split(key, 6)
    r = rcfg.d_rnn or d_model
    return {
        "in_x": dense_init(ks[0], d_model, r, dtype),
        "in_gate": dense_init(ks[1], d_model, r, dtype),
        "conv_w": truncated_normal(ks[2], (rcfg.d_conv, r), dtype,
                                   1.0 / math.sqrt(rcfg.d_conv)),
        "conv_b": jnp.zeros((r,), dtype),
        "w_a": dense_init(ks[3], r, r, dtype),
        "w_x": dense_init(ks[4], r, r, dtype),
        # Lambda init so that a = sigmoid(Lambda) in [0.9, 0.999]
        "lam": jnp.asarray(
            jnp.log(jnp.linspace(0.9, 0.999, r) /
                    (1 - jnp.linspace(0.9, 0.999, r))), jnp.float32),
        "out": dense_init(ks[5], r, d_model, dtype,
                          scale=1.0 / math.sqrt(r)),
    }


def _lru_scan(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t via associative scan.  a, b: [B, S, R]."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_core(p, x, rcfg: RGLRUConfig, h0=None):
    """x: [B, S, R] (post-conv).  Returns h: [B, S, R]."""
    r = jax.nn.sigmoid(dense(p["w_a"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["w_x"], x).astype(jnp.float32))
    log_a = -rcfg.c * jax.nn.softplus(-p["lam"]) * r   # log(a^(c r)), a=sig
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * (i * x.astype(jnp.float32))
    h = _lru_scan(a, gated, h0)
    return h


def rglru_apply(p, x, rcfg: RGLRUConfig, cache=None):
    """Full Griffin recurrent block.  cache: dict(conv, h)."""
    B, S, D = x.shape
    xb = dense(p["in_x"], x)
    gate = dense(p["in_gate"], x)
    xc, new_conv = _causal_conv(
        xb, p["conv_w"], p["conv_b"],
        None if cache is None else cache["conv"])
    xc = jax.nn.silu(xc)
    if cache is None:
        h = rglru_core(p, xc, rcfg)
        new_cache = None
    else:
        h = rglru_core(p, xc, rcfg, h0=cache["h"])
        new_cache = {"conv": new_conv, "h": h[:, -1]}
    y = h.astype(x.dtype) * jax.nn.gelu(gate)
    out = dense(p["out"], y)
    return out, new_cache


def rglru_cache_init(batch, d_model, rcfg: RGLRUConfig, dtype):
    r = rcfg.d_rnn or d_model
    return {
        "conv": jnp.zeros((batch, rcfg.d_conv - 1, r), dtype),
        "h": jnp.zeros((batch, r), jnp.float32),
    }
