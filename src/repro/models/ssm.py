"""Mamba-2 SSD (state-space duality) sequence-mixing block.

Chunked algorithm of Dao & Gu (arXiv:2405.21060): intra-chunk quadratic
attention-like term + inter-chunk state recurrence.  The chunked form is
what the Pallas kernel (src/repro/kernels/ssd_scan) tiles for the MXU;
this module is the pure-jnp implementation used for training/serving and
as the kernel oracle.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from .layers import dense_init, dense, truncated_normal, rmsnorm_init, rmsnorm


def ssm_init(key, d_model: int, scfg: SSMConfig, dtype):
    ks = jax.random.split(key, 6)
    di = scfg.d_inner(d_model)
    H = scfg.num_heads(d_model)
    N = scfg.d_state
    conv_dim = di + 2 * N
    return {
        # projections: z (gate), x, B, C, dt
        "in_proj": dense_init(ks[0], d_model,
                              2 * di + 2 * N + H, dtype),
        "conv_w": truncated_normal(ks[1], (scfg.d_conv, conv_dim), dtype,
                                   1.0 / math.sqrt(scfg.d_conv)),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_init(di),
        "out_proj": dense_init(ks[2], di, d_model, dtype,
                               scale=1.0 / math.sqrt(di)),
    }


def _causal_conv(x, w, b, cache=None):
    """Depthwise causal conv1d.  x: [B, S, C]; w: [K, C]."""
    K = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
        new_cache = xp[:, -(K - 1):] if K > 1 else None
    else:
        xp = jnp.concatenate([cache, x], axis=1)
        new_cache = xp[:, -(K - 1):]
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    return out, new_cache


def _split_proj(proj, di, N, H):
    z = proj[..., :di]
    x = proj[..., di:2 * di]
    Bm = proj[..., 2 * di:2 * di + N]
    Cm = proj[..., 2 * di + N:2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N:]
    return z, x, Bm, Cm, dt


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, head_group: int = 8):
    """SSD over chunks, scanning chunk-by-chunk (the state pass) and
    processing heads in groups so the [B, L, L, Hg] decay tensor stays
    small (this is the memory layout the Pallas kernel tiles per-head).

    xh: [B, S, H, P]; dt: [B, S, H] (post-softplus); A: [H] (positive decay
    rate); Bm, Cm: [B, S, N].  Returns y: [B, S, H, P] and the final state
    [B, H, P, N].
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // L
    Hg = min(head_group, H)
    while H % Hg:
        Hg -= 1
    ng = H // Hg
    f32 = jnp.float32
    # [nc, B, L, ...] chunk-major for the scan
    xc = xh.reshape(Bsz, nc, L, ng, Hg, P).transpose(1, 0, 3, 2, 4, 5)
    dtc = dt.reshape(Bsz, nc, L, ng, Hg).transpose(1, 0, 3, 2, 4)
    Bc = Bm.reshape(Bsz, nc, L, N).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(Bsz, nc, L, N).transpose(1, 0, 2, 3)
    Ag = A.reshape(ng, Hg)
    mask = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(s_prev, inp):
        # s_prev: [B, ng, Hg, P, N]
        xck, dck, bck, cck = inp      # [B,ng,L,Hg,P], [B,ng,L,Hg], [B,L,N]x2
        la = (-Ag[None, :, None, :] * dck).astype(f32)        # [B,ng,L,Hg]
        cum = jnp.cumsum(la, axis=2)
        cb = jnp.einsum("bin,bjn->bij", cck.astype(f32),
                        bck.astype(f32))                      # [B,L,L]
        seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,ng,i,j,Hg]
        # mask BEFORE exp: upper-triangle seg is large-positive and would
        # overflow, poisoning gradients through the where (inf * 0 = nan)
        seg = jnp.where(mask[None, None, :, :, None], seg, -1e30)
        att = jnp.exp(seg)
        w = cb[:, None, :, :, None] * att * dck[:, :, None, :, :]
        y = jnp.einsum("bgijh,bgjhp->bgihp", w, xck.astype(f32))
        # inter-chunk: y_i += exp(cum_i) * C_i . S_prev
        y += jnp.einsum("bin,bghpn,bgih->bgihp", cck.astype(f32),
                        s_prev, jnp.exp(cum))
        # state update
        decay_tail = jnp.exp(cum[:, :, -1:, :] - cum) * dck   # [B,ng,L,Hg]
        s_new = s_prev * jnp.exp(cum[:, :, -1])[..., None, None] \
            + jnp.einsum("bgjh,bjn,bgjhp->bghpn", decay_tail,
                         bck.astype(f32), xck.astype(f32))
        return s_new, y

    s0 = jnp.zeros((Bsz, ng, Hg, P, N), f32)
    s_final, ys = jax.lax.scan(chunk_step, s0, (xc, dtc, Bc, Cc))
    # ys: [nc, B, ng, L, Hg, P] -> [B, S, H, P]
    y = ys.transpose(1, 0, 3, 2, 4, 5).reshape(Bsz, nc * L, H, P)
    return y[:, :S].astype(xh.dtype), \
        s_final.reshape(Bsz, H, P, N)


def ssm_apply(p, x, scfg: SSMConfig, d_model: int, cache=None):
    """Full mamba2 block.  cache: dict(conv, state, ...) for decode."""
    B, S, D = x.shape
    di = scfg.d_inner(d_model)
    H = scfg.num_heads(d_model)
    N = scfg.d_state
    P = scfg.head_dim
    proj = dense(p["in_proj"], x)
    z, xs, Bm, Cm, dt = _split_proj(proj, di, N, H)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out, new_conv = _causal_conv(
        conv_in, p["conv_w"], p["conv_b"],
        None if cache is None else cache["conv"])
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :di]
    Bm = conv_out[..., di:di + N]
    Cm = conv_out[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = jnp.exp(p["A_log"])
    xh = xs.reshape(B, S, H, P)

    if cache is None:
        y, state = ssd_chunked(xh, dt, A, Bm, Cm, scfg.chunk)
        new_cache = None
    elif S > 1:
        # prefill: chunked scan over the prompt, keep the final state
        y, state = ssd_chunked(xh, dt, A, Bm, Cm, scfg.chunk)
        new_cache = {"conv": new_conv, "state": state}
    else:
        # decode: exact single-step recurrence (S == 1)
        s_prev = cache["state"]                               # [B,H,P,N]
        a = jnp.exp(-A[None, :] * dt[:, 0])                   # [B,H]
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0],
                         Bm[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        state = s_prev * a[:, :, None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32),
                       state)[:, None].reshape(B, 1, H, P)
        y = y.astype(x.dtype)
        new_cache = {"conv": new_conv, "state": state}

    y = y + xh * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y)
    out = dense(p["out_proj"], y)
    if cache is None:
        return out, None
    return out, new_cache


def ssm_cache_init(batch, d_model, scfg: SSMConfig, dtype):
    di = scfg.d_inner(d_model)
    H = scfg.num_heads(d_model)
    conv_dim = di + 2 * scfg.d_state
    return {
        "conv": jnp.zeros((batch, scfg.d_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, H, scfg.head_dim, scfg.d_state),
                           jnp.float32),
    }
