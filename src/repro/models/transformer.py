"""Unified LM stack covering all assigned families.

Layers are grouped into repeating "pattern" super-blocks (e.g. recurrent-
gemma's (rglru, rglru, attn)) and stacked with `lax.scan` so compile time
stays flat in depth (94-layer qwen3 compiles as one block).  Heterogeneous
preludes (DeepSeekMoE's first dense layer) stay unscanned.

Modes:
  train    - full sequence, loss-ready logits
  prefill  - full sequence + returns populated KV/state caches
  decode   - single token step against caches
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import layers as L
from .layers import AttnConfig
from .moe import moe_init, moe_apply
from .rglru import rglru_apply, rglru_cache_init, rglru_init
from .ssm import ssm_apply, ssm_cache_init, ssm_init


def _attn_cfg(cfg: ModelConfig, impl: str, kind: str) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
        use_bias=cfg.use_bias, rope_theta=cfg.rope_theta,
        rope_frac=cfg.rope_frac, causal=(kind != "enc"),
        window=(cfg.local_window or None) if kind == "local" else None,
        attn_impl=impl)


def _layer_kind(cfg: ModelConfig, i: int) -> str:
    return cfg.block_pattern[i % len(cfg.block_pattern)]


def _ffn_kind(cfg: ModelConfig, i: int) -> str:
    if cfg.moe is not None and i >= cfg.first_dense:
        return "moe"
    return "dense" if cfg.d_ff else "none"


# --- single sub-block --------------------------------------------------------

def _sub_init(key, cfg: ModelConfig, kind: str, ffn: str, dtype,
              cross: bool = False):
    ks = jax.random.split(key, 6)
    p = {"norm1": L.rmsnorm_init(cfg.d_model)}
    if kind in ("attn", "local", "enc"):
        p["mix"] = L.attention_init(ks[0], _attn_cfg(cfg, "naive", kind),
                                    dtype)
    elif kind == "rglru":
        p["mix"] = rglru_init(ks[0], cfg.d_model, cfg.rglru, dtype)
    elif kind == "ssm":
        p["mix"] = ssm_init(ks[0], cfg.d_model, cfg.ssm, dtype)
    else:
        raise ValueError(kind)
    if cross:
        p["norm_x"] = L.rmsnorm_init(cfg.d_model)
        p["cross"] = L.attention_init(ks[1], _attn_cfg(cfg, "naive", "enc"),
                                      dtype)
    if ffn == "dense":
        p["norm2"] = L.rmsnorm_init(cfg.d_model)
        p["ffn"] = L.swiglu_init(ks[2], cfg.d_model, cfg.d_ff, dtype,
                                 cfg.use_bias)
    elif ffn == "moe":
        p["norm2"] = L.rmsnorm_init(cfg.d_model)
        p["ffn"] = moe_init(ks[2], cfg.d_model, cfg.moe, dtype)
    return p


def _sub_apply(p, cfg: ModelConfig, kind: str, ffn: str, impl: str,
               x, positions, inv_freq, cache, memory=None):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    acfg = _attn_cfg(cfg, impl, kind)
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local", "enc"):
        mixed, new_cache = L.attention_apply(p["mix"], acfg, h, positions,
                                             inv_freq, cache)
    elif kind == "rglru":
        mixed, new_cache = rglru_apply(p["mix"], h, cfg.rglru, cache)
    elif kind == "ssm":
        mixed, new_cache = ssm_apply(p["mix"], h, cfg.ssm, cfg.d_model,
                                     cache)
    x = x + mixed
    if "cross" in p and memory is not None:
        hx = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        xa, _ = L.attention_apply(p["cross"], acfg, hx, positions, inv_freq,
                                  None, kv_memory=memory)
        x = x + xa
    if ffn == "dense":
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + L.swiglu(p["ffn"], h2)
    elif ffn == "moe":
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, aux = moe_apply(p["ffn"], h2, cfg.moe)
        x = x + y
    return x, new_cache, aux


def _sub_cache_init(cfg: ModelConfig, kind: str, batch, max_len, dtype):
    if kind in ("attn", "local"):
        W = min(cfg.local_window, max_len) if kind == "local" \
            and cfg.local_window else max_len
        return {"k": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.hd), dtype),
                "v": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.hd), dtype),
                "idx": jnp.zeros((), jnp.int32),
                "base": jnp.zeros((), jnp.int32)}
    if kind == "rglru":
        return rglru_cache_init(batch, cfg.d_model, cfg.rglru, dtype)
    if kind == "ssm":
        return ssm_cache_init(batch, cfg.d_model, cfg.ssm, dtype)
    raise ValueError(kind)


# --- model -------------------------------------------------------------------

def _segments(cfg: ModelConfig):
    """(prelude_idx, scanned group count, pattern len, postlude_idx)."""
    P = len(cfg.block_pattern)
    pre = list(range(cfg.first_dense))
    rest = cfg.num_layers - cfg.first_dense
    groups = rest // P
    post = list(range(cfg.first_dense + groups * P, cfg.num_layers))
    return pre, groups, P, post


def init_params(key, cfg: ModelConfig):
    dtype = cfg.jdtype
    ks = jax.random.split(key, 8)
    scale = 1.0 / math.sqrt(cfg.d_model)
    params = {"embed": L.truncated_normal(
        ks[0], (cfg.vocab_size, cfg.d_model), dtype, 1.0)}
    pre, groups, P, post = _segments(cfg)
    cross = cfg.encoder_layers > 0

    def block_init(k, i):
        return _sub_init(k, cfg, _layer_kind(cfg, i), _ffn_kind(cfg, i),
                         dtype, cross=cross)

    params["prelude"] = [block_init(k, i) for i, k in
                         zip(pre, jax.random.split(ks[1], max(len(pre), 1)))]
    if groups:
        def group_init(k):
            kk = jax.random.split(k, P)
            return {f"sub{j}": block_init(kk[j], cfg.first_dense + j)
                    for j in range(P)}
        gkeys = jax.random.split(ks[2], groups)
        params["blocks"] = jax.vmap(group_init)(gkeys)
    params["postlude"] = [block_init(k, i) for i, k in
                          zip(post, jax.random.split(ks[3], max(len(post), 1)))]
    if cfg.encoder_layers:
        def enc_init(k):
            return _sub_init(k, cfg, "enc", "dense", dtype)
        ekeys = jax.random.split(ks[4], cfg.encoder_layers)
        params["encoder"] = jax.vmap(enc_init)(ekeys)
    params["final_norm"] = L.rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.truncated_normal(
            ks[5], (cfg.d_model, cfg.vocab_size), dtype, scale)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = cfg.jdtype
    pre, groups, P, post = _segments(cfg)
    cache = {}
    cache["prelude"] = [
        _sub_cache_init(cfg, _layer_kind(cfg, i), batch, max_len, dtype)
        for i in pre]
    if groups:
        def one(j):
            return _sub_cache_init(cfg, _layer_kind(cfg, cfg.first_dense + j),
                                   batch, max_len, dtype)
        cache["blocks"] = {
            f"sub{j}": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (groups,) + x.shape), one(j))
            for j in range(P)}
    cache["postlude"] = [
        _sub_cache_init(cfg, _layer_kind(cfg, i), batch, max_len, dtype)
        for i in post]
    return cache


def forward(params, cfg: ModelConfig, batch: dict, mode: str = "train",
            cache=None, attn_impl: str = "chunked", remat: bool = True,
            constrain=None):
    """batch: tokens [B, S] (+ prefix_embeds / src_embeds).  Returns
    (logits, new_cache, aux_loss)."""
    dtype = cfg.jdtype
    constrain = constrain or (lambda x, kind="resid": x)
    tokens = batch["tokens"]
    B, S_tok = tokens.shape
    x = params["embed"][tokens]
    if cfg.frontend and "prefix_embeds" in batch:
        x = jnp.concatenate([batch["prefix_embeds"].astype(dtype), x],
                            axis=1)
    x = constrain(x)
    B, S, D = x.shape
    if mode == "decode":
        # positions from the first attention cache idx (all layers agree)
        idx = _first_idx(cache)
        positions = idx + jnp.arange(S)[None, :].repeat(B, 0)
    else:
        positions = jnp.arange(S)[None, :].repeat(B, 0)
    inv_freq = L.rope_freqs(cfg.hd, cfg.rope_theta,
                            rot_dim=int(cfg.hd * cfg.rope_frac))

    memory = batch.get("memory")
    if cfg.encoder_layers and memory is None and "src_embeds" in batch:
        src = batch["src_embeds"].astype(dtype)
        mpos = jnp.arange(src.shape[1])[None, :].repeat(B, 0)

        def enc_one(h, p):
            h2, _, _ = _sub_apply(p, cfg, "enc", "dense", attn_impl, h,
                                  mpos, inv_freq, None)
            return constrain(h2), None
        memory, _ = jax.lax.scan(enc_one, src, params["encoder"])

    pre, groups, P, post = _segments(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {"prelude": [], "postlude": []} if cache is not None else None
    use_cache = cache is not None

    def run_sub(p, i, x, c):
        kind = _layer_kind(cfg, i)
        ffn = _ffn_kind(cfg, i)
        return _sub_apply(p, cfg, kind, ffn, attn_impl, x, positions,
                          inv_freq, c, memory)

    for j, i in enumerate(pre):
        c = cache["prelude"][j] if use_cache else None
        x, nc, aux = run_sub(params["prelude"][j], i, x, c)
        x = constrain(x)
        aux_total += aux
        if use_cache:
            new_cache["prelude"].append(nc)

    if groups:
        def group_fn(carry, inp):
            x, aux_acc = carry
            gp = inp["params"]
            gc = inp.get("cache")
            ncs = {}
            for j in range(P):
                i = cfg.first_dense + j
                c = gc[f"sub{j}"] if use_cache else None
                x, nc, aux = run_sub(gp[f"sub{j}"], i, x, c)
                x = constrain(x)
                aux_acc = aux_acc + aux
                if use_cache:
                    ncs[f"sub{j}"] = nc
            return (x, aux_acc), ncs if use_cache else None

        fn = group_fn
        if remat and mode == "train":
            fn = jax.checkpoint(group_fn, prevent_cse=False)
        xs = {"params": params["blocks"]}
        if use_cache:
            xs["cache"] = cache["blocks"]
        (x, aux_total), blk_caches = jax.lax.scan(fn, (x, aux_total), xs)
        if use_cache:
            new_cache["blocks"] = blk_caches

    for j, i in enumerate(post):
        c = cache["postlude"][j] if use_cache else None
        x, nc, aux = run_sub(params["postlude"][j], i, x, c)
        x = constrain(x)
        aux_total += aux
        if use_cache:
            new_cache["postlude"].append(nc)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.frontend and "prefix_embeds" in batch and mode != "decode":
        x = x[:, -S_tok:]  # loss/logits only over the token positions
    x = constrain(x, "gather")  # un-shard seq before the vocab matmul
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    logits = constrain(logits, "logits")
    return logits, new_cache, aux_total


def _first_idx(cache):
    for part in ("prelude", "postlude"):
        for c in cache[part]:
            if "idx" in c:
                return c["idx"]
    if "blocks" in cache:
        for j in range(16):
            sub = cache["blocks"].get(f"sub{j}")
            if sub is None:
                break
            if "idx" in sub:
                return sub["idx"][0]
    return jnp.zeros((), jnp.int32)


def lm_loss(params, cfg: ModelConfig, batch: dict, attn_impl="chunked",
            remat=True, constrain=None):
    """Cross entropy over vocab-sharded logits (P(dp, None, "model"));
    the fp32 exp/sum fuses into the reduction so the only materialized
    [B, S, V] tensor is the bf16 logits, sharded dp x model."""
    logits, _, aux = forward(params, cfg, batch, "train",
                             attn_impl=attn_impl, remat=remat,
                             constrain=constrain)
    labels = batch["labels"]
    lg = logits.astype(jnp.float32)
    m = lg.max(axis=-1, keepdims=True)
    lse = jnp.log(jnp.exp(lg - m).sum(axis=-1)) + m[..., 0]
    ll = jnp.take_along_axis(lg, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = ((lse - ll) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll + aux, {"nll": nll, "aux": aux}
