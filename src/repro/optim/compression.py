"""Gradient compression with error feedback for the scarce cross-pod tier.

The switch-less Dragonfly's global (inter-W-group) links are the lowest
bandwidth tier (Sec. III: off-wafer << on-wafer); when gradients must
cross pods we quantize them to int8 with a per-tensor scale and carry the
quantization error into the next step (EF-SGD style), which keeps
convergence while cutting cross-pod bytes 4x vs fp32 / 2x vs bf16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(x):
    """fp -> (int8, scale).  Symmetric per-tensor quantization."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(xf).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, err):
    """Apply error feedback then quantize every leaf.

    Returns (quantized tree of (q, scale), new error tree)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress(corrected)
        back = decompress(q, s)
        return (q, s), corrected - back

    out = jax.tree.map(one, grads, err)
    qt = jax.tree.map(lambda t: t[0], out,
                      is_leaf=lambda x: isinstance(x, tuple)
                      and len(x) == 2 and not isinstance(x[0], dict))
    ne = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple)
                      and len(x) == 2 and not isinstance(x[0], dict))
    return qt, ne


def decompress_tree(qt):
    return jax.tree.map(
        lambda t: decompress(*t),
        qt, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)


def pod_compressed_psum(grads, err, pod_axis: str = "pod"):
    """Inside shard_map: full-precision psum within the pod ("data" axis
    handled by pjit), int8+EF psum across pods.

    Used by the train loop's manual-collective path; the pjit path prices
    the same traffic via the fabric cost model instead."""
    qt, new_err = ef_compress_tree(grads, err)

    def allreduce_one(t):
        q, s = t
        # sum int32 across pods, rescale by the max scale (conservative)
        qs = jax.lax.psum(q.astype(jnp.int32), pod_axis)
        ss = jax.lax.pmax(s, pod_axis)
        return qs.astype(jnp.float32) * ss

    summed = jax.tree.map(
        allreduce_one, qt,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)
    return summed, new_err
