"""AdamW with WSD / cosine schedules, gradient clipping, bf16 params with
fp32 master copies (ZeRO-sharded via runtime/sharding.opt_state_specs),
and optional gradient compression on the scarce cross-pod tier.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    decay_frac: float = 0.1        # WSD: last 10% of steps decay
    schedule: str = "cosine"       # "cosine" | "wsd" | "const"
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_pod_grads: bool = False


def schedule_lr(cfg: OptConfig, step):
    """Learning-rate schedules; WSD (warmup-stable-decay) is the MiniCPM
    schedule [arXiv:2404.06395]."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") \
        else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    if cfg.schedule == "cosine":
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) \
            * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "wsd":
        decay_start = 1.0 - cfg.decay_frac
        d = jnp.clip((t - decay_start) / cfg.decay_frac, 0, 1)
        frac = 1.0 - (1 - cfg.min_lr_frac) * d
    else:
        frac = jnp.ones_like(t)
    return cfg.lr * warm * frac


def init_opt_state(params):
    """fp32 master weights + first/second moments.  The master copy is a
    real copy even for fp32 leaves (donation safety)."""
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, grads, opt_state, params):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule_lr(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        w = w - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                      + cfg.weight_decay * w * (w.ndim > 1))
        return m, v, w

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"],
                       opt_state["master"])
    m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    w = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda wm, p: wm.astype(p.dtype), w, params)
    new_state = {"master": w, "m": m, "v": v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
