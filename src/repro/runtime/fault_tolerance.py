"""Fault tolerance: failure injection, checkpoint/restart, elastic
re-meshing, straggler detection.

The container has no real multi-host runtime, so node failures are
*simulated* (a configurable injector raises during the step loop) — but
the recovery code path is the real one a launcher would take: abandon the
step, rebuild the mesh over the surviving devices, restore the newest
snapshot (resharding onto the new mesh), fast-forward the data stream and
resume.  Straggler mitigation monitors per-step wall time against a
robust EMA and records mitigation actions (on a real cluster: re-dispatch
to a hot spare / exclude from the next allocation)."""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field

import numpy as np
import jax

from .trainer import Trainer, TrainSetup


class SimulatedNodeFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raises SimulatedNodeFailure at the configured global steps."""
    fail_at: tuple = ()
    fired: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedNodeFailure(f"injected node failure at {step}")


@dataclass
class StragglerMonitor:
    """Flags steps slower than factor x the EMA and logs the mitigation the
    production launcher would take."""
    factor: float = 3.0
    alpha: float = 0.2
    ema: float | None = None
    events: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = self.ema is not None and dt > self.factor * self.ema
        if is_straggler:
            self.events.append(
                {"step": step, "dt": dt, "ema": self.ema,
                 "action": "redispatch-to-backup"})
        else:
            self.ema = dt if self.ema is None else \
                (1 - self.alpha) * self.ema + self.alpha * dt
        return is_straggler


class FaultTolerantLoop:
    """Wraps a Trainer with injection, restart and straggler handling."""

    def __init__(self, trainer: Trainer, injector: FailureInjector,
                 monitor: StragglerMonitor | None = None,
                 max_restarts: int = 8):
        self.trainer = trainer
        self.injector = injector
        self.monitor = monitor or StragglerMonitor()
        self.max_restarts = max_restarts
        self.restarts = 0
        self.log = []

    def run(self, total_steps: int):
        while self.trainer.step < total_steps:
            remaining = total_steps - self.trainer.step
            try:
                self._run_segment(remaining)
            except SimulatedNodeFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                self.log.append({"event": "failure", "step":
                                 self.trainer.step, "msg": str(e)})
                self._recover()
        return self.trainer.history

    def _run_segment(self, steps: int):
        def on_step(step, metrics, dt):
            self.monitor.observe(step, dt)
            self.injector.check(step)

        self.trainer.run(steps, on_step=on_step)

    def _recover(self):
        """Restore from the newest snapshot and resume (re-mesh hook)."""
        ck = self.trainer.ckpt
        if ck is None or ck.latest_step() is None:
            raise RuntimeError("failure before the first checkpoint")
        step = self.trainer.restore()
        self.log.append({"event": "restart", "resumed_step": step,
                         "restarts": self.restarts})
