"""Collective-traffic extraction from compiled/optimized HLO text.

cost_analysis() has FLOPs and HBM bytes but not collective bytes; we
regex every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op, sum operand sizes, and attribute each op to a mesh
axis by the stride pattern of its replica groups."""
from __future__ import annotations

import re
from collections import defaultdict

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    bpe = _DTYPE_BYTES.get(dtype)
    if bpe is None:
        return 0
    if not dims:
        return bpe
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * bpe


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s")
_OPERAND_RE = re.compile(r"%[\w.\-]+")


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO result type (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def _build_def_table(hlo_text: str) -> dict:
    """%name -> result bytes (operand shapes are not printed inline in
    optimized HLO, so operand sizes are resolved through definitions)."""
    table = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            table[m.group(1)] = _type_bytes(m.group(2))
    return table


def _operand_bytes(line: str, def_table: dict) -> int:
    """Sum sizes of the operands of an HLO op line."""
    if "(" not in line:
        return 0
    args = line[line.index("("):]
    depth = 0
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args = args[:i]
                break
    total = 0
    # inline shapes (older printers) ...
    for m in _SHAPE_RE.finditer(args):
        total += _shape_bytes(m.group(1), m.group(2))
    if total:
        return total
    # ... otherwise resolve through the def table
    for m in _OPERAND_RE.finditer(args):
        total += def_table.get(m.group(0), 0)
    return total


def _classify_groups(line: str, axis_sizes: dict) -> str:
    """Map a collective's replica groups to a mesh-axis label.

    axis_sizes: ordered {axis: size} major-to-minor, e.g.
    {"pod": 2, "data": 16, "model": 16} -> device id =
    pod*256 + data*16 + model."""
    names = list(axis_sizes)
    sizes = [axis_sizes[a] for a in names]
    strides = {}
    s = 1
    for a, sz in zip(reversed(names), reversed(sizes)):
        strides[a] = s
        s *= sz

    def classify(group):
        if len(group) <= 1:
            return "none"
        d = group[1] - group[0]
        matched = [a for a in names if strides[a] == d
                   and len(group) <= axis_sizes[a] * (
                       strides[a] and 1)]
        # single-axis?
        for a in names:
            if d == strides[a] and len(group) == axis_sizes[a] and \
               all(group[i + 1] - group[i] == d
                   for i in range(len(group) - 1)):
                return a
        # combined axes (e.g. data+model = contiguous block)
        span = group[-1] - group[0] + 1
        if span == len(group):
            combo = []
            prod = 1
            for a in reversed(names):
                combo.append(a)
                prod *= axis_sizes[a]
                if prod == len(group):
                    return "+".join(reversed(combo))
        return "mixed"

    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        ids = [int(x) for x in first.split(",") if x]
        return classify(ids)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")] if m.group(4) \
            else list(range(len(dims)))
        arr = np.arange(int(np.prod(dims))).reshape(dims).transpose(perm)
        ids = arr.reshape(ngroups, gsize)[0].tolist()
        return classify(sorted(ids))
    if _PAIRS_RE.search(line):
        m2 = _PAIRS_RE.search(line)
        first = m2.group(1).split("},{")[0].strip("{}")
        ids = [int(x) for x in first.split(",") if x]
        if len(ids) == 2:
            d = abs(ids[1] - ids[0])
            for a, st in strides.items():
                if d == st:
                    return a
        return "mixed"
    return "unknown"


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def collective_bytes(hlo_text: str, axis_sizes: dict,
                     loop_trips: tuple = ()) -> dict:
    """Sum operand sizes of every collective in the (per-device SPMD
    partitioned) HLO.

    Collectives whose op_name metadata places them inside while bodies
    (jax scans) are scaled by the caller-supplied loop trip counts: the
    i-th "while/body" nesting level multiplies by loop_trips[i] (layers
    scan, then inner chunk scans).  Missing levels default to 1, so with
    loop_trips=() this degrades to a static count.
    """
    by_op = defaultdict(int)
    by_axis = defaultdict(int)
    ops = []
    def_table = _build_def_table(hlo_text)
    pat = re.compile(r"=\s+[\w\[\],{}\s]*?\b(" + "|".join(
        COLLECTIVE_OPS) + r")(?:-start|-done)?\(")
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = pat.search(ls)
        if not m:
            continue
        if "-done(" in ls:
            continue  # paired with -start; count once
        op = m.group(1)
        nbytes = _operand_bytes(ls, def_table)
        nm = _OPNAME_RE.search(ls)
        depth = nm.group(1).count("while/body") if nm else 0
        mult = 1
        for i in range(min(depth, len(loop_trips))):
            mult *= max(int(loop_trips[i]), 1)
        nbytes *= mult
        axis = _classify_groups(ls, axis_sizes)
        by_op[op] += nbytes
        by_axis[axis] += nbytes
        ops.append({"op": op, "bytes": nbytes, "axis": axis, "mult": mult})
    return {"by_op": dict(by_op), "by_axis": dict(by_axis), "ops": ops}
