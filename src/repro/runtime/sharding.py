"""Sharding rules: DP / TP (Megatron-style) / EP / FSDP via PartitionSpecs.

Axis->fabric-tier mapping (the paper's Eq. (3) load-balance transposed to
ML collectives, DESIGN.md Sec. 2):
  "model" -> on-wafer C-group links  (TP/EP collectives, highest volume)
  "data"  -> intra-W-group local links (gradient reduction)
  "pod"   -> global links (rare cross-pod sync, compressed)
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def param_spec(path: tuple, shape: tuple, mesh: Mesh,
               fsdp_threshold: int = 1 << 22) -> P:
    """Sharding rule for one parameter.

    path: tuple of pytree keys (strings).  Stacked scan blocks carry a
    leading group dim which is never sharded.
    """
    mp = _axis_size(mesh, "model")
    dsize = _axis_size(mesh, "data")
    name = "/".join(str(k) for k in path)
    nd = len(shape)
    spec = [None] * nd

    # detect the stacked-groups leading axis: blocks/* params have one more
    # dim than their logical shape; we simply never shard dim 0 of blocks.
    off = 1 if name.startswith("blocks/") or name.startswith("encoder/") \
        else 0

    def logical(i):
        return off + i

    ls = shape[off:]
    lnd = len(ls)

    if name.endswith("embed") or "lm_head" in name:
        # vocab-parallel embedding / output head
        vdim = 0 if name.endswith("embed") else 1
        if _div(ls[vdim], mp):
            spec[logical(vdim)] = "model"
        other = 1 - vdim
        if _div(ls[other], dsize) and np.prod(ls) > fsdp_threshold:
            spec[logical(other)] = "data"
    elif "router" in name:
        pass  # replicated
    elif lnd == 3:  # stacked experts [E, din, dout]
        if _div(ls[0], mp):
            spec[logical(0)] = "model"      # expert parallelism
            if _div(ls[1], dsize) and np.prod(ls) > fsdp_threshold:
                spec[logical(1)] = "data"   # FSDP within expert
        elif _div(ls[2], mp):
            spec[logical(2)] = "model"
    elif lnd == 2:
        din, dout = ls
        col_parallel = any(s in name for s in (
            "/q/", "/k/", "/v/", "wi", "wg", "in_x", "in_gate", "in_proj",
            "w_a", "w_x"))
        row_parallel = any(s in name for s in (
            "/o/", "wo", "out", "out_proj"))
        if col_parallel and _div(dout, mp):
            spec[logical(1)] = "model"
            if _div(din, dsize) and np.prod(ls) > fsdp_threshold:
                spec[logical(0)] = "data"
        elif row_parallel and _div(din, mp):
            spec[logical(0)] = "model"
            if _div(dout, dsize) and np.prod(ls) > fsdp_threshold:
                spec[logical(1)] = "data"
        elif _div(dout, mp):
            spec[logical(1)] = "model"
        elif _div(din, mp):
            spec[logical(0)] = "model"
    # 1D (biases, norm scales, A_log, conv) stay replicated
    return P(*spec)


def tree_param_specs(params_or_shapes, mesh: Mesh, **kw):
    """PartitionSpec pytree for a parameter pytree (arrays or
    ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_or_shapes)

    def key_name(k):
        if hasattr(k, "key"):
            return str(k.key)
        if hasattr(k, "idx"):
            return str(k.idx)
        return str(k)

    specs = []
    for path, leaf in flat:
        names = tuple(key_name(k) for k in path)
        specs.append(param_spec(names, leaf.shape, mesh, **kw))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_state_specs(param_specs_tree, params_or_shapes, mesh: Mesh):
    """ZeRO: optimizer moments reuse the param spec and additionally shard
    the first unsharded divisible dim over "data"."""
    dsize = _axis_size(mesh, "data")

    def extend(spec, leaf):
        parts = list(spec)
        parts += [None] * (len(leaf.shape) - len(parts))
        if "data" in parts:
            return P(*parts)
        for i, (p, s) in enumerate(zip(parts, leaf.shape)):
            if p is None and _div(s, dsize) and s >= dsize:
                parts[i] = "data"
                return P(*parts)
        return P(*parts)

    return jax.tree.map(extend, param_specs_tree, params_or_shapes)


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        n *= _axis_size(mesh, a)
    return n


def batch_specs(batch_shapes, mesh: Mesh) -> dict:
    dp = dp_axes(mesh)
    n = _dp_size(mesh)
    out = {}
    for k, v in batch_shapes.items():
        lead = dp if v.shape and _div(v.shape[0], n) else None
        spec = [lead] + [None] * (len(v.shape) - 1)
        # batch-1 long-context: shard the sequence dim over data instead
        if lead is None and len(v.shape) >= 2 and _div(v.shape[1], n) \
                and v.shape[1] >= n:
            spec[1] = dp
        out[k] = P(*spec)
    return out


def cache_specs(cache, mesh: Mesh):
    """KV/state caches: batch-sharded; KV heads sharded over model when
    divisible."""
    dp = dp_axes(mesh)
    mp = _axis_size(mesh, "model")

    n = _dp_size(mesh)

    def one(path, leaf):
        shape = leaf.shape
        names = [getattr(k, "key", None) for k in path]
        stacked = "blocks" in names
        off = 1 if stacked else 0
        spec = [None] * len(shape)
        if len(shape) - off == 0:
            return P(*spec)
        if len(shape) - off >= 1 and _div(shape[off], n):
            spec[off] = dp          # batch dim
        # kv cache [B, W, KV, hd]: shard KV heads over model if divisible,
        # otherwise shard the window (sequence) dim — ring-attention-style
        # sequence parallelism for long caches
        if len(shape) - off == 4:
            if _div(shape[off + 2], mp):
                spec[off + 2] = "model"
            elif _div(shape[off + 1], mp) and shape[off + 1] >= 4 * mp:
                spec[off + 1] = "model"
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    # scalars (idx) replicated
    specs = []
    for path, leaf in flat:
        if leaf.ndim == 0 or (leaf.ndim == 1 and "blocks" in
                              [getattr(k, "key", None) for k in path]):
            specs.append(P(*([None] * leaf.ndim)))
        else:
            specs.append(one(path, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def make_constrain(mesh: Mesh, seq_parallel: bool = True):
    """Activation constraint closure passed into the model: batch over the
    data axes and — Megatron sequence parallelism — the sequence dim over
    "model" for the residual stream (GSPMD inserts the all-gather /
    reduce-scatter pairs around attention/FFN, cutting per-device
    activation memory by the TP degree)."""
    dp = dp_axes(mesh)
    mp = _axis_size(mesh, "model")

    def constrain(x, kind: str = "resid"):
        if x.ndim != 3:
            return x
        if kind == "logits":
            spec = P(dp, None, "model") if x.shape[2] % mp == 0 \
                else P(dp, None, None)
        elif kind == "gather":      # replicate features, batch-shard only
            spec = P(dp, None, None)
        elif seq_parallel and x.shape[1] % mp == 0 and x.shape[1] >= mp:
            spec = P(dp, "model", None)
        else:
            spec = P(dp, None, None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return constrain


def shardings(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
