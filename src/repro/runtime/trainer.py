"""jit-compiled train/prefill/decode steps with full sharding, plus the
host-side training loop used by the launcher and the fault-tolerance
harness."""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import transformer as TF
from repro.optim.optimizer import OptConfig, adamw_update, init_opt_state
from repro.runtime import sharding as SH


@dataclass(frozen=True)
class TrainSetup:
    model: ModelConfig
    opt: OptConfig
    attn_impl: str = "chunked"
    remat: bool = True
    # gradient accumulation: split the global batch into this many
    # microbatches (scan) — divides activation memory by the same factor
    microbatch: int = 1


def make_train_step(setup: TrainSetup, mesh):
    cfg = setup.model
    constrain = SH.make_constrain(mesh)

    def loss_fn(p, batch):
        return TF.lm_loss(p, cfg, batch, attn_impl=setup.attn_impl,
                          remat=setup.remat, constrain=constrain)

    def train_step(params, opt_state, batch):
        k = setup.microbatch
        if k <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (l, met), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / k, g_acc, g)
                return (g_acc, l_acc + l / k), met

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), mets = jax.lax.scan(acc_fn, (g0, 0.0), micro)
            metrics = jax.tree.map(lambda m: m.mean(), mets)
        new_params, new_opt, om = adamw_update(setup.opt, grads, opt_state,
                                               params)
        return new_params, new_opt, dict(loss=loss, **metrics, **om)

    return train_step


def make_prefill_step(setup: TrainSetup, mesh):
    cfg = setup.model
    constrain = SH.make_constrain(mesh)

    def prefill_step(params, batch, cache):
        logits, new_cache, _ = TF.forward(
            params, cfg, batch, mode="prefill", cache=cache,
            attn_impl=setup.attn_impl, remat=False, constrain=constrain)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return prefill_step


def make_decode_step(setup: TrainSetup, mesh):
    cfg = setup.model

    def decode_step(params, batch, cache):
        logits, new_cache, _ = TF.forward(
            params, cfg, batch, mode="decode", cache=cache,
            attn_impl="naive", remat=False)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return decode_step


def jit_train_step(setup: TrainSetup, mesh, batch_shapes):
    """Fully sharded jitted train step (params/opt donated)."""
    pspec_tree = None

    def build(params_shapes, opt_shapes):
        nonlocal pspec_tree
        pspecs = SH.tree_param_specs(params_shapes, mesh)
        ospecs = {
            "master": SH.opt_state_specs(pspecs, params_shapes, mesh),
            "m": SH.opt_state_specs(pspecs, params_shapes, mesh),
            "v": SH.opt_state_specs(pspecs, params_shapes, mesh),
            "step": P(),
        }
        bspecs = SH.batch_specs(batch_shapes, mesh)
        pspec_tree = (pspecs, ospecs, bspecs)
        fn = make_train_step(setup, mesh)
        return jax.jit(
            fn,
            in_shardings=(SH.shardings(pspecs, mesh),
                          SH.shardings(ospecs, mesh),
                          SH.shardings(bspecs, mesh)),
            out_shardings=(SH.shardings(pspecs, mesh),
                           SH.shardings(ospecs, mesh), None),
            donate_argnums=(0, 1))

    return build


class Trainer:
    """Host loop: data -> jitted step -> metrics/checkpoints."""

    def __init__(self, setup: TrainSetup, mesh, data_it, checkpointer=None,
                 ckpt_every: int = 0, seed: int = 0):
        self.setup = setup
        self.mesh = mesh
        self.data = data_it
        self.ckpt = checkpointer
        self.ckpt_every = ckpt_every
        cfg = setup.model
        key = jax.random.PRNGKey(seed)
        with jax.default_device(jax.devices()[0]):
            params = TF.init_params(key, cfg)
        pspecs = SH.tree_param_specs(params, mesh)
        self.params = jax.device_put(params, SH.shardings(pspecs, mesh))
        opt = init_opt_state(self.params)
        ospecs = {
            "master": SH.opt_state_specs(pspecs, params, mesh),
            "m": SH.opt_state_specs(pspecs, params, mesh),
            "v": SH.opt_state_specs(pspecs, params, mesh),
            "step": P(),
        }
        self.opt_state = jax.device_put(opt, SH.shardings(ospecs, mesh))
        self.pspecs, self.ospecs = pspecs, ospecs
        self._jit = None
        self.step = 0
        self.history = []
        self.step_times = []

    def _ensure_jit(self, batch):
        if self._jit is None:
            bspecs = SH.batch_specs(batch, self.mesh)
            fn = make_train_step(self.setup, self.mesh)
            self._jit = jax.jit(
                fn,
                in_shardings=(SH.shardings(self.pspecs, self.mesh),
                              SH.shardings(self.ospecs, self.mesh),
                              SH.shardings(bspecs, self.mesh)),
                donate_argnums=(0, 1))

    def run(self, steps: int, on_step=None):
        for _ in range(steps):
            batch = next(self.data)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self._ensure_jit(batch)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._jit(
                self.params, self.opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.step += 1
            self.step_times.append(dt)
            self.history.append(metrics)
            if on_step:
                on_step(self.step, metrics, dt)
            if (self.ckpt is not None and self.ckpt_every
                    and self.step % self.ckpt_every == 0):
                self.save()
        return self.history

    def save(self, blocking: bool = True):
        state = {"params": self.params, "opt": self.opt_state,
                 "data": {"step": jnp.asarray(self.data.state()["step"])}}
        self.ckpt.save(self.step, state, blocking=blocking)

    def restore(self, step=None):
        tmpl = {"params": self.params, "opt": self.opt_state,
                "data": {"step": jnp.zeros((), jnp.int32)}}
        shardings = {"params": SH.shardings(self.pspecs, self.mesh),
                     "opt": SH.shardings(self.ospecs, self.mesh),
                     "data": {"step": None}}
        state, ck_step = self.ckpt.restore(tmpl, step, shardings=None)
        self.params = jax.device_put(state["params"],
                                     SH.shardings(self.pspecs, self.mesh))
        self.opt_state = jax.device_put(state["opt"],
                                        SH.shardings(self.ospecs, self.mesh))
        self.data.restore({"step": int(state["data"]["step"])})
        self.step = ck_step
        return ck_step
