"""Shared test helpers.

`conservation_trace` is the reusable packet-conservation invariant
check of the reliability lifecycle: it steps any configured engine impl
(jnp / fused / compact) cycle by cycle and asserts the exact invariant

    generated == delivered + dropped + reaped + in-flight

at EVERY cycle — across fault-schedule epoch boundaries (grow and
repair shrinks) and with the router-death reaper on (`reaped` is the
reaper's cumulative kill count, 0 when it is off; `stranded` is a
gauge over the in-flight population, never part of the sum).  Tests
import it via `from conftest import conservation_trace`.
"""
import numpy as np

import jax
import jax.numpy as jnp


def conservation_trace(net, cfg, pattern=None, faults=None, *, cycles,
                       rate, stop_inject_at=None, prng_seed=3):
    """Run `cycles` single engine steps of `cfg.step_impl` and assert
    exact conservation at every cycle.  Injection runs at `rate` until
    `stop_inject_at` (None = always on), then at 0 — so drain behavior
    is checkable from the returned trace.  Returns one dict per cycle
    with the counters (generated / delivered / dropped / reaped), the
    `stranded` gauge, and the in-flight population."""
    from repro.core import traffic as TR
    from repro.core.engine import build_lane, make_state, make_step

    if pattern is None:
        pattern = TR.uniform(net)
    step, consts = make_step(net, cfg, pattern)
    jstep = jax.jit(step)
    fl = build_lane(net, cfg, faults)
    state = make_state(net, cfg, consts["NV"])
    key = jax.random.PRNGKey(prng_seed)
    trace = []
    for t in range(cycles):
        key, sub = jax.random.split(key)
        r = rate if (stop_inject_at is None or t < stop_inject_at) else 0.0
        state, _ = jstep(state, (jnp.int32(t), sub, jnp.float32(r), fl))
        s = jax.tree.map(np.asarray, state)
        rec = dict(
            t=t,
            generated=int(s.stats.generated),
            delivered=int(s.stats.delivered),
            dropped=int(s.stats.dropped),
            reaped=int(s.stats.reaped),
            stranded=int(s.stats.stranded),
            inflight=int(s.b_count.sum()) + int(s.s_count.sum()))
        assert rec["generated"] == (rec["delivered"] + rec["dropped"]
                                    + rec["reaped"] + rec["inflight"]), \
            f"conservation leak at cycle {t} ({cfg.step_impl}): {rec}"
        trace.append(rec)
    return trace
