"""Lint fixture: REPRO004 violation (never imported)."""
import sys

sys.path.insert(0, "..")                                    # REPRO004
