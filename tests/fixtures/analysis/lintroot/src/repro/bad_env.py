"""Lint fixture: REPRO001 + REPRO002 violations (never imported)."""
import os

SHARDS = int(os.environ.get("REPRO_FIXTURE_SHARDS", "1"))   # REPRO002
WORK = int(os.getenv("REPRO_FIXTURE_WORK", "0"))            # REPRO002
HOME = os.environ["HOME"]                                   # REPRO002


def is_global(ch_type):
    return ch_type == 2                                     # REPRO001
