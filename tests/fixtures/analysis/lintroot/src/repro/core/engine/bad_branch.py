"""Lint fixture: REPRO003 violation (never imported)."""
import jax.numpy as jnp


def drain(state, occupancy):
    if jnp.sum(occupancy) > 0:                              # REPRO003
        return state
    while jnp.any(occupancy):                               # REPRO003
        occupancy = occupancy - 1
    return state
