"""Lint fixture: a serve module reading the environment directly
instead of through `repro.env_int` (never imported).  Proves REPRO002
covers the `exp/serve` tree — the service's `REPRO_SERVE_WINDOW` /
`REPRO_SERVE_PACK` knobs must stay auditable in `src/repro/__init__`.
"""
import os

WINDOW = int(os.environ.get("REPRO_SERVE_WINDOW", "128"))   # REPRO002
