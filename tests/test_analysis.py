"""Tests for `repro.analysis`: the static passes, the negative
fixtures (each must be flagged), and the grant_form surfacing."""
from pathlib import Path

import jax.numpy as jnp
import pytest

from repro.analysis import Allowlist, Report
from repro.analysis.check import build_parser, main, repo_root, run
from repro.analysis.compilepass import check_scenario as compile_scenario
from repro.analysis.jaxprpass import (TRACE_TOPO, check_combo,
                                      check_kernel_batch_purity)
from repro.analysis.lint import run_lint
from repro.analysis.specpass import (check_scenario, check_spec_file,
                                     grant_form)
from repro.core.simulator import SimConfig
from repro.exp.registry import list_scenarios

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------

def test_lint_fixture_flags_every_rule():
    findings = run_lint(FIXTURES / "lintroot")
    rules = {f.rule for f in findings if f.severity == "error"}
    assert {"REPRO001", "REPRO002", "REPRO003", "REPRO004"} <= rules


def test_lint_covers_serve_tree():
    """REPRO002 fires on `exp/serve` modules: the serve knobs
    (REPRO_SERVE_WINDOW/PACK) must route through repro.env_int."""
    findings = run_lint(FIXTURES / "lintroot")
    assert any(f.rule == "REPRO002"
               and "exp/serve/bad_env.py" in f.location
               for f in findings if f.severity == "error")


def test_lint_repo_clean_under_allowlist():
    """The satellite contract: zero violations outside the documented
    allowlist on the real tree."""
    report = Report()
    report.extend(run_lint(repo_root()))
    report.apply_allowlist(Allowlist())
    assert not report.failed, report.render()
    # the only standing waiver is the frozen seed baseline
    assert all("seed_reference" in f.location for f in report.findings
               if f.suppressed)


def test_lint_without_allowlist_flags_seed_reference():
    report = Report()
    report.extend(run_lint(repo_root()))
    assert any(f.rule == "REPRO001" and "seed_reference" in f.location
               for f in report.gating)


# ---------------------------------------------------------------------------
# spec pass
# ---------------------------------------------------------------------------

def test_spec_pass_smoke_scenarios_clean():
    report = Report()
    for name in ("smoke", "smoke_fused", "smoke_faults",
                 "smoke_warm_faults"):
        check_scenario(name, report)
    assert not report.failed, report.render()
    assert any(f.rule == "SPEC_CDG" for f in report.findings)


def test_overflow_fixture_warns_two_pass_fallback():
    report = Report()
    check_spec_file(str(FIXTURES / "overflow_spec.json"), report)
    assert report.failed
    assert any(f.rule == "SPEC_GRANT_OVERFLOW" and f.severity == "warning"
               for f in report.gating)


def test_stranding_fixture_rejected_as_invalid():
    report = Report()
    check_spec_file(str(FIXTURES / "stranding_spec.json"), report)
    assert report.failed
    [f] = report.gating
    assert f.rule == "SPEC_INVALID"
    assert "never activate" in f.message


def test_unreadable_spec_file_is_invalid(tmp_path):
    p = tmp_path / "broken.json"
    p.write_text("{not json")
    report = Report()
    check_spec_file(str(p), report)
    assert any(f.rule == "SPEC_INVALID" for f in report.gating)


def test_grant_form_interval_analysis():
    net = TRACE_TOPO.build()
    short = SimConfig(warmup=10, measure=100, step_impl="fused")
    long = SimConfig(warmup=0, measure=2_000_000, step_impl="fused")
    assert grant_form(net, short) == "combined"
    assert grant_form(net, long) == "two_pass"


# ---------------------------------------------------------------------------
# jaxpr pass
# ---------------------------------------------------------------------------

def test_jaxpr_one_combo_clean():
    report = Report()
    check_combo(report, "fused", "baseline", "warm")
    assert not report.failed, report.render()
    assert any(f.rule == "JAXPR_TRACE" and f.severity == "info"
               for f in report.findings)


def test_non_batch_pure_kernel_flagged():
    """A kernel that couples packets through a cumsum must fail the
    batch-purity probe."""
    net = TRACE_TOPO.build()
    from repro.core.routing.pipeline import make_pipeline
    real = make_pipeline(net, "baseline").kernel

    def coupled(fl, cur, dest, mis, meta):
        out_ch, req_vc, meta2 = real(fl, cur, dest, mis, meta)
        # packet i's VC now depends on packets 0..i-1: batch-impure
        return out_ch, req_vc + jnp.cumsum(jnp.ones_like(req_vc)) - 1, meta2

    report = Report()
    check_kernel_batch_purity(report, net, "baseline", kernel=coupled)
    assert any(f.rule == "JAXPR_BATCH" and f.severity == "error"
               for f in report.gating)

    report2 = Report()
    check_kernel_batch_purity(report2, net, "baseline")
    assert not report2.failed


# ---------------------------------------------------------------------------
# compile pass / CLI / report plumbing
# ---------------------------------------------------------------------------

def test_compile_pass_smoke_scenarios_one_signature():
    report = Report()
    for name in ("smoke", "smoke_fused", "smoke_warm_faults"):
        compile_scenario(name, report)
    assert not report.failed, report.render()
    assert sum(1 for f in report.findings if f.rule == "COMPILE_SIG") == 3


def test_cli_exit_codes(tmp_path):
    out = tmp_path / "report.json"
    rc = main(["--spec", str(FIXTURES / "overflow_spec.json"),
               "--out", str(out)])
    assert rc == 1
    assert out.exists() and '"failed": true' in out.read_text()
    assert main(["--scenario", "smoke"]) == 0
    assert main([]) == 2


# ---------------------------------------------------------------------------
# serve pass
# ---------------------------------------------------------------------------

def test_serve_pass_one_signature_per_bucket():
    """The --serve certification: the mixed smoke submission (cold,
    cold-faulted, warm-faulted) lowers to exactly one dispatch signature
    per bucket, every ghost-padded pack matching its bucket's canonical
    form."""
    from repro.analysis.servepass import (SMOKE_SUBMISSION,
                                          check_submission)
    report = Report()
    check_submission(SMOKE_SUBMISSION, report)
    assert not report.failed, report.render()
    [info] = [f for f in report.findings if f.rule == "SERVE_BUCKET"]
    assert "3 bucket(s) -> 3 compile signature(s)" in info.message


def test_serve_pass_signature_sees_epoch_mismatch():
    """A bucket key whose pinned epoch count disagrees with the lanes'
    real schedules must change the abstract signature — the defect
    SERVE_SIG exists to catch."""
    from dataclasses import replace
    from repro.analysis.servepass import _canonical_fsets, pack_signature
    from repro.exp.registry import get_scenario
    from repro.exp.serve.scheduler import lower_request

    units, _ = lower_request(get_scenario("smoke_warm_faults"), 1, "t", 0)
    key = units[0].bucket
    assert key.epochs >= 2
    good = pack_signature(key, [u.fset for u in units], pack=8)
    assert good == pack_signature(key, _canonical_fsets(key), pack=8)
    # under-pinned key: stack_lanes pads to the REAL epoch count, so the
    # lane shapes no longer match the key's canonical form
    bad_key = replace(key, epochs=1)
    assert (pack_signature(bad_key, [u.fset for u in units], pack=8)
            != pack_signature(bad_key, _canonical_fsets(bad_key), pack=8))


def test_serve_cli_flag():
    assert main(["--serve"]) == 0


def test_report_json_round_trip():
    import json
    report = Report()
    report.add("lint", "REPRO001", "error", "x.py:1", "m")
    d = json.loads(report.to_json())
    assert d["failed"] and d["counts"]["error"] == 1


@pytest.mark.slow
def test_all_registered_scenarios_pass_all_four_passes():
    """The acceptance gate: every registered scenario, all passes, no
    simulation cycles, clean under the documented allowlist."""
    args = build_parser().parse_args(["--all", "--lint"])
    report = run(args)
    assert not report.failed, report.render()
    checked = {f.location.split(" ")[0] for f in report.findings
               if f.location.startswith("scenario:")}
    assert checked == {f"scenario:{n}" for n in list_scenarios()}
