"""Property tests of the paper's closed-form models (Eqs. 1-7, Table III)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import analytical as A
from repro.core import topology as T


def test_eq1_paper_small_config():
    # "Using a very small configuration (a,b,m,n)=(2,4,2,6), the total
    # chiplet number can reach 1K."
    p = T.SwitchlessParams(a=2, b=4, m=2, n=6)
    assert A.total_chiplets(p) == 1312  # ~1K


def test_radix16_eval_config():
    p = T.paper_radix16_switchless()
    assert p.k == 12 and p.h == 5 and p.ab == 8
    assert p.g_max == 41 and p.num_chips == 1312
    d = T.paper_radix16_dragonfly()
    assert d.num_groups == 41 and d.num_chips == 1312


def test_radix32_eval_config():
    p = T.paper_radix32_switchless()
    assert p.k == 24 and p.h == 9 and p.ab == 16
    assert p.g_max == 145 and p.num_chips == 18560
    d = T.paper_radix32_dragonfly()
    assert d.num_groups == 145 and d.num_chips == 18560


def test_table3_case_study():
    p = T.paper_table3_switchless()
    assert p.g_max == 545
    assert A.total_chiplets(p) == 279040
    c = A.switchless_case(p)
    assert c.num_switches == 0
    assert c.num_cabinets == 545
    assert c.num_processors == 279040
    sling = A.dragonfly_slingshot_case()
    assert sling.num_processors == 279040
    assert sling.num_switches == 17440
    assert sling.num_cabinets == 2180
    # cable-length claim: less than half of the switch-based Dragonfly
    assert c.cable_length_E < 0.5 * sling.cable_length_E


def test_balanced_config_throughput():
    # Eq. (3): n = 3m, ab = 2m^2 gives T_global >= 1, T_local = 2, T_cg = 3
    for m in (2, 4):
        p = T.SwitchlessParams(a=2, b=m * m, m=m, n=3 * m)
        assert A.is_balanced_config(p)
        assert A.global_throughput_bound(p) >= 1.0
        assert A.local_throughput_bound(p) == pytest.approx(2.0)
        assert A.cgroup_throughput_bound(p) == pytest.approx(3.0)
        assert A.cgroup_bisection(p) == pytest.approx(p.k / 2)


def test_diameter_eq7():
    p = T.paper_radix16_switchless()
    d = A.switchless_diameter(p)
    assert (d.global_hops, d.local_hops, d.sr_hops) == (1, 2, 8 * p.m - 2)
    # switch-less trades 2 cable hops (H_l*) for on-wafer hops: latency win
    assert d.latency_ns() < A.dragonfly_diameter().latency_ns()


@given(m=st.integers(1, 6), am=st.integers(1, 4), bm=st.integers(1, 8),
       nm=st.integers(1, 12))
@settings(max_examples=200, deadline=None)
def test_eq1_consistency(m, am, bm, nm):
    """Eq. (1) equals ab*m^2*g_max for any feasible parameter set."""
    p = T.SwitchlessParams(a=am, b=bm, m=m, n=nm)
    if p.h < 1:
        return
    assert A.total_chiplets(p) == p.ab * m * m * p.g_max
    assert A.total_chiplets(p) == p.N_eq1


@given(m=st.integers(1, 5))
@settings(max_examples=20, deadline=None)
def test_balanced_family(m):
    """The Eq. (3) family is balanced for every m and hits T_global >= 1:
    (mn - ab + 1)/m^2 = (m^2 + 1)/m^2 >= 1."""
    p = T.SwitchlessParams(a=1, b=2 * m * m, m=m, n=3 * m)
    assert A.is_balanced_config(p)
    assert A.global_throughput_bound(p) == pytest.approx(
        (m * m + 1) / (m * m))
    assert A.global_throughput_bound(p) >= 1.0


def test_energy_model_switchless_beats_switch_based():
    # Fig. 15 qualitative claim with the Table II constants: a minimal-routed
    # packet (1 global + 2 local + ~14 SR hops at m=2) costs less than the
    # switch-based (1 global + 2 local + 2 terminal-cable hops).
    swl = A.energy_per_packet_pj_per_bit(
        {"mesh": 14, "local": 2, "global": 1, "term_onchip": 2})
    swb = A.energy_per_packet_pj_per_bit(
        {"local": 2, "global": 1, "term_cable": 2})
    assert swl < swb
