"""2-D (lanes x shards) channel sharding: bit-identity and accounting.

`REPRO_CHANNEL_SHARDS=K` block-partitions each lane's channel-id space
across K shard devices inside the fused cycle step (halo exchange at the
phase boundary; see repro/core/engine/fused.py).  The multi-device
backend state only exists before JAX initializes, so the sharded half
runs in a SUBPROCESS with `REPRO_HOST_DEVICES=4`; the parent runs the
identical grids single-device in-process and compares raw per-lane
counters exactly.

Coverage: all three vc_modes, a warm `FaultSchedule` lane mix (scheduled
lanes take the per-cycle routing fallback), non-dividing channel counts
(the dragonfly case pads ghost channels), and both 2-D shapes a 4-device
host offers (lanes:2,shards:2 and lanes:1,shards:4) — each with exactly
one compile per grid.
"""
import json
import os
import subprocess
import sys

import pytest

WARMUP, MEASURE = 41, 131

_CHILD = r"""
import json, sys
import repro            # applies REPRO_HOST_DEVICES before jax init
import numpy as np
import jax
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.engine import sweep as sweep_mod
from repro.core.simulator import SimConfig, Simulator
from repro.core.topology import FaultSet, FaultSchedule

assert len(jax.devices()) == 4, f"expected 4 devices, got {jax.devices()}"
K = sweep_mod.channel_shards()
out = []
for case in CASES:
    placement, pad, compiles, rows = RUN_CASE(case)
    out.append(dict(case=case, placement=placement, pad=pad,
                    compiles=compiles, rows=rows))
print(json.dumps(out))
"""

# the shared case runner: exec'd by the child and imported by the parent
# (single source, so both sides run byte-identical configurations)
_COMMON = r"""
WARMUP, MEASURE = %d, %d
CASES = ["baseline", "merged", "dragonfly_warm"]

def RUN_CASE(case):
    import numpy as np
    from repro.core import topology as T
    from repro.core import traffic as TR
    from repro.core.engine import sweep as sweep_mod
    from repro.core.simulator import SimConfig, Simulator
    from repro.core.topology import FaultSet, FaultSchedule

    def rowdump(results):
        return [dict(d=r.delivered_pkts, g=r.generated_pkts,
                     dr=r.dropped_pkts, lat=r.avg_latency,
                     thr=r.throughput_per_chip, st=r.stranded_pkts,
                     hops=sorted(r.hops_by_type.items()))
                for r in results]

    before = sweep_mod.compile_counter()
    if case == "baseline":
        net = T.build_switchless(
            T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=3), "chsh-b")
        cfg = SimConfig(warmup=WARMUP, measure=MEASURE, vc_mode="baseline",
                        route_mode="min", vcs_per_class=2,
                        step_impl="fused")
        sim = Simulator(net, cfg, TR.uniform(net))
        run = sim._batched.run_lanes(
            [(r, s, None) for r in (0.4, 0.9, 1.6) for s in (0, 1)])
    elif case == "merged":
        net = T.build_switchless(
            T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=3), "chsh-m")
        cfg = SimConfig(warmup=WARMUP, measure=MEASURE,
                        vc_mode="updown_merged", route_mode="min",
                        vcs_per_class=2, step_impl="fused")
        sim = Simulator(net, cfg, TR.uniform(net))
        run = sim._batched.run_lanes([(0.5, 0, None), (1.2, 1, None)])
    else:
        # non-dividing channel count (ghost-channel padding) + a warm
        # schedule lane mix: scheduled lanes route per cycle, pristine
        # lanes keep the cached-route fast path — in one dispatch
        net = T.build_switch_dragonfly(T.paper_radix16_dragonfly(g=3))
        cfg = SimConfig(warmup=WARMUP, measure=MEASURE, vc_mode="updown",
                        route_mode="val", vcs_per_class=2,
                        step_impl="fused")
        glob_ch = np.where(np.asarray(net.ch_type) == T.GLOBAL)[0]
        f = FaultSchedule((
            (0, FaultSet()),
            (60, FaultSet(dead_ch=frozenset(int(c)
                                            for c in glob_ch[:2])))))
        sim = Simulator(net, cfg, TR.uniform(net))
        run = sim._batched.run_lanes(
            [(0.4, 0, None), (0.9, 1, f), (1.6, 0, f)])
    compiles = sweep_mod.compile_counter() - before
    return (run.placement, round(run.pad_fraction, 9), compiles,
            rowdump(run.results))
""" % (WARMUP, MEASURE)


def _run_child(extra_env):
    env = dict(os.environ, **extra_env)
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in sys.path if p] +
        [p for p in (env.get("PYTHONPATH") or "").split(os.pathsep) if p])
    proc = subprocess.run([sys.executable, "-c", _COMMON + _CHILD],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.splitlines()[-1])


_single_cache = None


def _single_device():
    """The same three grids, single-device in-process (memoized: both
    shard-shape tests compare against the identical reference)."""
    global _single_cache
    if _single_cache is None:
        ns = {}
        exec(_COMMON, ns)
        # normalize through JSON exactly like the child's output does
        # (tuples -> lists, numpy scalars -> plain floats)
        _single_cache = {case: json.loads(json.dumps(ns["RUN_CASE"](case)))
                         for case in ns["CASES"]}
    return _single_cache


@pytest.mark.parametrize("shards,placement", [(2, "lanes:2,shards:2"),
                                              (4, "lanes:1,shards:4")])
def test_channel_sharded_bit_identical(shards, placement):
    """Acceptance: the 2-D sharded dispatch reproduces the single-device
    fused run bit for bit — every counter of every lane — across all
    three vc_modes, a warm-fault lane mix, and ghost-channel padding,
    with one compile per grid."""
    child = _run_child({"REPRO_HOST_DEVICES": "4",
                        "REPRO_CHANNEL_SHARDS": str(shards)})
    ref = _single_device()
    for rec in child:
        case = rec["case"]
        r_placement, r_pad, _, r_rows = ref[case]
        assert r_placement == "single"
        assert rec["placement"] == placement, case
        assert rec["compiles"] == 1, case
        if case == "dragonfly_warm":
            # E=438 channels don't divide the shard count: ghost pad
            assert rec["pad"] > 0
        assert rec["rows"] == r_rows, case   # exact: ints and floats


def test_channel_shards_knob_ignored_on_jnp_step():
    """REPRO_CHANNEL_SHARDS only applies to fused-step dispatches; the
    jnp oracle path never shards channels (placement stays 1-D)."""
    from repro.core.engine import sweep as sweep_mod
    os.environ["REPRO_CHANNEL_SHARDS"] = "2"
    try:
        assert sweep_mod.channel_shards() == 2
    finally:
        del os.environ["REPRO_CHANNEL_SHARDS"]
    assert sweep_mod.channel_shards() == 1
