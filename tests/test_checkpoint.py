"""Tests for `repro.checkpoint`: dtype round-trips through the npz
void-byte path (the bfloat16 regression), atomic write + retention,
manifest `extra` payloads, and exact `SimState` snapshot/restore."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import Checkpointer, restore_sim_state, save_sim_state
from repro.core import topology as T
from repro.core.engine import make_state
from repro.core.routing import num_vcs
from repro.core.simulator import SimConfig


def _tiny_state():
    p = T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=1)
    net = T.build_switchless(p, "tiny")
    cfg = SimConfig(warmup=10, measure=50)
    NV = (num_vcs("switchless", cfg.vc_mode, cfg.nonminimal)
          * cfg.vcs_per_class)
    return make_state(net, cfg, NV, (2,))


def test_bfloat16_void_bytes_reinterpreted_not_converted(tmp_path):
    """np.savez stores ml_dtypes arrays as raw void bytes; restore must
    `.view` them back through the template dtype, bit-exactly."""
    x = jnp.asarray([1.5, -2.25, 3.0e-2, 65504.0], dtype=jnp.bfloat16)
    ck = Checkpointer(str(tmp_path), keep=2)
    ck.save(0, {"x": x})
    restored, step = ck.restore({"x": jnp.zeros_like(x)})
    assert step == 0
    rx = np.asarray(restored["x"])
    assert rx.dtype == np.asarray(x).dtype
    assert np.array_equal(rx.view(np.uint16), np.asarray(x).view(np.uint16))


def test_typed_dtype_mismatch_converts_not_views(tmp_path):
    """An int32 snapshot restored into a float32 template must CONVERT
    the values — a `.view` there would scramble every one (the
    regression the void-only guard in `_unflatten_into` exists for)."""
    ck = Checkpointer(str(tmp_path))
    ck.save(0, {"c": np.arange(5, dtype=np.int32)})
    restored, _ = ck.restore({"c": np.zeros(5, dtype=np.float32)})
    assert restored["c"].dtype == np.float32
    assert np.array_equal(restored["c"], [0.0, 1.0, 2.0, 3.0, 4.0])


def test_python_scalar_leaves_round_trip(tmp_path):
    """Plain ints are valid template leaves (a session's cycle counter);
    they restore through `np.asarray` dtype inference."""
    ck = Checkpointer(str(tmp_path))
    ck.save(0, {"cycle": 128, "arr": np.ones(3)})
    restored, _ = ck.restore({"cycle": 0, "arr": np.zeros(3)})
    assert int(restored["cycle"]) == 128


def test_retention_keeps_newest_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for step in (5, 6, 7):
        ck.save(step, {"x": np.full(2, step)})
    assert ck.list_steps() == [6, 7]
    assert ck.latest_step() == 7
    restored, step = ck.restore({"x": np.zeros(2)})
    assert step == 7 and restored["x"][0] == 7
    # explicit older step still addressable while retained
    restored, step = ck.restore({"x": np.zeros(2)}, step=6)
    assert step == 6 and restored["x"][0] == 6


def test_restore_empty_directory_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        Checkpointer(str(tmp_path)).restore({"x": np.zeros(1)})


def test_sim_state_public_api_round_trip_exact(tmp_path):
    """`save_sim_state`/`restore_sim_state`: a full batched `SimState`
    pytree (every buffer/counter dtype the engine uses) round-trips
    bit-exactly, with the `extra` payload riding in the manifest."""
    state = _tiny_state()
    host = jax.tree.map(np.asarray, state)
    path = save_sim_state(str(tmp_path), 3, state,
                          extra={"round": 3, "pending": [[1, 0, 0, 2]]},
                          keep=2)
    assert path.endswith("step-00000003")
    template = jax.tree.map(np.zeros_like, host)
    restored, extra, step = restore_sim_state(str(tmp_path), template)
    assert step == 3
    assert extra == {"round": 3, "pending": [[1, 0, 0, 2]]}
    for a, b in zip(jax.tree.leaves(host), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        assert np.array_equal(a, b)
