"""shard_map collectives vs psum oracle.  Runs in a subprocess so the
multi-device CPU flag doesn't leak into the rest of the suite."""
import os
import subprocess
import sys
import textwrap

import pytest

_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map          # jax >= 0.5
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from repro.core import collectives as C

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    x = (jnp.arange(8 * 24, dtype=jnp.float32).reshape(8, 24) * 0.37 - 11.0)

    def f(fn):
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=P("data", "model"),
                                 out_specs=P("data", "model")))

    o_m = f(lambda s: jax.lax.psum(s, "model"))(x)
    o_all = f(lambda s: jax.lax.psum(s, ("model", "data")))(x)

    np.testing.assert_allclose(
        f(lambda s: C.ring_all_reduce(s, "model"))(x), o_m, rtol=1e-5)
    np.testing.assert_allclose(
        f(lambda s: C.bidir_ring_all_reduce(s, "model"))(x), o_m, rtol=1e-5)
    np.testing.assert_allclose(
        f(lambda s: C.hierarchical_psum(s, "model", "data"))(x), o_all,
        rtol=1e-5)
    np.testing.assert_allclose(
        f(lambda s: C.psum_2d(s, "model", "data"))(x), o_all, rtol=1e-5)

    # ragged leading dim (padding path)
    y = jnp.ones((8, 36), jnp.float32).cumsum(axis=1)
    o2 = f(lambda s: jax.lax.psum(s, "model"))(y)
    np.testing.assert_allclose(
        f(lambda s: C.ring_all_reduce(s, "model"))(y), o2, rtol=1e-5)
    np.testing.assert_allclose(
        f(lambda s: C.bidir_ring_all_reduce(s, "model"))(y), o2, rtol=1e-5)
    print("COLLECTIVES_OK")
""")


def test_collectives_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _BODY], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "COLLECTIVES_OK" in out.stdout
