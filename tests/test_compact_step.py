"""Occupancy-compacted step (`step_impl="compact"`) vs the jnp oracle.

The compact step partitions the live rows into a statically-bounded
active set of `capacity` rows before routing and arbitration (see
repro/core/engine/fused.py `make_compact_step`), so per-cycle cost
tracks occupancy instead of network capacity — but every counter must
stay BIT-IDENTICAL to the classic phase pipeline: the compaction is a
stable partition and each active slot's grant priority is its GLOBAL
row id, so every age tie resolves to the same packet.  Pinned here on
live engine runs across vc_modes, cold fault sets, and warm
`FaultSchedule`s, plus the ladder mechanics the sweep layer builds on
top:

  * capacity ESCALATION: a run whose live-row census overflows its rung
    is re-dispatched whole at the next ladder rung, and the rerun is
    still bit-identical to the oracle (`_PendingLanes.finish`);
  * windowed sessions can NOT escalate mid-run (snapshots already
    streamed) — `LaneSession.finish` must raise, never truncate;
  * K-cycle SUPERSTEPS (REPRO_SUPERSTEP): K unrolled cycles per scan
    iteration are bit-identical for any K dividing the run — including
    a warm-fault epoch onset landing MID-superstep — and silently fall
    back to K=1 when K does not divide;
  * the `grant_impl="pallas"` variant feeds the compacted rows' GLOBAL
    ids through the `cycle_core` kernel's explicit `prio` input and
    must also be bit-identical.
"""
import numpy as np
import pytest

from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.engine.fused import (capacity_ladder, initial_capacity,
                                     next_rung)
from repro.core.simulator import SimConfig, Simulator
from repro.core.topology import FaultSchedule, FaultSet

NET = T.build_switchless(
    T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=3), "compact-par")
GLOB = np.where(np.asarray(NET.ch_type) == T.GLOBAL)[0]
WARMUP, MEASURE = 40, 140
RATES, SEEDS = [0.4, 1.2], (0, 1)


def _faults(vc_mode):
    if vc_mode == "baseline":
        return FaultSet(dead_ch=frozenset(int(c) for c in GLOB[:2]))
    return FaultSet(dead_routers=frozenset({5, 11}))


def _schedule(vc_mode, onset=60):
    return FaultSchedule(((0, FaultSet()), (onset, _faults(vc_mode))))


def _rows(cfg, faults):
    sim = Simulator(NET, cfg, TR.uniform(NET), faults=faults)
    return [(r.delivered_pkts, r.generated_pkts, r.dropped_pkts,
             r.avg_latency, r.throughput_per_chip, r.stranded_pkts,
             r.occupancy_peak, tuple(sorted(r.hops_by_type.items())))
            for r in sim.sweep(RATES, seeds=SEEDS)]


def _cfg(impl, **kw):
    return SimConfig(warmup=WARMUP, measure=MEASURE, step_impl=impl, **kw)


CASES = [("baseline", "min", 2), ("baseline", "ugal", 1),
         ("updown", "val", 2)]


@pytest.mark.parametrize("vc_mode,route_mode,vpc", CASES)
@pytest.mark.parametrize("fkind", ["pristine", "cold", "warm"])
def test_compact_step_bit_identical(vc_mode, route_mode, vpc, fkind):
    faults = (None if fkind == "pristine"
              else _faults(vc_mode) if fkind == "cold"
              else _schedule(vc_mode))
    rows = {}
    for impl in ("jnp", "compact"):
        rows[impl] = _rows(_cfg(impl, vc_mode=vc_mode,
                                route_mode=route_mode,
                                vcs_per_class=vpc), faults)
    assert rows["compact"] == rows["jnp"]


def test_compact_telemetry_and_ladder():
    """SweepResult carries the compact telemetry: the occupancy peak is
    the oracle's (the census is capacity-independent), the capacity is
    the default starting rung, and no escalation fired (the rung has
    headroom on this net)."""
    sim = Simulator(NET, _cfg("compact", vcs_per_class=2), TR.uniform(NET))
    g = sim.sweep_grid(RATES, seeds=SEEDS)
    ref = Simulator(NET, _cfg("jnp", vcs_per_class=2),
                    TR.uniform(NET)).sweep_grid(RATES, seeds=SEEDS)
    N = sim._batched.step.compact_rows
    assert g.compact_capacity == initial_capacity(N)
    assert g.compact_capacity in capacity_ladder(N)
    assert 0 < g.occupancy_peak == ref.occupancy_peak
    assert g.occupancy_peak <= g.compact_capacity
    assert g.escalations == 0
    assert g.superstep == 1
    # the jnp oracle reports no capacity (nothing to escalate)
    assert ref.compact_capacity == 0
    # ladder algebra
    assert capacity_ladder(N)[-1] == N
    assert next_rung(N, N + 5) == N
    assert next_rung(N, 1) == capacity_ladder(N)[0]


def test_capacity_escalation_bit_identical():
    """A capacity pinned below the live-row peak must be DETECTED and
    escalated — the whole grid re-dispatched at the next ladder rung —
    and the escalated results still match the oracle bit for bit
    (per-lane rows here: the async path returns one result per lane,
    not the seed-averaged `sweep()` form)."""
    ref_sim = Simulator(NET, _cfg("jnp", vcs_per_class=2),
                        TR.uniform(NET))
    ref = [(r.delivered_pkts, r.generated_pkts, r.dropped_pkts,
            r.avg_latency, r.throughput_per_chip, r.stranded_pkts,
            r.occupancy_peak, tuple(sorted(r.hops_by_type.items())))
           for r in ref_sim.sweep_grid(RATES, seeds=SEEDS).flat()]
    sim = Simulator(NET, _cfg("compact", vcs_per_class=2), TR.uniform(NET))
    lanes = [(r, s, None) for r in RATES for s in SEEDS]
    # occupancy peaks near ~90 live rows on this net; 50 overflows
    run = sim._batched.run_lanes_async(lanes, capacity=50).finish()
    got = [(r.delivered_pkts, r.generated_pkts, r.dropped_pkts,
            r.avg_latency, r.throughput_per_chip, r.stranded_pkts,
            r.occupancy_peak, tuple(sorted(r.hops_by_type.items())))
           for r in run.results]
    assert got == ref
    assert run.escalations == 1
    assert run.occupancy_peak > 50
    N = sim._batched.step.compact_rows
    assert run.compact_capacity == next_rung(N, run.occupancy_peak)
    # each ladder rung is its own executable: the grid's compile count
    # stays 1 and the abandoned rung's compile is booked separately
    assert run.compile_count == 1
    assert run.escalation_compiles == 1
    # warm start: the sweep remembers the escalated rung, so the next
    # dispatch starts there and never re-breaches
    redo = sim._batched.run_lanes_async(lanes, capacity=50).finish()
    again = sim._batched.run_lanes_async(lanes).finish()
    assert again.escalations == 0
    assert again.compact_capacity == run.compact_capacity
    assert redo.escalations == 1   # explicit pins still escalate


def test_windowed_session_overflow_raises():
    """`LaneSession.finish` must refuse a capacity breach instead of
    truncating: windowed runs stream stats mid-flight, so re-dispatching
    at a larger rung can't happen transparently."""
    sim = Simulator(NET, _cfg("compact", vcs_per_class=2), TR.uniform(NET))
    lanes = [(r, s, None) for r in RATES for s in SEEDS]
    sess = sim._batched.start_lanes(lanes, window=60)
    while not sess.done():
        sess.advance()
    # simulate an undersized pinned rung (the default rung has headroom
    # on this net, so the breach is injected post-run; the guard only
    # compares the census against the session's rung)
    sess.capacity = 50
    with pytest.raises(RuntimeError, match="REPRO_COMPACT_CAP"):
        sess.finish()


@pytest.mark.parametrize("k", [1, 2, 4])
def test_superstep_bit_identical(k, monkeypatch):
    """K compacted cycles unrolled per scan iteration (K divides the
    180-cycle run) reproduce the oracle exactly, including a warm-fault
    epoch onset at cycle 61 — mid-superstep for K in {2, 4}."""
    ref = _rows(_cfg("jnp", vcs_per_class=2), _schedule("baseline", 61))
    monkeypatch.setenv("REPRO_SUPERSTEP", str(k))
    got = _rows(_cfg("compact", vcs_per_class=2),
                _schedule("baseline", 61))
    assert got == ref
    sim = Simulator(NET, _cfg("compact", vcs_per_class=2), TR.uniform(NET))
    assert sim.sweep_grid(RATES, seeds=SEEDS).superstep == k


def test_superstep_non_divisor_falls_back(monkeypatch):
    """K that does not divide warmup+measure falls back to K=1 (and the
    result is still exact) — the capacity pass warns about the silent
    fallback statically (analysis/capacitypass.py)."""
    from repro.core.engine.sweep import superstep

    ref = _rows(_cfg("jnp", vcs_per_class=2), None)
    monkeypatch.setenv("REPRO_SUPERSTEP", "7")   # 180 % 7 != 0
    assert superstep(WARMUP + MEASURE) == 1
    got = _rows(_cfg("compact", vcs_per_class=2), None)
    assert got == ref
    sim = Simulator(NET, _cfg("compact", vcs_per_class=2), TR.uniform(NET))
    assert sim.sweep_grid(RATES, seeds=SEEDS).superstep == 1


@pytest.mark.parametrize("fkind", ["pristine", "cold"])
def test_compact_pallas_grant_bit_identical(fkind):
    """grant_impl="pallas" inside the compact step: the kernel's
    explicit `prio` input carries the compacted rows' GLOBAL ids, and
    the grants match the jnp compact path exactly."""
    faults = None if fkind == "pristine" else _faults("baseline")
    rows = {}
    for gi in ("jnp", "pallas"):
        rows[gi] = _rows(_cfg("compact", vc_mode="baseline",
                              route_mode="min", vcs_per_class=2,
                              grant_impl=gi), faults)
    assert rows["pallas"] == rows["jnp"]


def test_capacity_bounds_validated():
    """make_compact_step rejects capacities outside [1, N]."""
    from repro.core.engine.fused import make_compact_step

    cfg = _cfg("compact", vcs_per_class=2)
    with pytest.raises(ValueError, match="capacity"):
        make_compact_step(NET, cfg, TR.uniform(NET), capacity=0)
    with pytest.raises(ValueError, match="capacity"):
        make_compact_step(NET, cfg, TR.uniform(NET), capacity=10 ** 9)
