"""Deliverable integrity: the multi-pod dry-run matrix and roofline.

These tests validate the artifacts produced by `repro.launch.dryrun`
(regenerate with `python -m repro.launch.dryrun`); they skip if the
matrix has not been run yet.
"""
import glob
import json
import os

import pytest

from repro.configs.base import LM_SHAPES
from repro.configs.registry import ARCHS, cell_applicable, get_config

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

have_artifacts = len(glob.glob(os.path.join(ART, "*.json"))) >= 10
pytestmark = pytest.mark.skipif(not have_artifacts,
                                reason="run repro.launch.dryrun first")


def _load(arch, shape, mesh):
    path = os.path.join(ART, f"{arch}__{shape}__{mesh}.json")
    assert os.path.exists(path), f"missing dry-run cell {path}"
    return json.load(open(path))


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_full_matrix_green(mesh):
    """Every (arch x shape x mesh) cell compiled or is a documented skip."""
    for arch in ARCHS:
        for shape in LM_SHAPES:
            art = _load(arch, shape.name, mesh)
            cfg = get_config(arch)
            ok, _ = cell_applicable(cfg, shape)
            if ok:
                assert art["status"] == "ok", (arch, shape.name, mesh,
                                               art.get("error"))
            else:
                assert art["status"] == "skipped"


def test_ok_cells_have_analysis():
    for path in glob.glob(os.path.join(ART, "*__single.json")):
        art = json.load(open(path))
        if art["status"] != "ok":
            continue
        assert art["flops"] > 0
        assert art["memory"]["temp_size_in_bytes"] > 0
        assert isinstance(art["collectives"]["by_axis"], dict)
        assert art["chips"] == 256


def test_multi_pod_uses_pod_axis():
    """At least some multi-pod train cells move bytes on the pod axis."""
    found = 0
    for path in glob.glob(os.path.join(ART, "*train_4k__multi.json")):
        art = json.load(open(path))
        if art["status"] != "ok":
            continue
        assert art["chips"] == 512
        if art["collectives"]["by_axis"].get("pod", 0) > 0:
            found += 1
    assert found >= 3


def test_roofline_rows_complete():
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.roofline import load_rows
    rows = [r for r in load_rows("single") if r.get("mesh") == "single"]
    assert len(rows) == len(ARCHS) * len(LM_SHAPES)
    ok_rows = [r for r in rows if "compute_s" in r]
    assert all(r["compute_s"] >= 0 and r["collective_s"] >= 0
               for r in ok_rows)
    # the paper's thesis: wafer-fabric collective term always cheaper than
    # the flat-ICI term
    assert all(r["collective_wafer_s"] <= r["collective_s"] + 1e-12
               for r in ok_rows)


def test_hillclimb_artifacts_improve_their_targets():
    """§Perf: the logged iterations actually moved the dominant term."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.roofline import roofline_row

    def row(tag, arch="minicpm-2b", shape="train_4k"):
        p = os.path.join(ART, f"{arch}__{shape}__{tag}.json")
        if not os.path.exists(p):
            pytest.skip(f"hillclimb artifact {tag} not present")
        return roofline_row(json.load(open(p)))

    base = row("single")
    tuned = row("single-dp64tp4")
    assert tuned["collective_s"] < 0.5 * base["collective_s"]
    assert tuned["roofline_frac"] > base["roofline_frac"]

    qb = row("single", arch="qwen3-moe-235b-a22b")
    qi = row("single-int8disp", arch="qwen3-moe-235b-a22b")
    assert qi["collective_s"] < 0.65 * qb["collective_s"]
