"""Engine-level tests: pytree SimState, phase composition, batch purity of
the route function, and BatchedSweep equivalence with sequential runs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import topology as T
from repro.core import traffic as TR
from repro.core import engine
from repro.core.engine import (BatchedSweep, Requests, SimState, SimStats,
                               build_lane, make_state, make_step)
from repro.core.engine import sweep as sweep_mod
from repro.core.routing import make_route_fn
from repro.core.simulator import SimConfig, Simulator


@pytest.fixture(scope="module")
def cgroup_net():
    p = T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=1)
    return T.build_switchless(p, "engine-cgroup")


def test_simstate_is_pytree(cgroup_net):
    cfg = SimConfig()
    consts, _ = engine.build_consts(cgroup_net, cfg)
    state = make_state(cgroup_net, cfg, consts["NV"])
    leaves, treedef = jax.tree.flatten(state)
    assert all(isinstance(l, jax.Array) for l in leaves)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rebuilt, SimState)
    assert isinstance(rebuilt.stats, SimStats)
    bumped = jax.tree.map(lambda x: x + 1, state)
    assert int(bumped.b_count.sum()) == state.b_count.size


def test_make_state_batch_axis(cgroup_net):
    cfg = SimConfig()
    consts, _ = engine.build_consts(cgroup_net, cfg)
    single = make_state(cgroup_net, cfg, consts["NV"])
    batched = make_state(cgroup_net, cfg, consts["NV"], batch=(3,))
    for a, b in zip(jax.tree.leaves(single), jax.tree.leaves(batched)):
        assert b.shape == (3,) + a.shape


def test_route_fn_batch_pure(cgroup_net):
    """vmapping the route function over a batch of packet vectors must equal
    looping it — the property BatchedSweep relies on."""
    route_fn = make_route_fn(cgroup_net, "baseline")
    rng = np.random.default_rng(0)
    V, Tn = cgroup_net.num_nodes, cgroup_net.num_terminals
    B, N = 4, 32
    cur = jnp.asarray(rng.integers(0, V, size=(B, N)))
    dest = jnp.asarray(rng.integers(0, Tn, size=(B, N)))
    mis = jnp.full((B, N), -1, dtype=jnp.int32)
    meta = jnp.zeros((B, N), dtype=jnp.int32)
    out_b, vc_b, meta_b = jax.vmap(route_fn)(cur, dest, mis, meta)
    for i in range(B):
        out, vc, m = route_fn(cur[i], dest[i], mis[i], meta[i])
        np.testing.assert_array_equal(np.asarray(out_b[i]), np.asarray(out))
        np.testing.assert_array_equal(np.asarray(vc_b[i]), np.asarray(vc))
        np.testing.assert_array_equal(np.asarray(meta_b[i]), np.asarray(m))


def test_step_grants_at_most_one_winner_per_channel(cgroup_net):
    cfg = SimConfig(warmup=10, measure=10, vcs_per_class=2)
    consts, route_kernel = engine.build_consts(cgroup_net, cfg)
    inject = engine.make_inject_fn(cgroup_net, cfg, consts, TR.uniform(cgroup_net))
    arbitrate = engine.make_arbitrate_fn(cgroup_net, cfg, consts,
                                         route_kernel)
    fl = build_lane(cgroup_net, cfg)
    state = make_state(cgroup_net, cfg, consts["NV"])
    key = jax.random.PRNGKey(0)
    apply_moves = engine.make_apply_fn(cgroup_net, cfg, consts)
    for t in range(8):
        key, sub = jax.random.split(key)
        state = inject(state, t, sub, jnp.float32(0.9), fl)
        req, win, won_ch = arbitrate(state, t, fl)
        assert isinstance(req, Requests)
        # one winner per output channel
        outs = np.asarray(req.out)[np.asarray(win)]
        assert len(outs) == len(np.unique(outs))
        # winners must be valid requesters
        assert bool((np.asarray(win) <= np.asarray(req.valid)).all())
        # the dense grant mask agrees with the winner rows
        assert set(outs) == set(np.flatnonzero(np.asarray(won_ch)))
        state = apply_moves(state, req, win, won_ch, t)
        # occupancy never exceeds capacity, never goes negative
        bc = np.asarray(state.b_count)
        assert bc.min() >= 0 and bc.max() <= cfg.buf_pkts


def test_batched_sweep_matches_sequential(cgroup_net):
    """Acceptance: >= 6 rates x 2 seeds, throughput/latency within 2% of
    per-rate sequential Simulator.run, ONE jit compile for the whole sweep.

    The compile count comes from the module-level trace counter
    (`sweep.compile_counter`), not the private jit `_cache_size` API, so
    it cannot silently degrade to 0 on JAX versions without that API.
    The cycle count (101 + 397) is unique in the suite, so this call can
    never be a cache hit from an earlier test even without `clear_cache`.
    """
    cfg = SimConfig(warmup=101, measure=397, vcs_per_class=2)
    sim = Simulator(cgroup_net, cfg, TR.uniform(cgroup_net))
    rates = [0.2, 0.5, 0.9, 1.4, 2.0, 2.6]
    seeds = (0, 1)
    before = sweep_mod.compile_counter()
    grid = sim.sweep_grid(rates, seeds)
    assert grid.compile_count == 1
    assert sweep_mod.compile_counter() - before == 1
    # a second identical sweep is a cache hit: zero new compiles
    grid2 = sim.sweep_grid(rates, seeds)
    assert grid2.compile_count == 0
    for i, r in enumerate(rates):
        for j, s in enumerate(seeds):
            seq = sim.run(r, seed=s)
            bat = grid.result(i, j)
            assert bat.throughput_per_chip == pytest.approx(
                seq.throughput_per_chip, rel=0.02)
            assert bat.avg_latency == pytest.approx(seq.avg_latency, rel=0.02)
    # curve-level reductions
    sat = grid.saturation_throughput()
    assert sat == max(r.throughput_per_chip for r in grid.mean_over_seeds())


def test_sweep_rejects_overdriven_rate(cgroup_net):
    cfg = SimConfig(warmup=10, measure=10)
    sweep = BatchedSweep(cgroup_net, cfg, TR.uniform(cgroup_net))
    with pytest.raises(ValueError):
        sweep.run([100.0])


@pytest.fixture(scope="module")
def multi_wg_net():
    return T.build_switchless(
        T.SwitchlessParams(a=2, b=2, m=2, n=4, noc=2, g=5), "engine-multiwg")


def test_ugal_watch_pads_with_sentinel(multi_wg_net):
    """Unused sensor slots are -1 (masked), never channel id 0."""
    cfg = SimConfig(route_mode="ugal")
    watch = np.asarray(engine.build_ugal_watch(multi_wg_net, cfg))
    g = multi_wg_net.meta["g"]
    for w in range(g):
        for u in range(g):
            sens = watch[w, u]
            if w == u:
                assert (sens == -1).all()
                continue
            # the first slot is the watched global link itself
            assert sens[0] >= 0
            assert multi_wg_net.ch_type[sens[0]] == T.GLOBAL
            # once a slot is empty, the rest are empty too — and empty
            # means the -1 sentinel, not channel 0
            n = int((sens >= 0).sum())
            assert (sens[n:] == -1).all()


def test_ugal_congested_channel_zero_does_not_flip_nonmin(multi_wg_net):
    """Regression: the old 0-padded sensor table added channel 0's buffered
    occupancy to every entry with fewer than 5 feeders, so congestion on
    channel 0 could flip `take_nonmin` for flows that never touch it."""
    net = multi_wg_net
    cfg = SimConfig(route_mode="ugal", vcs_per_class=1)
    consts, _ = engine.build_consts(net, cfg)
    gen_mis = engine.make_misroute_fn(net, cfg, consts)
    fl = build_lane(net, cfg)
    g = net.meta["g"]
    T_ = net.num_terminals
    # craft an adversarial sensor table: the minimal-path entry towards
    # W-group `wd` has 4 empty (sentinel) slots, every other entry has 5
    # real but EMPTY sensor channels.  With 0-padding, congestion on
    # channel 0 inflates q_min by 4 x occ(0) while q_non stays 0, flipping
    # the comparison; with the sentinel fix both stay 0.
    wd = g - 1
    empty = net.first_eject - 1      # a channel no crafted sensor watches
    crafted = np.full((g, g, 5), empty, dtype=np.int64)
    crafted[:, wd, 1:] = -1
    fl = dict(fl, ugal_watch=jnp.asarray(crafted))
    # every source sends to terminal 0 of W-group wd
    tpw = net.meta["terms_per_wg"]
    dest = jnp.full((T_,), wd * tpw, dtype=jnp.int32)
    key = jax.random.PRNGKey(3)
    quiet = jnp.zeros((net.num_channels, consts["NV"]), jnp.int32)
    congested = quiet.at[0, :].set(cfg.buf_pkts)
    mis_quiet = np.asarray(gen_mis(key, dest, quiet, fl))
    mis_hot = np.asarray(gen_mis(key, dest, congested, fl))
    src_wg = np.asarray(consts["term_wg"])
    differ = src_wg != wd
    # all-empty sensors -> minimal everywhere, congested channel 0 or not
    assert (mis_quiet[differ] == -1).all()
    np.testing.assert_array_equal(mis_hot, mis_quiet)


def test_simulator_sweep_facade(cgroup_net):
    """Simulator.sweep keeps the historical list[SimResult] contract."""
    cfg = SimConfig(warmup=50, measure=200, vcs_per_class=2)
    sim = Simulator(cgroup_net, cfg, TR.uniform(cgroup_net))
    rates = [0.3, 0.6]
    out = sim.sweep(rates)
    assert len(out) == len(rates)
    assert [r.offered_per_chip for r in out] == rates
    assert all(r.throughput_per_chip > 0 for r in out)
