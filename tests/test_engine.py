"""Engine-level tests: pytree SimState, phase composition, batch purity of
the route function, and BatchedSweep equivalence with sequential runs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import topology as T
from repro.core import traffic as TR
from repro.core import engine
from repro.core.engine import (BatchedSweep, Requests, SimState, SimStats,
                               make_state, make_step)
from repro.core.engine.sweep import run_scan_batched
from repro.core.routing import make_route_fn
from repro.core.simulator import SimConfig, Simulator


@pytest.fixture(scope="module")
def cgroup_net():
    p = T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=1)
    return T.build_switchless(p, "engine-cgroup")


def test_simstate_is_pytree(cgroup_net):
    cfg = SimConfig()
    consts, _ = engine.build_consts(cgroup_net, cfg)
    state = make_state(cgroup_net, cfg, consts["NV"])
    leaves, treedef = jax.tree.flatten(state)
    assert all(isinstance(l, jax.Array) for l in leaves)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rebuilt, SimState)
    assert isinstance(rebuilt.stats, SimStats)
    bumped = jax.tree.map(lambda x: x + 1, state)
    assert int(bumped.b_count.sum()) == state.b_count.size


def test_make_state_batch_axis(cgroup_net):
    cfg = SimConfig()
    consts, _ = engine.build_consts(cgroup_net, cfg)
    single = make_state(cgroup_net, cfg, consts["NV"])
    batched = make_state(cgroup_net, cfg, consts["NV"], batch=(3,))
    for a, b in zip(jax.tree.leaves(single), jax.tree.leaves(batched)):
        assert b.shape == (3,) + a.shape


def test_route_fn_batch_pure(cgroup_net):
    """vmapping the route function over a batch of packet vectors must equal
    looping it — the property BatchedSweep relies on."""
    route_fn = make_route_fn(cgroup_net, "baseline")
    rng = np.random.default_rng(0)
    V, Tn = cgroup_net.num_nodes, cgroup_net.num_terminals
    B, N = 4, 32
    cur = jnp.asarray(rng.integers(0, V, size=(B, N)))
    dest = jnp.asarray(rng.integers(0, Tn, size=(B, N)))
    mis = jnp.full((B, N), -1, dtype=jnp.int32)
    meta = jnp.zeros((B, N), dtype=jnp.int32)
    out_b, vc_b, meta_b = jax.vmap(route_fn)(cur, dest, mis, meta)
    for i in range(B):
        out, vc, m = route_fn(cur[i], dest[i], mis[i], meta[i])
        np.testing.assert_array_equal(np.asarray(out_b[i]), np.asarray(out))
        np.testing.assert_array_equal(np.asarray(vc_b[i]), np.asarray(vc))
        np.testing.assert_array_equal(np.asarray(meta_b[i]), np.asarray(m))


def test_step_grants_at_most_one_winner_per_channel(cgroup_net):
    cfg = SimConfig(warmup=10, measure=10, vcs_per_class=2)
    consts, route_fn = engine.build_consts(cgroup_net, cfg)
    inject = engine.make_inject_fn(cgroup_net, cfg, consts, TR.uniform(cgroup_net))
    arbitrate = engine.make_arbitrate_fn(cgroup_net, cfg, consts, route_fn)
    state = make_state(cgroup_net, cfg, consts["NV"])
    key = jax.random.PRNGKey(0)
    apply_moves = engine.make_apply_fn(cgroup_net, cfg, consts)
    for t in range(8):
        key, sub = jax.random.split(key)
        state = inject(state, t, sub, jnp.float32(0.9))
        req, win, won_ch = arbitrate(state, t)
        assert isinstance(req, Requests)
        # one winner per output channel
        outs = np.asarray(req.out)[np.asarray(win)]
        assert len(outs) == len(np.unique(outs))
        # winners must be valid requesters
        assert bool((np.asarray(win) <= np.asarray(req.valid)).all())
        # the dense grant mask agrees with the winner rows
        assert set(outs) == set(np.flatnonzero(np.asarray(won_ch)))
        state = apply_moves(state, req, win, won_ch, t)
        # occupancy never exceeds capacity, never goes negative
        bc = np.asarray(state.b_count)
        assert bc.min() >= 0 and bc.max() <= cfg.buf_pkts


def test_batched_sweep_matches_sequential(cgroup_net):
    """Acceptance: >= 6 rates x 2 seeds, throughput/latency within 2% of
    per-rate sequential Simulator.run, ONE jit compile for the whole sweep."""
    cfg = SimConfig(warmup=100, measure=400, vcs_per_class=2)
    sim = Simulator(cgroup_net, cfg, TR.uniform(cgroup_net))
    rates = [0.2, 0.5, 0.9, 1.4, 2.0, 2.6]
    seeds = (0, 1)
    # the jit-cache introspection is a private JAX API; sweep.py degrades
    # gracefully without it, and so does this assertion
    has_cache_api = hasattr(run_scan_batched, "clear_cache") and \
        hasattr(run_scan_batched, "_cache_size")
    if has_cache_api:
        run_scan_batched.clear_cache()
    grid = sim.sweep_grid(rates, seeds)
    if has_cache_api:
        assert grid.compile_count == 1
        assert run_scan_batched._cache_size() == 1
    for i, r in enumerate(rates):
        for j, s in enumerate(seeds):
            seq = sim.run(r, seed=s)
            bat = grid.result(i, j)
            assert bat.throughput_per_chip == pytest.approx(
                seq.throughput_per_chip, rel=0.02)
            assert bat.avg_latency == pytest.approx(seq.avg_latency, rel=0.02)
    # curve-level reductions
    sat = grid.saturation_throughput()
    assert sat == max(r.throughput_per_chip for r in grid.mean_over_seeds())


def test_sweep_rejects_overdriven_rate(cgroup_net):
    cfg = SimConfig(warmup=10, measure=10)
    sweep = BatchedSweep(cgroup_net, cfg, TR.uniform(cgroup_net))
    with pytest.raises(ValueError):
        sweep.run([100.0])


def test_simulator_sweep_facade(cgroup_net):
    """Simulator.sweep keeps the historical list[SimResult] contract."""
    cfg = SimConfig(warmup=50, measure=200, vcs_per_class=2)
    sim = Simulator(cgroup_net, cfg, TR.uniform(cgroup_net))
    rates = [0.3, 0.6]
    out = sim.sweep(rates)
    assert len(out) == len(rates)
    assert [r.offered_per_chip for r in out] == rates
    assert all(r.throughput_per_chip > 0 for r in out)
