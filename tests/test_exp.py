"""Declarative experiment API: construction-time validation, JSON
round-trips across every registered scenario, and runner parity with the
legacy imperative `Simulator.sweep` path (lane-for-lane, one compile per
grid)."""
import json

import numpy as np
import pytest

from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.simulator import SimConfig, Simulator
from repro.exp import (ExperimentSpec, FaultSpec, RoutingSpec, SweepAxes,
                       TopologySpec, TrafficSpec)
from repro.exp import registry
from repro.exp.runner import cells, run_experiment


# ---------------------------------------------------------------------------
# Construction-time validation
# ---------------------------------------------------------------------------

def _minimal_spec(**kw):
    base = dict(
        name="t",
        topologies=TopologySpec.switchless(a=1, b=1, m=2, n=6, noc=2, g=1),
        traffics=TrafficSpec("uniform"),
        routings=RoutingSpec(),
        axes=SweepAxes(rates=(0.5,), warmup=10, measure=20))
    base.update(kw)
    return ExperimentSpec(**base)


def test_topology_spec_validates():
    with pytest.raises(ValueError):
        TopologySpec("mesh3d")                       # unknown kind
    with pytest.raises(ValueError):
        TopologySpec.switchless(a=1)                 # missing fields
    with pytest.raises(ValueError):
        TopologySpec.switchless(a=1, b=1, m=2, n=6, noc=2, g=99)  # g range
    with pytest.raises(ValueError):
        TopologySpec.preset("radix99_switchless")    # unknown preset


def test_topology_spec_canonicalizes_defaults():
    """Specs naming the same network compare equal whether or not
    defaults were spelled out."""
    a = TopologySpec.switchless(a=1, b=1, m=2, n=6, noc=2, g=1, label="x")
    b = TopologySpec.switchless(a=1, b=1, m=2, n=6, noc=2, g=1,
                                cg_bw_mult=1, lr_latency=8, label="x")
    assert a == b and hash(a) == hash(b)


def test_traffic_spec_validates():
    with pytest.raises(ValueError):
        TrafficSpec("nope")
    with pytest.raises(ValueError):
        TrafficSpec("hotspot", params=(("bogus_param", 1),))
    # param order canonicalizes
    a = TrafficSpec("hotspot", params=(("seed", 0), ("num_hot", 4)))
    b = TrafficSpec("hotspot", params=(("num_hot", 4), ("seed", 0)))
    assert a == b and hash(a) == hash(b)


def test_routing_spec_validates():
    with pytest.raises(ValueError):
        RoutingSpec(route_mode="teleport")
    with pytest.raises(ValueError):
        RoutingSpec(vc_mode="reduced")
    # updown_merged requires restricted misrouting
    with pytest.raises(ValueError):
        RoutingSpec(vc_mode="updown_merged", route_mode="val")
    RoutingSpec(vc_mode="updown_merged", route_mode="val_restricted")
    with pytest.raises(ValueError):
        RoutingSpec(buf_pkts=0)


def test_fault_spec_validates():
    with pytest.raises(ValueError):
        FaultSpec(kind="gremlins")
    with pytest.raises(ValueError):
        FaultSpec(kind="links", frac=1.5)
    with pytest.raises(ValueError):
        FaultSpec(kind="links", types=("optical",))
    with pytest.raises(ValueError):
        FaultSpec(kind="routers", num=-1)


def test_fault_spec_warm_form():
    # onsets need a kind, positive strictly increasing cycles
    with pytest.raises(ValueError):
        FaultSpec(onsets=(100,))                      # kind none
    with pytest.raises(ValueError):
        FaultSpec(kind="links", frac=0.1, onsets=(0,))
    with pytest.raises(ValueError):
        FaultSpec(kind="links", frac=0.1, onsets=(50, 50))
    warm = FaultSpec(kind="links", frac=0.1, onsets=(50, 90))
    assert warm.is_warm and "@50,90" in warm.label
    assert not FaultSpec(kind="links", frac=0.1).is_warm
    # serialization round-trips the onsets
    assert FaultSpec.from_dict(warm.to_dict()) == warm
    # an onset past the cycle budget would never activate while the
    # accounting reported its degradation: rejected at the axes level
    with pytest.raises(ValueError, match="never activate"):
        SweepAxes(rates=(0.5,), faults=(warm,), warmup=10, measure=30)
    SweepAxes(rates=(0.5,), faults=(warm,), warmup=10, measure=100)


def test_fault_spec_warm_sample_is_monotone_schedule():
    from repro.core.topology import FaultSchedule
    net = T.build_switchless(
        T.SwitchlessParams(a=2, b=2, m=2, n=4, noc=2, g=5), "exp-warm")
    warm = FaultSpec(kind="links", frac=0.12, onsets=(60, 120), seed=4)
    sch = warm.sample(net, "updown", 0)
    assert isinstance(sch, FaultSchedule)
    assert [c for c, _ in sch.epochs] == [0, 60, 120]
    assert sch.epochs[0][1].is_empty
    # monotone growth: each epoch contains the previous one
    assert set(sch.epochs[1][1].dead_ch) <= set(sch.epochs[2][1].dead_ch)
    assert not sch.epochs[1][1].is_empty
    sch.validate(net, "updown")
    # the cold form of the same spec stays a plain FaultSet
    from repro.core.topology import FaultSet
    cold = FaultSpec(kind="links", frac=0.12, seed=4).sample(net, "updown", 0)
    assert isinstance(cold, FaultSet)


def test_get_scenario_fast_full_builders():
    full = registry.get_scenario("fig11", fast=False)
    fast = registry.get_scenario("fig11", fast=True)
    assert full.axes.measure > fast.axes.measure
    # the registered default IS the builder's fast instance
    assert registry.get_scenario("fig11") == fast
    with pytest.raises(KeyError):
        registry.get_scenario("smoke", fast=True)   # no builder
    with pytest.raises(KeyError):
        registry.get_scenario("nope")
    # the yield curve scales from g=3 (fast) to g=9 (full)
    yc_fast = registry.get_scenario("yield_curve", fast=True)
    yc_full = registry.get_scenario("yield_curve", fast=False)
    assert dict(yc_fast.topologies[0].params)["g"] == 3
    assert dict(yc_full.topologies[0].params)["g"] == 9


def test_sweep_axes_validate():
    with pytest.raises(ValueError):
        SweepAxes(rates=())
    with pytest.raises(ValueError):
        SweepAxes(rates=(0.5,), seeds=())
    with pytest.raises(ValueError):
        SweepAxes(rates=(-0.1,))
    with pytest.raises(ValueError):
        SweepAxes(rates=(0.5,), measure=0)


def test_cross_axis_validation():
    # dragonfly baseline cannot take an up*/down* VC scheme
    with pytest.raises(ValueError):
        _minimal_spec(topologies=TopologySpec.dragonfly(t=4, l=0, gl=0, g=1),
                      routings=RoutingSpec(vc_mode="updown"))
    # mesh/local faults need an up*/down* vc_mode on switchless
    with pytest.raises(ValueError):
        _minimal_spec(axes=SweepAxes(
            rates=(0.5,), faults=(FaultSpec(kind="links", frac=0.05),),
            warmup=10, measure=20))
    # GLOBAL-only faults are fine under baseline (need a multi-W-group net)
    _minimal_spec(
        topologies=TopologySpec.switchless(a=2, b=2, m=2, n=4, noc=2, g=5),
        axes=SweepAxes(rates=(0.5,),
                       faults=(FaultSpec(kind="links", frac=0.05,
                                         types=("global",)),),
                       warmup=10, measure=20))
    # clustered wafer defects only exist on switchless
    with pytest.raises(ValueError):
        _minimal_spec(topologies=TopologySpec.dragonfly(t=4, l=0, gl=0, g=1),
                      axes=SweepAxes(rates=(0.5,),
                                     faults=(FaultSpec(kind="clusters"),),
                                     warmup=10, measure=20))


# ---------------------------------------------------------------------------
# Serialization
# ---------------------------------------------------------------------------

def test_every_registered_scenario_round_trips():
    names = registry.list_scenarios()
    assert {"fig10a", "fig11", "fig13", "bench_faults",
            "smoke"} <= set(names)
    for name in names:
        spec = registry.get_scenario(name)
        wire = json.loads(json.dumps(spec.to_dict()))   # via real JSON
        back = ExperimentSpec.from_dict(wire)
        assert back == spec, name
        assert hash(back) == hash(spec), name


def test_from_dict_rejects_future_schema():
    d = registry.get_scenario("smoke").to_dict()
    d["version"] = 999
    with pytest.raises(ValueError):
        ExperimentSpec.from_dict(d)


def test_register_scenario_rejects_duplicates():
    spec = registry.get_scenario("smoke")
    with pytest.raises(ValueError):
        registry.register_scenario(spec)
    registry.register_scenario(spec, replace=True)  # idempotent escape


# ---------------------------------------------------------------------------
# Lowering / runner parity
# ---------------------------------------------------------------------------

def test_cells_enumerates_outer_product():
    spec = registry.get_scenario("fig10cf")
    cs = list(cells(spec))
    assert len(cs) == spec.num_grids == 6      # 3 topologies x 2 traffics
    assert cs[0].net.meta["kind"] == "switchless"
    assert cs[-1].net.meta["kind"] == "dragonfly"
    # hotspot cells resolve to a masked pattern
    hot = next(c for c in cells(registry.get_scenario("fig13"))
               if c.traffic.pattern == "hotspot")
    assert hot.pattern.inject_mask is not None
    assert hot.pattern.inject_mask.dtype == bool


def test_run_experiment_matches_legacy_sweep_lane_for_lane():
    """Acceptance: a registered Fig. 10 scenario lowered via
    `run_experiment` reproduces the legacy `Simulator.sweep` grid
    lane-for-lane, with exactly ONE compile per (rate x seed) grid."""
    spec = registry.get_scenario("smoke_fig10a")
    res = run_experiment(spec)
    assert [g.compile_count for g in res.grids] == [1, 1]  # one per grid
    rates, seeds = list(spec.axes.rates), list(spec.axes.seeds)
    for grid, cell in zip(res.grids, cells(spec)):
        sim = Simulator(cell.net, cell.cfg, cell.pattern)
        legacy = sim.sweep_grid(rates, seeds)
        for i in range(len(rates)):
            for j in range(len(seeds)):
                mine, ref = grid.result(0, i, j), legacy.result(i, j)
                assert mine.throughput_per_chip == pytest.approx(
                    ref.throughput_per_chip, rel=1e-9)
                assert mine.avg_latency == pytest.approx(
                    ref.avg_latency, rel=1e-9)
                assert mine.delivered_pkts == ref.delivered_pkts
        # seed-averaged rows match the Simulator.sweep list contract
        mean_legacy = sim.sweep(rates, seeds)
        mean_mine = grid.sweep_result(0).mean_over_seeds()
        for a, b in zip(mean_mine, mean_legacy):
            assert a.throughput_per_chip == pytest.approx(
                b.throughput_per_chip, rel=1e-9)
    # re-running the same spec reuses every compiled step: zero compiles
    res2 = run_experiment(spec)
    assert res2.compile_counts == [0, 0]
    assert res2.grids[0].result(0, 0, 0).delivered_pkts == \
        res.grids[0].result(0, 0, 0).delivered_pkts


def test_fault_grid_single_compile_and_degradation():
    """A (fault x rate x seed) grid lowers to one compile; the degraded
    row delivers less than the pristine row; per-lane fault sets come
    from the spec's seeded sampling streams."""
    spec = registry.get_scenario("smoke_faults")
    res = run_experiment(spec)
    [grid] = res.grids
    assert grid.compile_count == 1
    assert grid.fault_labels == ["pristine", "links:0.08"]
    assert grid.fault_fracs[0] == 0.0
    assert grid.fault_fracs[1] > 0.0
    pristine = [grid.result(0, 0, j).delivered_pkts for j in range(2)]
    degraded = [grid.result(1, 0, j).delivered_pkts for j in range(2)]
    assert sum(degraded) < sum(pristine)
    # per_seed sampling: seed lanes of the faulty row differ
    f0 = spec.axes.faults[1].sample(grid.topology.build(), "updown", 0)
    f1 = spec.axes.faults[1].sample(grid.topology.build(), "updown", 1)
    assert f0 != f1


# ---------------------------------------------------------------------------
# Normalized traffic protocol + satellite regressions
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_net():
    return T.build_switchless(
        T.SwitchlessParams(a=2, b=1, m=2, n=4, noc=2, g=2), "exp-traffic")


def test_every_pattern_returns_normalized_pair(small_net):
    import jax
    key = jax.random.PRNGKey(0)
    for name in TR.PATTERNS:
        pat = TR.make_pattern(small_net, name)
        assert isinstance(pat, TR.TrafficPattern)
        sample, mask = pat                     # uniform unpack contract
        assert callable(sample)
        assert mask is None or (np.asarray(mask).dtype == bool
                                and mask.shape == (small_net.num_terminals,))
        d = np.asarray(pat(key, 0))            # callable contract
        assert d.shape == (small_net.num_terminals,)
        assert (0 <= d).all() and (d < small_net.num_terminals).all()
    # the historical asymmetry: hotspot's mask now rides the pattern
    assert TR.make_pattern(small_net, "hotspot",
                           num_hot=2).inject_mask is not None


def test_as_pattern_composes_masks(small_net):
    T_ = small_net.num_terminals
    pat = TR.make_pattern(small_net, "hotspot", num_hot=2, seed=0)
    extra = np.zeros(T_, dtype=bool)
    extra[:4] = True
    combined = TR.as_pattern(pat, extra)
    np.testing.assert_array_equal(
        combined.inject_mask, np.asarray(pat.inject_mask) & extra)
    # idempotent on normalized patterns
    again = TR.as_pattern(combined)
    np.testing.assert_array_equal(again.inject_mask, combined.inject_mask)


def test_terms_per_group_missing_meta_raises():
    """Regression: used to return None and blow up later as a confusing
    TypeError inside the pattern factory."""
    import types
    fake = types.SimpleNamespace(meta={"g": 2})
    with pytest.raises(KeyError, match="terms_per_wg.*terms_per_grp"):
        TR._terms_per_group(fake)
