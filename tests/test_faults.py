"""Fault-injection subsystem: FaultSet semantics, sampler invariants,
deadlock freedom on degraded networks, fault-avoiding routing, and the
engine's fault-masked phase pipeline + batched failure-rate sweeps."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import engine
from repro.core import routing as R
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.engine import build_lane
from repro.core.simulator import SimConfig, Simulator


@pytest.fixture(scope="module")
def net():
    return T.build_switchless(
        T.SwitchlessParams(a=2, b=2, m=2, n=4, noc=2, g=5), "faults-net")


@pytest.fixture(scope="module")
def small_net():
    """Two C-groups x 4 W-groups, 128 terminals: engine-level fault tests
    compile in seconds here and exercise every channel type."""
    return T.build_switchless(
        T.SwitchlessParams(a=1, b=2, m=2, n=4, noc=2, g=4), "faults-small")


# --- FaultSet semantics ------------------------------------------------------

def test_dead_router_kills_incident_channels_and_terminal(net):
    v = 17
    f = T.FaultSet(dead_routers=(v,))
    alive = f.ch_alive(net)
    incident = (net.ch_src == v) | (net.ch_dst == v)
    assert (~alive[incident]).all()
    assert alive[~incident].all()
    ta = f.term_alive(net)
    assert not ta[v]                      # one terminal per router here
    assert ta.sum() == net.num_terminals - 1


def test_dead_channel_masks_only_itself(net):
    e = int(np.where(net.ch_type == T.GLOBAL)[0][0])
    f = T.FaultSet(dead_ch=(e,))
    alive = f.ch_alive(net)
    assert not alive[e] and alive.sum() == net.num_channels - 1
    assert f.frac_links_failed(net) > 0
    assert T.FaultSet().is_empty and f.union(T.FaultSet()) == f


def test_validate_rejects_unroutable_faults(net):
    # baseline vc_mode only tolerates GLOBAL faults
    mesh = int(np.where(net.ch_type == T.MESH)[0][0])
    rev = T.reverse_fabric_channel(net)
    with pytest.raises(ValueError):
        T.validate_faults(net, T.FaultSet(dead_ch=(mesh, int(rev[mesh]))),
                          vc_mode="baseline")
    # mesh faults must kill both directions
    with pytest.raises(ValueError):
        T.validate_faults(net, T.FaultSet(dead_ch=(mesh,)), "updown")
    # killing every global link of a W-group pair is unroutable
    t = net.tables
    ab = net.meta["ab"]
    chs = []
    npar = t["glob_route_cg"].shape[-1]
    for r in range(npar):
        cg = t["glob_route_cg"][0, 1, r]
        if cg >= 0:
            ch = t["ext_out"][cg, t["glob_route_port"][0, 1, r]]
            if ch >= 0:
                chs.append(int(ch))
    assert chs
    with pytest.raises(ValueError):
        T.validate_faults(net, T.FaultSet(dead_ch=tuple(chs)), "updown")


def test_samplers_produce_valid_fault_sets(net):
    rng = np.random.default_rng(5)
    fl = T.sample_link_faults(net, 0.1, rng)
    fr = T.sample_router_faults(net, 8, rng)
    fc = T.sample_cluster_faults(net, rng, num_clusters=2, radius=1)
    for f in (fl, fr, fc):
        info = T.validate_faults(net, f, "updown")
        assert info["alive_terminals"] > 0
    assert len(fl.dead_ch) > 0
    assert 0 < fl.frac_links_failed(net) <= 0.1 + 0.01
    assert len(fr.dead_routers) == 8
    assert len(fc.dead_routers) >= 3   # radius-1 cluster interior


def test_global_only_sampler_for_baseline(net):
    rng = np.random.default_rng(2)
    f = T.sample_link_faults(net, 0.25, rng, types=(T.GLOBAL,),
                             vc_mode="baseline")
    assert len(f.dead_ch) > 0
    assert (net.ch_type[list(f.dead_ch)] == T.GLOBAL).all()
    T.validate_faults(net, f, "baseline")


# --- deadlock freedom + fault avoidance on degraded networks -----------------

def _fault_for(net, vc_mode: str, seed: int) -> T.FaultSet:
    rng = np.random.default_rng(seed)
    if vc_mode == "baseline":
        return T.sample_link_faults(net, 0.3, rng, types=(T.GLOBAL,),
                                    vc_mode="baseline")
    # mix of a dead-router cluster and link failures composed on top of it
    # (the composed set is validated as a whole)
    cluster = T.sample_cluster_faults(net, rng, num_clusters=1, radius=1,
                                      vc_mode=vc_mode)
    return T.sample_link_faults(net, 0.08, rng, vc_mode=vc_mode,
                                base=cluster)


@pytest.mark.parametrize("mode", ["baseline", "updown", "updown_merged"])
@pytest.mark.parametrize("seed", [11, 23])
def test_deadlock_freedom_under_faults(net, mode, seed):
    """Acceptance: `assert_deadlock_free` on >= 2 distinct faulted networks
    per vc_mode; the traced paths must also avoid every dead channel."""
    faults = _fault_for(net, mode, seed)
    assert not faults.is_empty
    rng = np.random.default_rng(seed)
    edges = R.assert_deadlock_free(net, mode, nonminimal=True, rng=rng,
                                   n_pairs=4000, faults=faults)
    assert edges > 0


def test_vc_bounds_hold_under_faults(net):
    """The VC budget of each scheme survives degradation: rebuilt tables
    never push a packet past its class bound."""
    rng = np.random.default_rng(99)
    f = _fault_for(net, "updown", 99)
    alive_t = np.flatnonzero(f.term_alive(net))
    s = alive_t[rng.integers(0, len(alive_t), 3000)]
    d = alive_t[rng.integers(0, len(alive_t), 3000)]
    keep = s != d
    s, d = s[keep], d[keep]
    g = net.meta["g"]
    wg = net.tables["node_wg"]
    wg_s, wg_d = wg[net.term_node[s]], wg[net.term_node[d]]
    mis = rng.integers(0, g, size=len(s))
    mis = np.where((mis == wg_s) | (mis == wg_d), -1, mis)
    for mode, bound in [("updown", 3), ("updown_merged", 2)]:
        rf = R.make_route_fn(net, mode, f)
        m = mis if mode != "updown_merged" else np.where(mis < wg_d, mis, -1)
        _, vcs, _ = R.trace_paths(net, rf, s, d, m)
        assert int(vcs.max()) + 1 <= bound, mode


def test_faulted_updown_tables_avoid_dead_routers(net):
    f = _fault_for(net, "updown", 11)
    rank, nh = R.build_updown_tables(net, faults=f)
    g = net.meta["g"]
    NW = net.meta["ab"] * net.meta["nodes_per_cg"]
    node_alive = f.node_alive(net).reshape(g, NW)
    for wg in range(g):
        dead = np.where(~node_alive[wg])[0]
        alive = np.where(node_alive[wg])[0]
        if len(dead) == 0:
            continue
        # no alive->alive next hop ever routes through a dead router
        sub = nh[wg][np.ix_(alive, alive)]
        assert not np.isin(sub, dead).any()
    # pristine W-groups keep the pristine tables
    rank0, nh0 = R.build_updown_tables(net)
    untouched = [wg for wg in range(g)
                 if node_alive[wg].all()
                 and not np.isin(np.asarray(f.dead_ch),
                                 np.where(net.ch_src // NW == wg)[0]).any()]
    for wg in untouched:
        np.testing.assert_array_equal(nh[wg], nh0[wg])


def test_global_repick_spreads_over_alive_links(net):
    """Killing one parallel global link must redirect its flows onto the
    surviving parallel links of the same W-group pair."""
    wired = T._wired_global_links(net)
    w, u = 0, 1
    links = wired[w, u][wired[w, u] >= 0]
    if len(links) < 2:
        pytest.skip("net has no parallel global links for this pair")
    f = T.FaultSet(dead_ch=(int(links[0]),))
    fl = R.route_tables(net, "baseline", f)
    cnt = np.asarray(fl["glob_cnt"])
    idx = np.asarray(fl["glob_idx"])
    assert cnt[w, u] == len(links) - 1
    assert 0 not in idx[w, u, :cnt[w, u]]


# --- engine under faults -----------------------------------------------------

def test_engine_never_grants_dead_channel(small_net):
    """Phase-level invariant on a degraded network: no granted movement
    targets a dead channel, buffers stay in range."""
    net = small_net
    faults = _fault_for(net, "updown", 23)
    cfg = SimConfig(warmup=10, measure=10, vc_mode="updown",
                    vcs_per_class=2)
    consts, route_kernel = engine.build_consts(net, cfg)
    inject = engine.make_inject_fn(net, cfg, consts, TR.uniform(net))
    arbitrate = engine.make_arbitrate_fn(net, cfg, consts, route_kernel)
    apply_moves = engine.make_apply_fn(net, cfg, consts)
    fl = build_lane(net, cfg, faults)
    alive = np.asarray(fl["ch_alive"])
    dead_terms = ~np.asarray(fl["term_alive"])
    state = engine.make_state(net, cfg, consts["NV"])
    key = jax.random.PRNGKey(1)
    granted = 0
    for t in range(20):
        key, sub = jax.random.split(key)
        state = inject(state, t, sub, jnp.float32(0.9), fl)
        req, win, won_ch = arbitrate(state, t, fl)
        w = np.asarray(win)
        granted += int(w.sum())
        assert alive[np.asarray(req.out)[w]].all()
        assert not np.asarray(won_ch)[~alive].any()
        state = apply_moves(state, req, win, won_ch, t)
        bc = np.asarray(state.b_count)
        assert bc.min() >= 0 and bc.max() <= cfg.buf_pkts
    assert granted > 0
    # dead terminals never accumulate source-queue packets
    assert (np.asarray(state.s_count)[dead_terms] == 0).all()


def test_faulted_lane_delivers_at_low_load(small_net):
    """A faulted BatchedSweep lane delivers (essentially) every generated
    packet at low load: nothing is routed into a dead channel and lost."""
    net = small_net
    faults = _fault_for(net, "updown", 11)
    cfg = SimConfig(warmup=200, measure=1200, vc_mode="updown",
                    vcs_per_class=2)
    sim = Simulator(net, cfg, TR.uniform(net), faults=faults)
    r = sim.run(0.1)
    assert r.dropped_pkts == 0
    assert r.generated_pkts > 200
    # in-flight slack: a packet generated near the end of the window is
    # still traversing the network when measurement stops
    assert r.delivered_pkts >= 0.9 * r.generated_pkts
    assert r.throughput_per_chip == pytest.approx(0.1, rel=0.15)


def test_batched_fault_grid_matches_sequential(small_net):
    """One batched failure-rate x seed sweep == per-lane sequential runs,
    with exactly one compile for the whole grid.  The sequential side
    reuses ONE compiled Simulator and swaps fault sets per run (fault data
    is a traced argument, not part of the compiled step)."""
    from repro.core.engine import sweep as sweep_mod
    net = small_net
    cfg = SimConfig(warmup=103, measure=397, vc_mode="updown",
                    vcs_per_class=2)
    pattern = TR.uniform(net)
    seeds = (0, 1)
    fault_grid = [
        [T.FaultSet()] * len(seeds),
        [_fault_for(net, "updown", 11)] * len(seeds),
        [_fault_for(net, "updown", 23), _fault_for(net, "updown", 37)],
    ]
    sim = Simulator(net, cfg, pattern)
    before = sweep_mod.compile_counter()
    grid = sim.sweep_faults(0.3, fault_grid, seeds=seeds)
    assert grid.compile_count == 1
    assert sweep_mod.compile_counter() - before == 1
    assert grid.fault_fracs[0] == 0.0
    assert grid.fault_fracs[1] > 0 and grid.fault_fracs[2] > 0
    for i, row in enumerate(fault_grid):
        for j, (f, s) in enumerate(zip(row, seeds)):
            seq = sim.run(0.3, seed=s, faults=None if f.is_empty else f)
            bat = grid.result(i, j)
            assert bat.delivered_pkts == seq.delivered_pkts
            assert bat.generated_pkts == seq.generated_pkts
            assert bat.throughput_per_chip == pytest.approx(
                seq.throughput_per_chip, rel=1e-6)
