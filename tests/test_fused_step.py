"""Fused cycle step (`step_impl="fused"`) vs the jnp oracle: bit parity.

The fused step reorders the whole cycle around per-channel winner
arbitration (route-once-per-hop caching, one segment-min grant, gather
pops — see repro/core/engine/fused.py) but must stay BIT-IDENTICAL to
the classic phase pipeline on every counter of every lane: same grants,
same pops, same stats, exact int and float equality.  Pinned here on
live engine runs across the three vc_modes, cold fault sets, and warm
`FaultSchedule`s (scheduled lanes exercise the per-cycle routing
fallback, pristine ones the cached fast path).

The `grant_impl="pallas"` variant routes the fused grant through the
`repro.kernels.netsim.cycle_core` Pallas kernel (interpret mode on CPU)
and must also be bit-identical; its standalone contract against the jnp
reduction is pinned in test_netsim_kernel.py-style form below.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.simulator import SimConfig, Simulator
from repro.core.topology import FaultSchedule, FaultSet

NET = T.build_switchless(
    T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=3), "fused-par")
GLOB = np.where(np.asarray(NET.ch_type) == T.GLOBAL)[0]
WARMUP, MEASURE = 40, 140


def _faults(vc_mode):
    if vc_mode == "baseline":
        return FaultSet(dead_ch=frozenset(int(c) for c in GLOB[:2]))
    return FaultSet(dead_routers=frozenset({5, 11}))


def _schedule(vc_mode):
    return FaultSchedule(((0, FaultSet()), (60, _faults(vc_mode))))


def _rows(cfg, faults):
    sim = Simulator(NET, cfg, TR.uniform(NET), faults=faults)
    return [(r.delivered_pkts, r.generated_pkts, r.dropped_pkts,
             r.avg_latency, r.throughput_per_chip, r.stranded_pkts,
             tuple(sorted(r.hops_by_type.items())))
            for r in sim.sweep([0.4, 1.2], seeds=(0, 1))]


CASES = [("baseline", "min", 2), ("baseline", "ugal", 1),
         ("updown", "val", 2), ("updown_merged", "min", 2)]


@pytest.mark.parametrize("vc_mode,route_mode,vpc", CASES)
@pytest.mark.parametrize("fkind", ["pristine", "cold", "warm"])
def test_fused_step_bit_identical(vc_mode, route_mode, vpc, fkind):
    faults = (None if fkind == "pristine"
              else _faults(vc_mode) if fkind == "cold"
              else _schedule(vc_mode))
    rows = {}
    for impl in ("jnp", "fused"):
        cfg = SimConfig(warmup=WARMUP, measure=MEASURE, vc_mode=vc_mode,
                        route_mode=route_mode, vcs_per_class=vpc,
                        step_impl=impl)
        rows[impl] = _rows(cfg, faults)
    assert rows["fused"] == rows["jnp"]


@pytest.mark.parametrize("fkind", ["pristine", "cold"])
def test_fused_pallas_grant_bit_identical(fkind):
    """grant_impl="pallas" inside the fused step (interpret mode on CPU)
    matches the jnp fused path exactly on a live engine run."""
    faults = None if fkind == "pristine" else _faults("baseline")
    rows = {}
    for gi in ("jnp", "pallas"):
        cfg = SimConfig(warmup=WARMUP, measure=MEASURE,
                        vc_mode="baseline", route_mode="min",
                        vcs_per_class=2, step_impl="fused",
                        grant_impl=gi)
        rows[gi] = _rows(cfg, faults)
    assert rows["pallas"] == rows["jnp"]


def test_cycle_core_matches_jnp_reduction():
    """The standalone kernel contract: cycle_core == the fused step's
    `_grant` segment-min (winner mask, winner row ids, pop mask) on
    random request tables, including all-ineligible channels."""
    from repro.core.engine.fused import _grant
    from repro.kernels.netsim import cycle_core

    rng = np.random.default_rng(7)
    for N, E in [(300, 37), (1024, 128), (77, 5)]:
        out = jnp.asarray(rng.integers(-1, E, N), jnp.int32)
        itime = jnp.asarray(rng.integers(0, 900, N), jnp.int32)
        ok = jnp.asarray(rng.random(N) < 0.6) & (out >= 0)
        ch_ok = jnp.asarray(rng.random(E) < 0.8)
        r2 = 1 << int(N - 1).bit_length()
        prio = jnp.arange(N, dtype=jnp.int32)
        won_ref, wprio_ref = _grant(ok, out, itime, prio, ch_ok, E, r2,
                                    True)
        won, wprio, win = cycle_core(out, itime, ok, ch_ok, r2=r2)
        assert (np.asarray(won) == np.asarray(won_ref)).all()
        assert (np.asarray(wprio) == np.asarray(wprio_ref)).all()
        # the emitted pop mask is the winner rows exactly
        wp = np.where(np.asarray(won_ref), np.asarray(wprio_ref), -1)
        exp = np.zeros(N, bool)
        exp[wp[wp >= 0]] = True
        assert (np.asarray(win) == exp).all()


def test_cycle_core_compiled_unsupported_on_cpu():
    """Non-interpret Pallas lowering is a TPU feature; on CPU the
    compiled attempt must fail loudly (bench_perf records it as
    `supported: false`), never silently produce wrong grants."""
    from repro.kernels.netsim import cycle_core

    if jax.default_backend() == "tpu":
        pytest.skip("compiled path is supported on TPU")
    out = jnp.zeros(16, jnp.int32)
    ok = jnp.ones(16, bool)
    ch_ok = jnp.ones(4, bool)
    with pytest.raises(Exception):
        jax.block_until_ready(jax.jit(
            lambda o, t, k, c: cycle_core(o, t, k, c, r2=32,
                                          interpret=False)
        )(out, out, ok, ch_ok))


def test_step_impl_spec_roundtrip():
    """RoutingSpec carries step_impl through validation, SimConfig
    lowering, and JSON round-trip."""
    from repro.exp.spec import ExperimentSpec, RoutingSpec, SweepAxes

    r = RoutingSpec(step_impl="fused")
    axes = SweepAxes(rates=(0.5,), warmup=10, measure=20)
    assert r.to_simconfig(axes).step_impl == "fused"
    assert RoutingSpec.from_dict(r.to_dict()) == r
    with pytest.raises(ValueError):
        RoutingSpec(step_impl="warp")
    spec = ExperimentSpec(
        name="x",
        topologies={"kind": "switchless",
                    "params": dict(a=1, b=1, m=2, n=6, noc=2, g=1)},
        traffics={"pattern": "uniform"}, routings=r, axes=axes)
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
