"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; the kernels target TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rglru import ops as rg_ops
from repro.kernels.rglru import ref as rg_ref
from repro.kernels.ssd_scan import ops as sd_ops
from repro.kernels.ssd_scan import ref as sd_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    denom = max(np.abs(b).max(), 1e-6)
    return np.abs(a - b).max() / denom


@pytest.mark.parametrize("B,Sq,Sk,H,KV,hd", [
    (1, 128, 128, 2, 2, 64),
    (2, 256, 256, 4, 2, 64),      # GQA groups=2
    (2, 192, 320, 4, 1, 80),      # MQA, ragged seq, odd head_dim
    (1, 512, 512, 8, 8, 128),     # MHA, aligned
    (1, 64, 64, 10, 1, 256),      # recurrentgemma-like heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Sk, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * Sq + hd), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, hd), dtype)
    o = fa_ops.flash_attention(q, k, v, causal=True)
    ref = fa_ref.attention_ref(q, k, v, causal=True)
    assert _err(o, ref) < TOL[dtype]


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 256, 2, 64), jnp.float32)
    o = fa_ops.flash_attention(q, k, v, causal=True, window=window)
    ref = fa_ref.attention_ref(q, k, v, causal=True, window=window)
    assert _err(o, ref) < TOL[jnp.float32]


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 96, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 96, 2, 64), jnp.float32)
    o = fa_ops.flash_attention(q, k, v, causal=False)
    ref = fa_ref.attention_ref(q, k, v, causal=False)
    assert _err(o, ref) < TOL[jnp.float32]


@given(st.integers(1, 3), st.sampled_from([64, 100, 192]),
       st.sampled_from([16, 64]), st.sampled_from([16, 32]))
@settings(max_examples=8, deadline=None)
def test_ssd_property(B, S, P, N):
    H = 2
    ks = jax.random.split(jax.random.PRNGKey(S * P + N), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = jnp.abs(jax.random.normal(ks[2], (H,))) + 0.1
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y = sd_ops.ssd_scan(x, dt, A, Bm, Cm, chunk=32)
    ref, _ = sd_ref.ssd_ref(x, dt, A, Bm, Cm)
    assert _err(y, ref) < 1e-4


@pytest.mark.parametrize("chunk", [16, 64, 128])
def test_ssd_chunk_invariance(chunk):
    """The chunked kernel result must not depend on the chunk size."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, H, P, N = 1, 160, 2, 32, 16
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = jnp.abs(jax.random.normal(ks[2], (H,))) + 0.1
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y = sd_ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    ref, _ = sd_ref.ssd_ref(x, dt, A, Bm, Cm)
    assert _err(y, ref) < 1e-4


@pytest.mark.parametrize("B,S,R,chunk,block_r", [
    (1, 128, 128, 64, 128),
    (2, 300, 192, 128, 128),     # padding both dims
    (2, 64, 512, 64, 256),
])
def test_rglru_sweep(B, S, R, chunk, block_r):
    ks = jax.random.split(jax.random.PRNGKey(R + S), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, R))) * 0.2 + 0.79
    b = jax.random.normal(ks[1], (B, S, R)) * 0.1
    h = rg_ops.rglru_scan(a, b, chunk=chunk, block_r=block_r)
    ref = rg_ref.rglru_scan_ref(a, b)
    assert _err(h, ref) < 1e-5


def test_rglru_long_decay_stability():
    """Long sequences with a ~ 1 must not blow up."""
    B, S, R = 1, 2048, 128
    a = jnp.full((B, S, R), 0.999, jnp.float32)
    b = jnp.full((B, S, R), 0.01, jnp.float32)
    h = rg_ops.rglru_scan(a, b)
    ref = rg_ref.rglru_scan_ref(a, b)
    assert _err(h, ref) < 1e-5
    assert bool(jnp.isfinite(h).all())


def test_models_chunked_attention_matches_kernel():
    """The model-side chunked jnp implementation agrees with the Pallas
    kernel (two independent flash implementations)."""
    from repro.models.layers import chunked_attention
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    B, S, H, hd = 2, 256, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    o1 = chunked_attention(q, k, v, causal=True, chunk_q=64, chunk_k=64)
    o2 = fa_ops.flash_attention(q, k, v, causal=True)
    assert _err(o1, o2) < 2e-5
