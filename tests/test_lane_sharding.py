"""Multi-device lane sharding: bit-identity and compile accounting.

The interesting backend state (4 forced XLA host devices) can only be
created before JAX initializes, so the multi-device half runs in a
SUBPROCESS with `REPRO_HOST_DEVICES=4`; the parent runs the identical
sweep single-device in-process and compares raw per-lane counters
exactly.  B=6 lanes on 4 devices exercises the ghost-lane padding path
(6 % 4 != 0 — the case the old `_lane_sharding` silently fell back to
single-device on).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.engine import sweep as sweep_mod
from repro.core.simulator import SimConfig, Simulator

# B = 3 rates x 2 seeds = 6 lanes; cycle count unique in the suite so the
# in-process run can never be a jit-cache hit from another test
RATES = [0.4, 0.9, 1.6]
SEEDS = (0, 1)
WARMUP, MEASURE = 43, 167

_CHILD = r"""
import json, sys
import repro            # applies REPRO_HOST_DEVICES before jax init
import jax
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.engine import sweep as sweep_mod
from repro.core.simulator import SimConfig, Simulator

assert len(jax.devices()) == 4, f"expected 4 devices, got {jax.devices()}"
net = T.build_switchless(
    T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=1), "shard-par")
cfg = SimConfig(warmup=%d, measure=%d, vcs_per_class=2)
sim = Simulator(net, cfg, TR.uniform(net))
before = sweep_mod.compile_counter()
grid = sim.sweep_grid(%s, seeds=%s)
print(json.dumps(dict(
    ndev=len(jax.devices()),
    compiles=sweep_mod.compile_counter() - before,
    grid_compiles=grid.compile_count,
    placement=grid.placement,
    rows=[dict(d=r.delivered_pkts, g=r.generated_pkts,
               dr=r.dropped_pkts, lat=r.avg_latency,
               thr=r.throughput_per_chip, hops=r.hops_by_type)
          for r in grid.flat()])))
""" % (WARMUP, MEASURE, RATES, list(SEEDS))


def _run_child(extra_env):
    env = dict(os.environ, **extra_env)
    # make the parent's import path (src layout or installed) visible
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in sys.path if p] +
        [p for p in (env.get("PYTHONPATH") or "").split(os.pathsep) if p])
    proc = subprocess.run([sys.executable, "-c", _CHILD],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.splitlines()[-1])


def _single_device_rows():
    net = T.build_switchless(
        T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=1), "shard-seq")
    cfg = SimConfig(warmup=WARMUP, measure=MEASURE, vcs_per_class=2)
    sim = Simulator(net, cfg, TR.uniform(net))
    before = sweep_mod.compile_counter()
    grid = sim.sweep_grid(RATES, seeds=SEEDS)
    return [dict(d=r.delivered_pkts, g=r.generated_pkts,
                 dr=r.dropped_pkts, lat=r.avg_latency,
                 thr=r.throughput_per_chip, hops=r.hops_by_type)
            for r in grid.flat()], sweep_mod.compile_counter() - before


def test_sharded_non_multiple_lanes_bit_identical():
    """Acceptance: B=6 lanes on 4 forced host devices (ghost-padded to 8)
    reproduce the single-device sweep lane-for-lane, bit for bit, with
    exactly one compile.  REPRO_SHARD_MIN_WORK=0 disables the small-grid
    gate (this grid is deliberately tiny; by default it would run
    single-device — see test_small_grid_stays_single_device)."""
    child = _run_child({"REPRO_HOST_DEVICES": "4",
                        "REPRO_SHARD_MIN_WORK": "0"})
    assert child["ndev"] == 4
    assert child["compiles"] == 1
    assert child["grid_compiles"] == 1
    assert child["placement"] == "lanes:4"
    rows, compiles = _single_device_rows()
    assert compiles == 1
    assert child["rows"] == rows       # exact: ints and float equality


def test_small_grid_stays_single_device():
    """The min-work gate: a grid under REPRO_SHARD_MIN_WORK lane-cycles
    skips lane sharding even on a multi-device host (dispatch overhead
    dominates there), and records the choice in `placement`."""
    child = _run_child({"REPRO_HOST_DEVICES": "4"})
    assert child["ndev"] == 4
    assert child["placement"] == "single"
    assert child["compiles"] == 1
    rows, _ = _single_device_rows()
    assert child["rows"] == rows


def test_repro_host_devices_knob():
    """The env knob forces the CPU device count (and parses strictly)."""
    out = subprocess.run(
        [sys.executable, "-c",
         "import repro, jax; print(len(jax.devices()))"],
        capture_output=True, text=True, timeout=300,
        env=dict(os.environ, REPRO_HOST_DEVICES="3",
                 PYTHONPATH=os.pathsep.join(p for p in sys.path if p)))
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.strip().endswith("3")
    bad = subprocess.run(
        [sys.executable, "-c", "import repro"],
        capture_output=True, text=True, timeout=300,
        env=dict(os.environ, REPRO_HOST_DEVICES="many",
                 PYTHONPATH=os.pathsep.join(p for p in sys.path if p)))
    assert bad.returncode != 0
    assert "REPRO_HOST_DEVICES" in bad.stderr


def test_lane_mesh_single_device_is_none():
    """Without forced devices the mesh helper opts out (no sharding)."""
    import jax
    if len(jax.devices()) == 1:
        assert sweep_mod.lane_mesh() is None
    else:                              # running under REPRO_HOST_DEVICES
        assert sweep_mod.lane_mesh() is not None
