"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, output shapes + no NaNs (full configs are
exercised via the dry-run only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.configs.registry import ARCHS, get_config
from repro.models import transformer as TF
from repro.optim.optimizer import OptConfig, adamw_update, init_opt_state


def _batch(cfg, B=2, S=16, key=0):
    rng = np.random.default_rng(key)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_prefix, cfg.d_model)) * 0.02,
            cfg.jdtype)
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            rng.normal(size=(B, S // 4 or 1, cfg.d_model)) * 0.02,
            cfg.jdtype)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_config(arch + "-smoke")
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, _, aux = TF.forward(params, cfg, batch, "train",
                                attn_impl="naive", remat=False)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step_reduces_loss(arch):
    """A few optimizer steps on a repeated batch must reduce the loss."""
    cfg = get_config(arch + "-smoke")
    params = TF.init_params(jax.random.PRNGKey(1), cfg)
    opt_state = init_opt_state(params)
    ocfg = OptConfig(lr=3e-3, warmup_steps=1, total_steps=50,
                     schedule="const", weight_decay=0.0)
    batch = _batch(cfg, key=7)

    @jax.jit
    def step(params, opt_state):
        (loss, _), grads = jax.value_and_grad(
            lambda p: TF.lm_loss(p, cfg, batch, attn_impl="naive",
                                 remat=False), has_aux=True)(params)
        params, opt_state, _ = adamw_update(ocfg, grads, opt_state, params)
        return params, opt_state, loss

    losses = []
    for _ in range(6):
        params, opt_state, loss = step(params, opt_state)
        losses.append(float(loss))
        assert np.isfinite(loss)
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ["minicpm-2b", "mamba2-780m",
                                  "recurrentgemma-2b", "qwen3-moe-235b-a22b",
                                  "seamless-m4t-medium"])
def test_prefill_then_decode_matches_full_forward(arch):
    """Greedy decode token from (prefill + decode) == token from a full
    forward pass at the same position."""
    cfg = get_config(arch + "-smoke")
    params = TF.init_params(jax.random.PRNGKey(2), cfg)
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S, key=3)
    # full forward over S tokens
    logits_full, _, _ = TF.forward(params, cfg, batch, "train",
                                   attn_impl="naive", remat=False)

    # prefill on the first S-1 tokens, then decode token S-1
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 1]
    cache = TF.init_cache(cfg, B, max_len=S)
    logits_pre, cache, _ = TF.forward(params, cfg, pre, "prefill",
                                      cache=cache, attn_impl="naive",
                                      remat=False)
    dec = {"tokens": batch["tokens"][:, S - 1:S]}
    if cfg.family == "encdec":
        # decoder consumes the precomputed encoder memory during decode
        mem, _, _ = TF.forward(params, cfg, pre, "train", attn_impl="naive",
                               remat=False), None, None
        dec["src_embeds"] = batch["src_embeds"]
    logits_dec, cache, _ = TF.forward(params, cfg, dec, "decode",
                                      cache=cache, attn_impl="naive",
                                      remat=False)
    a = np.asarray(logits_full[:, S - 1], np.float32)
    b = np.asarray(logits_dec[:, 0], np.float32)
    denom = np.abs(a).max() + 1e-6
    assert np.abs(a - b).max() / denom < 0.06, \
        f"decode mismatch {np.abs(a - b).max() / denom}"


def test_vlm_prefix_changes_logits():
    cfg = get_config("phi-3-vision-4.2b-smoke")
    params = TF.init_params(jax.random.PRNGKey(3), cfg)
    b1 = _batch(cfg, key=5)
    b2 = dict(b1)
    b2["prefix_embeds"] = b1["prefix_embeds"] + 1.0
    l1, _, _ = TF.forward(params, cfg, b1, "train", attn_impl="naive",
                          remat=False)
    l2, _, _ = TF.forward(params, cfg, b2, "train", attn_impl="naive",
                          remat=False)
    assert float(jnp.abs(l1 - l2).max()) > 1e-3


def test_encdec_memory_changes_logits():
    cfg = get_config("seamless-m4t-medium-smoke")
    params = TF.init_params(jax.random.PRNGKey(3), cfg)
    b1 = _batch(cfg, key=5)
    b2 = dict(b1)
    b2["src_embeds"] = b1["src_embeds"] * -2.0
    l1, _, _ = TF.forward(params, cfg, b1, "train", attn_impl="naive",
                          remat=False)
    l2, _, _ = TF.forward(params, cfg, b2, "train", attn_impl="naive",
                          remat=False)
    assert float(jnp.abs(l1 - l2).max()) > 1e-3


def test_chunked_equals_naive_attention_in_model():
    cfg = get_config("llama3.2-3b-smoke")
    params = TF.init_params(jax.random.PRNGKey(4), cfg)
    batch = _batch(cfg, B=1, S=64, key=9)
    l1, _, _ = TF.forward(params, cfg, batch, "train", attn_impl="naive",
                          remat=False)
    l2, _, _ = TF.forward(params, cfg, batch, "train", attn_impl="chunked",
                          remat=False)
    a = np.asarray(l1, np.float32)
    b = np.asarray(l2, np.float32)
    assert np.abs(a - b).max() / (np.abs(a).max() + 1e-6) < 0.03


def test_moe_aux_loss_positive_and_capacity_drops():
    cfg = get_config("deepseek-moe-16b-smoke")
    params = TF.init_params(jax.random.PRNGKey(5), cfg)
    batch = _batch(cfg, B=2, S=32, key=11)
    _, _, aux = TF.forward(params, cfg, batch, "train", attn_impl="naive",
                           remat=False)
    assert float(aux) > 0.0
