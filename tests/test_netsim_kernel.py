"""Parity tests for the fused netsim grant kernel (interpret mode).

Acceptance: `repro.kernels.netsim.grant` is bit-identical to the engine's
`jax.ops.segment_min` path (`age_based_grant`, the default and oracle)
across all three vc_modes x {pristine, faulted}, on REAL request vectors
produced by driving the engine — not just random fuzz — plus an
end-to-end `grant_impl="pallas"` sweep equal to the "jnp" sweep
lane-for-lane, and the `ExperimentSpec` JSON round-trip of the flag.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import engine
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.engine import build_lane, make_state
from repro.core.engine.arbitrate import (age_based_grant, expand_vcs,
                                         gather_requests)
from repro.core.simulator import SimConfig, Simulator
from repro.core.topology import EJECT
from repro.kernels.netsim import grant, grant_ref


@pytest.fixture(scope="module")
def net():
    return T.build_switchless(
        T.SwitchlessParams(a=2, b=2, m=2, n=4, noc=2, g=3), "netsim-grant")


def _faults_for(net, vc_mode):
    rng = np.random.default_rng(7)
    if vc_mode == "baseline":      # baseline can only route around globals
        return T.sample_link_faults(net, 0.2, rng, types=(T.GLOBAL,),
                                    vc_mode=vc_mode)
    return T.sample_link_faults(net, 0.08, rng, vc_mode=vc_mode)


def _drive(net, cfg, fl, cycles=8, rate=0.6):
    """Real engine states: inject + arbitrate + apply for a few cycles,
    yielding the (req, state) pairs the grant stage actually sees."""
    consts, route_kernel = engine.build_consts(net, cfg)
    inject = engine.make_inject_fn(net, cfg, consts, TR.uniform(net))
    apply_moves = engine.make_apply_fn(net, cfg, consts)
    state = make_state(net, cfg, consts["NV"])
    key = jax.random.PRNGKey(0)
    out = []
    for t in range(cycles):
        key, sub = jax.random.split(key)
        state = inject(state, t, sub, jnp.float32(rate), fl)
        req = gather_requests(state, consts, route_kernel, fl, t)
        req = expand_vcs(req, state, cfg)
        out.append((req, state))
        win, _, won = (lambda w: (w[0], None, w[1]))(
            age_based_grant(req, state, consts, cfg.buf_pkts,
                            fl["ch_alive"]))
        state = apply_moves(state, req, win, won, t)
    return consts, out


@pytest.mark.parametrize("vc_mode", ["baseline", "updown", "updown_merged"])
@pytest.mark.parametrize("faulted", [False, True])
def test_grant_parity_engine_states(net, vc_mode, faulted):
    """kernel == oracle == engine path, bit for bit, on live states."""
    cfg = SimConfig(vc_mode=vc_mode, vcs_per_class=2)
    faults = _faults_for(net, vc_mode) if faulted else None
    fl = build_lane(net, cfg, faults)
    consts, pairs = _drive(net, cfg, fl)
    saw_request = False
    for req, state in pairs:
        win_e, won_e = age_based_grant(req, state, consts, cfg.buf_pkts,
                                       fl["ch_alive"])
        args = (req.out, req.itime, req.valid, req.ovc_count,
                req.otype == EJECT, state.ch_busy, fl["ch_alive"])
        win_r, won_r = grant_ref(*args, buf_pkts=cfg.buf_pkts)
        win_k, won_k = grant(*args, buf_pkts=cfg.buf_pkts, interpret=True)
        np.testing.assert_array_equal(np.asarray(win_e), np.asarray(win_r))
        np.testing.assert_array_equal(np.asarray(won_e), np.asarray(won_r))
        np.testing.assert_array_equal(np.asarray(win_e), np.asarray(win_k))
        np.testing.assert_array_equal(np.asarray(won_e), np.asarray(won_k))
        saw_request = saw_request or bool(np.asarray(win_e).any())
    assert saw_request, "drive produced no grants — parity test is vacuous"


def test_grant_pallas_end_to_end_sweep():
    """`grant_impl='pallas'` reproduces the 'jnp' sweep lane-for-lane
    through the full batched engine (vmap over lanes included)."""
    net = T.build_switchless(
        T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=1), "netsim-e2e")
    results = {}
    for impl in ("jnp", "pallas"):
        cfg = SimConfig(warmup=31, measure=127, vcs_per_class=2,
                        grant_impl=impl)
        sim = Simulator(net, cfg, TR.uniform(net))
        grid = sim.sweep_grid([0.5, 1.2], seeds=(0,))
        results[impl] = [
            (r.delivered_pkts, r.generated_pkts, r.dropped_pkts,
             r.avg_latency, r.hops_by_type) for r in grid.flat()]
    assert results["jnp"] == results["pallas"]


def test_grant_impl_validation():
    with pytest.raises(ValueError, match="grant_impl"):
        SimConfig(grant_impl="magic")
    from repro.exp.spec import RoutingSpec
    with pytest.raises(ValueError, match="grant_impl"):
        RoutingSpec(grant_impl="magic")


def test_grant_impl_spec_json_round_trip():
    """Acceptance: cfg.grant_impl='pallas' round-trips through
    ExperimentSpec JSON and lowers into the SimConfig."""
    import json
    from repro.exp.spec import (ExperimentSpec, RoutingSpec, SweepAxes,
                                TopologySpec, TrafficSpec)
    spec = ExperimentSpec(
        name="netsim-roundtrip",
        topologies=TopologySpec.switchless(a=1, b=1, m=2, n=6, noc=2, g=1),
        traffics=TrafficSpec("uniform"),
        routings=RoutingSpec(grant_impl="pallas"),
        axes=SweepAxes(rates=(0.5,), warmup=10, measure=40))
    back = ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert back == spec
    assert back.routings[0].grant_impl == "pallas"
    assert back.routings[0].to_simconfig(back.axes).grant_impl == "pallas"
    # default stays the oracle path and old JSON (no field) still loads
    d = spec.to_dict()
    del d["routings"][0]["grant_impl"]
    assert (ExperimentSpec.from_dict(d).routings[0].grant_impl == "jnp")
