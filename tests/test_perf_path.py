"""Engine hot-path bookkeeping: the stranded-request gauge, the
compile-vs-run wall split, and the async lane dispatch."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import engine
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.engine import build_lane, make_state
from repro.core.engine.arbitrate import age_based_grant
from repro.core.engine.stats import accumulate
from repro.core.simulator import SimConfig, Simulator


@pytest.fixture(scope="module")
def updown_net():
    return T.build_switchless(
        T.SwitchlessParams(a=2, b=2, m=2, n=4, noc=2, g=3), "perf-path")


def test_stranded_gauge_counts_minus_one_requests(updown_net):
    """A head-of-line request parked on the -1 non-channel shows up in
    `SimStats.stranded` (and only there — it is never granted)."""
    net = updown_net
    cfg = SimConfig(vc_mode="updown", vcs_per_class=1)
    consts, route_kernel = engine.build_consts(net, cfg)
    fl = build_lane(net, cfg)
    state = make_state(net, cfg, consts["NV"])
    state = state.replace(
        b_count=state.b_count.at[0, 0].set(1),
        b_pkt=state.b_pkt.at[0, 0, 0].set(
            jnp.asarray([5, 0, -1, 0, 0], jnp.int32)))
    crafted = dict(fl, ud_nh=jnp.full_like(fl["ud_nh"], -1))
    arbitrate = engine.make_arbitrate_fn(net, cfg, consts, route_kernel)
    req, win, _ = arbitrate(state, 0, crafted)
    stats = accumulate(state.stats, req, win, consts, 0)
    assert int(stats.stranded) == 1
    assert not bool(np.asarray(win)[0])
    # on the pristine tables the same packet routes fine: gauge reads 0
    req2, win2, _ = arbitrate(state, 0, fl)
    assert int(accumulate(state.stats, req2, win2, consts, 0).stranded) == 0


def test_stranded_surfaces_in_simresult(updown_net):
    """Pristine end-to-end runs report stranded_pkts == 0; the field is
    wired through finalize and the seed-averaged reductions."""
    net = updown_net
    cfg = SimConfig(warmup=29, measure=111, vc_mode="updown",
                    vcs_per_class=2)
    sim = Simulator(net, cfg, TR.uniform(net))
    grid = sim.sweep_grid([0.4], seeds=(0, 1))
    assert all(r.stranded_pkts == 0 for r in grid.flat())
    assert grid.mean_over_seeds()[0].stranded_pkts == 0


def test_sweep_wall_split_excludes_compile(updown_net):
    """First call reports compile_s > 0 separately from wall_s; the
    cache-hit re-run reports compile_s == 0.0 and compiles == 0."""
    net = updown_net
    cfg = SimConfig(warmup=23, measure=97, vc_mode="updown",
                    vcs_per_class=2)
    sim = Simulator(net, cfg, TR.uniform(net))
    first = sim.sweep_grid([0.3, 0.6], seeds=(0,))
    assert first.compile_count == 1
    assert first.compile_s > 0.0
    assert first.wall_s > 0.0
    again = sim.sweep_grid([0.3, 0.6], seeds=(0,))
    assert again.compile_count == 0
    assert again.compile_s == 0.0
    for a, b in zip(first.flat(), again.flat()):
        assert a.delivered_pkts == b.delivered_pkts
        assert a.avg_latency == b.avg_latency


def test_run_lanes_async_matches_sync(updown_net):
    """Async dispatch + finish returns the same lane results as the
    synchronous path (which is itself async + immediate finish)."""
    net = updown_net
    cfg = SimConfig(warmup=19, measure=83, vc_mode="updown",
                    vcs_per_class=2)
    sim = Simulator(net, cfg, TR.uniform(net))
    sweep = sim._batched
    lanes = [(0.3, 0, None), (0.5, 1, None)]
    sync = sweep.run_lanes(lanes)
    pend = sweep.run_lanes_async(lanes)
    out = pend.finish()
    assert [r.delivered_pkts for r in out.results] == \
        [r.delivered_pkts for r in sync.results]
    assert out.compile_count == 0      # second dispatch reuses the cache


def test_expand_vcs_single_gather_matches_loop(updown_net):
    """Regression for the vectorized VC expansion: the [N, vpc] gather
    equals the old per-VC loop (argmin ties break toward the lowest VC)."""
    from repro.core.engine.arbitrate import expand_vcs
    net = updown_net
    cfg = SimConfig(vc_mode="updown", vcs_per_class=3)
    consts, route_kernel = engine.build_consts(net, cfg)
    fl = build_lane(net, cfg)
    inject = engine.make_inject_fn(net, cfg, consts, TR.uniform(net))
    apply_moves = engine.make_apply_fn(net, cfg, consts)
    state = make_state(net, cfg, consts["NV"])
    key = jax.random.PRNGKey(5)
    vpc = cfg.vcs_per_class
    for t in range(6):
        key, sub = jax.random.split(key)
        state = inject(state, t, sub, jnp.float32(0.7), fl)
        req = engine.arbitrate.gather_requests(state, consts, route_kernel,
                                               fl, t)
        got = expand_vcs(req, state, cfg)
        base = req.vc * vpc
        occs = jnp.stack(
            [state.b_count[req.out, base + i] for i in range(vpc)], axis=-1)
        want_vc = base + jnp.argmin(occs, axis=-1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(got.vc),
                                      np.asarray(want_vc))
        np.testing.assert_array_equal(np.asarray(got.ovc_count),
                                      np.asarray(jnp.min(occs, axis=-1)))
        win, won = age_based_grant(got, state, consts, cfg.buf_pkts,
                                   fl["ch_alive"])
        state = apply_moves(state, got, win, won, t)
