"""Full reliability lifecycle: repair (shrinking) epochs, the
router-death reaper, and the wafer-fleet Monte Carlo spec.

Three pillars, matching the acceptance criteria:

  * repair epochs — LIFO-reverting `FaultSpec.repairs` sampling, engine
    runs across a shrink, per-epoch + transition deadlock proofs in all
    three vc_modes, and the degenerate static repair schedule
    bit-identical to its cold equivalent;
  * the router-death reaper — exact conservation (generated ==
    delivered + dropped + reaped + in-flight, via the shared
    `conservation_trace` helper) on jnp, fused, AND compact steps,
    trace-for-trace identical across the impls, with the stranded gauge
    draining to zero (non-increasing) once injection stops and the park
    age elapses;
  * the wafer fleet — `FleetSpec` validation/lowering/round-trip, the
    registered `smoke_fleet` scenario, the multi-tenant serve inbox,
    and a tiny end-to-end `run_fleet` with shared executables.
"""
import json

import numpy as np
import pytest

from conftest import conservation_trace
from repro.core import routing as R
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.engine import sweep as sweep_mod
from repro.core.engine.state import resolve_reap_age
from repro.core.simulator import SimConfig, Simulator

IMPLS = ("jnp", "fused", "compact")


@pytest.fixture(scope="module")
def small_net():
    return T.build_switchless(
        T.SwitchlessParams(a=1, b=2, m=2, n=4, noc=2, g=4), "rel-small")


@pytest.fixture(scope="module")
def multi_wg_net():
    return T.build_switchless(
        T.SwitchlessParams(a=2, b=2, m=2, n=4, noc=2, g=5), "rel-multiwg")


def _link_faults(net, frac, seed, vc_mode="updown", base=None):
    return T.sample_link_faults(net, frac, np.random.default_rng(seed),
                                types=(T.MESH, T.LOCAL, T.GLOBAL),
                                vc_mode=vc_mode, base=base)


def _router_faults(net, num, seed, vc_mode="updown", base=None):
    return T.sample_router_faults(net, num, np.random.default_rng(seed),
                                  vc_mode=vc_mode, base=base)


# --- repair (shrinking) epochs -----------------------------------------------

def test_schedule_allows_shrink_and_full_recovery(small_net):
    f = _link_faults(small_net, 0.08, 2)
    sch = T.FaultSchedule(((0, T.FaultSet()), (40, f), (120, T.FaultSet())))
    assert sch.num_epochs == 3 and not sch.is_static
    assert sch.final.is_empty          # fully recovered
    assert sch.epoch_at(119) == 1 and sch.epoch_at(120) == 2


def test_faultspec_repairs_revert_lifo(multi_wg_net):
    """`repairs` revert growth increments last-broken-first-fixed, so
    every repair epoch's fault set is an already-validated wear-out
    state; equal lengths mean the wafer fully recovers."""
    from repro.exp import FaultSpec
    spec = FaultSpec(kind="routers", num=2, seed=7,
                     onsets=(50, 100), repairs=(150, 200))
    sch = spec.sample(multi_wg_net, "updown", lane_seed=1)
    assert isinstance(sch, T.FaultSchedule) and sch.num_epochs == 5
    cycles = [c for c, _ in sch.epochs]
    assert cycles == [0, 50, 100, 150, 200]
    sets = [s for _, s in sch.epochs]
    assert sets[3] == sets[1]          # first repair reverts increment 2
    assert sets[4] == sets[0] == T.FaultSet()   # full recovery
    assert set(sets[2].dead_routers) > set(sets[1].dead_routers)


def test_repair_schedule_deadlock_free_all_vc_modes(multi_wg_net):
    """Acceptance: a shrinking schedule proves deadlock-free in all 3
    vc_modes — per-epoch CDG acyclicity AND the in-flight transition
    proof across the shrink (resumed down-phase walks on the recovered
    subgraph's recomputed rank order)."""
    net = multi_wg_net
    rng = np.random.default_rng(11)
    for mode in ("baseline", "updown", "updown_merged"):
        f1 = _link_faults(net, 0.05, 13, vc_mode=mode)
        f2 = _link_faults(net, 0.05, 17, vc_mode=mode, base=f1)
        sch = T.FaultSchedule(((0, T.FaultSet()), (60, f1), (120, f2),
                               (180, f1)))          # shrink back to f1
        sch.validate(net, mode)
        edges = R.assert_schedule_deadlock_free(net, mode, True, rng, sch,
                                                n_pairs=900)
        assert len(edges) == 4 and all(e > 0 for e in edges)


def test_static_repair_schedule_bit_identical_to_cold(small_net):
    """Acceptance: a repair-structured schedule whose fault set never
    changes reproduces the equivalent cold run bit-for-bit, in the same
    single-compile grid as genuinely shrinking lanes."""
    net = small_net
    f = _link_faults(net, 0.08, 19)
    cfg = SimConfig(warmup=80, measure=320, vc_mode="updown",
                    vcs_per_class=2)
    sim = Simulator(net, cfg, TR.uniform(net))
    # repair shape (grow @150, repair @300) with identical sets: the
    # engine must treat the two epoch swaps as no-ops
    static_repair = T.FaultSchedule(((0, f), (150, f), (300, f)))
    shrinking = T.FaultSchedule(((0, f), (150, _link_faults(
        net, 0.05, 23, base=f)), (300, f)))
    before = sweep_mod.compile_counter()
    grid = sim.sweep_faults(0.3, [f, static_repair, shrinking],
                            seeds=(0, 1))
    assert sweep_mod.compile_counter() - before == 1
    for j in range(2):
        cold, rep = grid.result(0, j), grid.result(1, j)
        assert rep.delivered_pkts == cold.delivered_pkts
        assert rep.generated_pkts == cold.generated_pkts
        assert rep.dropped_pkts == cold.dropped_pkts
        assert rep.avg_latency == cold.avg_latency
        assert rep.hops_by_type == cold.hops_by_type


def test_faultspec_level_static_repair_matches_pristine(small_net):
    """A sampled repair schedule that never grows (num=0) runs the
    repair machinery end to end and matches the pristine run exactly."""
    from repro.exp import FaultSpec
    net = small_net
    sch = FaultSpec(kind="routers", num=0, onsets=(60,), repairs=(140,),
                    per_seed=False).sample(net, "updown")
    assert sch.num_epochs == 3 and all(s.is_empty for _, s in sch.epochs)
    cfg = SimConfig(warmup=50, measure=250, vc_mode="updown",
                    vcs_per_class=2)
    sim = Simulator(net, cfg, TR.uniform(net))
    r_sch = sim.run(0.3, faults=sch)
    r_prist = sim.run(0.3)
    assert r_sch.delivered_pkts == r_prist.delivered_pkts
    assert r_sch.generated_pkts == r_prist.generated_pkts
    assert r_sch.avg_latency == r_prist.avg_latency


def test_repair_recovers_delivery(small_net):
    """Repairing a dead router mid-run recovers delivery: without the
    repair, every packet destined to its terminals strands forever;
    with it, the stranded population revives and delivers (the
    deterministic form of the recovery effect — link-fault repair gains
    drown in contention noise on a net this small)."""
    net = small_net
    rf = _router_faults(net, 2, 29)
    cfg = SimConfig(warmup=0, measure=800, vc_mode="updown",
                    vcs_per_class=2)
    sim = Simulator(net, cfg, TR.uniform(net))
    warm = T.FaultSchedule(((0, T.FaultSet()), (150, rf)))
    repaired = T.FaultSchedule(((0, T.FaultSet()), (150, rf),
                                (350, T.FaultSet())))
    r_warm = sim.run(0.25, faults=warm)
    r_rep = sim.run(0.25, faults=repaired)
    assert r_warm.stranded_pkts > 0
    assert r_rep.stranded_pkts == 0
    assert r_rep.delivered_pkts > r_warm.delivered_pkts
    assert r_warm.dropped_pkts == r_rep.dropped_pkts == 0


# --- conservation matrix: fault lifecycle x step impl ------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("fkind", ["pristine", "cold", "warm", "repair"])
def test_conservation_matrix(small_net, fkind, impl):
    """The conservation invariant holds at every cycle for every fault
    lifecycle on every step impl, and the network drains completely once
    injection stops (link faults keep destinations alive: nothing
    strands, nothing is reaped)."""
    net = small_net
    f = _link_faults(net, 0.10, 31)
    faults = dict(
        pristine=None,
        cold=f,
        warm=T.FaultSchedule(((0, T.FaultSet()), (40, f))),
        repair=T.FaultSchedule(((0, T.FaultSet()), (30, f),
                                (90, T.FaultSet()))))[fkind]
    cfg = SimConfig(warmup=0, measure=1, vc_mode="updown",
                    vcs_per_class=2, step_impl=impl)
    trace = conservation_trace(net, cfg, faults=faults, cycles=560,
                               rate=0.06, stop_inject_at=100)
    last = trace[-1]
    assert last["generated"] > 100
    assert last["inflight"] == 0, "network must drain once injection stops"
    assert last["reaped"] == 0 and last["stranded"] == 0


def test_conservation_across_repair_boundary_strands_then_revives(small_net):
    """Router death strands parked packets on the gauge; the repair
    epoch revives them (reaper off: nothing is ever dropped or reaped,
    the stranded population returns to flight and delivers)."""
    net = small_net
    rf = _router_faults(net, 2, 37)
    sch = T.FaultSchedule(((0, T.FaultSet()), (40, rf),
                           (160, T.FaultSet())))
    cfg = SimConfig(warmup=0, measure=1, vc_mode="updown", vcs_per_class=2)
    trace = conservation_trace(net, cfg, faults=sch, cycles=700,
                               rate=0.05, stop_inject_at=90)
    assert max(r["stranded"] for r in trace) > 0, "router death must strand"
    last = trace[-1]
    assert last["inflight"] == 0 and last["stranded"] == 0
    assert last["reaped"] == 0 and last["dropped"] == 0
    assert last["generated"] == last["delivered"]


# --- router-death reaper -----------------------------------------------------

def test_resolve_reap_age_precedence(monkeypatch):
    monkeypatch.delenv("REPRO_REAP_AGE", raising=False)
    assert resolve_reap_age(SimConfig()) == 0
    assert resolve_reap_age(SimConfig(reap_age=25)) == 25
    monkeypatch.setenv("REPRO_REAP_AGE", "40")
    assert resolve_reap_age(SimConfig()) == 40
    assert resolve_reap_age(SimConfig(reap_age=25)) == 25
    with pytest.raises(ValueError):
        SimConfig(reap_age=-1)


def test_reaper_spec_threads_to_simconfig():
    from repro.exp import ReaperSpec, RoutingSpec, SweepAxes
    axes = SweepAxes(rates=(0.3,), warmup=10, measure=20)
    rs = RoutingSpec(reaper={"park_age": 30})
    assert rs.reaper == ReaperSpec(park_age=30)
    assert rs.to_simconfig(axes).reap_age == 30
    assert RoutingSpec.from_dict(rs.to_dict()) == rs
    assert RoutingSpec().to_simconfig(axes).reap_age == 0
    with pytest.raises(ValueError):
        ReaperSpec(park_age=-1)


def test_reaper_drains_stranded_population(small_net):
    """Acceptance (small-scale form): with the reaper on, a router-death
    run's stranded gauge is non-increasing once the park age elapses
    after the last injection, drains to zero, and the books balance
    exactly — identically on jnp, fused, and compact."""
    net = small_net
    rf = _router_faults(net, 2, 41)
    sch = T.FaultSchedule(((0, T.FaultSet()), (50, rf)))
    reap_age, stop = 60, 120
    traces = {}
    for impl in IMPLS:
        cfg = SimConfig(warmup=0, measure=1, vc_mode="updown",
                        vcs_per_class=2, step_impl=impl,
                        reap_age=reap_age)
        traces[impl] = conservation_trace(net, cfg, faults=sch,
                                          cycles=640, rate=0.05,
                                          stop_inject_at=stop)
    for impl, trace in traces.items():
        last = trace[-1]
        assert last["reaped"] > 0, f"{impl}: router death must reap"
        assert max(r["stranded"] for r in trace) > 0
        # every injected packet has itime < stop, so by stop + reap_age
        # every parked packet has been reaped: the gauge hits zero and
        # stays there (non-increasing => bounded steady state)
        settled = [r["stranded"] for r in trace if r["t"] >= stop + reap_age]
        assert settled and all(s == 0 for s in settled), impl
        assert all(a >= b for a, b in zip(settled, settled[1:])), impl
        assert last["inflight"] == 0 and last["stranded"] == 0
        assert last["generated"] == (last["delivered"] + last["dropped"]
                                     + last["reaped"])
    # the reaper is bit-identical across the three step impls
    assert traces["fused"] == traces["jnp"]
    assert traces["compact"] == traces["jnp"]


@pytest.mark.slow
def test_reaper_drains_at_radix32(small_net):
    """Acceptance (paper-scale form): the same drain property on the
    radix-32-class network of the yield benchmark."""
    from repro.exp import TopologySpec
    net = TopologySpec.preset("radix32_switchless", g=2,
                              label="rel-radix32").build()
    rf = _router_faults(net, 4, 43)
    sch = T.FaultSchedule(((0, T.FaultSet()), (60, rf)))
    reap_age, stop = 80, 160
    cfg = SimConfig(warmup=0, measure=1, vc_mode="updown",
                    vcs_per_class=2, step_impl="fused",
                    reap_age=reap_age)
    trace = conservation_trace(net, cfg, faults=sch, cycles=1350,
                               rate=0.06, stop_inject_at=stop)
    last = trace[-1]
    assert last["reaped"] > 0 and max(r["stranded"] for r in trace) > 0
    settled = [r["stranded"] for r in trace if r["t"] >= stop + reap_age]
    assert settled and all(s == 0 for s in settled)
    assert last["inflight"] == 0
    assert last["generated"] == (last["delivered"] + last["dropped"]
                                 + last["reaped"])


def test_reaper_respects_park_age(small_net):
    """No packet is reaped before its generation age reaches the park
    age: a pristine run (nothing ever parks) reaps nothing even with an
    aggressive reaper, and a longer park age reaps no more packets than
    a shorter one on the same fault run."""
    net = small_net
    cfg = SimConfig(warmup=0, measure=1, vc_mode="updown",
                    vcs_per_class=2, reap_age=5)
    trace = conservation_trace(net, cfg, cycles=200, rate=0.08,
                               stop_inject_at=150)
    assert trace[-1]["reaped"] == 0
    rf = _router_faults(net, 2, 41)
    sch = T.FaultSchedule(((0, T.FaultSet()), (50, rf)))
    reaped = {}
    for age in (40, 120):
        cfg = SimConfig(warmup=0, measure=1, vc_mode="updown",
                        vcs_per_class=2, reap_age=age)
        reaped[age] = conservation_trace(
            net, cfg, faults=sch, cycles=400, rate=0.08,
            stop_inject_at=150)[-1]["reaped"]
    assert reaped[40] >= reaped[120] > 0


# --- wafer-fleet Monte Carlo -------------------------------------------------

def test_fleet_spec_validates():
    from repro.exp import FaultSpec, FleetSpec, RoutingSpec, TopologySpec
    topo = TopologySpec.switchless(a=1, b=2, m=2, n=4, noc=2, g=4,
                                   label="fleet-t")
    routing = RoutingSpec(vc_mode="updown", vcs_per_class=2)
    ok = FleetSpec(name="f", topology=topo, routing=routing,
                   levels=(FaultSpec(),
                           FaultSpec(kind="routers", num=1, seed=1)),
                   samples=4)
    assert ok.samples == 4
    with pytest.raises(ValueError, match="per_seed"):
        FleetSpec(name="f", topology=topo, routing=routing,
                  levels=(FaultSpec(kind="routers", num=1,
                                    per_seed=False),))
    with pytest.raises(ValueError):
        FleetSpec(name="f", topology=topo, routing=routing,
                  levels=(FaultSpec(),), samples=0)
    with pytest.raises(ValueError):
        FleetSpec(name="f", topology=topo, routing=routing,
                  levels=(FaultSpec(),), yield_threshold=1.5)
    assert FleetSpec.from_dict(ok.to_dict()) == ok


def test_fleet_lowers_to_seed_lanes_and_is_registered():
    from repro.exp import get_scenario, list_scenarios
    from repro.exp.fleet import smoke_fleet
    fleet = smoke_fleet()
    exp = fleet.to_experiment()
    assert exp.axes.seeds == tuple(range(fleet.samples))
    assert exp.axes.rates == (fleet.offered,)
    assert len(exp.axes.faults) == len(fleet.levels)
    # registered under the fleet's name -> covered by `check --spec`
    assert "smoke_fleet" in list_scenarios()
    assert get_scenario("smoke_fleet").axes == exp.axes


def test_fleet_inbox_is_multi_tenant(tmp_path):
    from repro.exp import ExperimentSpec, FaultSpec, FleetSpec, \
        RoutingSpec, TopologySpec, fleet_inbox
    fleet = FleetSpec(
        name="inboxed",
        topology=TopologySpec.switchless(a=1, b=2, m=2, n=4, noc=2, g=4,
                                         label="fleet-t"),
        routing=RoutingSpec(vc_mode="updown", vcs_per_class=2),
        levels=(FaultSpec(), FaultSpec(kind="routers", num=1, seed=1)),
        samples=3)
    paths = fleet_inbox(fleet, str(tmp_path))
    assert len(paths) == 3
    tenants = set()
    for i, p in enumerate(sorted(paths)):
        sub = json.loads(open(p).read())
        tenants.add(sub["tenant"])
        spec = ExperimentSpec.from_dict(sub["spec"])
        assert spec.axes.seeds == (i,)      # one wafer per submission
        assert spec.axes.faults == fleet.to_experiment().axes.faults
    assert tenants == {"wafer0", "wafer1", "wafer2"}


def test_run_fleet_end_to_end_shares_executables():
    from repro.exp import FaultSpec, FleetSpec, RoutingSpec, TopologySpec
    from repro.exp.fleet import run_fleet
    fleet = FleetSpec(
        name="tiny_fleet",
        topology=TopologySpec.switchless(a=1, b=2, m=2, n=4, noc=2, g=4,
                                         label="fleet-t"),
        routing=RoutingSpec(vc_mode="updown", vcs_per_class=2,
                            reaper={"park_age": 50}),
        levels=(FaultSpec(), FaultSpec(kind="routers", num=1, seed=1)),
        samples=4, offered=0.3, warmup=30, measure=150)
    res = run_fleet(fleet)
    assert len(res.records) == 2
    for rec in res.records:
        assert rec["samples"] == 4
        assert set(rec["throughput"]) == {"p10", "p50", "p90"}
        assert rec["compile_count"] <= 1
        assert rec["yield_frac"] <= 1.0
    prist, faulty = res.records
    assert prist["reaped_total"] == 0
    assert prist["throughput"]["p50"] >= faulty["throughput"]["p50"]
