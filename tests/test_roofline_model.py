"""Validate the analytic roofline FLOPs model against XLA cost_analysis
at smoke scale with a single scan group (where the scan-once counting of
HloCostAnalysis is exact)."""
import dataclasses
import os
import sys
from functools import partial

import jax
import jax.numpy as jnp
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.registry import get_config
from repro.models import transformer as TF


def test_analytic_flops_match_hlo_single_group():
    cfg = dataclasses.replace(get_config("llama3.2-3b-smoke"),
                              num_layers=1, vocab_size=512)
    B, S = 2, 64
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_sds = jax.eval_shape(partial(TF.init_params, cfg=cfg), key_sds)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def loss(p, b):
        return TF.lm_loss(p, cfg, b, attn_impl="naive", remat=False)[0]

    grad = jax.jit(jax.grad(loss))
    compiled = grad.lower(params_sds, batch).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax returns [dict]
        ca = ca[0] if ca else {}
    hlo_flops = ca.get("flops", 0.0)

    tokens = B * S
    n = cfg.num_params()
    analytic = 6 * n * tokens \
        + 12 * B * S * (S / 2) * cfg.num_heads * cfg.hd * cfg.num_layers
    # HLO counts matmul FLOPs (2mnk); elementwise/softmax add some slack
    assert hlo_flops > 0
    ratio = analytic / hlo_flops
    assert 0.4 < ratio < 2.5, (analytic, hlo_flops, ratio)
