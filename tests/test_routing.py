"""Routing correctness: delivery, hop bounds (Eq. 7), VC bounds, and
deadlock freedom via channel-dependency-graph acyclicity (Sec. IV)."""
import numpy as np
import pytest

from repro.core import routing as R
from repro.core import topology as T


@pytest.fixture(scope="module")
def net():
    return T.build_switchless(T.SwitchlessParams(a=2, b=2, m=2, n=4, noc=2,
                                                 g=5))


@pytest.fixture(scope="module")
def dnet():
    return T.build_switch_dragonfly(T.SwitchDragonflyParams(t=2, l=3, gl=2,
                                                            g=5))


def _all_pairs(net, limit=30000, seed=0):
    Tn = net.num_terminals
    if Tn * Tn <= limit:
        s, d = np.divmod(np.arange(Tn * Tn), Tn)
    else:
        rng = np.random.default_rng(seed)
        s = rng.integers(0, Tn, size=limit)
        d = rng.integers(0, Tn, size=limit)
    keep = s != d
    return s[keep], d[keep]


def test_minimal_paths_deliver_and_respect_diameter(net):
    """Eq. (7): inter-C-group hops <= 1 global + 2 local; intra-C-group
    hops <= 8m - 2 mesh hops (+ inject/eject)."""
    p = T.SwitchlessParams(**{k: v for k, v in
                              net.meta["params"].items()})
    route_fn = R.make_route_fn(net, "baseline")
    s, d = _all_pairs(net, limit=20000)
    mis = np.full(len(s), -1)
    chans, vcs, lengths = R.trace_paths(net, route_fn, s, d, mis)
    types = np.where(chans >= 0, net.ch_type[np.clip(chans, 0, None)], -1)
    n_global = (types == T.GLOBAL).sum(axis=1)
    n_local = (types == T.LOCAL).sum(axis=1)
    n_mesh = (types == T.MESH).sum(axis=1)
    assert (n_global <= 1).all()
    assert (n_local <= 2).all()
    # Eq. (7) at router granularity: 4 C-group transits x XY diameter
    # 2(R-1).  (The paper counts chiplet-level hops, 8m-2 with SR-LR
    # conversions; our SR-LR conversion cost lives in the LR link latency.)
    assert (n_mesh <= 4 * 2 * (p.R - 1)).all()
    # every path ends with an ejection at the right terminal
    last = chans[np.arange(len(s)), lengths - 1]
    assert (net.ch_type[last] == T.EJECT).all()
    # eject channel of terminal d
    assert (last == net.eject_ch[net.term_node[d]] ).all()


def test_vc_counts(net):
    """Baseline minimal uses <= 4 VCs (Sec. IV-A); our W-group-wide
    up*/down* scheme uses <= 2 (beyond the paper's 3, Sec. IV-B)."""
    s, d = _all_pairs(net, limit=20000)
    mis = np.full(len(s), -1)
    for mode, bound in [("baseline", 4), ("updown", 2),
                        ("updown_merged", 2)]:
        route_fn = R.make_route_fn(net, mode)
        _, vcs, _ = R.trace_paths(net, route_fn, s, d, mis)
        assert int(vcs.max()) + 1 <= bound, mode


def test_vc_counts_nonminimal(net):
    rng = np.random.default_rng(1)
    s, d = _all_pairs(net, limit=8000)
    g = net.meta["g"]
    wg = net.tables["node_wg"]
    wg_s, wg_d = wg[net.term_node[s]], wg[net.term_node[d]]
    mis = rng.integers(0, g, size=len(s))
    mis = np.where((mis == wg_s) | (mis == wg_d), -1, mis)
    for mode, bound in [("baseline", 6), ("updown", 3)]:
        route_fn = R.make_route_fn(net, mode)
        _, vcs, _ = R.trace_paths(net, route_fn, s, d, mis)
        assert int(vcs.max()) + 1 <= bound, mode


@pytest.mark.parametrize("mode,nonmin", [
    ("baseline", False), ("baseline", True),
    ("updown", False), ("updown", True),
    ("updown_merged", False), ("updown_merged", True),
])
def test_deadlock_freedom_switchless(net, mode, nonmin):
    rng = np.random.default_rng(7)
    edges = R.assert_deadlock_free(net, mode, nonmin, rng, n_pairs=6000)
    assert edges > 0


@pytest.mark.parametrize("nonmin", [False, True])
def test_deadlock_freedom_dragonfly(dnet, nonmin):
    rng = np.random.default_rng(7)
    edges = R.assert_deadlock_free(dnet, "baseline", nonmin, rng,
                                   n_pairs=6000)
    assert edges > 0


def test_deadlock_freedom_larger_net():
    """Paper radix-16 evaluation network (subset of W-groups)."""
    net = T.build_switchless(T.paper_radix16_switchless(g=7))
    rng = np.random.default_rng(3)
    for mode, nonmin in [("baseline", True), ("updown", True),
                         ("updown_merged", True)]:
        R.assert_deadlock_free(net, mode, nonmin, rng, n_pairs=5000)


def test_updown_paths_near_minimal(net):
    """up*/down* detours are bounded: mean hops within 35% of XY-minimal."""
    s, d = _all_pairs(net, limit=12000)
    mis = np.full(len(s), -1)
    base = R.make_route_fn(net, "baseline")
    ud = R.make_route_fn(net, "updown")
    _, _, len_b = R.trace_paths(net, base, s, d, mis)
    _, _, len_u = R.trace_paths(net, ud, s, d, mis)
    assert len_u.mean() <= 1.35 * len_b.mean()


def test_dragonfly_minimal_three_hops(dnet):
    route_fn = R.make_route_fn(dnet, "baseline")
    s, d = _all_pairs(dnet, limit=20000)
    mis = np.full(len(s), -1)
    chans, _, lengths = R.trace_paths(dnet, route_fn, s, d, mis)
    # inject + (<= l,g,l) + eject
    assert lengths.max() <= 5
    types = np.where(chans >= 0, dnet.ch_type[np.clip(chans, 0, None)], -1)
    assert ((types == T.GLOBAL).sum(axis=1) <= 1).all()
    assert ((types == T.LOCAL).sum(axis=1) <= 2).all()


def test_misroute_clears_and_delivers(net):
    """Non-minimal paths visit the intermediate W-group then deliver."""
    rng = np.random.default_rng(11)
    route_fn = R.make_route_fn(net, "baseline")
    wg = net.tables["node_wg"]
    Tn = net.num_terminals
    s = rng.integers(0, Tn, 500)
    d = rng.integers(0, Tn, 500)
    wg_s, wg_d = wg[net.term_node[s]], wg[net.term_node[d]]
    keep = wg_s != wg_d
    s, d, wg_s, wg_d = s[keep], d[keep], wg_s[keep], wg_d[keep]
    g = net.meta["g"]
    mis = (np.maximum(wg_s, wg_d) + 1) % g
    ok = (mis != wg_s) & (mis != wg_d)
    s, d, mis = s[ok], d[ok], mis[ok]
    chans, _, lengths = R.trace_paths(net, route_fn, s, d, mis)
    types = np.where(chans >= 0, net.ch_type[np.clip(chans, 0, None)], -1)
    # two global hops: src W-group -> mis W-group -> dest W-group
    assert ((types == T.GLOBAL).sum(axis=1) == 2).all()
    last = chans[np.arange(len(s)), lengths - 1]
    assert (last == net.eject_ch[net.term_node[d]]).all()
